//! Bench-trajectory store walkthrough: capture a fleet run's metrics
//! with a scoped registry, shape its headline numbers like a
//! `BENCH_*.json` artifact, ingest three successive "nightly runs" into
//! a content-hashed index, query the p99 trajectory back out, and watch
//! the diff gate stay clean across a healthy re-run, then catch an
//! injected tail regression (no model execution, no artifacts, fast).
//!
//!   cargo run --release --example bench_log

use qaci::bench_harness::Table;
use qaci::fleet::churn::{self, ChurnConfig, ChurnPolicy};
use qaci::fleet::events;
use qaci::obs::benchlog::{self, BenchLog, DiffOptions, Query};
use qaci::obs::metrics;
use qaci::system::Platform;
use qaci::util::json::Json;

fn main() {
    // one real (short) churn run, with the ambient metrics captured —
    // the same numbers `qaci fleet --churn --metrics-out` would export
    let cfg = ChurnConfig { horizon_s: 240.0, seed: 1, ..ChurnConfig::default() };
    let tl = churn::timeline(&cfg);
    let ((an, ev), captured) = metrics::scoped(|| {
        let an = churn::run_churn(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
        let ev = events::run_events(Platform::fleet_edge(), &tl, ChurnPolicy::Online, &cfg);
        (an, ev)
    });
    println!(
        "fleet run: cost {:.4e}, {} re-solves ({} skipped via warm-start fingerprint), \
         {} arrivals, e2e p99 {:.3}s",
        an.time_avg_cost,
        an.reallocations,
        an.realloc_skipped,
        ev.arrivals,
        ev.e2e_s.p99()
    );
    println!(
        "captured metrics: bisection.calls={}, warm_start hit/miss {}/{}, queue.wait_s n={}",
        captured.counter("solver.bisection.calls"),
        captured.counter("solver.warm_start.hit"),
        captured.counter("solver.warm_start.miss"),
        captured.histogram("queue.wait_s").map_or(0, |h| h.len())
    );

    // shape the headline numbers into a bench-artifact payload and
    // ingest three "nightly runs" into a fresh index: two healthy (the
    // second marginally faster), one with a synthetic 10x tail blowup
    let dir = std::env::temp_dir().join(format!("qaci-benchlog-example-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let index = BenchLog::open(dir.join("index.jsonl"));
    let _ = std::fs::remove_file(index.path());
    let p99 = ev.e2e_s.p99();
    for (night, tail) in [("night-1", p99), ("night-2", p99 * 0.97), ("night-3", p99 * 10.0)] {
        let payload = artifact(an.time_avg_cost, tail);
        let entry = index.ingest("fleet_churn", "bench", &payload).unwrap();
        println!("{night}: ingested as seq {} ({})", entry.seq, entry.digest);
    }
    // the metrics snapshot rides in the same index under its own kind
    let snap = index.ingest("fleet_churn_metrics", "metrics", &captured.to_json()).unwrap();
    println!("metrics snapshot: seq {} kind {}", snap.seq, snap.kind);

    // query the trajectory back out
    let q = Query {
        scenario: Some("storm".into()),
        policy: Some("online-proposed".into()),
        field: "p99_s".into(),
        ..Query::default()
    };
    let mut t = Table::new("p99_s trajectory (one row per ingested run)", &["seq", "p99_s"]);
    for row in index.query(&q).unwrap() {
        t.row(&[format!("{}", row.seq), format!("{:.3}", row.value.unwrap_or(f64::NAN))]);
    }
    t.print();

    // night-1 -> night-2 was healthy; night-2 -> night-3 blew the tail
    // past the value-regression headroom
    let healthy = BenchLog::open(dir.join("healthy.jsonl"));
    let _ = std::fs::remove_file(healthy.path());
    healthy.ingest("fleet_churn", "bench", &artifact(an.time_avg_cost, p99)).unwrap();
    healthy.ingest("fleet_churn", "bench", &artifact(an.time_avg_cost, p99 * 0.97)).unwrap();
    let opts = DiffOptions::default();
    let clean = benchlog::diff_latest_pair(&healthy, &opts).unwrap();
    println!("\nhealthy night-over-night diff: {} finding(s)", clean.len());
    assert!(clean.is_empty());
    let findings = benchlog::diff_latest_pair(&index, &opts).unwrap();
    println!("regressed night-over-night diff:");
    for f in &findings {
        println!("  {f}");
    }
    assert!(findings.iter().any(|f| f.kind == "regression"));
    println!(
        "\nOK: identical/improved runs gate clean, the injected tail blowup is caught \
         (CI runs the same gate via `qaci bench-log diff --fail-on-regression`)"
    );
}

/// A two-row artifact payload in the `BENCH_fleet_churn.json` shape: the
/// online policy against a frozen static whose tail does not move.
fn artifact(cost: f64, online_p99: f64) -> Json {
    let row = |policy: &str, cost: f64, p99: f64| {
        Json::obj()
            .set("scenario", "storm")
            .set("policy", policy)
            .set("cost", cost)
            .set("p99_s", p99)
    };
    Json::obj().set("bench", "fleet_churn").set("version", 1.0).set(
        "results",
        Json::Arr(vec![
            row("online-proposed", cost, online_p99),
            row("static-proposed", cost * 4.0, 600.0),
        ]),
    )
}
