//! End-to-end embodied-captioning driver — the full system on a real
//! workload (DESIGN.md "end-to-end validation" deliverable).
//!
//! Loads the trained BLIP-2-like captioner, serves a Poisson request
//! stream through the complete coordinator (router → scheduler → batcher →
//! quantized agent encoder → simulated 5 GHz WLAN → server decoder), for
//! all four design algorithms, and reports CIDEr / simulated delay &
//! energy / wall-clock throughput per algorithm.
//!
//!   cargo run --release --example embodied_captioning [-- --requests 64]

use qaci::bench_harness::Table;
use qaci::coordinator::batcher::BatcherConfig;
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::quant::Scheme;
use qaci::rl::env::BudgetRanges;
use qaci::rl::PpoConfig;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::channel::Channel;
use qaci::system::Platform;
use qaci::util::cli::Args;
use qaci::util::timer::Stopwatch;

fn main() -> anyhow::Result<()> {
    let args = Args::parse_env();
    let n_requests = args.usize("requests", 48);
    let reg = Registry::open(&qaci::artifacts_dir())?;
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco")?;
    let vocab = Vocab::from_manifest(&reg.manifest)?;
    let mut model = CoModel::load(&reg, "blip2ish")?;
    let platform = Platform::paper_blip2()
        .with_workload(model.agent_flops, model.server_flops);
    let lambda = model.agent_weights.lambda;

    // QoS budgets scaled to this platform's measured FLOPs: interactive is
    // delay-tight, background is energy-tight
    let t_scale = platform.min_delay(16.0);
    let e_ref = qaci::system::energy::total_energy(
        &platform, 8.0, platform.device.f_max / 2.0, platform.server.f_max / 2.0);
    let policy = QosPolicy::new(&[
        ("interactive", 1.2 * t_scale, 8.0 * e_ref),
        ("standard", 2.0 * t_scale, 2.0 * e_ref),
        ("background", 6.0 * t_scale, 0.5 * e_ref),
    ]);

    println!(
        "embodied captioning: {} requests over {} eval scenes, λ={lambda:.1}",
        n_requests,
        eval.len()
    );
    let mut table = Table::new(
        "end-to-end co-inference (BLIP-2-like on COCO-like)",
        &[
            "algorithm",
            "CIDEr(x100)",
            "mean b̂",
            "sim T p95 [ms]",
            "sim E mean [mJ]",
            "wall [req/s]",
            "QoS viol",
        ],
    );

    for alg in [
        Algorithm::Proposed,
        Algorithm::Ppo,
        Algorithm::FixedFreq,
        Algorithm::FeasibleRandom,
    ] {
        let mut scheduler = Scheduler::new(platform, lambda, alg, Scheme::Uniform, 11);
        if alg == Algorithm::Ppo {
            let ranges = BudgetRanges {
                t0: (0.8 * t_scale, 7.0 * t_scale),
                e0: (0.3 * e_ref, 10.0 * e_ref),
            };
            scheduler.train_ppo(ranges, PpoConfig::default());
        }
        let router = Router::new(policy.clone(), scheduler);
        let requests = generate(
            n_requests,
            eval.len(),
            Arrival::Poisson { lambda_rps: 100.0 },
            17,
        );
        let mut engine = Engine::new(
            &mut model,
            router,
            &vocab,
            &eval,
            Channel::wlan_5ghz(5),
            EngineConfig { batcher: BatcherConfig { max_batch: 4, max_wait_s: 0.02 } },
        );
        let sw = Stopwatch::start();
        let telemetry = engine.run(requests)?;
        let wall = sw.elapsed_s();

        let mean_bits: f64 = telemetry.records.iter().map(|r| r.b_hat as f64).sum::<f64>()
            / telemetry.len().max(1) as f64;
        let mut delays = qaci::util::timer::Samples::new();
        for r in &telemetry.records {
            delays.push(r.t_sim_total() * 1e3);
        }
        table.row(&[
            format!("{} ({} rejected)", alg.name(), telemetry.rejected),
            format!("{:.1}", telemetry.cider_x100(&eval.refs)),
            format!("{mean_bits:.1}"),
            format!("{:.2}", delays.p95()),
            format!("{:.3}", telemetry.total_energy_j() / telemetry.len().max(1) as f64 * 1e3),
            format!("{:.1}", telemetry.len() as f64 / wall),
            format!("{}", telemetry.qos_violations()),
        ]);
    }
    table.print();

    // show a few captions from the proposed configuration
    println!("\nsample captions (proposed design, standard class):");
    let mut scheduler = Scheduler::new(platform, lambda, Algorithm::Proposed, Scheme::Uniform, 11);
    let (t0, e0) = policy.budget("standard").unwrap();
    let plan = scheduler.plan(t0, e0).unwrap();
    for i in 0..4.min(eval.len()) {
        let toks = model.infer(eval.sample(i), 1, plan.design.b_hat, Scheme::Uniform)?;
        println!("  scene {i}: \"{}\"", vocab.detokenize(&toks[0]));
        println!("      ref: \"{}\"", eval.refs[i][0]);
    }
    Ok(())
}
