//! Churn walkthrough: watch the online allocator follow a changing
//! population while the static t=0 allocations decay — joins are turned
//! away, leavers strand their shares, and load bursts blow frozen
//! queue-aware delay budgets (no model execution, no artifacts, fast).
//! The same timeline is then replayed at the request level to show what
//! the tails looked like from inside the traffic.
//!
//!   cargo run --release --example fleet_churn

use qaci::bench_harness::Table;
use qaci::fleet::churn::{self, ChurnConfig, ChurnEvent, ChurnPolicy};
use qaci::fleet::events;
use qaci::system::Platform;

fn main() {
    let cfg = ChurnConfig { horizon_s: 400.0, seed: 1, ..ChurnConfig::default() };
    let timeline = churn::timeline(&cfg);
    println!(
        "churn timeline: {} events over {:.0}s ({} joins, {} leaves, {} bursts), \
         N0={} agents, queue-aware allocator",
        timeline.events.len(),
        cfg.horizon_s,
        timeline.joins,
        timeline.leaves,
        timeline.bursts,
        cfg.initial_agents
    );
    for &(t, event) in timeline.events.iter().filter(|(_, e)| *e != ChurnEvent::Tick) {
        let what = match event {
            ChurnEvent::Join(k) => format!("agent {k} joins"),
            ChurnEvent::Leave(k) => format!("agent {k} leaves"),
            ChurnEvent::BurstStart(k) => {
                format!("agent {k} bursts x{:.0}", cfg.burst_factor)
            }
            ChurnEvent::BurstEnd(k) => format!("agent {k} burst ends"),
            ChurnEvent::Tick => unreachable!("ticks filtered"),
        };
        println!("  t={t:6.1}s  {what}");
    }

    let reports: Vec<_> = ChurnPolicy::ALL
        .into_iter()
        .map(|p| churn::run_churn(Platform::fleet_edge(), &timeline, p, &cfg))
        .collect();

    let mut t = Table::new(
        "policy outcome (time-averaged fleet-weighted cost; lower is better)",
        &["policy", "avg cost", "avg D^U", "reallocs", "skipped", "final admitted"],
    );
    for r in &reports {
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.4e}", r.time_avg_cost),
            format!("{:.4e}", r.time_avg_d_upper),
            format!("{}", r.reallocations),
            format!("{}", r.realloc_skipped),
            format!("{}/{}", r.final_alloc.admitted, r.final_population),
        ]);
    }
    t.print();

    // the online cost trace: how the fleet cost rate moved per event
    let online = reports.iter().find(|r| r.policy == ChurnPolicy::Online).unwrap();
    let statik = reports.iter().find(|r| r.policy == ChurnPolicy::StaticProposed).unwrap();
    println!("\ncost-rate trace (online vs static-proposed):");
    for (o, s) in online.cost_trace.iter().zip(&statik.cost_trace) {
        println!("  t={:6.1}s  online {:.4e}   static {:.4e}", o.0, o.1, s.1);
    }
    let equal = reports.iter().find(|r| r.policy == ChurnPolicy::StaticEqual).unwrap();
    let best_static = statik.time_avg_cost.min(equal.time_avg_cost);
    println!(
        "\nonline beats the best static policy by {:.1}% on time-averaged cost",
        (1.0 - online.time_avg_cost / best_static) * 100.0
    );

    // the same timeline from the requests' point of view: per-policy
    // tail telemetry (rejected / departure-dropped requests count as
    // deadline violations — they never completed)
    let mut et = Table::new(
        "event-level tails (per-request replay of the same timeline)",
        &["policy", "arrivals", "completed", "e2e p50", "e2e p99", "wait p99", "viol %"],
    );
    for policy in ChurnPolicy::ALL {
        let r = events::run_events(Platform::fleet_edge(), &timeline, policy, &cfg);
        let pct = |s: &qaci::util::timer::Samples, p: f64| {
            if s.is_empty() { "--".into() } else { format!("{:.2}s", s.percentile(p)) }
        };
        et.row(&[
            r.policy.name().to_string(),
            format!("{}", r.arrivals),
            format!("{}", r.completed),
            pct(&r.e2e_s, 50.0),
            pct(&r.e2e_s, 99.0),
            pct(&r.queue_wait_s, 99.0),
            format!("{:.1}", r.violation_rate() * 100.0),
        ]);
    }
    et.print();
    println!(
        "\nthe static rows serve only the surviving t=0 agents (joiners rejected); the\n\
         online row serves the whole churned population — compare its violation rate"
    );
}
