//! Fleet sweep: how admission, per-class bit-widths and the
//! fleet-weighted distortion move as N agents contend for one edge
//! server and one wireless medium — the multi-agent allocator in
//! isolation (no model execution, no artifacts, fast).
//!
//!   cargo run --release --example fleet_sweep

use qaci::bench_harness::Table;
use qaci::opt::fleet::{self, AgentSpec, FleetAlgorithm, FleetProblem, SolveRequest};
use qaci::system::Platform;

fn req(algorithm: FleetAlgorithm) -> SolveRequest {
    SolveRequest { algorithm, seed: 42, ..SolveRequest::default() }
}

fn main() {
    let base = Platform::fleet_edge();
    println!(
        "fleet platform: shared edge server f̃^max={:.0} GHz (ψ̃={:.0e}), \
         shared uplink 400 Mbps, mixed interactive/standard/background fleet",
        base.server.f_max / 1e9,
        base.server.psi
    );

    // N sweep: objective + admission per algorithm
    let mut t = Table::new(
        "fleet size sweep (fleet-weighted bound gap; lower is better)",
        &["N", "proposed", "equal-share", "random (mean, 20)", "admitted prop.", "admitted equal"],
    );
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        let fp = FleetProblem::new(base, AgentSpec::mixed_fleet(n));
        let proposed = fp.solve(&SolveRequest::default());
        let equal = fp.solve(&req(FleetAlgorithm::EqualShare));
        let random = fleet::feasible_random_mean(&fp, 20, 42);
        t.row(&[
            format!("{n}"),
            format!("{:.3e}", proposed.objective),
            format!("{:.3e}", equal.objective),
            format!("{:.3e}", random),
            format!("{}/{n}", proposed.admitted),
            format!("{}/{n}", equal.admitted),
        ]);
    }
    t.print();

    // who gets what at N = 8: the water-filling outcome per class
    let n = 8;
    let fp = FleetProblem::new(base, AgentSpec::mixed_fleet(n));
    let proposed = fp.solve(&SolveRequest::default());
    let equal = fp.solve(&req(FleetAlgorithm::EqualShare));
    let mut t = Table::new(
        "per-agent outcome at N = 8 (b̂ / server share μ)",
        &["agent", "class", "weight", "proposed b̂", "proposed μ", "equal b̂", "equal μ"],
    );
    for i in 0..n {
        let fmt = |a: &fleet::AgentAllocation| match &a.design {
            Some(d) => (format!("{}", d.b_hat), format!("{:.3}", a.server_share)),
            None => ("REJ".to_string(), format!("{:.3}", a.server_share)),
        };
        let (pb, pm) = fmt(&proposed.agents[i]);
        let (eb, em) = fmt(&equal.agents[i]);
        t.row(&[
            format!("{i}"),
            fp.agents[i].class.to_string(),
            format!("{:.1}", fp.agents[i].weight),
            pb,
            pm,
            eb,
            em,
        ]);
    }
    t.print();

    // sanity echo of the headline property
    let better = FleetAlgorithm::ALL
        .into_iter()
        .map(|a| (a.name(), fp.solve(&req(a)).objective))
        .collect::<Vec<_>>();
    println!("\nobjectives at N = 8: {better:?}");
}
