//! Heterogeneous-silicon fleet: what the joint allocator buys once
//! agents stop sharing one device profile — sweep the orin/xavier/phone
//! tier ladder and watch the margin over the equal split widen with
//! silicon spread (no model execution, no artifacts, fast).
//!
//!   cargo run --release --example hetero_fleet

use qaci::bench_harness::Table;
use qaci::opt::fleet::{self, AgentSpec, FleetAlgorithm, FleetProblem, SolveRequest};
use qaci::system::Platform;

fn equal_share() -> SolveRequest {
    SolveRequest { algorithm: FleetAlgorithm::EqualShare, ..SolveRequest::default() }
}

fn main() {
    let base = Platform::fleet_edge();
    println!(
        "hetero fleet: shared edge server f̃^max={:.0} GHz, shared uplink 400 Mbps, \
         silicon ladder orin -> xavier -> phone (one QoS cycle per tier)",
        base.server.f_max / 1e9,
    );

    // spread sweep: margin over equal-share per fleet size
    let mut t = Table::new(
        "margin over equal-share (equal - proposed, fleet-weighted gap) vs tier spread",
        &["N", "uniform orin", "orin+xavier", "orin+xavier+phone"],
    );
    for n in [4usize, 5, 6, 7] {
        let margin = |spread: usize| {
            let fp = FleetProblem::new(
                base,
                AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(spread)),
            );
            fp.solve(&equal_share()).objective - fp.solve(&SolveRequest::default()).objective
        };
        t.row(&[
            format!("{n}"),
            format!("{:.3e}", margin(0)),
            format!("{:.3e}", margin(1)),
            format!("{:.3e}", margin(2)),
        ]);
    }
    t.print();

    // who gets what at N = 7 on the full ladder: the water-filling
    // outcome per class x tier, proposed vs equal
    let n = 7;
    let fp = FleetProblem::new(base, AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(2)));
    let proposed = fp.solve(&SolveRequest::default());
    let equal = fp.solve(&equal_share());
    let mut t = Table::new(
        "per-agent outcome at N = 7, full ladder (b̂ / server share μ)",
        &["agent", "class", "tier", "gain", "proposed b̂", "proposed μ", "equal b̂", "equal μ"],
    );
    for i in 0..n {
        let fmt = |a: &fleet::AgentAllocation| match &a.design {
            Some(d) => (format!("{}", d.b_hat), format!("{:.3}", a.server_share)),
            None => ("REJ".to_string(), format!("{:.3}", a.server_share)),
        };
        let (pb, pm) = fmt(&proposed.agents[i]);
        let (eb, em) = fmt(&equal.agents[i]);
        t.row(&[
            format!("{i}"),
            fp.agents[i].class.to_string(),
            fp.agents[i].device.tier.to_string(),
            format!("{:.1}", fp.agents[i].channel_gain),
            pb,
            pm,
            eb,
            em,
        ]);
    }
    t.print();

    println!(
        "\nat N = 7 the equal split starves the phone-class interactive agent entirely \
         (REJ) while the proposed design buys it a fat server slice and serves the whole \
         fleet: proposed {:.3e} vs equal {:.3e} ({} vs {} admitted)",
        proposed.objective,
        equal.objective,
        proposed.admitted,
        equal.admitted
    );
}
