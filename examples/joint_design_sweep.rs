//! Joint-design sweep: how the chosen bit-width, frequencies and the
//! rate–distortion objective move across (T0, E0) budgets, for the
//! proposed design vs every baseline — the optimizer in isolation, no
//! model execution (fast).
//!
//!   cargo run --release --example joint_design_sweep

use qaci::bench_harness::Table;
use qaci::opt::{bisection, feasible_random, fixed_freq, sca, Problem};
use qaci::system::Platform;

const LAMBDA: f64 = 15.0;

fn fmt_design(d: Option<qaci::opt::Design>) -> (String, String) {
    match d {
        Some(d) => (
            format!("{}", d.b_hat),
            format!("{:.2}/{:.2}", d.f / 1e9, d.f_tilde / 1e9),
        ),
        None => ("--".into(), "infeasible".into()),
    }
}

fn main() {
    let platform = Platform::paper_blip2();
    println!(
        "platform: paper BLIP-2 (N={:.1} GFLOP, Ñ={:.1} GFLOP, λ={LAMBDA})",
        platform.n_flop_agent / 1e9,
        platform.n_flop_server / 1e9
    );

    // delay sweep at fixed E0 = 2 J (Fig. 5-left shape)
    let mut t = Table::new(
        "delay sweep @ E0 = 2.0 J",
        &[
            "T0 [s]",
            "proposed b̂",
            "f/f̃ [GHz]",
            "exact b̂",
            "fixed-freq b̂",
            "rand mean gap",
            "proposed gap",
        ],
    );
    for t0 in [2.50, 2.75, 3.00, 3.25, 3.50, 3.75, 4.00] {
        let prob = Problem::new(platform, LAMBDA, t0, 2.0);
        let proposed = sca::solve(&prob, sca::ScaOptions::default());
        let (b_str, f_str) = fmt_design(proposed.as_ref().map(|r| r.design));
        let exact = bisection::solve(&prob);
        let ff = fixed_freq::solve(&prob);
        let rand_gap = feasible_random::mean_objective(&prob, 400, 42)
            .map(|g| format!("{g:.2e}"))
            .unwrap_or_else(|| "--".into());
        t.row(&[
            format!("{t0:.2}"),
            b_str,
            f_str,
            exact.map(|e| e.design.b_hat.to_string()).unwrap_or("--".into()),
            ff.map(|d| d.b_hat.to_string()).unwrap_or("--".into()),
            rand_gap,
            proposed
                .map(|r| format!("{:.2e}", r.objective))
                .unwrap_or_else(|| "--".into()),
        ]);
    }
    t.print();

    // energy sweep at fixed T0 = 3.5 s (Fig. 5-right shape)
    let mut t = Table::new(
        "energy sweep @ T0 = 3.5 s",
        &[
            "E0 [J]",
            "proposed b̂",
            "f/f̃ [GHz]",
            "exact b̂",
            "fixed-freq b̂",
            "rand mean gap",
            "proposed gap",
        ],
    );
    for e0 in [0.50, 1.00, 1.50, 2.00, 2.50, 3.00, 4.00] {
        let prob = Problem::new(platform, LAMBDA, 3.5, e0);
        let proposed = sca::solve(&prob, sca::ScaOptions::default());
        let (b_str, f_str) = fmt_design(proposed.as_ref().map(|r| r.design));
        let exact = bisection::solve(&prob);
        let ff = fixed_freq::solve(&prob);
        let rand_gap = feasible_random::mean_objective(&prob, 400, 42)
            .map(|g| format!("{g:.2e}"))
            .unwrap_or_else(|| "--".into());
        t.row(&[
            format!("{e0:.2}"),
            b_str,
            f_str,
            exact.map(|e| e.design.b_hat.to_string()).unwrap_or("--".into()),
            ff.map(|d| d.b_hat.to_string()).unwrap_or("--".into()),
            rand_gap,
            proposed
                .map(|r| format!("{:.2e}", r.objective))
                .unwrap_or_else(|| "--".into()),
        ]);
    }
    t.print();

    // sensitivity to model statistics (Remark 4.1: λ drives the bound)
    let mut t = Table::new(
        "λ sensitivity @ (T0=3.5, E0=2.0): same design, different distortion",
        &["λ", "b̂*", "D^U(b̂-1)", "D^L(b̂-1)", "gap"],
    );
    for lambda in [2.0, 5.0, 15.0, 50.0, 150.0] {
        let prob = Problem::new(platform, lambda, 3.5, 2.0);
        if let Some(r) = bisection::solve(&prob) {
            let rate = r.design.b_hat as f64 - 1.0;
            t.row(&[
                format!("{lambda:.0}"),
                r.design.b_hat.to_string(),
                format!("{:.3e}", qaci::theory::rate_distortion::d_upper(rate, lambda)),
                format!("{:.3e}", qaci::theory::rate_distortion::d_lower(rate, lambda)),
                format!("{:.3e}", r.objective),
            ]);
        }
    }
    t.print();
}
