//! Quickstart: load the artifacts, run one quantization-aware co-inference
//! round trip, and print what the joint design decided.
//!
//!   make artifacts && cargo run --release --example quickstart

use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::quant::Scheme;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::Platform;

fn main() -> anyhow::Result<()> {
    // 1. open the AOT bundle (HLO text + trained weights + eval data)
    let reg = Registry::open(&qaci::artifacts_dir())?;
    let mut model = CoModel::load(&reg, "blip2ish")?;
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco")?;
    let vocab = Vocab::from_manifest(&reg.manifest)?;
    println!(
        "loaded {}: agent {} params (λ={:.1}), server {} params",
        model.name,
        model.agent_weights.n_params(),
        model.agent_weights.lambda,
        model.server_weights.n_params()
    );

    // 2. joint quantization/computation design for a QoS budget
    let platform = Platform::paper_blip2()
        .with_workload(model.agent_flops, model.server_flops);
    let mut scheduler = Scheduler::new(
        platform,
        model.agent_weights.lambda,
        Algorithm::Proposed,
        Scheme::Uniform,
        0,
    );
    let (t0, e0) = (0.05, 0.01); // budgets scaled to this tiny testbed
    let plan = scheduler
        .plan(t0, e0)
        .expect("budget should be feasible");
    println!(
        "joint design @ (T0={t0}s, E0={e0}J): b̂={} bits, f={:.2} GHz, f̃={:.2} GHz",
        plan.design.b_hat,
        plan.design.f / 1e9,
        plan.design.f_tilde / 1e9
    );

    // 3. run the co-inference pipeline at the planned bit-width and at
    //    full precision, and compare
    for (label, bits) in [("planned", plan.design.b_hat), ("full-precision", 32)] {
        let tokens = model.infer(eval.sample(0), 1, bits, Scheme::Uniform)?;
        println!("{label:>16} ({bits:>2} bits): \"{}\"", vocab.detokenize(&tokens[0]));
    }
    println!("reference: \"{}\"", eval.refs[0][0]);
    Ok(())
}
