//! Testbed-profile demo (the Table-I scenario): coarse low/medium/high
//! DVFS profiles instead of continuous frequency control, contrasting the
//! delay-limited regime (higher profile wins) with the energy-limited
//! regime (lower profile wins) — on real co-inference runs.
//!
//!   cargo run --release --example testbed_profiles

use qaci::bench_harness::Table;
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::opt::Problem;
use qaci::quant::Scheme;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::channel::Channel;
use qaci::system::dvfs::Governor;
use qaci::system::Platform;

fn main() -> anyhow::Result<()> {
    let reg = Registry::open(&qaci::artifacts_dir())?;
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco")?;
    let vocab = Vocab::from_manifest(&reg.manifest)?;
    let mut model = CoModel::load(&reg, "blip2ish")?;
    let lambda = model.agent_weights.lambda;
    // Jetson-Orin-like testbed silicon, this repo's measured workloads
    let platform = Platform::testbed(model.agent_flops, model.server_flops);

    // budget anchors: knife-edge around the HIGH profile's full-precision
    // threshold (delay) and a low-profile mid-bit energy point — the same
    // calibration as the table1_testbed bench
    let t_ref = {
        let mut p = platform;
        p.device.f_max = Governor::jetson_profiles().profile("high").unwrap();
        p.min_delay(p.b_max as f64)
    };
    let e_ref = qaci::system::energy::total_energy(
        &platform,
        8.0,
        Governor::jetson_profiles().profile("low").unwrap(),
        platform.server.f_max / 2.0,
    );

    println!("testbed: Jetson-AGX-Orin-like device with coarse DVFS profiles");
    let mut table = Table::new(
        "CIDEr(x100) under coarse frequency profiles (Table-I shape)",
        &["profile", "delay-limited", "energy-limited"],
    );

    for profile in ["low", "medium", "high"] {
        let dev_gov = Governor::jetson_profiles();
        let f_dev = dev_gov.profile(profile).unwrap();
        let mut row = vec![profile.to_string()];
        for (t0, e0, label) in [
            (1.0 * t_ref, 1e6 * e_ref, "delay-limited"),
            (1e6 * t_ref, 1.0 * e_ref, "energy-limited"),
        ] {
            let _ = label;
            // pin the device to this profile by capping f_max; the design
            // then optimizes the bit-width + server frequency around it
            let mut p = platform;
            p.device.f_max = f_dev;
            let problem = Problem::new(p, lambda, t0, e0);
            // pinned-frequency planning: use the planner but force f=f_dev
            // by making it the only choice
            let mut scheduler =
                Scheduler::new(p, lambda, Algorithm::Exact, Scheme::Uniform, 3)
                    .with_governors(
                        Governor::Profiles { points: vec![f_dev] },
                        Governor::server_profiles(),
                    );
            match scheduler.plan(t0, e0) {
                None => row.push("infeasible".into()),
                Some(_) => {
                    let router = Router::new(QosPolicy::uniform(t0, e0), scheduler);
                    let mut engine = Engine::new(
                        &mut model,
                        router,
                        &vocab,
                        &eval,
                        Channel::wlan_5ghz(7),
                        EngineConfig::default(),
                    );
                    let t = engine.run(generate(24, eval.len(), Arrival::Batch, 9))?;
                    let bits = t.records.iter().map(|r| r.b_hat as f64).sum::<f64>()
                        / t.len().max(1) as f64;
                    row.push(format!(
                        "{:.1} (b̂≈{:.0})",
                        t.cider_x100(&eval.refs),
                        bits
                    ));
                }
            }
            let _ = problem;
        }
        table.row(&row);
    }
    table.print();
    println!(
        "\nexpected shape (paper Table I): the high profile wins when delay-\n\
         limited (more frequency => more bits fit the deadline); the low\n\
         profile wins when energy-limited (f² energy forces fewer bits at\n\
         high frequency)."
    );
    Ok(())
}
