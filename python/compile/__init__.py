# Build-time compile path: JAX models + Pallas kernels + AOT lowering.
# Nothing in this package is imported at runtime; the Rust coordinator
# consumes only the files under artifacts/.
