"""AOT pipeline: train -> lower -> serialize artifacts for the Rust runtime.

Interchange format is **HLO text** (never ``lowered.compile().serialize()``):
the xla crate's bundled xla_extension 0.5.1 rejects jax>=0.5 serialized
HloModuleProtos (64-bit instruction ids); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/README.md.

Outputs (all under --out, default ../artifacts):
  *.hlo.txt            lowered modules (agent/server per model, fcdnn, quant)
  *_weights.bin        trained parameters, f32 LE, concatenated in spec order
  coco_eval.bin etc.   deterministic eval inputs
  golden.json          end-to-end golden vectors for Rust integration tests
  manifest.json        ties everything together (written LAST = build stamp)

Run: cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datagen, model, train
from .kernels import quantize
from .model import BLIP2ISH, GITISH, ModelConfig

QUANT_ROWS = 2048  # quant artifacts operate on fixed (2048, 128) chunks


# ---------------------------------------------------------------------------
# lowering helpers
# ---------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_with_params(fn, spec, params, *example_inputs):
    """Lower fn(*inputs, *weights-in-spec-order) to HLO text."""
    names = [n for n, _ in spec]

    def flat_fn(*args):
        inputs = args[: len(example_inputs)]
        ws = dict(zip(names, args[len(example_inputs):]))
        return (fn(inputs, ws),)

    weight_args = [jax.ShapeDtypeStruct(params[n].shape, jnp.float32)
                   for n in names]
    lowered = jax.jit(flat_fn).lower(*example_inputs, *weight_args)
    return to_hlo_text(lowered)


def write_weights(path, spec, params):
    """Concatenate parameters (spec order) into one f32 LE blob."""
    blob = np.concatenate(
        [np.asarray(params[n], np.float32).reshape(-1) for n, _ in spec])
    blob.astype("<f4").tofile(path)
    return blob.size


def fit_lambda(params, spec):
    """MLE of the exponential magnitude model (paper eq. 3): 1/mean(|w|).

    LayerNorm gains/biases are excluded — they are not quantized (they sit
    at ~1/~0 by construction and are a negligible parameter fraction).
    """
    mags = np.concatenate([
        np.abs(np.asarray(params[n], np.float32)).reshape(-1)
        for n, _ in spec if not (n.endswith(".g") or n.endswith(".b"))
    ])
    return float(1.0 / max(mags.mean(), 1e-12)), int(mags.size)


# ---------------------------------------------------------------------------
# per-model artifact emission
# ---------------------------------------------------------------------------

def emit_captioner(cfg: ModelConfig, params, out, manifest, batches=(1, 4)):
    enc_spec = model.encoder_param_spec(cfg)
    dec_spec = model.decoder_param_spec(cfg)
    H = cfg.frames * cfg.image_hw

    def agent_fn(inputs, ws):
        (img,) = inputs
        enc1 = lambda im: model.encode(ws, im, cfg, use_pallas=True)
        return jax.vmap(enc1)(img)

    def server_fn(inputs, ws):
        (emb,) = inputs
        dec1 = lambda e: model.greedy_decode(ws, e, cfg, use_pallas=True)
        return jax.vmap(dec1)(emb)

    entry = {"agent": {}, "server": {}}
    for b in batches:
        img = jax.ShapeDtypeStruct((b, H, cfg.image_hw, 3), jnp.float32)
        name = f"{cfg.name}_agent_b{b}.hlo.txt"
        with open(os.path.join(out, name), "w") as f:
            f.write(lower_with_params(agent_fn, enc_spec, params, img))
        entry["agent"].setdefault("hlo", {})[str(b)] = name

        emb = jax.ShapeDtypeStruct((b, cfg.emb_tokens, cfg.d_model),
                                   jnp.float32)
        name = f"{cfg.name}_server_b{b}.hlo.txt"
        with open(os.path.join(out, name), "w") as f:
            f.write(lower_with_params(server_fn, dec_spec, params, emb))
        entry["server"].setdefault("hlo", {})[str(b)] = name

    for side, spec in (("agent", enc_spec), ("server", dec_spec)):
        wname = f"{cfg.name}_{side}_weights.bin"
        n = write_weights(os.path.join(out, wname), spec, params)
        lam, nq = fit_lambda(params, spec)
        entry[side].update({
            "weights": wname,
            "total_f32": n,
            "params": [{"name": nm, "shape": list(sh)} for nm, sh in spec],
            "lambda": lam,
            "quantizable_f32": nq,
        })
    entry["agent"]["flops"] = model.encoder_flops(cfg)
    entry["server"]["flops"] = model.decoder_flops(cfg)
    entry["config"] = {
        "image_hw": cfg.image_hw, "patch": cfg.patch, "frames": cfg.frames,
        "d_model": cfg.d_model, "n_heads": cfg.n_heads,
        "n_enc_layers": cfg.n_enc_layers, "n_dec_layers": cfg.n_dec_layers,
        "n_query": cfg.n_query, "use_bridge": cfg.use_bridge,
        "vocab": cfg.vocab, "max_len": cfg.max_len,
        "emb_tokens": cfg.emb_tokens, "input_shape": [H, cfg.image_hw, 3],
        "batches": list(batches),
    }
    manifest["models"][cfg.name] = entry


def emit_fcdnn(params, out, manifest, batch=8):
    spec = model.fcdnn_param_spec()

    def fn(inputs, ws):
        (x,) = inputs
        return model.fcdnn_forward(ws, x, use_pallas=True)

    x = jax.ShapeDtypeStruct((batch, 784), jnp.float32)
    hlo = f"fcdnn16_b{batch}.hlo.txt"
    with open(os.path.join(out, hlo), "w") as f:
        f.write(lower_with_params(fn, spec, params, x))
    wname = "fcdnn16_weights.bin"
    n = write_weights(os.path.join(out, wname), spec, params)
    lam, nq = fit_lambda(params, spec)
    manifest["models"]["fcdnn16"] = {
        "hlo": {str(batch): hlo}, "weights": wname, "total_f32": n,
        "params": [{"name": nm, "shape": list(sh)} for nm, sh in spec],
        "lambda": lam, "quantizable_f32": nq, "batch": batch,
        "dims": model.FCDNN_DIMS, "flops": model.fcdnn_flops(),
    }


def emit_quant(out, manifest):
    """Pallas fake-quant kernels as standalone artifacts: the Rust quantizer
    cross-checks its native implementation against these (same HLO the
    models could embed on a real deployment)."""
    wbuf = jax.ShapeDtypeStruct((QUANT_ROWS, 128), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    lowered = jax.jit(
        lambda w, s: (quantize.fake_quant_uniform(w, s),)
    ).lower(wbuf, scalar)
    with open(os.path.join(out, "quant_uniform.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(
        lambda w, lo, hi: (quantize.fake_quant_pot(w, lo, hi),)
    ).lower(wbuf, scalar, scalar)
    with open(os.path.join(out, "quant_pot.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    manifest["quant"] = {
        "rows": QUANT_ROWS, "lanes": 128,
        "uniform": "quant_uniform.hlo.txt", "pot": "quant_pot.hlo.txt",
    }


def emit_eval_sets(out, manifest, n_coco=64, n_vatex=32, seed=7):
    coco_x, coco_refs = datagen.dataset("image", n_coco, seed=seed)
    vatex_x, vatex_refs = datagen.dataset("video", n_vatex, seed=seed + 1)
    coco_x.astype("<f4").tofile(os.path.join(out, "coco_eval.bin"))
    vatex_x.astype("<f4").tofile(os.path.join(out, "vatex_eval.bin"))
    manifest["eval"] = {
        "coco": {"inputs": "coco_eval.bin",
                 "shape": [n_coco, 32, 32, 3], "refs": coco_refs},
        "vatex": {"inputs": "vatex_eval.bin",
                  "shape": [n_vatex, 4, 32, 32, 3], "refs": vatex_refs},
    }


def load_param_cache(out, name):
    """Load cached trained parameters ({out}/{name}_params.npz) if present."""
    path = os.path.join(out, f"{name}_params.npz")
    if not os.path.exists(path):
        return None
    data = np.load(path)
    return {k: jnp.asarray(data[k]) for k in data.files}


def save_param_cache(out, name, params):
    np.savez(os.path.join(out, f"{name}_params.npz"),
             **{k: np.asarray(v) for k, v in params.items()})


def emit_golden(out, manifest, all_params):
    """End-to-end golden vectors (pallas path, batch 1) for Rust tests.

    Inputs that Rust cannot regenerate (numpy RNG streams) are shipped as
    .bin files next to golden.json.
    """
    golden = {}
    rng = np.random.default_rng(42)

    for cfg in (BLIP2ISH, GITISH):
        params = all_params[cfg.name]
        kind = "image" if cfg.frames == 1 else "video"
        xs, _ = datagen.dataset(kind, 1, seed=7 if kind == "image" else 8)
        img = jnp.asarray(xs[0].reshape(cfg.frames * cfg.image_hw,
                                        cfg.image_hw, 3))
        emb = model.encode(params, img, cfg, use_pallas=True)
        toks = model.greedy_decode(params, emb, cfg, use_pallas=True)
        golden[cfg.name] = {
            "emb_l1": float(jnp.abs(emb).sum()),
            "emb_first8": [float(v) for v in np.asarray(emb).reshape(-1)[:8]],
            "tokens": [int(t) for t in np.asarray(toks)],
            "caption": datagen.detokenize(datagen.make_vocab(),
                                          [int(t) for t in np.asarray(toks)]),
        }

    params = all_params["fcdnn16"]
    x_np = rng.normal(0, 0.5, (8, 784)).astype(np.float32)
    x_np.astype("<f4").tofile(os.path.join(out, "golden_fcdnn_input.bin"))
    x = jnp.asarray(x_np)
    y = model.fcdnn_forward(params, x, use_pallas=True)
    golden["fcdnn16"] = {
        "input": "golden_fcdnn_input.bin",
        "out_l1": float(jnp.abs(y).sum()),
        "out_first8": [float(v) for v in np.asarray(y).reshape(-1)[:8]],
    }

    w_np = rng.normal(0, 0.1, (QUANT_ROWS, 128)).astype(np.float32)
    w_np.astype("<f4").tofile(os.path.join(out, "golden_quant_input.bin"))
    w = jnp.asarray(w_np)
    qu = quantize.fake_quant_uniform(w, 0.05)
    qp = quantize.fake_quant_pot(w, -6.0, 0.0)
    golden["quant"] = {
        "input": "golden_quant_input.bin",
        "buf_l1": float(jnp.abs(w).sum()),
        "uniform_step": 0.05,
        "uniform_l1": float(jnp.abs(qu).sum()),
        "pot_emin": -6.0, "pot_emax": 0.0,
        "pot_l1": float(jnp.abs(qp).sum()),
    }
    with open(os.path.join(out, "golden.json"), "w") as f:
        json.dump(golden, f, indent=1)
    manifest["golden"] = "golden.json"


# ---------------------------------------------------------------------------
# main
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--blip2-steps", type=int, default=2600)
    ap.add_argument("--git-steps", type=int, default=2000)
    ap.add_argument("--fcdnn-steps", type=int, default=800)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--retrain", action="store_true",
                    help="ignore cached trained weights")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    manifest = {"version": 1, "models": {}, "vocab": datagen.make_vocab(),
                "special_tokens": {"pad": 0, "bos": 1, "eos": 2, "unk": 3}}

    def fit(name, trainer):
        if not args.retrain:
            cached = load_param_cache(out, name)
            if cached is not None:
                print(f"== {name}: using cached weights ==", flush=True)
                return cached, None  # loss unknown: weights reused
        print(f"== training {name} ==", flush=True)
        params, loss = trainer()
        save_param_cache(out, name, params)
        return params, loss

    blip_params, blip_loss = fit(
        "blip2ish",
        lambda: train.train_captioner(BLIP2ISH, steps=args.blip2_steps,
                                      seed=args.seed))
    git_params, git_loss = fit(
        "gitish",
        lambda: train.train_captioner(GITISH, steps=args.git_steps, batch=24,
                                      seed=args.seed))
    fc_params, fc_loss = fit(
        "fcdnn16",
        lambda: train.train_fcdnn(steps=args.fcdnn_steps, seed=args.seed))
    manifest["train"] = {"blip2ish_loss": blip_loss, "gitish_loss": git_loss,
                         "fcdnn16_mse": fc_loss, "seed": args.seed}

    print("== lowering HLO ==", flush=True)
    emit_captioner(BLIP2ISH, blip_params, out, manifest)
    emit_captioner(GITISH, git_params, out, manifest)
    emit_fcdnn(fc_params, out, manifest)
    emit_quant(out, manifest)
    emit_eval_sets(out, manifest)
    print("== golden vectors ==", flush=True)
    emit_golden(out, manifest, {
        "blip2ish": blip_params, "gitish": git_params, "fcdnn16": fc_params})

    manifest["build_seconds"] = round(time.time() - t0, 1)
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"artifacts written to {out} in {manifest['build_seconds']}s")


if __name__ == "__main__":
    main()
