"""Synthetic captioning corpora (MS-COCO / VaTeX stand-ins).

The paper evaluates on MS-COCO (image captioning, 5 refs/image) and VaTeX
(video captioning, 4 uniformly sampled frames).  Neither is available in
this offline environment, so we build a seeded scene-grammar generator that
preserves what the experiments actually exercise (DESIGN.md §5):

* images contain compositional content (colored object glyphs in spatial
  relations) a small ViT can genuinely learn to describe;
* each sample carries multiple human-like paraphrase references, so the
  CIDEr consensus metric behaves as on COCO;
* videos are 4-frame clips whose caption requires temporal reasoning (the
  motion direction is only visible across frames).

Everything is deterministic in the seed; the Rust side re-creates the same
eval split from artifacts/ rather than regenerating.
"""

import numpy as np

# ---------------------------------------------------------------------------
# grammar
# ---------------------------------------------------------------------------

COLORS = ["red", "blue", "green", "yellow", "purple", "orange"]
OBJECTS = ["ball", "box", "robot", "cup", "tree", "car", "dog", "chair"]
RELATIONS = ["left of", "right of", "above", "below", "near"]
DIRECTIONS = ["left", "right", "up", "down"]

IMG_TEMPLATES = [
    "a {c1} {o1} is {rel} a {c2} {o2}",
    "the {c1} {o1} sits {rel} the {c2} {o2}",
    "there is a {c1} {o1} {rel} a {c2} {o2}",
    "a {c1} {o1} stands {rel} a {c2} {o2}",
    "one {c1} {o1} rests {rel} a {c2} {o2}",
]

VID_TEMPLATES = [
    "a {c1} {o1} moving {d} near a {c2} {o2}",
    "the {c1} {o1} moves {d} past the {c2} {o2}",
    "a {c1} {o1} is going {d} near a {c2} {o2}",
    "one {c1} {o1} drifts {d} past a {c2} {o2}",
    "the {c1} {o1} travels {d} near a {c2} {o2}",
]

PAD, BOS, EOS, UNK = 0, 1, 2, 3
SPECIALS = ["<pad>", "<bos>", "<eos>", "<unk>"]


def make_vocab():
    """Deterministic word list covering the full grammar."""
    words = set()
    for t in IMG_TEMPLATES + VID_TEMPLATES:
        for w in t.split():
            if not w.startswith("{"):
                words.add(w)
    words.update(COLORS)
    words.update(OBJECTS)
    words.update(DIRECTIONS)
    for r in RELATIONS:
        words.update(r.split())
    return SPECIALS + sorted(words)


def tokenize(vocab, sentence, max_len):
    idx = {w: i for i, w in enumerate(vocab)}
    ids = [BOS] + [idx.get(w, UNK) for w in sentence.split()] + [EOS]
    assert len(ids) <= max_len, f"caption too long: {sentence!r}"
    return ids + [PAD] * (max_len - len(ids))


def detokenize(vocab, ids):
    out = []
    for t in ids:
        if t == EOS:
            break
        if t in (PAD, BOS):
            continue
        out.append(vocab[t] if 0 <= t < len(vocab) else "<unk>")
    return " ".join(out)


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

COLOR_RGB = {
    "red": (0.9, 0.15, 0.1), "blue": (0.1, 0.2, 0.9),
    "green": (0.1, 0.8, 0.2), "yellow": (0.9, 0.85, 0.1),
    "purple": (0.6, 0.15, 0.8), "orange": (0.95, 0.55, 0.1),
}


def _glyph(obj):
    """8x8 binary silhouette per object class — shape is the only cue that
    distinguishes objects, so the encoder must learn it."""
    g = np.zeros((8, 8), np.float32)
    if obj == "ball":
        yy, xx = np.mgrid[0:8, 0:8]
        g[(yy - 3.5) ** 2 + (xx - 3.5) ** 2 <= 10] = 1
    elif obj == "box":
        g[1:7, 1:7] = 1
    elif obj == "robot":
        g[3:8, 1:7] = 1
        g[0:3, 3:5] = 1            # antenna head
    elif obj == "cup":
        g[2:7, 1:3] = 1
        g[2:7, 5:7] = 1
        g[5:7, 1:7] = 1            # U shape
    elif obj == "tree":
        for r in range(5):
            g[r, 3 - r // 2: 5 + r // 2] = 1
        g[5:8, 3:5] = 1            # trunk
    elif obj == "car":
        g[3:6, 0:8] = 1
        g[6:8, 1:3] = 1
        g[6:8, 5:7] = 1            # wheels
    elif obj == "dog":
        g[3:7, 1:7] = 1
        g[1:3, 1:2] = 1            # ear
        g[4:6, 7:8] = 1            # tail
    elif obj == "chair":
        g[0:7, 1:2] = 1
        g[4:5, 1:7] = 1
        g[4:8, 6:7] = 1            # L profile
    else:
        raise ValueError(obj)
    return g


GLYPHS = {o: _glyph(o) for o in OBJECTS}


def _paint(img, obj, color, cy, cx):
    g = GLYPHS[obj]
    rgb = COLOR_RGB[color]
    y0, x0 = int(cy) - 4, int(cx) - 4
    for dy in range(8):
        for dx in range(8):
            if g[dy, dx] > 0:
                y, x = y0 + dy, x0 + dx
                if 0 <= y < img.shape[0] and 0 <= x < img.shape[1]:
                    img[y, x] = rgb


def _relation_positions(rel, rng):
    """Centers (cy1,cx1),(cy2,cx2) consistent with `rel(obj1, obj2)`."""
    j = lambda: rng.uniform(-2, 2)
    if rel == "left of":
        return (16 + j(), 8 + j()), (16 + j(), 24 + j())
    if rel == "right of":
        return (16 + j(), 24 + j()), (16 + j(), 8 + j())
    if rel == "above":
        return (8 + j(), 16 + j()), (24 + j(), 16 + j())
    if rel == "below":
        return (24 + j(), 16 + j()), (8 + j(), 16 + j())
    # near: diagonal adjacency
    return (12 + j(), 12 + j()), (20 + j(), 20 + j())


def render_scene(scene, rng, noise=0.02):
    """scene: dict(c1,o1,rel,c2,o2) -> (32, 32, 3) f32 image."""
    img = np.zeros((32, 32, 3), np.float32) + 0.05
    (p1, p2) = _relation_positions(scene["rel"], rng)
    _paint(img, scene["o2"], scene["c2"], *p2)
    _paint(img, scene["o1"], scene["c1"], *p1)
    img += rng.normal(0, noise, img.shape).astype(np.float32)
    return np.clip(img, 0, 1)


def render_clip(scene, rng, frames=4, noise=0.02):
    """Video: obj1 translates along scene['dir']; obj2 static."""
    d = scene["dir"]
    vel = {"left": (0, -4), "right": (0, 4), "up": (-4, 0), "down": (4, 0)}[d]
    start = {"left": (16, 26), "right": (16, 6),
             "up": (26, 16), "down": (6, 16)}[d]
    stat = {"left": (6, 10), "right": (26, 22),
            "up": (6, 6), "down": (26, 26)}[d]
    clip = np.zeros((frames, 32, 32, 3), np.float32)
    for t in range(frames):
        img = np.zeros((32, 32, 3), np.float32) + 0.05
        _paint(img, scene["o2"], scene["c2"], *stat)
        cy = start[0] + vel[0] * t + rng.uniform(-1, 1)
        cx = start[1] + vel[1] * t + rng.uniform(-1, 1)
        _paint(img, scene["o1"], scene["c1"], cy, cx)
        img += rng.normal(0, noise, img.shape).astype(np.float32)
        clip[t] = np.clip(img, 0, 1)
    return clip


# ---------------------------------------------------------------------------
# samples + datasets
# ---------------------------------------------------------------------------

def _image_scene(rng):
    c1, c2 = rng.choice(COLORS, 2, replace=False)
    o1, o2 = rng.choice(OBJECTS, 2, replace=False)
    rel = RELATIONS[rng.integers(len(RELATIONS))]
    return {"c1": c1, "o1": o1, "rel": rel, "c2": c2, "o2": o2}


def _video_scene(rng):
    s = _image_scene(rng)
    s["dir"] = s["d"] = DIRECTIONS[rng.integers(len(DIRECTIONS))]
    return s


def image_sample(rng):
    """-> (image (32,32,3), refs: list of 5 caption strings)."""
    s = _image_scene(rng)
    refs = [t.format(**s) for t in IMG_TEMPLATES]
    return render_scene(s, rng), refs


def video_sample(rng):
    """-> (clip (4,32,32,3), refs: list of 5 caption strings)."""
    s = _video_scene(rng)
    refs = [t.format(**s) for t in VID_TEMPLATES]
    return render_clip(s, rng), refs


def dataset(kind, n, seed):
    """Deterministic dataset: (inputs f32 array, list of ref-lists)."""
    rng = np.random.default_rng(seed)
    gen = image_sample if kind == "image" else video_sample
    xs, refs = [], []
    for _ in range(n):
        x, r = gen(rng)
        xs.append(x)
        refs.append(r)
    return np.stack(xs), refs
