# L1 — Pallas kernels for the paper's compute hot-spots.
#
# All kernels are authored TPU-shaped (VMEM BlockSpecs, MXU-aligned tiles)
# but lowered with interpret=True so the emitted HLO runs on any PJRT
# backend, including the Rust CPU client (see DESIGN.md §4).
from . import quantize, matmul, attention, layernorm, ref  # noqa: F401
