"""Fused scaled-dot-product attention as a Pallas kernel.

One grid cell per head; each cell holds the full (lq, dh) / (lk, dh) tiles
in VMEM (sequence lengths in this system are <= 128, so a head's working
set is ~lq*lk + 2*lk*dh + lq*dh floats — well under the VMEM budget) and
fuses QK^T, the numerically stable softmax, and PV into a single pass, the
TPU analogue of a fused flash-style CUDA attention kernel for short
sequences.  Causality is compiled in (static) because the mask shape is
known at trace time.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, causal, scale):
    q = q_ref[0]  # (lq, dh)
    k = k_ref[0]  # (lk, dh)
    v = v_ref[0]  # (lk, dh)
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    if causal:
        lq, lk = logits.shape
        # row i may attend to keys 0..i+(lk-lq); expressed with 2-D iotas
        # (1-D iota is not TPU-legal).
        rows = jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (lq, lk), 1)
        logits = jnp.where(cols <= rows + (lk - lq), logits, -1e30)
    m = logits.max(axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / p.sum(axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("causal",))
def attention(q, k, v, causal=False):
    """q: (h, lq, dh), k/v: (h, lk, dh) -> (h, lq, dh)."""
    h, lq, dh = q.shape
    _, lk, _ = k.shape
    scale = 1.0 / float(dh) ** 0.5
    kernel = functools.partial(_attn_kernel, causal=causal, scale=scale)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((h, lq, dh), jnp.float32),
        grid=(h,),
        in_specs=[
            pl.BlockSpec((1, lq, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, lk, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, lq, dh), lambda i: (i, 0, 0)),
        interpret=True,
    )(q, k, v)
