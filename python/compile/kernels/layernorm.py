"""Row LayerNorm as a Pallas kernel.

Rows are tiled in groups of 8 (sublane dimension); gamma/beta ride along as
full-width (1, d) operands.  The mean/variance reduction happens entirely
inside the VMEM tile, so each row is read exactly once from HBM.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS_PER_BLOCK = 8


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * g_ref[0] + b_ref[0]


@functools.partial(jax.jit, static_argnames=("eps",))
def layernorm(x, gamma, beta, eps=1e-6):
    """x: (n, d) f32, gamma/beta: (d,) -> (n, d)."""
    n, d = x.shape
    rb = ROWS_PER_BLOCK
    while n % rb != 0:
        rb //= 2
    kernel = functools.partial(_ln_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        grid=(n // rb,),
        in_specs=[
            pl.BlockSpec((rb, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        interpret=True,
    )(x, gamma.reshape(1, d), beta.reshape(1, d))
