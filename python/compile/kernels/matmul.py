"""Blocked Pallas matmul — the MXU-shaped GEMM used in every model layer.

CUDA->TPU adaptation (DESIGN.md §4): the paper's testbed runs GEMMs on
tensor cores with shared-memory tiling; here the same schedule is expressed
as a 3-D grid over (M/bm, N/bn, K/bk) with VMEM BlockSpecs.  The K axis is
the innermost ("arbitrary" semantics) axis and accumulates into the output
block, which the index map pins to (i, j) for every k step — the canonical
Pallas accumulation pattern.  Tiles are capped at 128x128 to match the MXU
systolic array; f32 accumulation via preferred_element_type.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MXU = 128


def _pick_block(dim, cap=MXU):
    """Largest power-of-two tile <= cap that divides dim (dims here are
    powers of two or small multiples of 16, so this always terminates)."""
    b = min(dim, cap)
    while dim % b != 0:
        b //= 2
    return max(b, 1)


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm=None, bn=None, bk=None):
    """x: (M, K) f32, y: (K, N) f32 -> (M, N) f32 via blocked Pallas GEMM."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {x.shape} @ {y.shape}"
    bm = bm or _pick_block(m)
    bn = bn or _pick_block(n)
    bk = bk or _pick_block(k, cap=512)  # deeper K tiles amortize the loop
    grid = (m // bm, n // bn, k // bk)
    kernel = functools.partial(_matmul_kernel, k_steps=grid[2])
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(x, y)


def vmem_bytes(bm=MXU, bn=MXU, bk=512, dtype_bytes=4):
    """VMEM footprint of one grid cell — used by the L1 perf estimate."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)
