"""Pallas fake-quantization kernels (uniform + power-of-two log).

This is the paper's §II-C quantizer as a TPU kernel: the sign bit of every
parameter is preserved and only the magnitude is quantized, either on a
uniform grid [31] or on power-of-two logarithmic levels [32].

TPU shaping: the weight buffer is viewed as (rows, 128) so each block is a
(ROWS_PER_BLOCK, 128) VMEM tile aligned to the 8x128 VPU lanes; the scalar
quantizer parameters ride along as (1, 1) operands broadcast to every grid
cell.  The bit-width is a *runtime* input (encoded as step / emin / emax),
so one compiled artifact serves every bit-width the Rust scheduler picks.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# One VMEM tile: 8 sublanes x 128 lanes, the native f32 VPU tile.
LANES = 128
ROWS_PER_BLOCK = 8


def _uniform_kernel(w_ref, step_ref, o_ref):
    step = step_ref[0, 0]
    w = w_ref[...]
    mag = jnp.abs(w)
    q = jnp.round(mag / jnp.where(step > 0, step, 1.0)) * step
    q = jnp.where(step > 0, q, mag)
    o_ref[...] = jnp.sign(w) * q


def _pot_kernel(w_ref, emin_ref, emax_ref, o_ref):
    emin = emin_ref[0, 0]
    emax = emax_ref[0, 0]
    w = w_ref[...]
    mag = jnp.abs(w)
    safe = jnp.where(mag > 0, mag, 1.0)
    lg = jnp.log2(safe)
    e = jnp.clip(jnp.round(lg), emin, emax)
    q = jnp.exp2(e)
    q = jnp.where(lg < emin - 0.5, 0.0, q)
    q = jnp.where(mag > 0, q, 0.0)
    o_ref[...] = jnp.sign(w) * q


def _grid_call(kernel, w, scalars):
    """Launch `kernel` over a (rows/RPB,) grid of (RPB, LANES) tiles."""
    rows, lanes = w.shape
    assert lanes == LANES, f"weight buffer must be (_, {LANES}), got {w.shape}"
    assert rows % ROWS_PER_BLOCK == 0, f"rows {rows} % {ROWS_PER_BLOCK} != 0"
    grid = (rows // ROWS_PER_BLOCK,)
    scalar_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(w.shape, w.dtype),
        grid=grid,
        in_specs=[pl.BlockSpec((ROWS_PER_BLOCK, LANES), lambda i: (i, 0))]
        + [scalar_spec] * len(scalars),
        out_specs=pl.BlockSpec((ROWS_PER_BLOCK, LANES), lambda i: (i, 0)),
        interpret=True,
    )(w, *scalars)


@functools.partial(jax.jit, static_argnames=())
def fake_quant_uniform(w, step):
    """w: (rows, 128) f32; step: scalar f32 -> quantized (rows, 128)."""
    step2d = jnp.reshape(jnp.asarray(step, jnp.float32), (1, 1))
    return _grid_call(_uniform_kernel, w, [step2d])


@functools.partial(jax.jit, static_argnames=())
def fake_quant_pot(w, emin, emax):
    """w: (rows, 128) f32; emin/emax: scalar f32 -> quantized (rows, 128)."""
    emin2d = jnp.reshape(jnp.asarray(emin, jnp.float32), (1, 1))
    emax2d = jnp.reshape(jnp.asarray(emax, jnp.float32), (1, 1))
    return _grid_call(_pot_kernel, w, [emin2d, emax2d])


def pad_to_buffer(flat, multiple=ROWS_PER_BLOCK * LANES):
    """Pad a flat f32 vector to a (rows, 128) kernel buffer; returns (buf, n)."""
    n = flat.shape[0]
    padded = ((n + multiple - 1) // multiple) * multiple
    buf = jnp.zeros((padded,), jnp.float32).at[:n].set(flat)
    return buf.reshape(-1, LANES), n
