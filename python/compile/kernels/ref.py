"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest (python/tests) asserts the
Pallas kernels match these references over hypothesis-driven shape/value
sweeps, and train.py uses them (they are mathematically identical but much
faster than interpret-mode Pallas) to fit the model weights that aot.py
ships to the Rust runtime.
"""

import jax.numpy as jnp


def fake_quant_uniform(w, step):
    """Sign-preserving uniform fake-quantization (paper §II-C, [31]).

    Magnitudes are snapped to the uniform grid {0, step, 2*step, ...}
    (round-to-nearest); the sign bit is kept exactly.  ``step`` encodes the
    bit-width: for magnitude range [0, theta_max] and b quantization bits
    with one sign bit, step = theta_max / (2**(b-1) - 1).

    step <= 0 is treated as "no quantization" (identity), which is the
    natural limit step -> 0 and lets a single artifact serve the
    full-precision case.
    """
    step = jnp.asarray(step, w.dtype)
    mag = jnp.abs(w)
    q = jnp.round(mag / jnp.where(step > 0, step, 1.0)) * step
    q = jnp.where(step > 0, q, mag)
    return jnp.sign(w) * q


def fake_quant_pot(w, emin, emax):
    """Sign-preserving power-of-two logarithmic fake-quantization [32].

    Magnitude levels are {0} U {2^k : emin <= k <= emax}; a magnitude is
    mapped to the nearest level in the log2 domain and flushed to zero when
    it falls more than half a (log-domain) step below 2^emin.  emin/emax
    encode the bit-width: b bits = 1 sign bit + (b-1) magnitude bits giving
    2^(b-1) - 1 nonzero levels, emax - emin = 2^(b-1) - 2.
    """
    emin = jnp.asarray(emin, w.dtype)
    emax = jnp.asarray(emax, w.dtype)
    mag = jnp.abs(w)
    safe = jnp.where(mag > 0, mag, 1.0)
    e = jnp.round(jnp.log2(safe))
    e = jnp.clip(e, emin, emax)
    q = jnp.exp2(e)
    # flush-to-zero region: log2|w| < emin - 0.5  (nearest level is 0)
    q = jnp.where(jnp.log2(safe) < emin - 0.5, 0.0, q)
    q = jnp.where(mag > 0, q, 0.0)
    return jnp.sign(w) * q


def matmul(x, y):
    """f32 GEMM oracle for the blocked Pallas matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def attention(q, k, v, causal=False):
    """Scaled-dot-product attention oracle.

    q: (h, lq, dh), k/v: (h, lk, dh) -> (h, lq, dh).
    """
    dh = q.shape[-1]
    logits = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(jnp.float32(dh))
    if causal:
        lq, lk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((lq, lk), bool), k=lk - lq)
        logits = jnp.where(mask[None], logits, -1e30)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v)


def layernorm(x, gamma, beta, eps=1e-6):
    """Row LayerNorm oracle. x: (n, d), gamma/beta: (d,)."""
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta
