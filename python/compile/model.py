"""L2 — JAX model definitions for the co-inference stack.

Three model families, matching the paper's evaluation (§VI):

* ``blip2ish`` — a BLIP-2-shaped image captioner: a ViT patch encoder plus a
  learned-query bridge runs **on the agent**; a causal transformer decoder
  with cross-attention runs **on the server**.  The split point is the
  (n_query, d) embedding, exactly the paper's intermediate feature ``o``.
* ``gitish``  — a GIT-shaped video captioner: per-frame patch encoding over
  4 uniformly sampled frames (paper §VI-C), concatenated frame tokens, same
  decoder structure.
* ``fcdnn16`` — the 16-layer fully connected autoencoder of §VI-A (encoder
  dims [64,128,256,512,256,128,64,32], symmetric decoder, ReLU, MSE), used
  to verify the Prop. 3.1 distortion propagation bound.

Every function takes the parameters as an explicit dict so the lowered HLO
exposes them as runtime inputs: the Rust side quantizes the weight literals
per-request (paper §II-A) and feeds them to a single compiled executable —
no per-bitwidth artifacts.

``use_pallas=True`` routes matmul/attention/layernorm through the L1 Pallas
kernels (the AOT path); ``use_pallas=False`` uses the mathematically
identical jnp oracles (the training path — interpret-mode Pallas is far too
slow to train under).  python/tests asserts the two paths agree.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import attention as attention_k
from .kernels import layernorm as layernorm_k
from .kernels import matmul as matmul_k
from .kernels import ref

# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------

PAD, BOS, EOS, UNK = 0, 1, 2, 3


@dataclass(frozen=True)
class ModelConfig:
    """Geometry of a captioner. Defaults: the blip2ish preset."""

    name: str = "blip2ish"
    image_hw: int = 32          # square input images
    patch: int = 4              # patch side -> (image_hw/patch)^2 tokens
    frames: int = 1             # 1 = image model, 4 = video model
    d_model: int = 128
    n_heads: int = 4
    d_mlp: int = 256
    n_enc_layers: int = 4
    n_dec_layers: int = 4
    n_query: int = 16           # learned bridge queries (agent output tokens)
    use_bridge: bool = True     # blip2ish: Q-Former-ish bridge; gitish: no
    vocab: int = 128
    max_len: int = 12           # decoded caption length (incl. BOS)

    @property
    def tokens_per_frame(self) -> int:
        return (self.image_hw // self.patch) ** 2

    @property
    def n_tokens(self) -> int:
        return self.tokens_per_frame * self.frames

    @property
    def patch_dim(self) -> int:
        return self.patch * self.patch * 3

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def emb_tokens(self) -> int:
        """Number of tokens in the transmitted embedding ``o``."""
        return self.n_query if self.use_bridge else self.n_tokens


BLIP2ISH = ModelConfig()
# patch=8 keeps the video model at 4x16 = 64 visual tokens (one token per
# glyph-sized region), matching GIT's "concatenate frame tokens" design at a
# build-time-trainable size.
GITISH = ModelConfig(
    name="gitish", frames=4, patch=8, use_bridge=False,
    n_enc_layers=3, n_dec_layers=3,
)

FCDNN_DIMS = [784, 64, 128, 256, 512, 256, 128, 64, 32,
              64, 128, 256, 512, 256, 128, 64, 784]


# ---------------------------------------------------------------------------
# parameter specs + init
# ---------------------------------------------------------------------------

def _attn_spec(prefix, d):
    return [(f"{prefix}.{n}", (d, d)) for n in ("wq", "wk", "wv", "wo")]


def _ln_spec(prefix, d):
    return [(f"{prefix}.g", (d,)), (f"{prefix}.b", (d,))]


def _mlp_spec(prefix, d, dm):
    return [
        (f"{prefix}.w1", (d, dm)), (f"{prefix}.b1", (dm,)),
        (f"{prefix}.w2", (dm, d)), (f"{prefix}.b2", (d,)),
    ]


def encoder_param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list for the agent-side parameters."""
    spec = [
        ("patch_proj", (cfg.patch_dim, cfg.d_model)),
        ("pos_emb", (cfg.tokens_per_frame, cfg.d_model)),
    ]
    if cfg.frames > 1:
        spec.append(("frame_emb", (cfg.frames, cfg.d_model)))
    for i in range(cfg.n_enc_layers):
        p = f"enc{i}"
        spec += _ln_spec(f"{p}.ln1", cfg.d_model)
        spec += _attn_spec(f"{p}.attn", cfg.d_model)
        spec += _ln_spec(f"{p}.ln2", cfg.d_model)
        spec += _mlp_spec(f"{p}.mlp", cfg.d_model, cfg.d_mlp)
    if cfg.use_bridge:
        spec += [("bridge.queries", (cfg.n_query, cfg.d_model))]
        spec += _ln_spec("bridge.lnq", cfg.d_model)
        spec += _attn_spec("bridge.attn", cfg.d_model)
    spec += _ln_spec("enc_out_ln", cfg.d_model)
    return spec


def decoder_param_spec(cfg: ModelConfig):
    """Ordered (name, shape) list for the server-side parameters."""
    spec = [
        ("tok_emb", (cfg.vocab, cfg.d_model)),
        ("dec_pos_emb", (cfg.max_len, cfg.d_model)),
    ]
    for i in range(cfg.n_dec_layers):
        p = f"dec{i}"
        spec += _ln_spec(f"{p}.ln1", cfg.d_model)
        spec += _attn_spec(f"{p}.self", cfg.d_model)
        spec += _ln_spec(f"{p}.ln2", cfg.d_model)
        spec += _attn_spec(f"{p}.cross", cfg.d_model)
        spec += _ln_spec(f"{p}.ln3", cfg.d_model)
        spec += _mlp_spec(f"{p}.mlp", cfg.d_model, cfg.d_mlp)
    spec += _ln_spec("dec_out_ln", cfg.d_model)
    spec += [("out_proj", (cfg.d_model, cfg.vocab))]
    return spec


def fcdnn_param_spec():
    spec = []
    for i in range(len(FCDNN_DIMS) - 1):
        spec += [(f"fc{i}.w", (FCDNN_DIMS[i], FCDNN_DIMS[i + 1])),
                 (f"fc{i}.b", (FCDNN_DIMS[i + 1],))]
    return spec


def init_params(spec, key, scale=0.02):
    """He-ish init: normals for matrices, LayerNorm gains at 1, biases 0."""
    params = {}
    for name, shape in spec:
        key, sub = jax.random.split(key)
        if name.endswith(".g"):
            params[name] = jnp.ones(shape, jnp.float32)
        elif name.endswith((".b", ".b1", ".b2")) and len(shape) == 1:
            params[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            std = scale if len(shape) == 1 else (2.0 / fan_in) ** 0.5 * 0.7
            params[name] = std * jax.random.normal(sub, shape, jnp.float32)
    return params


# ---------------------------------------------------------------------------
# transformer building blocks (kernel-switchable)
# ---------------------------------------------------------------------------

def _ops(use_pallas):
    if use_pallas:
        return matmul_k.matmul, attention_k.attention, layernorm_k.layernorm
    return ref.matmul, (lambda q, k, v, causal=False: ref.attention(
        q, k, v, causal=causal)), ref.layernorm


def _mha(p, prefix, xq, xkv, cfg, ops, causal=False):
    """Multi-head attention: xq (lq, d), xkv (lk, d) -> (lq, d)."""
    mm, attn, _ = ops
    h, dh = cfg.n_heads, cfg.d_head
    q = mm(xq, p[f"{prefix}.wq"])
    k = mm(xkv, p[f"{prefix}.wk"])
    v = mm(xkv, p[f"{prefix}.wv"])
    # (l, d) -> (h, l, dh)
    to_heads = lambda t: t.reshape(t.shape[0], h, dh).transpose(1, 0, 2)
    o = attn(to_heads(q), to_heads(k), to_heads(v), causal=causal)
    o = o.transpose(1, 0, 2).reshape(xq.shape[0], cfg.d_model)
    return mm(o, p[f"{prefix}.wo"])


def _mlp(p, prefix, x, ops):
    mm = ops[0]
    hdn = jax.nn.gelu(mm(x, p[f"{prefix}.w1"]) + p[f"{prefix}.b1"])
    return mm(hdn, p[f"{prefix}.w2"]) + p[f"{prefix}.b2"]


def _ln(p, prefix, x, ops):
    return ops[2](x, p[f"{prefix}.g"], p[f"{prefix}.b"])


# ---------------------------------------------------------------------------
# agent-side: encoder  f(x, w_hat) -> o        (paper eq. 1)
# ---------------------------------------------------------------------------

def patchify(cfg: ModelConfig, image):
    """(F*)H x W x 3 image -> (n_tokens, patch_dim)."""
    hw, ps = cfg.image_hw, cfg.patch
    img = image.reshape(cfg.frames, hw, hw, 3)
    n = hw // ps
    x = img.reshape(cfg.frames, n, ps, n, ps, 3)
    x = x.transpose(0, 1, 3, 2, 4, 5).reshape(cfg.frames * n * n, cfg.patch_dim)
    return x


def encode(params, image, cfg: ModelConfig, use_pallas=True):
    """Agent-side forward: image (frames*hw, hw, 3) -> embedding o."""
    ops = _ops(use_pallas)
    mm = ops[0]
    x = patchify(cfg, image)
    x = mm(x, params["patch_proj"])
    pos = jnp.tile(params["pos_emb"], (cfg.frames, 1))
    if cfg.frames > 1:
        pos = pos + jnp.repeat(params["frame_emb"], cfg.tokens_per_frame, 0)
    x = x + pos
    for i in range(cfg.n_enc_layers):
        p = f"enc{i}"
        x = x + _mha(params, f"{p}.attn", _ln(params, f"{p}.ln1", x, ops),
                     _ln(params, f"{p}.ln1", x, ops), cfg, ops)
        x = x + _mlp(params, f"{p}.mlp", _ln(params, f"{p}.ln2", x, ops), ops)
    if cfg.use_bridge:
        q = _ln(params, "bridge.lnq", params["bridge.queries"], ops)
        x = _mha(params, "bridge.attn", q, x, cfg, ops)
    return _ln(params, "enc_out_ln", x, ops)


# ---------------------------------------------------------------------------
# server-side: decoder  f~(o, v) -> tokens     (paper eq. 2)
# ---------------------------------------------------------------------------

def decode_logits(params, emb, tokens, cfg: ModelConfig, use_pallas=True):
    """Teacher-forced decoder forward: logits (max_len, vocab)."""
    ops = _ops(use_pallas)
    mm = ops[0]
    x = jnp.take(params["tok_emb"], tokens, axis=0) + params["dec_pos_emb"]
    for i in range(cfg.n_dec_layers):
        p = f"dec{i}"
        y = _ln(params, f"{p}.ln1", x, ops)
        x = x + _mha(params, f"{p}.self", y, y, cfg, ops, causal=True)
        x = x + _mha(params, f"{p}.cross", _ln(params, f"{p}.ln2", x, ops),
                     emb, cfg, ops)
        x = x + _mlp(params, f"{p}.mlp", _ln(params, f"{p}.ln3", x, ops), ops)
    x = _ln(params, "dec_out_ln", x, ops)
    return mm(x, params["out_proj"])


def greedy_decode(params, emb, cfg: ModelConfig, use_pallas=True):
    """Greedy autoregressive decode: embedding -> token ids (max_len,).

    Each scan step re-runs the full causal forward over the token buffer
    (max_len is tiny, so this is cheaper than maintaining a KV cache in the
    lowered HLO) and commits the argmax at the current position.
    """
    T = cfg.max_len

    def step(tokens, t):
        logits = decode_logits(params, emb, tokens, cfg, use_pallas)
        nxt = jnp.argmax(jax.lax.dynamic_slice(
            logits, (t, 0), (1, cfg.vocab))[0]).astype(jnp.int32)
        tokens = jax.lax.dynamic_update_slice(tokens, nxt[None], (t + 1,))
        return tokens, nxt

    init = jnp.zeros((T,), jnp.int32).at[0].set(BOS)
    tokens, _ = jax.lax.scan(step, init, jnp.arange(T - 1))
    return tokens


# ---------------------------------------------------------------------------
# FCDNN-16 (Fig. 3 verification model)
# ---------------------------------------------------------------------------

def fcdnn_forward(params, x, use_pallas=True):
    """x: (batch, 784) -> reconstruction (batch, 784). ReLU autoencoder."""
    mm = _ops(use_pallas)[0]
    n_layers = len(FCDNN_DIMS) - 1
    for i in range(n_layers):
        x = mm(x, params[f"fc{i}.w"]) + params[f"fc{i}.b"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


# ---------------------------------------------------------------------------
# analytic FLOP counts (feeds the paper's delay/energy model, eq. 4-9)
# ---------------------------------------------------------------------------

def encoder_flops(cfg: ModelConfig) -> int:
    n, d, dm = cfg.n_tokens, cfg.d_model, cfg.d_mlp
    per_block = 2 * n * d * d * 4 + 2 * 2 * n * n * d + 2 * n * d * dm * 2
    total = 2 * n * cfg.patch_dim * d + cfg.n_enc_layers * per_block
    if cfg.use_bridge:
        nq = cfg.n_query
        total += 2 * (nq + 2 * n) * d * d + 2 * 2 * nq * n * d + 2 * nq * d * d
    return total


def decoder_flops(cfg: ModelConfig) -> int:
    T, d, dm, ne = cfg.max_len, cfg.d_model, cfg.d_mlp, cfg.emb_tokens
    per_block = (2 * T * d * d * 4 + 2 * 2 * T * T * d       # self
                 + 2 * (T + 2 * ne) * d * d + 2 * 2 * T * ne * d  # cross
                 + 2 * T * d * dm * 2)                        # mlp
    per_fwd = cfg.n_dec_layers * per_block + 2 * T * d * cfg.vocab
    return per_fwd * (T - 1)  # greedy decode re-runs the forward per step


def fcdnn_flops() -> int:
    return sum(2 * FCDNN_DIMS[i] * FCDNN_DIMS[i + 1]
               for i in range(len(FCDNN_DIMS) - 1))
