"""Build-time training of the captioners and the FCDNN-16 autoencoder.

Runs once inside ``make artifacts`` (python is never on the request path).
Training uses the pure-jnp reference kernels (``use_pallas=False``) — they
are mathematically identical to the Pallas kernels (asserted by
python/tests) but orders of magnitude faster than interpret mode, which is
the right trade-off for the compile path.

Optimizer: hand-rolled Adam (no optax in the offline environment).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import datagen, model
from .model import ModelConfig

# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": zeros, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=3e-4, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = {k: b1 * state["m"][k] + (1 - b1) * grads[k] for k in params}
    v = {k: b2 * state["v"][k] + (1 - b2) * grads[k] ** 2 for k in params}
    tf = t.astype(jnp.float32)
    mhat = {k: m[k] / (1 - b1 ** tf) for k in params}
    vhat = {k: v[k] / (1 - b2 ** tf) for k in params}
    new = {k: params[k] - lr * mhat[k] / (jnp.sqrt(vhat[k]) + eps)
           for k in params}
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# captioner training
# ---------------------------------------------------------------------------


def _caption_loss(params, image, tokens, cfg: ModelConfig):
    """Teacher-forced cross-entropy; logits[t] predicts tokens[t+1]."""
    emb = model.encode(params, image, cfg, use_pallas=False)
    logits = model.decode_logits(params, emb, tokens, cfg, use_pallas=False)
    targets = tokens[1:]
    lp = jax.nn.log_softmax(logits[:-1], axis=-1)
    nll = -jnp.take_along_axis(lp, targets[:, None], axis=-1)[:, 0]
    mask = (targets != model.PAD).astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def train_captioner(cfg: ModelConfig, steps=1500, batch=32, n_train=4096,
                    seed=0, lr=3e-4, log_every=200, log=print):
    """Fit encoder+decoder jointly on the synthetic corpus.

    Returns (params, final_loss). Deterministic in `seed`.
    """
    kind = "image" if cfg.frames == 1 else "video"
    xs, refs = datagen.dataset(kind, n_train, seed=seed + 1000)
    vocab = datagen.make_vocab()
    assert len(vocab) <= cfg.vocab, f"vocab {len(vocab)} > {cfg.vocab}"
    # all paraphrase references tokenized: (n_train, n_refs, max_len)
    toks = np.asarray(
        [[datagen.tokenize(vocab, r, cfg.max_len) for r in rs] for rs in refs],
        np.int32,
    )
    xs = jnp.asarray(xs.reshape(n_train, cfg.frames * cfg.image_hw,
                                cfg.image_hw, 3))
    toks = jnp.asarray(toks)

    spec = model.encoder_param_spec(cfg) + model.decoder_param_spec(cfg)
    params = model.init_params(spec, jax.random.PRNGKey(seed))
    opt = adam_init(params)

    batched_loss = jax.vmap(_caption_loss, in_axes=(None, 0, 0, None))

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step_fn(params, opt, key):
        ki, kr = jax.random.split(key)
        idx = jax.random.randint(ki, (batch,), 0, n_train)
        ref_idx = jax.random.randint(kr, (batch,), 0, toks.shape[1])
        imgs = xs[idx]
        tgts = toks[idx, ref_idx]
        loss, grads = jax.value_and_grad(
            lambda p: batched_loss(p, imgs, tgts, cfg).mean())(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    key = jax.random.PRNGKey(seed + 1)
    loss = jnp.inf
    for s in range(steps):
        key, sub = jax.random.split(key)
        params, opt, loss = step_fn(params, opt, sub)
        if log and (s % log_every == 0 or s == steps - 1):
            log(f"[train {cfg.name}] step {s:5d} loss {float(loss):.4f}")
    return params, float(loss)


# ---------------------------------------------------------------------------
# FCDNN-16 training (synthetic MNIST-like glyph reconstruction)
# ---------------------------------------------------------------------------


def _glyph_digits(n, rng):
    """28x28 grayscale glyph images, flattened to 784 — MNIST stand-in."""
    out = np.zeros((n, 28, 28), np.float32)
    objs = list(datagen.GLYPHS)
    for i in range(n):
        g = datagen.GLYPHS[objs[rng.integers(len(objs))]]
        scale = rng.integers(2, 4)
        big = np.kron(g, np.ones((scale, scale), np.float32))
        y = rng.integers(0, 29 - big.shape[0])
        x = rng.integers(0, 29 - big.shape[1])
        out[i, y:y + big.shape[0], x:x + big.shape[1]] = big
        out[i] += rng.normal(0, 0.05, (28, 28)).astype(np.float32)
    return np.clip(out, 0, 1).reshape(n, 784)


def train_fcdnn(steps=800, batch=64, n_train=2048, seed=0, lr=1e-3,
                log_every=200, log=print):
    """Fit the Fig.-3 autoencoder with MSE; returns (params, final_loss)."""
    rng = np.random.default_rng(seed)
    data = jnp.asarray(_glyph_digits(n_train, rng))
    params = model.init_params(model.fcdnn_param_spec(),
                               jax.random.PRNGKey(seed))
    opt = adam_init(params)

    @functools.partial(jax.jit, donate_argnums=(0, 1))
    def step_fn(params, opt, key):
        idx = jax.random.randint(key, (batch,), 0, n_train)
        x = data[idx]
        loss, grads = jax.value_and_grad(
            lambda p: jnp.mean(
                (model.fcdnn_forward(p, x, use_pallas=False) - x) ** 2)
        )(params)
        params, opt = adam_update(params, grads, opt, lr=lr)
        return params, opt, loss

    key = jax.random.PRNGKey(seed + 1)
    loss = jnp.inf
    for s in range(steps):
        key, sub = jax.random.split(key)
        params, opt, loss = step_fn(params, opt, sub)
        if log and (s % log_every == 0 or s == steps - 1):
            log(f"[train fcdnn16] step {s:5d} mse {float(loss):.5f}")
    return params, float(loss)
