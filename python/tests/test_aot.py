"""AOT path: HLO text emission, weight serialization, manifest consistency."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.model import BLIP2ISH

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_emits_parseable_module():
    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4, 4), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
    # return_tuple=True: the root must be a tuple
    assert "tuple" in text.lower()


def test_lower_with_params_binds_weights_in_spec_order():
    cfg = BLIP2ISH
    spec = model.encoder_param_spec(cfg)
    params = model.init_params(spec, jax.random.PRNGKey(0))

    def fn(inputs, ws):
        (img,) = inputs
        return model.encode(ws, img, cfg, use_pallas=False)

    img = jax.ShapeDtypeStruct((cfg.image_hw, cfg.image_hw, 3), jnp.float32)
    text = aot.lower_with_params(fn, spec, params, img)
    # one HLO parameter per weight + 1 input
    assert text.count("parameter(") >= len(spec) + 1


def test_write_weights_roundtrip(tmp_path):
    cfg = BLIP2ISH
    spec = model.encoder_param_spec(cfg)
    params = model.init_params(spec, jax.random.PRNGKey(1))
    path = tmp_path / "w.bin"
    n = aot.write_weights(str(path), spec, params)
    assert n == sum(int(np.prod(s)) for _, s in spec)
    blob = np.fromfile(str(path), "<f4")
    # first tensor must match exactly
    first = np.asarray(params[spec[0][0]]).reshape(-1)
    np.testing.assert_array_equal(blob[: first.size], first)


def test_fit_lambda_excludes_layernorm():
    cfg = BLIP2ISH
    spec = model.encoder_param_spec(cfg)
    params = model.init_params(spec, jax.random.PRNGKey(2))
    lam, nq = aot.fit_lambda(params, spec)
    assert lam > 0
    total = sum(int(np.prod(s)) for _, s in spec)
    assert nq < total  # ln gains/biases excluded
    # sanity: lambda = 1/mean|w| of an init'd net is O(10..1000)
    assert 1 < lam < 1e4


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built")
def test_manifest_consistency():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    for mname in ("blip2ish", "gitish"):
        entry = man["models"][mname]
        for side in ("agent", "server"):
            blob = np.fromfile(
                os.path.join(ART, entry[side]["weights"]), "<f4")
            assert blob.size == entry[side]["total_f32"]
            spec_n = sum(int(np.prod(p["shape"]))
                         for p in entry[side]["params"])
            assert spec_n == entry[side]["total_f32"]
            assert entry[side]["lambda"] > 0
            for hlo in entry[side]["hlo"].values():
                assert os.path.exists(os.path.join(ART, hlo))
    # eval refs shipped with the right fanout
    assert len(man["eval"]["coco"]["refs"][0]) == 5
    n = man["eval"]["coco"]["shape"][0]
    assert len(man["eval"]["coco"]["refs"]) == n
