"""Synthetic corpus invariants: determinism, tokenizer round-trip, grammar."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import datagen
from compile.model import BLIP2ISH


def test_vocab_is_deterministic_and_covers_grammar():
    v1, v2 = datagen.make_vocab(), datagen.make_vocab()
    assert v1 == v2
    assert v1[:4] == ["<pad>", "<bos>", "<eos>", "<unk>"]
    for w in datagen.COLORS + datagen.OBJECTS + datagen.DIRECTIONS:
        assert w in v1
    assert len(v1) <= BLIP2ISH.vocab


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_captions_fit_max_len_and_roundtrip(seed):
    rng = np.random.default_rng(seed)
    vocab = datagen.make_vocab()
    _, refs = datagen.image_sample(rng)
    for r in refs:
        ids = datagen.tokenize(vocab, r, BLIP2ISH.max_len)
        assert len(ids) == BLIP2ISH.max_len
        assert datagen.detokenize(vocab, ids) == r


def test_dataset_determinism():
    x1, r1 = datagen.dataset("image", 4, seed=5)
    x2, r2 = datagen.dataset("image", 4, seed=5)
    np.testing.assert_array_equal(x1, x2)
    assert r1 == r2
    x3, _ = datagen.dataset("image", 4, seed=6)
    assert np.abs(x1 - x3).max() > 0


def test_image_sample_shapes_and_range():
    rng = np.random.default_rng(0)
    img, refs = datagen.image_sample(rng)
    assert img.shape == (32, 32, 3)
    assert len(refs) == 5
    assert 0 <= img.min() and img.max() <= 1


def test_video_sample_has_motion():
    rng = np.random.default_rng(0)
    clip, refs = datagen.video_sample(rng)
    assert clip.shape == (4, 32, 32, 3)
    assert len(refs) == 5
    # frames must differ (the moving object) -> temporal signal exists
    assert np.abs(clip[0] - clip[3]).max() > 0.3


def test_glyphs_are_pairwise_distinct():
    gs = list(datagen.GLYPHS.values())
    for i in range(len(gs)):
        for j in range(i + 1, len(gs)):
            assert np.abs(gs[i] - gs[j]).sum() > 0


def test_detokenize_stops_at_eos():
    vocab = datagen.make_vocab()
    ids = datagen.tokenize(vocab, "a red ball", 12)
    # inject garbage after EOS; detokenize must ignore it
    eos_pos = ids.index(datagen.EOS)
    ids = ids[:eos_pos + 1] + [5] * (12 - eos_pos - 1)
    assert datagen.detokenize(vocab, ids) == "a red ball"
