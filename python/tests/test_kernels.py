"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis drives the shape/value sweeps — this is the core correctness
signal for the kernels that get lowered into the shipped artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, layernorm, matmul, quantize, ref

F32 = np.float32


def arr(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape).astype(F32))


# ---------------------------------------------------------------------------
# fake-quant kernels
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(rows=st.sampled_from([8, 16, 64]),
       step=st.floats(1e-4, 0.5),
       seed=st.integers(0, 2**31 - 1))
def test_fake_quant_uniform_matches_ref(rows, step, seed):
    rng = np.random.default_rng(seed)
    w = arr(rng, rows, 128, scale=0.2)
    got = np.asarray(quantize.fake_quant_uniform(w, step))
    want = np.asarray(ref.fake_quant_uniform(w, step))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


@settings(max_examples=25, deadline=None)
@given(rows=st.sampled_from([8, 32]),
       emin=st.integers(-12, -2),
       width=st.integers(0, 10),
       seed=st.integers(0, 2**31 - 1))
def test_fake_quant_pot_matches_ref(rows, emin, width, seed):
    rng = np.random.default_rng(seed)
    w = arr(rng, rows, 128, scale=0.2)
    got = np.asarray(quantize.fake_quant_pot(w, float(emin),
                                             float(emin + width)))
    want = np.asarray(ref.fake_quant_pot(w, float(emin), float(emin + width)))
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_fake_quant_uniform_zero_step_is_identity():
    rng = np.random.default_rng(0)
    w = arr(rng, 8, 128)
    got = np.asarray(quantize.fake_quant_uniform(w, 0.0))
    np.testing.assert_allclose(got, np.asarray(w))


@settings(max_examples=10, deadline=None)
@given(step=st.floats(1e-3, 0.3), seed=st.integers(0, 2**31 - 1))
def test_fake_quant_uniform_idempotent(step, seed):
    rng = np.random.default_rng(seed)
    w = arr(rng, 8, 128, scale=0.2)
    q1 = quantize.fake_quant_uniform(w, step)
    q2 = quantize.fake_quant_uniform(q1, step)
    np.testing.assert_allclose(np.asarray(q1), np.asarray(q2),
                               rtol=0, atol=1e-6)


def test_fake_quant_preserves_sign():
    rng = np.random.default_rng(3)
    w = arr(rng, 8, 128)
    for q in (quantize.fake_quant_uniform(w, 0.07),
              quantize.fake_quant_pot(w, -6.0, 0.0)):
        q = np.asarray(q)
        wn = np.asarray(w)
        assert ((np.sign(q) == np.sign(wn)) | (q == 0)).all()


def test_pad_to_buffer_roundtrip():
    flat = jnp.arange(1000, dtype=jnp.float32)
    buf, n = quantize.pad_to_buffer(flat)
    assert n == 1000 and buf.shape[1] == 128
    assert buf.shape[0] % quantize.ROWS_PER_BLOCK == 0
    np.testing.assert_allclose(np.asarray(buf).reshape(-1)[:n],
                               np.asarray(flat))


# ---------------------------------------------------------------------------
# matmul kernel
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(m=st.sampled_from([1, 4, 16, 64]),
       k=st.sampled_from([32, 48, 128, 784]),
       n=st.sampled_from([32, 128, 512]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = arr(rng, m, k), arr(rng, k, n)
    got = np.asarray(matmul.matmul(x, y))
    want = np.asarray(ref.matmul(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


def test_matmul_block_picker():
    assert matmul._pick_block(784, 128) == 16
    assert matmul._pick_block(128) == 128
    assert matmul._pick_block(1) == 1
    assert matmul._pick_block(48) == 48


def test_matmul_vmem_budget():
    # default tiles must fit the ~16 MiB per-core VMEM budget with margin
    assert matmul.vmem_bytes() < 4 * 1024 * 1024


# ---------------------------------------------------------------------------
# attention kernel
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(h=st.sampled_from([1, 4]),
       lq=st.sampled_from([12, 16, 64]),
       lk=st.sampled_from([16, 64]),
       causal=st.booleans(),
       seed=st.integers(0, 2**31 - 1))
def test_attention_matches_ref(h, lq, lk, causal, seed):
    if causal and lq > lk:
        lq = lk
    rng = np.random.default_rng(seed)
    q, k, v = arr(rng, h, lq, 32), arr(rng, h, lk, 32), arr(rng, h, lk, 32)
    got = np.asarray(attention.attention(q, k, v, causal=causal))
    want = np.asarray(ref.attention(q, k, v, causal=causal))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_attention_rows_are_convex_combinations():
    # each output row lies in the convex hull of V rows => max |out| <= max |v|
    rng = np.random.default_rng(1)
    q, k, v = arr(rng, 2, 16, 32), arr(rng, 2, 64, 32), arr(rng, 2, 64, 32)
    out = np.asarray(attention.attention(q, k, v))
    assert np.abs(out).max() <= np.abs(np.asarray(v)).max() + 1e-5


# ---------------------------------------------------------------------------
# layernorm kernel
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([1, 8, 24, 64]),
       d=st.sampled_from([32, 128]),
       seed=st.integers(0, 2**31 - 1))
def test_layernorm_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x = arr(rng, n, d, scale=3.0)
    g = arr(rng, d, scale=0.5) + 1.0
    b = arr(rng, d, scale=0.5)
    got = np.asarray(layernorm.layernorm(x, g, b))
    want = np.asarray(ref.layernorm(x, g, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_layernorm_output_standardized():
    rng = np.random.default_rng(2)
    x = arr(rng, 16, 128, scale=5.0)
    out = np.asarray(layernorm.layernorm(x, jnp.ones(128), jnp.zeros(128)))
    np.testing.assert_allclose(out.mean(-1), 0, atol=1e-4)
    np.testing.assert_allclose(out.std(-1), 1, atol=1e-2)
