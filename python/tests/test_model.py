"""L2 model invariants: pallas/ref agreement, shapes, causality, patchify."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model
from compile.model import BLIP2ISH, GITISH


def _params(cfg, seed=0):
    spec = model.encoder_param_spec(cfg) + model.decoder_param_spec(cfg)
    return model.init_params(spec, jax.random.PRNGKey(seed))


def _image(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(
        size=(cfg.frames * cfg.image_hw, cfg.image_hw, 3)).astype(np.float32))


@pytest.mark.parametrize("cfg", [BLIP2ISH, GITISH], ids=lambda c: c.name)
def test_encode_shape_and_pallas_agreement(cfg):
    p = _params(cfg)
    x = _image(cfg)
    e_ref = model.encode(p, x, cfg, use_pallas=False)
    e_pal = model.encode(p, x, cfg, use_pallas=True)
    assert e_ref.shape == (cfg.emb_tokens, cfg.d_model)
    np.testing.assert_allclose(np.asarray(e_pal), np.asarray(e_ref),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("cfg", [BLIP2ISH, GITISH], ids=lambda c: c.name)
def test_greedy_decode_pallas_agreement(cfg):
    p = _params(cfg)
    emb = model.encode(p, _image(cfg), cfg, use_pallas=False)
    t_ref = np.asarray(model.greedy_decode(p, emb, cfg, use_pallas=False))
    t_pal = np.asarray(model.greedy_decode(p, emb, cfg, use_pallas=True))
    assert t_ref.shape == (cfg.max_len,)
    assert t_ref[0] == model.BOS
    assert (t_ref == t_pal).all()


def test_decoder_is_causal():
    """Changing token t must not change logits at positions < t."""
    cfg = BLIP2ISH
    p = _params(cfg)
    emb = model.encode(p, _image(cfg), cfg, use_pallas=False)
    toks = jnp.asarray(np.arange(cfg.max_len) % 7 + 1, jnp.int32)
    base = np.asarray(model.decode_logits(p, emb, toks, cfg,
                                          use_pallas=False))
    toks2 = toks.at[6].set(42)
    pert = np.asarray(model.decode_logits(p, emb, toks2, cfg,
                                          use_pallas=False))
    np.testing.assert_allclose(pert[:6], base[:6], rtol=1e-5, atol=1e-6)
    assert np.abs(pert[6:] - base[6:]).max() > 1e-4


def test_greedy_decode_matches_argmax_rollout():
    """scan-based decode == a hand-rolled python greedy rollout."""
    cfg = BLIP2ISH
    p = _params(cfg)
    emb = model.encode(p, _image(cfg), cfg, use_pallas=False)
    got = np.asarray(model.greedy_decode(p, emb, cfg, use_pallas=False))
    toks = np.zeros(cfg.max_len, np.int32)
    toks[0] = model.BOS
    for t in range(cfg.max_len - 1):
        logits = np.asarray(model.decode_logits(
            p, emb, jnp.asarray(toks), cfg, use_pallas=False))
        toks[t + 1] = int(logits[t].argmax())
    assert (got == toks).all()


def test_patchify_partitions_image():
    """patchify is a bijective rearrangement: pixel multiset is preserved."""
    cfg = BLIP2ISH
    x = _image(cfg, seed=3)
    patches = model.patchify(cfg, x)
    assert patches.shape == (cfg.n_tokens, cfg.patch_dim)
    np.testing.assert_allclose(np.sort(np.asarray(patches).reshape(-1)),
                               np.sort(np.asarray(x).reshape(-1)), rtol=1e-6)


def test_fcdnn_forward_shapes_and_agreement():
    p = model.init_params(model.fcdnn_param_spec(), jax.random.PRNGKey(1))
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(8, 784)).astype(np.float32))
    y_ref = model.fcdnn_forward(p, x, use_pallas=False)
    y_pal = model.fcdnn_forward(p, x, use_pallas=True)
    assert y_ref.shape == (8, 784)
    np.testing.assert_allclose(np.asarray(y_pal), np.asarray(y_ref),
                               rtol=1e-4, atol=1e-4)


def test_flop_counts_positive_and_ordered():
    # the video model sees 4x the frames but fewer layers; both positive
    assert model.encoder_flops(BLIP2ISH) > 0
    assert model.decoder_flops(BLIP2ISH) > 0
    assert model.fcdnn_flops() == sum(
        2 * model.FCDNN_DIMS[i] * model.FCDNN_DIMS[i + 1]
        for i in range(len(model.FCDNN_DIMS) - 1))


def test_param_specs_are_disjoint_and_deterministic():
    enc = model.encoder_param_spec(BLIP2ISH)
    dec = model.decoder_param_spec(BLIP2ISH)
    names = [n for n, _ in enc + dec]
    assert len(names) == len(set(names))
    assert enc == model.encoder_param_spec(BLIP2ISH)
