"""Build-time training smoke tests: losses must fall, and the training
path (ref kernels) must remain numerically interchangeable with the AOT
path (pallas kernels)."""

import jax
import numpy as np

from compile import model, train
from compile.model import BLIP2ISH


def test_captioner_loss_decreases_quickly():
    logs = []
    params, loss = train.train_captioner(
        BLIP2ISH, steps=40, batch=8, n_train=32, seed=1,
        log_every=39, log=lambda m: logs.append(m))
    assert loss < 4.0, f"loss should fall well below init (~5.5): {loss}"
    assert len(params) == len(
        model.encoder_param_spec(BLIP2ISH) + model.decoder_param_spec(BLIP2ISH))


def test_fcdnn_loss_decreases():
    params, loss = train.train_fcdnn(steps=60, batch=16, n_train=128, seed=1,
                                     log=None)
    assert loss < 0.15, f"mse should fall from ~0.2: {loss}"
    assert "fc0.w" in params


def test_adam_bias_correction_first_step():
    # first Adam step must move by ~lr regardless of gradient scale
    params = {"w": np.asarray([0.0], np.float32)}
    params = {k: jax.numpy.asarray(v) for k, v in params.items()}
    opt = train.adam_init(params)
    grads = {"w": jax.numpy.asarray([1000.0], np.float32)}
    new, _ = train.adam_update(params, grads, opt, lr=0.01)
    assert abs(float(new["w"][0]) + 0.01) < 1e-4


def test_trained_params_transfer_to_pallas_path():
    # weights trained with ref kernels produce the same embedding through
    # the pallas kernels (the core weight-transfer assumption of aot.py)
    params, _ = train.train_captioner(
        BLIP2ISH, steps=10, batch=4, n_train=16, seed=2, log=None)
    rng = np.random.default_rng(0)
    x = jax.numpy.asarray(rng.uniform(size=(32, 32, 3)).astype(np.float32))
    e_ref = model.encode(params, x, BLIP2ISH, use_pallas=False)
    e_pal = model.encode(params, x, BLIP2ISH, use_pallas=True)
    np.testing.assert_allclose(np.asarray(e_pal), np.asarray(e_ref),
                               rtol=1e-4, atol=1e-5)
