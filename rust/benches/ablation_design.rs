//! Ablations over the design choices DESIGN.md calls out:
//!  A1  SCA (Algorithm 1) vs exact bisection vs grid resolution — solution
//!      quality and planning cost;
//!  A2  dynamic batching: engine wall-clock throughput vs max_batch;
//!  A3  fixed-frequency pin calibration (the DESIGN.md §5 substitution):
//!      feasibility/bit-width across server pin fractions;
//!  A4  quantized-weight literal cache: cold vs warm request cost.

use qaci::bench_harness::{scaled, Table};
use qaci::coordinator::batcher::BatcherConfig;
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::opt::{bisection, fixed_freq, grid, sca, Problem};
use qaci::quant::Scheme;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::channel::Channel;
use qaci::system::Platform;
use qaci::util::timer::Stopwatch;

fn a1_solver_ablation() {
    let mut t = Table::new(
        "A1 — solver ablation @ paper BLIP-2 platform",
        &["(T0,E0)", "exact b̂", "SCA b̂", "grid32 b̂", "grid96 b̂", "exact µs", "SCA µs", "grid96 µs"],
    );
    for (t0, e0) in [(2.5, 2.0), (3.0, 1.0), (3.5, 2.0), (4.0, 0.8)] {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
        let sw = Stopwatch::start();
        let e = bisection::solve(&prob);
        let t_exact = sw.elapsed_us();
        let sw = Stopwatch::start();
        let s = sca::solve(&prob, sca::ScaOptions::default());
        let t_sca = sw.elapsed_us();
        let g32 = grid::solve(&prob, 32);
        let sw = Stopwatch::start();
        let g96 = grid::solve(&prob, 96);
        let t_grid = sw.elapsed_us();
        let b = |d: Option<u32>| d.map(|x| x.to_string()).unwrap_or("--".into());
        t.row(&[
            format!("({t0},{e0})"),
            b(e.map(|r| r.design.b_hat)),
            b(s.map(|r| r.design.b_hat)),
            b(g32.map(|d| d.b_hat)),
            b(g96.map(|d| d.b_hat)),
            format!("{t_exact:.0}"),
            format!("{t_sca:.0}"),
            format!("{t_grid:.0}"),
        ]);
    }
    t.print();
}

fn a2_batching(reg: &Registry) -> anyhow::Result<()> {
    let mut model = CoModel::load(reg, "blip2ish")?;
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco")?;
    let vocab = Vocab::from_manifest(&reg.manifest)?;
    let platform = Platform::paper_blip2()
        .with_workload(model.agent_flops, model.server_flops);
    let lambda = model.agent_weights.lambda;
    let n = scaled(32);

    let mut t = Table::new(
        "A2 — dynamic batching ablation (wall-clock, same workload)",
        &["max_batch", "req/s", "mean wall/req [ms]"],
    );
    for max_batch in [1usize, 2, 4] {
        let scheduler = Scheduler::new(platform, lambda, Algorithm::Exact, Scheme::Uniform, 1);
        let router = Router::new(QosPolicy::uniform(3.5, 2.0), scheduler);
        let mut engine = Engine::new(
            &mut model,
            router,
            &vocab,
            &eval,
            Channel::ideal(),
            EngineConfig { batcher: BatcherConfig { max_batch, max_wait_s: 1e9 } },
        );
        let sw = Stopwatch::start();
        let telemetry = engine.run(generate(n, eval.len(), Arrival::Batch, 3))?;
        let wall = sw.elapsed_s();
        t.row(&[
            max_batch.to_string(),
            format!("{:.1}", telemetry.len() as f64 / wall),
            format!("{:.2}", wall / telemetry.len() as f64 * 1e3),
        ]);
    }
    t.print();
    Ok(())
}

fn a3_fixed_pin() {
    let mut t = Table::new(
        "A3 — fixed-frequency server pin calibration (T0=3.5, E0=2.0)",
        &["server pin (frac of f̃max)", "b̂", "feasible"],
    );
    let prob = Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0);
    for frac in [1.0, 0.6, 0.35, fixed_freq::SERVER_FRACTION, 0.12, 0.08] {
        let d = fixed_freq::solve_at_fractions(&prob, 1.0, frac);
        t.row(&[
            format!("{frac:.2}"),
            d.map(|x| x.b_hat.to_string()).unwrap_or("--".into()),
            if d.is_some() { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
    println!("(the DESIGN.md §5 calibration: max/max pin is energy-degenerate)");
}

fn a4_weight_cache(reg: &Registry) -> anyhow::Result<()> {
    let mut model = CoModel::load(reg, "blip2ish")?;
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco")?;
    let mut t = Table::new("A4 — quantized-weight literal cache", &["request", "encode wall [ms]"]);
    let one = eval.sample(0).to_vec();
    // cold: first request at a fresh bit-width pays quantize+literals
    let sw = Stopwatch::start();
    model.encode(&one, 1, 9, Scheme::Pot)?;
    t.row(&["cold (9-bit PoT, first)".into(), format!("{:.2}", sw.elapsed_us() / 1e3)]);
    let sw = Stopwatch::start();
    model.encode(&one, 1, 9, Scheme::Pot)?;
    t.row(&["warm (9-bit PoT, repeat)".into(), format!("{:.2}", sw.elapsed_us() / 1e3)]);
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    a1_solver_ablation();
    a3_fixed_pin();
    if let Ok(reg) = Registry::open(&qaci::artifacts_dir()) {
        a2_batching(&reg)?;
        a4_weight_cache(&reg)?;
    }
    Ok(())
}
