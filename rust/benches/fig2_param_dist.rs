//! Fig. 2 — distribution of parameter magnitudes of pre-trained models.
//!
//! The paper fits an exponential PDF (eq. 3) to the weight magnitudes of
//! ResNet-152 / VideoMAE / BERT / BLIP-2 / GIT / GPT-3. We fit the same
//! model to (a) every weight blob this repo ships (trained captioners +
//! FCDNN) and (b) synthetic LAIM-like blobs, and report λ, differential
//! entropy, the KS statistic, and empirical-vs-fitted density rows.
//!
//! Paper shape to reproduce: a sharp peak at zero, exponential fit close
//! to the empirical histogram.

use qaci::bench_harness::Table;
use qaci::metrics::stats;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::theory::expdist::ExponentialModel;
use qaci::util::rng::Rng;

fn report(table: &mut Table, name: &str, mags: &[f64]) {
    let model = ExponentialModel::fit(mags.iter().copied());
    let ks = model.ks_statistic(mags);
    table.row(&[
        name.to_string(),
        format!("{}", mags.len()),
        format!("{:.2}", model.lambda),
        format!("{:.3}", model.mean()),
        format!("{:.2}", model.differential_entropy_bits()),
        format!("{ks:.4}"),
    ]);
}

fn density_rows(name: &str, mags: &[f64]) {
    let model = ExponentialModel::fit(mags.iter().copied());
    let max = 4.0 / model.lambda; // ~98% of the mass
    let (centers, density) = stats::histogram(mags, max, 12);
    let mut t = Table::new(
        &format!("{name}: empirical vs fitted exponential density"),
        &["θ", "empirical", "λe^-λθ"],
    );
    for (c, d) in centers.iter().zip(&density) {
        t.row(&[format!("{c:.4}"), format!("{d:.2}"), format!("{:.2}", model.pdf(*c))]);
    }
    t.print();
}

fn main() -> anyhow::Result<()> {
    let mut summary = Table::new(
        "Fig. 2 — exponential fit of parameter magnitudes",
        &["weights", "n", "λ (MLE)", "E[θ]", "h(Θ) bits", "KS"],
    );

    // (a) the shipped trained models
    if let Ok(reg) = Registry::open(&qaci::artifacts_dir()) {
        for name in ["blip2ish", "gitish"] {
            let model = CoModel::load(&reg, name)?;
            for (side, store) in
                [("agent", &model.agent_weights), ("server", &model.server_weights)]
            {
                let mags: Vec<f64> = store.blob.iter().map(|w| w.abs() as f64).collect();
                report(&mut summary, &format!("{name}/{side}"), &mags);
            }
        }
        let fcdnn = qaci::runtime::executor::Fcdnn::load(&reg)?;
        let mags: Vec<f64> = fcdnn.weights.blob.iter().map(|w| w.abs() as f64).collect();
        report(&mut summary, "fcdnn16", &mags);

        // density comparison for the headline model (the Fig. 2 panels)
        let model = CoModel::load(&reg, "blip2ish")?;
        let mags: Vec<f64> = model.agent_weights.blob.iter().map(|w| w.abs() as f64).collect();
        summary.print();
        density_rows("blip2ish/agent", &mags);
    } else {
        eprintln!("artifacts not built; synthetic blobs only");
        summary.print();
    }

    // (b) synthetic LAIM-scale stand-ins for the paper's big checkpoints
    // (gaussian-mixture weights, the shape trained transformers exhibit)
    let mut synth = Table::new(
        "synthetic LAIM blobs (ResNet/BERT/GPT-3 stand-ins)",
        &["weights", "n", "λ (MLE)", "E[θ]", "h(Θ) bits", "KS"],
    );
    let mut rng = Rng::new(2);
    for (name, scales) in [
        ("resnet152-like", vec![0.02, 0.05]),
        ("bert-like", vec![0.03, 0.08, 0.15]),
        ("gpt3-like", vec![0.01, 0.02, 0.05, 0.12]),
    ] {
        let n = 400_000;
        let mags: Vec<f64> = (0..n)
            .map(|i| (scales[i % scales.len()] * rng.normal()).abs())
            .collect();
        report(&mut synth, name, &mags);
    }
    synth.print();
    println!(
        "\npaper check: KS well below 0.5 everywhere = the sharp-peak-at-zero\n\
         exponential shape holds for trained weights (Fig. 2's claim)."
    );
    Ok(())
}
