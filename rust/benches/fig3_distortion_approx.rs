//! Fig. 3 — model output distortion vs the parameter-distortion bound,
//! as a function of quantization bit-width, for FCDNN-16, BLIP-2-like and
//! GIT-like models under uniform and PoT quantization.
//!
//! Paper shape to reproduce: the bound always dominates the measured
//! output distortion, and the gap narrows as the bit-width grows (tight
//! beyond ~3 bits for PoT / ~4 bits for uniform).
//!
//! Method per §VI-A: the model-dependent coefficient relating parameter
//! distortion to output distortion ("H" of Remark 3.2) is estimated in a
//! data-driven manner as an empirical upper-bound constant — here from
//! the lowest-bit point, then applied across the sweep.

use qaci::bench_harness::Table;
use qaci::metrics::stats;
use qaci::quant::Scheme;
use qaci::runtime::executor::{CoModel, Fcdnn};
use qaci::runtime::Registry;
use qaci::theory::distortion;

const BITS: [u32; 7] = [2, 3, 4, 5, 6, 7, 8];

/// Measured (param L1 distortion, output L1 distortion) for the FCDNN via
/// PJRT, plus the exact Prop. 3.1 layered bound.
fn fcdnn_rows(reg: &Registry, scheme: Scheme) -> anyhow::Result<()> {
    let fcdnn = Fcdnn::load(reg)?;
    // probe batch: the golden input shipped with the artifacts
    let x: Vec<f32> = std::fs::read(reg.dir.join("golden_fcdnn_input.bin"))?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    let y_full = fcdnn.forward_with_blob(&x, &fcdnn.weights.blob.clone())?;

    // layer matrices for the exact Prop. 3.1 coefficients. Blob tensors
    // are (in, out) row-major = W^T in the y = Wx convention; entrywise
    // and induced-L1-after-transpose norms are computed accordingly.
    let to_layers = |blob: &[f32]| -> Vec<distortion::LayerMatrix> {
        fcdnn
            .weights
            .specs
            .iter()
            .filter(|s| s.name.ends_with(".w"))
            .map(|s| {
                let (inp, out) = (s.shape[0], s.shape[1]);
                // transpose to (out, in) so matvec is y = W x
                let src = &blob[s.offset..s.offset + s.len];
                let mut data = vec![0.0f32; s.len];
                for i in 0..inp {
                    for o in 0..out {
                        data[o * inp + i] = src[i * out + o];
                    }
                }
                distortion::LayerMatrix::new(out, inp, data)
            })
            .collect()
    };
    let full_layers = to_layers(&fcdnn.weights.blob);
    let max_x1: f64 = (0..8)
        .map(|b| stats::l1(&x[b * 784..(b + 1) * 784]))
        .fold(0.0, f64::max);

    // gather the sweep, then (per §VI-A) estimate the model-dependent
    // coefficient relating parameter to output distortion as an empirical
    // upper-bound constant. The exact Prop. 3.1 product bound is also
    // reported: over 16 layers the norm product makes it astronomically
    // loose — which is precisely why the paper adopts the data-driven
    // constant (the layered bound is verified tight on shallow nets in
    // the integration tests).
    let mut rows = Vec::new();
    for bits in BITS {
        let qblob = fcdnn.weights.quantized_blob(bits, scheme);
        let y_q = fcdnn.forward_with_blob(&x, &qblob)?;
        let out_dist = stats::l1_dist(&y_full, &y_q);
        let param_dist = stats::l1_dist(&fcdnn.weights.blob, &qblob);
        let q_layers = to_layers(&qblob);
        let prop31 = distortion::output_distortion_bound(&full_layers, &q_layers) * max_x1;
        rows.push((bits, param_dist, out_dist, prop31));
    }
    let h = rows
        .iter()
        .map(|(_, p, o, _)| if *p > 0.0 { o / p } else { 0.0 })
        .fold(0.0f64, f64::max);

    let mut t = Table::new(
        &format!("Fig. 3 FCDNN-16 / {} quantization (H={h:.3e})", scheme.name()),
        &[
            "b̂",
            "param L1 (eq.15)",
            "H·param (bound)",
            "output L1 (measured)",
            "bound/meas",
            "Prop3.1 product (log10)",
        ],
    );
    for (bits, param, out, prop31) in rows {
        t.row(&[
            bits.to_string(),
            format!("{param:.1}"),
            format!("{:.3e}", h * param),
            format!("{out:.3e}"),
            format!("{:.2}", if out > 0.0 { h * param / out } else { f64::NAN }),
            format!("{:.1}", prop31.log10()),
        ]);
    }
    t.print();
    Ok(())
}

/// Transformer captioners: output distortion of the *embedding* vs the
/// surrogate parameter distortion with the empirical H constant.
fn captioner_rows(reg: &Registry, name: &str, scheme: Scheme) -> anyhow::Result<()> {
    let mut model = CoModel::load(reg, name)?;
    let eval_name = if name == "gitish" { "vatex" } else { "coco" };
    let eval = qaci::data::eval::EvalSet::load(&reg.dir, &reg.manifest, eval_name)?;
    let n_probe = 4usize;
    let mut inputs = Vec::new();
    for i in 0..n_probe {
        inputs.extend_from_slice(eval.sample(i));
    }
    let emb_full = model.encode(&inputs, n_probe, 32, scheme)?;

    // gather (param, output) distortion pairs
    let mut pairs = Vec::new();
    for bits in BITS {
        let qblob = model.agent_weights.quantized_blob(bits, scheme);
        let param = stats::l1_dist(&model.agent_weights.blob, &qblob);
        let emb_q = model.encode(&inputs, n_probe, bits, scheme)?;
        let out = stats::l1_dist(&emb_full, &emb_q);
        pairs.push((bits, param, out));
    }
    // empirical H from the coarsest point (Remark 3.2 data-driven bound)
    let h = pairs
        .iter()
        .map(|(_, p, o)| if *p > 0.0 { o / p } else { 0.0 })
        .fold(0.0f64, f64::max);

    let mut t = Table::new(
        &format!("Fig. 3 {name} / {} quantization (H={h:.3e})", scheme.name()),
        &["b̂", "param L1 (eq.15)", "H·param (bound)", "output L1 (measured)", "bound/meas"],
    );
    for (bits, param, out) in pairs {
        t.row(&[
            bits.to_string(),
            format!("{param:.1}"),
            format!("{:.3e}", h * param),
            format!("{out:.3e}"),
            format!("{:.2}", if out > 0.0 { h * param / out } else { f64::NAN }),
        ]);
    }
    t.print();
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let reg = Registry::open(&qaci::artifacts_dir())?;
    for scheme in [Scheme::Uniform, Scheme::Pot] {
        fcdnn_rows(&reg, scheme)?;
        captioner_rows(&reg, "blip2ish", scheme)?;
        captioner_rows(&reg, "gitish", scheme)?;
    }
    println!(
        "\npaper check: bound/meas >= 1 everywhere (bound dominates) and the\n\
         ratio shrinks toward 1 as b̂ grows — tight past ~3 bits (PoT) /\n\
         ~4 bits (uniform)."
    );
    Ok(())
}
