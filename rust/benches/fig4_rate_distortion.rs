//! Fig. 4 — upper and lower bounds of the distortion–rate function vs a
//! numerically estimated D(R) (Blahut–Arimoto on the discretized
//! exponential source).
//!
//! Paper shape to reproduce: D(R) decays ~exponentially; D^U is loose at
//! very low rate (test-channel construction) but tightens past ~2 bits;
//! D^L captures the scaling law; both bounds sandwich the BA curve.

use qaci::bench_harness::Table;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::theory::blahut_arimoto::BlahutArimoto;
use qaci::theory::rate_distortion as rd;

fn figure_for_lambda(lambda: f64, label: &str) {
    let ba = BlahutArimoto::exponential(lambda, 400, 12.0);
    let pts = ba.sweep(&BlahutArimoto::default_slopes(lambda), 400, 1e-9);

    let mut t = Table::new(
        &format!("Fig. 4 — distortion-rate bounds, {label} (λ={lambda:.2})"),
        &["R [bits]", "D^L(R)", "D_BA(R) (numeric)", "D^U(R)", "U/L ratio"],
    );
    for r in [0.5, 1.0, 1.5, 2.0, 2.5, 3.0, 4.0, 5.0, 6.0, 7.0] {
        let lo = rd::d_lower(r, lambda);
        let hi = rd::d_upper(r, lambda);
        let num = BlahutArimoto::distortion_at_rate(&pts, r);
        t.row(&[
            format!("{r:.1}"),
            format!("{lo:.4e}"),
            num.map(|d| format!("{d:.4e}")).unwrap_or("--".into()),
            format!("{hi:.4e}"),
            format!("{:.2}", hi / lo),
        ]);
    }
    t.print();
}

fn main() {
    // the paper's generic illustration (unit-ish λ) ...
    figure_for_lambda(10.0, "illustrative source");
    // ... and the λ actually fitted to the shipped agent model weights
    if let Ok(reg) = Registry::open(&qaci::artifacts_dir()) {
        if let Ok(model) = CoModel::load(&reg, "blip2ish") {
            figure_for_lambda(model.agent_weights.lambda, "blip2ish agent weights");
        }
    }
    println!(
        "\npaper check: D_BA within [D^L, D^U] (sandwich); U/L ratio falls\n\
         toward ~2 as R grows (loose only in the low-rate regime); both\n\
         bounds decay ~2^-R (the scaling law of Prop. 4.1)."
    );
}
