//! Fig. 5 — BLIP-2 on MS-COCO (stand-ins): CIDEr vs delay and energy
//! budgets under **uniform** quantization, proposed vs PPO vs
//! fixed-frequency vs feasible-random.
//!
//! Axes follow the paper: T0 sweep at E0 = 2.00 J (left) and E0 sweep at
//! T0 = 3.50 s (right), on the paper's platform constants; quality is
//! measured by running this repo's trained BLIP-2-like captioner at each
//! planned bit-width (DESIGN.md §5 substitution).
//!
//! Paper shape to reproduce: proposed highest everywhere; CIDEr rises as
//! either budget loosens; fixed-freq/random clearly below.

use qaci::bench_harness::scaled;
use qaci::figures::{FigureRunner, Sweep};
use qaci::quant::Scheme;

//
// Budget bands: shifted from the paper's absolute values (2.5-4.0 s /
// 0.5-4.0 J) to the band where OUR platform's max-feasible bit-width
// walks the quality-sensitive 2..13-bit region — the same role the
// paper's band plays on its testbed (see DESIGN.md §5).

fn main() -> anyhow::Result<()> {
    let mut runner = FigureRunner::open("blip2ish", scaled(32))?;
    runner.run_figure(
        "Fig. 5 BLIP-2/COCO, uniform",
        &[
            Sweep::Delay { e0: 2.0, t0s: vec![1.90, 2.00, 2.10, 2.25, 2.40, 2.55, 2.75] },
            Sweep::Energy { t0: 3.5, e0s: vec![0.45, 0.55, 0.65, 0.80, 1.00, 1.25, 1.50] },
        ],
        Scheme::Uniform,
        5,
    )
}
