//! Fig. 8 — GIT on VaTeX (stand-ins): CIDEr vs delay and energy budgets
//! under **nonuniform (PoT-log)** quantization.

use qaci::bench_harness::scaled;
use qaci::figures::{FigureRunner, Sweep};
use qaci::quant::Scheme;

//
// Budget bands: shifted from the paper's absolute values to the band
// where the GIT platform's max-feasible bit-width walks the quality-
// sensitive 2..13-bit region (see DESIGN.md §5).

fn main() -> anyhow::Result<()> {
    let mut runner = FigureRunner::open("gitish", scaled(32))?;
    runner.run_figure(
        "Fig. 8 GIT/VaTeX, nonuniform (PoT)",
        &[
            Sweep::Delay { e0: 2.0, t0s: vec![0.45, 0.50, 0.55, 0.60, 0.70, 0.80, 0.90] },
            Sweep::Energy { t0: 2.0, e0s: vec![0.10, 0.12, 0.14, 0.16, 0.20, 0.25, 0.30] },
        ],
        Scheme::Pot,
        8,
    )
}
