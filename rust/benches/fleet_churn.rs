//! §Fleet-churn — policy comparison under a churning population: agents
//! join, burst and leave over a fixed horizon while three allocation
//! policies ride the *same* event timeline: the equal split frozen at
//! t = 0, the proposed allocation frozen at t = 0, and online
//! warm-started re-allocation gated by the fleet config fingerprint.
//! Artifact-free (analytic allocator + queue model only).
//!
//! Acceptance properties checked inline: whenever the timeline actually
//! churns, the online policy achieves strictly lower time-averaged
//! fleet-weighted cost than the *best* static policy — including on the
//! heterogeneous-silicon scenario, where newcomers draw from the full
//! orin/xavier/phone ladder; with churn disabled the online policy
//! reproduces static-proposed exactly and never re-solves.

use qaci::bench_harness::Table;
use qaci::fleet::churn::{self, ChurnConfig, ChurnPolicy};
use qaci::opt::fleet::AgentSpec;
use qaci::system::queue::QueueDiscipline;
use qaci::system::Platform;

fn main() {
    let mut t = Table::new(
        "fleet churn: time-averaged weighted cost per policy (lower is better)",
        &[
            "scenario",
            "policy",
            "events",
            "reallocs",
            "skipped",
            "avg cost",
            "avg D^U",
            "solve p50 ms",
            "final N",
        ],
    );
    let scenarios: [(&str, ChurnConfig); 5] = [
        ("baseline", ChurnConfig::default()),
        (
            "no-churn",
            ChurnConfig { queue: None, ..ChurnConfig::default() }.without_churn(),
        ),
        (
            "heavy-churn",
            ChurnConfig {
                join_rps: 0.05,
                leave_rps_per_agent: 0.008,
                burst_rps: 0.02,
                seed: 7,
                ..ChurnConfig::default()
            },
        ),
        (
            "priority-queue",
            ChurnConfig {
                queue: Some(QueueDiscipline::WeightedPriority),
                seed: 3,
                ..ChurnConfig::default()
            },
        ),
        (
            "hetero-tiers",
            ChurnConfig {
                tiers: AgentSpec::tier_mix(2),
                seed: 3,
                ..ChurnConfig::default()
            },
        ),
    ];

    for (name, cfg) in scenarios {
        let (tl, reports) = churn::compare(Platform::fleet_edge(), &cfg);
        for r in &reports {
            t.row(&[
                name.to_string(),
                r.policy.name().to_string(),
                format!("{}", r.events),
                format!("{}", r.reallocations),
                format!("{}", r.realloc_skipped),
                format!("{:.4e}", r.time_avg_cost),
                format!("{:.4e}", r.time_avg_d_upper),
                format!("{:.2}", r.solve_ms.p50()),
                format!("{}", r.final_population),
            ]);
        }
        let cost = |p: ChurnPolicy| {
            reports.iter().find(|r| r.policy == p).unwrap().time_avg_cost
        };
        let online = cost(ChurnPolicy::Online);
        let best_static = cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
        if tl.joins + tl.leaves + tl.bursts == 0 {
            assert_eq!(
                online,
                cost(ChurnPolicy::StaticProposed),
                "{name}: without churn, online must reproduce static-proposed"
            );
            let r = reports.iter().find(|r| r.policy == ChurnPolicy::Online).unwrap();
            assert_eq!(r.reallocations, 0, "{name}: no events, no re-solves");
        } else {
            assert!(
                online < best_static,
                "{name}: online {online} does not beat best static {best_static}"
            );
        }
    }
    t.print();
    println!("\nOK: online re-allocation beats the best static policy under churn");
}
