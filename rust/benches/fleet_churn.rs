//! §Fleet-churn — policy comparison under a churning population: agents
//! join, burst and leave over a fixed horizon while three allocation
//! policies ride the *same* event timeline, scored two ways — the
//! analytic time-averaged fleet cost ([`churn`]) and the request-level
//! tail telemetry of the event replay ([`events`]: p99 end-to-end delay,
//! deadline-violation rate). Artifact-free (analytic allocator + queue
//! model + discrete-event loop only).
//!
//! Acceptance properties checked inline and re-checked against the
//! emitted `BENCH_fleet_churn.json` (see the crate root's "Bench
//! artifacts" section for the schema):
//! * whenever the timeline actually churns, the online policy achieves
//!   strictly lower time-averaged fleet-weighted cost than the *best*
//!   static policy — including on the heterogeneous-silicon scenario;
//! * with churn disabled the online policy reproduces static-proposed
//!   exactly and never re-solves;
//! * on the designated `burst-storm` scenario the online policy beats
//!   the best static policy on **p99 end-to-end delay** by more than 2×
//!   (measured ~11× at this seed) and on deadline-violation rate: frozen
//!   shares let the shared queue diverge during bursts, online re-solves
//!   keep the tail bounded;
//! * every number in the artifact is finite (emission re-parses the file
//!   and rejects NaN/inf).

use qaci::bench_harness::{emit_bench_artifact, num_or_null, Table};
use qaci::fleet::churn::{self, ChurnConfig, ChurnPolicy};
use qaci::fleet::events;
use qaci::opt::fleet::AgentSpec;
use qaci::system::queue::QueueDiscipline;
use qaci::system::Platform;
use qaci::util::json::Json;
use qaci::util::timer::Stopwatch;

fn main() {
    let mut t = Table::new(
        "fleet churn: analytic cost + event-level tails per policy (lower is better)",
        &[
            "scenario",
            "policy",
            "events",
            "reallocs",
            "avg cost",
            "avg D^U",
            "arrivals",
            "completed",
            "e2e p99 [s]",
            "viol %",
            "wall [ms]",
        ],
    );
    let scenarios: [(&str, ChurnConfig); 6] = [
        ("baseline", ChurnConfig::default()),
        (
            "no-churn",
            ChurnConfig { queue: None, ..ChurnConfig::default() }.without_churn(),
        ),
        (
            "heavy-churn",
            ChurnConfig {
                join_rps: 0.05,
                leave_rps_per_agent: 0.008,
                burst_rps: 0.02,
                seed: 7,
                ..ChurnConfig::default()
            },
        ),
        (
            "priority-queue",
            ChurnConfig {
                queue: Some(QueueDiscipline::WeightedPriority),
                seed: 3,
                ..ChurnConfig::default()
            },
        ),
        (
            "hetero-tiers",
            ChurnConfig {
                tiers: AgentSpec::tier_mix(2),
                seed: 3,
                ..ChurnConfig::default()
            },
        ),
        // the designated tail scenario: pure burst churn against a loaded
        // queue — frozen shares diverge, online re-allocation holds p99
        (
            "burst-storm",
            ChurnConfig {
                initial_agents: 5,
                join_rps: 0.0,
                leave_rps_per_agent: 0.0,
                burst_rps: 0.04,
                burst_factor: 6.0,
                burst_duration_s: 60.0,
                arrival_rps: 0.04,
                seed: 7,
                ..ChurnConfig::default()
            },
        ),
    ];

    let base = Platform::fleet_edge();
    let mut records: Vec<Json> = Vec::new();
    for (name, cfg) in scenarios {
        let tl = churn::timeline(&cfg);
        // one (analytic, event) replay per policy, timed together
        struct Out {
            policy: ChurnPolicy,
            cost: f64,
            p99: f64,
            viol: f64,
            reallocations: usize,
        }
        let mut outs: Vec<Out> = Vec::new();
        for policy in ChurnPolicy::ALL {
            let sw = Stopwatch::start();
            let an = churn::run_churn(base, &tl, policy, &cfg);
            let ev = events::run_events(base, &tl, policy, &cfg);
            let wall_s = sw.elapsed_s();
            assert!(an.time_avg_cost.is_finite(), "{name}/{policy:?}: non-finite cost");
            assert_eq!(
                ev.arrivals,
                ev.completed + ev.rejected + ev.dropped_departure,
                "{name}/{policy:?}: request conservation"
            );
            assert_eq!(
                ev.reallocations,
                an.reallocations,
                "{name}/{policy:?}: event and analytic replays disagree on re-solves"
            );
            let p99 = if ev.e2e_s.is_empty() { f64::NAN } else { ev.e2e_s.p99() };
            let wait_p99 =
                if ev.queue_wait_s.is_empty() { f64::NAN } else { ev.queue_wait_s.p99() };
            t.row(&[
                name.to_string(),
                policy.name().to_string(),
                format!("{}", an.events),
                format!("{}", an.reallocations),
                format!("{:.4e}", an.time_avg_cost),
                format!("{:.4e}", an.time_avg_d_upper),
                format!("{}", ev.arrivals),
                format!("{}", ev.completed),
                if p99.is_finite() { format!("{p99:.3}") } else { "--".into() },
                format!("{:.1}", ev.violation_rate() * 100.0),
                format!("{:.1}", wall_s * 1e3),
            ]);
            records.push(
                Json::obj()
                    .set("scenario", name)
                    .set("policy", policy.name())
                    .set("cost", an.time_avg_cost)
                    .set("d_upper", an.time_avg_d_upper)
                    .set("reallocations", an.reallocations)
                    .set("arrivals", ev.arrivals as usize)
                    .set("completed", ev.completed as usize)
                    .set("p99_s", num_or_null(p99))
                    .set("queue_wait_p99_s", num_or_null(wait_p99))
                    .set("deadline_violation_rate", ev.violation_rate())
                    .set("wall_clock_s", wall_s),
            );
            outs.push(Out {
                policy,
                cost: an.time_avg_cost,
                p99,
                viol: ev.violation_rate(),
                reallocations: an.reallocations,
            });
        }
        let by = |p: ChurnPolicy| outs.iter().find(|o| o.policy == p).unwrap();
        let online = by(ChurnPolicy::Online);
        let best_static_cost =
            by(ChurnPolicy::StaticEqual).cost.min(by(ChurnPolicy::StaticProposed).cost);
        if tl.joins + tl.leaves + tl.bursts == 0 {
            assert_eq!(
                online.cost,
                by(ChurnPolicy::StaticProposed).cost,
                "{name}: without churn, online must reproduce static-proposed"
            );
            assert_eq!(online.reallocations, 0, "{name}: no events, no re-solves");
        } else {
            assert!(
                online.cost < best_static_cost,
                "{name}: online {} does not beat best static {best_static_cost}",
                online.cost
            );
        }
        if name == "burst-storm" {
            let best_static_p99 =
                by(ChurnPolicy::StaticEqual).p99.min(by(ChurnPolicy::StaticProposed).p99);
            assert!(
                online.p99 < best_static_p99 * 0.5,
                "burst-storm: online p99 {} not clearly below best static {best_static_p99}",
                online.p99
            );
            let best_static_viol =
                by(ChurnPolicy::StaticEqual).viol.min(by(ChurnPolicy::StaticProposed).viol);
            assert!(
                online.viol < best_static_viol,
                "burst-storm: online violation rate {} vs best static {best_static_viol}",
                online.viol
            );
        }
    }
    t.print();

    // the machine-readable artifact CI uploads; orderings are re-checked
    // against the parsed-back document so the uploaded file is the
    // verified one
    let (_, doc) = emit_bench_artifact("fleet_churn", records);
    check_artifact_orderings(&doc);
    println!(
        "\nOK: online beats the best static policy under churn (cost), and on p99 under \
         burst-storm"
    );
}

/// Re-verify the headline orderings from the parsed artifact itself.
fn check_artifact_orderings(doc: &Json) {
    let results = doc.get("results").and_then(Json::as_arr).expect("results array");
    let field = |r: &Json, k: &str| -> String {
        r.get(k).and_then(Json::as_str).unwrap_or_default().to_string()
    };
    let cost_of = |scenario: &str, policy: &str| -> f64 {
        results
            .iter()
            .find(|r| field(r, "scenario") == scenario && field(r, "policy") == policy)
            .and_then(|r| r.get("cost"))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing cost for {scenario}/{policy}"))
    };
    for scenario in ["baseline", "heavy-churn", "priority-queue", "hetero-tiers", "burst-storm"] {
        let online = cost_of(scenario, "online-proposed");
        let best = cost_of(scenario, "static-equal").min(cost_of(scenario, "static-proposed"));
        assert!(online < best, "artifact: {scenario} online {online} !< best static {best}");
    }
    let p99_of = |policy: &str| -> f64 {
        results
            .iter()
            .find(|r| field(r, "scenario") == "burst-storm" && field(r, "policy") == policy)
            .and_then(|r| r.get("p99_s"))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing burst-storm p99 for {policy}"))
    };
    let online = p99_of("online-proposed");
    let best = p99_of("static-equal").min(p99_of("static-proposed"));
    assert!(online < best * 0.5, "artifact: burst-storm p99 {online} !< {best} / 2");
}
