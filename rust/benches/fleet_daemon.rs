//! §Fleet-daemon — closed-loop serving control plane A/B on the
//! designated `burst-storm` scenario: the hysteresis daemon (measured
//! admission pricing + predicted-gain probe + backlog urgency +
//! cooldown) against the resolve-always daemon and the static
//! allocations, all riding the same event timeline. Artifact-free (analytic allocator + queue model
//! + discrete-event loop only).
//!
//! Acceptance properties checked inline and re-checked against the
//! emitted `BENCH_fleet_daemon.json` (see the crate root's "Bench
//! artifacts" section for the schema):
//! * the storm forces re-solves, and the hysteresis daemon takes **at
//!   most half** of resolve-always's solve count (the gain gate and the
//!   cooldown must actually skip);
//! * the solves it does skip are cheap: hysteresis fleet p99 end-to-end
//!   delay stays within **1.5×** of resolve-always's;
//! * hysteresis still beats **every static policy** on p99 end-to-end
//!   delay strictly — fewer solves, not frozen shares (this is the
//!   ordering the bench-log baseline gates in CI);
//! * every arm conserves requests (completed + rejected + dropped =
//!   arrivals) and every number in the artifact is finite.
//!
//! `QACI_BENCH_FAST=1` (the CI smoke) serves fewer epochs and skips the
//! cross-arm tail assertions — short horizons starve the percentiles —
//! while still exercising every arm end to end.

use qaci::bench_harness::{emit_bench_artifact, fast_mode, num_or_null, Table};
use qaci::fleet::churn::{self, ChurnConfig, ChurnPolicy};
use qaci::fleet::daemon::{run_daemon, DaemonConfig};
use qaci::fleet::events;
use qaci::opt::fleet::AdmissionPricing;
use qaci::system::Platform;
use qaci::util::json::Json;
use qaci::util::timer::Stopwatch;

/// The designated tail scenario, shared with `benches/fleet_churn.rs`
/// and the daemon unit tests: pure burst churn against a loaded queue.
fn burst_storm() -> ChurnConfig {
    ChurnConfig {
        initial_agents: 5,
        join_rps: 0.0,
        leave_rps_per_agent: 0.0,
        burst_rps: 0.04,
        burst_factor: 6.0,
        burst_duration_s: 60.0,
        arrival_rps: 0.04,
        pricing: AdmissionPricing::Measured,
        seed: 7,
        ..ChurnConfig::default()
    }
}

struct Arm {
    policy: &'static str,
    arrivals: u64,
    completed: u64,
    resolves_taken: usize,
    resolves_skipped: usize,
    p99: f64,
    wait_p99: f64,
    viol: f64,
    energy_per_req: f64,
    wall_s: f64,
}

fn main() {
    let base = Platform::fleet_edge();
    let epochs = if fast_mode() { 2 } else { 8 };
    let hyst_cfg = DaemonConfig { churn: burst_storm(), epochs, ..DaemonConfig::default() };
    let always_cfg = DaemonConfig { resolve_always: true, ..hyst_cfg.clone() };
    // the statics ride the byte-identical timeline: same churn config,
    // horizon pinned to the daemon's epochs × epoch_s
    let mut ccfg = hyst_cfg.churn.clone();
    ccfg.horizon_s = hyst_cfg.horizon_s();
    let tl = churn::timeline(&ccfg);

    let mut arms: Vec<Arm> = Vec::new();
    for (policy, dcfg) in
        [("daemon-hysteresis", &hyst_cfg), ("daemon-resolve-always", &always_cfg)]
    {
        let sw = Stopwatch::start();
        let r = run_daemon(base, dcfg);
        let wall_s = sw.elapsed_s();
        assert_eq!(r.epochs.len(), dcfg.epochs, "{policy}: one snapshot per epoch");
        let rep = &r.report;
        assert_eq!(
            rep.arrivals,
            rep.completed + rep.rejected + rep.dropped_departure,
            "{policy}: request conservation"
        );
        arms.push(Arm {
            policy,
            arrivals: rep.arrivals,
            completed: rep.completed,
            resolves_taken: r.resolves_taken,
            resolves_skipped: r.skipped_cooldown + r.skipped_gain,
            p99: if rep.e2e_s.is_empty() { f64::NAN } else { rep.e2e_s.p99() },
            wait_p99: if rep.queue_wait_s.is_empty() { f64::NAN } else { rep.queue_wait_s.p99() },
            viol: rep.violation_rate(),
            energy_per_req: rep.energy_per_request_j(),
            wall_s,
        });
    }
    for policy in [ChurnPolicy::StaticEqual, ChurnPolicy::StaticProposed] {
        let sw = Stopwatch::start();
        let rep = events::run_events(base, &tl, policy, &ccfg);
        let wall_s = sw.elapsed_s();
        assert_eq!(
            rep.arrivals,
            rep.completed + rep.rejected + rep.dropped_departure,
            "{policy:?}: request conservation"
        );
        arms.push(Arm {
            policy: match policy {
                ChurnPolicy::StaticEqual => "static-equal",
                _ => "static-proposed",
            },
            arrivals: rep.arrivals,
            completed: rep.completed,
            resolves_taken: rep.reallocations,
            resolves_skipped: rep.realloc_skipped,
            p99: if rep.e2e_s.is_empty() { f64::NAN } else { rep.e2e_s.p99() },
            wait_p99: if rep.queue_wait_s.is_empty() { f64::NAN } else { rep.queue_wait_s.p99() },
            viol: rep.violation_rate(),
            energy_per_req: rep.energy_per_request_j(),
            wall_s,
        });
    }

    let mut t = Table::new(
        "fleet daemon: control policy x burst-storm (fewer solves, bounded tail)",
        &[
            "policy",
            "solves",
            "skipped",
            "arrivals",
            "completed",
            "e2e p99 [s]",
            "wait p99 [s]",
            "viol %",
            "J/req",
            "wall [ms]",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    for a in &arms {
        t.row(&[
            a.policy.to_string(),
            format!("{}", a.resolves_taken),
            format!("{}", a.resolves_skipped),
            format!("{}", a.arrivals),
            format!("{}", a.completed),
            if a.p99.is_finite() { format!("{:.3}", a.p99) } else { "--".into() },
            if a.wait_p99.is_finite() { format!("{:.3}", a.wait_p99) } else { "--".into() },
            format!("{:.1}", a.viol * 100.0),
            format!("{:.2}", a.energy_per_req),
            format!("{:.1}", a.wall_s * 1e3),
        ]);
        records.push(
            Json::obj()
                .set("scenario", "burst-storm")
                .set("policy", a.policy)
                .set("resolves_taken", a.resolves_taken)
                .set("resolves_skipped", a.resolves_skipped)
                .set("arrivals", a.arrivals as usize)
                .set("completed", a.completed as usize)
                .set("p99_s", num_or_null(a.p99))
                .set("queue_wait_p99_s", num_or_null(a.wait_p99))
                .set("deadline_violation_rate", a.viol)
                .set("energy_per_request_j", a.energy_per_req)
                .set("wall_clock_s", a.wall_s),
        );
    }
    t.print();

    let by = |p: &str| arms.iter().find(|a| a.policy == p).unwrap();
    let (hyst, always) = (by("daemon-hysteresis"), by("daemon-resolve-always"));
    assert!(always.resolves_taken > 0, "storm must force re-solves");
    if !fast_mode() {
        // the tentpole ordering: at most half the solves...
        assert!(
            2 * hyst.resolves_taken <= always.resolves_taken,
            "hysteresis took {} of resolve-always's {} solves",
            hyst.resolves_taken,
            always.resolves_taken
        );
        assert!(hyst.resolves_skipped > 0, "hysteresis must actually skip");
        // ...at a bounded tail cost against the reactive ceiling...
        assert!(
            hyst.p99 <= always.p99 * 1.5,
            "hysteresis p99 {} blew past 1.5x resolve-always {}",
            hyst.p99,
            always.p99
        );
        // ...while still beating every frozen allocation outright
        let best_static = by("static-equal").p99.min(by("static-proposed").p99);
        assert!(
            hyst.p99 < best_static,
            "hysteresis p99 {} not strictly below best static {best_static}",
            hyst.p99
        );
    }

    // the machine-readable artifact CI uploads; the headline ordering is
    // re-checked against the parsed-back document so the uploaded file
    // is the verified one (and the bench-log baseline gates it from
    // then on)
    let (_, doc) = emit_bench_artifact("fleet_daemon", records);
    if !fast_mode() {
        let results = doc.get("results").and_then(Json::as_arr).expect("results array");
        let p99_of = |policy: &str| -> f64 {
            results
                .iter()
                .find(|r| r.get("policy").and_then(Json::as_str) == Some(policy))
                .and_then(|r| r.get("p99_s"))
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("missing burst-storm p99 for {policy}"))
        };
        let hyst_p99 = p99_of("daemon-hysteresis");
        let best = p99_of("static-equal").min(p99_of("static-proposed"));
        assert!(
            hyst_p99 < best,
            "artifact: hysteresis p99 {hyst_p99} not below best static {best}"
        );
        println!(
            "\nOK: hysteresis takes <= half of resolve-always's solves ({} vs {}), holds p99 \
             within 1.5x ({:.3}s vs {:.3}s) and beats the best static ({:.3}s)",
            hyst.resolves_taken, always.resolves_taken, hyst.p99, always.p99, best
        );
    } else {
        println!("\nOK (fast mode): all arms ran end to end and conserved requests");
    }
}
