//! §Fleet-placement — multi-server fleets: the outer placement loop
//! (agent → server) composed with the exact per-server inner allocator,
//! for local-search against the equal-spread and nearest-server
//! baselines across server banks. Artifact-free (analytic allocator
//! only).
//!
//! Acceptance properties checked inline and re-checked against the
//! emitted `BENCH_fleet_placement.json` (see the crate root's "Bench
//! artifacts" section for the schema):
//! * on the designated `hot-server` scenario — two full-budget boxes
//!   plus one badly underpowered one, where round-robin strands a whole
//!   QoS block on the weak box — local-search achieves strictly lower
//!   fleet-weighted cost than equal-spread, and whenever it improves
//!   past both of its warm starts the accepted migrations show up as
//!   `placement.moves`;
//! * on uniform server banks local-search never loses to equal-spread;
//! * at S = 1 every placement strategy collapses to the single-server
//!   solver bit for bit (the legacy `solve_proposed` wrapper);
//! * on the `airtime-split` bank the explicit per-server airtime pins
//!   are honored: no server's agents ever sum past its reserved slice
//!   of the medium (checked for every strategy on every scenario);
//! * on the `queue-mix` bank a per-server queue-discipline override
//!   solves cleanly alongside the fleet-wide discipline, and an
//!   override *equal* to the global discipline is the identity — same
//!   allocation, bit for bit.

use qaci::bench_harness::{emit_bench_artifact, Table};
use qaci::obs::metrics;
use qaci::opt::fleet::{
    self, AgentSpec, FleetProblem, FleetSpec, PlacementStrategy, ServerSpec, SolveRequest,
};
use qaci::system::queue::{QueueDiscipline, QueueModel};
use qaci::system::Platform;
use qaci::util::json::Json;
use qaci::util::timer::Stopwatch;

fn fleet(n: usize, servers: Vec<ServerSpec>, queue: Option<QueueDiscipline>) -> FleetProblem {
    let mut spec = FleetSpec::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n));
    spec.servers = servers;
    spec.queue = queue.map(|d| QueueModel::uniform(d, n, 0.02));
    FleetProblem::from_spec(spec)
}

fn main() {
    let scenarios: Vec<(&str, usize, Vec<ServerSpec>, Option<QueueDiscipline>)> = vec![
        // the hot-server burst: round-robin strands the background block
        // on the 12%-budget box, where even the full budget can't seat it
        (
            "hot-server",
            9,
            vec![ServerSpec::default(), ServerSpec::default(), ServerSpec::scaled(0.12)],
            None,
        ),
        ("uniform-2", 8, ServerSpec::identical(2), None),
        ("uniform-3", 12, ServerSpec::identical(3), None),
        ("single", 8, ServerSpec::identical(1), None),
        // explicit asymmetric airtime pins: one box reserves 70% of the
        // medium, the other gets the rest — no head-count split
        (
            "airtime-split",
            8,
            vec![
                ServerSpec { airtime_fraction: Some(0.7), ..ServerSpec::default() },
                ServerSpec { airtime_fraction: Some(0.3), ..ServerSpec::default() },
            ],
            None,
        ),
        // per-server discipline override riding a fleet-wide FIFO queue:
        // box 1 serves its sub-fleet weighted-priority
        (
            "queue-mix",
            8,
            vec![
                ServerSpec {
                    queue: Some(QueueDiscipline::WeightedPriority),
                    ..ServerSpec::default()
                },
                ServerSpec::default(),
            ],
            Some(QueueDiscipline::Fifo),
        ),
    ];

    let mut t = Table::new(
        "fleet placement: strategy x server bank (fleet-weighted gap; lower is better)",
        &["scenario", "N", "S", "placement", "cost", "wgt D^U", "admitted", "moves", "alloc [ms]"],
    );
    let mut records: Vec<Json> = Vec::new();
    for (name, n, servers, queue) in &scenarios {
        let fp = fleet(*n, servers.clone(), *queue);
        let mut cost = std::collections::BTreeMap::<&str, f64>::new();
        let mut moves_of = std::collections::BTreeMap::<&str, u64>::new();
        for strategy in PlacementStrategy::ALL {
            let sw = Stopwatch::start();
            let (alloc, run) = metrics::scoped(|| {
                fp.solve(&SolveRequest { placement: strategy, ..SolveRequest::default() })
            });
            let alloc_s = sw.elapsed_s().max(1e-9);
            let moves = run.counter("placement.moves");
            let d_upper = alloc.weighted_d_upper(&fp);
            assert!(alloc.objective.is_finite(), "{name}/{strategy:?}: non-finite objective");
            assert_eq!(alloc.placement.assignment.len(), *n, "{name}: placement covers fleet");
            assert!(
                alloc.placement.assignment.iter().all(|&k| k < servers.len()),
                "{name}/{strategy:?}: agent placed on a nonexistent server"
            );
            // explicit airtime pins are a hard cap: a server's agents
            // can never sum past its reserved slice of the medium
            for (k, srv) in servers.iter().enumerate() {
                if let Some(f) = srv.airtime_fraction {
                    let sum: f64 = alloc
                        .agents
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| alloc.placement.assignment[*i] == k)
                        .map(|(_, a)| a.airtime_share)
                        .sum();
                    assert!(
                        sum <= f + 1e-9,
                        "{name}/{strategy:?}: server {k} airtime {sum} exceeds pinned {f}"
                    );
                }
            }
            cost.insert(strategy.name(), alloc.objective);
            moves_of.insert(strategy.name(), moves);
            t.row(&[
                name.to_string(),
                format!("{n}"),
                format!("{}", servers.len()),
                strategy.name().to_string(),
                format!("{:.3e}", alloc.objective),
                format!("{:.3e}", d_upper),
                format!("{}/{n}", alloc.admitted),
                format!("{moves}"),
                format!("{:.2}", alloc_s * 1e3),
            ]);
            records.push(
                Json::obj()
                    .set("scenario", *name)
                    .set("policy", strategy.name())
                    .set("cost", alloc.objective)
                    .set("d_upper", d_upper)
                    .set("admitted", alloc.admitted)
                    .set("placement_moves", moves as usize)
                    .set("wall_clock_s", alloc_s),
            );
        }
        let (local, spread) = (cost["local-search"], cost["equal-spread"]);
        // strictly better than both of its warm starts (the round-robin
        // spread and the all-on-strongest bank) ⇒ some move was accepted
        if local < spread - 1e-12 && local < cost["nearest-server"] - 1e-12 {
            assert!(
                moves_of["local-search"] > 0,
                "{name}: improved past both starts with no recorded placement.moves"
            );
        }
        if *name == "hot-server" {
            assert!(
                local < spread - 1e-9,
                "{name}: local-search {local} not strictly below equal-spread {spread}"
            );
        } else {
            assert!(
                local <= spread + 1e-15,
                "{name}: local-search {local} lost to equal-spread {spread}"
            );
        }
        if *name == "queue-mix" {
            // a per-server override equal to the fleet-wide discipline
            // is the identity: the sub-fleets see the same QueueModel,
            // so the solve reproduces the no-override bank bit for bit
            let redundant = fleet(
                *n,
                vec![
                    ServerSpec {
                        queue: Some(QueueDiscipline::Fifo),
                        ..ServerSpec::default()
                    };
                    2
                ],
                Some(QueueDiscipline::Fifo),
            );
            let plain = fleet(*n, ServerSpec::identical(2), Some(QueueDiscipline::Fifo));
            let a = redundant.solve(&SolveRequest::default());
            let b = plain.solve(&SolveRequest::default());
            assert_eq!(a.objective, b.objective, "redundant override must be the identity");
            for (x, y) in a.agents.iter().zip(&b.agents) {
                assert_eq!(x.server_share, y.server_share);
                assert_eq!(x.airtime_share, y.airtime_share);
            }
        }
        if servers.len() == 1 {
            // every strategy is the single-server solver, bit for bit
            let legacy = fleet::solve_proposed(&fp);
            for strategy in PlacementStrategy::ALL {
                let via = fp.solve(&SolveRequest { placement: strategy, ..Default::default() });
                assert_eq!(via.objective, legacy.objective, "{name}/{strategy:?}: S=1 identity");
                for (a, b) in via.agents.iter().zip(&legacy.agents) {
                    assert_eq!(a.server_share, b.server_share);
                    assert_eq!(a.airtime_share, b.airtime_share);
                }
            }
        }
    }
    t.print();

    // machine-readable artifact; the headline ordering is re-checked
    // against the parsed-back document so CI uploads exactly what was
    // verified (and the bench-log baseline gates it from then on)
    let (_, doc) = emit_bench_artifact("fleet_placement", records);
    let results = doc.get("results").and_then(Json::as_arr).expect("results array");
    let cost_of = |scenario: &str, policy: &str| -> f64 {
        results
            .iter()
            .find(|r| {
                r.get("scenario").and_then(Json::as_str) == Some(scenario)
                    && r.get("policy").and_then(Json::as_str) == Some(policy)
            })
            .and_then(|r| r.get("cost"))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing cost for {scenario}/{policy}"))
    };
    assert!(
        cost_of("hot-server", "local-search") < cost_of("hot-server", "equal-spread"),
        "artifact: hot-server local-search does not beat equal-spread"
    );
    println!(
        "\nOK: local-search strictly beats equal-spread on the hot-server bank and never \
         loses on uniform banks; S=1 reproduces the single-server solver bit for bit; \
         airtime pins are honored and a redundant queue override is the identity"
    );
}
