//! §Fleet-quant — dynamic mixed-precision quantization A/B (ROADMAP
//! item 3, QVLA / DyQ-VLA): the adaptive per-agent policy against every
//! pinned static bit-width on the drifting-load churn scenario, plus the
//! per-group bit allocator against the uniform static at matched average
//! rate. Artifact-free (analytic allocator + queue model only).
//!
//! Acceptance properties checked inline and re-checked against the
//! emitted `BENCH_fleet_quant.json` (see the crate root's "Bench
//! artifacts" section for the schema):
//! * **temporal adaptation** — on the drifting-load timeline (bursts
//!   swell queue rates, joins/leaves churn the population) the adaptive
//!   policy's time-averaged fleet D^U sits **strictly below every static
//!   pin b̂ ∈ {1..16}**: a coarse pin wastes rate headroom when the fleet
//!   is idle, a fine pin rejects agents outright when it is loaded, and
//!   only re-picking at warm re-solve boundaries tracks the sweet spot;
//! * **bit-identity** — the adaptive default window reproduces the
//!   legacy `Static(None)` solver pick bit for bit (same integrals, same
//!   re-solve counts), so the redesigned policy API costs nothing when
//!   unused;
//! * **mixed precision** — at every golden average-rate budget R̄ the
//!   greedy per-group allocation predicts strictly lower distortion than
//!   the uniform static at the same budget (the QVLA channel-spread
//!   gain; mixed <= uniform is structural, strictness is the measured
//!   margin);
//! * every number in the artifact is finite.
//!
//! `QACI_BENCH_FAST=1` (the CI smoke) rides a shorter horizon with a
//! thinned static ladder and skips the cross-arm ordering assertions —
//! short horizons under-sample the bursts — while still exercising every
//! code path end to end.

use qaci::bench_harness::{emit_bench_artifact, fast_mode, Table};
use qaci::fleet::churn::{self, ChurnConfig, ChurnPolicy, ChurnReport, Timeline};
use qaci::quant::mixed::{allocate_bits, AdaptConfig, QuantPolicy};
use qaci::system::Platform;
use qaci::theory::distortion::DistortionModel;
use qaci::theory::rate_distortion::RateBoundModel;
use qaci::util::json::Json;
use qaci::util::timer::Stopwatch;

/// The fitted channel-group spread the allocator golden tests pin
/// (§IV: three contiguous groups with visibly different Exp(λ) tails).
const GOLDEN_LAMBDAS: [f64; 3] = [4.0, 15.0, 60.0];
const GOLDEN_WEIGHTS: [f64; 3] = [1.0, 1.0, 1.0];

struct Arm {
    policy: String,
    d_upper: f64,
    cost: f64,
    reallocations: usize,
    realloc_skipped: usize,
    admitted: usize,
    wall_s: f64,
}

fn ride(base: Platform, tl: &Timeline, cfg: &ChurnConfig, quant: QuantPolicy) -> (Arm, ChurnReport) {
    let label = quant.label();
    let cfg = ChurnConfig { quant, ..cfg.clone() };
    let sw = Stopwatch::start();
    let rep = churn::run_churn(base, tl, ChurnPolicy::Online, &cfg);
    let wall_s = sw.elapsed_s();
    assert!(
        rep.time_avg_cost.is_finite() && rep.time_avg_d_upper.is_finite(),
        "{label}: non-finite integrals"
    );
    let arm = Arm {
        policy: label,
        d_upper: rep.time_avg_d_upper,
        cost: rep.time_avg_cost,
        reallocations: rep.reallocations,
        realloc_skipped: rep.realloc_skipped,
        admitted: rep.final_alloc.admitted,
        wall_s,
    };
    (arm, rep)
}

fn main() {
    let base = Platform::fleet_edge();
    // the drifting-load scenario IS the repo's baseline churn config:
    // Poisson joins/leaves churn the population while load bursts swell
    // per-agent queue rates 5x for 40 s at a time — the allocator's
    // feasible bit-width window genuinely moves over the horizon
    let mut cfg = ChurnConfig::default();
    if fast_mode() {
        cfg.horizon_s = 150.0;
    }
    let tl = churn::timeline(&cfg);
    assert!(tl.joins + tl.leaves + tl.bursts > 0, "scenario must drift");

    let statics: Vec<u32> = if fast_mode() { vec![1, 4, 8, 12, 16] } else { (1..=16).collect() };

    let (adaptive, adaptive_rep) =
        ride(base, &tl, &cfg, QuantPolicy::Adaptive(AdaptConfig::default()));
    assert!(adaptive.reallocations > 0, "drifting load must force re-solves");
    // bit-identity: the default adaptive window IS the legacy solver
    // pick — same integrals to the bit, same re-solve/skip counts
    let (legacy, legacy_rep) = ride(base, &tl, &cfg, QuantPolicy::Static(None));
    assert_eq!(
        adaptive.d_upper.to_bits(),
        legacy.d_upper.to_bits(),
        "adaptive default must reproduce the legacy D^U integral bit for bit"
    );
    assert_eq!(adaptive.cost.to_bits(), legacy.cost.to_bits());
    assert_eq!(
        (adaptive.reallocations, adaptive.realloc_skipped),
        (legacy.reallocations, legacy.realloc_skipped)
    );
    assert_eq!(adaptive_rep.final_alloc.admitted, legacy_rep.final_alloc.admitted);

    let mut arms = vec![adaptive, legacy];
    for &b in &statics {
        let (arm, _) = ride(base, &tl, &cfg, QuantPolicy::Static(Some(b)));
        arms.push(arm);
    }

    let mut t = Table::new(
        "fleet quant: per-agent policy x drifting-load (adaptive beats every pin)",
        &["policy", "avg D^U", "avg cost", "resolves", "skipped", "admitted", "wall [ms]"],
    );
    let mut records: Vec<Json> = Vec::new();
    for a in &arms {
        t.row(&[
            a.policy.clone(),
            format!("{:.6}", a.d_upper),
            format!("{:.6}", a.cost),
            format!("{}", a.reallocations),
            format!("{}", a.realloc_skipped),
            format!("{}", a.admitted),
            format!("{:.1}", a.wall_s * 1e3),
        ]);
        records.push(
            Json::obj()
                .set("scenario", "drifting-load")
                .set("policy", a.policy.as_str())
                .set("d_upper", a.d_upper)
                .set("cost", a.cost)
                .set("reallocations", a.reallocations)
                .set("realloc_skipped", a.realloc_skipped)
                .set("admitted", a.admitted)
                .set("wall_clock_s", a.wall_s),
        );
    }
    t.print();

    let adaptive_du = arms[0].d_upper;
    if !fast_mode() {
        for a in arms.iter().filter(|a| a.policy.starts_with("static:")) {
            assert!(
                adaptive_du < a.d_upper,
                "adaptive D^U {adaptive_du} not strictly below {} ({})",
                a.policy,
                a.d_upper
            );
        }
    }

    // §IV mixed precision: greedy per-group water-filling against the
    // uniform static at the same average-rate budget over the golden
    // channel-group spread
    let budgets: Vec<u32> = if fast_mode() { vec![2, 6, 10] } else { vec![2, 4, 6, 8, 10, 12] };
    let mut mt = Table::new(
        "per-group bit allocation vs uniform static at matched average rate",
        &["budget R̄", "mixed bits", "avg bits", "D^U mixed", "D^U uniform", "gain"],
    );
    for &rbar in &budgets {
        let mixed = allocate_bits(&GOLDEN_LAMBDAS, &GOLDEN_WEIGHTS, rbar as f64, 16, &RateBoundModel)
            .expect("golden allocation");
        let uniform = mixed.uniform_like(rbar);
        let (d_mixed, d_uniform) = (RateBoundModel.predict(&mixed), RateBoundModel.predict(&uniform));
        assert!(mixed.avg_bits() <= rbar as f64 + 1e-9, "budget violated at R̄={rbar}");
        assert!(
            d_mixed <= d_uniform,
            "mixed {d_mixed} above uniform {d_uniform} at R̄={rbar} (structurally impossible)"
        );
        // measured margin on the golden spread: ~41-44% below uniform
        assert!(
            d_mixed < d_uniform * 0.95,
            "mixed {d_mixed} not strictly below uniform {d_uniform} at R̄={rbar}"
        );
        let bits: Vec<String> = mixed.bits().iter().map(u32::to_string).collect();
        mt.row(&[
            format!("{rbar}"),
            bits.join("/"),
            format!("{:.2}", mixed.avg_bits()),
            format!("{:.6}", d_mixed),
            format!("{:.6}", d_uniform),
            format!("{:.1}%", (1.0 - d_mixed / d_uniform) * 100.0),
        ]);
        for (policy, du, alloc, bits_str) in [
            ("mixed", d_mixed, &mixed, bits.join("/")),
            ("uniform", d_uniform, &uniform, format!("{rbar}")),
        ] {
            records.push(
                Json::obj()
                    .set("scenario", format!("rate-{rbar}").as_str())
                    .set("policy", policy)
                    .set("d_upper", du)
                    .set("avg_bits", alloc.avg_bits())
                    .set("bits", bits_str.as_str()),
            );
        }
    }
    mt.print();

    // the machine-readable artifact CI uploads; the headline orderings
    // are re-checked against the parsed-back document so the uploaded
    // file is the verified one (and the bench-log baseline gates them
    // from then on)
    let (_, doc) = emit_bench_artifact("fleet_quant", records);
    if !fast_mode() {
        let results = doc.get("results").and_then(Json::as_arr).expect("results array");
        let du_of = |scenario: &str, policy: &str| -> f64 {
            results
                .iter()
                .find(|r| {
                    r.get("scenario").and_then(Json::as_str) == Some(scenario)
                        && r.get("policy").and_then(Json::as_str) == Some(policy)
                })
                .and_then(|r| r.get("d_upper"))
                .and_then(Json::as_f64)
                .unwrap_or_else(|| panic!("missing d_upper for {scenario}/{policy}"))
        };
        let adaptive = du_of("drifting-load", "adaptive:1-16");
        let best_static = (1..=16)
            .map(|b| du_of("drifting-load", &format!("static:{b}")))
            .fold(f64::INFINITY, f64::min);
        assert!(
            adaptive < best_static,
            "artifact: adaptive D^U {adaptive} not below best static {best_static}"
        );
        for &rbar in &budgets {
            let s = format!("rate-{rbar}");
            assert!(du_of(&s, "mixed") < du_of(&s, "uniform"), "artifact: mixed lost at {s}");
        }
        println!(
            "\nOK: adaptive D^U {:.6} beats every static pin (best {:.6}); mixed beats uniform \
             at every budget",
            adaptive, best_static
        );
    } else {
        println!("\nOK (fast mode): all arms ran end to end with finite integrals");
    }
}
