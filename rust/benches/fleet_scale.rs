//! §Fleet — fleet-scale sweep: distortion / latency / energy and
//! allocator throughput vs. fleet size N ∈ {1, 2, 4, …, 64}, for the
//! proposed joint multi-agent design against the equal-share and
//! feasible-random baselines. Artifact-free (analytic serving loop).
//!
//! Acceptance property checked inline: the proposed allocator never loses
//! to the equal split, and strictly beats it on fleet-weighted distortion
//! for every contended size N ≥ 4.

use qaci::bench_harness::{scaled, Table};
use qaci::coordinator::batcher::BatcherConfig;
use qaci::data::workload::Arrival;
use qaci::fleet::{sim, FleetSimConfig};
use qaci::opt::fleet::{self, AgentSpec, FleetAlgorithm, FleetProblem};
use qaci::system::Platform;
use qaci::util::timer::Stopwatch;

fn main() {
    let mut t = Table::new(
        "fleet scale: N agents on one edge server + one medium (mixed QoS fleet)",
        &[
            "N",
            "algorithm",
            "admitted",
            "wgt gap",
            "wgt D^U",
            "e2e p50 [s]",
            "e2e p95 [s]",
            "E/req [J]",
            "alloc [ms]",
            "plans/s",
        ],
    );
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        let fp = FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n));
        let mut objective = [0.0f64; 3];
        let mut d_upper = [0.0f64; 3];
        for (k, algorithm) in FleetAlgorithm::ALL.into_iter().enumerate() {
            let sw = Stopwatch::start();
            let alloc = fleet::solve(&fp, algorithm, 42);
            let alloc_s = sw.elapsed_s().max(1e-9);
            objective[k] = alloc.objective;
            d_upper[k] = alloc.weighted_d_upper(&fp);
            let report = sim::run(
                &fp,
                &alloc,
                &FleetSimConfig {
                    requests_per_agent: scaled(16),
                    arrival: Arrival::Poisson { lambda_rps: 2.0 },
                    seed: 1,
                    batcher: BatcherConfig::default(),
                    queue: None,
                },
            );
            let (p50, p95, epr) = if report.served > 0 {
                (
                    format!("{:.3}", report.e2e_s.p50()),
                    format!("{:.3}", report.e2e_s.p95()),
                    format!("{:.3}", report.total_energy_j / report.served as f64),
                )
            } else {
                ("--".into(), "--".into(), "--".into())
            };
            t.row(&[
                format!("{n}"),
                algorithm.name().to_string(),
                format!("{}/{}", alloc.admitted, n),
                format!("{:.3e}", alloc.objective),
                format!("{:.3e}", d_upper[k]),
                p50,
                p95,
                epr,
                format!("{:.2}", alloc_s * 1e3),
                format!("{:.0}", n as f64 / alloc_s),
            ]);
        }
        let (proposed, equal) = (objective[0], objective[1]);
        assert!(
            proposed <= equal + 1e-15,
            "N={n}: proposed {proposed} worse than equal-share {equal}"
        );
        if n >= 4 {
            assert!(
                proposed < equal * 0.999,
                "N={n}: proposed {proposed} does not strictly beat equal-share {equal}"
            );
            assert!(
                d_upper[0] < d_upper[1],
                "N={n}: weighted D^U {} not below equal-share {}",
                d_upper[0],
                d_upper[1]
            );
        }
    }
    t.print();
    println!("\nOK: proposed <= equal-share everywhere, strictly better for N >= 4");
}
