//! §Fleet — fleet-scale sweep: distortion / latency / energy and
//! allocator throughput vs. fleet size N ∈ {1, 2, 4, …, 64}, for the
//! proposed joint multi-agent design against the equal-share and
//! feasible-random baselines. Artifact-free (analytic serving loop).
//!
//! Acceptance properties checked inline:
//! * the proposed allocator never loses to the equal split, and strictly
//!   beats it on fleet-weighted distortion for every contended size N ≥ 4;
//! * on heterogeneous silicon (the orin/xavier/phone ladder) the margin
//!   over equal-share is non-decreasing in tier spread at every
//!   fully-admitted size and strictly widens once all three tiers are
//!   present, while the uniform-orin ladder reproduces the homogeneous
//!   fleet bit for bit;
//! * the fixed-point interference pass converges (no mean-field
//!   fallback) on every queued scenario in the table below, and its
//!   waits never leave the mean-field bracket;
//! * the class-collapsed solver reproduces the per-agent allocation bit
//!   for bit at every shared ladder rung and is >= 10x faster at
//!   N = 10^4 on the 3-tier mix (the `solve-scale-*` records).

use qaci::bench_harness::{emit_bench_artifact, fast_mode, num_or_null, scaled, Table};
use qaci::coordinator::batcher::BatcherConfig;
use qaci::data::workload::Arrival;
use qaci::fleet::{sim, FleetSimConfig, LaneSeedMix};
use qaci::opt::fleet::{
    AgentSpec, Classing, FleetAlgorithm, FleetProblem, FleetSpec, SolveRequest,
};
use qaci::system::queue::{QueueDiscipline, QueueModel};
use qaci::system::Platform;
use qaci::util::json::Json;
use qaci::util::timer::Stopwatch;

/// One-shot request for a named algorithm (default placement applies).
fn req(algorithm: FleetAlgorithm, seed: u64) -> SolveRequest {
    SolveRequest { algorithm, seed, ..SolveRequest::default() }
}

fn main() {
    let mut t = Table::new(
        "fleet scale: N agents on one edge server + one medium (mixed QoS fleet)",
        &[
            "N",
            "algorithm",
            "admitted",
            "wgt gap",
            "wgt D^U",
            "e2e p50 [s]",
            "e2e p95 [s]",
            "E/req [J]",
            "alloc [ms]",
            "plans/s",
        ],
    );
    let mut records: Vec<Json> = Vec::new();
    for n in [1usize, 2, 4, 8, 16, 32, 64] {
        let fp = FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n));
        let mut objective = [0.0f64; 3];
        let mut d_upper = [0.0f64; 3];
        for (k, algorithm) in FleetAlgorithm::ALL.into_iter().enumerate() {
            let sw = Stopwatch::start();
            let alloc = fp.solve(&req(algorithm, 42));
            let alloc_s = sw.elapsed_s().max(1e-9);
            objective[k] = alloc.objective;
            d_upper[k] = alloc.weighted_d_upper(&fp);
            let report = sim::run(
                &fp,
                &alloc,
                &FleetSimConfig {
                    requests_per_agent: scaled(16),
                    arrival: Arrival::Poisson { lambda_rps: 2.0 },
                    seed: 1,
                    batcher: BatcherConfig::default(),
                    queue: None,
                    lane_mix: LaneSeedMix::default(),
                },
            );
            let (p50, p95, epr) = if report.served > 0 {
                (
                    format!("{:.3}", report.e2e_s.p50()),
                    format!("{:.3}", report.e2e_s.p95()),
                    format!("{:.3}", report.total_energy_j / report.served as f64),
                )
            } else {
                ("--".into(), "--".into(), "--".into())
            };
            t.row(&[
                format!("{n}"),
                algorithm.name().to_string(),
                format!("{}/{}", alloc.admitted, n),
                format!("{:.3e}", alloc.objective),
                format!("{:.3e}", d_upper[k]),
                p50,
                p95,
                epr,
                format!("{:.2}", alloc_s * 1e3),
                format!("{:.0}", n as f64 / alloc_s),
            ]);
            assert!(alloc.objective.is_finite(), "N={n} {algorithm:?}: non-finite objective");
            let p99 = if report.served > 0 { report.e2e_s.p99() } else { f64::NAN };
            records.push(
                Json::obj()
                    .set("scenario", format!("scale-{n}"))
                    .set("policy", algorithm.name())
                    .set("cost", alloc.objective)
                    .set("d_upper", d_upper[k])
                    .set("admitted", alloc.admitted)
                    .set("p99_s", num_or_null(p99))
                    .set("wall_clock_s", alloc_s),
            );
        }
        let (proposed, equal) = (objective[0], objective[1]);
        assert!(
            proposed <= equal + 1e-15,
            "N={n}: proposed {proposed} worse than equal-share {equal}"
        );
        if n >= 4 {
            assert!(
                proposed < equal * 0.999,
                "N={n}: proposed {proposed} does not strictly beat equal-share {equal}"
            );
            assert!(
                d_upper[0] < d_upper[1],
                "N={n}: weighted D^U {} not below equal-share {}",
                d_upper[0],
                d_upper[1]
            );
        }
    }
    t.print();
    println!("\nOK: proposed <= equal-share everywhere, strictly better for N >= 4");

    hetero_margin_ladder();
    fixed_point_scenarios();
    solve_scale_ladder(&mut records);

    // machine-readable artifact (schema in the crate root under "Bench
    // artifacts"); the ordering invariant is re-checked against the
    // parsed-back document so CI uploads exactly what was verified
    let (_, doc) = emit_bench_artifact("fleet_scale", records);
    let results = doc.get("results").and_then(Json::as_arr).expect("results array");
    let cost_of = |scenario: &str, policy: &str| -> f64 {
        results
            .iter()
            .find(|r| {
                r.get("scenario").and_then(Json::as_str) == Some(scenario)
                    && r.get("policy").and_then(Json::as_str) == Some(policy)
            })
            .and_then(|r| r.get("cost"))
            .and_then(Json::as_f64)
            .unwrap_or_else(|| panic!("missing cost for {scenario}/{policy}"))
    };
    for n in [4usize, 8, 16, 32, 64] {
        let scenario = format!("scale-{n}");
        let (proposed, equal) = (cost_of(&scenario, "proposed"), cost_of(&scenario, "equal-share"));
        assert!(
            proposed < equal,
            "artifact: {scenario} proposed {proposed} !< equal-share {equal}"
        );
    }
}

/// Solve time vs fleet size for the per-agent and class-collapsed
/// solvers on the no-queue 3-tier mix (a handful of equivalence
/// classes regardless of N). The classed solver must reproduce the
/// per-agent allocation **bit for bit** at every rung both run, and be
/// at least 10x faster at the largest shared rung; solve time must
/// grow with N within each solver (the emitted `solve-scale-*` records
/// carry the curves, `cost_bits_equal` and `speedup`, re-checked by
/// the CI artifact validator).
fn solve_scale_ladder(records: &mut Vec<Json>) {
    let mut t = Table::new(
        "solve scale: class-collapsed vs per-agent allocator (3-tier mix, no queue)",
        &["N", "solver", "classes", "solve [ms]", "cost", "admitted", "speedup"],
    );
    let full = !fast_mode();
    // the rungs both solvers run (bit-identity + speedup measured here)
    let shared: &[usize] = if full { &[100, 1_000, 10_000] } else { &[100, 1_000] };
    // the classed solver alone continues up the ladder
    let top: usize = if full { 100_000 } else { 10_000 };
    let mut per_agent_curve: Vec<f64> = Vec::new();
    let mut classed_curve: Vec<f64> = Vec::new();
    let mut top_speedup = 0.0f64;
    for &n in shared.iter().chain(std::iter::once(&top)) {
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(2)),
        );
        let classes = fp.class_index(Classing::Exact).classes();
        let solve = |classing: Classing| {
            let sw = Stopwatch::start();
            let alloc = fp.solve(&SolveRequest { classing, ..SolveRequest::default() });
            (sw.elapsed_s().max(1e-9), alloc)
        };
        let (classed_s, classed) = solve(Classing::Exact);
        classed_curve.push(classed_s);
        assert!(classed.objective.is_finite(), "solve-scale-{n}: non-finite classed cost");
        let mut classed_rec = Json::obj()
            .set("scenario", format!("solve-scale-{n}"))
            .set("policy", "classed")
            .set("cost", classed.objective)
            .set("admitted", classed.admitted)
            .set("classes", classes)
            .set("wall_clock_s", classed_s);
        let mut speedup_cell = "--".to_string();
        if shared.contains(&n) {
            let (pa_s, pa) = solve(Classing::PerAgent);
            per_agent_curve.push(pa_s);
            assert_eq!(
                pa.objective.to_bits(),
                classed.objective.to_bits(),
                "solve-scale-{n}: classed cost {} != per-agent {}",
                classed.objective,
                pa.objective
            );
            assert_eq!(pa.admitted, classed.admitted, "solve-scale-{n}: admitted set diverged");
            for (i, (a, b)) in pa.agents.iter().zip(&classed.agents).enumerate() {
                assert_eq!(
                    a.server_share.to_bits(),
                    b.server_share.to_bits(),
                    "solve-scale-{n} agent {i}: mu diverged"
                );
                assert_eq!(
                    a.airtime_share.to_bits(),
                    b.airtime_share.to_bits(),
                    "solve-scale-{n} agent {i}: alpha diverged"
                );
                assert_eq!(
                    a.cost.to_bits(),
                    b.cost.to_bits(),
                    "solve-scale-{n} agent {i}: cost diverged"
                );
            }
            let speedup = pa_s / classed_s;
            if n == *shared.last().unwrap() {
                top_speedup = speedup;
            }
            speedup_cell = format!("{speedup:.1}x");
            classed_rec = classed_rec.set("cost_bits_equal", true).set("speedup", speedup);
            t.row(&[
                format!("{n}"),
                "per-agent".into(),
                format!("{n}"),
                format!("{:.2}", pa_s * 1e3),
                format!("{:.6e}", pa.objective),
                format!("{}/{n}", pa.admitted),
                "1.0x".into(),
            ]);
            records.push(
                Json::obj()
                    .set("scenario", format!("solve-scale-{n}"))
                    .set("policy", "per-agent")
                    .set("cost", pa.objective)
                    .set("admitted", pa.admitted)
                    .set("classes", n)
                    .set("wall_clock_s", pa_s),
            );
        }
        t.row(&[
            format!("{n}"),
            "classed".into(),
            format!("{classes}"),
            format!("{:.2}", classed_s * 1e3),
            format!("{:.6e}", classed.objective),
            format!("{}/{n}", classed.admitted),
            speedup_cell,
        ]);
        records.push(classed_rec);
    }
    t.print();
    // solve time grows up the ladder for each solver (decade rungs, so
    // timer noise cannot plausibly invert an ordering of 10x the work)
    assert!(
        per_agent_curve.windows(2).all(|w| w[0] < w[1]),
        "per-agent solve curve not increasing: {per_agent_curve:?}"
    );
    assert!(
        classed_curve.windows(2).all(|w| w[0] < w[1]),
        "classed solve curve not increasing: {classed_curve:?}"
    );
    if full {
        assert!(
            top_speedup >= 10.0,
            "classed solver only {top_speedup:.1}x faster than per-agent at N=10^4"
        );
        println!(
            "\nOK: classed == per-agent bit for bit on every shared rung, {top_speedup:.0}x \
             faster at N=10^4"
        );
    } else {
        println!("\nOK: classed == per-agent bit for bit on every shared rung (fast mode)");
    }
}

/// Margin over equal-share vs. silicon spread, at fully-admitted fleet
/// sizes (the regime where heterogeneity — not admission control — is
/// the whole story). Margin is the absolute fleet-weighted objective
/// difference equal − proposed.
fn hetero_margin_ladder() {
    let mut t = Table::new(
        "hetero ladder: margin over equal-share vs tier spread (higher = wider win)",
        &["N", "spread", "tiers", "proposed", "equal", "margin", "admitted"],
    );
    for n in [4usize, 6, 7] {
        let mut margins = Vec::new();
        for spread in 0..=2 {
            let tiers = AgentSpec::tier_mix(spread);
            let fp = FleetProblem::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(n, &tiers),
            );
            let proposed = fp.solve(&SolveRequest::default());
            let equal = fp.solve(&req(FleetAlgorithm::EqualShare, 0));
            let margin = equal.objective - proposed.objective;
            t.row(&[
                format!("{n}"),
                format!("{spread}"),
                tiers.iter().map(|p| p.tier).collect::<Vec<_>>().join("+"),
                format!("{:.3e}", proposed.objective),
                format!("{:.3e}", equal.objective),
                format!("{:.3e}", margin),
                format!("{}/{n}", proposed.admitted),
            ]);
            if spread == 0 {
                // the uniform ladder is the homogeneous fleet, exactly
                let homogeneous = FleetProblem::new(
                    Platform::fleet_edge(),
                    AgentSpec::mixed_fleet(n),
                )
                .solve(&SolveRequest::default());
                assert_eq!(
                    proposed.objective, homogeneous.objective,
                    "N={n}: uniform tier ladder must reproduce the homogeneous fleet"
                );
            }
            assert!(
                proposed.objective <= equal.objective + 1e-12,
                "N={n} spread={spread}: proposed above equal-share"
            );
            margins.push(margin);
        }
        assert!(
            margins.windows(2).all(|w| w[0] <= w[1] + 1e-12),
            "N={n}: margin not non-decreasing in tier spread: {margins:?}"
        );
        if n == 7 {
            assert!(
                margins[2] > margins[1] * 1.5,
                "N=7: 3-tier margin {} does not strictly widen past 2-tier {}",
                margins[2],
                margins[1]
            );
        }
    }
    t.print();
    println!("\nOK: margin over equal-share non-decreasing in tier spread, widening at N=7");
}

/// Designated queued scenarios for the fixed-point interference pass:
/// every one must converge (no mean-field fallback), with waits inside
/// the mean-field bracket spanned by the fastest and slowest active
/// service — the pass sharpens the mean-field envelope, never exits it.
fn fixed_point_scenarios() {
    let mut t = Table::new(
        "fixed-point interference: designated scenarios (all must converge)",
        &["N", "spread", "rps", "alloc", "active", "max wait [s]"],
    );
    for &(n, rps) in &[(2usize, 0.02), (2, 0.05), (4, 0.02), (4, 0.05), (6, 0.02)] {
        for spread in [0usize, 2] {
            let mut spec = FleetSpec::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(spread)),
            );
            spec.queue = Some(QueueModel::uniform(QueueDiscipline::Fifo, n, rps));
            let fp = FleetProblem::from_spec(spec);
            for name in ["equal", "proposed"] {
                let alloc = if name == "equal" {
                    fp.solve(&req(FleetAlgorithm::EqualShare, 0))
                } else {
                    fp.solve(&SolveRequest::default())
                };
                let result =
                    fp.interference_waits(&alloc.server_shares(), &alloc.airtime_shares());
                assert!(
                    result.converged,
                    "N={n} rps={rps} spread={spread} {name}: fixed point fell back"
                );
                let services: Vec<f64> =
                    alloc.server_shares().iter().map(|&m| fp.own_service(m)).collect();
                let act: Vec<f64> =
                    result.active.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
                let active_s: Vec<f64> = services
                    .iter()
                    .zip(&result.active)
                    .filter(|(s, &a)| a && s.is_finite())
                    .map(|(s, _)| *s)
                    .collect();
                let queue = fp.queue.as_ref().unwrap();
                if let (Some(&s_min), Some(&s_max)) = (
                    active_s.iter().min_by(|a, b| a.total_cmp(b)),
                    active_s.iter().max_by(|a, b| a.total_cmp(b)),
                ) {
                    for i in 0..n {
                        if !result.active[i] || !services[i].is_finite() {
                            continue;
                        }
                        let mut lo_vec = vec![s_min; n];
                        lo_vec[i] = services[i];
                        let mut hi_vec = vec![s_max; n];
                        hi_vec[i] = services[i];
                        let lo = queue.waits_given(&lo_vec, &act, |j| fp.agents[j].weight)[i];
                        let hi = queue.waits_given(&hi_vec, &act, |j| fp.agents[j].weight)[i];
                        assert!(
                            result.waits[i] >= lo - 1e-12,
                            "N={n} rps={rps} {name}: wait {} under bracket {lo}",
                            result.waits[i]
                        );
                        assert!(
                            result.waits[i] <= hi + 1e-12 || hi.is_infinite(),
                            "N={n} rps={rps} {name}: wait {} over bracket {hi}",
                            result.waits[i]
                        );
                    }
                }
                let max_wait = result
                    .waits
                    .iter()
                    .cloned()
                    .filter(|w| w.is_finite())
                    .fold(0.0f64, f64::max);
                t.row(&[
                    format!("{n}"),
                    format!("{spread}"),
                    format!("{rps}"),
                    name.to_string(),
                    format!("{}", result.active.iter().filter(|&&a| a).count()),
                    format!("{max_wait:.3}"),
                ]);
            }
        }
    }
    t.print();
    println!("\nOK: fixed-point pass converged within the mean-field bracket on all scenarios");
}
