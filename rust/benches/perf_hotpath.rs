//! §Perf — whole-stack hot-path profile (EXPERIMENTS.md §Perf feeds off
//! this bench's output).
//!
//! L3 hot paths: weight quantization (+cache), PJRT literal construction,
//! agent/edge stage execution at batch 1 and 4, scheduler planning (SCA
//! vs exact), CIDEr scoring, router+batcher throughput without PJRT.
//! L1/L2 are profiled structurally (VMEM footprint / MXU utilization
//! estimates + lowered-HLO op counts) since interpret-mode wallclock is
//! not a TPU proxy.

use qaci::bench_harness::{scaled, time, Table};
use qaci::coordinator::batcher::{Batcher, BatcherConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::data::eval::EvalSet;
use qaci::data::workload::{generate, Arrival};
use qaci::metrics::cider::CiderScorer;
use qaci::opt::{bisection, sca, Problem};
use qaci::quant::{self, Scheme};
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::Platform;

fn main() -> anyhow::Result<()> {
    let reg = Registry::open(&qaci::artifacts_dir())?;
    let mut model = CoModel::load(&reg, "blip2ish")?;
    let eval = EvalSet::load(&reg.dir, &reg.manifest, "coco")?;
    let iters = scaled(40);

    // ---- L3: quantization hot path -------------------------------------
    let blob = model.agent_weights.blob.clone();
    let mut out = vec![0.0f32; blob.len()];
    time("quantize_uniform 610k params (alloc-free)", 3, iters, || {
        let step = quant::uniform_step(1.0, 6);
        quant::quantize_uniform_into(&blob, step, &mut out);
    });
    time("quantize_pot 610k params (alloc-free)", 3, iters, || {
        quant::quantize_pot_into(&blob, -8.0, 0.0, &mut out);
    });
    // cold vs warm quantized-literal cache
    time("weights.quantized COLD (quantize + literals)", 0, scaled(8).max(3), || {
        let mut store = qaci::runtime::weights::WeightStore::from_parts(
            model
                .agent_weights
                .specs
                .iter()
                .map(|s| (s.name.clone(), s.shape.clone()))
                .collect(),
            blob.clone(),
        );
        store.quantized(6, Scheme::Uniform).unwrap();
    });
    time("weights.quantized WARM (cache hit)", 3, iters, || {
        model.agent_weights.quantized(6, Scheme::Uniform).unwrap();
    });

    // ---- L3: stage execution -------------------------------------------
    let one = eval.sample(0).to_vec();
    let mut four = Vec::new();
    for i in 0..4 {
        four.extend_from_slice(eval.sample(i));
    }
    time("agent encode batch=1", 2, scaled(24), || {
        model.encode(&one, 1, 6, Scheme::Uniform).unwrap();
    });
    time("agent encode batch=4 (per batch)", 2, scaled(24), || {
        model.encode(&four, 4, 6, Scheme::Uniform).unwrap();
    });
    let emb1 = model.encode(&one, 1, 6, Scheme::Uniform)?;
    let mut emb4 = Vec::new();
    for _ in 0..4 {
        emb4.extend_from_slice(&emb1);
    }
    time("edge decode batch=1", 2, scaled(24), || {
        model.decode(&emb1, 1).unwrap();
    });
    time("edge decode batch=4 (per batch)", 2, scaled(24), || {
        model.decode(&emb4, 4).unwrap();
    });
    time("full co-inference batch=1", 1, scaled(16), || {
        model.infer(&one, 1, 6, Scheme::Uniform).unwrap();
    });

    // ---- L3: planning ----------------------------------------------------
    let prob = Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0);
    time("scheduler plan: SCA (Algorithm 1)", 2, scaled(20), || {
        sca::solve(&prob, sca::ScaOptions::default()).unwrap();
    });
    time("scheduler plan: exact bisection", 2, iters, || {
        bisection::solve(&prob).unwrap();
    });
    time("scheduler plan: cached", 2, iters, || {
        let mut s =
            Scheduler::new(Platform::paper_blip2(), 15.0, Algorithm::Exact, Scheme::Uniform, 1);
        s.plan(3.5, 2.0).unwrap();
        s.plan(3.5, 2.0).unwrap(); // warm
    });

    // ---- L3: metrics + routing (no PJRT) ---------------------------------
    let scorer = CiderScorer::new(&eval.refs);
    let candidates: Vec<String> = (0..eval.len()).map(|i| eval.refs[i][0].clone()).collect();
    time("CIDEr corpus scoring (64 candidates)", 2, iters, || {
        scorer.score(&candidates);
    });
    time("router+batcher 1k requests (no exec)", 2, scaled(20), || {
        let scheduler =
            Scheduler::new(Platform::paper_blip2(), 15.0, Algorithm::Exact, Scheme::Uniform, 1);
        let mut router = Router::new(QosPolicy::paper_default(), scheduler);
        let mut batcher = Batcher::new(BatcherConfig::default());
        let mut count = 0;
        for r in generate(1000, 64, Arrival::Poisson { lambda_rps: 1e4 }, 3) {
            if let Ok(rr) = router.route(r) {
                if let Some(b) = batcher.push(rr) {
                    count += b.requests.len();
                }
            }
        }
        count += batcher.drain().iter().map(|b| b.requests.len()).sum::<usize>();
        assert_eq!(count, 1000);
    });

    // ---- L1: structural kernel profile (TPU estimates) -------------------
    let mut t = Table::new(
        "L1 Pallas kernel structure (TPU estimates; interpret mode is not a perf proxy)",
        &["kernel", "block", "VMEM/block", "MXU-aligned", "est. utilization"],
    );
    t.row(&[
        "matmul".into(),
        "128x128x512".into(),
        format!("{} KiB", (128 * 512 + 512 * 128 + 128 * 128) * 4 / 1024),
        "yes (128 lanes)".into(),
        "~0.85 (K-major accum)".into(),
    ]);
    t.row(&[
        "fake_quant".into(),
        "8x128".into(),
        format!("{} KiB", 8 * 128 * 4 * 2 / 1024),
        "yes (8 sublanes)".into(),
        "VPU elementwise".into(),
    ]);
    t.row(&[
        "attention".into(),
        "per-head lq*dh".into(),
        format!("{} KiB", (64 * 32 * 3 + 64 * 64) * 4 / 1024),
        "dh=32 sublane packed".into(),
        "fused softmax".into(),
    ]);
    t.row(&[
        "layernorm".into(),
        "8x128".into(),
        "8 KiB".into(),
        "yes".into(),
        "single HBM pass".into(),
    ]);
    t.print();

    // ---- L2: lowered module size audit -----------------------------------
    let mut t = Table::new(
        "L2 lowered HLO audit (fusion health: chars ~ op count)",
        &["module", "HLO chars", "while-loops", "fusions"],
    );
    for f in [
        "blip2ish_agent_b1.hlo.txt",
        "blip2ish_server_b1.hlo.txt",
        "gitish_agent_b1.hlo.txt",
        "fcdnn16_b8.hlo.txt",
    ] {
        let text = std::fs::read_to_string(reg.dir.join(f))?;
        t.row(&[
            f.into(),
            format!("{}", text.len()),
            format!("{}", text.matches("while(").count()),
            format!("{}", text.matches("fusion").count()),
        ]);
    }
    t.print();
    Ok(())
}
