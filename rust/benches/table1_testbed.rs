//! Table I — co-inference performance (CIDEr) on the testbed with coarse
//! frequency profiles (low / medium / high), under delay-only and
//! energy-only constraints, for BLIP-2-like and GIT-like models.
//!
//! The paper's testbed is a Jetson AGX Orin + Xeon/RTX-3090 server where
//! only a few device frequency profiles are settable. We reproduce it
//! with the [`Platform::testbed`] silicon profile, the paper-scale
//! workloads, and profile-pinned governors; budgets are knife-edge bands
//! around the feasibility threshold, as in the paper's Table I.
//!
//! Paper shape to reproduce: in the delay-limited regime the HIGH profile
//! wins (more frequency => more bits fit the deadline); in the
//! energy-limited regime the LOW profile wins (f² energy forces
//! aggressive quantization at high frequency).

use qaci::bench_harness::{scaled, Table};
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::quant::Scheme;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::channel::Channel;
use qaci::system::dvfs::Governor;
use qaci::system::Platform;

fn main() -> anyhow::Result<()> {
    let reg = Registry::open(&qaci::artifacts_dir())?;
    let vocab = Vocab::from_manifest(&reg.manifest)?;
    let n_requests = scaled(16);

    for (model_name, eval_name, workloads) in [
        ("blip2ish", "coco", (0.30 * 533.66e9, 0.70 * 533.66e9)),
        ("gitish", "vatex", (0.30 * 212.27e9, 0.70 * 212.27e9)),
    ] {
        let mut model = CoModel::load(&reg, model_name)?;
        let eval = EvalSet::load(&reg.dir, &reg.manifest, eval_name)?;
        let lambda = model.agent_weights.lambda;
        let base = Platform::testbed(workloads.0, workloads.1);
        let dev_gov = Governor::jetson_profiles();

        // knife-edge budget bands around the high-profile thresholds
        let t_hi = {
            let mut p = base;
            p.device.f_max = dev_gov.profile("high").unwrap();
            p.min_delay(p.b_max as f64)
        };
        let delay_budgets = [0.90 * t_hi, 1.00 * t_hi, 1.10 * t_hi];
        let e_anchor = {
            let p = base;
            // energy of a balanced mid-bit plan at the low profile
            qaci::system::energy::total_energy(
                &p,
                8.0,
                dev_gov.profile("low").unwrap(),
                p.server.f_max * 0.5,
            )
        };
        let energy_budgets = [0.90 * e_anchor, 1.00 * e_anchor, 1.10 * e_anchor];

        let mut table = Table::new(
            &format!("Table I — {model_name} testbed CIDEr(x100), coarse profiles"),
            &[
                "profile",
                &format!("T0={:.2}s", delay_budgets[0]),
                &format!("T0={:.2}s", delay_budgets[1]),
                &format!("T0={:.2}s", delay_budgets[2]),
                &format!("E0={:.1}J", energy_budgets[0]),
                &format!("E0={:.1}J", energy_budgets[1]),
                &format!("E0={:.1}J", energy_budgets[2]),
            ],
        );

        for profile in ["low", "medium", "high"] {
            let f_dev = dev_gov.profile(profile).unwrap();
            let mut row = vec![profile.to_string()];
            let mut platform = base;
            platform.device.f_max = f_dev;

            let budgets: Vec<(f64, f64)> = delay_budgets
                .iter()
                .map(|&t0| (t0, 1e9)) // delay-limited, energy-sufficient
                .chain(energy_budgets.iter().map(|&e0| (1e9, e0))) // energy-limited
                .collect();
            for (t0, e0) in budgets {
                let scheduler =
                    Scheduler::new(platform, lambda, Algorithm::Exact, Scheme::Uniform, 3)
                        .with_governors(
                            Governor::Profiles { points: vec![f_dev] },
                            Governor::server_profiles(),
                        );
                let mut sched = scheduler;
                match sched.plan(t0, e0) {
                    None => row.push("--".into()),
                    Some(plan) => {
                        let router = Router::new(QosPolicy::uniform(t0, e0), sched);
                        let mut engine = Engine::new(
                            &mut model,
                            router,
                            &vocab,
                            &eval,
                            Channel::ideal(),
                            EngineConfig::default(),
                        );
                        let t = engine
                            .run(generate(n_requests, eval.len(), Arrival::Batch, 13))?;
                        row.push(format!(
                            "{:.1} (b̂={})",
                            t.cider_x100(&eval.refs),
                            plan.design.b_hat
                        ));
                    }
                }
            }
            table.row(&row);
        }
        table.print();
    }
    println!(
        "\npaper check (Table I): delay-limited columns grow downward (high\n\
         profile best); energy-limited columns grow upward (low profile\n\
         best); tighter budgets always reduce CIDEr."
    );
    Ok(())
}
