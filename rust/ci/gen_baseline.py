#!/usr/bin/env python3
"""Regenerate ci/benchlog-baseline.jsonl, the ordering baseline the
bench-artifacts CI job diffs against (`qaci bench-log diff --baseline ...
--orderings-only --fail-on-regression`).

The baseline encodes only *machine-invariant* facts as strict orderings
(the same ones the benches assert in-process before emitting their
artifacts); everything machine-dependent is stored as a tie, and ties
derive no constraint in `obs::benchlog::diff`:

* fleet_churn — on every churning scenario the online policy's
  time-averaged cost sits strictly below both statics (encoded 1 vs 2);
  on burst-storm the same holds for p99 end-to-end delay and the
  deadline-violation rate. The no-churn rows are ties (online
  reproduces static-proposed exactly), present for coverage only.
* fleet_scale — proposed cost and weighted D^U strictly below
  equal-share for every contended size N >= 4; N in {1, 2} are ties.
  feasible-random rows carry no tracked fields (no ordering against a
  randomized policy is machine-invariant) but must keep being emitted.
  The solve-scale-* ladder (class-collapsed vs per-agent allocator) is
  all cost ties — the classed solver is *exact*, so its cost equals the
  per-agent cost bit for bit; the >= 10x speedup and monotone
  solve-time growth are wall-clock facts, gated by the in-bench asserts
  and the CI artifact validator (wall_clock_s is untracked here).
* fleet_placement — on the designated hot-server bank the local-search
  placement's cost sits strictly below equal-spread (the same ordering
  the bench asserts in-process); the uniform, single-server,
  airtime-split and queue-mix banks are ties (local-search may land
  exactly on the round-robin split), and nearest-server rows are
  coverage-only on the hot-server bank (local <= nearest holds by
  construction but need not be strict).
* fleet_daemon — on the burst-storm scenario both daemon arms
  (hysteresis and resolve-always) keep p99 end-to-end delay strictly
  below both static policies (encoded 1 vs 2; the bench additionally
  asserts the <= 50% solve-count and 1.5x tail bounds in-process and
  the CI validator re-checks them — solve counts are not a tracked
  diff field). Hysteresis vs resolve-always is a tie: neither
  direction is machine-invariant.
* fleet_quant — on the drifting-load scenario the adaptive per-agent
  policy's time-averaged fleet D^U sits strictly below every static
  pin b in 1..=16 (encoded 1 vs 2); adaptive vs the legacy "static"
  row is a tie (they are bit-identical by construction, checked
  exactly in-bench and by the CI validator). On every rate-R budget
  the per-group mixed allocation's predicted D^U sits strictly below
  the uniform static at the same average rate.

Entry lines replicate `obs::benchlog::Entry::to_line` byte for byte:
compact JSON (no spaces, insertion order, whole numbers rendered
without a fraction — hence integer values only below) wrapped with the
qaci.benchlog v1 schema stamp and an FNV-1a digest over the payload's
canonical bytes. `tests/integration_benchlog.rs` re-reads the committed
file through the Rust side, so a drift between this serializer and
`util::json` fails the suite, not the nightly bench job.

Usage: python3 ci/gen_baseline.py  (run from rust/, rewrites the .jsonl)
"""

import json
import os

SCHEMA = "qaci.benchlog"
VERSION = 1

CHURN_SCENARIOS = [
    "baseline",
    "no-churn",
    "heavy-churn",
    "priority-queue",
    "hetero-tiers",
    "burst-storm",
]
CHURN_POLICIES = ["online-proposed", "static-equal", "static-proposed"]
SCALE_NS = [1, 2, 4, 8, 16, 32, 64]
SCALE_POLICIES = ["proposed", "equal-share", "feasible-random"]
SOLVE_SCALE_SHARED_NS = [100, 1000, 10000]  # both solvers run these
SOLVE_SCALE_CLASSED_NS = [100, 1000, 10000, 100000]
PLACEMENT_SCENARIOS = [
    "hot-server",
    "uniform-2",
    "uniform-3",
    "single",
    "airtime-split",
    "queue-mix",
]
PLACEMENT_POLICIES = ["local-search", "equal-spread", "nearest-server"]
DAEMON_POLICIES = [
    "daemon-hysteresis",
    "daemon-resolve-always",
    "static-equal",
    "static-proposed",
]
QUANT_STATIC_BITS = range(1, 17)
QUANT_RATE_BUDGETS = [2, 4, 6, 8, 10, 12]


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


def compact(doc) -> str:
    """util::json's compact form: ints stay ints, no whitespace."""
    return json.dumps(doc, separators=(",", ":"))


def entry_line(seq: int, bench: str, payload) -> str:
    digest = f"fnv1a:{fnv1a64(compact(payload).encode()):016x}"
    return compact(
        {
            "schema": SCHEMA,
            "version": VERSION,
            "seq": seq,
            "bench": bench,
            "kind": "bench",
            "digest": digest,
            "payload": payload,
        }
    )


def churn_payload():
    results = []
    for scenario in CHURN_SCENARIOS:
        for policy in CHURN_POLICIES:
            row = {"scenario": scenario, "policy": policy}
            if scenario == "no-churn":
                row["cost"] = 1  # tie: coverage only
            else:
                row["cost"] = 1 if policy == "online-proposed" else 2
            if scenario == "burst-storm":
                tail = 1 if policy == "online-proposed" else 2
                row["p99_s"] = tail
                row["deadline_violation_rate"] = tail
            results.append(row)
    return {"bench": "fleet_churn", "version": 1, "results": results}


def scale_payload():
    results = []
    for n in SCALE_NS:
        for policy in SCALE_POLICIES:
            row = {"scenario": f"scale-{n}", "policy": policy}
            if policy != "feasible-random":
                contended = n >= 4
                worse = policy == "equal-share" and contended
                row["cost"] = 2 if worse else 1
                row["d_upper"] = 2 if worse else 1
            results.append(row)
    for n in SOLVE_SCALE_CLASSED_NS:
        # classed == per-agent cost bit for bit (exactness), so every
        # solve-scale row is a tie: coverage only
        if n in SOLVE_SCALE_SHARED_NS:
            results.append({"scenario": f"solve-scale-{n}", "policy": "per-agent", "cost": 1})
        results.append({"scenario": f"solve-scale-{n}", "policy": "classed", "cost": 1})
    return {"bench": "fleet_scale", "version": 1, "results": results}


def placement_payload():
    results = []
    for scenario in PLACEMENT_SCENARIOS:
        for policy in PLACEMENT_POLICIES:
            row = {"scenario": scenario, "policy": policy}
            if scenario == "hot-server":
                if policy == "local-search":
                    row["cost"] = 1
                elif policy == "equal-spread":
                    row["cost"] = 2
                # nearest-server: coverage only (local <= nearest is not
                # guaranteed strict)
            else:
                row["cost"] = 1  # tie: coverage only
            results.append(row)
    return {"bench": "fleet_placement", "version": 1, "results": results}


def daemon_payload():
    results = []
    for policy in DAEMON_POLICIES:
        row = {"scenario": "burst-storm", "policy": policy}
        row["p99_s"] = 1 if policy.startswith("daemon-") else 2
        results.append(row)
    return {"bench": "fleet_daemon", "version": 1, "results": results}


def quant_payload():
    results = []
    # adaptive and the legacy solver pick are bit-identical, so both sit
    # at rank 1 (a tie derives no ordering between them) while every
    # static pin sits above at rank 2
    results.append({"scenario": "drifting-load", "policy": "adaptive:1-16", "d_upper": 1})
    results.append({"scenario": "drifting-load", "policy": "static", "d_upper": 1})
    for b in QUANT_STATIC_BITS:
        results.append({"scenario": "drifting-load", "policy": f"static:{b}", "d_upper": 2})
    for r in QUANT_RATE_BUDGETS:
        results.append({"scenario": f"rate-{r}", "policy": "mixed", "d_upper": 1})
        results.append({"scenario": f"rate-{r}", "policy": "uniform", "d_upper": 2})
    return {"bench": "fleet_quant", "version": 1, "results": results}


def main():
    out = os.path.join(os.path.dirname(os.path.abspath(__file__)), "benchlog-baseline.jsonl")
    lines = [
        entry_line(0, "fleet_churn", churn_payload()),
        entry_line(1, "fleet_scale", scale_payload()),
        entry_line(2, "fleet_placement", placement_payload()),
        entry_line(3, "fleet_daemon", daemon_payload()),
        entry_line(4, "fleet_quant", quant_payload()),
    ]
    with open(out, "w") as f:
        f.write("\n".join(lines) + "\n")
    print(f"wrote {out}: {len(lines)} entries")


if __name__ == "__main__":
    main()
