//! Benchmark harness (criterion stand-in) + paper-table printer.
//!
//! Three roles:
//! * `time(...)` — warmup + timed iterations with percentile reporting, for
//!   hot-path micro/macro benchmarks (`perf_hotpath` bench, §Perf).
//! * [`Table`] — aligned row printer used by every `fig*`/`table1` bench to
//!   emit the same rows/series the paper reports, so `cargo bench` output
//!   can be diffed against EXPERIMENTS.md.
//! * [`emit_bench_artifact`] — machine-readable `BENCH_<name>.json` result
//!   files (schema documented in the crate root under "Bench artifacts")
//!   that the `bench-artifacts` CI job uploads; emission round-trips the
//!   file through the crate's own JSON parser and rejects any non-finite
//!   number, so a NaN/inf result can never land in a green artifact.

use crate::util::json::{self, Json};
use crate::util::timer::{Samples, Stopwatch};
use std::path::PathBuf;

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn time(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_us());
    }
    println!("bench {name:<44} {}", samples.summary("us"));
    samples
}

/// Run with fewer iterations when QACI_BENCH_FAST=1 (used by the smoke
/// integration test so `cargo test` stays quick).
pub fn fast_mode() -> bool {
    std::env::var("QACI_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 8).max(1)
    } else {
        n
    }
}

// ---------------------------------------------------------------------------
// machine-readable bench artifacts
// ---------------------------------------------------------------------------

/// Where bench artifacts land: `$QACI_BENCH_DIR` if set, else the
/// working directory (`rust/` under `cargo bench`, which is what the CI
/// job uploads from).
pub fn artifact_dir() -> PathBuf {
    std::env::var_os("QACI_BENCH_DIR").map(PathBuf::from).unwrap_or_else(|| PathBuf::from("."))
}

/// Write `BENCH_<bench>.json` with the given result records and return
/// the path plus the **parsed-back** document.
///
/// The round trip is the validity gate: the file is re-read through
/// [`crate::util::json::parse`] (our serializer renders NaN/±inf as
/// bare `NaN`/`inf` tokens, which the parser rejects), and every number
/// in the parsed tree is additionally asserted finite. Benches then
/// re-check their ordering invariants *against the parsed document*, so
/// the artifact CI uploads is exactly what was verified.
///
/// Publication is **atomic**: the bytes are written and validated at a
/// `.json.tmp` sibling and only renamed into place once the gate
/// passes. A crash mid-write or a failed validation therefore never
/// leaves a truncated `BENCH_*.json` behind — the previously published
/// artifact, if any, survives byte-identical (regression-tested below).
pub fn emit_bench_artifact(bench: &str, results: Vec<Json>) -> (PathBuf, Json) {
    let doc = Json::obj()
        .set("bench", bench)
        .set("version", 1usize)
        .set("results", Json::Arr(results));
    let path = artifact_dir().join(format!("BENCH_{bench}.json"));
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, doc.to_string_pretty())
        .unwrap_or_else(|e| panic!("writing {}: {e}", tmp.display()));
    let text = std::fs::read_to_string(&tmp)
        .unwrap_or_else(|e| panic!("re-reading {}: {e}", tmp.display()));
    let back = json::parse(&text)
        .unwrap_or_else(|e| panic!("{} is not valid JSON: {e}", tmp.display()));
    assert_all_finite(&back, bench);
    assert_eq!(back, doc, "artifact round-trip must be lossless");
    std::fs::rename(&tmp, &path)
        .unwrap_or_else(|e| panic!("publishing {}: {e}", path.display()));
    println!("\nwrote {}", path.display());
    (path, back)
}

/// Recursively assert every number in a JSON tree is finite.
pub fn assert_all_finite(j: &Json, context: &str) {
    match j {
        Json::Num(n) => assert!(n.is_finite(), "{context}: non-finite number {n}"),
        Json::Arr(a) => a.iter().for_each(|v| assert_all_finite(v, context)),
        Json::Obj(kv) => kv.iter().for_each(|(k, v)| {
            assert_all_finite(v, &format!("{context}.{k}"));
        }),
        _ => {}
    }
}

/// `f64` → JSON, representing a missing measurement (`NaN`, e.g. a
/// percentile over zero completions) as `null` instead of a non-finite
/// number the artifact gate would reject.
pub fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

// ---------------------------------------------------------------------------
// table printer
// ---------------------------------------------------------------------------

/// Aligned console table: `Table::new(...).row(...).print()`.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, label: &str, vals: &[f64], prec: usize) -> &mut Table {
        let mut cells = vec![label.to_string()];
        cells.extend(vals.iter().map(|v| format!("{v:.prec$}")));
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("\n=== {} ===\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["scheme", "x", "y"]);
        t.rowf("proposed", &[1.23456, 2.0], 3);
        t.rowf("baseline-with-long-name", &[0.1, 20.5], 3);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("proposed"));
        assert!(s.contains("1.235"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().filter(|l| l.contains("  ")).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        Table::new("t", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn time_returns_all_samples() {
        let s = time("noop", 1, 5, || {});
        assert_eq!(s.len(), 5);
    }

    #[test]
    fn bench_artifact_roundtrips_and_rejects_non_finite() {
        let dir = std::env::temp_dir().join("qaci_bench_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("QACI_BENCH_DIR", &dir);
        let rec = Json::obj()
            .set("scenario", "s")
            .set("policy", "p")
            .set("cost", 0.25)
            .set("p99", num_or_null(f64::NAN));
        let (path, back) = emit_bench_artifact("selftest", vec![rec]);
        assert!(path.ends_with("BENCH_selftest.json"));
        assert_eq!(back.get("bench").and_then(Json::as_str), Some("selftest"));
        let results = back.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].get("cost").and_then(Json::as_f64), Some(0.25));
        assert_eq!(results[0].get("p99"), Some(&Json::Null));
        // a genuinely non-finite number must be rejected, not uploaded
        let bad = Json::obj().set("x", f64::INFINITY);
        let res = std::panic::catch_unwind(|| assert_all_finite(&bad, "bad"));
        assert!(res.is_err());
        // atomic publication regression: an emit that fails its validity
        // gate panics before the rename, so the previously published
        // artifact stays byte-identical — never truncated or clobbered
        let before = std::fs::read_to_string(&path).unwrap();
        let failed = std::panic::catch_unwind(|| {
            emit_bench_artifact("selftest", vec![Json::obj().set("cost", f64::INFINITY)])
        });
        std::env::remove_var("QACI_BENCH_DIR");
        assert!(failed.is_err(), "non-finite artifact must fail to emit");
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            before,
            "failed emit must leave the published artifact untouched"
        );
        std::fs::remove_file(path).ok();
        std::fs::remove_file(dir.join("BENCH_selftest.json.tmp")).ok();
    }
}
