//! Benchmark harness (criterion stand-in) + paper-table printer.
//!
//! Two roles:
//! * `time(...)` — warmup + timed iterations with percentile reporting, for
//!   hot-path micro/macro benchmarks (`perf_hotpath` bench, §Perf).
//! * [`Table`] — aligned row printer used by every `fig*`/`table1` bench to
//!   emit the same rows/series the paper reports, so `cargo bench` output
//!   can be diffed against EXPERIMENTS.md.

use crate::util::timer::{Samples, Stopwatch};

/// Time `f` for `iters` iterations after `warmup` untimed runs.
pub fn time(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> Samples {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Samples::new();
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_us());
    }
    println!("bench {name:<44} {}", samples.summary("us"));
    samples
}

/// Run with fewer iterations when QACI_BENCH_FAST=1 (used by the smoke
/// integration test so `cargo test` stays quick).
pub fn fast_mode() -> bool {
    std::env::var("QACI_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

pub fn scaled(n: usize) -> usize {
    if fast_mode() {
        (n / 8).max(1)
    } else {
        n
    }
}

// ---------------------------------------------------------------------------
// table printer
// ---------------------------------------------------------------------------

/// Aligned console table: `Table::new(...).row(...).print()`.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
        self
    }

    pub fn rowf(&mut self, label: &str, vals: &[f64], prec: usize) -> &mut Table {
        let mut cells = vec![label.to_string()];
        cells.extend(vals.iter().map(|v| format!("{v:.prec$}")));
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{c:>w$}", w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        let mut out = format!("\n=== {} ===\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["scheme", "x", "y"]);
        t.rowf("proposed", &[1.23456, 2.0], 3);
        t.rowf("baseline-with-long-name", &[0.1, 20.5], 3);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("proposed"));
        assert!(s.contains("1.235"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().filter(|l| l.contains("  ")).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        Table::new("t", &["a", "b"]).row(&["only-one".into()]);
    }

    #[test]
    fn time_returns_all_samples() {
        let s = time("noop", 1, 5, || {});
        assert_eq!(s.len(), 5);
    }
}
