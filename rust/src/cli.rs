//! CLI command implementations (see `main.rs` for the synopsis).

use qaci::bench_harness::Table;
use qaci::coordinator::batcher::BatcherConfig;
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::coordinator::server::PipelinedServer;
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::fleet::churn::{self, ChurnConfig};
use qaci::fleet::{daemon, events, sim as fleet_sim, DaemonConfig, FleetSimConfig, LaneSeedMix};
use qaci::obs::benchlog::{self, BenchLog, DiffOptions, Query};
use qaci::opt::fleet::{
    AdmissionPricing, AgentSpec, Classing, FleetAlgorithm, FleetProblem, FleetSpec,
    PlacementStrategy, ServerSpec, SolveRequest,
};
use qaci::opt::{bisection, sca, Problem};
use qaci::quant::{QuantPolicy, Scheme};
use qaci::rl::env::BudgetRanges;
use qaci::rl::PpoConfig;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::platform::DeviceProfile;
use qaci::system::queue::{QueueDiscipline, QueueModel};
use qaci::system::Platform;
use qaci::theory::expdist::ExponentialModel;
use qaci::util::cli::{Args, ParseError};
use qaci::util::json::Json;
use qaci::util::timer::Stopwatch;

pub fn main() {
    let args = Args::parse_env()
        .describe("t0", "delay budget [s]", Some("3.5"))
        .describe("e0", "energy budget [J]", Some("2.0"))
        .describe("model", "blip2ish | gitish", Some("blip2ish"))
        .describe(
            "algorithm",
            "proposed|exact|ppo|fixed-freq|feasible-random \
             (fleet: proposed | equal-share | feasible-random)",
            Some("proposed"),
        )
        .describe("scheme", "uniform | pot", Some("uniform"))
        .describe("requests", "number of requests (fleet: per agent, default 16)", Some("32"))
        .describe("rps", "Poisson arrival rate (fleet default 2)", Some("20"))
        .describe("seed", "rng seed", Some("0"))
        .describe("paper-platform", "use paper FLOPs instead of measured", None)
        .describe("agents", "fleet size N (fleet subcommand)", Some("8"))
        .describe(
            "tiers",
            "fleet silicon ladder, comma list of orin|xavier|phone (one QoS cycle per tier)",
            Some("orin"),
        )
        .describe("rate-mbps", "shared uplink goodput (fleet)", Some("400"))
        .describe("servers", "fleet: number of identical edge servers S", Some("1"))
        .describe(
            "server-scales",
            "fleet: per-server f̃^max scales, comma list in (0,1] (overrides --servers)",
            None,
        )
        .describe(
            "placement",
            "fleet: agent→server placement, local-search | equal-spread | nearest-server",
            Some("local-search"),
        )
        .describe(
            "queue",
            "shared edge queue: fifo | priority | off (churn default fifo)",
            Some("off"),
        )
        .describe("churn", "fleet: run the churn comparison instead of one allocation", None)
        .describe(
            "events",
            "churn: also replay request-level traffic and print tail telemetry",
            None,
        )
        .describe(
            "serve",
            "fleet: run the closed-loop serving daemon (epochs + hysteresis) instead",
            None,
        )
        .describe("epochs", "serve: number of telemetry epochs", Some("8"))
        .describe("epoch-dur", "serve: epoch length [s]", Some("75"))
        .describe("cooldown", "serve: minimum spacing between taken re-solves [s]", Some("60"))
        .describe(
            "gain-threshold",
            "serve: skip a rate-only re-solve while the frozen-shares cost stays within \
             this fraction of the counterfactual warm solve",
            Some("0.05"),
        )
        .describe(
            "urgent-backlog",
            "serve: measured queue backlog [s] past which a pending change re-solves \
             immediately, cooldown or not",
            Some("5"),
        )
        .describe("resolve-always", "serve: disable hysteresis (A/B baseline)", None)
        .describe(
            "closed-loop",
            "churn/serve: closed-loop (single-inflight) clients instead of open Poisson streams",
            None,
        )
        .describe(
            "admission-pricing",
            "fleet: rejection pricing, uniform | tiered (capability-scaled) | measured \
             (telemetry-scaled, fed by --serve epochs)",
            Some("uniform"),
        )
        .describe(
            "classing",
            "fleet: allocator equivalence classing, per-agent | exact | bucketed[:decimals]",
            Some("per-agent"),
        )
        .describe(
            "class-reuse",
            "churn: reuse departed same-class agents' allocations across re-solves",
            None,
        )
        .describe("lane-mix", "fleet sim: per-lane seed mix, additive | splitmix", Some("additive"))
        .describe(
            "quant-policy",
            "fleet/churn/serve: per-agent quantization policy, static | static:<bits> | \
             adaptive | adaptive:<min>-<max>[:<backoff>]",
            Some("static"),
        )
        .describe("horizon", "churn: simulated horizon [s]", Some("600"))
        .describe("join-rps", "churn: Poisson join rate [1/s]", Some("0.02"))
        .describe("leave-rps", "churn: per-agent leave rate [1/s]", Some("0.003"))
        .describe("burst-rps", "churn: load-burst start rate [1/s]", Some("0.01"))
        .describe("burst-factor", "churn: arrival multiplier during a burst", Some("5"))
        .describe("burst-dur", "churn: burst duration [s]", Some("40"))
        .describe("tick", "churn: fingerprint re-check period [s]", Some("20"))
        .describe("max-agents", "churn: population cap", Some("16"))
        .describe("arrival-rps", "churn: steady per-agent request rate [1/s]", Some("0.02"))
        .describe(
            "metrics-out",
            "fleet: write the run's qaci.metrics snapshot to this path",
            None,
        )
        .describe("index", "bench-log: index file", Some("benchlog.jsonl"))
        .describe(
            "baseline",
            "bench-log diff: baseline index (omitted: previous vs latest run)",
            None,
        )
        .describe("bench", "bench-log query: restrict to one bench name", None)
        .describe("scenario", "bench-log query: restrict to one scenario", None)
        .describe("policy", "bench-log query: restrict to one policy", None)
        .describe("field", "bench-log query: result field to extract", Some("p99_s"))
        .describe("last", "bench-log query: only the last K runs (0 = all)", Some("0"))
        .describe("tolerance", "bench-log diff: relative value-regression headroom", Some("0.05"))
        .describe(
            "orderings-only",
            "bench-log diff: machine-invariant ordering checks only (CI mode)",
            None,
        )
        .describe("fail-on-regression", "bench-log diff: exit nonzero on any finding", None);
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        eprintln!("unknown flags: {unknown:?}");
        std::process::exit(2);
    }
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("plan") => cmd_plan(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("fit") => cmd_fit(&args),
        Some("bench-log") => cmd_bench_log(&args),
        _ => {
            print!(
                "{}",
                args.usage(
                    "qaci",
                    "quantization-aware collaborative inference \
                     (subcommands: info, plan, eval, serve, fleet, fit, bench-log)"
                )
            );
            0
        }
    };
    std::process::exit(code);
}

fn open_registry() -> Option<Registry> {
    match Registry::open(&qaci::artifacts_dir()) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("error: {e:#}");
            None
        }
    }
}

fn platform_for(args: &Args, model: &CoModel) -> Platform {
    let base = if model.name == "gitish" {
        Platform::paper_git()
    } else {
        Platform::paper_blip2()
    };
    if args.has("paper-platform") {
        base
    } else {
        base.with_workload(model.agent_flops, model.server_flops)
    }
}

fn scheduler_for(args: &Args, platform: Platform, lambda: f64) -> Result<Scheduler, ParseError> {
    let algorithm = Algorithm::parse(&args.str("algorithm", "proposed"))?;
    let scheme = Scheme::parse(&args.str("scheme", "uniform"))?;
    let mut s = Scheduler::new(platform, lambda, algorithm, scheme, args.usize("seed", 0) as u64);
    if algorithm == Algorithm::Ppo {
        eprintln!("training PPO policy (one-time)...");
        s.train_ppo(BudgetRanges::default(), PpoConfig::default());
    }
    Ok(s)
}

/// Unwrap a CLI token parse, printing the actionable "expected one of"
/// message on failure (callers then exit 2 — a usage error, not a crash).
fn parsed<T>(r: Result<T, ParseError>) -> Option<T> {
    match r {
        Ok(v) => Some(v),
        Err(e) => {
            eprintln!("error: {e}");
            None
        }
    }
}

/// `--queue` accepts `off` (no shared edge queue) on top of the
/// discipline names, so the off-switch lives here, not in `system::queue`;
/// the error choices include it.
fn parse_queue(token: &str) -> Result<Option<QueueDiscipline>, ParseError> {
    match token {
        "off" | "none" => Ok(None),
        tok => QueueDiscipline::parse(tok)
            .map(Some)
            .map_err(|e| ParseError { choices: &["fifo", "priority", "off"], ..e }),
    }
}

/// The fleet's server bank: `--server-scales 1.0,0.5` (heterogeneous
/// boxes) wins over `--servers N` (identical full-budget boxes).
fn fleet_servers(args: &Args) -> Option<Vec<ServerSpec>> {
    match args.opt_str("server-scales") {
        Some(list) => {
            let mut servers = Vec::new();
            for tok in list.split(',') {
                match tok.trim().parse::<f64>() {
                    Ok(s) if s > 0.0 && s <= 1.0 => servers.push(ServerSpec::scaled(s)),
                    _ => {
                        eprintln!(
                            "error: invalid --server-scales entry \"{tok}\" \
                             (expected comma-separated numbers in (0, 1])"
                        );
                        return None;
                    }
                }
            }
            Some(servers)
        }
        None => Some(ServerSpec::identical(args.usize("servers", 1))),
    }
}

fn cmd_info() -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    println!("artifacts: {}", reg.dir.display());
    for name in reg.model_names() {
        let m = reg.model(name).unwrap();
        if let Some(agent) = m.get("agent") {
            let lam = agent.get("lambda").and_then(Json::as_f64).unwrap_or(0.0);
            let fl = agent.get("flops").and_then(Json::as_f64).unwrap_or(0.0);
            let sfl = m.at(&["server", "flops"]).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "  {name:10} agent λ={lam:7.2}  agent {:>8.1} MFLOPs  server {:>8.1} MFLOPs",
                fl / 1e6,
                sfl / 1e6
            );
        } else {
            let lam = m.get("lambda").and_then(Json::as_f64).unwrap_or(0.0);
            println!("  {name:10} λ={lam:7.2}");
        }
    }
    for set in ["coco", "vatex"] {
        if let Ok(ev) = EvalSet::load(&reg.dir, &reg.manifest, set) {
            println!("  eval/{set}: {} samples x {:?}", ev.len(), ev.sample_shape);
        }
    }
    0
}

fn cmd_plan(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model = match CoModel::load(&reg, &args.str("model", "blip2ish")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let platform = platform_for(args, &model);
    let problem = Problem::new(
        platform,
        model.agent_weights.lambda,
        args.f64("t0", 3.5),
        args.f64("e0", 2.0),
    );
    println!(
        "platform: N={:.3e} Ñ={:.3e} f^max={:.2}GHz f̃^max={:.2}GHz λ={:.2}",
        platform.n_flop_agent,
        platform.n_flop_server,
        platform.device.f_max / 1e9,
        platform.server.f_max / 1e9,
        problem.lambda
    );
    match sca::solve(&problem, sca::ScaOptions::default()) {
        Some(r) => {
            println!(
                "proposed (SCA, {} iters): b̂={}  f={:.3} GHz  f̃={:.3} GHz",
                r.trace.len(),
                r.design.b_hat,
                r.design.f / 1e9,
                r.design.f_tilde / 1e9
            );
            println!(
                "  T={:.4}s (T0={})  E={:.4}J (E0={})  gap objective={:.3e}",
                problem.total_delay(&r.design),
                problem.t0,
                problem.total_energy(&r.design),
                problem.e0,
                r.objective
            );
            if let Some(exact) = bisection::solve(&problem) {
                println!(
                    "exact reference: b̂={} (b̃*={:.3})",
                    exact.design.b_hat, exact.b_tilde_star
                );
            }
            0
        }
        None => {
            println!("INFEASIBLE under (T0={}, E0={})", problem.t0, problem.e0);
            1
        }
    }
}

fn cmd_eval(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model_name = args.str("model", "blip2ish");
    let mut model = match CoModel::load(&reg, &model_name) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let eval_name = if model_name == "gitish" { "vatex" } else { "coco" };
    let eval = EvalSet::load(&reg.dir, &reg.manifest, eval_name).unwrap();
    let vocab = Vocab::from_manifest(&reg.manifest).unwrap();
    let platform = platform_for(args, &model);
    let Some(scheduler) = parsed(scheduler_for(args, platform, model.agent_weights.lambda)) else {
        return 2;
    };
    let router = Router::new(
        QosPolicy::uniform(args.f64("t0", 3.5), args.f64("e0", 2.0)),
        scheduler,
    );
    let requests = generate(
        args.usize("requests", 32),
        eval.len(),
        Arrival::Batch,
        args.usize("seed", 0) as u64,
    );
    let mut engine = Engine::new(
        &mut model,
        router,
        &vocab,
        &eval,
        qaci::system::channel::Channel::wlan_5ghz(1),
        EngineConfig::default(),
    );
    match engine.run(requests) {
        Ok(t) => {
            println!(
                "served {} requests  rejected {}  CIDEr(x100) {:.1}",
                t.len(),
                t.rejected,
                t.cider_x100(&eval.refs)
            );
            for (class, s) in t.by_class() {
                println!(
                    "  {class:12} n={:3}  b̂≈{:.1}  sim T {}  sim E {}",
                    s.count,
                    s.mean_bits,
                    s.sim_delay.summary("s"),
                    s.sim_energy.summary("J")
                );
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model_name = args.str("model", "blip2ish");
    let model = match CoModel::load(&reg, &model_name) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let eval_name = if model_name == "gitish" { "vatex" } else { "coco" };
    let eval = EvalSet::load(&reg.dir, &reg.manifest, eval_name).unwrap();
    let platform = platform_for(args, &model);
    let lambda = model.agent_weights.lambda;
    drop(model);
    let Some(scheduler) = parsed(scheduler_for(args, platform, lambda)) else { return 2 };
    let mut server = PipelinedServer {
        artifacts: reg.dir.clone(),
        model_name,
        router: Router::new(QosPolicy::paper_default(), scheduler),
        batcher_cfg: BatcherConfig::default(),
        queue_depth: 8,
    };
    let n = args.usize("requests", 32);
    let requests = generate(
        n,
        eval.len(),
        Arrival::Poisson { lambda_rps: args.f64("rps", 20.0) },
        args.usize("seed", 0) as u64,
    );
    let sw = qaci::util::timer::Stopwatch::start();
    match server.run(requests, &eval) {
        Ok(t) => {
            let wall = sw.elapsed_s();
            println!(
                "pipelined: {} requests in {:.2}s wall = {:.1} req/s, CIDEr(x100) {:.1}",
                t.len(),
                wall,
                t.len() as f64 / wall,
                t.cider_x100(&eval.refs)
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

/// Fleet-scale co-inference: joint multi-agent allocation + serving-loop
/// simulation. Artifact-free (analytic models only), so it runs anywhere.
/// `--churn` switches to the online-re-allocation comparison. With
/// `--metrics-out <path>` the run's ambient metrics (solver counters,
/// queue histograms, spans) are written as a schema-versioned
/// `qaci.metrics` snapshot after the command finishes.
fn cmd_fleet(args: &Args) -> i32 {
    qaci::obs::metrics::reset(); // snapshot covers this run only
    let code = if args.has("serve") {
        cmd_fleet_serve(args)
    } else if args.has("churn") {
        cmd_fleet_churn(args)
    } else {
        cmd_fleet_alloc(args)
    };
    if let Some(path) = args.opt_str("metrics-out") {
        let body = qaci::obs::metrics::snapshot().to_json().to_string_pretty();
        if let Err(e) = std::fs::write(&path, body + "\n") {
            eprintln!("error writing metrics snapshot {path}: {e}");
            return 1;
        }
        println!("wrote metrics snapshot {path}");
    }
    code
}

fn cmd_fleet_alloc(args: &Args) -> i32 {
    let n = args.usize("agents", 8).max(1);
    let Some(algorithm) = parsed(FleetAlgorithm::parse(&args.str("algorithm", "proposed"))) else {
        return 2;
    };
    let Some(placement) = parsed(PlacementStrategy::parse(&args.str("placement", "local-search")))
    else {
        return 2;
    };
    let seed = args.usize("seed", 0) as u64;
    let Some(queue) = parsed(parse_queue(&args.str("queue", "off"))) else { return 2 };
    let Some(tiers) = parsed(DeviceProfile::parse_mix(&args.str("tiers", "orin"))) else {
        return 2;
    };
    let Some(pricing) = parsed(AdmissionPricing::parse(&args.str("admission-pricing", "uniform")))
    else {
        return 2;
    };
    let Some(servers) = fleet_servers(args) else { return 2 };
    let Some(quant) = parsed(QuantPolicy::parse(&args.str("quant-policy", "static"))) else {
        return 2;
    };
    let multi = servers != [ServerSpec::default()];
    // with the queue on, the allocator's analytic load and the simulated
    // arrivals must describe the same traffic: one rate drives both
    // (explicit --rps still wins for stress runs)
    let arrival_rps = if queue.is_some() && !args.has("rps") {
        args.f64("arrival-rps", 0.02)
    } else {
        args.f64("rps", 2.0)
    };
    let mut agents = AgentSpec::tiered_fleet(n, &tiers);
    for a in &mut agents {
        a.quant = quant;
    }
    let mut spec = FleetSpec::new(Platform::fleet_edge(), agents);
    spec.link_rate_bps = args.f64("rate-mbps", 400.0) * 1e6;
    spec.pricing = pricing;
    spec.servers = servers.clone();
    if let Some(discipline) = queue {
        spec.queue = Some(QueueModel::uniform(discipline, n, arrival_rps));
    }
    let fp = FleetProblem::from_spec(spec);
    println!(
        "fleet: N={n} agents, tiers [{}], shared server f̃^max={:.1} GHz, shared uplink \
         {:.0} Mbps, algorithm={}, queue={}, pricing={}, arrivals {:.3}/s per agent",
        tiers.iter().map(|t| t.tier).collect::<Vec<_>>().join(","),
        fp.base.server.f_max / 1e9,
        fp.link_rate_bps / 1e6,
        algorithm.name(),
        queue.map_or("off", QueueDiscipline::name),
        pricing.name(),
        arrival_rps
    );
    if multi {
        println!(
            "  servers: S={} (f̃^max scales [{}]), placement={}",
            servers.len(),
            servers.iter().map(|s| format!("{:.2}", s.freq_scale)).collect::<Vec<_>>().join(","),
            placement.name()
        );
    }

    let Some(classing) = parsed(Classing::parse(&args.str("classing", "per-agent"))) else {
        return 2;
    };
    let Some(lane_mix) = parsed(LaneSeedMix::parse(&args.str("lane-mix", "additive"))) else {
        return 2;
    };
    let sw = Stopwatch::start();
    let req = SolveRequest { algorithm, placement, seed, classing, ..SolveRequest::default() };
    let alloc = fp.solve(&req);
    let solve_s = sw.elapsed_s();

    let cfg = FleetSimConfig {
        requests_per_agent: args.usize("requests", 16),
        arrival: Arrival::Poisson { lambda_rps: arrival_rps },
        seed,
        batcher: BatcherConfig::default(),
        queue,
        lane_mix,
    };
    let report = fleet_sim::run(&fp, &alloc, &cfg);

    // the "srv" column only appears on multi-server fleets, so the
    // single-server table stays byte-identical to the historical output
    let mut header = vec!["agent", "class", "tier"];
    if multi {
        header.push("srv");
    }
    header.extend_from_slice(&[
        "w", "T0", "E0", "b̂", "μ", "α", "link ms", "e2e p50", "e2e p95", "E mean", "served",
    ]);
    let mut t = Table::new("per-agent allocation", &header);
    for (a, spec) in report.per_agent.iter().zip(&fp.agents) {
        let slot = &alloc.agents[a.agent];
        let mut cells = vec![format!("{}", a.agent), a.class.to_string(), a.tier.to_string()];
        if multi {
            cells.push(format!("{}", alloc.placement.assignment[a.agent]));
        }
        cells.extend([
            format!("{:.1}", spec.weight),
            format!("{:.2}", spec.t0),
            format!("{:.2}", spec.e0),
            if a.admitted { format!("{}", a.b_hat) } else { "REJ".into() },
            format!("{:.3}", a.server_share),
            format!("{:.3}", a.airtime_share),
            if slot.link_s.is_finite() {
                format!("{:.1}", slot.link_s * 1e3)
            } else {
                "--".into()
            },
            if a.served > 0 { format!("{:.3}", a.e2e_s.p50()) } else { "--".into() },
            if a.served > 0 { format!("{:.3}", a.e2e_s.p95()) } else { "--".into() },
            if a.served > 0 { format!("{:.3}", a.energy_j.mean()) } else { "--".into() },
            format!("{}/{}", a.served, a.served + a.rejected as usize),
        ]);
        t.row(&cells);
    }
    t.print();

    println!(
        "\nfleet aggregate ({}): admitted {}/{}  weighted gap {:.3e}  weighted D^U {:.3e}",
        algorithm.name(),
        report.admitted_agents,
        n,
        report.weighted_gap,
        report.weighted_d_upper
    );
    if report.served > 0 {
        println!(
            "  e2e delay: p50 {:.3}s  p95 {:.3}s  p99 {:.3}s  (served {}, rejected {})",
            report.e2e_s.p50(),
            report.e2e_s.p95(),
            report.e2e_s.p99(),
            report.served,
            report.rejected
        );
        if queue.is_some() {
            println!(
                "  edge-queue wait: p50 {:.3}s  p95 {:.3}s  max {:.3}s",
                report.queue_wait_s.p50(),
                report.queue_wait_s.p95(),
                report.queue_wait_s.max()
            );
        }
    } else {
        println!("  no requests served (fleet inadmissible); rejected {}", report.rejected);
    }
    println!(
        "  energy {:.2} J total  qos violations {}  slo misses {}  allocator {:.1} ms",
        report.total_energy_j,
        report.qos_violations,
        report.slo_misses,
        solve_s * 1e3
    );
    if report.admitted_agents == 0 {
        1
    } else {
        0
    }
}

/// The shared `--churn`/`--serve` workload config from CLI flags
/// (`None` = a flag failed to parse; the caller exits 2).
fn churn_config(args: &Args) -> Option<ChurnConfig> {
    let tiers = parsed(DeviceProfile::parse_mix(&args.str("tiers", "orin")))?;
    let pricing = parsed(AdmissionPricing::parse(&args.str("admission-pricing", "uniform")))?;
    let queue = parsed(parse_queue(&args.str("queue", "fifo")))?;
    let servers = fleet_servers(args)?;
    Some(ChurnConfig {
        initial_agents: args.usize("agents", 4).max(1),
        horizon_s: args.f64("horizon", 600.0),
        join_rps: args.f64("join-rps", 0.02),
        leave_rps_per_agent: args.f64("leave-rps", 0.003),
        burst_rps: args.f64("burst-rps", 0.01),
        burst_factor: args.f64("burst-factor", 5.0),
        burst_duration_s: args.f64("burst-dur", 40.0),
        tick_s: args.f64("tick", 20.0),
        max_agents: args.usize("max-agents", 16),
        arrival_rps: args.f64("arrival-rps", 0.02),
        closed_loop: args.has("closed-loop"),
        queue,
        link_rate_bps: args.f64("rate-mbps", 400.0) * 1e6,
        link_base_latency_s: 2e-3,
        tiers,
        pricing,
        servers,
        classing: parsed(Classing::parse(&args.str("classing", "per-agent")))?,
        class_reuse: args.has("class-reuse"),
        quant: parsed(QuantPolicy::parse(&args.str("quant-policy", "static")))?,
        seed: args.usize("seed", 0) as u64,
    })
}

/// `qaci fleet --churn`: replay one churn timeline (Poisson joins,
/// leaves, load bursts) under the static t=0 allocations and the online
/// warm-started re-allocation, and compare time-averaged fleet cost.
fn cmd_fleet_churn(args: &Args) -> i32 {
    let Some(cfg) = churn_config(args) else { return 2 };
    let multi = cfg.servers != [ServerSpec::default()];
    let (tl, reports) = churn::compare(Platform::fleet_edge(), &cfg);
    println!(
        "churn: N0={} agents, tiers [{}], horizon {:.0}s, {} events ({} joins, {} leaves, \
         {} bursts), queue={}, pricing={}, quant={}",
        cfg.initial_agents,
        cfg.tiers.iter().map(|t| t.tier).collect::<Vec<_>>().join(","),
        cfg.horizon_s,
        tl.events.len(),
        tl.joins,
        tl.leaves,
        tl.bursts,
        cfg.queue.map_or("off", QueueDiscipline::name),
        cfg.pricing.name(),
        cfg.quant.label()
    );
    if multi {
        let scales: Vec<String> =
            cfg.servers.iter().map(|s| format!("{:.2}", s.freq_scale)).collect();
        println!(
            "  servers: S={} (f̃^max scales [{}]), sticky placement + per-server warm re-solves",
            cfg.servers.len(),
            scales.join(",")
        );
    }

    let mut t = Table::new(
        "policy comparison (time-averaged fleet-weighted cost; lower is better)",
        &[
            "policy",
            "avg cost",
            "avg D^U",
            "reallocs",
            "skipped",
            "solve p50 ms",
            "solve max ms",
            "final N",
            "final admitted",
        ],
    );
    for r in &reports {
        t.row(&[
            r.policy.name().to_string(),
            format!("{:.4e}", r.time_avg_cost),
            format!("{:.4e}", r.time_avg_d_upper),
            format!("{}", r.reallocations),
            format!("{}", r.realloc_skipped),
            format!("{:.2}", r.solve_ms.p50()),
            format!("{:.2}", r.solve_ms.max()),
            format!("{}", r.final_population),
            format!("{}", r.final_alloc.admitted),
        ]);
    }
    t.print();

    if args.has("events") {
        // the same timeline, request level: what each policy's traffic
        // actually experienced (rejected/departure-dropped requests count
        // as deadline violations — they never completed)
        let mut et = Table::new(
            "event-level telemetry (per-request; e2e/wait over completed requests)",
            &[
                "policy",
                "arrivals",
                "completed",
                "rejected",
                "dropped",
                "e2e p50",
                "e2e p95",
                "e2e p99",
                "wait p50",
                "wait p99",
                "deadline viol",
            ],
        );
        let sec = |s: &qaci::util::timer::Samples, p: f64| {
            if s.is_empty() {
                "--".into()
            } else {
                format!("{:.3}s", s.percentile(p))
            }
        };
        for policy in churn::ChurnPolicy::ALL {
            let r = events::run_events(Platform::fleet_edge(), &tl, policy, &cfg);
            et.row(&[
                r.policy.name().to_string(),
                format!("{}", r.arrivals),
                format!("{}", r.completed),
                format!("{}", r.rejected),
                format!("{}", r.dropped_departure),
                sec(&r.e2e_s, 50.0),
                sec(&r.e2e_s, 95.0),
                sec(&r.e2e_s, 99.0),
                sec(&r.queue_wait_s, 50.0),
                sec(&r.queue_wait_s, 99.0),
                format!("{:.1}%", r.violation_rate() * 100.0),
            ]);
        }
        et.print();
    }

    let cost = |name: &str| {
        reports
            .iter()
            .find(|r| r.policy.name() == name)
            .map(|r| r.time_avg_cost)
            .unwrap_or(f64::INFINITY)
    };
    let online = cost("online-proposed");
    let best_static = cost("static-equal").min(cost("static-proposed"));
    if tl.events.is_empty() || tl.joins + tl.leaves + tl.bursts == 0 {
        println!("\nno churn events fired — static and online coincide by design");
        0
    } else if online < best_static {
        println!(
            "\nOK: online re-allocation beats the best static policy by {:.1}%",
            (1.0 - online / best_static) * 100.0
        );
        0
    } else {
        println!("\nWARNING: online did not beat the best static policy");
        1
    }
}

/// `qaci fleet --serve`: the closed-loop serving daemon — run the event
/// engine in telemetry epochs and let measured admission pricing plus
/// hysteresis (predicted-gain probe + measured-backlog urgency +
/// cooldown) decide which fingerprint changes are worth a re-solve at
/// all (see `qaci::fleet::daemon`).
fn cmd_fleet_serve(args: &Args) -> i32 {
    let Some(churn) = churn_config(args) else { return 2 };
    let dcfg = DaemonConfig {
        churn,
        epochs: args.usize("epochs", 8).max(1),
        epoch_s: args.f64("epoch-dur", 75.0),
        cooldown_s: args.f64("cooldown", 60.0),
        gain_threshold: args.f64("gain-threshold", 0.05),
        urgent_backlog_s: args.f64("urgent-backlog", 5.0),
        resolve_always: args.has("resolve-always"),
        audit: false,
    };
    let r = daemon::run_daemon(Platform::fleet_edge(), &dcfg);
    println!(
        "serve: N0={} agents, {} epochs x {:.0}s, cooldown {:.0}s, gain threshold {:.0}%, \
         urgency backlog {:.0}s, pricing={}, {} arrivals, {}",
        dcfg.churn.initial_agents,
        dcfg.epochs,
        dcfg.epoch_s,
        dcfg.cooldown_s,
        dcfg.gain_threshold * 100.0,
        dcfg.urgent_backlog_s,
        dcfg.churn.pricing.name(),
        if dcfg.churn.closed_loop { "closed-loop" } else { "open" },
        if dcfg.resolve_always { "resolve-always" } else { "hysteresis" },
    );

    let mut t = Table::new(
        "telemetry epochs (per-epoch deltas; p99s cumulative to date)",
        &[
            "epoch", "t end", "arrivals", "completed", "viol", "energy J", "p99 e2e", "p99 wait",
            "solves",
        ],
    );
    for e in &r.epochs {
        t.row(&[
            format!("{}", e.epoch),
            format!("{:.0}", e.t_end_s),
            format!("{}", e.arrivals),
            format!("{}", e.completed),
            format!("{}", e.violations),
            format!("{:.2}", e.energy_j),
            format!("{:.3}", e.p99_e2e_s),
            format!("{:.3}", e.p99_wait_s),
            format!("{}", e.resolves_taken),
        ]);
    }
    t.print();

    println!(
        "\nre-solves: taken {}  skipped {} (cooldown {}, gain {})  cancelled deferrals {}",
        r.resolves_taken,
        r.skipped_cooldown + r.skipped_gain,
        r.skipped_cooldown,
        r.skipped_gain,
        r.cancelled
    );
    let rep = &r.report;
    println!(
        "drained: arrivals {}  completed {}  rejected {}  dropped {}  p99 e2e {:.3}s  \
         viol {:.1}%  energy/req {:.2} J",
        rep.arrivals,
        rep.completed,
        rep.rejected,
        rep.dropped_departure,
        rep.e2e_s.p99(),
        rep.violation_rate() * 100.0,
        rep.energy_per_request_j()
    );
    if rep.completed == 0 {
        1
    } else {
        0
    }
}

/// `qaci bench-log <ingest|query|diff>`: the persistent, content-hashed
/// bench-trajectory store (see `obs::benchlog`). `ingest` appends
/// `BENCH_*.json` artifacts or `qaci.metrics` snapshots to the index;
/// `query` answers "field F on scenario S over the last K runs"; `diff`
/// gates the newest run against `--baseline` (or the previous run in
/// the same index), exiting nonzero with `--fail-on-regression`.
fn cmd_bench_log(args: &Args) -> i32 {
    let index = BenchLog::open(args.str("index", "benchlog.jsonl"));
    match args.positional.first().map(String::as_str) {
        Some("ingest") => {
            if args.positional.len() < 2 {
                eprintln!("bench-log ingest: no files given");
                return 2;
            }
            for file in &args.positional[1..] {
                match index.ingest_file(std::path::Path::new(file)) {
                    Ok(e) => println!(
                        "ingested {file} -> seq {} (bench {}, kind {}, {})",
                        e.seq, e.bench, e.kind, e.digest
                    ),
                    Err(e) => {
                        eprintln!("error: {e:#}");
                        return 1;
                    }
                }
            }
            0
        }
        Some("query") => {
            let q = Query {
                bench: args.opt_str("bench"),
                scenario: args.opt_str("scenario"),
                policy: args.opt_str("policy"),
                field: args.str("field", "p99_s"),
                last: args.usize("last", 0),
            };
            let rows = match index.query(&q) {
                Ok(rows) => rows,
                Err(e) => {
                    eprintln!("error: {e:#}");
                    return 1;
                }
            };
            let mut t = Table::new(
                &format!("bench-log: {} ({})", q.field, index.path().display()),
                &["seq", "bench", "scenario", "policy", "value"],
            );
            for r in &rows {
                t.row(&[
                    format!("{}", r.seq),
                    r.bench.clone(),
                    r.scenario.clone(),
                    r.policy.clone(),
                    r.value.map_or_else(|| "null".into(), |v| format!("{v}")),
                ]);
            }
            t.print();
            println!("{} row(s)", rows.len());
            0
        }
        Some("diff") => {
            let opts = DiffOptions {
                orderings_only: args.has("orderings-only"),
                tolerance: args.f64("tolerance", 0.05),
            };
            let findings = match args.opt_str("baseline") {
                Some(b) => benchlog::diff(&index, &BenchLog::open(b), &opts),
                None => benchlog::diff_latest_pair(&index, &opts),
            };
            match findings {
                Ok(findings) if findings.is_empty() => {
                    println!("bench-log diff: clean");
                    0
                }
                Ok(findings) => {
                    for f in &findings {
                        println!("{f}");
                    }
                    println!("bench-log diff: {} finding(s)", findings.len());
                    if args.has("fail-on-regression") {
                        1
                    } else {
                        0
                    }
                }
                Err(e) => {
                    eprintln!("error: {e:#}");
                    1
                }
            }
        }
        _ => {
            eprintln!("bench-log: expected a subcommand — ingest <files...> | query | diff");
            2
        }
    }
}

fn cmd_fit(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model = match CoModel::load(&reg, &args.str("model", "blip2ish")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    for (side, store) in [("agent", &model.agent_weights), ("server", &model.server_weights)] {
        let fit = ExponentialModel::fit_weights(&store.blob);
        let mags: Vec<f64> = store.blob.iter().map(|w| w.abs() as f64).collect();
        println!(
            "{side:6} n={:8}  λ(manifest)={:8.2}  λ(rust fit)={:8.2}  h(Θ)={:6.2} bits  KS={:.4}",
            store.n_params(),
            store.lambda,
            fit.lambda,
            fit.differential_entropy_bits(),
            fit.ks_statistic(&mags)
        );
    }
    0
}
