//! CLI command implementations (see `main.rs` for the synopsis).

use qaci::coordinator::batcher::BatcherConfig;
use qaci::coordinator::engine::{Engine, EngineConfig};
use qaci::coordinator::router::{QosPolicy, Router};
use qaci::coordinator::scheduler::{Algorithm, Scheduler};
use qaci::coordinator::server::PipelinedServer;
use qaci::data::eval::EvalSet;
use qaci::data::vocab::Vocab;
use qaci::data::workload::{generate, Arrival};
use qaci::opt::{bisection, sca, Problem};
use qaci::quant::Scheme;
use qaci::rl::env::BudgetRanges;
use qaci::rl::PpoConfig;
use qaci::runtime::executor::CoModel;
use qaci::runtime::Registry;
use qaci::system::Platform;
use qaci::theory::expdist::ExponentialModel;
use qaci::util::cli::Args;
use qaci::util::json::Json;

pub fn main() {
    let args = Args::parse_env()
        .describe("t0", "delay budget [s]", Some("3.5"))
        .describe("e0", "energy budget [J]", Some("2.0"))
        .describe("model", "blip2ish | gitish", Some("blip2ish"))
        .describe("algorithm", "proposed|exact|ppo|fixed-freq|random", Some("proposed"))
        .describe("scheme", "uniform | pot", Some("uniform"))
        .describe("requests", "number of requests", Some("32"))
        .describe("rps", "Poisson arrival rate", Some("20"))
        .describe("seed", "rng seed", Some("0"))
        .describe("paper-platform", "use paper FLOPs instead of measured", None);
    let unknown = args.unknown_keys();
    if !unknown.is_empty() {
        eprintln!("unknown flags: {unknown:?}");
        std::process::exit(2);
    }
    let code = match args.subcommand.as_deref() {
        Some("info") => cmd_info(),
        Some("plan") => cmd_plan(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("fit") => cmd_fit(&args),
        _ => {
            print!(
                "{}",
                args.usage(
                    "qaci",
                    "quantization-aware collaborative inference \
                     (subcommands: info, plan, eval, serve, fit)"
                )
            );
            0
        }
    };
    std::process::exit(code);
}

fn open_registry() -> Option<Registry> {
    match Registry::open(&qaci::artifacts_dir()) {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("error: {e:#}");
            None
        }
    }
}

fn platform_for(args: &Args, model: &CoModel) -> Platform {
    let base = if model.name == "gitish" {
        Platform::paper_git()
    } else {
        Platform::paper_blip2()
    };
    if args.has("paper-platform") {
        base
    } else {
        base.with_workload(model.agent_flops, model.server_flops)
    }
}

fn scheduler_for(args: &Args, platform: Platform, lambda: f64) -> Scheduler {
    let algorithm = Algorithm::parse(&args.str("algorithm", "proposed"))
        .unwrap_or(Algorithm::Proposed);
    let scheme =
        Scheme::parse(&args.str("scheme", "uniform")).unwrap_or(Scheme::Uniform);
    let mut s = Scheduler::new(platform, lambda, algorithm, scheme,
                               args.usize("seed", 0) as u64);
    if algorithm == Algorithm::Ppo {
        eprintln!("training PPO policy (one-time)...");
        s.train_ppo(BudgetRanges::default(), PpoConfig::default());
    }
    s
}

fn cmd_info() -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    println!("artifacts: {}", reg.dir.display());
    for name in reg.model_names() {
        let m = reg.model(name).unwrap();
        if let Some(agent) = m.get("agent") {
            let lam = agent.get("lambda").and_then(Json::as_f64).unwrap_or(0.0);
            let fl = agent.get("flops").and_then(Json::as_f64).unwrap_or(0.0);
            let sfl = m.at(&["server", "flops"]).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "  {name:10} agent λ={lam:7.2}  agent {:>8.1} MFLOPs  server {:>8.1} MFLOPs",
                fl / 1e6,
                sfl / 1e6
            );
        } else {
            let lam = m.get("lambda").and_then(Json::as_f64).unwrap_or(0.0);
            println!("  {name:10} λ={lam:7.2}");
        }
    }
    for set in ["coco", "vatex"] {
        if let Ok(ev) = EvalSet::load(&reg.dir, &reg.manifest, set) {
            println!("  eval/{set}: {} samples x {:?}", ev.len(), ev.sample_shape);
        }
    }
    0
}

fn cmd_plan(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model = match CoModel::load(&reg, &args.str("model", "blip2ish")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let platform = platform_for(args, &model);
    let problem = Problem::new(
        platform,
        model.agent_weights.lambda,
        args.f64("t0", 3.5),
        args.f64("e0", 2.0),
    );
    println!(
        "platform: N={:.3e} Ñ={:.3e} f^max={:.2}GHz f̃^max={:.2}GHz λ={:.2}",
        platform.n_flop_agent,
        platform.n_flop_server,
        platform.device.f_max / 1e9,
        platform.server.f_max / 1e9,
        problem.lambda
    );
    match sca::solve(&problem, sca::ScaOptions::default()) {
        Some(r) => {
            println!(
                "proposed (SCA, {} iters): b̂={}  f={:.3} GHz  f̃={:.3} GHz",
                r.trace.len(),
                r.design.b_hat,
                r.design.f / 1e9,
                r.design.f_tilde / 1e9
            );
            println!(
                "  T={:.4}s (T0={})  E={:.4}J (E0={})  gap objective={:.3e}",
                problem.total_delay(&r.design),
                problem.t0,
                problem.total_energy(&r.design),
                problem.e0,
                r.objective
            );
            if let Some(exact) = bisection::solve(&problem) {
                println!(
                    "exact reference: b̂={} (b̃*={:.3})",
                    exact.design.b_hat, exact.b_tilde_star
                );
            }
            0
        }
        None => {
            println!("INFEASIBLE under (T0={}, E0={})", problem.t0, problem.e0);
            1
        }
    }
}

fn cmd_eval(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model_name = args.str("model", "blip2ish");
    let mut model = match CoModel::load(&reg, &model_name) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let eval_name = if model_name == "gitish" { "vatex" } else { "coco" };
    let eval = EvalSet::load(&reg.dir, &reg.manifest, eval_name).unwrap();
    let vocab = Vocab::from_manifest(&reg.manifest).unwrap();
    let platform = platform_for(args, &model);
    let scheduler = scheduler_for(args, platform, model.agent_weights.lambda);
    let router = Router::new(
        QosPolicy::uniform(args.f64("t0", 3.5), args.f64("e0", 2.0)),
        scheduler,
    );
    let requests = generate(
        args.usize("requests", 32),
        eval.len(),
        Arrival::Batch,
        args.usize("seed", 0) as u64,
    );
    let mut engine = Engine::new(
        &mut model,
        router,
        &vocab,
        &eval,
        qaci::system::channel::Channel::wlan_5ghz(1),
        EngineConfig::default(),
    );
    match engine.run(requests) {
        Ok(t) => {
            println!(
                "served {} requests  rejected {}  CIDEr(x100) {:.1}",
                t.len(),
                t.rejected,
                t.cider_x100(&eval.refs)
            );
            for (class, s) in t.by_class() {
                println!(
                    "  {class:12} n={:3}  b̂≈{:.1}  sim T {}  sim E {}",
                    s.count,
                    s.mean_bits,
                    s.sim_delay.summary("s"),
                    s.sim_energy.summary("J")
                );
            }
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_serve(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model_name = args.str("model", "blip2ish");
    let model = match CoModel::load(&reg, &model_name) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    let eval_name = if model_name == "gitish" { "vatex" } else { "coco" };
    let eval = EvalSet::load(&reg.dir, &reg.manifest, eval_name).unwrap();
    let platform = platform_for(args, &model);
    let lambda = model.agent_weights.lambda;
    drop(model);
    let scheduler = scheduler_for(args, platform, lambda);
    let mut server = PipelinedServer {
        artifacts: reg.dir.clone(),
        model_name,
        router: Router::new(QosPolicy::paper_default(), scheduler),
        batcher_cfg: BatcherConfig::default(),
        queue_depth: 8,
    };
    let n = args.usize("requests", 32);
    let requests = generate(
        n,
        eval.len(),
        Arrival::Poisson { lambda_rps: args.f64("rps", 20.0) },
        args.usize("seed", 0) as u64,
    );
    let sw = qaci::util::timer::Stopwatch::start();
    match server.run(requests, &eval) {
        Ok(t) => {
            let wall = sw.elapsed_s();
            println!(
                "pipelined: {} requests in {:.2}s wall = {:.1} req/s, CIDEr(x100) {:.1}",
                t.len(),
                wall,
                t.len() as f64 / wall,
                t.cider_x100(&eval.refs)
            );
            0
        }
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    }
}

fn cmd_fit(args: &Args) -> i32 {
    let Some(reg) = open_registry() else { return 1 };
    let model = match CoModel::load(&reg, &args.str("model", "blip2ish")) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("error: {e:#}");
            return 1;
        }
    };
    for (side, store) in [("agent", &model.agent_weights), ("server", &model.server_weights)] {
        let fit = ExponentialModel::fit_weights(&store.blob);
        let mags: Vec<f64> = store.blob.iter().map(|w| w.abs() as f64).collect();
        println!(
            "{side:6} n={:8}  λ(manifest)={:8.2}  λ(rust fit)={:8.2}  h(Θ)={:6.2} bits  KS={:.4}",
            store.n_params(),
            store.lambda,
            fit.lambda,
            fit.differential_entropy_bits(),
            fit.ks_statistic(&mags)
        );
    }
    0
}
