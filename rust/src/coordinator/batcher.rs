//! Dynamic batcher: groups routed requests that share an operating point
//! (same bit-width ⇒ same quantized weights ⇒ one PJRT call) under a size
//! cap and a waiting deadline.

use super::router::RoutedRequest;
use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Batch {
    pub b_hat: u32,
    pub requests: Vec<RoutedRequest>,
    /// arrival time of the oldest member
    pub oldest_arrival_s: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// flush when a group reaches this size
    pub max_batch: usize,
    /// flush a group once its oldest member waited this long
    pub max_wait_s: f64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4, max_wait_s: 0.05 }
    }
}

/// Size/deadline batcher keyed by bit-width.
pub struct Batcher {
    cfg: BatcherConfig,
    groups: HashMap<u32, Batch>,
    pub accepted: u64,
    pub flushed: u64,
}

impl Batcher {
    pub fn new(cfg: BatcherConfig) -> Batcher {
        Batcher { cfg, groups: HashMap::new(), accepted: 0, flushed: 0 }
    }

    /// Add a request; returns a batch if the group filled up.
    pub fn push(&mut self, req: RoutedRequest) -> Option<Batch> {
        self.accepted += 1;
        let key = req.plan.design.b_hat;
        let group = self.groups.entry(key).or_insert_with(|| Batch {
            b_hat: key,
            requests: Vec::new(),
            oldest_arrival_s: req.request.arrival_s,
        });
        group.oldest_arrival_s = group.oldest_arrival_s.min(req.request.arrival_s);
        group.requests.push(req);
        if group.requests.len() >= self.cfg.max_batch {
            self.flushed += 1;
            return self.groups.remove(&key);
        }
        None
    }

    /// Flush groups whose oldest member exceeded the wait deadline at
    /// (virtual or wall) time `now_s`.
    pub fn poll_deadlines(&mut self, now_s: f64) -> Vec<Batch> {
        let due: Vec<u32> = self
            .groups
            .iter()
            .filter(|(_, g)| now_s - g.oldest_arrival_s >= self.cfg.max_wait_s)
            .map(|(k, _)| *k)
            .collect();
        due.iter()
            .map(|k| {
                self.flushed += 1;
                self.groups.remove(k).expect("key present")
            })
            .collect()
    }

    /// Flush everything (end of stream).
    pub fn drain(&mut self) -> Vec<Batch> {
        let keys: Vec<u32> = self.groups.keys().copied().collect();
        keys.iter()
            .map(|k| {
                self.flushed += 1;
                self.groups.remove(k).expect("key present")
            })
            .collect()
    }

    pub fn pending(&self) -> usize {
        self.groups.values().map(|g| g.requests.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::{Algorithm, Scheduler};
    use crate::coordinator::router::{QosPolicy, Router};
    use crate::data::workload::{generate, Arrival};
    use crate::quant::Scheme;
    use crate::system::Platform;
    use crate::util::prop::forall;

    fn routed(n: usize, seed: u64) -> Vec<RoutedRequest> {
        let mut router = Router::new(
            QosPolicy::paper_default(),
            Scheduler::new(Platform::paper_blip2(), 15.0, Algorithm::Exact, Scheme::Uniform, 1),
        );
        generate(n, 16, Arrival::Poisson { lambda_rps: 100.0 }, seed)
            .into_iter()
            .filter_map(|r| router.route(r).ok())
            .collect()
    }

    #[test]
    fn conservation_no_request_lost_or_duplicated() {
        forall(
            "batcher conserves requests",
            20,
            |r| (10 + r.below(100), r.next_u64()),
            |&(n, seed)| {
                let reqs = routed(n, seed);
                let total = reqs.len();
                let mut b = Batcher::new(BatcherConfig { max_batch: 4, max_wait_s: 0.01 });
                let mut seen = Vec::new();
                for rr in reqs {
                    let now = rr.request.arrival_s;
                    if let Some(batch) = b.push(rr) {
                        seen.extend(batch.requests.iter().map(|r| r.request.id));
                    }
                    for batch in b.poll_deadlines(now) {
                        seen.extend(batch.requests.iter().map(|r| r.request.id));
                    }
                }
                for batch in b.drain() {
                    seen.extend(batch.requests.iter().map(|r| r.request.id));
                }
                seen.sort();
                let mut dedup = seen.clone();
                dedup.dedup();
                if seen.len() == total && dedup.len() == total {
                    Ok(())
                } else {
                    Err(format!("{} in, {} out ({} unique)", total, seen.len(), dedup.len()))
                }
            },
        );
    }

    #[test]
    fn batches_are_bitwidth_homogeneous() {
        let reqs = routed(120, 5);
        let mut b = Batcher::new(BatcherConfig::default());
        let mut batches = Vec::new();
        for rr in reqs {
            if let Some(batch) = b.push(rr) {
                batches.push(batch);
            }
        }
        batches.extend(b.drain());
        for batch in &batches {
            assert!(batch
                .requests
                .iter()
                .all(|r| r.plan.design.b_hat == batch.b_hat));
        }
    }

    #[test]
    fn size_cap_is_respected() {
        let reqs = routed(64, 9);
        let mut b = Batcher::new(BatcherConfig { max_batch: 4, max_wait_s: 1e9 });
        for rr in reqs {
            if let Some(batch) = b.push(rr) {
                assert!(batch.requests.len() <= 4);
            }
        }
    }

    #[test]
    fn deadline_flushes_partial_batches() {
        let reqs = routed(2, 11);
        let mut b = Batcher::new(BatcherConfig { max_batch: 64, max_wait_s: 0.1 });
        for rr in reqs {
            assert!(b.push(rr).is_none());
        }
        assert_eq!(b.pending(), 2);
        let flushed = b.poll_deadlines(1e9);
        assert!(!flushed.is_empty());
        assert_eq!(b.pending(), 0);
    }
}
