//! Deterministic single-thread co-inference engine.
//!
//! Drives the full request path — route → batch → quantized agent encode →
//! simulated WLAN uplink → server decode → detokenize — over a workload,
//! producing [`Telemetry`]. This is the engine every figure/table bench
//! uses; the threaded [`super::server`] wraps the same pieces for
//! throughput experiments.

use super::batcher::{Batch, Batcher, BatcherConfig};
use super::router::Router;
use super::telemetry::{RequestRecord, Telemetry};
use crate::data::eval::EvalSet;
use crate::data::vocab::Vocab;
use crate::data::workload::Request;
use crate::runtime::executor::CoModel;
use crate::system::channel::Channel;
use crate::system::{delay, energy};
use crate::util::timer::Stopwatch;
use anyhow::Result;

pub struct EngineConfig {
    pub batcher: BatcherConfig,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { batcher: BatcherConfig::default() }
    }
}

pub struct Engine<'a> {
    pub model: &'a mut CoModel,
    pub router: Router,
    pub vocab: &'a Vocab,
    pub eval: &'a EvalSet,
    pub channel: Channel,
    cfg: EngineConfig,
}

impl<'a> Engine<'a> {
    pub fn new(
        model: &'a mut CoModel,
        router: Router,
        vocab: &'a Vocab,
        eval: &'a EvalSet,
        channel: Channel,
        cfg: EngineConfig,
    ) -> Engine<'a> {
        Engine { model, router, vocab, eval, channel, cfg }
    }

    /// Run a closed-loop workload to completion.
    pub fn run(&mut self, requests: Vec<Request>) -> Result<Telemetry> {
        let mut telemetry = Telemetry::default();
        let mut batcher = Batcher::new(self.cfg.batcher);
        for req in requests {
            let now = req.request_time();
            match self.router.route(req) {
                Ok(routed) => {
                    if let Some(batch) = batcher.push(routed) {
                        self.execute_batch(batch, &mut telemetry)?;
                    }
                    for batch in batcher.poll_deadlines(now) {
                        self.execute_batch(batch, &mut telemetry)?;
                    }
                }
                Err(_) => telemetry.rejected += 1,
            }
        }
        for batch in batcher.drain() {
            self.execute_batch(batch, &mut telemetry)?;
        }
        Ok(telemetry)
    }

    fn execute_batch(&mut self, batch: Batch, telemetry: &mut Telemetry) -> Result<()> {
        let n = batch.requests.len();
        let in_len = self.model.dims.input_len();
        let mut inputs = Vec::with_capacity(n * in_len);
        for rr in &batch.requests {
            inputs.extend_from_slice(self.eval.sample(rr.request.sample));
        }
        let plan = batch.requests[0].plan;
        let scheme = plan.scheme;
        let sw = Stopwatch::start();
        // agent stage with quantized encoder weights
        let embs = self.model.encode(&inputs, n, batch.b_hat, scheme)?;
        // uplink: one transfer per request's embedding
        let emb_bytes =
            Channel::embedding_bytes(self.model.dims.emb_tokens, self.model.dims.d_model);
        let link_times: Vec<f64> = (0..n).map(|_| self.channel.transmit_s(emb_bytes)).collect();
        // edge stage
        let tokens = self.model.decode(&embs, n)?;
        let wall = sw.elapsed_s() / n as f64;

        let platform = &self.router.scheduler.platform;
        for (i, rr) in batch.requests.into_iter().enumerate() {
            let b = rr.plan.design.b_hat as f64;
            let (f, ft) = (rr.plan.f_realized, rr.plan.f_tilde_realized);
            telemetry.push(RequestRecord {
                id: rr.request.id,
                class: rr.request.class,
                sample: rr.request.sample,
                b_hat: rr.plan.design.b_hat,
                t_agent_sim_s: delay::agent_delay(platform, b, f),
                t_server_sim_s: delay::server_delay(platform, ft),
                t_link_s: link_times[i],
                energy_sim_j: energy::total_energy(platform, b, f, ft),
                t_wall_s: wall,
                caption: self.vocab.detokenize(&tokens[i]),
                t0: rr.t0,
                e0: rr.e0,
            });
        }
        Ok(())
    }
}

/// Small extension used by the engine loop.
trait ArrivalTime {
    fn request_time(&self) -> f64;
}

impl ArrivalTime for Request {
    fn request_time(&self) -> f64 {
        self.arrival_s
    }
}
