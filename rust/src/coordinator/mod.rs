//! The co-inference coordinator: the serving system around the paper's
//! joint design (Fig. 1).
//!
//! Request path: [`router`] assigns each request its QoS class and the
//! class's planned operating point (bit-width + frequencies, from
//! [`scheduler`]); [`batcher`] groups compatible requests (same bit-width)
//! into bounded-delay batches; the agent stage ([`engine`]) runs the
//! quantized encoder, the simulated WLAN [`crate::system::channel`]
//! carries the embedding, the edge stage decodes, and [`telemetry`]
//! aggregates per-request delay/energy/quality.
//!
//! Two drivers share those pieces:
//! * [`engine::Engine`] — deterministic single-thread engine (benches).
//! * [`server::PipelinedServer`] — threaded pipeline (agent stage thread +
//!   edge stage thread) exercising backpressure; PJRT state is built
//!   thread-locally because XLA handles are not `Send`.
//!
//! The fleet layer ([`crate::fleet`]) instantiates one router + batcher +
//! scheduler per agent, with each scheduler made **contention-aware** by
//! building it on the agent's slice of the shared resources (the
//! share-scaled platform from [`crate::opt::fleet`] and a link-reduced
//! delay budget); the scheduler's plan cache is keyed on every
//! plan-relevant field, so mutating `algorithm`/`scheme`/`lambda`/
//! governors between plans re-plans instead of serving stale designs.

pub mod batcher;
pub mod engine;
pub mod router;
pub mod scheduler;
pub mod server;
pub mod telemetry;

pub use engine::{Engine, EngineConfig};
pub use router::{QosPolicy, Router};
pub use scheduler::{Algorithm, Scheduler};
pub use telemetry::{RequestRecord, Telemetry};
