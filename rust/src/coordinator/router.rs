//! Request routing: QoS classes -> budgets -> planned operating points.

use super::scheduler::{Plan, Scheduler};
use crate::data::workload::Request;
use std::collections::HashMap;

/// (T0, E0) budgets per QoS class.
#[derive(Debug, Clone)]
pub struct QosPolicy {
    budgets: HashMap<&'static str, (f64, f64)>,
}

impl QosPolicy {
    pub fn new(entries: &[(&'static str, f64, f64)]) -> QosPolicy {
        QosPolicy {
            budgets: entries.iter().map(|(c, t, e)| (*c, (*t, *e))).collect(),
        }
    }

    /// Default classes matching the workload generator: interactive is
    /// delay-tight, background is energy-tight, standard in between.
    /// Budgets are in the paper's Fig. 5 bands.
    pub fn paper_default() -> QosPolicy {
        QosPolicy::new(&[
            ("interactive", 2.50, 2.50),
            ("standard", 3.50, 2.00),
            ("background", 5.00, 1.00),
        ])
    }

    /// A uniform single-budget policy (figure sweeps).
    pub fn uniform(t0: f64, e0: f64) -> QosPolicy {
        QosPolicy::new(&[("interactive", t0, e0), ("standard", t0, e0), ("background", t0, e0)])
    }

    pub fn budget(&self, class: &str) -> Option<(f64, f64)> {
        self.budgets.get(class).copied()
    }

    pub fn classes(&self) -> Vec<&'static str> {
        let mut c: Vec<&'static str> = self.budgets.keys().copied().collect();
        c.sort();
        c
    }
}

/// A request annotated with its plan, ready for batching.
#[derive(Debug, Clone)]
pub struct RoutedRequest {
    pub request: Request,
    pub plan: Plan,
    pub t0: f64,
    pub e0: f64,
}

/// Routing outcome for requests whose class cannot meet its budget.
#[derive(Debug, Clone, PartialEq)]
pub enum RouteError {
    UnknownClass(String),
    Infeasible { class: String },
}

pub struct Router {
    pub policy: QosPolicy,
    pub scheduler: Scheduler,
    /// count of rejected requests per reason (observability)
    pub rejected_unknown: u64,
    pub rejected_infeasible: u64,
}

impl Router {
    pub fn new(policy: QosPolicy, scheduler: Scheduler) -> Router {
        Router { policy, scheduler, rejected_unknown: 0, rejected_infeasible: 0 }
    }

    pub fn route(&mut self, request: Request) -> Result<RoutedRequest, RouteError> {
        let Some((t0, e0)) = self.policy.budget(request.class) else {
            self.rejected_unknown += 1;
            return Err(RouteError::UnknownClass(request.class.to_string()));
        };
        match self.scheduler.plan(t0, e0) {
            Some(plan) => Ok(RoutedRequest { request, plan, t0, e0 }),
            None => {
                self.rejected_infeasible += 1;
                Err(RouteError::Infeasible { class: request.class.to_string() })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scheduler::Algorithm;
    use crate::data::workload::{generate, Arrival};
    use crate::quant::Scheme;
    use crate::system::Platform;

    fn router() -> Router {
        Router::new(
            QosPolicy::paper_default(),
            Scheduler::new(Platform::paper_blip2(), 15.0, Algorithm::Exact, Scheme::Uniform, 3),
        )
    }

    #[test]
    fn routes_all_default_classes() {
        let mut r = router();
        let reqs = generate(30, 8, Arrival::Batch, 1);
        for req in reqs {
            let routed = r.route(req).expect("routable");
            assert!(routed.plan.feasible);
            assert!(routed.plan.design.b_hat >= 1);
        }
        assert_eq!(r.rejected_infeasible, 0);
        // 3 classes -> at most 3 scheduler cache entries
        assert!(r.scheduler.cache_len() <= 3);
    }

    #[test]
    fn interactive_gets_lower_or_equal_bits_than_background() {
        // delay-tight class must sacrifice precision (or match)
        let mut r = router();
        let mk = |class| Request { id: 0, sample: 0, arrival_s: 0.0, class };
        let i = r.route(mk("interactive")).unwrap().plan.design.b_hat;
        let b = r.route(mk("background")).unwrap().plan.design.b_hat;
        // background has a much tighter energy budget: relationship is
        // platform-dependent, but both must be valid bitwidths
        assert!(i >= 1 && b >= 1);
    }

    #[test]
    fn unknown_class_is_rejected() {
        let mut r = router();
        let req = Request { id: 0, sample: 0, arrival_s: 0.0, class: "bogus" };
        assert!(matches!(r.route(req), Err(RouteError::UnknownClass(_))));
        assert_eq!(r.rejected_unknown, 1);
    }

    #[test]
    fn infeasible_budget_is_rejected() {
        let mut r = Router::new(
            QosPolicy::uniform(1e-9, 1e-12),
            Scheduler::new(Platform::paper_blip2(), 15.0, Algorithm::Exact, Scheme::Uniform, 3),
        );
        let req = Request { id: 0, sample: 0, arrival_s: 0.0, class: "standard" };
        assert!(matches!(r.route(req), Err(RouteError::Infeasible { .. })));
    }
}
