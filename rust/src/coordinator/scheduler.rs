//! Per-class operating-point planning: applies the paper's joint design
//! (or a baseline) to each QoS class's (T0, E0) budget and caches the
//! result until budgets or platform change.

use crate::opt::{bisection, feasible_random, fixed_freq, sca, Design, Problem};
use crate::quant::Scheme;
use crate::rl::{env::BudgetRanges, DesignEnv, Ppo, PpoConfig};
use crate::system::dvfs::Governor;
use crate::system::Platform;
use crate::util::cli::ParseError;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// Which design algorithm drives the scheduler.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// paper's proposed joint design (SCA Algorithm 1)
    Proposed,
    /// exact reference (monotone bisection) — identical results, faster
    Exact,
    /// DRL baseline [12]
    Ppo,
    /// benchmark scheme 2
    FixedFreq,
    /// benchmark scheme 3
    FeasibleRandom,
}

impl Algorithm {
    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Proposed => "proposed",
            Algorithm::Exact => "exact",
            Algorithm::Ppo => "ppo",
            Algorithm::FixedFreq => "fixed-freq",
            Algorithm::FeasibleRandom => "feasible-random",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<Algorithm, ParseError> {
        match s {
            "proposed" | "sca" => Ok(Algorithm::Proposed),
            "exact" | "bisection" => Ok(Algorithm::Exact),
            "ppo" | "drl" => Ok(Algorithm::Ppo),
            "fixed-freq" | "fixed" => Ok(Algorithm::FixedFreq),
            "feasible-random" | "random" => Ok(Algorithm::FeasibleRandom),
            _ => Err(ParseError::new(
                "design algorithm",
                s,
                &["proposed", "exact", "ppo", "fixed-freq", "feasible-random"],
            )),
        }
    }
}

/// A planned operating point for one QoS class.
#[derive(Debug, Clone, Copy)]
pub struct Plan {
    pub design: Design,
    /// frequencies after DVFS realization (== design on continuous govs)
    pub f_realized: f64,
    pub f_tilde_realized: f64,
    pub scheme: Scheme,
    pub feasible: bool,
}

pub struct Scheduler {
    pub platform: Platform,
    pub lambda: f64,
    pub algorithm: Algorithm,
    pub scheme: Scheme,
    pub device_gov: Governor,
    pub server_gov: Governor,
    ppo: Option<Ppo>,
    rng: Rng,
    cache: HashMap<(u64, u64), Plan>,
    /// fingerprint of every plan-relevant field at the time the cache was
    /// filled; a mismatch on `plan()` clears stale entries (the fields are
    /// `pub`, so callers can mutate them between plans)
    config_stamp: u64,
}

fn budget_key(t0: f64, e0: f64) -> (u64, u64) {
    (t0.to_bits(), e0.to_bits())
}

/// Fully discrete testbed planning: device pinned at `f_dev`, server
/// restricted to its governor's operating points. Largest feasible b̂,
/// cheapest (slowest) server point within it.
fn plan_discrete(problem: &Problem, f_dev: f64, server_points: &[f64]) -> Option<Design> {
    let p = &problem.platform;
    let c2 = p.server_cycles();
    for b_hat in (1..=p.b_max).rev() {
        let c1 = p.agent_cycles(b_hat as f64);
        let t1 = c1 / f_dev;
        let e1 = p.device.pue * p.device.psi * c1 * f_dev * f_dev;
        if t1 > problem.t0 || e1 > problem.e0 {
            continue;
        }
        // ascending server points: the first that meets the delay budget
        // is the energy-cheapest realizable choice
        for &f_tilde in server_points {
            let t2 = c2 / f_tilde;
            let e2 = p.server.pue * p.server.psi * c2 * f_tilde * f_tilde;
            if t1 + t2 <= problem.t0 && e1 + e2 <= problem.e0 {
                return Some(Design { b_hat, f: f_dev, f_tilde });
            }
            if e1 + e2 > problem.e0 {
                break; // faster points only cost more energy
            }
        }
    }
    None
}

fn hash_f64<H: Hasher>(x: f64, h: &mut H) {
    x.to_bits().hash(h);
}

fn hash_governor<H: Hasher>(g: &Governor, h: &mut H) {
    match g {
        Governor::Continuous { f_max } => {
            0u8.hash(h);
            hash_f64(*f_max, h);
        }
        Governor::Profiles { points } => {
            1u8.hash(h);
            points.len().hash(h);
            for p in points {
                hash_f64(*p, h);
            }
        }
    }
}

impl Scheduler {
    pub fn new(
        platform: Platform,
        lambda: f64,
        algorithm: Algorithm,
        scheme: Scheme,
        seed: u64,
    ) -> Scheduler {
        let mut s = Scheduler {
            device_gov: Governor::Continuous { f_max: platform.device.f_max },
            server_gov: Governor::Continuous { f_max: platform.server.f_max },
            platform,
            lambda,
            algorithm,
            scheme,
            ppo: None,
            rng: Rng::new(seed),
            cache: HashMap::new(),
            config_stamp: 0,
        };
        s.config_stamp = s.config_fingerprint();
        s
    }

    /// Everything a cached [`Plan`] depends on besides the (T0, E0) key.
    fn config_fingerprint(&self) -> u64 {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        self.algorithm.hash(&mut h);
        self.scheme.hash(&mut h);
        hash_f64(self.lambda, &mut h);
        let p = &self.platform;
        for x in [
            p.device.f_max,
            p.device.flops_per_cycle,
            p.device.pue,
            p.device.psi,
            p.server.f_max,
            p.server.flops_per_cycle,
            p.server.pue,
            p.server.psi,
            p.n_flop_agent,
            p.n_flop_server,
            p.full_bits,
        ] {
            hash_f64(x, &mut h);
        }
        p.b_max.hash(&mut h);
        hash_governor(&self.device_gov, &mut h);
        hash_governor(&self.server_gov, &mut h);
        h.finish()
    }

    /// Switch to coarse testbed governors (Table I mode).
    pub fn with_governors(mut self, device: Governor, server: Governor) -> Scheduler {
        self.device_gov = device;
        self.server_gov = server;
        self.cache.clear();
        self
    }

    /// Train the PPO policy (required before using Algorithm::Ppo).
    /// Replacing the policy invalidates any plans it produced — the policy
    /// lives outside the config fingerprint, so clear explicitly.
    pub fn train_ppo(&mut self, ranges: BudgetRanges, cfg: PpoConfig) {
        let env = DesignEnv::new(self.platform, self.lambda, ranges);
        let mut rng = self.rng.fork(0x99);
        let mut ppo = Ppo::new(env, cfg, &mut rng);
        ppo.train(&mut rng);
        self.ppo = Some(ppo);
        self.cache.clear();
    }

    /// Plan (and cache) the operating point for a (T0, E0) budget.
    pub fn plan(&mut self, t0: f64, e0: f64) -> Option<Plan> {
        // drop stale plans if any plan-relevant field changed since the
        // cache was filled (algorithm, scheme, lambda, governors, platform)
        let stamp = self.config_fingerprint();
        if stamp != self.config_stamp {
            self.cache.clear();
            self.config_stamp = stamp;
        }
        let key = budget_key(t0, e0);
        if let Some(p) = self.cache.get(&key) {
            return Some(*p);
        }
        let problem = Problem::new(self.platform, self.lambda, t0, e0);
        // testbed mode: a single-point device governor pins the device
        // frequency — the continuous planners would pick unrealizable
        // (lower) frequencies, so plan against the actual operating points
        if let Governor::Profiles { points } = &self.device_gov {
            if points.len() == 1 {
                let f_dev = points[0];
                let design = match &self.server_gov {
                    Governor::Profiles { points: srv } => {
                        plan_discrete(&problem, f_dev, srv)
                    }
                    Governor::Continuous { .. } => problem.plan_pinned_device(f_dev),
                }?;
                let plan = Plan {
                    design,
                    f_realized: f_dev,
                    f_tilde_realized: design.f_tilde,
                    scheme: self.scheme,
                    feasible: problem.is_feasible(&design),
                };
                self.cache.insert(key, plan);
                return Some(plan);
            }
        }
        let design = match self.algorithm {
            Algorithm::Proposed => {
                sca::solve(&problem, sca::ScaOptions::default()).map(|r| r.design)
            }
            Algorithm::Exact => bisection::solve(&problem).map(|r| r.design),
            Algorithm::FixedFreq => fixed_freq::solve(&problem),
            Algorithm::FeasibleRandom => {
                feasible_random::solve(&problem, self.rng.next_u64())
            }
            Algorithm::Ppo => {
                let ppo = self.ppo.as_ref().expect("call train_ppo first");
                ppo.solve_projected(&problem)
            }
        }?;
        // realize frequencies on the actual governors (testbed: snap up to
        // the next profile, which preserves the delay budget)
        let plan = Plan {
            design,
            f_realized: self.device_gov.realize(design.f),
            f_tilde_realized: self.server_gov.realize(design.f_tilde),
            scheme: self.scheme,
            feasible: problem.is_feasible(&design),
        };
        self.cache.insert(key, plan);
        Some(plan)
    }

    pub fn cache_len(&self) -> usize {
        self.cache.len()
    }

    pub fn invalidate(&mut self) {
        self.cache.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sched(alg: Algorithm) -> Scheduler {
        Scheduler::new(Platform::paper_blip2(), 15.0, alg, Scheme::Uniform, 7)
    }

    #[test]
    fn proposed_plans_are_feasible_and_cached() {
        let mut s = sched(Algorithm::Proposed);
        let p1 = s.plan(3.5, 2.0).unwrap();
        assert!(p1.feasible);
        assert_eq!(s.cache_len(), 1);
        let p2 = s.plan(3.5, 2.0).unwrap();
        assert_eq!(p1.design.b_hat, p2.design.b_hat);
        assert_eq!(s.cache_len(), 1);
    }

    #[test]
    fn exact_matches_proposed_bitwidth_closely() {
        let mut a = sched(Algorithm::Proposed);
        let mut b = sched(Algorithm::Exact);
        for (t0, e0) in [(3.5, 2.0), (2.5, 1.0), (4.0, 3.0)] {
            let pa = a.plan(t0, e0).unwrap().design.b_hat as i64;
            let pb = b.plan(t0, e0).unwrap().design.b_hat as i64;
            assert!((pa - pb).abs() <= 1, "({t0},{e0}): sca {pa} exact {pb}");
        }
    }

    #[test]
    fn governor_realization_snaps_up() {
        let mut s = sched(Algorithm::Exact).with_governors(
            Governor::jetson_profiles(),
            Governor::server_profiles(),
        );
        // clamp the platform to the governor's reality first
        s.platform.device.f_max = s.device_gov.f_max();
        s.platform.server.f_max = s.server_gov.f_max();
        s.invalidate();
        let p = s.plan(3.0, 4.0).unwrap();
        assert!(p.f_realized >= p.design.f.min(s.device_gov.f_max()));
        assert!(Governor::jetson_profiles()
            .profile_names()
            .iter()
            .any(|_| true));
    }

    #[test]
    fn infeasible_budget_returns_none() {
        let mut s = sched(Algorithm::Exact);
        assert!(s.plan(1e-9, 1e-12).is_none());
    }

    #[test]
    fn different_budgets_get_different_cache_slots() {
        let mut s = sched(Algorithm::Exact);
        s.plan(3.5, 2.0);
        s.plan(2.0, 2.0);
        assert_eq!(s.cache_len(), 2);
    }

    #[test]
    fn algorithm_change_invalidates_cached_plans() {
        // regression: the cache used to key only on (T0, E0), so mutating
        // `algorithm` after the first plan served stale designs
        let mut s = sched(Algorithm::Exact);
        let exact = s.plan(3.5, 2.0).unwrap();
        assert_eq!(s.cache_len(), 1);
        s.algorithm = Algorithm::FixedFreq;
        let fixed = s.plan(3.5, 2.0).unwrap();
        // fixed-freq pins the device at f^max; the exact design relaxes it
        assert_eq!(fixed.design.f, s.platform.device.f_max);
        assert_ne!(
            (exact.design.f, exact.design.f_tilde),
            (fixed.design.f, fixed.design.f_tilde),
            "stale plan served after algorithm change"
        );
        assert_eq!(s.cache_len(), 1, "stale entries must be dropped, not kept");
    }

    #[test]
    fn scheme_change_reaches_subsequent_plans() {
        let mut s = sched(Algorithm::Exact);
        assert_eq!(s.plan(3.5, 2.0).unwrap().scheme, Scheme::Uniform);
        s.scheme = Scheme::Pot;
        assert_eq!(s.plan(3.5, 2.0).unwrap().scheme, Scheme::Pot);
    }

    #[test]
    fn lambda_and_governor_changes_invalidate() {
        let mut s = sched(Algorithm::Exact);
        s.plan(3.5, 2.0).unwrap();
        s.plan(2.5, 2.5).unwrap();
        assert_eq!(s.cache_len(), 2);
        s.lambda = 40.0;
        s.plan(3.5, 2.0).unwrap();
        assert_eq!(s.cache_len(), 1, "lambda change must clear the cache");
        s.server_gov = Governor::server_profiles();
        s.plan(3.5, 2.0).unwrap();
        assert_eq!(s.cache_len(), 1, "governor change must clear the cache");
    }

    #[test]
    fn unchanged_config_keeps_cache_warm() {
        let mut s = sched(Algorithm::Proposed);
        let a = s.plan(3.5, 2.0).unwrap();
        let b = s.plan(3.5, 2.0).unwrap();
        assert_eq!(a.design.b_hat, b.design.b_hat);
        assert_eq!(s.cache_len(), 1);
    }
}
