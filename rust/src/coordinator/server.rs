//! Threaded pipelined server: agent stage and edge stage run on their own
//! threads connected by bounded channels (backpressure included), so the
//! encoder of batch k+1 overlaps the decoder of batch k — the serving
//! analogue of the paper's two-stage split.
//!
//! XLA/PJRT handles are not `Send`, so each stage thread opens its own
//! [`Registry`]/[`CoModel`]; only plain tensors cross threads.

use super::batcher::{Batcher, BatcherConfig};
use super::router::Router;
use super::telemetry::{RequestRecord, Telemetry};
use crate::data::eval::EvalSet;
use crate::data::vocab::Vocab;
use crate::data::workload::Request;
use crate::quant::Scheme;
use crate::runtime::artifact::Registry;
use crate::runtime::executor::CoModel;
use crate::system::channel::Channel;
use crate::system::{delay, energy, Platform};
use crate::util::pool::{bounded, Receiver, Sender};
use crate::util::timer::Stopwatch;
use anyhow::Result;
use std::path::PathBuf;

/// Work crossing the router -> agent boundary.
struct AgentJob {
    records: Vec<RequestRecord>,
    inputs: Vec<f32>,
    b_hat: u32,
    scheme: Scheme,
}

/// Work crossing the agent -> edge boundary.
struct EdgeJob {
    records: Vec<RequestRecord>,
    embs: Vec<f32>,
}

pub struct PipelinedServer {
    pub artifacts: PathBuf,
    pub model_name: String,
    pub router: Router,
    pub batcher_cfg: BatcherConfig,
    pub queue_depth: usize,
}

impl PipelinedServer {
    /// Run the workload through the 2-stage pipeline; blocks until done.
    pub fn run(&mut self, requests: Vec<Request>, eval: &EvalSet) -> Result<Telemetry> {
        let (tx_agent, rx_agent) = bounded::<AgentJob>(self.queue_depth);
        let (tx_edge, rx_edge) = bounded::<EdgeJob>(self.queue_depth);
        let (tx_done, rx_done) = bounded::<Vec<RequestRecord>>(self.queue_depth * 2);

        let platform = self.router.scheduler.platform;
        let agent = spawn_agent_stage(
            self.artifacts.clone(),
            self.model_name.clone(),
            rx_agent,
            tx_edge,
            platform,
        );
        let edge = spawn_edge_stage(
            self.artifacts.clone(),
            self.model_name.clone(),
            rx_edge,
            tx_done,
        );

        let mut telemetry = Telemetry::default();
        let mut batcher = Batcher::new(self.batcher_cfg);
        let submit = |batch: super::batcher::Batch, tx: &Sender<AgentJob>| -> Result<()> {
            let scheme = batch.requests[0].plan.scheme;
            let mut inputs = Vec::new();
            let mut records = Vec::with_capacity(batch.requests.len());
            for rr in &batch.requests {
                inputs.extend_from_slice(eval.sample(rr.request.sample));
                // simulated metrics are plan-determined and per-request:
                // classes share a batch (same b̂ ⇒ same weights) but keep
                // their own planned frequencies
                let b = rr.plan.design.b_hat as f64;
                records.push(RequestRecord {
                    id: rr.request.id,
                    class: rr.request.class,
                    sample: rr.request.sample,
                    b_hat: rr.plan.design.b_hat,
                    t_agent_sim_s: delay::agent_delay(&platform, b, rr.plan.f_realized),
                    t_server_sim_s: delay::server_delay(&platform, rr.plan.f_tilde_realized),
                    t_link_s: 0.0,
                    energy_sim_j: energy::total_energy(
                        &platform, b, rr.plan.f_realized, rr.plan.f_tilde_realized),
                    t_wall_s: 0.0,
                    caption: String::new(),
                    t0: rr.t0,
                    e0: rr.e0,
                });
            }
            tx.send(AgentJob {
                records,
                inputs,
                b_hat: batch.b_hat,
                scheme,
            })
            .map_err(|_| anyhow::anyhow!("agent stage died"))?;
            Ok(())
        };

        for req in requests {
            let now = req.arrival_s;
            match self.router.route(req) {
                Ok(routed) => {
                    if let Some(b) = batcher.push(routed) {
                        submit(b, &tx_agent)?;
                    }
                    for b in batcher.poll_deadlines(now) {
                        submit(b, &tx_agent)?;
                    }
                }
                Err(_) => telemetry.rejected += 1,
            }
        }
        for b in batcher.drain() {
            submit(b, &tx_agent)?;
        }
        drop(tx_agent); // close the pipeline head

        while let Some(records) = rx_done.recv() {
            for r in records {
                telemetry.push(r);
            }
        }
        agent.join().expect("agent stage")?;
        edge.join().expect("edge stage")?;
        Ok(telemetry)
    }
}

fn spawn_agent_stage(
    artifacts: PathBuf,
    model_name: String,
    rx: Receiver<AgentJob>,
    tx: Sender<EdgeJob>,
    _platform: Platform,
) -> std::thread::JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name("qaci-agent-stage".into())
        .spawn(move || -> Result<()> {
            let reg = Registry::open(&artifacts)?;
            let mut model = CoModel::load(&reg, &model_name)?;
            let mut channel = Channel::wlan_5ghz(0xA9E17);
            let emb_bytes = Channel::embedding_bytes(model.dims.emb_tokens, model.dims.d_model);
            while let Some(mut job) = rx.recv() {
                let n = job.records.len();
                let sw = Stopwatch::start();
                let embs = model.encode(&job.inputs, n, job.b_hat, job.scheme)?;
                let wall = sw.elapsed_s() / n as f64;
                for r in &mut job.records {
                    r.t_wall_s += wall;
                    r.t_link_s = channel.transmit_s(emb_bytes);
                }
                if tx.send(EdgeJob { records: job.records, embs }).is_err() {
                    break; // edge stage gone
                }
            }
            Ok(())
        })
        .expect("spawn agent stage")
}

fn spawn_edge_stage(
    artifacts: PathBuf,
    model_name: String,
    rx: Receiver<EdgeJob>,
    tx: Sender<Vec<RequestRecord>>,
) -> std::thread::JoinHandle<Result<()>> {
    std::thread::Builder::new()
        .name("qaci-edge-stage".into())
        .spawn(move || -> Result<()> {
            let reg = Registry::open(&artifacts)?;
            let mut model = CoModel::load(&reg, &model_name)?;
            let vocab = Vocab::from_manifest(&reg.manifest)?;
            while let Some(mut job) = rx.recv() {
                let n = job.records.len();
                let sw = Stopwatch::start();
                let tokens = model.decode(&job.embs, n)?;
                let wall = sw.elapsed_s() / n as f64;
                for (r, t) in job.records.iter_mut().zip(&tokens) {
                    r.t_wall_s += wall;
                    r.caption = vocab.detokenize(t);
                }
                if tx.send(job.records).is_err() {
                    break;
                }
            }
            Ok(())
        })
        .expect("spawn edge stage")
}
