//! Per-request records and aggregate rollups: the coordinator's metrics
//! pipeline (latency/energy/quality per QoS class).

use crate::metrics::cider::CiderScorer;
use crate::util::timer::Samples;
use std::collections::BTreeMap;

#[derive(Debug, Clone)]
pub struct RequestRecord {
    pub id: u64,
    pub class: &'static str,
    pub sample: usize,
    pub b_hat: u32,
    /// simulated delays from the paper's model (eq. 4/5) at the realized
    /// frequencies
    pub t_agent_sim_s: f64,
    pub t_server_sim_s: f64,
    /// simulated WLAN transfer time (excluded from the QoS constraint,
    /// reported separately)
    pub t_link_s: f64,
    /// simulated energy (eq. 9)
    pub energy_sim_j: f64,
    /// wall-clock time the PJRT stages actually took (batched, amortized)
    pub t_wall_s: f64,
    /// the caption this request produced
    pub caption: String,
    /// QoS budgets the plan was made against
    pub t0: f64,
    pub e0: f64,
}

impl RequestRecord {
    pub fn t_sim_total(&self) -> f64 {
        self.t_agent_sim_s + self.t_server_sim_s
    }

    pub fn meets_qos(&self) -> bool {
        self.t_sim_total() <= self.t0 * (1.0 + 1e-6)
            && self.energy_sim_j <= self.e0 * (1.0 + 1e-6)
    }
}

/// Aggregated view over a run.
#[derive(Debug, Default)]
pub struct Telemetry {
    pub records: Vec<RequestRecord>,
    pub rejected: u64,
}

#[derive(Debug, Clone)]
pub struct ClassSummary {
    pub class: String,
    pub count: usize,
    pub mean_bits: f64,
    pub sim_delay: Samples,
    pub sim_energy: Samples,
    pub wall: Samples,
    pub qos_violations: usize,
}

impl Telemetry {
    pub fn push(&mut self, r: RequestRecord) {
        self.records.push(r);
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Corpus CIDEr over all records (candidates ordered by eval sample).
    /// `refs[i]` are the references of eval sample i.
    pub fn cider_x100(&self, refs: &[Vec<String>]) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let scorer = CiderScorer::new(refs);
        let total: f64 = self
            .records
            .iter()
            .map(|r| scorer.score_one(r.sample, &r.caption))
            .sum();
        total / self.records.len() as f64 * 10.0
    }

    pub fn by_class(&self) -> BTreeMap<String, ClassSummary> {
        let mut out: BTreeMap<String, ClassSummary> = BTreeMap::new();
        for r in &self.records {
            let s = out.entry(r.class.to_string()).or_insert_with(|| ClassSummary {
                class: r.class.to_string(),
                count: 0,
                mean_bits: 0.0,
                sim_delay: Samples::new(),
                sim_energy: Samples::new(),
                wall: Samples::new(),
                qos_violations: 0,
            });
            s.count += 1;
            s.mean_bits += r.b_hat as f64;
            s.sim_delay.push(r.t_sim_total());
            s.sim_energy.push(r.energy_sim_j);
            s.wall.push(r.t_wall_s);
            if !r.meets_qos() {
                s.qos_violations += 1;
            }
        }
        for s in out.values_mut() {
            s.mean_bits /= s.count.max(1) as f64;
        }
        out
    }

    pub fn total_energy_j(&self) -> f64 {
        self.records.iter().map(|r| r.energy_sim_j).sum()
    }

    pub fn qos_violations(&self) -> usize {
        self.records.iter().filter(|r| !r.meets_qos()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: u64, class: &'static str, bits: u32, t: f64, e: f64) -> RequestRecord {
        RequestRecord {
            id,
            class,
            sample: 0,
            b_hat: bits,
            t_agent_sim_s: t * 0.6,
            t_server_sim_s: t * 0.4,
            t_link_s: 0.001,
            energy_sim_j: e,
            t_wall_s: 0.01,
            caption: "a red ball is left of a blue box".into(),
            t0: 3.5,
            e0: 2.0,
        }
    }

    #[test]
    fn qos_check() {
        assert!(rec(0, "standard", 8, 3.0, 1.5).meets_qos());
        assert!(!rec(0, "standard", 8, 4.0, 1.5).meets_qos());
        assert!(!rec(0, "standard", 8, 3.0, 2.5).meets_qos());
    }

    #[test]
    fn class_rollups() {
        let mut t = Telemetry::default();
        t.push(rec(0, "interactive", 4, 2.0, 1.0));
        t.push(rec(1, "interactive", 6, 2.5, 1.2));
        t.push(rec(2, "standard", 8, 3.0, 1.5));
        let by = t.by_class();
        assert_eq!(by.len(), 2);
        assert_eq!(by["interactive"].count, 2);
        assert!((by["interactive"].mean_bits - 5.0).abs() < 1e-12);
        assert_eq!(t.qos_violations(), 0);
    }

    #[test]
    fn cider_of_exact_captions_is_high() {
        let mut t = Telemetry::default();
        t.push(rec(0, "standard", 8, 1.0, 1.0));
        let refs = vec![vec![
            "a red ball is left of a blue box".to_string(),
            "the red ball sits left of the blue box".to_string(),
        ]];
        assert!(t.cider_x100(&refs) > 50.0);
    }
}
