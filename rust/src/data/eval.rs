//! Eval-set loading: the deterministic COCO-like / VaTeX-like splits that
//! `make artifacts` serialized (inputs as f32 LE blobs, references in the
//! manifest).

use crate::util::json::Json;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct EvalSet {
    /// flattened inputs; sample i occupies `[i*sample_len .. (i+1)*sample_len)`
    pub inputs: Vec<f32>,
    /// per-sample input length (product of non-batch dims)
    pub sample_len: usize,
    /// input shape per sample (without the leading batch dim)
    pub sample_shape: Vec<usize>,
    /// reference captions per sample
    pub refs: Vec<Vec<String>>,
}

impl EvalSet {
    /// `name` is "coco" or "vatex".
    pub fn load(artifacts: &Path, manifest: &Json, name: &str) -> anyhow::Result<EvalSet> {
        let entry = manifest
            .at(&["eval", name])
            .ok_or_else(|| anyhow::anyhow!("manifest missing eval.{name}"))?;
        let file = entry
            .get("inputs")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("eval.{name}.inputs missing"))?;
        let shape: Vec<usize> = entry
            .get("shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("eval.{name}.shape missing"))?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        anyhow::ensure!(shape.len() >= 2 && shape.iter().all(|&d| d > 0));
        let n = shape[0];
        let sample_shape = shape[1..].to_vec();
        let sample_len: usize = sample_shape.iter().product();

        let bytes = std::fs::read(artifacts.join(file))?;
        anyhow::ensure!(
            bytes.len() == n * sample_len * 4,
            "eval blob {} has {} bytes, expected {}",
            file,
            bytes.len(),
            n * sample_len * 4
        );
        let inputs: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();

        let refs: Vec<Vec<String>> = entry
            .get("refs")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("eval.{name}.refs missing"))?
            .iter()
            .map(|rs| {
                rs.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|r| r.as_str().map(str::to_string))
                    .collect()
            })
            .collect();
        anyhow::ensure!(refs.len() == n, "refs {} != inputs {}", refs.len(), n);
        Ok(EvalSet { inputs, sample_len, sample_shape, refs })
    }

    pub fn len(&self) -> usize {
        self.refs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.refs.is_empty()
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        &self.inputs[i * self.sample_len..(i + 1) * self.sample_len]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::parse;

    #[test]
    fn load_roundtrip(){
        let dir = std::env::temp_dir().join(format!("qaci-eval-test-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let data: Vec<f32> = (0..2 * 6).map(|i| i as f32).collect();
        let bytes: Vec<u8> = data.iter().flat_map(|f| f.to_le_bytes()).collect();
        std::fs::write(dir.join("x.bin"), &bytes).unwrap();
        let man = parse(
            r#"{"eval":{"coco":{"inputs":"x.bin","shape":[2,3,2],
                 "refs":[["a b","c"],["d e","f"]]}}}"#,
        )
        .unwrap();
        let ev = EvalSet::load(&dir, &man, "coco").unwrap();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev.sample_len, 6);
        assert_eq!(ev.sample(1), &[6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        assert_eq!(ev.refs[0][1], "c");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn size_mismatch_rejected() {
        let dir = std::env::temp_dir().join(format!("qaci-eval-test2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("x.bin"), [0u8; 8]).unwrap();
        let man = parse(
            r#"{"eval":{"coco":{"inputs":"x.bin","shape":[2,3],"refs":[[],[]]}}}"#,
        )
        .unwrap();
        assert!(EvalSet::load(&dir, &man, "coco").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
