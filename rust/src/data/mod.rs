//! Evaluation data plumbing: the shared vocabulary/tokenizer (mirroring the
//! build-time python side), eval-set loading from artifacts, and synthetic
//! request workloads for the coordinator benches.

pub mod eval;
pub mod vocab;
pub mod workload;
