//! Word-level vocabulary shared with the build-time tokenizer.
//!
//! The manifest ships the exact vocab list python trained with; this module
//! is the runtime mirror: ids -> words for decoding server outputs, words
//! -> ids for tests and tooling. Special ids match python/compile/datagen.

use crate::util::json::Json;
use std::collections::HashMap;

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const UNK: i32 = 3;

#[derive(Debug, Clone)]
pub struct Vocab {
    words: Vec<String>,
    index: HashMap<String, i32>,
}

impl Vocab {
    pub fn new(words: Vec<String>) -> Vocab {
        let index = words
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Vocab { words, index }
    }

    /// Build from the manifest's `"vocab"` array.
    pub fn from_manifest(manifest: &Json) -> anyhow::Result<Vocab> {
        let arr = manifest
            .get("vocab")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing vocab"))?;
        let words = arr
            .iter()
            .map(|w| {
                w.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow::anyhow!("non-string vocab entry"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        anyhow::ensure!(words.len() >= 4, "vocab too small");
        anyhow::ensure!(words[PAD as usize] == "<pad>", "vocab[0] != <pad>");
        Ok(Vocab::new(words))
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    pub fn word(&self, id: i32) -> &str {
        self.words
            .get(id as usize)
            .map(String::as_str)
            .unwrap_or("<unk>")
    }

    pub fn id(&self, word: &str) -> i32 {
        self.index.get(word).copied().unwrap_or(UNK)
    }

    /// Decode token ids to a caption: stop at EOS, skip PAD/BOS.
    /// Mirrors python `datagen.detokenize`.
    pub fn detokenize(&self, ids: &[i32]) -> String {
        let mut out: Vec<&str> = Vec::new();
        for &t in ids {
            if t == EOS {
                break;
            }
            if t == PAD || t == BOS {
                continue;
            }
            out.push(self.word(t));
        }
        out.join(" ")
    }

    /// Encode a caption: BOS + word ids + EOS, padded to max_len.
    /// Mirrors python `datagen.tokenize`.
    pub fn tokenize(&self, caption: &str, max_len: usize) -> Vec<i32> {
        let mut ids = vec![BOS];
        ids.extend(caption.split_whitespace().map(|w| self.id(w)));
        ids.push(EOS);
        assert!(ids.len() <= max_len, "caption too long: {caption}");
        ids.resize(max_len, PAD);
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn vocab() -> Vocab {
        Vocab::new(
            ["<pad>", "<bos>", "<eos>", "<unk>", "a", "red", "ball"]
                .iter()
                .map(|s| s.to_string())
                .collect(),
        )
    }

    #[test]
    fn tokenize_detokenize_roundtrip() {
        let v = vocab();
        let ids = v.tokenize("a red ball", 8);
        assert_eq!(ids, vec![BOS, 4, 5, 6, EOS, PAD, PAD, PAD]);
        assert_eq!(v.detokenize(&ids), "a red ball");
    }

    #[test]
    fn unknown_words_map_to_unk() {
        let v = vocab();
        assert_eq!(v.id("zebra"), UNK);
        let ids = v.tokenize("a zebra", 6);
        assert_eq!(v.detokenize(&ids), "a <unk>");
    }

    #[test]
    fn detokenize_ignores_out_of_range() {
        let v = vocab();
        assert_eq!(v.detokenize(&[BOS, 4, 99, EOS]), "a <unk>");
    }

    #[test]
    fn from_manifest_validates_specials() {
        let j = crate::util::json::parse(r#"{"vocab":["<pad>","<bos>","<eos>","<unk>","x"]}"#)
            .unwrap();
        let v = Vocab::from_manifest(&j).unwrap();
        assert_eq!(v.len(), 5);
        let bad = crate::util::json::parse(r#"{"vocab":["a","b","c","d"]}"#).unwrap();
        assert!(Vocab::from_manifest(&bad).is_err());
    }
}
