//! Synthetic request workloads for the coordinator: Poisson and bursty
//! (on/off) arrival processes over the eval set, plus per-class QoS tags.
//!
//! The paper's testbed issues captioning requests one at a time; the
//! serving benches also exercise batched regimes, so the generator covers
//! open-loop arrivals with configurable intensity.

use crate::util::rng::Rng;

/// One inference request: which eval sample to run and its QoS class.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    /// index into the eval set
    pub sample: usize,
    /// arrival time, seconds from epoch start
    pub arrival_s: f64,
    /// QoS class name (maps to (T0, E0) budgets in the scheduler)
    pub class: &'static str,
}

/// QoS classes used across benches: interactive (tight T0), standard,
/// background (tight E0).
pub const CLASSES: [&str; 3] = ["interactive", "standard", "background"];

#[derive(Debug, Clone, Copy)]
pub enum Arrival {
    /// Poisson with rate `lambda_rps` requests/second.
    Poisson { lambda_rps: f64 },
    /// On/off bursts: `burst` back-to-back requests, then `idle_s` silence.
    Bursty { burst: usize, idle_s: f64 },
    /// Closed-loop: all requests available at t=0 (offline batch job).
    Batch,
}

/// Generate `n` requests over `n_samples` eval items.
pub fn generate(n: usize, n_samples: usize, arrival: Arrival, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for id in 0..n {
        match arrival {
            Arrival::Poisson { lambda_rps } => {
                t += rng.exponential(lambda_rps.max(1e-9));
            }
            Arrival::Bursty { burst, idle_s } => {
                if id > 0 && id % burst.max(1) == 0 {
                    t += idle_s;
                }
            }
            Arrival::Batch => {}
        }
        out.push(Request {
            id: id as u64,
            sample: rng.below(n_samples.max(1)),
            arrival_s: t,
            class: CLASSES[rng.below(CLASSES.len())],
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_interarrival_mean() {
        let reqs = generate(20_000, 10, Arrival::Poisson { lambda_rps: 50.0 }, 1);
        let span = reqs.last().unwrap().arrival_s;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 50.0).abs() < 2.0, "rate {rate}");
    }

    #[test]
    fn arrivals_are_monotone() {
        for arrival in [
            Arrival::Poisson { lambda_rps: 10.0 },
            Arrival::Bursty { burst: 4, idle_s: 0.5 },
            Arrival::Batch,
        ] {
            let reqs = generate(100, 5, arrival, 2);
            assert!(reqs.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
            assert!(reqs.iter().all(|r| r.sample < 5));
        }
    }

    #[test]
    fn bursty_has_gaps() {
        let reqs = generate(12, 5, Arrival::Bursty { burst: 4, idle_s: 1.0 }, 3);
        assert_eq!(reqs[3].arrival_s, reqs[0].arrival_s);
        assert!((reqs[4].arrival_s - reqs[3].arrival_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_in_seed() {
        let a = generate(50, 8, Arrival::Poisson { lambda_rps: 5.0 }, 7);
        let b = generate(50, 8, Arrival::Poisson { lambda_rps: 5.0 }, 7);
        assert_eq!(a, b);
    }
}
