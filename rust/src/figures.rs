//! Shared driver for the paper-figure benches (Figs. 5–8, Table I).
//!
//! The planning world runs at the **paper scale** (BLIP-2/GIT GFLOP
//! workloads, the paper's silicon constants, the paper's T0/E0 axes); the
//! *quality* of each planned bit-width is then measured by actually
//! executing this repo's trained captioner at that bit-width and scoring
//! CIDEr — i.e. the decision variable transfers, the testbed substitutes
//! (DESIGN.md §5).

use crate::coordinator::engine::{Engine, EngineConfig};
use crate::coordinator::router::{QosPolicy, Router};
use crate::coordinator::scheduler::{Algorithm, Scheduler};
use crate::data::eval::EvalSet;
use crate::data::vocab::Vocab;
use crate::data::workload::Request;
use crate::quant::Scheme;
use crate::rl::env::BudgetRanges;
use crate::rl::PpoConfig;
use crate::runtime::executor::CoModel;
use crate::runtime::Registry;
use crate::system::channel::Channel;
use crate::system::Platform;

/// Which budget axis a sweep walks.
#[derive(Debug, Clone)]
pub enum Sweep {
    /// vary T0 at fixed E0 (the left panel of each figure)
    Delay { e0: f64, t0s: Vec<f64> },
    /// vary E0 at fixed T0 (the right panel)
    Energy { t0: f64, e0s: Vec<f64> },
}

impl Sweep {
    pub fn points(&self) -> Vec<(f64, f64)> {
        match self {
            Sweep::Delay { e0, t0s } => t0s.iter().map(|t| (*t, *e0)).collect(),
            Sweep::Energy { t0, e0s } => e0s.iter().map(|e| (*t0, *e)).collect(),
        }
    }

    pub fn axis_name(&self) -> &'static str {
        match self {
            Sweep::Delay { .. } => "T0 [s]",
            Sweep::Energy { .. } => "E0 [J]",
        }
    }

    pub fn axis_value(&self, point: (f64, f64)) -> f64 {
        match self {
            Sweep::Delay { .. } => point.0,
            Sweep::Energy { .. } => point.1,
        }
    }
}

/// One sweep point's outcome for one algorithm.
#[derive(Debug, Clone)]
pub struct QualityPoint {
    pub axis: f64,
    pub algorithm: Algorithm,
    /// None = infeasible at this budget
    pub cider_x100: Option<f64>,
    pub mean_bits: f64,
}

pub struct FigureRunner {
    pub registry: Registry,
    pub model: CoModel,
    pub eval: EvalSet,
    pub vocab: Vocab,
    pub platform: Platform,
    pub requests_per_point: usize,
}

impl FigureRunner {
    /// `model_name`: blip2ish (coco eval, paper_blip2 platform) or gitish
    /// (vatex eval, paper_git platform).
    pub fn open(model_name: &str, requests_per_point: usize) -> anyhow::Result<FigureRunner> {
        let registry = Registry::open(&crate::artifacts_dir())?;
        let model = CoModel::load(&registry, model_name)?;
        let (eval_name, platform) = if model_name == "gitish" {
            ("vatex", Platform::paper_git())
        } else {
            ("coco", Platform::paper_blip2())
        };
        let eval = EvalSet::load(&registry.dir, &registry.manifest, eval_name)?;
        let vocab = Vocab::from_manifest(&registry.manifest)?;
        Ok(FigureRunner { registry, model, eval, vocab, platform, requests_per_point })
    }

    /// Execute a quality sweep for one algorithm.
    pub fn run(
        &mut self,
        sweep: &Sweep,
        algorithm: Algorithm,
        scheme: Scheme,
        seed: u64,
    ) -> anyhow::Result<Vec<QualityPoint>> {
        let lambda = self.model.agent_weights.lambda;
        let mut scheduler = Scheduler::new(self.platform, lambda, algorithm, scheme, seed);
        if algorithm == Algorithm::Ppo {
            let pts = sweep.points();
            let (t_lo, t_hi) = pts
                .iter()
                .fold((f64::MAX, 0.0f64), |(lo, hi), p| (lo.min(p.0), hi.max(p.0)));
            let (e_lo, e_hi) = pts
                .iter()
                .fold((f64::MAX, 0.0f64), |(lo, hi), p| (lo.min(p.1), hi.max(p.1)));
            scheduler.train_ppo(
                BudgetRanges {
                    t0: (0.8 * t_lo, 1.2 * t_hi),
                    e0: (0.8 * e_lo, 1.2 * e_hi),
                },
                PpoConfig::default(),
            );
        }
        let mut out = Vec::new();
        for point in sweep.points() {
            let (t0, e0) = point;
            // feasible-random must resample per point (it's a distribution,
            // not a point estimate): new seeds come from the scheduler rng
            scheduler.invalidate();
            let feasible = scheduler.plan(t0, e0).is_some();
            if !feasible {
                out.push(QualityPoint {
                    axis: sweep.axis_value(point),
                    algorithm,
                    cider_x100: None,
                    mean_bits: 0.0,
                });
                continue;
            }
            let router = Router::new(QosPolicy::uniform(t0, e0), scheduler);
            // identical request set at every sweep point and algorithm:
            // round-robin over the eval corpus, so curve differences are
            // design differences, not sampling noise
            let requests: Vec<Request> = (0..self.requests_per_point)
                .map(|i| Request {
                    id: i as u64,
                    sample: i % self.eval.len(),
                    arrival_s: 0.0,
                    class: "standard",
                })
                .collect();
            let mut engine = Engine::new(
                &mut self.model,
                router,
                &self.vocab,
                &self.eval,
                Channel::ideal(),
                EngineConfig::default(),
            );
            let telemetry = engine.run(requests)?;
            let mean_bits = telemetry
                .records
                .iter()
                .map(|r| r.b_hat as f64)
                .sum::<f64>()
                / telemetry.len().max(1) as f64;
            let cider = telemetry.cider_x100(&self.eval.refs);
            out.push(QualityPoint {
                axis: sweep.axis_value(point),
                algorithm,
                cider_x100: Some(cider),
                mean_bits,
            });
            // hand the scheduler back for the next point
            scheduler = engine.router.scheduler;
        }
        Ok(out)
    }

    /// The full figure: all four algorithms over both panels, printed as
    /// paper-shaped tables. Returns (panel, algorithm, points).
    pub fn run_figure(
        &mut self,
        title: &str,
        sweeps: &[Sweep],
        scheme: Scheme,
        seed: u64,
    ) -> anyhow::Result<()> {
        for sweep in sweeps {
            let algorithms = [
                Algorithm::Proposed,
                Algorithm::Ppo,
                Algorithm::FixedFreq,
                Algorithm::FeasibleRandom,
            ];
            let mut results = Vec::new();
            for alg in algorithms {
                results.push(self.run(sweep, alg, scheme, seed)?);
            }
            let mut header = vec![sweep.axis_name()];
            for alg in &algorithms {
                header.push(alg.name());
            }
            let header_bits: Vec<String> =
                algorithms.iter().map(|a| format!("b̂({})", a.name())).collect();
            let mut all_cols = header.clone();
            all_cols.extend(header_bits.iter().map(String::as_str));
            let mut table =
                crate::bench_harness::Table::new(&format!("{title} — CIDEr(x100)"), &all_cols);
            for (i, _) in sweep.points().iter().enumerate() {
                let mut row = vec![format!("{:.2}", results[0][i].axis)];
                for r in &results {
                    row.push(match r[i].cider_x100 {
                        Some(c) => format!("{c:.1}"),
                        None => "--".into(),
                    });
                }
                for r in &results {
                    row.push(format!("{:.1}", r[i].mean_bits));
                }
                table.row(&row);
            }
            table.print();

            // sanity: proposed never below the baselines where all feasible
            for (i, _) in sweep.points().iter().enumerate() {
                if let Some(p) = results[0][i].cider_x100 {
                    for r in &results[1..] {
                        if let Some(c) = r[i].cider_x100 {
                            if c > p + 12.0 {
                                println!(
                                    "WARN: {} beat proposed at point {i} ({c:.1} vs {p:.1})",
                                    r[i].algorithm.name()
                                );
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }
}
