//! Event-driven churn: agents join, burst and leave while contending for
//! the edge — and the allocation follows them online. With
//! [`ChurnConfig::servers`] holding more than the single default server,
//! the online policy additionally keeps a sticky agent→server seating
//! (`sticky_placement`) and gates the warm re-solve **per server**
//! ([`FleetProblem::server_fingerprint`]): an event that only touches one
//! server's sub-problem reuses every other server's slots verbatim.
//!
//! The static allocator ([`crate::opt::fleet`]) answers "who gets what"
//! for a fixed population; this module answers what the paper's
//! single-shot design cannot: **what happens when the population changes
//! mid-flight**. A deterministic [`Timeline`] of Poisson joins, leaves
//! and load bursts is generated once per seed and replayed under three
//! policies:
//!
//! * [`ChurnPolicy::StaticEqual`] / [`ChurnPolicy::StaticProposed`] —
//!   the allocation computed at t = 0 is kept forever: departed agents'
//!   shares idle, joiners are turned away (rejection penalty), and a
//!   burst that blows an agent's queue-aware delay budget turns its
//!   frozen design infeasible (penalty while the burst lasts);
//! * [`ChurnPolicy::Online`] — every event re-fingerprints the fleet
//!   problem (the same config-fingerprint idiom the coordinator's
//!   [`Scheduler`](crate::coordinator::Scheduler) uses to invalidate its
//!   plan cache); on a change, the water-filling exchange re-runs
//!   **warm-started** from the previous allocation
//!   ([`crate::opt::fleet::solve_proposed_warm`]). Periodic `Tick`
//!   events re-check the fingerprint and are counted as skipped
//!   re-allocations when nothing changed — with churn disabled the
//!   online path therefore never re-solves and reproduces the static
//!   proposed allocation exactly.
//!
//! The score is the **time-averaged fleet-weighted distortion cost**
//! (the (P1) objective integrated over the horizon, rejection penalties
//! included), plus the matching time-averaged weighted D^U. This is the
//! *analytic* view — what the allocator guarantees between events; the
//! same [`Timeline`] can be replayed at the request level by
//! [`super::events`], which measures the tails (p50/p95/p99 wait and
//! e2e, deadline-violation rate) the integration cannot see.

use crate::obs::metrics as obs_metrics;
use crate::opt::fleet::{
    self, AdmissionPricing, AgentAllocation, AgentSpec, Classing, FleetAlgorithm,
    FleetAllocation, FleetProblem, FleetSpec, Placement, PlacementStrategy, ProposedOptions,
    ServerSpec, SolveRequest,
};
use crate::quant::mixed::QuantPolicy;
use crate::system::platform::DeviceProfile;
use crate::system::queue::{QueueDiscipline, QueueModel};
use crate::system::Platform;
use crate::theory::rate_distortion as rd;
use crate::util::cli::ParseError;
use crate::util::rng::Rng;
use crate::util::timer::{Samples, Stopwatch};
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};

/// Knobs for a churn run. Rates are per second of simulated time.
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    /// population at t = 0
    pub initial_agents: usize,
    pub horizon_s: f64,
    /// Poisson rate of agents joining (suppressed at `max_agents`)
    pub join_rps: f64,
    /// per-live-agent Poisson rate of leaving (suppressed at 1 agent)
    pub leave_rps_per_agent: f64,
    /// Poisson rate of load bursts starting (on a non-bursting agent)
    pub burst_rps: f64,
    /// arrival-rate multiplier while an agent bursts
    pub burst_factor: f64,
    pub burst_duration_s: f64,
    /// period of fingerprint re-check ticks (0 disables them)
    pub tick_s: f64,
    pub max_agents: usize,
    /// steady-state per-agent request rate (feeds the queue model)
    pub arrival_rps: f64,
    /// event-level arrival model: `false` = open Poisson streams (the
    /// default), `true` = closed-loop single-inflight clients mirroring
    /// [`super::sim`]'s model — each agent keeps at most one request in
    /// flight and draws its next exponential think-time gap from the
    /// previous request's completion (rejected arrivals retry after a
    /// think-time gap). Only [`super::events`] reads this; the analytic
    /// replay and the allocator's queue model are unchanged.
    pub closed_loop: bool,
    /// shared edge-queue discipline; `None` = PR 1's fluid sharing (load
    /// bursts are then invisible to the allocator)
    pub queue: Option<QueueDiscipline>,
    /// shared uplink
    pub link_rate_bps: f64,
    pub link_base_latency_s: f64,
    /// silicon ladder newcomers draw from: an agent's stable key picks
    /// its tier via [`AgentSpec::tiered_spec`], so a replayed timeline
    /// seats identical silicon every run. The default uniform-Orin
    /// ladder reproduces the homogeneous fleet exactly.
    pub tiers: Vec<DeviceProfile>,
    /// how the allocator prices rejections (the default
    /// [`AdmissionPricing::Uniform`] reproduces the silicon-blind 2/λ
    /// scoring bit for bit)
    pub pricing: AdmissionPricing,
    /// edge servers agents are placed across; the default single
    /// full-budget server reproduces the single-server replay bit for
    /// bit, while S > 1 turns on sticky seating with per-server
    /// fingerprint-gated re-solves
    pub servers: Vec<ServerSpec>,
    /// equivalence-class collapsing forwarded to every solve the replay
    /// takes (the default [`Classing::PerAgent`] keeps the historical
    /// per-agent path bit for bit)
    pub classing: Classing,
    /// class-level incremental re-solves (single-server online path):
    /// at a fingerprint-changed event, diff per-agent class hashes
    /// ([`FleetProblem::agent_class_hashes`]) against the previous
    /// population — an unchanged class multiset is a pure relabel whose
    /// slots are remapped class-wise with **no** solve, and otherwise
    /// newcomers inherit the slots departed same-class agents freed, so
    /// the warm exchange starts at the previous optimum and only
    /// classes whose membership actually changed have work left. The
    /// default `false` keeps the historical warm path byte for byte.
    pub class_reuse: bool,
    /// quantization policy every agent in the fleet runs under
    /// ([`QuantPolicy`]): the default `Static(None)` keeps the legacy
    /// exact-bisection pick bit for bit; `Adaptive` lets each re-solve
    /// re-pick bit-widths inside a pressure-damped window — the online
    /// temporal adaptation the drifting-load bench measures
    pub quant: QuantPolicy,
    pub seed: u64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            initial_agents: 4,
            horizon_s: 600.0,
            join_rps: 0.02,
            leave_rps_per_agent: 0.003,
            burst_rps: 0.01,
            burst_factor: 5.0,
            burst_duration_s: 40.0,
            tick_s: 20.0,
            max_agents: 16,
            arrival_rps: 0.02,
            closed_loop: false,
            queue: Some(QueueDiscipline::Fifo),
            link_rate_bps: 400e6,
            link_base_latency_s: 2e-3,
            tiers: vec![DeviceProfile::orin()],
            pricing: AdmissionPricing::Uniform,
            servers: vec![ServerSpec::default()],
            classing: Classing::PerAgent,
            class_reuse: false,
            quant: QuantPolicy::default(),
            seed: 0,
        }
    }
}

impl ChurnConfig {
    /// Same fleet, zero churn: only ticks fire. The online policy must
    /// then reproduce the static proposed allocation exactly.
    pub fn without_churn(mut self) -> ChurnConfig {
        self.join_rps = 0.0;
        self.leave_rps_per_agent = 0.0;
        self.burst_rps = 0.0;
        self
    }
}

/// One population change. Agents are identified by a stable key; the
/// key also determines the agent's QoS contract
/// ([`AgentSpec::class_spec`]), so a replayed timeline is exactly
/// reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    Join(u64),
    Leave(u64),
    BurstStart(u64),
    BurstEnd(u64),
    /// periodic fingerprint re-check (no state change)
    Tick,
}

/// A pre-generated event schedule, shared verbatim by every policy so
/// the comparison is apples-to-apples.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// keys live at t = 0
    pub initial: Vec<u64>,
    /// (time, event), non-decreasing in time, all ≤ the horizon
    pub events: Vec<(f64, ChurnEvent)>,
    pub joins: usize,
    pub leaves: usize,
    pub bursts: usize,
}

/// Generate the churn timeline for a config (deterministic per seed).
pub fn timeline(cfg: &ChurnConfig) -> Timeline {
    assert!(cfg.initial_agents >= 1 && cfg.horizon_s > 0.0);
    let mut rng = Rng::new(cfg.seed ^ 0xC0FF_EE00);
    let mut events: Vec<(f64, ChurnEvent)> = Vec::new();
    let mut live: Vec<u64> = (0..cfg.initial_agents as u64).collect();
    let mut next_key = cfg.initial_agents as u64;
    // pending burst ends, kept sorted by end time
    let mut burst_ends: Vec<(f64, u64)> = Vec::new();
    let mut next_tick = if cfg.tick_s > 0.0 { cfg.tick_s } else { f64::INFINITY };
    let mut t = 0.0;
    let (mut joins, mut leaves, mut bursts) = (0usize, 0usize, 0usize);
    loop {
        let bursting: HashSet<u64> = burst_ends.iter().map(|&(_, k)| k).collect();
        let idle: Vec<u64> = live.iter().copied().filter(|k| !bursting.contains(k)).collect();
        let r_join = if live.len() < cfg.max_agents { cfg.join_rps } else { 0.0 };
        let r_leave = if live.len() > 1 {
            cfg.leave_rps_per_agent * live.len() as f64
        } else {
            0.0
        };
        let r_burst = if idle.is_empty() { 0.0 } else { cfg.burst_rps };
        let total = r_join + r_leave + r_burst;
        let t_next = if total > 0.0 { t + rng.exponential(total) } else { f64::INFINITY };
        // deterministic events (burst ends, ticks) due before the next
        // random event fire first
        let mut burst_end_fired = None;
        loop {
            let end = burst_ends.first().map_or(f64::INFINITY, |&(e, _)| e);
            let due = end.min(next_tick);
            if due > t_next || due > cfg.horizon_s {
                break;
            }
            if end <= next_tick {
                let (e, k) = burst_ends.remove(0);
                events.push((e, ChurnEvent::BurstEnd(k)));
                burst_end_fired = Some(e);
            } else {
                events.push((next_tick, ChurnEvent::Tick));
                next_tick += cfg.tick_s;
            }
        }
        if t_next > cfg.horizon_s {
            // an all-suppressed rate vector (e.g. a 1-agent fleet whose
            // only member is mid-burst) is not terminal: a burst end that
            // just fired restores eligibility, so resume the clock there
            // instead of silently ending the timeline
            if total <= 0.0 {
                if let Some(resume) = burst_end_fired {
                    t = resume;
                    continue;
                }
            }
            break;
        }
        t = t_next;
        let pick = rng.f64() * total;
        if pick < r_join {
            let key = next_key;
            next_key += 1;
            live.push(key);
            events.push((t, ChurnEvent::Join(key)));
            joins += 1;
        } else if pick < r_join + r_leave {
            let key = live.remove(rng.below(live.len()));
            burst_ends.retain(|&(_, k)| k != key);
            events.push((t, ChurnEvent::Leave(key)));
            leaves += 1;
        } else {
            let key = idle[rng.below(idle.len())];
            let end = t + cfg.burst_duration_s;
            let at = burst_ends.partition_point(|&(e, _)| e <= end);
            burst_ends.insert(at, (end, key));
            events.push((t, ChurnEvent::BurstStart(key)));
            bursts += 1;
        }
    }
    Timeline { initial: (0..cfg.initial_agents as u64).collect(), events, joins, leaves, bursts }
}

/// Which allocation policy rides the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChurnPolicy {
    /// equal split frozen at t = 0
    StaticEqual,
    /// proposed allocation frozen at t = 0
    StaticProposed,
    /// warm-started proposed re-allocation on every fingerprint change
    Online,
}

impl ChurnPolicy {
    pub const ALL: [ChurnPolicy; 3] =
        [ChurnPolicy::StaticEqual, ChurnPolicy::StaticProposed, ChurnPolicy::Online];

    pub fn name(self) -> &'static str {
        match self {
            ChurnPolicy::StaticEqual => "static-equal",
            ChurnPolicy::StaticProposed => "static-proposed",
            ChurnPolicy::Online => "online-proposed",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<ChurnPolicy, ParseError> {
        match s {
            "static-equal" | "equal" => Ok(ChurnPolicy::StaticEqual),
            "static-proposed" | "static" => Ok(ChurnPolicy::StaticProposed),
            "online-proposed" | "online" => Ok(ChurnPolicy::Online),
            _ => Err(ParseError::new(
                "churn policy",
                s,
                &["static-equal", "static-proposed", "online-proposed"],
            )),
        }
    }
}

/// Outcome of one policy over one timeline.
#[derive(Debug, Clone)]
pub struct ChurnReport {
    pub policy: ChurnPolicy,
    pub horizon_s: f64,
    pub events: usize,
    pub joins: usize,
    pub leaves: usize,
    pub bursts: usize,
    /// online re-solves actually run (0 for static policies)
    pub reallocations: usize,
    /// fingerprint checks that found nothing changed (ticks, no-op
    /// events) — the config-fingerprint reuse at work
    pub realloc_skipped: usize,
    /// ∫ fleet-weighted (P1) cost dt / horizon — the headline score
    pub time_avg_cost: f64,
    /// ∫ fleet-weighted D^U dt / horizon
    pub time_avg_d_upper: f64,
    pub final_population: usize,
    /// the allocation in force at the horizon (static: the t = 0 one)
    pub final_alloc: FleetAllocation,
    /// allocation solve wall times [ms]: the t = 0 solve plus every
    /// online re-solve (static policies only ever record the first)
    pub solve_ms: Samples,
    /// (event time, fleet cost rate) after each event — for plots/CLI
    pub cost_trace: Vec<(f64, f64)>,
}

/// Everything the fleet problem depends on, hashed — the same
/// invalidation idiom as the coordinator scheduler's `config_stamp`.
/// Since the [`fleet::FleetSpec`] redesign this is the spec's own
/// `Hash` (floats by bit pattern), so the gate covers every field the
/// solver can see — agent contracts, device profiles, channel gains,
/// servers, link, queue rates, pricing — instead of chasing them one by
/// one across four builder fields (regression-tested below).
pub(crate) fn fingerprint(fp: &FleetProblem) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    fp.spec.hash(&mut h);
    h.finish()
}

/// The live population under a policy run (shared with the event-level
/// replay in [`crate::fleet::events`], so both score against the same
/// fleet problem derivation).
pub(crate) struct Population {
    pub(crate) live: Vec<u64>,
    pub(crate) bursting: HashSet<u64>,
}

impl Population {
    pub(crate) fn spec(cfg: &ChurnConfig, key: u64) -> AgentSpec {
        let mut s = AgentSpec::tiered_spec(key as usize, &cfg.tiers);
        s.quant = cfg.quant;
        s
    }

    pub(crate) fn problem(&self, base: Platform, cfg: &ChurnConfig) -> FleetProblem {
        self.problem_with_pressure(base, cfg, &HashMap::new())
    }

    /// [`Self::problem`] with measured violation pressure attached (the
    /// serving daemon's telemetry feedback): keys absent from the map
    /// carry zero pressure, and an empty map leaves the spec's pressure
    /// vector empty — bit-identical to the plain derivation, so the
    /// fingerprint only moves when telemetry actually exists.
    pub(crate) fn problem_with_pressure(
        &self,
        base: Platform,
        cfg: &ChurnConfig,
        pressure: &HashMap<u64, f64>,
    ) -> FleetProblem {
        let specs: Vec<AgentSpec> = self.live.iter().map(|&k| Self::spec(cfg, k)).collect();
        let mut spec = FleetSpec::new(base, specs);
        spec.link_rate_bps = cfg.link_rate_bps;
        spec.link_base_latency_s = cfg.link_base_latency_s;
        spec.pricing = cfg.pricing;
        spec.servers = cfg.servers.clone();
        if !pressure.is_empty() {
            spec.pressure =
                self.live.iter().map(|k| pressure.get(k).copied().unwrap_or(0.0)).collect();
        }
        if let Some(discipline) = cfg.queue {
            let rates: Vec<f64> = self
                .live
                .iter()
                .map(|k| {
                    let boost = if self.bursting.contains(k) { cfg.burst_factor } else { 1.0 };
                    cfg.arrival_rps * boost
                })
                .collect();
            spec.queue = Some(QueueModel::new(discipline, rates));
        }
        FleetProblem::from_spec(spec)
    }

    pub(crate) fn apply(&mut self, event: ChurnEvent) {
        match event {
            ChurnEvent::Join(k) => self.live.push(k),
            ChurnEvent::Leave(k) => {
                self.live.retain(|&x| x != k);
                self.bursting.remove(&k);
            }
            ChurnEvent::BurstStart(k) => {
                self.bursting.insert(k);
            }
            ChurnEvent::BurstEnd(k) => {
                self.bursting.remove(&k);
            }
            ChurnEvent::Tick => {}
        }
    }
}

/// Cost and D^U rates of a **frozen** allocation under current
/// conditions: keys absent from the t = 0 slots (joiners) pay the
/// rejection penalty; frozen designs that the current conditions (queue
/// load, shares) no longer support pay it too. Feasibility is checked
/// at the actual-share waits of the frozen slots held by the live
/// population (frozen-admitted agents load the queue, everyone else's
/// traffic is turned away) — the same interference model the online
/// policy is scored under, so the comparison stays apples-to-apples.
fn static_rates(
    fp: &FleetProblem,
    live: &[u64],
    slots: &HashMap<u64, AgentAllocation>,
    groups: Option<&[usize]>,
) -> (f64, f64) {
    let (mut cost, mut du) = (0.0, 0.0);
    let (services, activity): (Vec<f64>, Vec<f64>) = live
        .iter()
        .map(|key| match slots.get(key) {
            Some(slot) if slot.design.is_some() => (fp.own_service(slot.server_share), 1.0),
            _ => (f64::INFINITY, 0.0),
        })
        .unzip();
    // multi-server fleets queue per server: an agent's wait only sees
    // the traffic of its own server's members (groups[i] = server of
    // live[i], from the frozen t = 0 placement), modeled by masking the
    // other servers' activity out of the shared analytic queue
    let waits = match groups {
        None => fp.queue_waits_given(&services, &activity),
        Some(gs) => {
            let mut waits = vec![0.0; live.len()];
            let mut seen: Vec<usize> = gs.to_vec();
            seen.sort_unstable();
            seen.dedup();
            for &g in &seen {
                let masked: Vec<f64> = activity
                    .iter()
                    .zip(gs)
                    .map(|(&a, &gg)| if gg == g { a } else { 0.0 })
                    .collect();
                let w = fp.queue_waits_given(&services, &masked);
                for (i, &gg) in gs.iter().enumerate() {
                    if gg == g {
                        waits[i] = w[i];
                    }
                }
            }
            waits
        }
    };
    for (i, key) in live.iter().enumerate() {
        let spec = &fp.agents[i];
        let served_bits = slots.get(key).and_then(|slot| {
            let d = slot.design?;
            fp.agent_problem_at_wait(i, slot.server_share, slot.airtime_share, waits[i])
                .is_some_and(|p| p.is_feasible(&d))
                .then_some(d.b_hat)
        });
        match served_bits {
            Some(b) => {
                cost += spec.weight * rd::bound_gap(b as f64, spec.lambda);
                du += spec.weight * rd::d_upper(b as f64 - 1.0, spec.lambda);
            }
            None => {
                cost += fp.rejection_cost(i);
                du += spec.weight * rd::d_upper(0.0, spec.lambda);
            }
        }
    }
    (cost, du)
}

/// Sticky seating for the online multi-server policy: survivors keep
/// their server, newcomers land on the least-loaded one (head-count per
/// unit frequency budget), then a deterministic rebalance migrates the
/// newest agent off the most overloaded server while that strictly
/// reduces the squared capacity-normalized load imbalance — so a
/// one-agent join never reshuffles the whole fleet, and migrations only
/// happen when the imbalance is real. Each accepted migration counts as
/// `placement.moves`; the event-level replay mirrors them
/// queue-to-queue ([`EdgeQueue::drain_agent`](crate::system::queue::EdgeQueue::drain_agent)
/// + re-queue).
pub(crate) fn sticky_placement(
    cfg: &ChurnConfig,
    live: &[u64],
    server_of: &mut HashMap<u64, usize>,
) -> Placement {
    let s = cfg.servers.len();
    let mut counts = vec![0usize; s];
    let mut assignment = vec![usize::MAX; live.len()];
    for (i, key) in live.iter().enumerate() {
        if let Some(&k) = server_of.get(key) {
            assignment[i] = k;
            counts[k] += 1;
        }
    }
    for (i, key) in live.iter().enumerate() {
        if assignment[i] == usize::MAX {
            let k = (0..s)
                .min_by(|&a, &b| {
                    let la = counts[a] as f64 / cfg.servers[a].freq_scale;
                    let lb = counts[b] as f64 / cfg.servers[b].freq_scale;
                    la.total_cmp(&lb)
                })
                .expect("at least one server");
            assignment[i] = k;
            counts[k] += 1;
            server_of.insert(*key, k);
        }
    }
    // each migration strictly decreases Σ (count_k / freq_k)², so the
    // rebalance terminates
    loop {
        let mut best: Option<(usize, usize, f64)> = None;
        for from in 0..s {
            if counts[from] == 0 {
                continue;
            }
            for to in 0..s {
                if to == from {
                    continue;
                }
                let (cf, ct) = (counts[from] as f64, counts[to] as f64);
                let (ff, ft) = (cfg.servers[from].freq_scale, cfg.servers[to].freq_scale);
                let delta = ((cf - 1.0).powi(2) - cf.powi(2)) / (ff * ff)
                    + ((ct + 1.0).powi(2) - ct.powi(2)) / (ft * ft);
                if delta < best.map_or(-1e-12, |(_, _, d)| d) {
                    best = Some((from, to, delta));
                }
            }
        }
        let Some((from, to, _)) = best else { break };
        let (i, key) = live
            .iter()
            .enumerate()
            .filter(|&(i, _)| assignment[i] == from)
            .max_by_key(|&(_, &k)| k)
            .map(|(i, &k)| (i, k))
            .expect("overloaded server has a member");
        assignment[i] = to;
        counts[from] -= 1;
        counts[to] += 1;
        server_of.insert(key, to);
        obs_metrics::counter_add("placement.moves", 1);
    }
    Placement { assignment }
}

/// Class-aware warm slots for a single-server online re-solve
/// ([`ChurnConfig::class_reuse`]): a surviving key keeps its previous
/// slot verbatim; a newcomer inherits a full slot freed by a departed
/// agent of the same equivalence class (content hash per
/// [`FleetProblem::agent_class_hashes`] — an agent of the same class is
/// float-for-float the same subproblem, so the freed slot is exactly as
/// valid for the newcomer). Returns the per-live-agent slots plus
/// whether the class multiset is unchanged — a pure relabel, in which
/// case every slot is guaranteed filled and no solve is needed at all.
pub(crate) fn class_warm_slots(
    prev_hashes: &[u64],
    prev_assoc: &[u64],
    prev_agents: &[AgentAllocation],
    live: &[u64],
    fresh_hashes: &[u64],
    prev_by_key: &HashMap<u64, AgentAllocation>,
) -> (Vec<Option<AgentAllocation>>, bool) {
    let live_set: HashSet<u64> = live.iter().copied().collect();
    let mut freed: HashMap<u64, Vec<AgentAllocation>> = HashMap::new();
    for ((&k, &h), a) in prev_assoc.iter().zip(prev_hashes).zip(prev_agents) {
        if !live_set.contains(&k) {
            freed.entry(h).or_default().push(*a);
        }
    }
    let slots: Vec<Option<AgentAllocation>> = live
        .iter()
        .zip(fresh_hashes)
        .map(|(k, h)| match prev_by_key.get(k) {
            Some(a) => Some(*a),
            None => freed.get_mut(h).and_then(|v| {
                if v.is_empty() {
                    None
                } else {
                    Some(v.remove(0))
                }
            }),
        })
        .collect();
    let mut a = prev_hashes.to_vec();
    let mut b = fresh_hashes.to_vec();
    a.sort_unstable();
    b.sort_unstable();
    (slots, a == b)
}

/// Re-solve (or class-remap) the single-server online allocation after a
/// fingerprint change, honoring [`ChurnConfig::class_reuse`] and
/// [`ChurnConfig::classing`]. `prev` is the plain key-matched warm
/// vector; `class_hashes` holds the previous population's per-agent
/// class hashes and is updated in place.
pub(crate) fn resolve_single(
    fp: &FleetProblem,
    cfg: &ChurnConfig,
    opts: ProposedOptions,
    prev: Vec<Option<(f64, f64)>>,
    prev_by_key: &HashMap<u64, AgentAllocation>,
    prev_assoc: &[u64],
    prev_agents: &[AgentAllocation],
    live: &[u64],
    class_hashes: &mut Vec<u64>,
) -> FleetAllocation {
    if !cfg.class_reuse {
        return fp.solve(&SolveRequest {
            options: opts,
            warm_start: Some(prev),
            classing: cfg.classing,
            ..SolveRequest::default()
        });
    }
    let fresh_hashes = fp.agent_class_hashes();
    let (slots, relabel) = class_warm_slots(
        class_hashes,
        prev_assoc,
        prev_agents,
        live,
        &fresh_hashes,
        prev_by_key,
    );
    *class_hashes = fresh_hashes;
    if relabel && slots.iter().all(|s| s.is_some()) {
        // no class's membership changed: remap slots class-wise, skip
        // the solve entirely
        obs_metrics::counter_add("solver.class.relabel", 1);
        let agents: Vec<AgentAllocation> = slots.into_iter().flatten().collect();
        return FleetAllocation {
            objective: agents.iter().map(|a| a.cost).sum(),
            admitted: agents.iter().filter(|a| a.design.is_some()).count(),
            placement: Placement::single(agents.len()),
            agents,
        };
    }
    let inherited = live
        .iter()
        .zip(&slots)
        .filter(|(k, s)| s.is_some() && !prev_by_key.contains_key(k))
        .count();
    if inherited > 0 {
        obs_metrics::counter_add("solver.class.warm_inherit", inherited as u64);
    }
    let warm: Vec<Option<(f64, f64)>> =
        slots.iter().map(|s| s.map(|a| (a.server_share, a.airtime_share))).collect();
    fp.solve(&SolveRequest {
        options: opts,
        warm_start: Some(warm),
        classing: cfg.classing,
        ..SolveRequest::default()
    })
}

/// Replay `timeline` under `policy` and integrate the fleet cost.
pub fn run_churn(
    base: Platform,
    timeline: &Timeline,
    policy: ChurnPolicy,
    cfg: &ChurnConfig,
) -> ChurnReport {
    let opts = ProposedOptions::default();
    let multi = cfg.servers != [ServerSpec::default()];
    let mut pop = Population {
        live: timeline.initial.clone(),
        bursting: HashSet::new(),
    };
    let mut fp = pop.problem(base, cfg);
    let mut stamp = fingerprint(&fp);

    // t = 0 allocation
    let mut solve_ms = Samples::new();
    let sw = Stopwatch::start();
    let mut alloc = match policy {
        ChurnPolicy::StaticEqual => fp.solve(&SolveRequest {
            algorithm: FleetAlgorithm::EqualShare,
            placement: PlacementStrategy::EqualSpread,
            classing: cfg.classing,
            ..SolveRequest::default()
        }),
        ChurnPolicy::StaticProposed | ChurnPolicy::Online => fp.solve(&SolveRequest {
            classing: cfg.classing,
            ..SolveRequest::default()
        }),
    };
    solve_ms.push(sw.elapsed_s() * 1e3);
    // frozen per-key slots (and server seats) for the static policies
    let slots: HashMap<u64, AgentAllocation> = pop
        .live
        .iter()
        .zip(&alloc.agents)
        .map(|(&k, a)| (k, *a))
        .collect();
    let static_server_of: HashMap<u64, usize> = pop
        .live
        .iter()
        .zip(&alloc.placement.assignment)
        .map(|(&k, &s)| (k, s))
        .collect();
    let static_groups = |live: &[u64]| -> Option<Vec<usize>> {
        multi.then(|| {
            live.iter().map(|k| static_server_of.get(k).copied().unwrap_or(0)).collect()
        })
    };
    // which key owns which row of `alloc` (online warm-start mapping)
    let mut assoc: Vec<u64> = pop.live.clone();
    // online, multi-server: sticky key→server seating plus per-server
    // fingerprints, so a re-solve only touches the servers an event
    // actually changed
    let mut server_of: HashMap<u64, usize> = HashMap::new();
    let mut server_stamps: Vec<u64> = Vec::new();
    if multi && policy == ChurnPolicy::Online {
        for (key, &s) in pop.live.iter().zip(&alloc.placement.assignment) {
            server_of.insert(*key, s);
        }
        server_stamps =
            (0..cfg.servers.len()).map(|k| fp.server_fingerprint(&alloc.placement, k)).collect();
    }

    // class-level fingerprints of the population the current allocation
    // was solved for (single-server class_reuse path only)
    let mut class_hashes: Vec<u64> = if policy == ChurnPolicy::Online && cfg.class_reuse && !multi
    {
        fp.agent_class_hashes()
    } else {
        Vec::new()
    };

    let mut rates = match policy {
        ChurnPolicy::Online => (alloc.objective, alloc.weighted_d_upper(&fp)),
        _ => static_rates(&fp, &pop.live, &slots, static_groups(&pop.live).as_deref()),
    };
    let mut cost_trace = vec![(0.0, rates.0)];
    let (mut acc_cost, mut acc_du) = (0.0, 0.0);
    let (mut reallocations, mut realloc_skipped) = (0usize, 0usize);
    let mut t_cur = 0.0;

    for &(t, event) in &timeline.events {
        let dt = (t - t_cur).max(0.0);
        acc_cost += rates.0 * dt;
        acc_du += rates.1 * dt;
        t_cur = t;
        pop.apply(event);
        fp = pop.problem(base, cfg);
        if policy == ChurnPolicy::Online {
            let new_stamp = fingerprint(&fp);
            if new_stamp == stamp {
                realloc_skipped += 1;
                obs_metrics::counter_add("solver.warm_start.hit", 1);
            } else {
                stamp = new_stamp;
                obs_metrics::counter_add("solver.warm_start.miss", 1);
                let prev_by_key: HashMap<u64, AgentAllocation> = assoc
                    .iter()
                    .zip(&alloc.agents)
                    .map(|(&k, a)| (k, *a))
                    .collect();
                let prev: Vec<Option<(f64, f64)>> = pop
                    .live
                    .iter()
                    .map(|k| prev_by_key.get(k).map(|a| (a.server_share, a.airtime_share)))
                    .collect();
                let sw = Stopwatch::start();
                alloc = if multi {
                    // sticky seating: survivors keep their server, then
                    // only the servers whose sub-problem fingerprint
                    // actually moved are re-solved (warm); the rest
                    // reuse their previous slots verbatim
                    let placement = sticky_placement(cfg, &pop.live, &mut server_of);
                    let fresh: Vec<u64> = (0..cfg.servers.len())
                        .map(|k| fp.server_fingerprint(&placement, k))
                        .collect();
                    let dirty: Vec<bool> =
                        fresh.iter().zip(&server_stamps).map(|(a, b)| a != b).collect();
                    let reuse: Vec<Option<AgentAllocation>> =
                        pop.live.iter().map(|k| prev_by_key.get(k).copied()).collect();
                    server_stamps = fresh;
                    let req = SolveRequest {
                        options: opts,
                        warm_start: Some(prev),
                        classing: cfg.classing,
                        ..SolveRequest::default()
                    };
                    fp.solve_with_placement_reusing(&placement, &req, &dirty, &reuse)
                } else {
                    resolve_single(
                        &fp,
                        cfg,
                        opts,
                        prev,
                        &prev_by_key,
                        &assoc,
                        &alloc.agents,
                        &pop.live,
                        &mut class_hashes,
                    )
                };
                solve_ms.push(sw.elapsed_s() * 1e3);
                assoc.clone_from(&pop.live);
                reallocations += 1;
            }
            rates = (alloc.objective, alloc.weighted_d_upper(&fp));
        } else {
            rates = static_rates(&fp, &pop.live, &slots, static_groups(&pop.live).as_deref());
        }
        cost_trace.push((t, rates.0));
    }
    let dt = (cfg.horizon_s - t_cur).max(0.0);
    acc_cost += rates.0 * dt;
    acc_du += rates.1 * dt;

    ChurnReport {
        policy,
        horizon_s: cfg.horizon_s,
        events: timeline.events.len(),
        joins: timeline.joins,
        leaves: timeline.leaves,
        bursts: timeline.bursts,
        reallocations,
        realloc_skipped,
        time_avg_cost: acc_cost / cfg.horizon_s,
        time_avg_d_upper: acc_du / cfg.horizon_s,
        final_population: pop.live.len(),
        final_alloc: alloc,
        solve_ms,
        cost_trace,
    }
}

/// Run all three policies over one shared timeline (the comparison the
/// bench and CLI print).
pub fn compare(base: Platform, cfg: &ChurnConfig) -> (Timeline, Vec<ChurnReport>) {
    let tl = timeline(cfg);
    let reports = ChurnPolicy::ALL
        .into_iter()
        .map(|p| run_churn(base, &tl, p, cfg))
        .collect();
    (tl, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Platform {
        Platform::fleet_edge()
    }

    #[test]
    fn timeline_is_deterministic_and_ordered() {
        let cfg = ChurnConfig::default();
        let a = timeline(&cfg);
        let b = timeline(&cfg);
        assert_eq!(a.events, b.events);
        assert!(a.events.windows(2).all(|w| w[0].0 <= w[1].0), "unsorted");
        assert!(a.events.iter().all(|&(t, _)| t <= cfg.horizon_s));
        assert!(a.joins + a.leaves + a.bursts > 0, "default config must churn");
        let c = timeline(&ChurnConfig { seed: 99, ..cfg });
        assert_ne!(a.events, c.events, "seed must matter");
    }

    #[test]
    fn timeline_respects_population_bounds() {
        let cfg = ChurnConfig {
            join_rps: 0.2,
            leave_rps_per_agent: 0.05,
            max_agents: 6,
            ..ChurnConfig::default()
        };
        let tl = timeline(&cfg);
        let mut n = tl.initial.len() as i64;
        for &(_, e) in &tl.events {
            match e {
                ChurnEvent::Join(_) => n += 1,
                ChurnEvent::Leave(_) => n -= 1,
                _ => {}
            }
            assert!(n >= 1, "population emptied");
            assert!(n <= cfg.max_agents as i64, "population overflowed");
        }
    }

    #[test]
    fn solo_agent_bursts_repeat_after_recovery() {
        // regression: with a capped 1-agent fleet every random rate is
        // suppressed while the agent bursts; the timeline must resume
        // once the burst ends instead of going silent for the rest of
        // the horizon
        let cfg = ChurnConfig {
            initial_agents: 1,
            max_agents: 1,
            join_rps: 0.0,
            leave_rps_per_agent: 0.0,
            burst_rps: 0.05,
            burst_duration_s: 10.0,
            tick_s: 0.0,
            horizon_s: 400.0,
            ..ChurnConfig::default()
        };
        let tl = timeline(&cfg);
        assert!(tl.bursts >= 2, "only {} burst(s) fired over 400s", tl.bursts);
        let ends = tl
            .events
            .iter()
            .filter(|(_, e)| matches!(e, ChurnEvent::BurstEnd(_)))
            .count();
        assert!(ends >= 2, "burst ends missing: {ends}");
    }

    #[test]
    fn burst_ends_pair_with_starts() {
        let tl = timeline(&ChurnConfig { burst_rps: 0.05, ..ChurnConfig::default() });
        let mut open: HashSet<u64> = HashSet::new();
        for &(_, e) in &tl.events {
            match e {
                ChurnEvent::BurstStart(k) => {
                    assert!(open.insert(k), "double burst on {k}");
                }
                ChurnEvent::BurstEnd(k) => {
                    assert!(open.remove(&k), "end without start on {k}");
                }
                ChurnEvent::Leave(k) => {
                    open.remove(&k); // leaving cancels the pending end
                }
                _ => {}
            }
        }
    }

    #[test]
    fn no_churn_online_reproduces_static_proposed_exactly() {
        // acceptance: with churn disabled the online path must be
        // indistinguishable from PR 1's static solve_proposed — no
        // re-solve fires (the fingerprint never changes) and the final
        // allocation matches field for field
        let cfg = ChurnConfig { queue: None, ..ChurnConfig::default() }.without_churn();
        let tl = timeline(&cfg);
        assert!(tl.events.iter().all(|&(_, e)| e == ChurnEvent::Tick));
        let online = run_churn(base(), &tl, ChurnPolicy::Online, &cfg);
        let statik = run_churn(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
        assert_eq!(online.reallocations, 0);
        assert!(online.realloc_skipped > 0, "ticks must exercise the fingerprint");
        assert_eq!(online.time_avg_cost, statik.time_avg_cost);
        assert_eq!(online.final_alloc.objective, statik.final_alloc.objective);
        assert_eq!(online.final_alloc.admitted, statik.final_alloc.admitted);
        for (a, b) in online.final_alloc.agents.iter().zip(&statik.final_alloc.agents) {
            assert_eq!(a.design.map(|d| d.b_hat), b.design.map(|d| d.b_hat));
            assert_eq!(a.server_share, b.server_share);
            assert_eq!(a.airtime_share, b.airtime_share);
        }
        // and byte-identical to calling the allocator directly
        let pop = Population { live: tl.initial.clone(), bursting: HashSet::new() };
        let direct = fleet::solve_proposed(&pop.problem(base(), &cfg));
        assert_eq!(direct.objective, online.final_alloc.objective);
    }

    #[test]
    fn online_beats_both_static_policies_under_churn() {
        // acceptance: under joins/leaves/bursts the online re-allocation
        // achieves strictly lower time-averaged fleet cost than the best
        // static allocation computed at t = 0
        for seed in [0u64, 1, 2] {
            let cfg = ChurnConfig { seed, ..ChurnConfig::default() };
            let (tl, reports) = compare(base(), &cfg);
            assert!(tl.joins + tl.leaves + tl.bursts > 0);
            let cost =
                |p: ChurnPolicy| reports.iter().find(|r| r.policy == p).unwrap().time_avg_cost;
            let online = cost(ChurnPolicy::Online);
            let best_static = cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
            assert!(
                online < best_static,
                "seed {seed}: online {online} !< best static {best_static}"
            );
            let r_online = reports.iter().find(|r| r.policy == ChurnPolicy::Online).unwrap();
            assert!(r_online.reallocations > 0, "churn must trigger re-solves");
        }
    }

    #[test]
    fn static_policies_never_reallocate() {
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        for p in [ChurnPolicy::StaticEqual, ChurnPolicy::StaticProposed] {
            let r = run_churn(base(), &tl, p, &cfg);
            assert_eq!(r.reallocations, 0);
            assert!(r.time_avg_cost.is_finite());
            assert!(r.time_avg_d_upper.is_finite());
        }
    }

    #[test]
    fn fingerprint_covers_device_profiles_and_channel_gains() {
        // regression (bugfix): two fleets with identical QoS contracts
        // but different silicon or radios must not alias to the same
        // warm-start cache entry — before tiers existed the fingerprint
        // hashed contracts only
        let base_fleet = |tiers: &[DeviceProfile]| {
            FleetProblem::new(base(), AgentSpec::tiered_fleet(6, tiers))
        };
        let uniform = base_fleet(&AgentSpec::tier_mix(0));
        let hetero = base_fleet(&AgentSpec::tier_mix(2));
        assert_ne!(
            fingerprint(&uniform),
            fingerprint(&hetero),
            "tier mix must change the fleet fingerprint"
        );
        // a lone channel-gain change (same tiers, same contracts) counts
        let mut faded = uniform.clone();
        faded.agents[3].channel_gain = 0.7;
        assert_ne!(fingerprint(&uniform), fingerprint(&faded));
        // and a lone device-constant change counts too
        let mut hotter = uniform.clone();
        hotter.agents[0].device.spec.psi *= 2.0;
        assert_ne!(fingerprint(&uniform), fingerprint(&hotter));
        // while re-deriving the same fleet reproduces the same stamp
        assert_eq!(fingerprint(&uniform), fingerprint(&base_fleet(&AgentSpec::tier_mix(0))));
    }

    #[test]
    fn tiered_churn_online_still_beats_best_static() {
        // newcomers drawn from the full silicon ladder: the online
        // policy's edge survives heterogeneity (bench scenario seed)
        let cfg = ChurnConfig { tiers: AgentSpec::tier_mix(2), seed: 3, ..ChurnConfig::default() };
        let (tl, reports) = compare(base(), &cfg);
        assert!(tl.joins + tl.leaves + tl.bursts > 0);
        let cost =
            |p: ChurnPolicy| reports.iter().find(|r| r.policy == p).unwrap().time_avg_cost;
        let online = cost(ChurnPolicy::Online);
        let best_static = cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
        assert!(online < best_static, "online {online} !< best static {best_static}");
        // the timeline's key->spec map is tier-stable: replaying the
        // same config seats identical silicon
        let (_, again) = compare(base(), &cfg);
        let online_again = again.iter().find(|r| r.policy == ChurnPolicy::Online).unwrap();
        assert_eq!(online_again.time_avg_cost, online);
    }

    #[test]
    fn warm_start_counters_mirror_fingerprint_gating() {
        // observability acceptance: the solver.warm_start.hit/miss
        // counters must equal the report's realloc_skipped/reallocations
        // — the metrics are the fingerprint gate, not a parallel estimate
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let (r, m) =
            crate::obs::metrics::scoped(|| run_churn(base(), &tl, ChurnPolicy::Online, &cfg));
        assert_eq!(m.counter("solver.warm_start.hit"), r.realloc_skipped as u64);
        assert_eq!(m.counter("solver.warm_start.miss"), r.reallocations as u64);
        assert!(r.reallocations > 0, "default config must churn");
        // the re-solves themselves show up as solver activity
        assert!(m.counter("solver.bisection.calls") > 0);
        assert!(m.histogram("span.solver.warm.s").is_some());
        // static policies never touch the warm-start gate
        let (s, ms) =
            crate::obs::metrics::scoped(|| run_churn(base(), &tl, ChurnPolicy::StaticEqual, &cfg));
        assert_eq!(s.reallocations, 0);
        assert_eq!(ms.counter("solver.warm_start.hit") + ms.counter("solver.warm_start.miss"), 0);
    }

    #[test]
    fn cost_trace_integrates_to_the_average() {
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let r = run_churn(base(), &tl, ChurnPolicy::Online, &cfg);
        // re-integrate the step-function trace
        let mut acc = 0.0;
        for w in r.cost_trace.windows(2) {
            acc += w[0].1 * (w[1].0 - w[0].0);
        }
        acc += r.cost_trace.last().unwrap().1
            * (cfg.horizon_s - r.cost_trace.last().unwrap().0);
        assert!(
            (acc / cfg.horizon_s - r.time_avg_cost).abs() < 1e-9,
            "trace does not integrate to the reported average"
        );
    }

    #[test]
    fn multi_server_churn_reuses_untouched_servers() {
        // two identical servers with a fixed half-medium each: any one
        // event (join, leave, burst) perturbs a single server's
        // sub-problem, so the per-server fingerprint gate must re-solve
        // that server and reuse the other one's slots verbatim
        let servers = vec![
            ServerSpec { airtime_fraction: Some(0.5), ..ServerSpec::default() },
            ServerSpec { airtime_fraction: Some(0.5), ..ServerSpec::default() },
        ];
        let cfg = ChurnConfig { servers, ..ChurnConfig::default() };
        let tl = timeline(&cfg);
        assert!(tl.joins + tl.leaves + tl.bursts > 0);
        let (r, m) =
            crate::obs::metrics::scoped(|| run_churn(base(), &tl, ChurnPolicy::Online, &cfg));
        assert!(r.reallocations > 0, "churn must trigger re-solves");
        assert!(r.time_avg_cost.is_finite());
        assert_eq!(m.counter("solver.warm_start.miss"), r.reallocations as u64);
        assert!(m.counter("placement.server.resolved") > 0);
        assert!(
            m.counter("placement.server.reused") > 0,
            "no server ever reused: the per-server gate is not gating"
        );
        // sticky seating: the final placement seats every live agent
        assert_eq!(r.final_alloc.placement.assignment.len(), r.final_population);
    }

    #[test]
    fn multi_server_online_still_beats_best_static() {
        let cfg =
            ChurnConfig { servers: ServerSpec::identical(2), ..ChurnConfig::default() };
        let (tl, reports) = compare(base(), &cfg);
        assert!(tl.joins + tl.leaves + tl.bursts > 0);
        let cost = |p: ChurnPolicy| reports.iter().find(|r| r.policy == p).unwrap().time_avg_cost;
        let online = cost(ChurnPolicy::Online);
        let best_static = cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
        assert!(online < best_static, "online {online} !< best static {best_static}");
        for r in &reports {
            assert!(r.time_avg_cost.is_finite(), "{:?}", r.policy);
        }
    }

    #[test]
    fn churn_policy_parse_errors_name_the_choices() {
        assert_eq!(ChurnPolicy::parse("online"), Ok(ChurnPolicy::Online));
        assert_eq!(ChurnPolicy::parse("static-equal"), Ok(ChurnPolicy::StaticEqual));
        let err = ChurnPolicy::parse("offline").unwrap_err();
        assert_eq!(err.token, "offline");
        assert!(err.choices.contains(&"online-proposed"));
        assert!(err.to_string().contains("static-proposed"));
    }
    // -- PR 9: class-aware warm reuse --

    fn slot(cost: f64) -> AgentAllocation {
        AgentAllocation {
            design: None,
            server_share: 0.1,
            airtime_share: 0.1,
            link_s: 0.0,
            queue_wait_s: 0.0,
            cost,
        }
    }

    #[test]
    fn class_warm_slots_inherits_departed_same_class_slot() {
        // keys 1,2,3 were live; key 2 (class hash 10) departs and key 9
        // of the *same class* joins: the newcomer inherits 2's slot
        // verbatim and the class multiset is an exact relabel
        let prev_hashes = [10u64, 10, 20];
        let prev_assoc = [1u64, 2, 3];
        let prev_agents = [slot(1.0), slot(2.0), slot(3.0)];
        let mut prev_by_key = HashMap::new();
        prev_by_key.insert(1u64, slot(1.0));
        prev_by_key.insert(3u64, slot(3.0));
        let live = [1u64, 3, 9];
        let fresh_hashes = [10u64, 20, 10];
        let (slots, relabel) = class_warm_slots(
            &prev_hashes,
            &prev_assoc,
            &prev_agents,
            &live,
            &fresh_hashes,
            &prev_by_key,
        );
        assert!(relabel, "class multiset unchanged => relabel");
        let costs: Vec<f64> = slots.iter().map(|s| s.unwrap().cost).collect();
        assert_eq!(costs, vec![1.0, 3.0, 2.0], "newcomer 9 must inherit key 2's slot");
    }

    #[test]
    fn class_warm_slots_newcomer_of_new_class_starts_cold() {
        // the joining key's class (hash 30) has no freed slot: its entry
        // stays None and the multiset change disables the relabel path
        let prev_hashes = [10u64, 20];
        let prev_assoc = [1u64, 2];
        let prev_agents = [slot(1.0), slot(2.0)];
        let mut prev_by_key = HashMap::new();
        prev_by_key.insert(1u64, slot(1.0));
        prev_by_key.insert(2u64, slot(2.0));
        let live = [1u64, 2, 9];
        let fresh_hashes = [10u64, 20, 30];
        let (slots, relabel) = class_warm_slots(
            &prev_hashes,
            &prev_assoc,
            &prev_agents,
            &live,
            &fresh_hashes,
            &prev_by_key,
        );
        assert!(!relabel);
        assert!(slots[0].is_some() && slots[1].is_some());
        assert!(slots[2].is_none(), "no same-class donor => cold slot");
        // two departures of one class free two slots, consumed in order
        let (slots, _) = class_warm_slots(
            &[10u64, 10],
            &[1u64, 2],
            &[slot(1.0), slot(2.0)],
            &[8u64, 9],
            &[10u64, 10],
            &HashMap::new(),
        );
        assert_eq!(slots.iter().filter(|s| s.is_some()).count(), 2);
    }

    #[test]
    fn class_reuse_churn_stays_finite_and_defaults_off() {
        // defaults keep the historical path (no classing, no reuse)
        let cfg = ChurnConfig::default();
        assert_eq!(cfg.classing, Classing::PerAgent);
        assert!(!cfg.class_reuse);
        // the class-reuse online run completes with a finite integrated
        // cost on the default timeline and never loses to static-equal
        let reuse_cfg = ChurnConfig {
            classing: Classing::Exact,
            class_reuse: true,
            ..ChurnConfig::default()
        };
        let tl = timeline(&reuse_cfg);
        let online = run_churn(base(), &tl, ChurnPolicy::Online, &reuse_cfg);
        let equal = run_churn(base(), &tl, ChurnPolicy::StaticEqual, &reuse_cfg);
        assert!(online.time_avg_cost.is_finite());
        assert!(
            online.time_avg_cost <= equal.time_avg_cost + 1e-9,
            "class-reuse online {} lost to static equal {}",
            online.time_avg_cost,
            equal.time_avg_cost
        );
    }

    // ---- quantization-policy temporal adaptation ---------------------

    fn assert_report_bit_identical(a: &ChurnReport, b: &ChurnReport) {
        assert_eq!(a.time_avg_cost.to_bits(), b.time_avg_cost.to_bits(), "time_avg_cost");
        assert_eq!(a.time_avg_d_upper.to_bits(), b.time_avg_d_upper.to_bits(), "time_avg_d_upper");
        assert_eq!(a.reallocations, b.reallocations);
        assert_eq!(a.realloc_skipped, b.realloc_skipped);
        assert_eq!(a.final_alloc.objective.to_bits(), b.final_alloc.objective.to_bits());
        assert_eq!(a.final_alloc.admitted, b.final_alloc.admitted);
        for (x, y) in a.final_alloc.agents.iter().zip(&b.final_alloc.agents) {
            assert_eq!(x.design.map(|d| d.b_hat), y.design.map(|d| d.b_hat));
            assert_eq!(x.server_share.to_bits(), y.server_share.to_bits());
            assert_eq!(x.airtime_share.to_bits(), y.airtime_share.to_bits());
        }
    }

    #[test]
    fn adaptive_full_window_replay_is_bit_identical_to_legacy() {
        // acceptance: the full-window Adaptive policy never clamps, so
        // every replay — the churning default timeline included, which
        // subsumes the constant-population case — reproduces the legacy
        // Static(None) run bit for bit, for every policy
        for legacy_cfg in [ChurnConfig::default(), ChurnConfig::default().without_churn()] {
            let adaptive_cfg = ChurnConfig {
                quant: QuantPolicy::Adaptive(crate::quant::mixed::AdaptConfig::default()),
                ..legacy_cfg.clone()
            };
            let tl = timeline(&legacy_cfg);
            for policy in [ChurnPolicy::StaticProposed, ChurnPolicy::Online] {
                let a = run_churn(base(), &tl, policy, &legacy_cfg);
                let b = run_churn(base(), &tl, policy, &adaptive_cfg);
                assert_report_bit_identical(&a, &b);
            }
        }
    }

    #[test]
    fn adaptive_pinned_window_matches_explicit_static_pin() {
        // Adaptive clamped to a one-width window [b, b] is the same
        // policy as Static(Some(b)): same designs, same rejections,
        // same integrated cost
        let tl = timeline(&ChurnConfig::default());
        for b in [2u32, 4, 6] {
            let pinned = ChurnConfig {
                quant: QuantPolicy::Static(Some(b)),
                ..ChurnConfig::default()
            };
            let windowed = ChurnConfig {
                quant: QuantPolicy::Adaptive(crate::quant::mixed::AdaptConfig {
                    min_bits: b,
                    max_bits: b,
                    pressure_backoff: 0.0,
                }),
                ..ChurnConfig::default()
            };
            let x = run_churn(base(), &tl, ChurnPolicy::Online, &pinned);
            let y = run_churn(base(), &tl, ChurnPolicy::Online, &windowed);
            assert_report_bit_identical(&x, &y);
        }
    }
}

