//! Event-driven churn: agents join, burst and leave while contending for
//! one edge server — and the allocation follows them online.
//!
//! The static allocator ([`crate::opt::fleet`]) answers "who gets what"
//! for a fixed population; this module answers what the paper's
//! single-shot design cannot: **what happens when the population changes
//! mid-flight**. A deterministic [`Timeline`] of Poisson joins, leaves
//! and load bursts is generated once per seed and replayed under three
//! policies:
//!
//! * [`ChurnPolicy::StaticEqual`] / [`ChurnPolicy::StaticProposed`] —
//!   the allocation computed at t = 0 is kept forever: departed agents'
//!   shares idle, joiners are turned away (rejection penalty), and a
//!   burst that blows an agent's queue-aware delay budget turns its
//!   frozen design infeasible (penalty while the burst lasts);
//! * [`ChurnPolicy::Online`] — every event re-fingerprints the fleet
//!   problem (the same config-fingerprint idiom the coordinator's
//!   [`Scheduler`](crate::coordinator::Scheduler) uses to invalidate its
//!   plan cache); on a change, the water-filling exchange re-runs
//!   **warm-started** from the previous allocation
//!   ([`crate::opt::fleet::solve_proposed_warm`]). Periodic `Tick`
//!   events re-check the fingerprint and are counted as skipped
//!   re-allocations when nothing changed — with churn disabled the
//!   online path therefore never re-solves and reproduces the static
//!   proposed allocation exactly.
//!
//! The score is the **time-averaged fleet-weighted distortion cost**
//! (the (P1) objective integrated over the horizon, rejection penalties
//! included), plus the matching time-averaged weighted D^U. This is the
//! *analytic* view — what the allocator guarantees between events; the
//! same [`Timeline`] can be replayed at the request level by
//! [`super::events`], which measures the tails (p50/p95/p99 wait and
//! e2e, deadline-violation rate) the integration cannot see.

use crate::obs::metrics as obs_metrics;
use crate::opt::fleet::{
    self, AdmissionPricing, AgentAllocation, AgentSpec, FleetAllocation, FleetProblem,
    ProposedOptions,
};
use crate::system::platform::DeviceProfile;
use crate::system::queue::{QueueDiscipline, QueueModel};
use crate::system::Platform;
use crate::theory::rate_distortion as rd;
use crate::util::rng::Rng;
use crate::util::timer::{Samples, Stopwatch};
use std::collections::{HashMap, HashSet};
use std::hash::{Hash, Hasher};

/// Knobs for a churn run. Rates are per second of simulated time.
#[derive(Debug, Clone)]
pub struct ChurnConfig {
    /// population at t = 0
    pub initial_agents: usize,
    pub horizon_s: f64,
    /// Poisson rate of agents joining (suppressed at `max_agents`)
    pub join_rps: f64,
    /// per-live-agent Poisson rate of leaving (suppressed at 1 agent)
    pub leave_rps_per_agent: f64,
    /// Poisson rate of load bursts starting (on a non-bursting agent)
    pub burst_rps: f64,
    /// arrival-rate multiplier while an agent bursts
    pub burst_factor: f64,
    pub burst_duration_s: f64,
    /// period of fingerprint re-check ticks (0 disables them)
    pub tick_s: f64,
    pub max_agents: usize,
    /// steady-state per-agent request rate (feeds the queue model)
    pub arrival_rps: f64,
    /// shared edge-queue discipline; `None` = PR 1's fluid sharing (load
    /// bursts are then invisible to the allocator)
    pub queue: Option<QueueDiscipline>,
    /// shared uplink
    pub link_rate_bps: f64,
    pub link_base_latency_s: f64,
    /// silicon ladder newcomers draw from: an agent's stable key picks
    /// its tier via [`AgentSpec::tiered_spec`], so a replayed timeline
    /// seats identical silicon every run. The default uniform-Orin
    /// ladder reproduces the homogeneous fleet exactly.
    pub tiers: Vec<DeviceProfile>,
    /// how the allocator prices rejections (the default
    /// [`AdmissionPricing::Uniform`] reproduces the silicon-blind 2/λ
    /// scoring bit for bit)
    pub pricing: AdmissionPricing,
    pub seed: u64,
}

impl Default for ChurnConfig {
    fn default() -> Self {
        ChurnConfig {
            initial_agents: 4,
            horizon_s: 600.0,
            join_rps: 0.02,
            leave_rps_per_agent: 0.003,
            burst_rps: 0.01,
            burst_factor: 5.0,
            burst_duration_s: 40.0,
            tick_s: 20.0,
            max_agents: 16,
            arrival_rps: 0.02,
            queue: Some(QueueDiscipline::Fifo),
            link_rate_bps: 400e6,
            link_base_latency_s: 2e-3,
            tiers: vec![DeviceProfile::orin()],
            pricing: AdmissionPricing::Uniform,
            seed: 0,
        }
    }
}

impl ChurnConfig {
    /// Same fleet, zero churn: only ticks fire. The online policy must
    /// then reproduce the static proposed allocation exactly.
    pub fn without_churn(mut self) -> ChurnConfig {
        self.join_rps = 0.0;
        self.leave_rps_per_agent = 0.0;
        self.burst_rps = 0.0;
        self
    }
}

/// One population change. Agents are identified by a stable key; the
/// key also determines the agent's QoS contract
/// ([`AgentSpec::class_spec`]), so a replayed timeline is exactly
/// reproducible.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChurnEvent {
    Join(u64),
    Leave(u64),
    BurstStart(u64),
    BurstEnd(u64),
    /// periodic fingerprint re-check (no state change)
    Tick,
}

/// A pre-generated event schedule, shared verbatim by every policy so
/// the comparison is apples-to-apples.
#[derive(Debug, Clone)]
pub struct Timeline {
    /// keys live at t = 0
    pub initial: Vec<u64>,
    /// (time, event), non-decreasing in time, all ≤ the horizon
    pub events: Vec<(f64, ChurnEvent)>,
    pub joins: usize,
    pub leaves: usize,
    pub bursts: usize,
}

/// Generate the churn timeline for a config (deterministic per seed).
pub fn timeline(cfg: &ChurnConfig) -> Timeline {
    assert!(cfg.initial_agents >= 1 && cfg.horizon_s > 0.0);
    let mut rng = Rng::new(cfg.seed ^ 0xC0FF_EE00);
    let mut events: Vec<(f64, ChurnEvent)> = Vec::new();
    let mut live: Vec<u64> = (0..cfg.initial_agents as u64).collect();
    let mut next_key = cfg.initial_agents as u64;
    // pending burst ends, kept sorted by end time
    let mut burst_ends: Vec<(f64, u64)> = Vec::new();
    let mut next_tick = if cfg.tick_s > 0.0 { cfg.tick_s } else { f64::INFINITY };
    let mut t = 0.0;
    let (mut joins, mut leaves, mut bursts) = (0usize, 0usize, 0usize);
    loop {
        let bursting: HashSet<u64> = burst_ends.iter().map(|&(_, k)| k).collect();
        let idle: Vec<u64> = live.iter().copied().filter(|k| !bursting.contains(k)).collect();
        let r_join = if live.len() < cfg.max_agents { cfg.join_rps } else { 0.0 };
        let r_leave = if live.len() > 1 {
            cfg.leave_rps_per_agent * live.len() as f64
        } else {
            0.0
        };
        let r_burst = if idle.is_empty() { 0.0 } else { cfg.burst_rps };
        let total = r_join + r_leave + r_burst;
        let t_next = if total > 0.0 { t + rng.exponential(total) } else { f64::INFINITY };
        // deterministic events (burst ends, ticks) due before the next
        // random event fire first
        let mut burst_end_fired = None;
        loop {
            let end = burst_ends.first().map_or(f64::INFINITY, |&(e, _)| e);
            let due = end.min(next_tick);
            if due > t_next || due > cfg.horizon_s {
                break;
            }
            if end <= next_tick {
                let (e, k) = burst_ends.remove(0);
                events.push((e, ChurnEvent::BurstEnd(k)));
                burst_end_fired = Some(e);
            } else {
                events.push((next_tick, ChurnEvent::Tick));
                next_tick += cfg.tick_s;
            }
        }
        if t_next > cfg.horizon_s {
            // an all-suppressed rate vector (e.g. a 1-agent fleet whose
            // only member is mid-burst) is not terminal: a burst end that
            // just fired restores eligibility, so resume the clock there
            // instead of silently ending the timeline
            if total <= 0.0 {
                if let Some(resume) = burst_end_fired {
                    t = resume;
                    continue;
                }
            }
            break;
        }
        t = t_next;
        let pick = rng.f64() * total;
        if pick < r_join {
            let key = next_key;
            next_key += 1;
            live.push(key);
            events.push((t, ChurnEvent::Join(key)));
            joins += 1;
        } else if pick < r_join + r_leave {
            let key = live.remove(rng.below(live.len()));
            burst_ends.retain(|&(_, k)| k != key);
            events.push((t, ChurnEvent::Leave(key)));
            leaves += 1;
        } else {
            let key = idle[rng.below(idle.len())];
            let end = t + cfg.burst_duration_s;
            let at = burst_ends.partition_point(|&(e, _)| e <= end);
            burst_ends.insert(at, (end, key));
            events.push((t, ChurnEvent::BurstStart(key)));
            bursts += 1;
        }
    }
    Timeline { initial: (0..cfg.initial_agents as u64).collect(), events, joins, leaves, bursts }
}

/// Which allocation policy rides the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChurnPolicy {
    /// equal split frozen at t = 0
    StaticEqual,
    /// proposed allocation frozen at t = 0
    StaticProposed,
    /// warm-started proposed re-allocation on every fingerprint change
    Online,
}

impl ChurnPolicy {
    pub const ALL: [ChurnPolicy; 3] =
        [ChurnPolicy::StaticEqual, ChurnPolicy::StaticProposed, ChurnPolicy::Online];

    pub fn name(self) -> &'static str {
        match self {
            ChurnPolicy::StaticEqual => "static-equal",
            ChurnPolicy::StaticProposed => "static-proposed",
            ChurnPolicy::Online => "online-proposed",
        }
    }

    pub fn parse(s: &str) -> Option<ChurnPolicy> {
        match s {
            "static-equal" | "equal" => Some(ChurnPolicy::StaticEqual),
            "static-proposed" | "static" => Some(ChurnPolicy::StaticProposed),
            "online-proposed" | "online" => Some(ChurnPolicy::Online),
            _ => None,
        }
    }
}

/// Outcome of one policy over one timeline.
#[derive(Debug, Clone)]
pub struct ChurnReport {
    pub policy: ChurnPolicy,
    pub horizon_s: f64,
    pub events: usize,
    pub joins: usize,
    pub leaves: usize,
    pub bursts: usize,
    /// online re-solves actually run (0 for static policies)
    pub reallocations: usize,
    /// fingerprint checks that found nothing changed (ticks, no-op
    /// events) — the config-fingerprint reuse at work
    pub realloc_skipped: usize,
    /// ∫ fleet-weighted (P1) cost dt / horizon — the headline score
    pub time_avg_cost: f64,
    /// ∫ fleet-weighted D^U dt / horizon
    pub time_avg_d_upper: f64,
    pub final_population: usize,
    /// the allocation in force at the horizon (static: the t = 0 one)
    pub final_alloc: FleetAllocation,
    /// allocation solve wall times [ms]: the t = 0 solve plus every
    /// online re-solve (static policies only ever record the first)
    pub solve_ms: Samples,
    /// (event time, fleet cost rate) after each event — for plots/CLI
    pub cost_trace: Vec<(f64, f64)>,
}

/// Everything the fleet problem depends on, hashed — the same
/// invalidation idiom as the coordinator scheduler's `config_stamp`.
/// Covers each agent's device profile and channel gain: once agents
/// differ in silicon, two fleets with identical contracts but different
/// tiers must not alias to the same warm-start cache entry (regression-
/// tested below).
pub(crate) fn fingerprint(fp: &FleetProblem) -> u64 {
    let mut h = std::collections::hash_map::DefaultHasher::new();
    fp.n().hash(&mut h);
    for a in &fp.agents {
        a.class.hash(&mut h);
        for x in [a.lambda, a.t0, a.e0, a.weight] {
            x.to_bits().hash(&mut h);
        }
        a.payload_bytes.hash(&mut h);
        a.device.tier.hash(&mut h);
        for x in [
            a.device.spec.f_max,
            a.device.spec.flops_per_cycle,
            a.device.spec.pue,
            a.device.spec.psi,
            a.device.link_gain,
            a.channel_gain,
        ] {
            x.to_bits().hash(&mut h);
        }
    }
    fp.link_rate_bps.to_bits().hash(&mut h);
    fp.link_base_latency_s.to_bits().hash(&mut h);
    match &fp.queue {
        None => 0u8.hash(&mut h),
        Some(q) => {
            1u8.hash(&mut h);
            q.discipline.hash(&mut h);
            for r in &q.arrival_rps {
                r.to_bits().hash(&mut h);
            }
        }
    }
    fp.pricing.hash(&mut h);
    h.finish()
}

/// The live population under a policy run (shared with the event-level
/// replay in [`crate::fleet::events`], so both score against the same
/// fleet problem derivation).
pub(crate) struct Population {
    pub(crate) live: Vec<u64>,
    pub(crate) bursting: HashSet<u64>,
}

impl Population {
    pub(crate) fn spec(cfg: &ChurnConfig, key: u64) -> AgentSpec {
        AgentSpec::tiered_spec(key as usize, &cfg.tiers)
    }

    pub(crate) fn problem(&self, base: Platform, cfg: &ChurnConfig) -> FleetProblem {
        let specs: Vec<AgentSpec> = self.live.iter().map(|&k| Self::spec(cfg, k)).collect();
        let mut fp = FleetProblem::new(base, specs)
            .with_link(cfg.link_rate_bps, cfg.link_base_latency_s)
            .with_pricing(cfg.pricing);
        if let Some(discipline) = cfg.queue {
            let rates: Vec<f64> = self
                .live
                .iter()
                .map(|k| {
                    let boost = if self.bursting.contains(k) { cfg.burst_factor } else { 1.0 };
                    cfg.arrival_rps * boost
                })
                .collect();
            fp = fp.with_queue(QueueModel::new(discipline, rates));
        }
        fp
    }

    pub(crate) fn apply(&mut self, event: ChurnEvent) {
        match event {
            ChurnEvent::Join(k) => self.live.push(k),
            ChurnEvent::Leave(k) => {
                self.live.retain(|&x| x != k);
                self.bursting.remove(&k);
            }
            ChurnEvent::BurstStart(k) => {
                self.bursting.insert(k);
            }
            ChurnEvent::BurstEnd(k) => {
                self.bursting.remove(&k);
            }
            ChurnEvent::Tick => {}
        }
    }
}

/// Cost and D^U rates of a **frozen** allocation under current
/// conditions: keys absent from the t = 0 slots (joiners) pay the
/// rejection penalty; frozen designs that the current conditions (queue
/// load, shares) no longer support pay it too. Feasibility is checked
/// at the actual-share waits of the frozen slots held by the live
/// population (frozen-admitted agents load the queue, everyone else's
/// traffic is turned away) — the same interference model the online
/// policy is scored under, so the comparison stays apples-to-apples.
fn static_rates(
    fp: &FleetProblem,
    live: &[u64],
    slots: &HashMap<u64, AgentAllocation>,
) -> (f64, f64) {
    let (mut cost, mut du) = (0.0, 0.0);
    let (services, activity): (Vec<f64>, Vec<f64>) = live
        .iter()
        .map(|key| match slots.get(key) {
            Some(slot) if slot.design.is_some() => (fp.own_service(slot.server_share), 1.0),
            _ => (f64::INFINITY, 0.0),
        })
        .unzip();
    let waits = fp.queue_waits_given(&services, &activity);
    for (i, key) in live.iter().enumerate() {
        let spec = &fp.agents[i];
        let served_bits = slots.get(key).and_then(|slot| {
            let d = slot.design?;
            fp.agent_problem_at_wait(i, slot.server_share, slot.airtime_share, waits[i])
                .is_some_and(|p| p.is_feasible(&d))
                .then_some(d.b_hat)
        });
        match served_bits {
            Some(b) => {
                cost += spec.weight * rd::bound_gap(b as f64, spec.lambda);
                du += spec.weight * rd::d_upper(b as f64 - 1.0, spec.lambda);
            }
            None => {
                cost += fp.rejection_cost(i);
                du += spec.weight * rd::d_upper(0.0, spec.lambda);
            }
        }
    }
    (cost, du)
}

/// Replay `timeline` under `policy` and integrate the fleet cost.
pub fn run_churn(
    base: Platform,
    timeline: &Timeline,
    policy: ChurnPolicy,
    cfg: &ChurnConfig,
) -> ChurnReport {
    let opts = ProposedOptions::default();
    let mut pop = Population {
        live: timeline.initial.clone(),
        bursting: HashSet::new(),
    };
    let mut fp = pop.problem(base, cfg);
    let mut stamp = fingerprint(&fp);

    // t = 0 allocation
    let mut solve_ms = Samples::new();
    let sw = Stopwatch::start();
    let mut alloc = match policy {
        ChurnPolicy::StaticEqual => fleet::solve_equal_share(&fp),
        ChurnPolicy::StaticProposed | ChurnPolicy::Online => fleet::solve_proposed(&fp),
    };
    solve_ms.push(sw.elapsed_s() * 1e3);
    // frozen per-key slots for the static policies
    let slots: HashMap<u64, AgentAllocation> = pop
        .live
        .iter()
        .zip(&alloc.agents)
        .map(|(&k, a)| (k, *a))
        .collect();
    // which key owns which row of `alloc` (online warm-start mapping)
    let mut assoc: Vec<u64> = pop.live.clone();

    let mut rates = match policy {
        ChurnPolicy::Online => (alloc.objective, alloc.weighted_d_upper(&fp)),
        _ => static_rates(&fp, &pop.live, &slots),
    };
    let mut cost_trace = vec![(0.0, rates.0)];
    let (mut acc_cost, mut acc_du) = (0.0, 0.0);
    let (mut reallocations, mut realloc_skipped) = (0usize, 0usize);
    let mut t_cur = 0.0;

    for &(t, event) in &timeline.events {
        let dt = (t - t_cur).max(0.0);
        acc_cost += rates.0 * dt;
        acc_du += rates.1 * dt;
        t_cur = t;
        pop.apply(event);
        fp = pop.problem(base, cfg);
        if policy == ChurnPolicy::Online {
            let new_stamp = fingerprint(&fp);
            if new_stamp == stamp {
                realloc_skipped += 1;
                obs_metrics::counter_add("solver.warm_start.hit", 1);
            } else {
                stamp = new_stamp;
                obs_metrics::counter_add("solver.warm_start.miss", 1);
                let prev_by_key: HashMap<u64, (f64, f64)> = assoc
                    .iter()
                    .zip(&alloc.agents)
                    .map(|(&k, a)| (k, (a.server_share, a.airtime_share)))
                    .collect();
                let prev: Vec<Option<(f64, f64)>> = pop
                    .live
                    .iter()
                    .map(|k| prev_by_key.get(k).copied())
                    .collect();
                let sw = Stopwatch::start();
                alloc = fleet::solve_proposed_warm(&fp, &prev, opts);
                solve_ms.push(sw.elapsed_s() * 1e3);
                assoc.clone_from(&pop.live);
                reallocations += 1;
            }
            rates = (alloc.objective, alloc.weighted_d_upper(&fp));
        } else {
            rates = static_rates(&fp, &pop.live, &slots);
        }
        cost_trace.push((t, rates.0));
    }
    let dt = (cfg.horizon_s - t_cur).max(0.0);
    acc_cost += rates.0 * dt;
    acc_du += rates.1 * dt;

    ChurnReport {
        policy,
        horizon_s: cfg.horizon_s,
        events: timeline.events.len(),
        joins: timeline.joins,
        leaves: timeline.leaves,
        bursts: timeline.bursts,
        reallocations,
        realloc_skipped,
        time_avg_cost: acc_cost / cfg.horizon_s,
        time_avg_d_upper: acc_du / cfg.horizon_s,
        final_population: pop.live.len(),
        final_alloc: alloc,
        solve_ms,
        cost_trace,
    }
}

/// Run all three policies over one shared timeline (the comparison the
/// bench and CLI print).
pub fn compare(base: Platform, cfg: &ChurnConfig) -> (Timeline, Vec<ChurnReport>) {
    let tl = timeline(cfg);
    let reports = ChurnPolicy::ALL
        .into_iter()
        .map(|p| run_churn(base, &tl, p, cfg))
        .collect();
    (tl, reports)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Platform {
        Platform::fleet_edge()
    }

    #[test]
    fn timeline_is_deterministic_and_ordered() {
        let cfg = ChurnConfig::default();
        let a = timeline(&cfg);
        let b = timeline(&cfg);
        assert_eq!(a.events, b.events);
        assert!(a.events.windows(2).all(|w| w[0].0 <= w[1].0), "unsorted");
        assert!(a.events.iter().all(|&(t, _)| t <= cfg.horizon_s));
        assert!(a.joins + a.leaves + a.bursts > 0, "default config must churn");
        let c = timeline(&ChurnConfig { seed: 99, ..cfg });
        assert_ne!(a.events, c.events, "seed must matter");
    }

    #[test]
    fn timeline_respects_population_bounds() {
        let cfg = ChurnConfig {
            join_rps: 0.2,
            leave_rps_per_agent: 0.05,
            max_agents: 6,
            ..ChurnConfig::default()
        };
        let tl = timeline(&cfg);
        let mut n = tl.initial.len() as i64;
        for &(_, e) in &tl.events {
            match e {
                ChurnEvent::Join(_) => n += 1,
                ChurnEvent::Leave(_) => n -= 1,
                _ => {}
            }
            assert!(n >= 1, "population emptied");
            assert!(n <= cfg.max_agents as i64, "population overflowed");
        }
    }

    #[test]
    fn solo_agent_bursts_repeat_after_recovery() {
        // regression: with a capped 1-agent fleet every random rate is
        // suppressed while the agent bursts; the timeline must resume
        // once the burst ends instead of going silent for the rest of
        // the horizon
        let cfg = ChurnConfig {
            initial_agents: 1,
            max_agents: 1,
            join_rps: 0.0,
            leave_rps_per_agent: 0.0,
            burst_rps: 0.05,
            burst_duration_s: 10.0,
            tick_s: 0.0,
            horizon_s: 400.0,
            ..ChurnConfig::default()
        };
        let tl = timeline(&cfg);
        assert!(tl.bursts >= 2, "only {} burst(s) fired over 400s", tl.bursts);
        let ends = tl
            .events
            .iter()
            .filter(|(_, e)| matches!(e, ChurnEvent::BurstEnd(_)))
            .count();
        assert!(ends >= 2, "burst ends missing: {ends}");
    }

    #[test]
    fn burst_ends_pair_with_starts() {
        let tl = timeline(&ChurnConfig { burst_rps: 0.05, ..ChurnConfig::default() });
        let mut open: HashSet<u64> = HashSet::new();
        for &(_, e) in &tl.events {
            match e {
                ChurnEvent::BurstStart(k) => {
                    assert!(open.insert(k), "double burst on {k}");
                }
                ChurnEvent::BurstEnd(k) => {
                    assert!(open.remove(&k), "end without start on {k}");
                }
                ChurnEvent::Leave(k) => {
                    open.remove(&k); // leaving cancels the pending end
                }
                _ => {}
            }
        }
    }

    #[test]
    fn no_churn_online_reproduces_static_proposed_exactly() {
        // acceptance: with churn disabled the online path must be
        // indistinguishable from PR 1's static solve_proposed — no
        // re-solve fires (the fingerprint never changes) and the final
        // allocation matches field for field
        let cfg = ChurnConfig { queue: None, ..ChurnConfig::default() }.without_churn();
        let tl = timeline(&cfg);
        assert!(tl.events.iter().all(|&(_, e)| e == ChurnEvent::Tick));
        let online = run_churn(base(), &tl, ChurnPolicy::Online, &cfg);
        let statik = run_churn(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
        assert_eq!(online.reallocations, 0);
        assert!(online.realloc_skipped > 0, "ticks must exercise the fingerprint");
        assert_eq!(online.time_avg_cost, statik.time_avg_cost);
        assert_eq!(online.final_alloc.objective, statik.final_alloc.objective);
        assert_eq!(online.final_alloc.admitted, statik.final_alloc.admitted);
        for (a, b) in online.final_alloc.agents.iter().zip(&statik.final_alloc.agents) {
            assert_eq!(a.design.map(|d| d.b_hat), b.design.map(|d| d.b_hat));
            assert_eq!(a.server_share, b.server_share);
            assert_eq!(a.airtime_share, b.airtime_share);
        }
        // and byte-identical to calling the allocator directly
        let pop = Population { live: tl.initial.clone(), bursting: HashSet::new() };
        let direct = fleet::solve_proposed(&pop.problem(base(), &cfg));
        assert_eq!(direct.objective, online.final_alloc.objective);
    }

    #[test]
    fn online_beats_both_static_policies_under_churn() {
        // acceptance: under joins/leaves/bursts the online re-allocation
        // achieves strictly lower time-averaged fleet cost than the best
        // static allocation computed at t = 0
        for seed in [0u64, 1, 2] {
            let cfg = ChurnConfig { seed, ..ChurnConfig::default() };
            let (tl, reports) = compare(base(), &cfg);
            assert!(tl.joins + tl.leaves + tl.bursts > 0);
            let cost =
                |p: ChurnPolicy| reports.iter().find(|r| r.policy == p).unwrap().time_avg_cost;
            let online = cost(ChurnPolicy::Online);
            let best_static = cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
            assert!(
                online < best_static,
                "seed {seed}: online {online} !< best static {best_static}"
            );
            let r_online = reports.iter().find(|r| r.policy == ChurnPolicy::Online).unwrap();
            assert!(r_online.reallocations > 0, "churn must trigger re-solves");
        }
    }

    #[test]
    fn static_policies_never_reallocate() {
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        for p in [ChurnPolicy::StaticEqual, ChurnPolicy::StaticProposed] {
            let r = run_churn(base(), &tl, p, &cfg);
            assert_eq!(r.reallocations, 0);
            assert!(r.time_avg_cost.is_finite());
            assert!(r.time_avg_d_upper.is_finite());
        }
    }

    #[test]
    fn fingerprint_covers_device_profiles_and_channel_gains() {
        // regression (bugfix): two fleets with identical QoS contracts
        // but different silicon or radios must not alias to the same
        // warm-start cache entry — before tiers existed the fingerprint
        // hashed contracts only
        let base_fleet = |tiers: &[DeviceProfile]| {
            FleetProblem::new(base(), AgentSpec::tiered_fleet(6, tiers))
        };
        let uniform = base_fleet(&AgentSpec::tier_mix(0));
        let hetero = base_fleet(&AgentSpec::tier_mix(2));
        assert_ne!(
            fingerprint(&uniform),
            fingerprint(&hetero),
            "tier mix must change the fleet fingerprint"
        );
        // a lone channel-gain change (same tiers, same contracts) counts
        let mut faded = uniform.clone();
        faded.agents[3].channel_gain = 0.7;
        assert_ne!(fingerprint(&uniform), fingerprint(&faded));
        // and a lone device-constant change counts too
        let mut hotter = uniform.clone();
        hotter.agents[0].device.spec.psi *= 2.0;
        assert_ne!(fingerprint(&uniform), fingerprint(&hotter));
        // while re-deriving the same fleet reproduces the same stamp
        assert_eq!(fingerprint(&uniform), fingerprint(&base_fleet(&AgentSpec::tier_mix(0))));
    }

    #[test]
    fn tiered_churn_online_still_beats_best_static() {
        // newcomers drawn from the full silicon ladder: the online
        // policy's edge survives heterogeneity (bench scenario seed)
        let cfg = ChurnConfig { tiers: AgentSpec::tier_mix(2), seed: 3, ..ChurnConfig::default() };
        let (tl, reports) = compare(base(), &cfg);
        assert!(tl.joins + tl.leaves + tl.bursts > 0);
        let cost =
            |p: ChurnPolicy| reports.iter().find(|r| r.policy == p).unwrap().time_avg_cost;
        let online = cost(ChurnPolicy::Online);
        let best_static = cost(ChurnPolicy::StaticEqual).min(cost(ChurnPolicy::StaticProposed));
        assert!(online < best_static, "online {online} !< best static {best_static}");
        // the timeline's key->spec map is tier-stable: replaying the
        // same config seats identical silicon
        let (_, again) = compare(base(), &cfg);
        let online_again = again.iter().find(|r| r.policy == ChurnPolicy::Online).unwrap();
        assert_eq!(online_again.time_avg_cost, online);
    }

    #[test]
    fn warm_start_counters_mirror_fingerprint_gating() {
        // observability acceptance: the solver.warm_start.hit/miss
        // counters must equal the report's realloc_skipped/reallocations
        // — the metrics are the fingerprint gate, not a parallel estimate
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let (r, m) =
            crate::obs::metrics::scoped(|| run_churn(base(), &tl, ChurnPolicy::Online, &cfg));
        assert_eq!(m.counter("solver.warm_start.hit"), r.realloc_skipped as u64);
        assert_eq!(m.counter("solver.warm_start.miss"), r.reallocations as u64);
        assert!(r.reallocations > 0, "default config must churn");
        // the re-solves themselves show up as solver activity
        assert!(m.counter("solver.bisection.calls") > 0);
        assert!(m.histogram("span.solver.warm.s").is_some());
        // static policies never touch the warm-start gate
        let (s, ms) =
            crate::obs::metrics::scoped(|| run_churn(base(), &tl, ChurnPolicy::StaticEqual, &cfg));
        assert_eq!(s.reallocations, 0);
        assert_eq!(ms.counter("solver.warm_start.hit") + ms.counter("solver.warm_start.miss"), 0);
    }

    #[test]
    fn cost_trace_integrates_to_the_average() {
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let r = run_churn(base(), &tl, ChurnPolicy::Online, &cfg);
        // re-integrate the step-function trace
        let mut acc = 0.0;
        for w in r.cost_trace.windows(2) {
            acc += w[0].1 * (w[1].0 - w[0].0);
        }
        acc += r.cost_trace.last().unwrap().1
            * (cfg.horizon_s - r.cost_trace.last().unwrap().0);
        assert!(
            (acc / cfg.horizon_s - r.time_avg_cost).abs() < 1e-9,
            "trace does not integrate to the reported average"
        );
    }
}
