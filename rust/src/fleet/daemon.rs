//! Closed-loop serving daemon: a supervising control loop that runs the
//! event engine ([`super::events`]) in bounded epochs and feeds each
//! epoch's measured telemetry back into the next re-solve.
//!
//! The one-shot replays solve reactively: every fingerprint change takes
//! a warm re-solve ([`super::events::run_events`] under
//! [`ChurnPolicy::Online`]). A long-running serving plane cannot afford
//! that — bursts arrive in pairs (start/end), joiners trickle, and most
//! rate drifts move the optimum by less than the cost of migrating
//! backlogs. The daemon closes the loop instead:
//!
//! * **epochs** — the horizon is cut into `epochs × epoch_s`; at each
//!   boundary the supervisor snapshots the engine's cumulative per-agent
//!   rollups, differences them into this epoch's arrivals/violations/
//!   energy, and records fleet p99 wait/e2e to date;
//! * **measured admission pricing** — each agent's epoch violation rate
//!   (violations ÷ arrivals, quantized to ⅛ steps so the fingerprint
//!   only moves on material drift) becomes its
//!   [`FleetSpec::pressure`](crate::opt::fleet::FleetSpec::pressure)
//!   entry when the config selects
//!   [`AdmissionPricing::Measured`](crate::opt::fleet::AdmissionPricing):
//!   agents observed missing deadlines get cheaper to reject, so the
//!   next solve sheds load where it measurably hurts instead of where
//!   static capability ratios guess it would;
//! * **hysteresis** — a fingerprint change that alters the *agent set*
//!   is always taken (stale rows cannot price a new population), but
//!   rate-/pressure-only drift runs a three-signal gate. **Predicted
//!   gain**: price the drifted problem at the frozen shares via
//!   [`fleet::probe_frozen`] against the counterfactual warm re-solve;
//!   within `gain_threshold` of each other, standing pat is cheap *in
//!   design cost*. **Measured backlog**: the design objective is
//!   first-order flat in shares near the optimum while queue service
//!   rates are not, so a burst can build a tail-wrecking backlog that
//!   the cost probe cannot see — queued work (expected drain time) past
//!   `urgent_backlog_s` makes the change urgent regardless of the cost
//!   delta. Cheap-and-calm drift is skipped outright; a material cost
//!   gain inside the **cooldown** window (`cooldown_s` since the last
//!   take) is deferred to the window's edge; an urgent backlog bypasses
//!   the cooldown and re-solves immediately;
//! * **job queue + cancellation** — timeline events, epoch boundaries
//!   and deferred re-solves are jobs on one deterministic time-ordered
//!   queue; a newer decision supersedes a pending deferred re-solve,
//!   which is counted as cancelled when it surfaces. Graceful shutdown
//!   drains the engine's residual backlog (every request still reaches a
//!   terminal state) and emits a final metrics snapshot.
//!
//! Everything is deterministic: same seed + config ⇒ byte-identical
//! [`DaemonReport::transcript`] (property-tested below). Counters:
//! `daemon.epochs`, `daemon.resolve.taken`,
//! `daemon.resolve.skipped.cooldown`, `daemon.resolve.skipped.gain`,
//! `daemon.resolve.cancelled`.

use super::churn::{timeline, ChurnConfig, ChurnPolicy, Timeline};
use super::events::{EventEngine, EventReport};
use crate::obs::metrics as obs_metrics;
use crate::obs::Metrics;
use crate::opt::fleet::{self, AdmissionPricing, FleetAllocation, ProposedOptions, SolveRequest};
use crate::system::Platform;
use crate::util::timer::Samples;
use std::collections::{BinaryHeap, HashMap};

/// Control-plane knobs layered on a [`ChurnConfig`] workload. The churn
/// config's own horizon is ignored: the daemon serves exactly
/// `epochs × epoch_s` seconds.
#[derive(Debug, Clone)]
pub struct DaemonConfig {
    /// workload + fleet shape (arrival model, tiers, servers, pricing —
    /// select [`AdmissionPricing::Measured`] to let epoch telemetry
    /// reprice admission)
    pub churn: ChurnConfig,
    /// number of telemetry epochs to serve
    pub epochs: usize,
    /// epoch length [s]
    pub epoch_s: f64,
    /// minimum spacing between taken re-solves [s]; rate-only drift
    /// inside the window is deferred, not dropped
    pub cooldown_s: f64,
    /// skip a rate-only re-solve while the frozen-shares cost stays
    /// within this fraction of the counterfactual warm solve's
    /// objective (and the backlog stays calm)
    pub gain_threshold: f64,
    /// measured-backlog urgency threshold [s]: when the engine's queued
    /// work (expected drain time) exceeds this, a pending fingerprint
    /// change re-solves immediately, cooldown or not. Default 5 s — the
    /// loosest class deadline, past which queued requests are already
    /// doomed however flat the cost probe looks
    pub urgent_backlog_s: f64,
    /// disable hysteresis: take every fingerprint change (the A/B
    /// baseline the bench compares against)
    pub resolve_always: bool,
    /// audit mode (tests): at every gain-skip, also run the
    /// counterfactual warm solve without applying it and track the worst
    /// realized-vs-taken cost excess ([`DaemonReport::audit_excess`])
    pub audit: bool,
}

impl Default for DaemonConfig {
    fn default() -> DaemonConfig {
        DaemonConfig {
            churn: ChurnConfig::default(),
            epochs: 8,
            epoch_s: 75.0,
            cooldown_s: 60.0,
            gain_threshold: 0.05,
            urgent_backlog_s: 5.0,
            resolve_always: false,
            audit: false,
        }
    }
}

impl DaemonConfig {
    fn validate(&self) {
        assert!(self.epochs > 0, "daemon needs at least one epoch");
        assert!(
            self.epoch_s.is_finite() && self.epoch_s > 0.0,
            "epoch length must be positive"
        );
        assert!(
            self.cooldown_s.is_finite() && self.cooldown_s >= 0.0,
            "cooldown must be non-negative"
        );
        assert!(
            self.gain_threshold.is_finite() && self.gain_threshold >= 0.0,
            "gain threshold must be non-negative"
        );
        assert!(
            self.urgent_backlog_s.is_finite() && self.urgent_backlog_s >= 0.0,
            "urgency backlog threshold must be non-negative"
        );
    }

    /// Total served horizon [s].
    pub fn horizon_s(&self) -> f64 {
        self.epochs as f64 * self.epoch_s
    }
}

/// One epoch boundary's telemetry snapshot.
#[derive(Debug, Clone)]
pub struct EpochSnapshot {
    /// 1-based epoch index
    pub epoch: usize,
    /// boundary time [s]
    pub t_end_s: f64,
    /// arrivals during this epoch
    pub arrivals: u64,
    /// completions during this epoch
    pub completed: u64,
    /// violations during this epoch (rejected + dropped + missed)
    pub violations: u64,
    /// compute + uplink energy [J] of requests completed this epoch
    pub energy_j: f64,
    /// fleet p99 end-to-end delay over all completions to date [s]
    pub p99_e2e_s: f64,
    /// fleet p99 queue wait over all completions to date [s]
    pub p99_wait_s: f64,
    /// worst per-agent violation pressure after this epoch's refresh —
    /// what Measured pricing and a pressure-backed adaptive quant
    /// policy both react to at the next re-solve
    pub max_pressure: f64,
    /// taken re-solves to date
    pub resolves_taken: usize,
}

/// Outcome of one daemon run.
#[derive(Debug, Clone)]
pub struct DaemonReport {
    /// the drained event-level report (its embedded `metrics` capture is
    /// empty — the daemon-wide capture below spans solves made *between*
    /// engine calls too)
    pub report: EventReport,
    /// per-epoch telemetry snapshots, in order
    pub epochs: Vec<EpochSnapshot>,
    /// fingerprint changes taken as warm re-solves
    pub resolves_taken: usize,
    /// re-solves skipped inside the cooldown window
    pub skipped_cooldown: usize,
    /// re-solves skipped by the gain gate: cheap in design cost *and*
    /// calm in measured backlog
    pub skipped_gain: usize,
    /// deferred re-solves superseded before they fired
    pub cancelled: usize,
    /// deterministic decision log: one line per epoch and per gate
    /// decision — same seed + config ⇒ byte-identical
    pub transcript: String,
    /// audit mode only: worst observed `frozen − counterfactual` cost
    /// excess across gain-skips, normalized by the counterfactual
    /// objective (0 when auditing is off or nothing was skipped)
    pub audit_excess: f64,
    /// the run's full scoped metrics capture (engine replay counters,
    /// queue activity, solver gate counters, `daemon.*` counters) — the
    /// final snapshot graceful shutdown emits
    pub metrics: Metrics,
}

/// Scheduler job kinds, in one deterministic time-ordered queue.
#[derive(Debug, Clone, Copy)]
enum Job {
    /// apply timeline event `i`
    Event(usize),
    /// close epoch `k` (1-based)
    EpochEnd(usize),
    /// cooldown expired: retry the re-solve decision (cancelled when the
    /// id no longer matches the newest deferral)
    DeferredResolve(u64),
}

struct Entry {
    t: f64,
    seq: u64,
    job: Job,
}

// min-heap on (t, seq): earlier time first, insertion order breaks ties
impl PartialEq for Entry {
    fn eq(&self, other: &Entry) -> bool {
        self.seq == other.seq
    }
}
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Entry) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Entry) -> std::cmp::Ordering {
        other.t.total_cmp(&self.t).then_with(|| other.seq.cmp(&self.seq))
    }
}

/// The supervising control loop. Build with [`Daemon::new`], drive to
/// completion with [`Daemon::run`]; everything in between is scheduled
/// internally (tests that need epoch-level visibility read the
/// [`DaemonReport`] transcript and snapshots).
pub struct Daemon {
    cfg: DaemonConfig,
    churn: ChurnConfig,
    tl: Timeline,
    engine: EventEngine,
    heap: BinaryHeap<Entry>,
    seq: u64,
    horizon_s: f64,
    /// time of the last taken re-solve (t = 0 initial solve included)
    last_solve_t: f64,
    /// per-agent measured violation pressure fed to Measured pricing
    pressure: HashMap<u64, f64>,
    /// newest outstanding deferred re-solve (older ones are cancelled)
    pending_resolve: Option<u64>,
    /// cumulative (arrivals, completed, violations, energy) per agent at
    /// the last epoch boundary
    prev_cum: HashMap<u64, (u64, u64, u64, f64)>,
    snapshots: Vec<EpochSnapshot>,
    transcript: String,
    resolves_taken: usize,
    skipped_cooldown: usize,
    skipped_gain: usize,
    cancelled: usize,
    audit_excess: f64,
}

impl Daemon {
    pub fn new(base: Platform, cfg: DaemonConfig) -> Daemon {
        cfg.validate();
        let mut churn = cfg.churn.clone();
        churn.horizon_s = cfg.horizon_s();
        let tl = timeline(&churn);
        let engine = EventEngine::new(base, &tl.initial, ChurnPolicy::Online, &churn);
        let mut daemon = Daemon {
            horizon_s: churn.horizon_s,
            churn,
            engine,
            heap: BinaryHeap::new(),
            seq: 0,
            last_solve_t: 0.0,
            pressure: HashMap::new(),
            pending_resolve: None,
            prev_cum: HashMap::new(),
            snapshots: Vec::new(),
            transcript: String::new(),
            resolves_taken: 0,
            skipped_cooldown: 0,
            skipped_gain: 0,
            cancelled: 0,
            audit_excess: 0.0,
            tl,
            cfg,
        };
        for i in 0..daemon.tl.events.len() {
            let t = daemon.tl.events[i].0;
            daemon.push(t, Job::Event(i));
        }
        for k in 1..=daemon.cfg.epochs {
            daemon.push(k as f64 * daemon.cfg.epoch_s, Job::EpochEnd(k));
        }
        daemon
    }

    fn push(&mut self, t: f64, job: Job) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry { t, seq, job });
    }

    /// Whether measured pressure participates in the fingerprint (only
    /// then may an epoch boundary itself warrant a re-solve): either
    /// Measured admission pricing re-prices rejections from it, or a
    /// pressure-backed adaptive quantization policy re-picks bit-widths
    /// from it at the same warm re-solve boundaries.
    fn measured(&self) -> bool {
        self.churn.pricing == AdmissionPricing::Measured
            || self.churn.quant.pressure_sensitive()
    }

    /// Run the loop to completion: drain the job queue, then shut down
    /// gracefully — the engine drains its residual backlog and the whole
    /// run's metrics capture is attached as the final snapshot.
    pub fn run(self) -> DaemonReport {
        let (mut report, metrics) = obs_metrics::scoped(|| self.run_inner());
        report.metrics = metrics;
        report
    }

    fn run_inner(mut self) -> DaemonReport {
        let _span = obs_metrics::span("daemon.run");
        while let Some(entry) = self.heap.pop() {
            self.step(entry.t, entry.job);
        }
        self.shutdown()
    }

    fn step(&mut self, t: f64, job: Job) {
        match job {
            Job::Event(i) => {
                self.engine.advance_to(t);
                let event = self.tl.events[i].1;
                self.engine.apply_event(t, event);
                self.consider(t, &format!("{event:?}"));
            }
            Job::EpochEnd(k) => {
                self.engine.advance_to(t);
                self.ingest_epoch(k, t);
                if self.measured() {
                    self.consider(t, "epoch");
                }
            }
            Job::DeferredResolve(id) => {
                if self.pending_resolve != Some(id) {
                    self.cancelled += 1;
                    obs_metrics::counter_add("daemon.resolve.cancelled", 1);
                    self.log(format_args!("t={t:.3} cancel deferred#{id}"));
                    return;
                }
                self.pending_resolve = None;
                self.engine.advance_to(t);
                self.consider(t, "deferred");
            }
        }
    }

    /// The hysteresis gate: probe the fingerprint for the current
    /// population (+ pressure) and decide take / skip / defer.
    fn consider(&mut self, t: f64, cause: &str) {
        let pressure =
            if self.measured() { self.pressure.clone() } else { HashMap::new() };
        if !self.engine.gate(&pressure) {
            self.engine.note_skip();
            return;
        }
        if !self.cfg.resolve_always && !self.engine.population_changed() {
            // rate-/pressure-only drift: how bad is standing pat? The
            // cost probe (frozen shares vs the counterfactual warm
            // solve) prices the *design*; the backlog probe measures
            // the *queue* — near the optimum the design cost is flat in
            // shares while service rates are not, so only the backlog
            // sees a burst piling up work under a still-cheap design.
            let shares = self.engine.frozen_shares();
            let frozen = fleet::probe_frozen(&self.engine.fp, &shares);
            let trial = self.counterfactual_warm(shares).objective;
            let material = frozen > trial * (1.0 + self.cfg.gain_threshold);
            let backlog = self.engine.backlog_s(t);
            let urgent = backlog > self.cfg.urgent_backlog_s;
            if !material && !urgent {
                self.skipped_gain += 1;
                obs_metrics::counter_add("daemon.resolve.skipped.gain", 1);
                self.engine.note_skip();
                if self.cfg.audit {
                    self.audit_skip(frozen);
                }
                self.log(format_args!(
                    "t={t:.3} skip gain cause={cause} frozen={frozen:.6} trial={trial:.6} \
                     backlog={backlog:.3}"
                ));
                return;
            }
            if t < self.last_solve_t + self.cfg.cooldown_s && !urgent {
                // material but not urgent, too soon after the last
                // solve: defer to the window edge (a later decision
                // supersedes this deferral)
                self.skipped_cooldown += 1;
                obs_metrics::counter_add("daemon.resolve.skipped.cooldown", 1);
                self.engine.note_skip();
                let due = self.last_solve_t + self.cfg.cooldown_s;
                if due < self.horizon_s {
                    // the deferral's id is its own queue seq
                    let id = self.seq;
                    self.push(due, Job::DeferredResolve(id));
                    self.pending_resolve = Some(id);
                    self.log(format_args!(
                        "t={t:.3} skip cooldown cause={cause} retry_at={due:.3}"
                    ));
                } else {
                    self.log(format_args!("t={t:.3} skip cooldown cause={cause} (run ends)"));
                }
                return;
            }
        }
        let objective = self.engine.resolve(t);
        self.resolves_taken += 1;
        obs_metrics::counter_add("daemon.resolve.taken", 1);
        self.last_solve_t = t;
        self.pending_resolve = None; // supersedes any outstanding deferral
        self.log(format_args!("t={t:.3} take cause={cause} objective={objective:.6}"));
    }

    /// The counterfactual warm solve the hysteresis gate prices, with
    /// the churn config's classing forwarded so the probe runs exactly
    /// what a taken re-solve would (class-collapsed fleets price the
    /// probe per class too).
    fn counterfactual_warm(&self, shares: Vec<Option<(f64, f64)>>) -> FleetAllocation {
        self.engine.fp.solve(&SolveRequest {
            options: ProposedOptions::default(),
            warm_start: Some(shares),
            classing: self.cfg.churn.classing,
            ..SolveRequest::default()
        })
    }

    /// Audit mode: run the counterfactual warm solve the gain gate just
    /// skipped (single-server path — what the soundness property tests
    /// drive) without applying it, and track the realized-cost excess.
    fn audit_skip(&mut self, frozen: f64) {
        let shares = self.engine.frozen_shares();
        let counterfactual = self.counterfactual_warm(shares).objective;
        if counterfactual > 0.0 {
            let excess = (frozen - counterfactual) / counterfactual;
            if excess > self.audit_excess {
                self.audit_excess = excess;
            }
        }
    }

    /// Close epoch `k` at boundary `t`: difference the engine's
    /// cumulative rollups into this epoch's telemetry, refresh the
    /// per-agent violation pressure (⅛-quantized so only material drift
    /// perturbs the fingerprint), and snapshot fleet-tail state.
    fn ingest_epoch(&mut self, k: usize, t: f64) {
        obs_metrics::counter_add("daemon.epochs", 1);
        let (mut arrivals, mut completed, mut violations, mut energy) = (0u64, 0u64, 0u64, 0.0f64);
        let mut e2e = Samples::new();
        let mut wait = Samples::new();
        for (key, st) in self.engine.stats.iter() {
            let cum_v = st.rejected + st.dropped_departure + st.deadline_misses;
            let (pa, pc, pv, pe) = self.prev_cum.get(key).copied().unwrap_or((0, 0, 0, 0.0));
            let (da, dc, dv) = (st.arrivals - pa, st.completed - pc, cum_v - pv);
            arrivals += da;
            completed += dc;
            violations += dv;
            energy += st.energy_j - pe;
            e2e.merge(&st.e2e_s);
            wait.merge(&st.queue_wait_s);
            self.prev_cum.insert(*key, (st.arrivals, st.completed, cum_v, st.energy_j));
            let p = if da == 0 { 0.0 } else { dv as f64 / da as f64 };
            // quantize to 1/8 steps: small jitter must not move the
            // fingerprint (and 1/8 matches the pricing floor's grid)
            self.pressure.insert(*key, ((p * 8.0).round() / 8.0).clamp(0.0, 1.0));
        }
        let snap = EpochSnapshot {
            epoch: k,
            t_end_s: t,
            arrivals,
            completed,
            violations,
            energy_j: energy,
            p99_e2e_s: e2e.p99(),
            p99_wait_s: wait.p99(),
            max_pressure: self.pressure.values().copied().fold(0.0, f64::max),
            resolves_taken: self.resolves_taken,
        };
        self.log(format_args!(
            "epoch {k} t={t:.3} arrivals={arrivals} completed={completed} \
             violations={violations} energy_j={energy:.3} p99_e2e={:.6} p99_wait={:.6} \
             pressure={:.3} solves={}",
            snap.p99_e2e_s, snap.p99_wait_s, snap.max_pressure, snap.resolves_taken
        ));
        self.snapshots.push(snap);
    }

    fn log(&mut self, line: std::fmt::Arguments<'_>) {
        use std::fmt::Write;
        writeln!(self.transcript, "{line}").expect("string write");
    }

    /// Graceful shutdown: drain the engine (residual backlog completes
    /// or drops — conservation is asserted inside), log the final tally.
    fn shutdown(mut self) -> DaemonReport {
        let (t, taken) = (self.horizon_s, self.resolves_taken);
        let (sc, sg, ca) = (self.skipped_cooldown, self.skipped_gain, self.cancelled);
        self.log(format_args!(
            "shutdown t={t:.3} taken={taken} skipped_cooldown={sc} skipped_gain={sg} \
             cancelled={ca}"
        ));
        let report = self.engine.finish();
        DaemonReport {
            report,
            epochs: self.snapshots,
            resolves_taken: self.resolves_taken,
            skipped_cooldown: self.skipped_cooldown,
            skipped_gain: self.skipped_gain,
            cancelled: self.cancelled,
            transcript: self.transcript,
            audit_excess: self.audit_excess,
            metrics: Metrics::new(),
        }
    }
}

/// Convenience one-call runner: build the daemon and drive it to
/// completion (what `qaci fleet --serve` and the bench call).
pub fn run_daemon(base: Platform, cfg: &DaemonConfig) -> DaemonReport {
    Daemon::new(base, cfg.clone()).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::events::run_events;
    use crate::system::Platform;

    fn base() -> Platform {
        Platform::fleet_edge()
    }

    /// The bench's burst-storm workload, sized down only in horizon (the
    /// daemon re-cuts it into epochs anyway).
    fn burst_storm() -> ChurnConfig {
        ChurnConfig {
            initial_agents: 5,
            join_rps: 0.0,
            leave_rps_per_agent: 0.0,
            burst_rps: 0.04,
            burst_factor: 6.0,
            burst_duration_s: 60.0,
            arrival_rps: 0.04,
            tick_s: 20.0,
            seed: 7,
            ..ChurnConfig::default()
        }
    }

    #[test]
    fn daemon_transcript_is_deterministic() {
        // satellite: same seed + config ⇒ byte-identical transcript and
        // identical telemetry, for both pricing modes
        for pricing in [AdmissionPricing::Uniform, AdmissionPricing::Measured] {
            let cfg = DaemonConfig {
                churn: ChurnConfig { pricing, ..burst_storm() },
                ..DaemonConfig::default()
            };
            let a = run_daemon(base(), &cfg);
            let b = run_daemon(base(), &cfg);
            assert_eq!(a.transcript, b.transcript, "{pricing:?}");
            assert!(!a.transcript.is_empty());
            assert_eq!(a.report.arrivals, b.report.arrivals);
            assert_eq!(a.report.e2e_s.values(), b.report.e2e_s.values());
            assert_eq!(a.resolves_taken, b.resolves_taken);
            assert_eq!(a.epochs.len(), cfg.epochs);
        }
    }

    #[test]
    fn resolve_always_daemon_matches_the_online_replay() {
        // with hysteresis off and uniform pricing the daemon is the
        // event replay plus extra (telemetry-only) slot boundaries, so
        // the per-request telemetry and the re-solve schedule must match
        // run_events exactly — slot-refinement invariance, daemon level
        let dcfg = DaemonConfig { resolve_always: true, ..DaemonConfig::default() };
        let mut ccfg = dcfg.churn.clone();
        ccfg.horizon_s = dcfg.horizon_s();
        let tl = timeline(&ccfg);
        let replay = run_events(base(), &tl, ChurnPolicy::Online, &ccfg);
        let daemon = run_daemon(base(), &dcfg);
        assert_eq!(daemon.resolves_taken, replay.reallocations);
        assert_eq!(daemon.report.arrivals, replay.arrivals);
        assert_eq!(daemon.report.e2e_s.values(), replay.e2e_s.values());
        assert_eq!(daemon.report.queue_wait_s.values(), replay.queue_wait_s.values());
        assert_eq!(daemon.report.energy_j, replay.energy_j);
        assert_eq!(daemon.skipped_cooldown + daemon.skipped_gain, 0);
    }

    #[test]
    fn epoch_snapshots_tile_the_run() {
        // epoch deltas must sum to the pre-drain totals: every arrival
        // lands in exactly one epoch (the post-horizon drain completes
        // requests but admits nothing new, so arrivals tile exactly)
        let cfg = DaemonConfig {
            churn: burst_storm(),
            ..DaemonConfig::default()
        };
        let r = run_daemon(base(), &cfg);
        assert_eq!(r.epochs.len(), cfg.epochs);
        let arrivals: u64 = r.epochs.iter().map(|e| e.arrivals).sum();
        assert_eq!(arrivals, r.report.arrivals);
        assert!(r.epochs.iter().any(|e| e.arrivals > 0));
        // counters mirror the report
        assert_eq!(r.metrics.counter("daemon.epochs"), cfg.epochs as u64);
        assert_eq!(r.metrics.counter("daemon.resolve.taken"), r.resolves_taken as u64);
        assert_eq!(
            r.metrics.counter("daemon.resolve.skipped.cooldown"),
            r.skipped_cooldown as u64
        );
        assert_eq!(r.metrics.counter("daemon.resolve.skipped.gain"), r.skipped_gain as u64);
        assert_eq!(r.metrics.counter("daemon.resolve.cancelled"), r.cancelled as u64);
        assert!(r.metrics.histogram("span.daemon.run.s").is_some());
    }

    #[test]
    fn hysteresis_skips_solves_on_the_burst_storm() {
        // the tentpole ordering, unit level (the bench pins it with the
        // full A/B): hysteresis must take at most half of resolve-always'
        // solves on the storm while conserving every request
        let hyst = DaemonConfig {
            churn: ChurnConfig { pricing: AdmissionPricing::Measured, ..burst_storm() },
            ..DaemonConfig::default()
        };
        let always = DaemonConfig { resolve_always: true, ..hyst.clone() };
        let h = run_daemon(base(), &hyst);
        let a = run_daemon(base(), &always);
        assert!(a.resolves_taken > 0, "storm must force re-solves");
        assert!(
            2 * h.resolves_taken <= a.resolves_taken,
            "hysteresis took {} of {} solves",
            h.resolves_taken,
            a.resolves_taken
        );
        assert!(h.skipped_cooldown + h.skipped_gain > 0, "hysteresis must actually skip");
        assert_eq!(
            h.report.arrivals,
            h.report.completed + h.report.rejected + h.report.dropped_departure
        );
    }

    #[test]
    fn skipped_resolves_stay_within_the_gain_threshold() {
        // satellite soundness property: at every gain-skip the realized
        // (frozen-shares) fleet cost stays within gain_threshold of the
        // counterfactual taken solve — audited in-line across seeds
        for seed in [7u64, 11, 23] {
            let cfg = DaemonConfig {
                churn: ChurnConfig { seed, ..burst_storm() },
                audit: true,
                // force the gain gate to do the work: no cooldown window
                cooldown_s: 0.0,
                ..DaemonConfig::default()
            };
            let r = run_daemon(base(), &cfg);
            assert!(
                r.audit_excess <= cfg.gain_threshold + 1e-9,
                "seed {seed}: audit excess {} exceeds threshold {}",
                r.audit_excess,
                cfg.gain_threshold
            );
        }
    }

    #[test]
    fn deferred_resolves_fire_after_the_cooldown_and_supersede() {
        // a cooldown skip schedules a deferred retry; either it fires
        // (a later take or skip decision at the window edge) or a newer
        // decision supersedes it (counted as cancelled) — and the
        // transcript records each outcome
        let cfg = DaemonConfig {
            churn: burst_storm(),
            cooldown_s: 120.0, // wide window: bursts land inside it
            ..DaemonConfig::default()
        };
        let r = run_daemon(base(), &cfg);
        assert!(r.skipped_cooldown > 0, "wide cooldown must defer something");
        assert!(r.transcript.contains("skip cooldown"));
        for line in r.transcript.lines() {
            assert!(!line.is_empty());
        }
        // bookkeeping: every deferral was either consumed or cancelled
        assert!(r.cancelled <= r.skipped_cooldown);
    }

    #[test]
    fn pressure_backed_quant_policy_opens_the_epoch_telemetry_gate() {
        // tentpole: a pressure-backed adaptive policy reads epoch
        // telemetry exactly where Measured pricing does — pressure joins
        // the fingerprint, so epoch boundaries themselves can trigger
        // warm re-solves even under Uniform admission pricing. A
        // backoff-free policy must leave the epoch gate closed.
        use crate::quant::mixed::{AdaptConfig, QuantPolicy};
        let storm = |quant: QuantPolicy| DaemonConfig {
            churn: ChurnConfig { quant, ..burst_storm() },
            resolve_always: true, // isolate the gate: no hysteresis
            ..DaemonConfig::default()
        };
        let backed = storm(QuantPolicy::Adaptive(AdaptConfig {
            min_bits: 1,
            max_bits: 16,
            pressure_backoff: 4.0,
        }));
        let free = storm(QuantPolicy::Adaptive(AdaptConfig::default()));
        let b = run_daemon(base(), &backed);
        let f = run_daemon(base(), &free);
        // the storm generates violations, so pressure becomes non-zero
        // and the epoch boundary decisions appear in the transcript
        assert!(
            b.epochs.iter().any(|e| e.max_pressure > 0.0),
            "storm must register violation pressure"
        );
        assert!(
            b.transcript.contains("cause=epoch"),
            "pressure-backed policy must open the epoch gate"
        );
        assert!(
            !f.transcript.contains("cause=epoch"),
            "backoff-free policy must keep the epoch gate closed"
        );
        // conservation still holds under the adaptive re-picks
        assert_eq!(
            b.report.arrivals,
            b.report.completed + b.report.rejected + b.report.dropped_departure
        );
    }
}
