//! Event-level churn serving: one continuous discrete-event simulation
//! of per-request traffic riding a churn [`Timeline`] — the
//! request-experienced counterpart of [`super::churn`]'s analytic
//! scoring.
//!
//! The analytic replay ([`super::churn::run_churn`]) integrates what the
//! allocator *guarantees* between events; this module measures what
//! requests actually *experience* while agents join, burst and leave:
//!
//! * every live agent emits an open Poisson request stream (rate
//!   [`ChurnConfig::arrival_rps`], burst-boosted while the timeline says
//!   so). Streams are **continuous across events** — a rate change
//!   rescales the residual exponential gap (memorylessness) instead of
//!   redrawing, so no-op boundaries (ticks) leave the sample path
//!   untouched and every policy sees byte-identical arrivals;
//! * each request pays its agent-compute and nominal uplink time at the
//!   operating point in force when it arrives, then its server stage
//!   either serializes through its server's [`EdgeQueue`] — one queue
//!   per [`ChurnConfig::servers`] entry; a re-solve that migrates an
//!   agent moves its waiting backlog queue-to-queue
//!   ([`EdgeQueue::drain_agent`] + re-queue, counted as
//!   `events.migrations`) and jobs are re-priced in place when the
//!   share vector changes (queues are **not** reset) — or runs on the
//!   agent's private server slice ([`ChurnConfig::queue`] = `None`);
//! * dispatch is **slot-bounded** ([`EdgeQueue::pop_due`]): nothing may
//!   start at or after the next churn event, because that event may
//!   re-price, retire or create lanes. The dispatch sequence is invariant
//!   under slot refinement (property-tested below) — the clock cannot
//!   drift across slot boundaries;
//! * lanes are created at `Join` and retired at `Leave`: a departing
//!   agent's in-service job drains on the server, its queued backlog is
//!   explicitly dropped ([`EdgeQueue::drain_agent`]) and accounted —
//!   every request ends **completed, rejected or dropped-at-departure**
//!   (conservation, asserted in the report);
//! * the Online policy re-runs the same fingerprint-gated warm re-solve
//!   as the analytic path, so its re-allocation schedule matches
//!   [`super::churn::ChurnReport`] event for event.
//!
//! The report carries per-agent and fleet-level tail telemetry — p50/p95/
//! p99 queue wait and end-to-end delay plus the deadline-violation rate
//! (a request violates when it is rejected, dropped at departure, or
//! completes after its class's T0). Note the deliberate asymmetry this
//! exposes: a static policy that *rejects* a joiner keeps that traffic
//! out of its queue (and out of its e2e percentiles), while the online
//! policy serves it — so under join-heavy churn the online policy can
//! show a *longer* completed-request tail while serving far more traffic
//! at a far lower violation rate. Under burst overload the static
//! policies' frozen shares let the queue diverge and online's re-solve
//! (degrade, re-balance, or turn the burster away) protects the tail —
//! the designated `burst-storm` bench scenario pins that ordering.

use super::churn::{
    fingerprint, resolve_single, sticky_placement, ChurnConfig, ChurnEvent, ChurnPolicy,
    Population, Timeline,
};
use crate::obs::metrics as obs_metrics;
use crate::obs::Metrics;
use crate::opt::fleet::{
    self, AgentAllocation, AgentSpec, FleetAlgorithm, FleetAllocation, FleetProblem,
    PlacementStrategy, ProposedOptions, ServerSpec, SolveRequest,
};
use crate::opt::Design;
use crate::quant::mixed::QuantPolicy;
use crate::system::queue::EdgeQueue;
use crate::system::{delay, energy, Platform};
use crate::theory::rate_distortion as rd;
use crate::util::rng::Rng;
use crate::util::timer::Samples;
use std::collections::{BTreeMap, HashMap, HashSet, VecDeque};

/// Per-agent request-level rollup over one event-level replay.
#[derive(Debug, Clone)]
pub struct EventAgentReport {
    /// stable churn key (also the agent id jobs carry in the queue)
    pub key: u64,
    pub class: &'static str,
    pub tier: &'static str,
    pub arrivals: u64,
    pub completed: u64,
    /// turned away at arrival (no admitted design) or when a
    /// re-allocation revoked the agent's admission mid-backlog
    pub rejected: u64,
    /// queued work dropped because the agent left mid-service
    pub dropped_departure: u64,
    /// completed requests whose end-to-end delay exceeded the class T0
    pub deadline_misses: u64,
    /// total compute + uplink energy [J] of completed requests, each
    /// priced at the operating point in force when it arrived
    /// ([`crate::system::energy::total_energy`] at the lane's design and
    /// shares — the same per-request pricing as [`super::sim`])
    pub energy_j: f64,
    /// total distortion D^U of completed requests, each priced at the
    /// operating point in force when it arrived: the request-level
    /// mirror of the analytic replay's weighted-D^U integrand (a mixed
    /// allocation prices its own per-group bit vector, every other
    /// policy the served width)
    pub distortion: f64,
    /// end-to-end delay (arrival → server finish) of completed requests
    pub e2e_s: Samples,
    /// measured server-queue wait of completed requests
    pub queue_wait_s: Samples,
}

impl EventAgentReport {
    fn new(key: u64, class: &'static str, tier: &'static str) -> EventAgentReport {
        EventAgentReport {
            key,
            class,
            tier,
            arrivals: 0,
            completed: 0,
            rejected: 0,
            dropped_departure: 0,
            deadline_misses: 0,
            energy_j: 0.0,
            distortion: 0.0,
            e2e_s: Samples::new(),
            queue_wait_s: Samples::new(),
        }
    }

    /// Fraction of this agent's requests that missed their deadline:
    /// rejected and departure-dropped requests count as violations (they
    /// never completed at all), plus completions past T0.
    pub fn violation_rate(&self) -> f64 {
        if self.arrivals == 0 {
            return 0.0;
        }
        (self.deadline_misses + self.rejected + self.dropped_departure) as f64
            / self.arrivals as f64
    }
}

/// Fleet-level outcome of one policy over one timeline, event level.
#[derive(Debug, Clone)]
pub struct EventReport {
    pub policy: ChurnPolicy,
    pub horizon_s: f64,
    pub arrivals: u64,
    pub completed: u64,
    pub rejected: u64,
    pub dropped_departure: u64,
    pub deadline_misses: u64,
    /// fleet total compute + uplink energy [J] over completed requests
    /// (see [`EventAgentReport::energy_j`])
    pub energy_j: f64,
    /// fleet total per-request distortion over completed requests (see
    /// [`EventAgentReport::distortion`])
    pub distortion: f64,
    /// e2e percentiles across every completed request in the fleet
    pub e2e_s: Samples,
    /// measured queue-wait percentiles across every completed request
    pub queue_wait_s: Samples,
    /// online re-solves actually run (0 for static policies); matches
    /// the analytic replay's count on the same timeline
    pub reallocations: usize,
    /// fingerprint checks that found nothing changed
    pub realloc_skipped: usize,
    /// per-agent rollups, ascending by key (departed agents included)
    pub per_agent: Vec<EventAgentReport>,
    /// everything the run recorded into the ambient metrics registry
    /// (`events.*` replay counters, the per-slot `events.queue_depth`
    /// timeline, `queue.*` edge-queue activity, `solver.*` re-solve
    /// counters, spans), captured via [`crate::obs::metrics::scoped`]
    pub metrics: Metrics,
}

impl EventReport {
    /// Fleet deadline-violation rate (see
    /// [`EventAgentReport::violation_rate`] for what counts).
    pub fn violation_rate(&self) -> f64 {
        if self.arrivals == 0 {
            return 0.0;
        }
        (self.deadline_misses + self.rejected + self.dropped_departure) as f64
            / self.arrivals as f64
    }

    /// Mean per-request energy [J] over completed requests (0 when
    /// nothing completed).
    pub fn energy_per_request_j(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.energy_j / self.completed as f64
    }

    /// Mean per-request distortion D^U over completed requests (0 when
    /// nothing completed).
    pub fn distortion_per_request(&self) -> f64 {
        if self.completed == 0 {
            return 0.0;
        }
        self.distortion / self.completed as f64
    }
}

/// One live agent's serving state.
struct EventLane {
    key: u64,
    spec: AgentSpec,
    /// current operating point (`None` = not admitted: arrivals rejected)
    design: Option<Design>,
    mu: f64,
    alpha: f64,
    /// arrival-stream rng, seeded per (config seed, key): identical
    /// across policies
    rng: Rng,
    /// current arrival rate [req/s]
    rate: f64,
    /// which server's queue this agent's requests ride (always 0 on a
    /// single-server fleet; updated at every online re-solve)
    server: usize,
    /// absolute time of the next arrival (∞ while the stream is off)
    next_arrival: f64,
    /// closed-loop mode: a request is in flight, so the arrival stream
    /// is paused until [`Self::release`] observes its completion/drop
    inflight: bool,
    /// fluid mode: when this agent's private server slice frees up
    slice_free_at: f64,
    /// fluid mode: (tag, ready) backlog awaiting the private slice
    pending: VecDeque<(u64, f64)>,
}

impl EventLane {
    fn new(key: u64, cfg: &ChurnConfig, row: Option<&AgentAllocation>) -> EventLane {
        let mut lane = EventLane {
            key,
            spec: super::churn::Population::spec(cfg, key),
            design: None,
            mu: 0.0,
            alpha: 0.0,
            rng: Rng::new(
                cfg.seed
                    ^ key.wrapping_add(1).wrapping_mul(0x9E3779B97F4A7C15)
                    ^ 0xE7E7_0000_0000_0000,
            ),
            rate: 0.0,
            server: 0,
            next_arrival: f64::INFINITY,
            inflight: false,
            slice_free_at: 0.0,
            pending: VecDeque::new(),
        };
        if let Some(row) = row {
            lane.retarget(row);
        }
        lane
    }

    fn retarget(&mut self, row: &AgentAllocation) {
        self.design = row.design;
        self.mu = row.server_share;
        self.alpha = row.airtime_share;
    }

    /// Change the arrival rate at `now`, preserving the residual
    /// exponential gap by memoryless rescaling — a rate change consumes
    /// a draw only when the stream was off, and a *non*-change (ticks,
    /// rival events) consumes nothing, which is what keeps the sample
    /// path invariant under slot refinement and identical across
    /// policies.
    fn set_rate(&mut self, now: f64, rate: f64) {
        if rate == self.rate {
            return;
        }
        let old = self.rate;
        self.rate = rate;
        if self.inflight {
            // closed loop, request outstanding: nothing to retime now —
            // the pending release draws its think gap at the new rate
            return;
        }
        if rate <= 0.0 {
            self.next_arrival = f64::INFINITY;
        } else if old <= 0.0 || !self.next_arrival.is_finite() {
            self.next_arrival = now + self.rng.exponential(rate);
        } else {
            self.next_arrival = now + (self.next_arrival - now) * old / rate;
        }
    }

    /// Closed-loop release: the client observed its request terminate
    /// (complete or drop) at `t`; draw the next exponential think gap
    /// from there. No-op in open-loop mode (`inflight` never set).
    fn release(&mut self, t: f64) {
        if !self.inflight {
            return;
        }
        self.inflight = false;
        self.next_arrival =
            if self.rate > 0.0 { t + self.rng.exponential(self.rate) } else { f64::INFINITY };
    }

    /// Compute + uplink energy of one request at the current operating
    /// point — the per-request pricing [`super::sim`] applies, reused
    /// verbatim so the event replay's totals are comparable.
    fn request_energy(&self, base: Platform) -> f64 {
        let Some(d) = self.design else { return 0.0 };
        let platform = self.spec.platform_at(base, self.mu);
        let e = energy::total_energy(&platform, d.b_hat as f64, d.f, d.f_tilde);
        if e.is_finite() {
            e
        } else {
            0.0
        }
    }

    /// Policy-aware distortion D^U of one request at the current
    /// operating point — a mixed allocation prices its own per-group
    /// bit vector, every other policy the served width.
    fn request_distortion(&self) -> f64 {
        let Some(d) = self.design else { return 0.0 };
        match self.spec.quant {
            QuantPolicy::Mixed(alloc) => alloc.d_upper_total(),
            _ => rd::d_upper(d.b_hat as f64 - 1.0, self.spec.lambda),
        }
    }

    /// `(agent + uplink time, server service time)` at the current
    /// operating point; `None` when not admitted or degenerate.
    fn stage_times(&self, base: Platform, cfg: &ChurnConfig) -> Option<(f64, f64)> {
        let d = self.design?;
        let platform = self.spec.platform_at(base, self.mu);
        let t_agent = delay::agent_delay(&platform, d.b_hat as f64, d.f);
        let t_link = self.spec.link_time_at(cfg.link_rate_bps, cfg.link_base_latency_s, self.alpha);
        let t_server = delay::server_delay(&platform, d.f_tilde);
        let pre = t_agent + t_link;
        (pre.is_finite() && t_server.is_finite()).then_some((pre, t_server))
    }
}

/// What one tag refers to once its job flows through the shared queue.
struct RequestMeta {
    key: u64,
    arrival_s: f64,
    t0: f64,
    /// compute + uplink energy [J] priced at the arrival operating point
    energy_j: f64,
    /// distortion D^U priced at the arrival operating point
    distortion: f64,
}

/// A popped job lands in its agent's report.
fn complete(
    stats: &mut BTreeMap<u64, EventAgentReport>,
    meta: &[RequestMeta],
    tag: u64,
    ready: f64,
    start: f64,
    finish: f64,
) {
    let m = &meta[tag as usize];
    let st = stats.get_mut(&m.key).expect("completed job has stats");
    st.completed += 1;
    st.energy_j += m.energy_j;
    st.distortion += m.distortion;
    let e2e = finish - m.arrival_s;
    st.e2e_s.push(e2e);
    st.queue_wait_s.push((start - ready).max(0.0));
    if e2e > m.t0 {
        st.deadline_misses += 1;
    }
}

/// Generate arrivals strictly before `until` for every live lane. Each
/// request lands in its agent's server's queue (`lane.server`). In
/// closed-loop mode ([`ChurnConfig::closed_loop`]) a successful
/// submission pauses the lane until [`EventLane::release`] observes the
/// request terminate; a rejected arrival retries after a think gap (the
/// same draw the open stream would have made).
fn generate(
    base: Platform,
    cfg: &ChurnConfig,
    pop: &super::churn::Population,
    lanes: &mut BTreeMap<u64, EventLane>,
    stats: &mut BTreeMap<u64, EventAgentReport>,
    meta: &mut Vec<RequestMeta>,
    queues: &mut Option<Vec<EdgeQueue>>,
    until: f64,
) {
    for &key in &pop.live {
        let lane = lanes.get_mut(&key).expect("live agent has a lane");
        while lane.next_arrival < until {
            let arrival = lane.next_arrival;
            let st = stats.get_mut(&key).expect("live agent has stats");
            st.arrivals += 1;
            let Some((pre, t_server)) = lane.stage_times(base, cfg) else {
                st.rejected += 1;
                lane.next_arrival = arrival + lane.rng.exponential(lane.rate);
                continue;
            };
            if cfg.closed_loop {
                lane.inflight = true;
                lane.next_arrival = f64::INFINITY;
            } else {
                lane.next_arrival = arrival + lane.rng.exponential(lane.rate);
            }
            let ready = arrival + pre;
            let tag = meta.len() as u64;
            meta.push(RequestMeta {
                key,
                arrival_s: arrival,
                t0: lane.spec.t0,
                energy_j: lane.request_energy(base),
                distortion: lane.request_distortion(),
            });
            match queues {
                Some(qs) => {
                    qs[lane.server].push_tagged(key as usize, tag, ready, t_server, lane.spec.weight)
                }
                None => lane.pending.push_back((tag, ready)),
            }
        }
    }
}

/// Dispatch everything that can START strictly before `until`.
fn dispatch_until(
    base: Platform,
    cfg: &ChurnConfig,
    pop: &super::churn::Population,
    lanes: &mut BTreeMap<u64, EventLane>,
    stats: &mut BTreeMap<u64, EventAgentReport>,
    meta: &[RequestMeta],
    queues: &mut Option<Vec<EdgeQueue>>,
    until: f64,
) {
    match queues {
        Some(qs) => {
            // each server serializes independently; completion order
            // across servers does not affect any per-request telemetry
            for q in qs.iter_mut() {
                while let Some((job, start, finish)) = q.pop_due(until) {
                    complete(stats, meta, job.tag, job.ready_s, start, finish);
                    if cfg.closed_loop {
                        // the lane may already be gone (departure drains
                        // its in-service job after the Leave)
                        if let Some(lane) = lanes.get_mut(&meta[job.tag as usize].key) {
                            lane.release(finish);
                        }
                    }
                }
            }
        }
        None => {
            // fluid mode: each admitted lane serializes on its own
            // slice; same slot-bounded start gate
            for &key in &pop.live {
                let lane = lanes.get_mut(&key).expect("live agent has a lane");
                while let Some(&(tag, ready)) = lane.pending.front() {
                    let start = lane.slice_free_at.max(ready);
                    if start >= until {
                        break;
                    }
                    let Some((_, t_server)) = lane.stage_times(base, cfg) else {
                        break; // admission revoked; backlog is drained by the caller
                    };
                    let finish = start + t_server;
                    lane.slice_free_at = finish;
                    complete(stats, meta, tag, ready, start, finish);
                    lane.pending.pop_front();
                    lane.release(finish);
                }
            }
        }
    }
}

/// Drop an agent's waiting backlog into the given accounting bucket
/// (`departed` = dropped-at-departure, otherwise admission-revoked →
/// rejected). In closed-loop mode a *surviving* agent whose waiting
/// request was just dropped re-arms its stream at `now` — its client
/// observed the drop; a departing agent's lane is removed by the caller,
/// so nothing re-arms there.
fn drop_backlog(
    lanes: &mut BTreeMap<u64, EventLane>,
    stats: &mut BTreeMap<u64, EventAgentReport>,
    queues: &mut Option<Vec<EdgeQueue>>,
    key: u64,
    departed: bool,
    now: f64,
) {
    let mut n = 0u64;
    if let Some(qs) = queues {
        // the agent only ever queues on its own server, but draining all
        // queues is cheap and immune to a stale lane-server mapping
        for q in qs.iter_mut() {
            n += q.drain_agent(key as usize).len() as u64;
        }
    }
    if let Some(lane) = lanes.get_mut(&key) {
        n += lane.pending.len() as u64;
        lane.pending.clear();
        if n > 0 && !departed {
            lane.release(now);
        }
    }
    let st = stats.get_mut(&key).expect("agent has stats");
    if departed {
        st.dropped_departure += n;
    } else {
        st.rejected += n;
    }
}

/// Replay `timeline` under `policy` at the request level. The run's
/// metrics capture (replay counters, queue activity, solver counters,
/// spans) rides along in [`EventReport::metrics`]; it is also folded
/// into the surrounding ambient registry, so an outer `--metrics-out`
/// snapshot still sees the full run.
pub fn run_events(
    base: Platform,
    timeline: &Timeline,
    policy: ChurnPolicy,
    cfg: &ChurnConfig,
) -> EventReport {
    let (mut report, metrics) =
        obs_metrics::scoped(|| run_events_inner(base, timeline, policy, cfg));
    report.metrics = metrics;
    report
}

fn run_events_inner(
    base: Platform,
    timeline: &Timeline,
    policy: ChurnPolicy,
    cfg: &ChurnConfig,
) -> EventReport {
    let _span = obs_metrics::span("events.run");
    let mut engine = EventEngine::new(base, &timeline.initial, policy, cfg);
    let no_pressure = HashMap::new();
    for &(t, event) in &timeline.events {
        engine.advance_to(t);
        engine.apply_event(t, event);
        if policy == ChurnPolicy::Online {
            // resolve-always: every fingerprint change is taken — the
            // daemon layers its hysteresis on the same gate instead
            if engine.gate(&no_pressure) {
                engine.resolve(t);
            } else {
                engine.note_skip();
            }
        }
    }
    engine.finish()
}

/// The event-level serving machinery behind [`run_events`], factored out
/// so the closed-loop daemon ([`super::daemon`]) can drive it epoch by
/// epoch: advance the clock, apply churn events, and decide *itself*
/// whether a fingerprint change is worth taking (cooldown + predicted
/// gain) instead of the resolve-always gate [`run_events`] applies for
/// [`ChurnPolicy::Online`]. Method order mirrors a replay: [`Self::new`],
/// then per event [`Self::advance_to`] → [`Self::apply_event`] →
/// [`Self::gate`] → [`Self::resolve`] or [`Self::note_skip`], then
/// [`Self::finish`].
pub(crate) struct EventEngine {
    base: Platform,
    cfg: ChurnConfig,
    policy: ChurnPolicy,
    opts: ProposedOptions,
    multi: bool,
    /// live agent set as of the last applied event
    pub(crate) pop: Population,
    /// the fleet problem [`Self::gate`] last built (what a taken
    /// re-solve solves; what the frozen-shares probe prices)
    pub(crate) fp: FleetProblem,
    /// fingerprint of the problem the current allocation was solved for
    stamp: u64,
    /// current allocation; rows are keyed by `assoc`
    pub(crate) alloc: FleetAllocation,
    /// frozen per-key slots for the static policies (joiners have none)
    slots: HashMap<u64, AgentAllocation>,
    /// keys the current `alloc` rows belong to, in row order
    assoc: Vec<u64>,
    server_of: HashMap<u64, usize>,
    server_stamps: Vec<u64>,
    /// class-level fingerprints of the population the current allocation
    /// was solved for ([`ChurnConfig::class_reuse`], single-server only)
    class_hashes: Vec<u64>,
    lanes: BTreeMap<u64, EventLane>,
    /// cumulative per-agent rollups (the daemon snapshots these at epoch
    /// boundaries and differences them into violation pressure)
    pub(crate) stats: BTreeMap<u64, EventAgentReport>,
    queues: Option<Vec<EdgeQueue>>,
    meta: Vec<RequestMeta>,
    reallocations: usize,
    realloc_skipped: usize,
}

impl EventEngine {
    pub(crate) fn new(
        base: Platform,
        initial: &[u64],
        policy: ChurnPolicy,
        cfg: &ChurnConfig,
    ) -> EventEngine {
        let opts = ProposedOptions::default();
        let multi = cfg.servers != [ServerSpec::default()];
        let pop = Population { live: initial.to_vec(), bursting: HashSet::new() };
        let fp = pop.problem(base, cfg);
        let stamp = fingerprint(&fp);
        // the same t = 0 requests as the analytic replay, so the two
        // views share placements and re-allocation schedules event for
        // event
        let alloc = match policy {
            ChurnPolicy::StaticEqual => fp.solve(&SolveRequest {
                algorithm: FleetAlgorithm::EqualShare,
                placement: PlacementStrategy::EqualSpread,
                classing: cfg.classing,
                ..SolveRequest::default()
            }),
            ChurnPolicy::StaticProposed | ChurnPolicy::Online => fp.solve(&SolveRequest {
                classing: cfg.classing,
                ..SolveRequest::default()
            }),
        };
        let slots: HashMap<u64, AgentAllocation> =
            pop.live.iter().zip(&alloc.agents).map(|(&k, a)| (k, *a)).collect();
        let assoc: Vec<u64> = pop.live.clone();
        // online, multi-server: sticky seating + per-server fingerprints,
        // mirroring the analytic replay's gate
        let mut server_of: HashMap<u64, usize> = HashMap::new();
        let mut server_stamps: Vec<u64> = Vec::new();
        if multi && policy == ChurnPolicy::Online {
            for (key, &s) in pop.live.iter().zip(&alloc.placement.assignment) {
                server_of.insert(*key, s);
            }
            server_stamps = (0..cfg.servers.len())
                .map(|k| fp.server_fingerprint(&alloc.placement, k))
                .collect();
        }
        let class_hashes = if policy == ChurnPolicy::Online && cfg.class_reuse && !multi {
            fp.agent_class_hashes()
        } else {
            Vec::new()
        };

        let mut lanes: BTreeMap<u64, EventLane> = BTreeMap::new();
        let mut stats: BTreeMap<u64, EventAgentReport> = BTreeMap::new();
        for ((&k, row), &srv) in pop.live.iter().zip(&alloc.agents).zip(&alloc.placement.assignment)
        {
            let mut lane = EventLane::new(k, cfg, Some(row));
            lane.server = srv;
            lane.set_rate(0.0, cfg.arrival_rps);
            stats.insert(k, EventAgentReport::new(k, lane.spec.class, lane.spec.device.tier));
            lanes.insert(k, lane);
        }

        // one edge queue per server (honoring per-server discipline
        // overrides); `None` keeps PR 1's fluid per-agent slices
        let queues: Option<Vec<EdgeQueue>> = cfg
            .queue
            .map(|d| cfg.servers.iter().map(|srv| EdgeQueue::new(srv.queue.unwrap_or(d))).collect());

        EventEngine {
            base,
            cfg: cfg.clone(),
            policy,
            opts,
            multi,
            pop,
            fp,
            stamp,
            alloc,
            slots,
            assoc,
            server_of,
            server_stamps,
            class_hashes,
            lanes,
            stats,
            queues,
            meta: Vec::new(),
            reallocations: 0,
            realloc_skipped: 0,
        }
    }

    /// generate + dispatch, iterated to a fixpoint in closed-loop mode:
    /// a completion before `run_until` re-arms its client, whose next
    /// arrival may itself land (and need serving) before the boundary.
    /// Each extra pass admits at least one new request and every re-arm
    /// pushes the stream strictly forward, so the loop terminates; in
    /// open mode no pass is added at all — the sample path and rng state
    /// stay byte-identical to the pre-daemon engine.
    fn step(&mut self, gen_until: f64, run_until: f64) {
        generate(
            self.base,
            &self.cfg,
            &self.pop,
            &mut self.lanes,
            &mut self.stats,
            &mut self.meta,
            &mut self.queues,
            gen_until,
        );
        dispatch_until(
            self.base,
            &self.cfg,
            &self.pop,
            &mut self.lanes,
            &mut self.stats,
            &self.meta,
            &mut self.queues,
            run_until,
        );
        if self.cfg.closed_loop {
            loop {
                let admitted = self.meta.len();
                generate(
                    self.base,
                    &self.cfg,
                    &self.pop,
                    &mut self.lanes,
                    &mut self.stats,
                    &mut self.meta,
                    &mut self.queues,
                    gen_until,
                );
                if self.meta.len() == admitted {
                    break;
                }
                dispatch_until(
                    self.base,
                    &self.cfg,
                    &self.pop,
                    &mut self.lanes,
                    &mut self.stats,
                    &self.meta,
                    &mut self.queues,
                    run_until,
                );
            }
        }
    }

    /// Advance the clock to `until`: generate arrivals strictly before
    /// it, dispatch everything that can start before it, and record the
    /// per-slot queue-depth observation at the boundary (fleet total,
    /// plus a per-server breakdown on S > 1 fleets).
    pub(crate) fn advance_to(&mut self, until: f64) {
        self.step(until, until);
        if let Some(qs) = &self.queues {
            let depth: usize = qs.iter().map(EdgeQueue::len).sum();
            obs_metrics::observe("events.queue_depth", depth as f64);
            if self.multi {
                for (k, q) in qs.iter().enumerate() {
                    obs_metrics::observe(&format!("events.queue_depth.s{k}"), q.len() as f64);
                }
            }
            // closed loop: a single-inflight client can never have more
            // than one request waiting, on any server
            if self.cfg.closed_loop && cfg!(debug_assertions) {
                for &k in &self.pop.live {
                    let waiting: usize = qs.iter().map(|q| q.backlog_of(k as usize)).sum();
                    debug_assert!(waiting <= 1, "agent {k} has {waiting} waiting requests");
                }
            }
        }
    }

    /// Apply one churn event at `t` (the caller has already advanced the
    /// clock to `t`): update the live set and create/retire/retime lanes.
    pub(crate) fn apply_event(&mut self, t: f64, event: ChurnEvent) {
        self.pop.apply(event);
        match event {
            ChurnEvent::Join(k) => {
                let mut lane = EventLane::new(k, &self.cfg, self.slots.get(&k));
                lane.set_rate(t, self.cfg.arrival_rps);
                let (class, tier) = (lane.spec.class, lane.spec.device.tier);
                self.stats.entry(k).or_insert_with(|| EventAgentReport::new(k, class, tier));
                self.lanes.insert(k, lane);
            }
            ChurnEvent::Leave(k) => {
                drop_backlog(&mut self.lanes, &mut self.stats, &mut self.queues, k, true, t);
                self.lanes.remove(&k);
            }
            ChurnEvent::BurstStart(k) => {
                if let Some(lane) = self.lanes.get_mut(&k) {
                    lane.set_rate(t, self.cfg.arrival_rps * self.cfg.burst_factor);
                }
            }
            ChurnEvent::BurstEnd(k) => {
                if let Some(lane) = self.lanes.get_mut(&k) {
                    lane.set_rate(t, self.cfg.arrival_rps);
                }
            }
            ChurnEvent::Tick => {}
        }
    }

    /// Rebuild the fleet problem for the current population (carrying
    /// the supplied measured violation pressure, keyed by churn key) and
    /// report whether its fingerprint moved since the last taken
    /// re-solve. Pure probe: neither the stamp nor the gate counters
    /// move — commit the decision with [`Self::resolve`] or
    /// [`Self::note_skip`].
    pub(crate) fn gate(&mut self, pressure: &HashMap<u64, f64>) -> bool {
        self.fp = self.pop.problem_with_pressure(self.base, &self.cfg, pressure);
        fingerprint(&self.fp) != self.stamp
    }

    /// Record a gate check that led to no re-solve (unchanged
    /// fingerprint, or a hysteresis skip).
    pub(crate) fn note_skip(&mut self) {
        self.realloc_skipped += 1;
        obs_metrics::counter_add("solver.warm_start.hit", 1);
    }

    /// Whether the pending problem's agent set differs from the one the
    /// current allocation was solved for (join/leave churn, as opposed
    /// to rate-only or pressure-only drift).
    pub(crate) fn population_changed(&self) -> bool {
        self.pop.live != self.assoc
    }

    /// Previous `(server_share, airtime_share)` per current live agent
    /// (`None` for joiners): the warm-start seed, and the input
    /// [`fleet::probe_frozen`] prices to predict the cost of *not*
    /// re-solving.
    pub(crate) fn frozen_shares(&self) -> Vec<Option<(f64, f64)>> {
        let prev_by_key: HashMap<u64, AgentAllocation> =
            self.assoc.iter().zip(&self.alloc.agents).map(|(&k, a)| (k, *a)).collect();
        self.pop
            .live
            .iter()
            .map(|k| prev_by_key.get(k).map(|a| (a.server_share, a.airtime_share)))
            .collect()
    }

    /// Measured queue backlog at `t` [s]: summed over every server, the
    /// residual in-flight service plus all waiting jobs' priced service
    /// times — the expected drain time were arrivals to stop. Zero in
    /// fluid (queue-less) mode. The daemon's hysteresis gate treats a
    /// backlog past the loosest class deadline as urgent: the frozen
    /// design is already missing deadlines no matter how flat the cost
    /// probe looks.
    pub(crate) fn backlog_s(&self, t: f64) -> f64 {
        self.queues
            .as_ref()
            .map(|qs| qs.iter().map(|q| q.backlog_s(t)).sum())
            .unwrap_or(0.0)
    }

    /// Take the re-solve for the problem [`Self::gate`] last built: warm
    /// solve, retarget lanes, migrate waiting backlogs queue-to-queue,
    /// reject revoked backlogs (at `t`) and re-price waiting jobs.
    /// Returns the new fleet-cost objective.
    pub(crate) fn resolve(&mut self, t: f64) -> f64 {
        self.stamp = fingerprint(&self.fp);
        obs_metrics::counter_add("solver.warm_start.miss", 1);
        let prev_by_key: HashMap<u64, AgentAllocation> =
            self.assoc.iter().zip(&self.alloc.agents).map(|(&k, a)| (k, *a)).collect();
        let prev: Vec<Option<(f64, f64)>> = self
            .pop
            .live
            .iter()
            .map(|k| prev_by_key.get(k).map(|a| (a.server_share, a.airtime_share)))
            .collect();
        self.alloc = if self.multi {
            // the analytic replay's sticky seating + per-server gate, so
            // both views re-solve the same servers
            let placement = sticky_placement(&self.cfg, &self.pop.live, &mut self.server_of);
            let fresh: Vec<u64> = (0..self.cfg.servers.len())
                .map(|k| self.fp.server_fingerprint(&placement, k))
                .collect();
            let dirty: Vec<bool> =
                fresh.iter().zip(&self.server_stamps).map(|(a, b)| a != b).collect();
            let reuse: Vec<Option<AgentAllocation>> =
                self.pop.live.iter().map(|k| prev_by_key.get(k).copied()).collect();
            self.server_stamps = fresh;
            let req = SolveRequest {
                options: self.opts,
                warm_start: Some(prev),
                classing: self.cfg.classing,
                ..SolveRequest::default()
            };
            self.fp.solve_with_placement_reusing(&placement, &req, &dirty, &reuse)
        } else {
            resolve_single(
                &self.fp,
                &self.cfg,
                self.opts,
                prev,
                &prev_by_key,
                &self.assoc,
                &self.alloc.agents,
                &self.pop.live,
                &mut self.class_hashes,
            )
        };
        self.assoc.clone_from(&self.pop.live);
        self.reallocations += 1;
        let mut revoked: Vec<u64> = Vec::new();
        let mut migrated: Vec<(u64, usize, usize)> = Vec::new();
        for (i, &k) in self.pop.live.iter().enumerate() {
            let lane = self.lanes.get_mut(&k).expect("live agent has a lane");
            let had = lane.design.is_some();
            lane.retarget(&self.alloc.agents[i]);
            let srv = self.alloc.placement.assignment[i];
            if srv != lane.server {
                migrated.push((k, lane.server, srv));
                lane.server = srv;
            }
            if lane.design.is_none() && had {
                revoked.push(k);
            }
        }
        // a migrated agent's waiting backlog follows it to the new
        // server's queue (its in-service job, if any, drains where it
        // started); ready times stand
        if let Some(qs) = self.queues.as_mut() {
            for &(k, from, to) in &migrated {
                for job in qs[from].drain_agent(k as usize) {
                    qs[to].push_tagged(job.agent, job.tag, job.ready_s, job.service_s, job.weight);
                }
                obs_metrics::counter_add("events.migrations", 1);
            }
        }
        // a revoked agent's backlog is turned away at admission
        for k in revoked {
            drop_backlog(&mut self.lanes, &mut self.stats, &mut self.queues, k, false, t);
        }
        // waiting jobs follow the new share vector (ready times stand —
        // those stages already ran); the queues are NOT reset: free_at,
        // seq and in-service work carry over
        let (base, cfg, lanes) = (self.base, &self.cfg, &self.lanes);
        if let Some(qs) = self.queues.as_mut() {
            for q in qs.iter_mut() {
                q.reprice(|job| {
                    let lane = &lanes[&(job.agent as u64)];
                    match lane.stage_times(base, cfg) {
                        Some((_, t_server)) => (t_server, lane.spec.weight),
                        None => (job.service_s, job.weight),
                    }
                });
            }
        }
        self.alloc.objective
    }

    /// Drain the run to termination and build the report: arrivals are
    /// bounded by the config horizon, the residual backlog then drains
    /// fully so every request reaches a terminal state (the conservation
    /// invariant is asserted here), and the replay counters land in the
    /// ambient metrics registry.
    pub(crate) fn finish(mut self) -> EventReport {
        let horizon = self.cfg.horizon_s;
        self.step(horizon, f64::INFINITY);

        let per_agent: Vec<EventAgentReport> = self.stats.into_values().collect();
        let mut report = EventReport {
            policy: self.policy,
            horizon_s: horizon,
            arrivals: per_agent.iter().map(|a| a.arrivals).sum(),
            completed: per_agent.iter().map(|a| a.completed).sum(),
            rejected: per_agent.iter().map(|a| a.rejected).sum(),
            dropped_departure: per_agent.iter().map(|a| a.dropped_departure).sum(),
            deadline_misses: per_agent.iter().map(|a| a.deadline_misses).sum(),
            energy_j: per_agent.iter().map(|a| a.energy_j).sum(),
            distortion: per_agent.iter().map(|a| a.distortion).sum(),
            e2e_s: Samples::new(),
            queue_wait_s: Samples::new(),
            reallocations: self.reallocations,
            realloc_skipped: self.realloc_skipped,
            per_agent,
            metrics: Metrics::new(),
        };
        for a in &report.per_agent {
            report.e2e_s.merge(&a.e2e_s);
            report.queue_wait_s.merge(&a.queue_wait_s);
        }
        assert_eq!(
            report.arrivals,
            report.completed + report.rejected + report.dropped_departure,
            "request conservation violated"
        );
        obs_metrics::counter_add("events.arrivals", report.arrivals);
        obs_metrics::counter_add("events.completed", report.completed);
        obs_metrics::counter_add("events.rejected", report.rejected);
        obs_metrics::counter_add("events.dropped", report.dropped_departure);
        obs_metrics::counter_add("events.deadline_misses", report.deadline_misses);
        obs_metrics::counter_add("events.reallocations", report.reallocations as u64);
        obs_metrics::counter_add("events.realloc_skipped", report.realloc_skipped as u64);
        report
    }
}

/// Run all three policies over one shared timeline at the event level
/// (the comparison `qaci fleet --churn --events` and the bench print).
pub fn compare_events(base: Platform, cfg: &ChurnConfig) -> (Timeline, Vec<EventReport>) {
    let tl = super::churn::timeline(cfg);
    let reports = ChurnPolicy::ALL
        .into_iter()
        .map(|p| run_events(base, &tl, p, cfg))
        .collect();
    (tl, reports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::churn::{timeline, Population};
    use crate::system::queue::QueueDiscipline;
    use crate::system::Platform;

    fn base() -> Platform {
        Platform::fleet_edge()
    }

    fn by_policy(reports: &[EventReport], p: ChurnPolicy) -> &EventReport {
        reports.iter().find(|r| r.policy == p).unwrap()
    }

    #[test]
    fn every_request_reaches_a_terminal_state_under_churn() {
        // conservation, per agent and fleet-wide, across policies and
        // both server models
        for queue in [Some(QueueDiscipline::Fifo), None] {
            let cfg = ChurnConfig { queue, ..ChurnConfig::default() };
            let tl = timeline(&cfg);
            for policy in ChurnPolicy::ALL {
                let r = run_events(base(), &tl, policy, &cfg);
                assert_eq!(
                    r.arrivals,
                    r.completed + r.rejected + r.dropped_departure,
                    "{policy:?} {queue:?}"
                );
                for a in &r.per_agent {
                    assert_eq!(
                        a.arrivals,
                        a.completed + a.rejected + a.dropped_departure,
                        "agent {} under {policy:?}",
                        a.key
                    );
                    assert_eq!(a.completed as usize, a.e2e_s.len());
                    assert_eq!(a.completed as usize, a.queue_wait_s.len());
                }
                assert!(r.arrivals > 0, "default churn config must generate traffic");
            }
        }
    }

    #[test]
    fn leave_drops_queued_backlog_and_drains_in_service_work() {
        // hand-crafted timeline: agent 1 bursts at t = 1 (×8 load its
        // frozen share cannot drain) and leaves at t = 30 with a deep
        // backlog — the drop must be explicit (dropped_departure), never
        // a stranded queue entry, and no arrivals occur past departure
        let tl = Timeline {
            initial: vec![0, 1],
            events: vec![(1.0, ChurnEvent::BurstStart(1)), (30.0, ChurnEvent::Leave(1))],
            joins: 0,
            leaves: 1,
            bursts: 1,
        };
        let cfg = ChurnConfig {
            initial_agents: 2,
            arrival_rps: 0.1,
            burst_factor: 8.0,
            horizon_s: 60.0,
            ..ChurnConfig::default()
        };
        let r = run_events(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
        let departed = r.per_agent.iter().find(|a| a.key == 1).unwrap();
        assert!(departed.arrivals > 0);
        assert!(
            departed.dropped_departure > 0,
            "overloaded departure must leave a dropped backlog: {departed:?}"
        );
        assert_eq!(
            departed.arrivals,
            departed.completed + departed.rejected + departed.dropped_departure
        );
        // the survivor keeps serving the whole horizon
        let survivor = r.per_agent.iter().find(|a| a.key == 0).unwrap();
        assert!(survivor.completed > 0);
        assert_eq!(r.dropped_departure, departed.dropped_departure);
    }

    #[test]
    fn deterministic_and_policies_see_identical_arrivals() {
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let a = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        let b = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        assert_eq!(a.e2e_s.values(), b.e2e_s.values());
        assert_eq!(a.queue_wait_s.values(), b.queue_wait_s.values());
        // arrivals are policy-independent, per agent
        let c = run_events(base(), &tl, ChurnPolicy::StaticEqual, &cfg);
        assert_eq!(a.arrivals, c.arrivals);
        for (x, y) in a.per_agent.iter().zip(&c.per_agent) {
            assert_eq!((x.key, x.arrivals), (y.key, y.arrivals));
        }
    }

    #[test]
    fn no_churn_online_reproduces_static_proposed_event_for_event() {
        // with churn disabled the online path never re-solves, so the
        // request-level telemetry must match static-proposed sample for
        // sample
        let cfg = ChurnConfig::default().without_churn();
        let tl = timeline(&cfg);
        let online = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        let statik = run_events(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
        assert_eq!(online.reallocations, 0);
        assert!(online.realloc_skipped > 0, "ticks must exercise the fingerprint");
        assert_eq!(online.e2e_s.values(), statik.e2e_s.values());
        assert_eq!(online.queue_wait_s.values(), statik.queue_wait_s.values());
        assert_eq!(online.deadline_misses, statik.deadline_misses);
    }

    #[test]
    fn telemetry_is_invariant_under_slot_refinement() {
        // the slot-boundary clock-drift regression, engine level: tick
        // events add slot boundaries without changing any state, so the
        // per-request telemetry must be byte-identical with and without
        // them — under churn too (rate rescaling consumes no draws)
        for churn in [false, true] {
            let quiet = |cfg: ChurnConfig| if churn { cfg } else { cfg.without_churn() };
            let with_ticks =
                quiet(ChurnConfig { tick_s: 20.0, arrival_rps: 0.05, ..ChurnConfig::default() });
            let no_ticks =
                quiet(ChurnConfig { tick_s: 0.0, arrival_rps: 0.05, ..ChurnConfig::default() });
            let tl_ticks = timeline(&with_ticks);
            let tl_plain = timeline(&no_ticks);
            let strip = |tl: &Timeline| -> Vec<(f64, ChurnEvent)> {
                tl.events.iter().copied().filter(|(_, e)| *e != ChurnEvent::Tick).collect()
            };
            assert_eq!(
                strip(&tl_ticks),
                strip(&tl_plain),
                "ticks must not perturb the random event stream"
            );
            for policy in ChurnPolicy::ALL {
                let a = run_events(base(), &tl_ticks, policy, &with_ticks);
                let b = run_events(base(), &tl_plain, policy, &no_ticks);
                assert_eq!(
                    a.e2e_s.values(),
                    b.e2e_s.values(),
                    "churn={churn} {policy:?}: slot boundaries drifted the clock"
                );
                assert_eq!(a.queue_wait_s.values(), b.queue_wait_s.values());
                assert_eq!(a.arrivals, b.arrivals);
            }
        }
    }

    #[test]
    fn prop_stationary_mean_wait_converges_to_analytic_mg1() {
        // satellite property: under a stationary (no-churn) load the
        // event-level mean queue wait converges to the analytic
        // non-preemptive M/G/1 wait evaluated at the very service times
        // the engine dispatches (QueueModel::waits_given), per agent,
        // for both disciplines, across 3 seeds. Tolerances from the
        // sample-size analysis: ~1000 completions per agent at ρ ≈ 0.3
        // puts the worst observed relative error near 0.08; 0.20 leaves
        // 2.5× headroom without masking a broken estimator (which is off
        // by integer factors).
        for discipline in [QueueDiscipline::Fifo, QueueDiscipline::WeightedPriority] {
            for seed in [1u64, 2, 3] {
                let cfg = ChurnConfig {
                    initial_agents: 4,
                    queue: Some(discipline),
                    arrival_rps: 0.05,
                    horizon_s: 20_000.0,
                    tick_s: 0.0,
                    seed,
                    ..ChurnConfig::default()
                }
                .without_churn();
                let tl = timeline(&cfg);
                assert!(tl.events.is_empty(), "stationary run must have no events");
                let r = run_events(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
                // the analytic wait at the engine's actual service times
                let pop = Population { live: tl.initial.clone(), bursting: Default::default() };
                let fp = pop.problem(base(), &cfg);
                let alloc = fleet::solve_proposed(&fp);
                let services: Vec<f64> = (0..fp.n())
                    .map(|i| {
                        let d = alloc.agents[i].design.expect("stationary fleet admitted");
                        let p = fp.agent_platform(i, alloc.agents[i].server_share);
                        delay::server_delay(&p, d.f_tilde)
                    })
                    .collect();
                let analytic = fp.queue_waits_given(&services, &vec![1.0; fp.n()]);
                for (i, a) in r.per_agent.iter().enumerate() {
                    assert!(
                        a.completed > 500,
                        "agent {i}: only {} completions — not stationary enough",
                        a.completed
                    );
                    let sim = a.queue_wait_s.mean();
                    let rel = (sim - analytic[i]).abs() / analytic[i];
                    assert!(
                        rel < 0.20,
                        "{discipline:?} seed {seed} agent {i}: sim {sim} vs {} (rel {rel:.3})",
                        analytic[i]
                    );
                }
            }
        }
    }

    #[test]
    fn burst_storm_online_protects_the_tail() {
        // the designated tail scenario (also asserted by the bench):
        // frozen shares let the queue diverge during bursts; the online
        // re-solve keeps p99 bounded — by an order of magnitude here
        let cfg = ChurnConfig {
            initial_agents: 5,
            join_rps: 0.0,
            leave_rps_per_agent: 0.0,
            burst_rps: 0.04,
            burst_factor: 6.0,
            burst_duration_s: 60.0,
            arrival_rps: 0.04,
            tick_s: 20.0,
            seed: 7,
            ..ChurnConfig::default()
        };
        let (tl, reports) = compare_events(base(), &cfg);
        assert!(tl.bursts > 0);
        let online = by_policy(&reports, ChurnPolicy::Online);
        let equal = by_policy(&reports, ChurnPolicy::StaticEqual);
        let statik = by_policy(&reports, ChurnPolicy::StaticProposed);
        let best_static_p99 = equal.e2e_s.p99().min(statik.e2e_s.p99());
        assert!(
            online.e2e_s.p99() < best_static_p99 * 0.5,
            "online p99 {} not clearly below best static {}",
            online.e2e_s.p99(),
            best_static_p99
        );
        assert!(online.reallocations > 0);
        // and the violation rate orders the same way on this scenario
        let best_static_viol = equal.violation_rate().min(statik.violation_rate());
        assert!(
            online.violation_rate() < best_static_viol,
            "online viol {} vs best static {}",
            online.violation_rate(),
            best_static_viol
        );
    }

    #[test]
    fn event_report_embeds_its_metrics_capture() {
        // the report's metrics are the run's own scoped capture: replay
        // counters mirror the report fields exactly, the warm-start gate
        // counters mirror the re-allocation schedule, and the queue's
        // activity (pushes, waits, per-slot depth) is present
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let r = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        let m = &r.metrics;
        assert_eq!(m.counter("events.arrivals"), r.arrivals);
        assert_eq!(m.counter("events.completed"), r.completed);
        assert_eq!(m.counter("events.rejected"), r.rejected);
        assert_eq!(m.counter("events.dropped"), r.dropped_departure);
        assert_eq!(m.counter("events.deadline_misses"), r.deadline_misses);
        assert_eq!(m.counter("events.reallocations"), r.reallocations as u64);
        assert_eq!(m.counter("events.realloc_skipped"), r.realloc_skipped as u64);
        assert_eq!(m.counter("solver.warm_start.miss"), r.reallocations as u64);
        assert_eq!(m.counter("solver.warm_start.hit"), r.realloc_skipped as u64);
        // every completed or departure-dropped request was pushed (and a
        // revocation-rejected one too); arrival-time rejections never are
        assert!(m.counter("queue.push") >= r.completed + r.dropped_departure);
        assert!(m.counter("queue.push") <= r.arrivals);
        assert_eq!(m.counter("queue.pop"), r.completed);
        assert!(m.histogram("events.queue_depth").is_some(), "per-slot depth timeline");
        assert!(m.histogram("queue.wait_s").is_some());
        assert!(m.histogram("span.events.run.s").is_some());
        // a static policy's capture carries no solver gate activity
        let s = run_events(base(), &tl, ChurnPolicy::StaticEqual, &cfg);
        let gate = s.metrics.counter("solver.warm_start.hit")
            + s.metrics.counter("solver.warm_start.miss");
        assert_eq!(gate, 0);
    }

    #[test]
    fn multi_server_replay_conserves_requests_and_tracks_per_server_depth() {
        // S = 2 end-to-end: per-server queues, sticky placement and
        // queue-to-queue migration must never strand a request, the
        // depth timeline gains a per-server breakdown, and the
        // re-allocation schedule still matches the analytic replay
        let cfg = ChurnConfig { servers: ServerSpec::identical(2), ..ChurnConfig::default() };
        let tl = timeline(&cfg);
        for policy in ChurnPolicy::ALL {
            let r = run_events(base(), &tl, policy, &cfg);
            assert_eq!(
                r.arrivals,
                r.completed + r.rejected + r.dropped_departure,
                "{policy:?}"
            );
            assert!(r.arrivals > 0);
        }
        let online = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        assert!(online.reallocations > 0, "churn must trigger re-solves");
        assert!(online.metrics.histogram("events.queue_depth").is_some());
        assert!(online.metrics.histogram("events.queue_depth.s0").is_some());
        assert!(online.metrics.histogram("events.queue_depth.s1").is_some());
        let analytic = super::super::churn::run_churn(base(), &tl, ChurnPolicy::Online, &cfg);
        assert_eq!(online.reallocations, analytic.reallocations);
        assert_eq!(online.realloc_skipped, analytic.realloc_skipped);
    }

    #[test]
    fn closed_loop_arrivals_conserve_requests_and_bound_the_backlog() {
        // satellite: the single-inflight arrival model must preserve the
        // terminal-state invariant under full churn (joins, leaves,
        // bursts, revocations) for every policy and both server models —
        // and, with a shared queue, at most one request per live agent
        // can ever be waiting, so the per-slot depth is bounded by the
        // fleet size cap (open mode has no such bound: bursts pile up)
        for queue in [Some(QueueDiscipline::Fifo), None] {
            let open = ChurnConfig { queue, ..ChurnConfig::default() };
            let closed = ChurnConfig { closed_loop: true, ..open.clone() };
            // the timeline is arrival-model independent (its rng never
            // touches the lanes'), so both models replay the same churn
            let tl = timeline(&open);
            assert_eq!(tl.events, timeline(&closed).events);
            for policy in ChurnPolicy::ALL {
                let c = run_events(base(), &tl, policy, &closed);
                assert_eq!(
                    c.arrivals,
                    c.completed + c.rejected + c.dropped_departure,
                    "closed loop {policy:?} {queue:?}"
                );
                assert!(c.arrivals > 0, "closed loop must generate traffic");
                for a in &c.per_agent {
                    assert_eq!(
                        a.arrivals,
                        a.completed + a.rejected + a.dropped_departure,
                        "agent {} under {policy:?}",
                        a.key
                    );
                }
                if queue.is_some() {
                    let depth = c.metrics.histogram("events.queue_depth").unwrap();
                    let max = depth.values().iter().copied().fold(0.0, f64::max);
                    assert!(
                        max <= closed.max_agents as f64,
                        "{policy:?}: closed-loop backlog {max} exceeds the live-agent bound"
                    );
                }
                // the open replay of the same timeline stays conserved
                // too (it shares lanes-rng seeds but draws differently)
                let o = run_events(base(), &tl, policy, &open);
                assert_eq!(o.arrivals, o.completed + o.rejected + o.dropped_departure);
            }
        }
        // determinism: same seed + config ⇒ identical closed-loop runs
        let cfg = ChurnConfig { closed_loop: true, ..ChurnConfig::default() };
        let tl = timeline(&cfg);
        let a = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        let b = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.e2e_s.values(), b.e2e_s.values());
        assert_eq!(a.queue_wait_s.values(), b.queue_wait_s.values());
    }

    #[test]
    fn per_request_energy_rolls_up_and_matches_the_arrival_operating_point() {
        // satellite: stationary no-churn run — every request is priced
        // at the one static operating point, so each agent's total must
        // equal completions × the analytic per-request energy, and the
        // fleet total must be the per-agent sum
        let cfg = ChurnConfig::default().without_churn();
        let tl = timeline(&cfg);
        let r = run_events(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
        assert!(r.energy_j > 0.0, "completed requests must carry energy");
        assert!(r.energy_per_request_j() > 0.0);
        let total: f64 = r.per_agent.iter().map(|a| a.energy_j).sum();
        assert!((r.energy_j - total).abs() <= 1e-9 * total.max(1.0));
        let pop = Population { live: tl.initial.clone(), bursting: Default::default() };
        let fp = pop.problem(base(), &cfg);
        let alloc = fleet::solve_proposed(&fp);
        for (i, a) in r.per_agent.iter().enumerate() {
            let row = &alloc.agents[i];
            let d = row.design.expect("stationary fleet admitted");
            let p = fp.agent_platform(i, row.server_share);
            let per_req = crate::system::energy::total_energy(&p, d.b_hat as f64, d.f, d.f_tilde);
            let expect = per_req * a.completed as f64;
            assert!(
                (a.energy_j - expect).abs() <= 1e-9 * expect.max(1.0),
                "agent {i}: rolled-up {} vs analytic {expect}",
                a.energy_j
            );
        }
        // under churn the totals still roll up (operating points move,
        // so each request keeps its own arrival-time price)
        let churned = ChurnConfig::default();
        let tl2 = timeline(&churned);
        let rc = run_events(base(), &tl2, ChurnPolicy::Online, &churned);
        assert!(rc.energy_j > 0.0);
        let sum: f64 = rc.per_agent.iter().map(|a| a.energy_j).sum();
        assert!((rc.energy_j - sum).abs() <= 1e-9 * sum.max(1.0));
    }

    #[test]
    fn per_request_distortion_rolls_up_and_coarse_pins_price_higher() {
        // stationary run: each completed request carries the arrival
        // operating point's D^U, so the agent totals are completions ×
        // the analytic bound and the fleet total is the per-agent sum
        let cfg = ChurnConfig::default().without_churn();
        let tl = timeline(&cfg);
        let r = run_events(base(), &tl, ChurnPolicy::StaticProposed, &cfg);
        assert!(r.distortion > 0.0 && r.distortion_per_request() > 0.0);
        let total: f64 = r.per_agent.iter().map(|a| a.distortion).sum();
        assert!((r.distortion - total).abs() <= 1e-9 * total.max(1.0));
        let pop = Population { live: tl.initial.clone(), bursting: Default::default() };
        let fp = pop.problem(base(), &cfg);
        let alloc = fleet::solve_proposed(&fp);
        for (i, a) in r.per_agent.iter().enumerate() {
            let d = alloc.agents[i].design.expect("stationary fleet admitted");
            let expect =
                rd::d_upper(d.b_hat as f64 - 1.0, fp.agents[i].lambda) * a.completed as f64;
            assert!(
                (a.distortion - expect).abs() <= 1e-9 * expect.max(1.0),
                "agent {i}: rolled-up {} vs analytic {expect}",
                a.distortion
            );
            assert!(d.b_hat > 2, "premise: the free pick leaves width headroom");
        }
        // a coarser pinned fleet completes its requests at strictly
        // higher distortion per request — the telemetry the daemon's
        // policy re-pick gets to see
        let coarse = ChurnConfig { quant: QuantPolicy::Static(Some(2)), ..cfg.clone() };
        let rc = run_events(base(), &timeline(&coarse), ChurnPolicy::StaticProposed, &coarse);
        assert!(rc.completed > 0, "pinned width below the free pick must stay feasible");
        assert!(
            rc.distortion_per_request() > r.distortion_per_request(),
            "coarse {} vs free {}",
            rc.distortion_per_request(),
            r.distortion_per_request()
        );
    }

    #[test]
    fn event_reallocation_schedule_matches_the_analytic_replay() {
        // both replays drive the same fingerprint-gated warm re-solve, so
        // their re-allocation counts must agree on any timeline
        let cfg = ChurnConfig::default();
        let tl = timeline(&cfg);
        let analytic = super::super::churn::run_churn(base(), &tl, ChurnPolicy::Online, &cfg);
        let event = run_events(base(), &tl, ChurnPolicy::Online, &cfg);
        assert_eq!(event.reallocations, analytic.reallocations);
        assert_eq!(event.realloc_skipped, analytic.realloc_skipped);
    }
}
