//! Fleet-scale co-inference: serving many embodied agents against one
//! edge server and one wireless medium.
//!
//! The layer sits on top of the existing single-pair stack and reuses it
//! wholesale:
//!
//! * the **contention model** lives in
//!   [`crate::system::channel::MultiAccessChannel`] (airtime shares) and
//!   [`crate::opt::fleet::FleetProblem::agent_platform`] (server-frequency
//!   shares) — each agent's slice of the shared resources is expressed as
//!   an ordinary [`crate::system::Platform`];
//! * the **joint multi-agent allocator** is [`crate::opt::fleet`]:
//!   per-agent exact bisection inside a water-filling outer loop, with
//!   greedy admission control and equal-share / feasible-random baselines;
//! * the **serving loop** ([`sim`]) drives one router + batcher +
//!   contention-aware [`crate::coordinator::Scheduler`] per agent through
//!   the shared medium, and aggregates per-agent
//!   [`crate::coordinator::Telemetry`] into fleet-level percentiles.
//!
//! Entry points: `qaci fleet` (CLI), `benches/fleet_scale.rs` (N-sweep),
//! `examples/fleet_sweep.rs`.

pub mod sim;

pub use sim::{AgentReport, FleetReport, FleetSimConfig};
