//! Fleet-scale co-inference: serving many embodied agents against one
//! edge server and one wireless medium.
//!
//! The layer sits on top of the existing single-pair stack and reuses it
//! wholesale:
//!
//! * the **contention model** lives in
//!   [`crate::system::channel::MultiAccessChannel`] (airtime shares),
//!   [`crate::opt::fleet::FleetProblem::agent_platform`] (server-frequency
//!   shares) and [`crate::system::queue`] (the shared edge queue between
//!   the per-agent batchers and the server shares) — each agent's slice
//!   of the shared resources is expressed as an ordinary
//!   [`crate::system::Platform`];
//! * the **joint multi-agent allocator** is [`crate::opt::fleet`]:
//!   per-agent exact bisection inside a water-filling outer loop, with
//!   greedy admission control, queue-aware delay budgets and equal-share
//!   / feasible-random baselines;
//! * the **serving loop** ([`sim`]) drives one router + batcher +
//!   contention-aware [`crate::coordinator::Scheduler`] per agent through
//!   the shared medium (and optionally the shared serialized edge
//!   queue), and aggregates per-agent [`crate::coordinator::Telemetry`]
//!   into fleet-level percentiles;
//! * the **churn loop** ([`churn`]) replays Poisson joins/leaves/bursts
//!   and re-runs the allocator online, warm-started from the previous
//!   allocation and gated by a config fingerprint — static t = 0
//!   allocations ride the same timeline for comparison;
//! * the **event-level churn replay** ([`events`]) threads per-request
//!   traffic through the same timeline: lanes created/retired at
//!   join/leave, queued work dropped (and accounted) at departure,
//!   re-allocations swapping the share vector without resetting the
//!   shared queue — producing the tail telemetry (p50/p95/p99 queue wait
//!   and end-to-end delay, deadline-violation rate, per-request energy)
//!   the analytic scoring cannot see;
//! * the **closed-loop serving daemon** ([`daemon`]) promotes the event
//!   replay into a supervising control plane: bounded telemetry epochs,
//!   measured-pressure admission pricing, and hysteresis (predicted-gain
//!   probe + measured-backlog urgency + cooldown) deciding which
//!   fingerprint changes are worth a re-solve at all — with deferred
//!   re-solves scheduled, superseded and cancelled on one deterministic
//!   job queue.
//!
//! Entry points: `qaci fleet [--churn [--events]] [--serve]` (CLI),
//! `benches/fleet_scale.rs` (N-sweep), `benches/fleet_churn.rs` (policy
//! comparison under churn), `benches/fleet_daemon.rs` (hysteresis vs
//! resolve-always A/B), `examples/fleet_sweep.rs`,
//! `examples/fleet_churn.rs`.

pub mod churn;
pub mod daemon;
pub mod events;
pub mod sim;

pub use churn::{ChurnConfig, ChurnPolicy, ChurnReport, Timeline};
pub use daemon::{Daemon, DaemonConfig, DaemonReport, EpochSnapshot};
pub use events::{EventAgentReport, EventReport};
pub use sim::{AgentReport, FleetReport, FleetSimConfig, LaneSeedMix};
