//! Fleet serving loop: an analytic (no-PJRT) discrete simulation of N
//! agents sharing the medium and the edge server.
//!
//! Per admitted agent, the loop instantiates the same request path the
//! single-pair coordinator uses — [`Router`] (QoS budgets → plans, via a
//! **contention-aware** [`Scheduler`] built on the agent's own silicon
//! tier, its share-scaled server slice, and its link/queue-reduced delay
//! budget; the shared medium applies each agent's channel gain) and
//! [`Batcher`] — then
//! walks the arrival sequence with a single-inflight FIFO per agent: a
//! request starts once it has arrived, its batch was released, and the
//! agent's previous request finished; it pays the simulated
//! agent-compute, shared-uplink (jittered [`MultiAccessChannel`]) and
//! server-compute times and lands in the agent's [`Telemetry`]. The
//! *allocation's* per-agent design is the authoritative operating point
//! for the simulated physics (for proposed/equal-share it coincides with
//! the router's exact re-plan; the random baseline is simulated at its
//! own random designs). Agents the allocator rejected (admission
//! control) have every request counted as rejected.
//!
//! Two server models are available ([`FleetSimConfig::queue`]):
//!
//! * `None` — PR 1's fluid sharing: every agent's server stage runs
//!   concurrently on its frequency slice (optimistic; no cross-agent
//!   interference beyond the shared medium).
//! * `Some(discipline)` — the server-stage jobs serialize through one
//!   [`EdgeQueue`] **per server** (FIFO or weighted priority, honoring
//!   per-server [`ServerSpec::queue`] overrides), routed by the
//!   allocation's [`Placement`](crate::opt::fleet::Placement): a burst
//!   from one agent head-of-line blocks its server's tenants, and the
//!   measured per-request queue wait lands in the report — the
//!   event-level counterpart of the allocator's analytic
//!   [`QueueModel`](crate::system::queue::QueueModel) term. A
//!   single-server fleet reproduces the historical one-shared-queue
//!   behavior exactly.
//!
//! Delay/energy are the paper's models (eq. 4–9) at the planned
//! frequencies; wall-clock execution is intentionally absent so the loop
//! runs in tests and benches without artifacts.
//!
//! This loop serves a **fixed population** against one allocation; its
//! churning counterpart — lanes created and retired mid-flight by a
//! [`Timeline`](super::churn::Timeline), slot-bounded dispatch, queued
//! work re-priced on re-allocation — is [`super::events`].

use crate::coordinator::batcher::{Batcher, BatcherConfig};
use crate::coordinator::router::{QosPolicy, RoutedRequest, Router};
use crate::coordinator::scheduler::Algorithm;
use crate::coordinator::telemetry::{RequestRecord, Telemetry};
use crate::coordinator::Scheduler;
use crate::data::workload::{generate, Arrival};
use crate::opt::fleet::{FleetAllocation, FleetProblem, ServerSpec};
use crate::opt::Design;
use crate::quant::Scheme;
use crate::system::channel::MultiAccessChannel;
use crate::system::queue::{EdgeQueue, QueueDiscipline};
use crate::system::{delay, energy, Platform};
use crate::util::cli::ParseError;
use crate::util::timer::Samples;

/// How per-lane RNG streams are derived from the run seed.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum LaneSeedMix {
    /// the historical additive offsets (`seed + i`, `seed + 0x9E37·(i+1)`)
    /// — kept as the default so pinned-telemetry transcripts stay byte
    /// for byte. Adjacent run seeds overlap lane streams: run seed `s`,
    /// lane `i+1` draws the same scheduler stream as run seed `s+1`,
    /// lane `i`
    #[default]
    Additive,
    /// a full splitmix64 finalizer over (seed, stream, lane): the mix is
    /// a bijection of the combined input, so no pair of adjacent run
    /// seeds can reproduce each other's lane streams (cross-seed
    /// non-collision is tested below)
    Splitmix,
}

impl LaneSeedMix {
    /// CLI spelling — rejects unknown tokens via [`ParseError`] instead
    /// of silently falling back to the default mix.
    pub fn parse(s: &str) -> Result<LaneSeedMix, ParseError> {
        match s {
            "additive" => Ok(LaneSeedMix::Additive),
            "splitmix" | "splitmix64" => Ok(LaneSeedMix::Splitmix),
            _ => Err(ParseError::new("lane mix", s, &["additive", "splitmix"])),
        }
    }
}

/// splitmix64-finalized lane seed: `stream` separates generator families
/// (arrival vs scheduler) so one lane's streams are independent too.
pub fn splitmix_lane(seed: u64, stream: u64, lane: u64) -> u64 {
    let mut z = seed
        ^ stream.wrapping_mul(0xA076_1D64_78BD_642F)
        ^ lane.wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Knobs for one fleet serving run.
#[derive(Debug, Clone, Copy)]
pub struct FleetSimConfig {
    pub requests_per_agent: usize,
    pub arrival: Arrival,
    pub seed: u64,
    pub batcher: BatcherConfig,
    /// `Some(discipline)` serializes all server stages through one
    /// shared edge queue; `None` keeps PR 1's concurrent slices
    pub queue: Option<QueueDiscipline>,
    /// per-lane RNG stream derivation; the [`LaneSeedMix::Additive`]
    /// default reproduces the historical streams byte for byte
    pub lane_mix: LaneSeedMix,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        FleetSimConfig {
            requests_per_agent: 16,
            arrival: Arrival::Poisson { lambda_rps: 2.0 },
            seed: 0,
            batcher: BatcherConfig::default(),
            queue: None,
            lane_mix: LaneSeedMix::default(),
        }
    }
}

/// One agent's rollup over the run.
#[derive(Debug, Clone)]
pub struct AgentReport {
    pub agent: usize,
    pub class: &'static str,
    /// silicon tier name ([`crate::system::platform::DeviceProfile`])
    pub tier: &'static str,
    pub admitted: bool,
    /// planned bit-width (0 when rejected)
    pub b_hat: u32,
    pub server_share: f64,
    pub airtime_share: f64,
    pub served: usize,
    pub rejected: u64,
    /// end-to-end time (queue + compute + shared uplink) per request [s]
    pub e2e_s: Samples,
    /// simulated energy per request [J]
    pub energy_j: Samples,
    /// time spent waiting in the shared edge queue per request [s]
    /// (all zeros when the run used concurrent slices)
    pub queue_wait_s: Samples,
    /// records whose *compute* delay/energy broke the planned budgets
    pub qos_violations: usize,
    /// requests whose *end-to-end* time exceeded the agent's full T0
    pub slo_misses: usize,
}

/// Fleet-level aggregate (per-agent [`Telemetry`] rolled up).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub per_agent: Vec<AgentReport>,
    /// e2e percentiles across every served request in the fleet
    pub e2e_s: Samples,
    /// shared edge-queue waits across every served request
    pub queue_wait_s: Samples,
    pub served: usize,
    pub rejected: u64,
    pub qos_violations: usize,
    pub slo_misses: usize,
    pub total_energy_j: f64,
    /// the allocation's fleet-weighted (P1) objective
    pub weighted_gap: f64,
    /// fleet-weighted distortion upper bound Σ w_i D^U(b̂_i − 1)
    pub weighted_d_upper: f64,
    pub admitted_agents: usize,
}

/// One admitted agent's prepared request stream plus its runtime state.
struct Lane {
    agent: usize,
    /// which server's queue this lane's server stages ride
    server: usize,
    design: Design,
    platform: Platform,
    weight: f64,
    t0_full: f64,
    payload_bytes: usize,
    /// (routed request, batch release time) in execution order
    jobs: Vec<(RoutedRequest, f64)>,
    next: usize,
    prev_finish: f64,
    /// readiness + stage times of the head job once computed
    head: Option<(f64, f64, f64)>, // (ready_s, t_agent, t_link)
    telemetry: Telemetry,
    e2e: Samples,
    waits: Samples,
    slo_misses: usize,
}

impl Lane {
    /// Compute (once) when the head job is ready for the server stage;
    /// draws the head's uplink jitter from the shared medium.
    fn ready_head(&mut self, medium: &mut MultiAccessChannel) -> Option<(f64, f64, f64)> {
        if self.head.is_none() {
            let (rr, release) = self.jobs.get(self.next)?;
            let t_agent =
                delay::agent_delay(&self.platform, self.design.b_hat as f64, self.design.f);
            let t_link = medium.transmit_s(self.agent, self.payload_bytes);
            let start = rr.request.arrival_s.max(*release).max(self.prev_finish);
            self.head = Some((start + t_agent + t_link, t_agent, t_link));
        }
        self.head
    }

    /// Land the head job: it occupied the server during
    /// [server_start, server_finish).
    fn finish_head(&mut self, ready_s: f64, t_agent: f64, t_link: f64, finish: f64) {
        let (rr, _) = &self.jobs[self.next];
        let t_server = delay::server_delay(&self.platform, self.design.f_tilde);
        let total = finish - rr.request.arrival_s;
        self.e2e.push(total);
        self.waits.push((finish - t_server - ready_s).max(0.0));
        if total > self.t0_full {
            self.slo_misses += 1;
        }
        self.telemetry.push(RequestRecord {
            id: rr.request.id,
            class: rr.request.class,
            sample: rr.request.sample,
            b_hat: self.design.b_hat,
            t_agent_sim_s: t_agent,
            t_server_sim_s: t_server,
            t_link_s: t_link,
            energy_sim_j: energy::total_energy(
                &self.platform,
                self.design.b_hat as f64,
                self.design.f,
                self.design.f_tilde,
            ),
            t_wall_s: 0.0,
            caption: String::new(),
            t0: rr.t0,
            e0: rr.e0,
        });
        self.prev_finish = finish;
        self.next += 1;
        self.head = None;
    }
}

/// PR 1 semantics: slices run concurrently; each agent's chain is
/// independent once the (jittered) medium draws are made.
fn dispatch_fluid(lanes: &mut [Lane], medium: &mut MultiAccessChannel) {
    for lane in lanes {
        while let Some((ready, t_agent, t_link)) = lane.ready_head(medium) {
            let t_server = delay::server_delay(&lane.platform, lane.design.f_tilde);
            lane.finish_head(ready, t_agent, t_link, ready + t_server);
        }
    }
}

/// Server stages serialize through one [`EdgeQueue`] per server, routed
/// by each lane's placement. The population is fixed for the whole run,
/// so the unbounded [`EdgeQueue::pop`] is sound here; the churning
/// variant of this loop lives in [`super::events`] and must use the
/// slot-bounded [`EdgeQueue::pop_due`] instead (lanes appear, retire and
/// re-price mid-flight there).
fn dispatch_queued(
    lanes: &mut [Lane],
    medium: &mut MultiAccessChannel,
    discipline: QueueDiscipline,
    servers: &[ServerSpec],
) {
    let mut queues: Vec<EdgeQueue> =
        servers.iter().map(|s| EdgeQueue::new(s.queue.unwrap_or(discipline))).collect();
    loop {
        let mut pushed_any = false;
        for lane in lanes.iter_mut() {
            if lane.head.is_none() {
                if let Some((ready, _, _)) = lane.ready_head(medium) {
                    let t_server = delay::server_delay(&lane.platform, lane.design.f_tilde);
                    queues[lane.server].push(lane.agent, ready, t_server, lane.weight);
                    pushed_any = true;
                }
            }
        }
        // one dispatch per round, taken from the first server with a
        // dispatchable job — servers are independent, so the cross-server
        // completion order carries no telemetry; a single-server fleet
        // reproduces the historical shared-queue order exactly
        let mut popped = None;
        for q in queues.iter_mut() {
            if let Some((job, _, finish)) = q.pop() {
                popped = Some((job, finish));
                break;
            }
        }
        let Some((job, finish)) = popped else {
            debug_assert!(!pushed_any, "pushed jobs must be dispatchable");
            break;
        };
        let lane = lanes
            .iter_mut()
            .find(|l| l.agent == job.agent)
            .expect("job belongs to a lane");
        let (ready, t_agent, t_link) = lane.head.expect("head in flight");
        lane.finish_head(ready, t_agent, t_link, finish);
    }
}

/// Run the fleet serving loop for a solved allocation.
pub fn run(fp: &FleetProblem, alloc: &FleetAllocation, cfg: &FleetSimConfig) -> FleetReport {
    assert_eq!(alloc.agents.len(), fp.n());
    let mut medium = MultiAccessChannel::new(
        fp.link_rate_bps,
        fp.link_base_latency_s,
        0.10,
        alloc.airtime_shares(),
        cfg.seed ^ 0x5EED_F1EE,
    )
    .with_gains(fp.agents.iter().map(|a| a.channel_gain).collect());
    let mut rejected_reports: Vec<AgentReport> = Vec::new();
    let mut lanes: Vec<Lane> = Vec::new();

    // ---- phase 1: per-agent routing + batching (order-preserving) ----
    for (i, slot) in alloc.agents.iter().enumerate() {
        let spec = &fp.agents[i];
        let arrival_seed = match cfg.lane_mix {
            LaneSeedMix::Additive => cfg.seed.wrapping_add(0x9E37 * (i as u64 + 1)),
            LaneSeedMix::Splitmix => splitmix_lane(cfg.seed, 1, i as u64),
        };
        let mut requests = generate(cfg.requests_per_agent, 1, cfg.arrival, arrival_seed);
        for r in &mut requests {
            r.class = spec.class;
        }

        let Some(design) = slot.design else {
            // admission control rejected this agent: nothing is served
            rejected_reports.push(AgentReport {
                agent: i,
                class: spec.class,
                tier: spec.device.tier,
                admitted: false,
                b_hat: 0,
                server_share: slot.server_share,
                airtime_share: slot.airtime_share,
                served: 0,
                rejected: requests.len() as u64,
                e2e_s: Samples::new(),
                energy_j: Samples::new(),
                queue_wait_s: Samples::new(),
                qos_violations: 0,
                slo_misses: 0,
            });
            continue;
        };

        // contention-aware scheduler: the agent's own silicon tier on
        // its slice of the shared server, and the delay budget net of
        // its nominal uplink time and the analytic queue wait its design
        // was scored at (fixed-point when it converged)
        let platform = fp.agent_platform(i, slot.server_share);
        let t0_compute = spec.t0 - fp.link_time(i, slot.airtime_share) - slot.queue_wait_s;
        // the historical additive offset collides across adjacent runs
        // (seed s, lane i+1 == seed s+1, lane i); Splitmix derives
        // collision-free streams instead
        let scheduler_seed = match cfg.lane_mix {
            LaneSeedMix::Additive => cfg.seed.wrapping_add(i as u64),
            LaneSeedMix::Splitmix => splitmix_lane(cfg.seed, 2, i as u64),
        };
        let scheduler = Scheduler::new(
            platform,
            spec.lambda,
            Algorithm::Exact,
            Scheme::Uniform,
            scheduler_seed,
        );
        let mut router = Router::new(
            QosPolicy::new(&[(spec.class, t0_compute, spec.e0)]),
            scheduler,
        );
        let mut batcher = Batcher::new(cfg.batcher);
        let mut telemetry = Telemetry::default();
        let mut jobs: Vec<(RoutedRequest, f64)> = Vec::new();

        // `release_s` = simulated time the batcher actually let the batch
        // go (size fill, deadline poll, or end-of-stream drain): requests
        // pay their batching wait in e2e, not just queue + compute
        let end_s = requests.last().map_or(0.0, |r| r.arrival_s);
        for req in requests {
            let now = req.arrival_s;
            match router.route(req) {
                Ok(routed) => {
                    if let Some(batch) = batcher.push(routed) {
                        jobs.extend(batch.requests.into_iter().map(|rr| (rr, now)));
                    }
                    for batch in batcher.poll_deadlines(now) {
                        jobs.extend(batch.requests.into_iter().map(|rr| (rr, now)));
                    }
                }
                Err(_) => telemetry.rejected += 1,
            }
        }
        // the stream ends at the last arrival; leftover groups drain then
        for batch in batcher.drain() {
            jobs.extend(batch.requests.into_iter().map(|rr| (rr, end_s)));
        }

        lanes.push(Lane {
            agent: i,
            server: alloc.placement.assignment.get(i).copied().unwrap_or(0),
            design,
            platform,
            weight: spec.weight,
            t0_full: spec.t0,
            payload_bytes: spec.payload_bytes,
            jobs,
            next: 0,
            prev_finish: 0.0,
            head: None,
            telemetry,
            e2e: Samples::new(),
            waits: Samples::new(),
            slo_misses: 0,
        });
    }

    // ---- phase 2: dispatch ----
    match cfg.queue {
        None => dispatch_fluid(&mut lanes, &mut medium),
        Some(discipline) => dispatch_queued(&mut lanes, &mut medium, discipline, &fp.servers),
    }

    // ---- rollup ----
    let mut per_agent = rejected_reports;
    let mut fleet_e2e = Samples::new();
    let mut fleet_waits = Samples::new();
    let mut total_energy = 0.0;
    for lane in lanes {
        let mut energy_samples = Samples::new();
        for r in &lane.telemetry.records {
            energy_samples.push(r.energy_sim_j);
            total_energy += r.energy_sim_j;
        }
        fleet_e2e.merge(&lane.e2e);
        fleet_waits.merge(&lane.waits);
        let slot = &alloc.agents[lane.agent];
        per_agent.push(AgentReport {
            agent: lane.agent,
            class: fp.agents[lane.agent].class,
            tier: fp.agents[lane.agent].device.tier,
            admitted: true,
            b_hat: lane.design.b_hat,
            server_share: slot.server_share,
            airtime_share: slot.airtime_share,
            served: lane.telemetry.len(),
            rejected: lane.telemetry.rejected,
            qos_violations: lane.telemetry.qos_violations(),
            e2e_s: lane.e2e,
            energy_j: energy_samples,
            queue_wait_s: lane.waits,
            slo_misses: lane.slo_misses,
        });
    }
    per_agent.sort_by_key(|a| a.agent);

    // fleet-level rollup from the per-agent reports
    let served = per_agent.iter().map(|a| a.served).sum();
    let rejected = per_agent.iter().map(|a| a.rejected).sum();
    let qos_violations = per_agent.iter().map(|a| a.qos_violations).sum();
    let slo_misses = per_agent.iter().map(|a| a.slo_misses).sum();
    FleetReport {
        e2e_s: fleet_e2e,
        queue_wait_s: fleet_waits,
        served,
        rejected,
        qos_violations,
        slo_misses,
        total_energy_j: total_energy,
        weighted_gap: alloc.objective,
        weighted_d_upper: alloc.weighted_d_upper(fp),
        admitted_agents: alloc.admitted,
        per_agent,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::fleet::{self, AgentSpec};
    use crate::system::Platform;

    fn fp(n: usize) -> FleetProblem {
        FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
    }

    fn cfg(requests: usize) -> FleetSimConfig {
        FleetSimConfig {
            requests_per_agent: requests,
            arrival: Arrival::Poisson { lambda_rps: 1.0 },
            seed: 7,
            batcher: BatcherConfig::default(),
            queue: None,
            lane_mix: LaneSeedMix::default(),
        }
    }

    #[test]
    fn proposed_fleet_serves_every_admitted_request() {
        let fp = fp(4);
        let alloc = fleet::solve_proposed(&fp);
        let report = run(&fp, &alloc, &cfg(8));
        assert_eq!(report.admitted_agents, alloc.admitted);
        assert_eq!(report.served, alloc.admitted * 8);
        assert_eq!(
            report.rejected,
            ((fp.n() - alloc.admitted) * 8) as u64,
            "rejected-agent requests must be counted"
        );
        // plans are made against the compute budget, so compute-side QoS
        // holds exactly; only e2e (queue + shared link) may exceed T0
        assert_eq!(report.qos_violations, 0);
        assert_eq!(report.e2e_s.len(), report.served);
        assert!(report.total_energy_j > 0.0);
        for a in &report.per_agent {
            if a.admitted {
                assert!(a.b_hat >= 1);
                assert!(a.e2e_s.min() > 0.0);
            }
        }
    }

    #[test]
    fn tiered_fleet_serves_with_per_agent_silicon() {
        // a mixed-tier fleet runs end to end; tier names surface in the
        // per-agent reports and weak-silicon agents pay visibly longer
        // agent-stage compute than Orin peers of the same class
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(7, &AgentSpec::tier_mix(2)),
        );
        let alloc = fleet::solve_proposed(&fp);
        assert_eq!(alloc.admitted, 7, "mixed-tier N=7 fleet should be fully seated");
        let report = run(&fp, &alloc, &cfg(6));
        assert_eq!(report.served, 7 * 6);
        assert_eq!(report.qos_violations, 0);
        let tiers: Vec<&str> = report.per_agent.iter().map(|a| a.tier).collect();
        assert!(tiers.contains(&"orin") && tiers.contains(&"xavier") && tiers.contains(&"phone"));
        // same QoS class, weaker silicon: the phone-tier interactive
        // agent (6) runs at fewer bits than the Orin one (0)
        let (orin_i, phone_i) = (&report.per_agent[0], &report.per_agent[6]);
        assert_eq!((orin_i.class, phone_i.class), ("interactive", "interactive"));
        assert_eq!((orin_i.tier, phone_i.tier), ("orin", "phone"));
        assert!(
            phone_i.b_hat < orin_i.b_hat,
            "phone-tier b̂ {} should trail orin b̂ {}",
            phone_i.b_hat,
            orin_i.b_hat
        );
    }

    #[test]
    fn equal_share_rejections_surface_in_the_report() {
        // at N = 8 the equal split cannot serve the interactive class at
        // all (shared server too slow) — those agents' traffic must show
        // up as rejected, not silently vanish
        let fp = fp(8);
        let alloc = fleet::solve_equal_share(&fp);
        assert!(alloc.admitted < fp.n(), "expected partial admission");
        let report = run(&fp, &alloc, &cfg(4));
        assert_eq!(report.served, alloc.admitted * 4);
        assert_eq!(report.rejected, ((fp.n() - alloc.admitted) * 4) as u64);
        let rejected_classes: Vec<&str> = report
            .per_agent
            .iter()
            .filter(|a| !a.admitted)
            .map(|a| a.class)
            .collect();
        assert!(rejected_classes.contains(&"interactive"), "{rejected_classes:?}");
    }

    #[test]
    fn e2e_includes_queueing_above_pure_compute() {
        let fp = fp(2);
        let alloc = fleet::solve_proposed(&fp);
        // batch arrivals: every request after the first queues behind its
        // predecessor, so max e2e must exceed the single-request time
        let report = run(
            &fp,
            &alloc,
            &FleetSimConfig {
                requests_per_agent: 6,
                arrival: Arrival::Batch,
                seed: 3,
                batcher: BatcherConfig::default(),
                queue: None,
                lane_mix: LaneSeedMix::default(),
            },
        );
        assert!(report.served > 0);
        assert!(report.e2e_s.max() > report.e2e_s.min() * 3.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let fp = fp(3);
        let alloc = fleet::solve_proposed(&fp);
        let a = run(&fp, &alloc, &cfg(5));
        let b = run(&fp, &alloc, &cfg(5));
        assert_eq!(a.served, b.served);
        assert_eq!(a.e2e_s.mean(), b.e2e_s.mean());
        assert_eq!(a.total_energy_j, b.total_energy_j);
        // and the queued flavors are deterministic too
        for d in [QueueDiscipline::Fifo, QueueDiscipline::WeightedPriority] {
            let mut c = cfg(5);
            c.queue = Some(d);
            let x = run(&fp, &alloc, &c);
            let y = run(&fp, &alloc, &c);
            assert_eq!(x.e2e_s.mean(), y.e2e_s.mean());
            assert_eq!(x.queue_wait_s.mean(), y.queue_wait_s.mean());
        }
    }

    #[test]
    fn shared_queue_only_delays_never_drops() {
        // same allocation, same arrivals: serializing the server stages
        // keeps every request served but stretches the tail — and the
        // measured queue waits become visible
        let fp = fp(6);
        let alloc = fleet::solve_proposed(&fp);
        let base = FleetSimConfig {
            requests_per_agent: 8,
            arrival: Arrival::Batch,
            seed: 11,
            batcher: BatcherConfig::default(),
            queue: None,
            lane_mix: LaneSeedMix::default(),
        };
        let plain = run(&fp, &alloc, &base);
        let queued = run(
            &fp,
            &alloc,
            &FleetSimConfig { queue: Some(QueueDiscipline::Fifo), ..base },
        );
        assert_eq!(plain.served, queued.served);
        assert_eq!(plain.rejected, queued.rejected);
        assert!(plain.queue_wait_s.max() == 0.0);
        assert!(
            queued.queue_wait_s.max() > 0.0,
            "contended batch arrivals must produce visible queue waits"
        );
        assert!(
            queued.e2e_s.max() >= plain.e2e_s.max(),
            "serialization cannot shrink the tail: {} < {}",
            queued.e2e_s.max(),
            plain.e2e_s.max()
        );
    }

    #[test]
    fn multi_server_run_routes_each_lane_to_its_servers_queue() {
        // a 2-server fleet serves end to end under the queued model:
        // every admitted request completes, none vanish, and the run is
        // deterministic — the placement decides which queue each lane's
        // server stages ride
        use crate::opt::fleet::{ServerSpec, SolveRequest};
        let fp = fp(6).with_servers(ServerSpec::identical(2));
        let alloc = fp.solve(&SolveRequest::default());
        assert_eq!(alloc.placement.assignment.len(), 6);
        assert!(
            alloc.placement.assignment.iter().any(|&s| s == 0)
                && alloc.placement.assignment.iter().any(|&s| s == 1),
            "two identical servers should both be used: {:?}",
            alloc.placement.assignment
        );
        let mut c = cfg(6);
        c.queue = Some(QueueDiscipline::Fifo);
        let a = run(&fp, &alloc, &c);
        assert_eq!(a.served, alloc.admitted * 6);
        assert_eq!(a.rejected, ((fp.n() - alloc.admitted) * 6) as u64);
        let b = run(&fp, &alloc, &c);
        assert_eq!(a.e2e_s.mean(), b.e2e_s.mean());
        assert_eq!(a.queue_wait_s.mean(), b.queue_wait_s.mean());
    }

    #[test]
    fn weighted_priority_favors_heavy_classes() {
        // under contention the weighted discipline must cut the
        // interactive (w = 2) queue wait relative to FIFO, at the expense
        // of background (w = 0.5)
        let fp = fp(6);
        let alloc = fleet::solve_proposed(&fp);
        let base = FleetSimConfig {
            requests_per_agent: 8,
            arrival: Arrival::Batch,
            seed: 4,
            batcher: BatcherConfig::default(),
            queue: Some(QueueDiscipline::Fifo),
            lane_mix: LaneSeedMix::default(),
        };
        let class_wait = |r: &FleetReport, class: &str| -> f64 {
            let mut s = Samples::new();
            for a in r.per_agent.iter().filter(|a| a.class == class && a.admitted) {
                s.merge(&a.queue_wait_s);
            }
            s.mean()
        };
        let fifo = run(&fp, &alloc, &base);
        let prio = run(
            &fp,
            &alloc,
            &FleetSimConfig { queue: Some(QueueDiscipline::WeightedPriority), ..base },
        );
        let (fi, pi) = (class_wait(&fifo, "interactive"), class_wait(&prio, "interactive"));
        let (fb, pb) = (class_wait(&fifo, "background"), class_wait(&prio, "background"));
        assert!(pi < fi * 0.5, "priority should cut interactive waits: {pi} vs {fi}");
        // background may not pay more than jitter noise, but must not gain
        assert!(pb >= fb - 0.01, "priority helped background: {pb} < {fb}");
        assert!(
            pi < pb,
            "interactive must wait less than background under priority: {pi} vs {pb}"
        );
    }
    // -- PR 9: per-lane RNG stream derivation --

    #[test]
    fn additive_default_keeps_historical_lane_streams() {
        assert_eq!(LaneSeedMix::default(), LaneSeedMix::Additive);
        assert_eq!(FleetSimConfig::default().lane_mix, LaneSeedMix::Additive);
    }

    #[test]
    fn splitmix_lane_streams_do_not_collide_across_adjacent_seeds() {
        // the historical additive scheme collides across adjacent run
        // seeds: seed s, lane i+1 drew the same scheduler stream as
        // seed s+1, lane i —
        let (s0, lane) = (7u64, 3u64);
        assert_eq!(s0.wrapping_add(lane + 1), (s0 + 1).wrapping_add(lane));
        // — the splitmix mix must not, for either generator family, and
        // must keep every (seed, stream, lane) triple in a broad window
        // on a distinct stream
        let mut seen = std::collections::HashSet::new();
        for seed in 0..64u64 {
            for stream in [1u64, 2] {
                for lane in 0..128u64 {
                    assert!(
                        seen.insert(splitmix_lane(seed, stream, lane)),
                        "stream collision at seed {seed} stream {stream} lane {lane}"
                    );
                }
            }
        }
        for seed in 0..512u64 {
            for lane in 0..64u64 {
                for stream in [1u64, 2] {
                    assert_ne!(
                        splitmix_lane(seed, stream, lane + 1),
                        splitmix_lane(seed + 1, stream, lane),
                        "adjacent-seed collision at seed {seed} stream {stream} lane {lane}"
                    );
                }
            }
        }
    }

    #[test]
    fn splitmix_mix_rederives_lane_streams_in_the_run() {
        // flipping the mix changes the per-lane draws (different
        // arrival jitter), not the population or the request count
        let fp = fp(3);
        let alloc = fleet::solve_proposed(&fp);
        let base = cfg(8);
        let mixed = FleetSimConfig { lane_mix: LaneSeedMix::Splitmix, ..base };
        let a = run(&fp, &alloc, &base);
        let b = run(&fp, &alloc, &mixed);
        assert_eq!(a.served, b.served);
        assert_eq!(a.rejected, b.rejected);
        let pa: Vec<u64> = a.per_agent.iter().map(|r| r.e2e_s.p50().to_bits()).collect();
        let pb: Vec<u64> = b.per_agent.iter().map(|r| r.e2e_s.p50().to_bits()).collect();
        assert_ne!(pa, pb, "splitmix must re-derive the lane streams");
    }

    #[test]
    fn lane_mix_parse_rejects_unknown_tokens() {
        assert_eq!(LaneSeedMix::parse("additive").unwrap(), LaneSeedMix::Additive);
        assert_eq!(LaneSeedMix::parse("splitmix").unwrap(), LaneSeedMix::Splitmix);
        assert_eq!(LaneSeedMix::parse("splitmix64").unwrap(), LaneSeedMix::Splitmix);
        for bad in ["", "Additive", "xor", "splitmix-64"] {
            let err = LaneSeedMix::parse(bad).unwrap_err();
            assert_eq!(err.what, "lane mix");
            assert_eq!(err.token, bad);
        }
    }
}

