//! Fleet serving loop: an analytic (no-PJRT) discrete simulation of N
//! agents sharing the medium and the edge server.
//!
//! Per admitted agent, the loop instantiates the same request path the
//! single-pair coordinator uses — [`Router`] (QoS budgets → plans, via a
//! **contention-aware** [`Scheduler`] built on the agent's share-scaled
//! platform and link-reduced delay budget) and [`Batcher`] — then walks
//! the arrival sequence with a single-inflight FIFO per agent: a request
//! starts once it has arrived, its batch was released, and the agent's
//! previous request finished; it pays the simulated agent-compute,
//! shared-uplink (jittered [`MultiAccessChannel`]) and server-compute
//! times and lands in the agent's [`Telemetry`]. The *allocation's*
//! per-agent design is the authoritative operating point for the
//! simulated physics (for proposed/equal-share it coincides with the
//! router's exact re-plan; the random baseline is simulated at its own
//! random designs). Agents the allocator rejected (admission control)
//! have every request counted as rejected.
//!
//! Delay/energy are the paper's models (eq. 4–9) at the planned
//! frequencies; wall-clock execution is intentionally absent so the loop
//! runs in tests and benches without artifacts.

use crate::coordinator::batcher::{Batch, Batcher, BatcherConfig};
use crate::coordinator::router::{QosPolicy, Router};
use crate::coordinator::scheduler::Algorithm;
use crate::coordinator::telemetry::{RequestRecord, Telemetry};
use crate::coordinator::Scheduler;
use crate::data::workload::{generate, Arrival};
use crate::opt::fleet::{FleetAllocation, FleetProblem};
use crate::quant::Scheme;
use crate::system::channel::MultiAccessChannel;
use crate::system::{delay, energy};
use crate::util::timer::Samples;

/// Knobs for one fleet serving run.
#[derive(Debug, Clone, Copy)]
pub struct FleetSimConfig {
    pub requests_per_agent: usize,
    pub arrival: Arrival,
    pub seed: u64,
    pub batcher: BatcherConfig,
}

impl Default for FleetSimConfig {
    fn default() -> Self {
        FleetSimConfig {
            requests_per_agent: 16,
            arrival: Arrival::Poisson { lambda_rps: 2.0 },
            seed: 0,
            batcher: BatcherConfig::default(),
        }
    }
}

/// One agent's rollup over the run.
#[derive(Debug, Clone)]
pub struct AgentReport {
    pub agent: usize,
    pub class: &'static str,
    pub admitted: bool,
    /// planned bit-width (0 when rejected)
    pub b_hat: u32,
    pub server_share: f64,
    pub airtime_share: f64,
    pub served: usize,
    pub rejected: u64,
    /// end-to-end time (queue + compute + shared uplink) per request [s]
    pub e2e_s: Samples,
    /// simulated energy per request [J]
    pub energy_j: Samples,
    /// records whose *compute* delay/energy broke the planned budgets
    pub qos_violations: usize,
    /// requests whose *end-to-end* time exceeded the agent's full T0
    pub slo_misses: usize,
}

/// Fleet-level aggregate (per-agent [`Telemetry`] rolled up).
#[derive(Debug, Clone)]
pub struct FleetReport {
    pub per_agent: Vec<AgentReport>,
    /// e2e percentiles across every served request in the fleet
    pub e2e_s: Samples,
    pub served: usize,
    pub rejected: u64,
    pub qos_violations: usize,
    pub slo_misses: usize,
    pub total_energy_j: f64,
    /// the allocation's fleet-weighted (P1) objective
    pub weighted_gap: f64,
    /// fleet-weighted distortion upper bound Σ w_i D^U(b̂_i − 1)
    pub weighted_d_upper: f64,
    pub admitted_agents: usize,
}

/// Run the fleet serving loop for a solved allocation.
pub fn run(fp: &FleetProblem, alloc: &FleetAllocation, cfg: &FleetSimConfig) -> FleetReport {
    assert_eq!(alloc.agents.len(), fp.n());
    let mut medium = MultiAccessChannel::new(
        fp.link_rate_bps,
        fp.link_base_latency_s,
        0.10,
        alloc.airtime_shares(),
        cfg.seed ^ 0x5EED_F1EE,
    );
    let mut per_agent = Vec::with_capacity(fp.n());
    let mut fleet_e2e = Samples::new();
    let mut total_energy = 0.0;

    for (i, slot) in alloc.agents.iter().enumerate() {
        let spec = &fp.agents[i];
        let mut requests = generate(
            cfg.requests_per_agent,
            1,
            cfg.arrival,
            cfg.seed.wrapping_add(0x9E37 * (i as u64 + 1)),
        );
        for r in &mut requests {
            r.class = spec.class;
        }

        let Some(design) = slot.design else {
            // admission control rejected this agent: nothing is served
            per_agent.push(AgentReport {
                agent: i,
                class: spec.class,
                admitted: false,
                b_hat: 0,
                server_share: slot.server_share,
                airtime_share: slot.airtime_share,
                served: 0,
                rejected: requests.len() as u64,
                e2e_s: Samples::new(),
                energy_j: Samples::new(),
                qos_violations: 0,
                slo_misses: 0,
            });
            continue;
        };

        // contention-aware scheduler: the agent's slice of the shared
        // server, and the delay budget net of its nominal uplink time
        let platform = fp.agent_platform(slot.server_share);
        let t0_compute = spec.t0 - slot.link_s;
        let scheduler = Scheduler::new(
            platform,
            spec.lambda,
            Algorithm::Exact,
            Scheme::Uniform,
            cfg.seed.wrapping_add(i as u64),
        );
        let mut router = Router::new(
            QosPolicy::new(&[(spec.class, t0_compute, spec.e0)]),
            scheduler,
        );
        let mut batcher = Batcher::new(cfg.batcher);
        let mut telemetry = Telemetry::default();
        let mut e2e = Samples::new();
        let mut slo_misses = 0usize;
        let mut busy_until = 0.0f64;

        // `release_s` = simulated time the batcher actually let the batch
        // go (size fill, deadline poll, or end-of-stream drain): requests
        // pay their batching wait in e2e, not just queue + compute
        let execute = |batch: Batch,
                           release_s: f64,
                           telemetry: &mut Telemetry,
                           e2e: &mut Samples,
                           slo_misses: &mut usize,
                           busy_until: &mut f64,
                           medium: &mut MultiAccessChannel| {
            for rr in batch.requests {
                // the fleet allocation's design is the authoritative
                // operating point: for proposed/equal-share it coincides
                // with the router's exact re-plan, while the random
                // baseline must be simulated at the random designs it
                // actually chose, not at what exact bisection would pick
                let b = design.b_hat as f64;
                let (f, ft) = (design.f, design.f_tilde);
                let t_agent = delay::agent_delay(&platform, b, f);
                let t_server = delay::server_delay(&platform, ft);
                let t_link = medium.transmit_s(i, spec.payload_bytes);
                let start = rr.request.arrival_s.max(release_s).max(*busy_until);
                let finish = start + t_agent + t_link + t_server;
                *busy_until = finish;
                let total = finish - rr.request.arrival_s;
                e2e.push(total);
                if total > spec.t0 {
                    *slo_misses += 1;
                }
                telemetry.push(RequestRecord {
                    id: rr.request.id,
                    class: rr.request.class,
                    sample: rr.request.sample,
                    b_hat: design.b_hat,
                    t_agent_sim_s: t_agent,
                    t_server_sim_s: t_server,
                    t_link_s: t_link,
                    energy_sim_j: energy::total_energy(&platform, b, f, ft),
                    t_wall_s: 0.0,
                    caption: String::new(),
                    t0: rr.t0,
                    e0: rr.e0,
                });
            }
        };

        let end_s = requests.last().map_or(0.0, |r| r.arrival_s);
        for req in requests {
            let now = req.arrival_s;
            match router.route(req) {
                Ok(routed) => {
                    if let Some(batch) = batcher.push(routed) {
                        execute(
                            batch,
                            now,
                            &mut telemetry,
                            &mut e2e,
                            &mut slo_misses,
                            &mut busy_until,
                            &mut medium,
                        );
                    }
                    for batch in batcher.poll_deadlines(now) {
                        execute(
                            batch,
                            now,
                            &mut telemetry,
                            &mut e2e,
                            &mut slo_misses,
                            &mut busy_until,
                            &mut medium,
                        );
                    }
                }
                Err(_) => telemetry.rejected += 1,
            }
        }
        // the stream ends at the last arrival; leftover groups drain then
        for batch in batcher.drain() {
            execute(
                batch,
                end_s,
                &mut telemetry,
                &mut e2e,
                &mut slo_misses,
                &mut busy_until,
                &mut medium,
            );
        }

        let mut energy_samples = Samples::new();
        for r in &telemetry.records {
            energy_samples.push(r.energy_sim_j);
            total_energy += r.energy_sim_j;
        }
        for &v in e2e.values() {
            fleet_e2e.push(v);
        }
        per_agent.push(AgentReport {
            agent: i,
            class: spec.class,
            admitted: true,
            b_hat: design.b_hat,
            server_share: slot.server_share,
            airtime_share: slot.airtime_share,
            served: telemetry.len(),
            rejected: telemetry.rejected,
            qos_violations: telemetry.qos_violations(),
            e2e_s: e2e,
            energy_j: energy_samples,
            slo_misses,
        });
    }

    // fleet-level rollup from the per-agent reports
    let served = per_agent.iter().map(|a| a.served).sum();
    let rejected = per_agent.iter().map(|a| a.rejected).sum();
    let qos_violations = per_agent.iter().map(|a| a.qos_violations).sum();
    let slo_misses = per_agent.iter().map(|a| a.slo_misses).sum();
    FleetReport {
        e2e_s: fleet_e2e,
        served,
        rejected,
        qos_violations,
        slo_misses,
        total_energy_j: total_energy,
        weighted_gap: alloc.objective,
        weighted_d_upper: alloc.weighted_d_upper(fp),
        admitted_agents: alloc.admitted,
        per_agent,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::fleet::{self, AgentSpec};
    use crate::system::Platform;

    fn fp(n: usize) -> FleetProblem {
        FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
    }

    fn cfg(requests: usize) -> FleetSimConfig {
        FleetSimConfig {
            requests_per_agent: requests,
            arrival: Arrival::Poisson { lambda_rps: 1.0 },
            seed: 7,
            batcher: BatcherConfig::default(),
        }
    }

    #[test]
    fn proposed_fleet_serves_every_admitted_request() {
        let fp = fp(4);
        let alloc = fleet::solve_proposed(&fp);
        let report = run(&fp, &alloc, &cfg(8));
        assert_eq!(report.admitted_agents, alloc.admitted);
        assert_eq!(report.served, alloc.admitted * 8);
        assert_eq!(
            report.rejected,
            ((fp.n() - alloc.admitted) * 8) as u64,
            "rejected-agent requests must be counted"
        );
        // plans are made against the compute budget, so compute-side QoS
        // holds exactly; only e2e (queue + shared link) may exceed T0
        assert_eq!(report.qos_violations, 0);
        assert_eq!(report.e2e_s.len(), report.served);
        assert!(report.total_energy_j > 0.0);
        for a in &report.per_agent {
            if a.admitted {
                assert!(a.b_hat >= 1);
                assert!(a.e2e_s.min() > 0.0);
            }
        }
    }

    #[test]
    fn equal_share_rejections_surface_in_the_report() {
        // at N = 8 the equal split cannot serve the interactive class at
        // all (shared server too slow) — those agents' traffic must show
        // up as rejected, not silently vanish
        let fp = fp(8);
        let alloc = fleet::solve_equal_share(&fp);
        assert!(alloc.admitted < fp.n(), "expected partial admission");
        let report = run(&fp, &alloc, &cfg(4));
        assert_eq!(report.served, alloc.admitted * 4);
        assert_eq!(report.rejected, ((fp.n() - alloc.admitted) * 4) as u64);
        let rejected_classes: Vec<&str> = report
            .per_agent
            .iter()
            .filter(|a| !a.admitted)
            .map(|a| a.class)
            .collect();
        assert!(rejected_classes.contains(&"interactive"), "{rejected_classes:?}");
    }

    #[test]
    fn e2e_includes_queueing_above_pure_compute() {
        let fp = fp(2);
        let alloc = fleet::solve_proposed(&fp);
        // batch arrivals: every request after the first queues behind its
        // predecessor, so max e2e must exceed the single-request time
        let report = run(
            &fp,
            &alloc,
            &FleetSimConfig {
                requests_per_agent: 6,
                arrival: Arrival::Batch,
                seed: 3,
                batcher: BatcherConfig::default(),
            },
        );
        assert!(report.served > 0);
        assert!(report.e2e_s.max() > report.e2e_s.min() * 3.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let fp = fp(3);
        let alloc = fleet::solve_proposed(&fp);
        let a = run(&fp, &alloc, &cfg(5));
        let b = run(&fp, &alloc, &cfg(5));
        assert_eq!(a.served, b.served);
        assert_eq!(a.e2e_s.mean(), b.e2e_s.mean());
        assert_eq!(a.total_energy_j, b.total_energy_j);
    }
}
