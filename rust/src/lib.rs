//! # qaci — Quantization-Aware Collaborative Inference for Large Embodied AI Models
//!
//! [![ci](../../../actions/workflows/ci.yml/badge.svg)](../../../actions/workflows/ci.yml)
//!
//! Production-shaped reproduction of Lyu et al. (2026). The crate is the
//! L3 coordinator of a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (build-time Python): Pallas kernels — fused fake-quantization
//!   (uniform + power-of-two), MXU-tiled matmul, fused attention, layernorm.
//! * **L2** (build-time Python): JAX captioners (BLIP-2-like, GIT-like) and
//!   the FCDNN-16 verification model, AOT-lowered to HLO text.
//! * **L3** (this crate): PJRT runtime, quantization-aware co-inference
//!   coordinator, the paper's rate–distortion theory (§III–IV), the joint
//!   bit-width/frequency optimizer (§V, Algorithm 1), all evaluation
//!   baselines (PPO, fixed-frequency, feasible-random), and the benchmark
//!   harness regenerating every figure/table of §VI.
//!
//! Python never runs on the request path: after `make artifacts`, the
//! binary is self-contained.
//!
//! ## Module map
//!
//! | area | modules |
//! |---|---|
//! | substrates | [`util`] (json, cli, rng, pool, prop), [`nn`], [`metrics`], [`data`] |
//! | theory (§III–IV) | [`theory`] |
//! | quantizers (§II-C) | [`quant`] |
//! | system model (§II-D) | [`system`] (incl. multi-access contention + [`system::queue`]) |
//! | joint design (§V) | [`opt`] (incl. [`opt::fleet`]), [`rl`] |
//! | serving | [`runtime`], [`coordinator`], [`fleet`] (incl. [`fleet::churn`] + [`fleet::events`] + [`fleet::daemon`]) |
//! | evaluation | [`bench_harness`], `rust/benches/*` |
//! | observability | [`obs`] (metrics/spans, shared percentiles, bench-log store) |
//!
//! The **fleet layer** generalizes the paper's single agent–server pair to
//! N agents contending for S edge servers and one wireless medium. A
//! fleet instance is one plain config struct,
//! [`opt::fleet::FleetSpec`] (shared silicon, the
//! [`opt::fleet::ServerSpec`] bank, link, queue feedback, admission
//! pricing), validated once by
//! [`opt::fleet::FleetProblem::from_spec`]; every solve goes through one
//! entry point, [`opt::fleet::FleetProblem::solve`], driven by an
//! [`opt::fleet::SolveRequest`] (algorithm + options + placement
//! strategy + warm start + seed). Airtime shares and per-agent channel
//! gains live in [`system::channel::MultiAccessChannel`], the edge
//! queues (analytic M/G/1 feedback + event-level dispatch) in
//! [`system::queue`], the joint multi-agent allocator (per-agent
//! bisection + water-filling + admission control, queue-aware delay
//! budgets) in [`opt::fleet`], and the fleet serving loop in
//! [`fleet::sim`]. The old `solve_*` free functions remain as thin
//! wrappers over `SolveRequest`s (bit-identical, regression-tested).
//! For large fleets, [`opt::fleet::SolveRequest::classing`] collapses
//! agents into (tier × QoS class × arrival × gain) **equivalence
//! classes** and solves one representative subproblem per class —
//! [`opt::fleet::Classing::Exact`] is *not* an approximation: every
//! per-agent number the direct solver would compute is memoized per
//! class and broadcast, so the allocation is bit-identical
//! (property-tested) while the per-agent bisections collapse to one
//! per class, run in parallel on [`util::pool::ThreadPool`]. A few
//! distinct hardware/QoS profiles mean a million-agent fleet solves at
//! the cost of a handful of agents plus O(N) bookkeeping
//! ([`opt::fleet::Classing::Bucketed`] additionally buckets continuous
//! channel gains at a configurable decimal, trading exactness for
//! fewer classes on heterogeneous-gain fleets). Entry points:
//! `qaci fleet [--classing exact]`, `benches/fleet_scale.rs` (the
//! `solve-scale-*` ladder), `examples/fleet_sweep.rs`.
//!
//! ## Multi-server placement
//!
//! With `FleetSpec::servers` holding more than one
//! [`opt::fleet::ServerSpec`] (per-server frequency budget, optional
//! explicit airtime slice, optional queue-discipline override), the
//! solver composes an outer **placement** loop with the exact
//! single-server inner allocator: an
//! [`opt::fleet::Placement`] maps each agent to a server, each server's
//! members are solved as an independent sub-fleet (its airtime slice
//! split by head count unless pinned), and
//! [`opt::fleet::PlacementStrategy`] picks the outer search —
//! `local-search` (best-improving single-agent moves from the better of
//! the two baselines, each accepted move counted as `placement.moves`)
//! against the `equal-spread` and `nearest-server` baselines. An S = 1
//! bank collapses to the legacy single-server solver bit for bit. The
//! serving loop runs one [`system::queue::EdgeQueue`] per server routed
//! by the allocation's placement; churn keeps survivors seated
//! (sticky placement), re-solving only servers whose sub-fleet
//! fingerprint changed and migrating queued work between per-server
//! queues when an agent moves. Entry points: `qaci fleet --servers 3
//! --placement local-search` (also `--server-scales 1.0,0.5`, and
//! `--churn --events` on top), `benches/fleet_placement.rs`.
//!
//! ## Heterogeneous silicon
//!
//! Fleets are not built from one device: each
//! [`opt::fleet::AgentSpec`] carries a [`system::DeviceProfile`] — the
//! Jetson-Orin / Xavier / phone-class tier ladder with per-tier f^max,
//! compute efficiency κ, power curve and radio gain — and every
//! per-agent subproblem runs on that silicon
//! ([`opt::fleet::FleetProblem::agent_platform`]). The uniform-Orin
//! ladder reproduces the homogeneous fleet bit for bit (regression-
//! tested); on a mixed ladder the proposed allocator's margin over the
//! equal split widens with tier spread, because only the exchange can
//! buy a weak device the fatter server slice its QoS needs. Queue
//! interference is scored by a damped **fixed-point pass over the
//! actual shares** ([`opt::fleet::FleetProblem::interference_waits`];
//! mean-field fallback on non-convergence), with property/golden tests
//! (`system/queue.rs`, `tests/golden_theory.rs`) pinning the numeric
//! core. Entry points: `qaci fleet --tiers orin,xavier,phone`,
//! `examples/hetero_fleet.rs`, the hetero sections of
//! `benches/fleet_scale.rs` and `benches/fleet_churn.rs`.
//!
//! ## Churn mode
//!
//! Real fleets are not static: agents arrive, burst and leave while the
//! edge resources stay fixed. [`fleet::churn`] replays a deterministic
//! Poisson timeline of joins/leaves/load-bursts and re-runs the
//! water-filling allocator **online** — warm-started from the previous
//! [`opt::fleet::FleetAllocation`] and gated by a fingerprint of the
//! whole [`opt::fleet::FleetSpec`] (the same invalidation idiom the
//! coordinator's scheduler uses for its plan cache), so an unchanged
//! fleet never re-solves and a changed one re-converges in a few
//! exchange moves. On a multi-server bank the gate refines per server:
//! survivors keep their seat, newcomers go to the least-loaded box, and
//! only servers whose sub-fleet fingerprint actually changed are
//! re-solved (the rest reuse their previous slots). Static t = 0
//! allocations ride the same timeline for comparison: they strand the
//! shares of departed agents, turn joiners away, and lose their frozen
//! designs when a burst blows the queue-aware delay budget — which is
//! why online re-allocation strictly wins on time-averaged
//! fleet-weighted distortion cost whenever the population actually
//! churns (and reproduces the static allocation exactly when it does
//! not). Entry points: `qaci fleet --churn`, `benches/fleet_churn.rs`,
//! `examples/fleet_churn.rs`.
//!
//! ## Event mode
//!
//! The analytic churn score integrates what the allocator *guarantees*;
//! `qaci fleet --churn --events` additionally replays the same timeline
//! at the **request level** ([`fleet::events`]): every live agent emits
//! an open Poisson request stream (continuous across events — rate
//! changes rescale the residual gap, so every policy sees identical
//! arrivals), each request pays agent-compute + uplink at its arrival
//! operating point and serializes through the shared
//! [`system::queue::EdgeQueue`], dispatch is slot-bounded
//! ([`system::queue::EdgeQueue::pop_due`], invariant under slot
//! refinement), lanes are created/retired at joins/leaves (queued work
//! of a leaver is dropped *and accounted* — every request completes, is
//! rejected, or is dropped at departure), and online re-allocations
//! re-price the waiting queues without resetting them. On a
//! multi-server bank the replay runs one queue per server; when an
//! online re-solve moves an agent, its waiting backlog is drained from
//! the old server's queue and re-queued on the new one (counted as
//! `events.migrations`). The result is tail
//! telemetry the analytic path cannot see: per-agent/fleet p50/p95/p99
//! queue wait and end-to-end delay plus deadline-violation rate. Under
//! burst overload frozen static shares let the queue diverge while the
//! online re-solve keeps p99 bounded (the `burst-storm` bench scenario
//! pins online beating the best static policy on p99); admission
//! pricing can be made silicon-aware with `--admission-pricing tiered`
//! ([`opt::fleet::AdmissionPricing`]), trading phone-class coverage for
//! orin throughput — visibly, in the same traces. A stationary-load
//! property test pins the event engine to the analytic M/G/1
//! [`system::queue::QueueModel`] per-agent waits for both disciplines.
//! Every completed request also pays **compute + uplink energy at its
//! arrival operating point** (priced once at admission via
//! [`system::energy`], so a later re-solve never re-bills in-flight
//! work), rolled up per agent and fleet-wide in
//! [`fleet::EventAgentReport::energy_j`] /
//! [`fleet::EventReport::energy_j`]; and `ChurnConfig::closed_loop`
//! switches arrivals from open Poisson streams to one-outstanding-
//! request clients (think time re-drawn at each completion, mirroring
//! [`fleet::sim`]'s client model) — the backlog is then bounded by the
//! population instead of the load.
//!
//! ## Closed-loop serving
//!
//! The event replay re-solves on *every* fingerprint change; a real
//! control plane cannot afford that. [`fleet::daemon`] promotes the
//! replay into a supervising serving daemon (`qaci fleet --serve`,
//! library entry [`fleet::Daemon`]): one deterministic job queue holds
//! churn events, epoch boundaries and deferred re-solves; the engine
//! runs in **bounded telemetry epochs** whose tail deltas (per-agent
//! p99 wait/e2e, violation rate, per-request energy) feed the next
//! solve two ways —
//!
//! * **measured admission pricing**
//!   ([`opt::fleet::AdmissionPricing::Measured`]): per-agent observed
//!   violation pressure (⅛-quantized for fingerprint stability)
//!   discounts that agent's rejection penalty, so the allocator stops
//!   defending demand the telemetry says is already being dropped;
//! * **re-allocation hysteresis**: a change whose predicted fleet-cost
//!   gain — frozen shares probed via [`opt::fleet::probe_frozen`]
//!   against the counterfactual warm re-solve — falls under
//!   `gain_threshold` while the measured queue backlog stays under
//!   `urgent_backlog_s` is skipped outright; a material gain inside the
//!   `cooldown_s` window is deferred to the window's edge (the deferral
//!   cancelled if a newer decision supersedes it); an urgent backlog
//!   bypasses the cooldown — near the optimum the design cost is flat
//!   in shares while queue service rates are not, so the backlog probe
//!   is what catches a burst the cost probe cannot see.
//!
//! Shutdown drains the queues (the engine runs to the horizon, every
//! request completes / is rejected / is dropped) and emits a final
//! metrics snapshot plus a byte-stable transcript
//! ([`fleet::DaemonReport::transcript`]) — same seed + config ⇒
//! identical bytes, which the determinism test pins. On the
//! burst-storm scenario the hysteresis daemon takes ≤ half of
//! resolve-always's solves while keeping fleet p99 e2e within 1.5× of
//! it and still beating every static policy
//! (`benches/fleet_daemon.rs`, gated in CI via the bench-log ordering
//! diff).
//!
//! ## Quantization
//!
//! All weight quantization goes through one front door:
//! [`quant::Quantizer::new`] validates a [`quant::QuantConfig`] (scheme
//! × bit depth × optional channel grouping) once and
//! [`quant::Quantizer::quantize`] /
//! [`quant::Quantizer::quantize_into`] apply the exact f32 kernels the
//! L1 Pallas layer mirrors ([`quant::quantize_magnitudes`] remains as a
//! thin wrapper, bit-identical, regression-tested). Distortion
//! prediction is behind the [`theory::distortion::DistortionModel`]
//! trait — the analytic §III rate bound
//! ([`theory::rate_distortion::RateBoundModel`]), the measured-grid
//! empirical model ([`quant::error::EmpiricalUniformModel`]), and the
//! layer-matrix surrogates ([`theory::distortion::SurrogateModel`],
//! [`theory::distortion::OutputBoundModel`]) all answer the same
//! "predicted D at this allocation" question, so the allocator is
//! model-agnostic.
//!
//! **Mixed precision** ([`quant::mixed`]): a
//! [`quant::mixed::BitAllocation`] carries per-channel-group bit widths
//! with fitted Exp(λ) tails and weights;
//! [`quant::mixed::allocate_bits`] greedily water-fills bits across
//! groups under an average-rate budget R̄, minimizing the predicted
//! distortion of whichever `DistortionModel` is plugged in, and keeps
//! the uniform allocation as a candidate so mixed ≤ uniform at matched
//! rate is structural. **Per-agent policy**
//! ([`quant::mixed::QuantPolicy`], carried by
//! [`opt::fleet::AgentSpec::quant`] and threaded through every fleet
//! solve): `Static(None)` is the legacy exact bisection pick (the
//! default — bit-identical to the pre-policy solver), `Static(Some(b̂))`
//! pins a width, `Mixed(BitAllocation)` solves at the allocation's
//! pinned average width while scoring its per-group distortion, and
//! `Adaptive(AdaptConfig)` clamps the solver pick into a
//! `[min_bits, max_bits]` window whose ceiling tightens with observed
//! violation pressure ([`quant::mixed::AdaptConfig::effective_max`]) —
//! under churn the window re-picks at every warm re-solve boundary, and
//! under the serving daemon the same telemetry that drives
//! [`opt::fleet::AdmissionPricing::Measured`] re-prices it per epoch.
//! On the drifting-load scenario the adaptive policy's time-averaged
//! fleet D^U sits strictly below every static pin b̂ ∈ {1..16}
//! (`benches/fleet_quant.rs`, gated in CI via the bench-log ordering
//! diff). Entry points: `qaci fleet --quant-policy
//! static|static:8|adaptive|adaptive:2-12`, `benches/fleet_quant.rs`.
//!
//! ## Bench artifacts
//!
//! `benches/fleet_churn.rs`, `benches/fleet_scale.rs`,
//! `benches/fleet_placement.rs`, `benches/fleet_daemon.rs` and
//! `benches/fleet_quant.rs` emit
//! machine-readable results next to their tables —
//! `BENCH_fleet_churn.json` / `BENCH_fleet_scale.json` /
//! `BENCH_fleet_placement.json` / `BENCH_fleet_daemon.json` /
//! `BENCH_fleet_quant.json` (or under
//! `$QACI_BENCH_DIR`), uploaded by the `bench-artifacts` CI job.
//! Schema (version 1):
//!
//! ```json
//! {
//!   "bench": "fleet_churn",
//!   "version": 1,
//!   "results": [
//!     {
//!       "scenario": "burst-storm",
//!       "policy": "online-proposed",
//!       "cost": 0.2563,
//!       "d_upper": 0.0461,
//!       "reallocations": 29,
//!       "arrivals": 362, "completed": 158,
//!       "p99_s": 19.7, "queue_wait_p99_s": 17.8,
//!       "deadline_violation_rate": 0.718,
//!       "wall_clock_s": 0.42
//!     }
//!   ]
//! }
//! ```
//!
//! `fleet_scale` records carry `scenario: "scale-<N>"`, `policy` (the
//! allocator name), `cost`, `d_upper`, `admitted`, `p99_s` and
//! `wall_clock_s` (the allocation solve time), plus one
//! `solve-scale-<N>` row per allocator ladder rung (`policy`
//! `"per-agent"` or `"classed"`) carrying `cost`, `admitted`,
//! `classes`, `wall_clock_s` and — on rungs both solvers run —
//! `cost_bits_equal` and `speedup` on the classed row (the CI
//! validator asserts bit-equal costs, ≥ 10× at N = 10⁴ and monotone
//! solve-time growth in N); `fleet_placement`
//! records carry the placement-strategy name as `policy` plus `cost`,
//! `d_upper`, `admitted` and `placement_moves` per server-bank
//! scenario; `fleet_daemon` records carry one `burst-storm` row per
//! control policy (`daemon-hysteresis`, `daemon-resolve-always`, the
//! statics) with `resolves_taken`, `resolves_skipped`, `p99_s`,
//! `queue_wait_p99_s`, `deadline_violation_rate` and
//! `energy_per_request_j`; `fleet_quant` records carry one
//! `drifting-load` row per quantization policy label (`adaptive:1-16`,
//! the legacy `static`, every `static:<b>` pin) with `d_upper`, `cost`,
//! `reallocations`, `realloc_skipped`, `admitted` and `wall_clock_s`,
//! plus `rate-<R̄>` rows (`policy` `"mixed"` or `"uniform"`) with the
//! allocator's predicted `d_upper`, `avg_bits` and the `bits` string.
//! Fields whose measurement does not exist (e.g. a p99 over
//! zero completions) are `null`, never NaN: emission
//! ([`bench_harness::emit_bench_artifact`]) re-parses the file and
//! rejects any non-finite number, the benches re-check their ordering
//! invariants (online ≤ best-static under churn, online p99 under
//! burst-storm, proposed ≤ equal at N ≥ 4, local-search < equal-spread
//! on the hot-server bank) against the parsed document, and the CI job
//! validates the files once more before uploading.
//!
//! ## Observability
//!
//! The [`obs`] layer makes the solver and the queue legible at runtime
//! and across runs.
//!
//! **Metrics + spans** ([`obs::metrics`]): a thread-local registry of
//! monotone counters, last-write gauges and f64 histograms (summarized
//! with the same p50/p95/p99 convention as every fleet report — the one
//! percentile implementation lives in [`obs::stats`] and
//! [`util::timer::Samples`] delegates to it). The hot paths record
//! under dotted names grouped by subsystem:
//!
//! * `solver.*` — `warm_start.hit`/`warm_start.miss` (fingerprint-gated
//!   online re-solves), `fixed_point.converged`/`fixed_point.fallback`
//!   (interference pass outcomes), `bisection.calls`/`bisection.iters`,
//!   `exchange.rounds`/`exchange.moves`, `admission.rejected`;
//! * `queue.*` — `push`/`pop`/`drain.calls`/`drain.jobs`/
//!   `reprice.calls`/`reprice.jobs` counters plus `queue.depth` and
//!   `queue.wait_s` histograms recorded by [`system::queue::EdgeQueue`];
//! * `placement.*` — `placement.moves` (accepted local-search /
//!   rebalance migrations) and the per-server warm-path counters
//!   `placement.server.resolved`/`placement.server.reused`;
//! * `events.*` — replay counters (`arrivals`, `completed`, `dropped`,
//!   `rejected`, `deadline_misses`, `reallocations`, `realloc_skipped`,
//!   `events.migrations`) and the per-slot `events.queue_depth`
//!   timeline histogram (plus `events.queue_depth.s<k>` per server on
//!   multi-server banks);
//! * `daemon.*` — control-plane counters recorded by [`fleet::daemon`]:
//!   `daemon.epochs` (telemetry epochs ingested), `daemon.resolve.taken`
//!   and `daemon.resolve.skipped.cooldown`/`daemon.resolve.skipped.gain`
//!   (hysteresis decisions), plus `solver.probe.frozen` for each
//!   predicted-gain probe;
//! * `span.<name>.s` — wall-clock span histograms recorded when an
//!   [`obs::metrics::Span`] guard drops (e.g. `span.solver.proposed.s`,
//!   `span.events.run.s`).
//!
//! `qaci fleet ... --metrics-out <path>` writes the run's snapshot as
//! schema-versioned JSON (`{"schema":"qaci.metrics","version":1,
//! "counters":{...},"gauges":{...},"histograms":{name:{n,mean,min,max,
//! p50,p95,p99}}}`), and every event replay embeds its own capture in
//! [`fleet::EventReport::metrics`] via [`obs::metrics::scoped`].
//!
//! **Bench-log store** ([`obs::benchlog`]): `qaci bench-log
//! ingest|query|diff` maintains an append-only JSON-lines index where
//! each line wraps one ingested `BENCH_*.json` artifact or metrics
//! snapshot as `{"schema":"qaci.benchlog","version":1,"seq":N,
//! "bench":...,"kind":"bench"|"metrics","digest":"fnv1a:<16 hex>",
//! "payload":{...}}`. The digest is 64-bit FNV-1a over the payload's
//! compact canonical bytes, so corruption is caught on read and
//! re-serialization is byte-stable; unknown schema names or versions are
//! rejected cleanly. `query` answers trajectory questions ("p99 on
//! burst-storm over the last K runs"); `diff` gates regressions against
//! a stored baseline — ordering-invariant checks (machine-independent,
//! what CI enforces against `rust/ci/benchlog-baseline.jsonl` with
//! `--orderings-only --fail-on-regression`) plus tolerance-banded value
//! checks on the tracked lower-is-better fields for same-machine runs.

pub mod bench_harness;
pub mod coordinator;
pub mod figures;
pub mod data;
pub mod fleet;
pub mod metrics;
pub mod nn;
pub mod obs;
pub mod opt;
pub mod quant;
pub mod rl;
pub mod runtime;
pub mod system;
pub mod theory;
pub mod util;

/// Directory where `make artifacts` places the AOT bundle, unless
/// overridden by `QACI_ARTIFACTS`.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("QACI_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
