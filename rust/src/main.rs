//! `qaci` — the co-inference coordinator CLI.
//!
//! Subcommands:
//!   info     inspect the artifact bundle (models, λ, FLOPs, eval sets)
//!   plan     run the joint design for a (T0, E0) budget and print the plan
//!   eval     serve the eval set through the engine, report CIDEr/delay/energy
//!   serve    threaded pipelined serving demo over a Poisson workload
//!   fleet    N agents on S edge servers + one medium: joint placement
//!            (`--servers 3` / `--server-scales 1.0,0.5` with `--placement
//!            local-search|equal-spread|nearest-server`) and per-server
//!            allocation (proposed | equal-share | feasible-random), plus the
//!            fleet serving loop — artifact-free; `--tiers orin,xavier,phone`
//!            mixes heterogeneous silicon (one QoS cycle per tier),
//!            `--queue fifo|priority` adds the shared edge queue (one per
//!            server), `--churn`
//!            replays a churning population (Poisson joins/leaves/bursts)
//!            and compares the static t=0 allocations against online
//!            re-allocation, `--churn --events` adds the request-level
//!            replay (p50/p95/p99 wait + e2e, deadline-violation rate),
//!            `--admission-pricing tiered` scales rejection penalties by
//!            silicon capability (phone coverage vs orin throughput),
//!            `--serve` runs the closed-loop serving daemon instead:
//!            `--epochs K --epoch-dur S` bounded telemetry epochs feeding
//!            `--admission-pricing measured` re-solves, rate-limited by
//!            `--cooldown` and a `--gain-threshold` predicted-gain probe
//!            (`--resolve-always` disables hysteresis, `--closed-loop`
//!            switches arrivals to one-outstanding-request clients),
//!            `--metrics-out m.json` writes the ambient solver/queue/replay
//!            metrics snapshot (schema `qaci.metrics` v1, see `qaci::obs`)
//!   fit      fit the exponential magnitude model to a weight blob
//!   bench-log  persistent content-hashed bench-trajectory store
//!            (`qaci::obs::benchlog`): `ingest <files...>` appends
//!            `BENCH_*.json` artifacts / metrics snapshots to `--index`,
//!            `query` reports `--field` per scenario/policy over `--last K`
//!            runs, `diff` gates the newest run against `--baseline` (or
//!            the previous run) — `--orderings-only` restricts to
//!            machine-invariant policy orderings, `--fail-on-regression`
//!            turns findings into a nonzero exit for CI
//!
//! Examples:
//!   qaci plan --t0 3.5 --e0 2.0 --algorithm proposed
//!   qaci eval --model blip2ish --algorithm proposed --requests 64
//!   qaci serve --model gitish --rps 20 --requests 100
//!   qaci fleet --agents 8 --algorithm proposed --requests 16
//!   qaci fleet --agents 7 --tiers orin,xavier,phone
//!   qaci fleet --agents 9 --servers 3 --placement local-search
//!   qaci fleet --servers 3 --churn --events
//!   qaci fleet --churn --agents 4 --horizon 600 --queue fifo
//!   qaci fleet --churn --events --admission-pricing tiered --tiers orin,xavier,phone
//!   qaci fleet --churn --events --metrics-out metrics.json
//!   qaci fleet --serve --epochs 8 --epoch-dur 75 --admission-pricing measured
//!   qaci bench-log ingest BENCH_fleet_churn.json --index benchlog.jsonl
//!   qaci bench-log query --index benchlog.jsonl --scenario burst-storm --field p99_s --last 5
//!   qaci bench-log diff --index benchlog.jsonl --baseline rust/ci/benchlog-baseline.jsonl \
//!       --orderings-only --fail-on-regression
fn main() { cli::main() }
mod cli;
