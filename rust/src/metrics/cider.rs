//! CIDEr — Consensus-based Image Description Evaluation (paper eq. 37).
//!
//! For candidate sentence p_i and reference set {p̂_ij}:
//!
//!   CIDEr_n(p_i) = (1/m) Σ_j  g_n(p_i)·g_n(p̂_ij) / (‖g_n(p_i)‖‖g_n(p̂_ij)‖)
//!
//! where g_n is the TF-IDF-weighted n-gram count vector; the overall score
//! averages over n-gram orders 1..=4 and (per the reference implementation)
//! scales by 10. Document frequencies are computed over the evaluation
//! corpus' reference sets, exactly like pycocoevalcap.

use super::ngram::{self, Counts};
use std::collections::HashMap;

pub const MAX_N: usize = 4;
pub const SCALE: f64 = 10.0;

/// Corpus-bound CIDEr scorer. Construct once per eval set (IDF statistics
/// are corpus-level), then score any number of candidate batches.
pub struct CiderScorer {
    /// per-sample, per-order reference count maps (+ cached norms)
    refs: Vec<Vec<Vec<Counts>>>,
    /// document frequency per n-gram (order-merged; keys are unique anyway)
    df: HashMap<String, f64>,
    /// log(total documents)
    log_n_docs: f64,
}

impl CiderScorer {
    /// `refs[i]` is the list of reference captions for sample i.
    pub fn new(refs: &[Vec<String>]) -> CiderScorer {
        assert!(!refs.is_empty(), "empty reference corpus");
        let per_sample: Vec<Vec<Vec<Counts>>> = refs
            .iter()
            .map(|rs| rs.iter().map(|r| ngram::all_orders(r, MAX_N)).collect())
            .collect();
        // df(g) = number of *images* (documents) whose reference set
        // contains n-gram g at least once
        let mut df: HashMap<String, f64> = HashMap::new();
        for sample in &per_sample {
            let mut seen: HashMap<&str, ()> = HashMap::new();
            for ref_orders in sample {
                for order in ref_orders {
                    for g in order.keys() {
                        seen.entry(g.as_str()).or_insert(());
                    }
                }
            }
            for g in seen.keys() {
                *df.entry((*g).to_string()).or_insert(0.0) += 1.0;
            }
        }
        // +1 smoothing keeps IDF strictly positive on tiny corpora (a
        // 1-document corpus would otherwise zero every vector); on the
        // 64-sample eval sets the difference to pycocoevalcap's ln(N) is
        // < 2%.
        CiderScorer {
            log_n_docs: (refs.len() as f64 + 1.0).ln(),
            refs: per_sample,
            df,
        }
    }

    pub fn n_samples(&self) -> usize {
        self.refs.len()
    }

    /// TF-IDF vector for one order's counts. TF is the raw count normalized
    /// by the total n-gram count of the sentence; IDF = log(N) - log(df),
    /// clipped at df >= 1.
    fn tfidf(&self, counts: &Counts) -> HashMap<String, f64> {
        let total: f64 = counts.values().sum();
        if total == 0.0 {
            return HashMap::new();
        }
        counts
            .iter()
            .map(|(g, c)| {
                let df = self.df.get(g).copied().unwrap_or(1.0).max(1.0);
                let idf = (self.log_n_docs - df.ln()).max(0.0);
                (g.clone(), (c / total) * idf)
            })
            .collect()
    }

    fn cosine(a: &HashMap<String, f64>, b: &HashMap<String, f64>) -> f64 {
        if a.is_empty() || b.is_empty() {
            return 0.0;
        }
        let dot: f64 = a
            .iter()
            .filter_map(|(g, va)| b.get(g).map(|vb| va * vb))
            .sum();
        let na: f64 = a.values().map(|v| v * v).sum::<f64>().sqrt();
        let nb: f64 = b.values().map(|v| v * v).sum::<f64>().sqrt();
        if na == 0.0 || nb == 0.0 {
            0.0
        } else {
            dot / (na * nb)
        }
    }

    /// CIDEr score of one candidate against sample i's references
    /// (already scaled by `SCALE`, i.e. in the familiar 0..~10 range;
    /// the paper's Table I reports these x10 values as e.g. 132.4 = x100,
    /// our benches report the same x100 convention).
    pub fn score_one(&self, i: usize, candidate: &str) -> f64 {
        let cand_orders = ngram::all_orders(candidate, MAX_N);
        let cand_tfidf: Vec<HashMap<String, f64>> =
            cand_orders.iter().map(|c| self.tfidf(c)).collect();
        let mut per_order = [0.0f64; MAX_N];
        let m = self.refs[i].len() as f64;
        for ref_orders in &self.refs[i] {
            for n in 0..MAX_N {
                let ref_tfidf = self.tfidf(&ref_orders[n]);
                per_order[n] += Self::cosine(&cand_tfidf[n], &ref_tfidf) / m;
            }
        }
        SCALE * per_order.iter().sum::<f64>() / MAX_N as f64
    }

    /// Corpus CIDEr: mean over samples. `candidates.len()` must equal the
    /// corpus size.
    pub fn score(&self, candidates: &[String]) -> f64 {
        assert_eq!(candidates.len(), self.refs.len(), "candidate count");
        let total: f64 = candidates
            .iter()
            .enumerate()
            .map(|(i, c)| self.score_one(i, c))
            .sum();
        total / candidates.len() as f64
    }

    /// Convention used in the paper's figures/tables: CIDEr x 100.
    pub fn score_x100(&self, candidates: &[String]) -> f64 {
        self.score(candidates) * 10.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<Vec<String>> {
        vec![
            vec![
                "a red ball is left of a blue box".into(),
                "the red ball sits left of the blue box".into(),
            ],
            vec![
                "a green tree is above a yellow car".into(),
                "the green tree sits above the yellow car".into(),
            ],
            vec![
                "a purple dog is near a orange chair".into(),
                "the purple dog sits near the orange chair".into(),
            ],
        ]
    }

    #[test]
    fn exact_match_scores_higher_than_wrong_caption() {
        let sc = CiderScorer::new(&corpus());
        let exact = sc.score_one(0, "a red ball is left of a blue box");
        let wrong = sc.score_one(0, "a green tree is above a yellow car");
        assert!(exact > wrong, "exact {exact} !> wrong {wrong}");
        assert!(exact > 1.0);
    }

    #[test]
    fn empty_candidate_scores_zero() {
        let sc = CiderScorer::new(&corpus());
        assert_eq!(sc.score_one(0, ""), 0.0);
    }

    #[test]
    fn partial_match_between_zero_and_exact() {
        let sc = CiderScorer::new(&corpus());
        let exact = sc.score_one(0, "a red ball is left of a blue box");
        let partial = sc.score_one(0, "a red ball is above a blue box");
        assert!(partial > 0.0 && partial < exact);
    }

    #[test]
    fn corpus_score_is_mean() {
        let sc = CiderScorer::new(&corpus());
        let cands: Vec<String> = vec![
            "a red ball is left of a blue box".into(),
            "a green tree is above a yellow car".into(),
            "a purple dog is near a orange chair".into(),
        ];
        let per: f64 = (0..3).map(|i| sc.score_one(i, &cands[i])).sum::<f64>() / 3.0;
        assert!((sc.score(&cands) - per).abs() < 1e-12);
    }

    #[test]
    fn common_words_weigh_less_than_distinctive_words() {
        // "a" appears in every document (idf = 0); "red" only in doc 0
        let sc = CiderScorer::new(&corpus());
        let with_distinctive = sc.score_one(0, "red ball");
        let with_common = sc.score_one(0, "a is");
        assert!(with_distinctive > with_common);
    }

    #[test]
    fn score_is_invariant_to_case() {
        let sc = CiderScorer::new(&corpus());
        let lo = sc.score_one(0, "a red ball is left of a blue box");
        let hi = sc.score_one(0, "A RED BALL IS LEFT OF A BLUE BOX");
        assert!((lo - hi).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "candidate count")]
    fn score_rejects_wrong_candidate_count() {
        CiderScorer::new(&corpus()).score(&["x".into()]);
    }
}
