//! Inference-quality metrics. CIDEr (the paper's §VI-C quality measure,
//! eq. 37) plus the generic stats helpers shared by benches and telemetry.

pub mod cider;
pub mod ngram;
pub mod stats;
