//! N-gram extraction and counting for the CIDEr scorer.

use std::collections::HashMap;

/// A token sequence's n-gram multiset, keyed by the joined token string.
pub type Counts = HashMap<String, f64>;

/// Tokenize a caption: lowercase + whitespace split (matches the build-time
/// python tokenizer, which is also whitespace-based).
pub fn tokenize(caption: &str) -> Vec<String> {
    caption
        .split_whitespace()
        .map(|w| w.to_lowercase())
        .filter(|w| !w.is_empty())
        .collect()
}

/// Extract n-gram counts of order `n` from tokens.
pub fn counts(tokens: &[String], n: usize) -> Counts {
    let mut out = Counts::new();
    if n == 0 || tokens.len() < n {
        return out;
    }
    for win in tokens.windows(n) {
        *out.entry(win.join(" ")).or_insert(0.0) += 1.0;
    }
    out
}

/// All n-gram count maps for orders 1..=max_n.
pub fn all_orders(caption: &str, max_n: usize) -> Vec<Counts> {
    let toks = tokenize(caption);
    (1..=max_n).map(|n| counts(&toks, n)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tokenize_lowercases_and_splits() {
        assert_eq!(tokenize("A Red  Ball"), vec!["a", "red", "ball"]);
        assert!(tokenize("   ").is_empty());
    }

    #[test]
    fn bigram_counts() {
        let toks = tokenize("a red ball a red box");
        let c = counts(&toks, 2);
        assert_eq!(c["a red"], 2.0);
        assert_eq!(c["red ball"], 1.0);
        assert_eq!(c.len(), 4);
    }

    #[test]
    fn order_longer_than_sentence_is_empty() {
        let toks = tokenize("hi");
        assert!(counts(&toks, 2).is_empty());
    }

    #[test]
    fn all_orders_shapes() {
        let v = all_orders("a b c", 4);
        assert_eq!(v.len(), 4);
        assert_eq!(v[0].len(), 3); // unigrams
        assert_eq!(v[2].len(), 1); // single trigram
        assert!(v[3].is_empty()); // no 4-gram
    }
}
