//! Shared statistics helpers: empirical distributions, histograms, the
//! Kolmogorov–Smirnov fit test used for Fig. 2, and L1 norms.

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// L1 norm Σ|x| — the paper's parameter distortion building block (eq. 15).
pub fn l1(xs: &[f32]) -> f64 {
    xs.iter().map(|x| x.abs() as f64).sum()
}

/// L1 distance Σ|x-y|.
pub fn l1_dist(xs: &[f32], ys: &[f32]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    xs.iter()
        .zip(ys)
        .map(|(x, y)| (*x as f64 - *y as f64).abs())
        .sum()
}

/// Normalized histogram over [0, max] with `bins` buckets.
/// Returns (bin_centers, density) with Σ density * bin_width = 1.
pub fn histogram(xs: &[f64], max: f64, bins: usize) -> (Vec<f64>, Vec<f64>) {
    let width = max / bins as f64;
    let mut counts = vec![0.0; bins];
    let mut total = 0.0;
    for &x in xs {
        if x >= 0.0 && x < max {
            counts[(x / width) as usize] += 1.0;
            total += 1.0;
        }
    }
    let centers = (0..bins).map(|i| (i as f64 + 0.5) * width).collect();
    let density = counts
        .into_iter()
        .map(|c| if total > 0.0 { c / (total * width) } else { 0.0 })
        .collect();
    (centers, density)
}

/// One-sample Kolmogorov–Smirnov statistic against a CDF closure.
pub fn ks_statistic(xs: &[f64], cdf: impl Fn(f64) -> f64) -> f64 {
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = sorted.len() as f64;
    let mut d: f64 = 0.0;
    for (i, &x) in sorted.iter().enumerate() {
        let f = cdf(x);
        let emp_hi = (i + 1) as f64 / n;
        let emp_lo = i as f64 / n;
        d = d.max((emp_hi - f).abs()).max((f - emp_lo).abs());
    }
    d
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn l1_basics() {
        assert_eq!(l1(&[1.0, -2.0, 3.0]), 6.0);
        assert_eq!(l1_dist(&[1.0, 2.0], &[0.0, 4.0]), 3.0);
    }

    #[test]
    fn histogram_integrates_to_one() {
        let mut r = Rng::new(0);
        let xs: Vec<f64> = (0..10_000).map(|_| r.exponential(5.0)).collect();
        let (centers, density) = histogram(&xs, 2.0, 50);
        let width = centers[1] - centers[0];
        let integral: f64 = density.iter().map(|d| d * width).sum();
        assert!(integral > 0.95 && integral <= 1.0 + 1e-9, "{integral}");
    }

    #[test]
    fn ks_accepts_matching_distribution() {
        let mut r = Rng::new(1);
        let lam = 3.0;
        let xs: Vec<f64> = (0..20_000).map(|_| r.exponential(lam)).collect();
        let d = ks_statistic(&xs, |x| 1.0 - (-lam * x).exp());
        assert!(d < 0.02, "KS {d} too large for a true exponential");
    }

    #[test]
    fn ks_rejects_wrong_distribution() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..20_000).map(|_| r.f64()).collect(); // uniform
        let d = ks_statistic(&xs, |x| 1.0 - (-3.0 * x).exp());
        assert!(d > 0.2, "KS {d} should reject exponential fit of uniform");
    }
}
