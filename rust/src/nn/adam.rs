//! Adam over a flat parameter vector.

#[derive(Debug, Clone)]
pub struct Adam {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    m: Vec<f64>,
    v: Vec<f64>,
    t: u64,
}

impl Adam {
    pub fn new(n_params: usize, lr: f64) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
            t: 0,
        }
    }

    /// In-place parameter update from grads; optional global-norm clip.
    pub fn step(&mut self, params: &mut [f64], grads: &[f64], clip: Option<f64>) {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.t += 1;

        let scale = match clip {
            Some(c) => {
                let norm: f64 = grads.iter().map(|g| g * g).sum::<f64>().sqrt();
                if norm > c {
                    c / norm
                } else {
                    1.0
                }
            }
            None => 1.0,
        };

        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i] * scale;
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * g;
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * g * g;
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = (x-3)^2 + (y+1)^2
        let mut p = vec![0.0, 0.0];
        let mut opt = Adam::new(2, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (p[0] - 3.0), 2.0 * (p[1] + 1.0)];
            opt.step(&mut p, &g, None);
        }
        assert!((p[0] - 3.0).abs() < 1e-2, "{p:?}");
        assert!((p[1] + 1.0).abs() < 1e-2, "{p:?}");
    }

    #[test]
    fn clipping_bounds_update_magnitude() {
        let mut p = vec![0.0];
        let mut opt = Adam::new(1, 0.1);
        opt.step(&mut p, &[1e9], Some(1.0));
        assert!(p[0].abs() <= 0.11, "{p:?}");
    }
}
