//! Row-major f64 matrix with the handful of ops the MLPs need.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Xavier/He-style init: N(0, sqrt(2/fan_in)).
    pub fn he(rows: usize, cols: usize, rng: &mut Rng) -> Matrix {
        let std = (2.0 / rows as f64).sqrt();
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| std * rng.normal()).collect(),
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    /// y = x @ self, x: (cols_in = rows) vector.
    pub fn vec_mul(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "vec_mul dim");
        let mut y = vec![0.0; self.cols];
        for (r, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            for (c, w) in row.iter().enumerate() {
                y[c] += xv * w;
            }
        }
        y
    }

    /// grad wrt self of (x @ self) given upstream dy: outer(x, dy),
    /// accumulated into `acc`.
    pub fn accumulate_outer(acc: &mut Matrix, x: &[f64], dy: &[f64]) {
        assert_eq!(x.len(), acc.rows);
        assert_eq!(dy.len(), acc.cols);
        for (r, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let row = &mut acc.data[r * acc.cols..(r + 1) * acc.cols];
            for (c, d) in dy.iter().enumerate() {
                row[c] += xv * d;
            }
        }
    }

    /// dx of (x @ self) given dy: self @ dy (row-space product).
    pub fn grad_input(&self, dy: &[f64]) -> Vec<f64> {
        assert_eq!(dy.len(), self.cols, "grad_input dim");
        let mut dx = vec![0.0; self.rows];
        for r in 0..self.rows {
            let row = &self.data[r * self.cols..(r + 1) * self.cols];
            dx[r] = row.iter().zip(dy).map(|(w, d)| w * d).sum();
        }
        dx
    }

    pub fn scale(&mut self, s: f64) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn fill(&mut self, v: f64) {
        self.data.fill(v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vec_mul_known_values() {
        // [1,2] @ [[1,2,3],[4,5,6]] = [9,12,15]
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(m.vec_mul(&[1.0, 2.0]), vec![9.0, 12.0, 15.0]);
    }

    #[test]
    fn grad_input_is_transpose_mul() {
        let m = Matrix::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
        // dx = M @ dy
        assert_eq!(m.grad_input(&[1.0, 0.0, 1.0]), vec![4.0, 10.0]);
    }

    #[test]
    fn outer_accumulation() {
        let mut acc = Matrix::zeros(2, 2);
        Matrix::accumulate_outer(&mut acc, &[1.0, 2.0], &[3.0, 4.0]);
        assert_eq!(acc.data, vec![3.0, 4.0, 6.0, 8.0]);
        Matrix::accumulate_outer(&mut acc, &[1.0, 0.0], &[1.0, 1.0]);
        assert_eq!(acc.data, vec![4.0, 5.0, 6.0, 8.0]);
    }

    #[test]
    fn he_init_statistics() {
        let mut rng = Rng::new(0);
        let m = Matrix::he(256, 256, &mut rng);
        let mean = m.data.iter().sum::<f64>() / m.data.len() as f64;
        let var = m.data.iter().map(|v| (v - mean) * (v - mean)).sum::<f64>() / m.data.len() as f64;
        assert!(mean.abs() < 0.01);
        assert!((var - 2.0 / 256.0).abs() < 0.002);
    }
}
