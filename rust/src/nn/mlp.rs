//! MLP with manual backprop: Linear → activation stacks, per-sample
//! forward caches, gradient accumulation across a minibatch.

use super::matrix::Matrix;
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activation {
    Tanh,
    Relu,
    /// identity (output layer)
    Linear,
}

impl Activation {
    fn apply(self, x: f64) -> f64 {
        match self {
            Activation::Tanh => x.tanh(),
            Activation::Relu => x.max(0.0),
            Activation::Linear => x,
        }
    }

    /// derivative expressed via the *activated* output a = act(z)
    fn dapply(self, a: f64) -> f64 {
        match self {
            Activation::Tanh => 1.0 - a * a,
            Activation::Relu => {
                if a > 0.0 {
                    1.0
                } else {
                    0.0
                }
            }
            Activation::Linear => 1.0,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Layer {
    pub w: Matrix, // (in, out)
    pub b: Vec<f64>,
    pub act: Activation,
}

/// Forward cache for one sample: the activated output of every layer
/// (index 0 = the input itself).
pub type Cache = Vec<Vec<f64>>;

#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<Layer>,
}

/// Gradient buffers matching an Mlp's parameters.
#[derive(Debug, Clone)]
pub struct Grads {
    pub w: Vec<Matrix>,
    pub b: Vec<Vec<f64>>,
}

impl Mlp {
    /// `dims = [in, h1, ..., out]`; hidden layers use `hidden_act`,
    /// output layer is linear.
    pub fn new(dims: &[usize], hidden_act: Activation, rng: &mut Rng) -> Mlp {
        assert!(dims.len() >= 2);
        let layers = dims
            .windows(2)
            .enumerate()
            .map(|(i, w2)| Layer {
                w: Matrix::he(w2[0], w2[1], rng),
                b: vec![0.0; w2[1]],
                act: if i + 2 == dims.len() { Activation::Linear } else { hidden_act },
            })
            .collect();
        Mlp { layers }
    }

    pub fn forward(&self, x: &[f64]) -> Vec<f64> {
        let mut h = x.to_vec();
        for layer in &self.layers {
            let mut z = layer.w.vec_mul(&h);
            for (zi, bi) in z.iter_mut().zip(&layer.b) {
                *zi = layer.act.apply(*zi + bi);
            }
            h = z;
        }
        h
    }

    /// Forward keeping every intermediate activation for backprop.
    pub fn forward_cached(&self, x: &[f64]) -> (Vec<f64>, Cache) {
        let mut cache: Cache = vec![x.to_vec()];
        let mut h = x.to_vec();
        for layer in &self.layers {
            let mut z = layer.w.vec_mul(&h);
            for (zi, bi) in z.iter_mut().zip(&layer.b) {
                *zi = layer.act.apply(*zi + bi);
            }
            cache.push(z.clone());
            h = z;
        }
        (h, cache)
    }

    /// Backprop `dout` (d loss / d output) through the cached forward,
    /// accumulating parameter grads into `grads`; returns d loss / d input.
    pub fn backward(&self, cache: &Cache, dout: &[f64], grads: &mut Grads) -> Vec<f64> {
        let mut delta = dout.to_vec();
        for (li, layer) in self.layers.iter().enumerate().rev() {
            let a_out = &cache[li + 1];
            // through the activation
            for (d, a) in delta.iter_mut().zip(a_out) {
                *d *= layer.act.dapply(*a);
            }
            // bias grad
            for (g, d) in grads.b[li].iter_mut().zip(&delta) {
                *g += d;
            }
            // weight grad + input grad
            Matrix::accumulate_outer(&mut grads.w[li], &cache[li], &delta);
            delta = layer.w.grad_input(&delta);
        }
        delta
    }

    pub fn zero_grads(&self) -> Grads {
        Grads {
            w: self.layers.iter().map(|l| Matrix::zeros(l.w.rows, l.w.cols)).collect(),
            b: self.layers.iter().map(|l| vec![0.0; l.b.len()]).collect(),
        }
    }

    /// Flatten parameters (for the Adam optimizer).
    pub fn flat_params(&self) -> Vec<f64> {
        let mut out = Vec::new();
        for l in &self.layers {
            out.extend_from_slice(&l.w.data);
            out.extend_from_slice(&l.b);
        }
        out
    }

    pub fn set_flat_params(&mut self, flat: &[f64]) {
        let mut i = 0;
        for l in &mut self.layers {
            let nw = l.w.data.len();
            l.w.data.copy_from_slice(&flat[i..i + nw]);
            i += nw;
            let nb = l.b.len();
            l.b.copy_from_slice(&flat[i..i + nb]);
            i += nb;
        }
        assert_eq!(i, flat.len());
    }

    pub fn flat_grads(grads: &Grads) -> Vec<f64> {
        let mut out = Vec::new();
        for (w, b) in grads.w.iter().zip(&grads.b) {
            out.extend_from_slice(&w.data);
            out.extend_from_slice(b);
        }
        out
    }

    pub fn n_params(&self) -> usize {
        self.layers.iter().map(|l| l.w.data.len() + l.b.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Numerical-gradient check: the backbone guarantee for PPO.
    #[test]
    fn backprop_matches_finite_differences() {
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[3, 8, 2], Activation::Tanh, &mut rng);
        let x = [0.3, -0.7, 1.2];
        // loss = sum of squares of outputs
        let loss = |m: &Mlp| -> f64 { m.forward(&x).iter().map(|o| o * o).sum() };

        let (out, cache) = mlp.forward_cached(&x);
        let mut grads = mlp.zero_grads();
        let dout: Vec<f64> = out.iter().map(|o| 2.0 * o).collect();
        mlp.backward(&cache, &dout, &mut grads);
        let analytic = Mlp::flat_grads(&grads);

        let eps = 1e-6;
        let flat = mlp.flat_params();
        for idx in (0..flat.len()).step_by(7) {
            let mut plus = flat.clone();
            plus[idx] += eps;
            mlp.set_flat_params(&plus);
            let lp = loss(&mlp);
            let mut minus = flat.clone();
            minus[idx] -= eps;
            mlp.set_flat_params(&minus);
            let lm = loss(&mlp);
            mlp.set_flat_params(&flat);
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic[idx]).abs() < 1e-5 * (1.0 + numeric.abs()),
                "param {idx}: numeric {numeric} vs analytic {}",
                analytic[idx]
            );
        }
    }

    #[test]
    fn relu_backprop_matches_finite_differences() {
        let mut rng = Rng::new(4);
        let mut mlp = Mlp::new(&[2, 6, 1], Activation::Relu, &mut rng);
        let x = [0.9, -0.4];
        let loss = |m: &Mlp| m.forward(&x)[0];
        let (_, cache) = mlp.forward_cached(&x);
        let mut grads = mlp.zero_grads();
        mlp.backward(&cache, &[1.0], &mut grads);
        let analytic = Mlp::flat_grads(&grads);
        let eps = 1e-6;
        let flat = mlp.flat_params();
        for idx in (0..flat.len()).step_by(3) {
            let mut plus = flat.clone();
            plus[idx] += eps;
            mlp.set_flat_params(&plus);
            let lp = loss(&mlp);
            let mut minus = flat.clone();
            minus[idx] -= eps;
            mlp.set_flat_params(&minus);
            let lm = loss(&mlp);
            mlp.set_flat_params(&flat);
            let numeric = (lp - lm) / (2.0 * eps);
            assert!(
                (numeric - analytic[idx]).abs() < 1e-5 * (1.0 + numeric.abs()),
                "param {idx}"
            );
        }
    }

    #[test]
    fn flat_roundtrip() {
        let mut rng = Rng::new(5);
        let mut mlp = Mlp::new(&[4, 5, 3], Activation::Tanh, &mut rng);
        let flat = mlp.flat_params();
        assert_eq!(flat.len(), mlp.n_params());
        let out_before = mlp.forward(&[1.0, 2.0, 3.0, 4.0]);
        mlp.set_flat_params(&flat);
        assert_eq!(mlp.forward(&[1.0, 2.0, 3.0, 4.0]), out_before);
    }
}
