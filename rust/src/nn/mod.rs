//! Minimal dense-network substrate (torch stand-in) powering the PPO
//! baseline (§VI-C benchmark 1) and the FC-DNN used to verify Prop. 3.1.
//!
//! Design: plain `Vec<f64>` matrices, explicit forward caches, manual
//! backprop, Adam. No autograd graph — the networks here are 2-3 layer
//! MLPs where hand-written gradients are simpler and faster.

pub mod adam;
pub mod matrix;
pub mod mlp;

pub use adam::Adam;
pub use matrix::Matrix;
pub use mlp::{Activation, Mlp};
