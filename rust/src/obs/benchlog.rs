//! Append-only, content-hashed bench-trajectory store backing
//! `qaci bench-log ingest|query|diff`.
//!
//! The index is a JSON-lines file: one compact object per line, each
//! wrapping one ingested payload (a `BENCH_*.json` artifact or a
//! `qaci.metrics` snapshot):
//!
//! ```text
//! {"schema":"qaci.benchlog","version":1,"seq":0,"bench":"fleet_churn",
//!  "kind":"bench","digest":"fnv1a:9c3e4f0a1b2c3d4e","payload":{...}}
//! ```
//!
//! The digest is 64-bit FNV-1a over the payload's *canonical bytes* —
//! its compact [`crate::util::json`] serialization — so byte-level
//! corruption of any stored payload is caught on read, and a parsed
//! entry re-serializes to exactly the bytes its digest covers. Entries
//! with an unknown schema name or version are rejected cleanly rather
//! than misread.
//!
//! [`diff`] compares the newest run of every bench against a stored
//! baseline at two strictness levels: **ordering invariants** (strict
//! per-scenario orderings between policies in the baseline — e.g.
//! online-proposed cost below the statics — must not invert; these are
//! machine-invariant, so CI gates on them) and **value regressions**
//! (tracked lower-is-better fields must stay within a relative
//! tolerance of the baseline; skipped with
//! [`DiffOptions::orderings_only`] because absolute timings vary across
//! machines). `wall_clock_s` is deliberately untracked.

use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Schema identifier stamped on every index entry.
pub const BENCHLOG_SCHEMA: &str = "qaci.benchlog";
/// Entry layout version this build reads and writes.
pub const BENCHLOG_VERSION: u32 = 1;

/// Numeric result fields [`diff`] tracks (all lower-is-better);
/// `wall_clock_s` is deliberately absent — absolute machine timings are
/// too noisy to gate on.
pub const TRACKED_FIELDS: [&str; 5] =
    ["cost", "d_upper", "p99_s", "queue_wait_p99_s", "deadline_violation_rate"];

/// 64-bit FNV-1a over raw bytes (the same algorithm the property
/// harness uses for its per-name seed streams).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Content digest of a payload: FNV-1a over its compact canonical
/// bytes, rendered as `fnv1a:<16 lowercase hex digits>`.
pub fn digest_of(payload: &Json) -> String {
    format!("fnv1a:{:016x}", fnv1a64(payload.to_string_compact().as_bytes()))
}

/// One verified index entry.
#[derive(Debug, Clone)]
pub struct Entry {
    /// position in the index (0-based ingest order)
    pub seq: u64,
    /// bench name the payload belongs to (e.g. `fleet_churn`)
    pub bench: String,
    /// `"bench"` for bench artifacts, `"metrics"` for metrics snapshots
    pub kind: String,
    /// `fnv1a:<hex>` content digest of the canonical payload bytes
    pub digest: String,
    /// the stored document itself
    pub payload: Json,
}

impl Entry {
    /// Serialize to the canonical single-line index form.
    pub fn to_line(&self) -> String {
        Json::obj()
            .set("schema", BENCHLOG_SCHEMA)
            .set("version", BENCHLOG_VERSION as usize)
            .set("seq", self.seq as usize)
            .set("bench", self.bench.as_str())
            .set("kind", self.kind.as_str())
            .set("digest", self.digest.as_str())
            .set("payload", self.payload.clone())
            .to_string_compact()
    }

    /// Parse and verify one index line: the schema and version must be
    /// the ones this build writes, and the recomputed payload digest
    /// must match the stored one (a mismatch means the payload bytes
    /// were altered after ingest).
    pub fn from_line(line: &str) -> Result<Entry> {
        let j = json::parse(line).map_err(|e| anyhow!("bench-log entry: {e}"))?;
        let schema = j.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != BENCHLOG_SCHEMA {
            bail!("bench-log entry: unknown schema {schema:?} (expected {BENCHLOG_SCHEMA:?})");
        }
        let version = j.get("version").and_then(Json::as_usize);
        if version != Some(BENCHLOG_VERSION as usize) {
            bail!(
                "bench-log entry: unsupported schema version {version:?} \
                 (this build reads version {BENCHLOG_VERSION})"
            );
        }
        let field = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(Json::as_str)
                .map(str::to_string)
                .ok_or_else(|| anyhow!("bench-log entry: missing field {k:?}"))
        };
        let seq = j
            .get("seq")
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow!("bench-log entry: missing field \"seq\""))?
            as u64;
        let bench = field("bench")?;
        let kind = field("kind")?;
        let digest = field("digest")?;
        let payload = j
            .get("payload")
            .cloned()
            .ok_or_else(|| anyhow!("bench-log entry: missing field \"payload\""))?;
        let actual = digest_of(&payload);
        if actual != digest {
            bail!(
                "bench-log entry seq {seq} ({bench}): digest mismatch — stored {digest}, \
                 payload hashes to {actual} (corrupted index?)"
            );
        }
        Ok(Entry { seq, bench, kind, digest, payload })
    }
}

/// Handle on one append-only index file (which need not exist yet).
#[derive(Debug, Clone)]
pub struct BenchLog {
    path: PathBuf,
}

impl BenchLog {
    /// Open (lazily) the index at `path`.
    pub fn open(path: impl Into<PathBuf>) -> BenchLog {
        BenchLog { path: path.into() }
    }

    /// The index file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Read and verify every entry; a missing file is an empty index,
    /// but any malformed or digest-corrupted line fails the whole read
    /// (an append-only log with a bad record cannot be trusted past it).
    pub fn entries(&self) -> Result<Vec<Entry>> {
        let text = match std::fs::read_to_string(&self.path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(anyhow!("reading {}: {e}", self.path.display())),
        };
        let mut out = Vec::new();
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let entry = Entry::from_line(line)
                .map_err(|e| anyhow!("{} line {}: {e:#}", self.path.display(), i + 1))?;
            out.push(entry);
        }
        Ok(out)
    }

    /// Append one payload under the given bench name and kind; returns
    /// the stored entry.
    pub fn ingest(&self, bench: &str, kind: &str, payload: &Json) -> Result<Entry> {
        let seq = self.entries()?.len() as u64;
        let entry = Entry {
            seq,
            bench: bench.to_string(),
            kind: kind.to_string(),
            digest: digest_of(payload),
            payload: payload.clone(),
        };
        if let Some(dir) = self.path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let mut f = std::fs::OpenOptions::new().create(true).append(true).open(&self.path)?;
        writeln!(f, "{}", entry.to_line())?;
        Ok(entry)
    }

    /// Ingest a JSON document from disk: bench artifacts are recognized
    /// by their `bench`/`results` keys, metrics snapshots by their
    /// `qaci.metrics` schema stamp; anything else (including a
    /// truncated artifact from an interrupted bench run) is rejected.
    pub fn ingest_file(&self, path: &Path) -> Result<Entry> {
        let doc = json::parse_file(path)?;
        if doc.get("schema").and_then(Json::as_str) == Some(super::metrics::METRICS_SCHEMA) {
            let stem = path.file_stem().and_then(|s| s.to_str()).unwrap_or("metrics");
            return self.ingest(stem, "metrics", &doc);
        }
        match doc.get("bench").and_then(Json::as_str) {
            Some(bench) if doc.get("results").and_then(Json::as_arr).is_some() => {
                let bench = bench.to_string();
                self.ingest(&bench, "bench", &doc)
            }
            _ => bail!(
                "{}: neither a bench artifact (bench/results keys) nor a metrics snapshot",
                path.display()
            ),
        }
    }

    /// Answer "field F on scenario S over the last K runs": scan the
    /// bench entries oldest-to-newest, keep the last `q.last` runs
    /// matching the bench filter (0 = all), and pull the field out of
    /// every result row matching the scenario/policy filters.
    pub fn query(&self, q: &Query) -> Result<Vec<QueryRow>> {
        let mut entries: Vec<Entry> = self
            .entries()?
            .into_iter()
            .filter(|e| e.kind == "bench")
            .filter(|e| q.bench.as_deref().is_none_or(|b| e.bench == b))
            .collect();
        if q.last > 0 && entries.len() > q.last {
            entries = entries.split_off(entries.len() - q.last);
        }
        let mut rows = Vec::new();
        for e in &entries {
            for r in e.payload.get("results").and_then(Json::as_arr).unwrap_or(&[]) {
                let scenario = r.get("scenario").and_then(Json::as_str).unwrap_or("");
                let policy = r.get("policy").and_then(Json::as_str).unwrap_or("");
                if q.scenario.as_deref().is_none_or(|s| s == scenario)
                    && q.policy.as_deref().is_none_or(|p| p == policy)
                {
                    rows.push(QueryRow {
                        seq: e.seq,
                        bench: e.bench.clone(),
                        scenario: scenario.to_string(),
                        policy: policy.to_string(),
                        field: q.field.clone(),
                        value: r.get(&q.field).and_then(Json::as_f64),
                    });
                }
            }
        }
        Ok(rows)
    }
}

/// Filters for [`BenchLog::query`] (all optional except the field).
#[derive(Debug, Clone, Default)]
pub struct Query {
    /// restrict to one bench name
    pub bench: Option<String>,
    /// restrict to one scenario
    pub scenario: Option<String>,
    /// restrict to one policy
    pub policy: Option<String>,
    /// result field to extract (e.g. `p99_s`)
    pub field: String,
    /// only the last K matching runs (0 = all)
    pub last: usize,
}

/// One row answered by [`BenchLog::query`].
#[derive(Debug, Clone)]
pub struct QueryRow {
    /// index entry the row came from
    pub seq: u64,
    /// bench name
    pub bench: String,
    /// scenario label
    pub scenario: String,
    /// policy label
    pub policy: String,
    /// the queried field name
    pub field: String,
    /// `None` when the artifact stored `null` (e.g. a percentile with
    /// no samples) or lacks the field
    pub value: Option<f64>,
}

/// Knobs for [`diff`].
#[derive(Debug, Clone)]
pub struct DiffOptions {
    /// skip the absolute-value regression check (CI mode: orderings are
    /// machine-invariant, absolute numbers are not)
    pub orderings_only: bool,
    /// relative headroom for the value check: latest ≤ baseline·(1+tol)
    pub tolerance: f64,
}

impl Default for DiffOptions {
    fn default() -> Self {
        DiffOptions { orderings_only: false, tolerance: 0.05 }
    }
}

/// One regression finding from [`diff`].
#[derive(Debug, Clone)]
pub struct Finding {
    /// `"coverage"`, `"ordering"` or `"regression"`
    pub kind: &'static str,
    /// human-readable description
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "[{}] {}", self.kind, self.message)
    }
}

type Rows = BTreeMap<(String, String), BTreeMap<&'static str, f64>>;

/// Tracked fields per (scenario, policy) row of one bench payload.
fn result_rows(e: &Entry) -> Rows {
    let mut rows = Rows::new();
    for r in e.payload.get("results").and_then(Json::as_arr).unwrap_or(&[]) {
        let scenario = r.get("scenario").and_then(Json::as_str).unwrap_or("").to_string();
        let policy = r.get("policy").and_then(Json::as_str).unwrap_or("").to_string();
        let mut fields = BTreeMap::new();
        for name in TRACKED_FIELDS {
            if let Some(v) = r.get(name).and_then(Json::as_f64) {
                fields.insert(name, v);
            }
        }
        rows.insert((scenario, policy), fields);
    }
    rows
}

/// Newest bench-kind entry per bench name.
fn latest_per_bench(entries: &[Entry]) -> BTreeMap<String, Entry> {
    let mut out = BTreeMap::new();
    for e in entries.iter().filter(|e| e.kind == "bench") {
        out.insert(e.bench.clone(), e.clone());
    }
    out
}

/// Compare one bench's latest run against its baseline run, appending
/// findings: coverage (baseline rows must still be emitted), ordering
/// (strict baseline orderings between policies must not invert) and —
/// unless `orderings_only` — value regressions on the tracked fields.
fn diff_one(
    bench: &str,
    base_entry: &Entry,
    new_entry: &Entry,
    opts: &DiffOptions,
    findings: &mut Vec<Finding>,
) {
    let base_rows = result_rows(base_entry);
    let new_rows = result_rows(new_entry);
    for (scenario, policy) in base_rows.keys() {
        if !new_rows.contains_key(&(scenario.clone(), policy.clone())) {
            findings.push(Finding {
                kind: "coverage",
                message: format!("{bench}/{scenario}/{policy}: row missing from latest run"),
            });
        }
    }
    let scenarios: BTreeSet<&String> = base_rows.keys().map(|(s, _)| s).collect();
    for scenario in scenarios {
        let keys: Vec<&(String, String)> =
            base_rows.keys().filter(|(s, _)| s == scenario).collect();
        for (ai, a) in keys.iter().enumerate() {
            for b in keys.iter().skip(ai + 1) {
                for field in TRACKED_FIELDS {
                    let pair = (
                        base_rows[*a].get(field).copied(),
                        base_rows[*b].get(field).copied(),
                        new_rows.get(*a).and_then(|r| r.get(field)).copied(),
                        new_rows.get(*b).and_then(|r| r.get(field)).copied(),
                    );
                    let (Some(ba), Some(bb), Some(na), Some(nb)) = pair else { continue };
                    // a strict baseline ordering may weaken to a tie but
                    // must not invert
                    if (ba < bb && na > nb) || (ba > bb && na < nb) {
                        findings.push(Finding {
                            kind: "ordering",
                            message: format!(
                                "{bench}/{scenario}: {field} ordering inverted — baseline \
                                 {pa}={ba} vs {pb}={bb}, latest {pa}={na} vs {pb}={nb}",
                                pa = a.1,
                                pb = b.1,
                            ),
                        });
                    }
                }
            }
        }
    }
    if opts.orderings_only {
        return;
    }
    for (key, bfields) in &base_rows {
        let Some(nfields) = new_rows.get(key) else { continue };
        for field in TRACKED_FIELDS {
            let (Some(&bv), Some(&nv)) = (bfields.get(field), nfields.get(field)) else {
                continue;
            };
            let limit = bv * (1.0 + opts.tolerance) + 1e-12;
            if nv > limit {
                findings.push(Finding {
                    kind: "regression",
                    message: format!(
                        "{bench}/{}/{}: {field} regressed {bv} -> {nv} (over {:.1}% headroom)",
                        key.0,
                        key.1,
                        opts.tolerance * 100.0
                    ),
                });
            }
        }
    }
}

/// Diff the newest run of every bench in `index` against the newest run
/// in `baseline`. Clean = empty vector; benches present only in `index`
/// are ignored (new benches are not regressions), benches present only
/// in `baseline` are coverage findings.
pub fn diff(index: &BenchLog, baseline: &BenchLog, opts: &DiffOptions) -> Result<Vec<Finding>> {
    let latest = latest_per_bench(&index.entries()?);
    let base = latest_per_bench(&baseline.entries()?);
    let mut findings = Vec::new();
    for (bench, base_entry) in &base {
        match latest.get(bench) {
            Some(new_entry) => diff_one(bench, base_entry, new_entry, opts, &mut findings),
            None => findings.push(Finding {
                kind: "coverage",
                message: format!("bench {bench}: in baseline but missing from index"),
            }),
        }
    }
    Ok(findings)
}

/// Diff the newest run of each bench against the *previous* run in the
/// same index — the "did my last run regress?" mode used when no
/// external baseline is given. Benches with fewer than two runs are
/// skipped.
pub fn diff_latest_pair(index: &BenchLog, opts: &DiffOptions) -> Result<Vec<Finding>> {
    let entries = index.entries()?;
    let benches: BTreeSet<String> =
        entries.iter().filter(|e| e.kind == "bench").map(|e| e.bench.clone()).collect();
    let mut findings = Vec::new();
    for bench in benches {
        let runs: Vec<&Entry> =
            entries.iter().filter(|e| e.kind == "bench" && e.bench == bench).collect();
        if let [.., prev, last] = runs.as_slice() {
            diff_one(&bench, prev, last, opts, &mut findings);
        }
    }
    Ok(findings)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn tmpdir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("qaci-benchlog-{tag}-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn bench_doc(bench: &str, rows: &[(&str, &str, f64, f64)]) -> Json {
        let results: Vec<Json> = rows
            .iter()
            .map(|(scenario, policy, cost, p99)| {
                Json::obj()
                    .set("scenario", *scenario)
                    .set("policy", *policy)
                    .set("cost", *cost)
                    .set("p99_s", *p99)
            })
            .collect();
        Json::obj().set("bench", bench).set("version", 1.0).set("results", Json::Arr(results))
    }

    #[test]
    fn fnv1a_known_vectors() {
        // offset basis and the classic single-byte vectors pin the exact
        // algorithm (matches util::prop's seed-stream hash)
        assert_eq!(fnv1a64(b""), 0xcbf29ce484222325);
        assert_eq!(fnv1a64(b"a"), 0xaf63dc4c8601ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        assert_eq!(digest_of(&Json::Null), format!("fnv1a:{:016x}", fnv1a64(b"null")));
    }

    #[test]
    fn ingest_query_roundtrip_is_byte_stable() {
        let path = tmpdir("roundtrip").join("index.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = BenchLog::open(&path);
        let doc = bench_doc("fleet_churn", &[("burst-storm", "online-proposed", 1.25, 19.7)]);
        log.ingest("fleet_churn", "bench", &doc).unwrap();
        let entries = log.entries().unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].payload, doc);
        // re-serialization reproduces the stored line byte for byte
        let stored = std::fs::read_to_string(&path).unwrap();
        assert_eq!(stored, format!("{}\n", entries[0].to_line()));
        let rows = log.query(&Query { field: "p99_s".into(), ..Query::default() }).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].value, Some(19.7));
    }

    #[test]
    fn mutated_payload_is_rejected_by_digest() {
        let path = tmpdir("corrupt").join("index.jsonl");
        let _ = std::fs::remove_file(&path);
        let log = BenchLog::open(&path);
        log.ingest("b", "bench", &bench_doc("b", &[("s", "p", 2.0, 3.0)])).unwrap();
        let line = std::fs::read_to_string(&path).unwrap();
        // flip one payload byte ("cost":2 -> "cost":9), keep valid JSON
        let tampered = line.replace("\"cost\":2", "\"cost\":9");
        assert_ne!(tampered, line, "mutation must apply");
        std::fs::write(&path, tampered).unwrap();
        let err = log.entries().unwrap_err().to_string();
        assert!(err.contains("digest mismatch"), "{err}");
    }

    #[test]
    fn unknown_schema_and_version_rejected_cleanly() {
        let good = Entry {
            seq: 0,
            bench: "b".into(),
            kind: "bench".into(),
            digest: digest_of(&Json::Null),
            payload: Json::Null,
        }
        .to_line();
        let wrong_schema = good.replace("qaci.benchlog", "qaci.other");
        let err = Entry::from_line(&wrong_schema).unwrap_err().to_string();
        assert!(err.contains("unknown schema"), "{err}");
        let wrong_version = good.replace("\"version\":1", "\"version\":99");
        let err = Entry::from_line(&wrong_version).unwrap_err().to_string();
        assert!(err.contains("unsupported schema version"), "{err}");
        assert!(Entry::from_line("{\"schema\":").is_err(), "truncated line must be rejected");
    }

    #[test]
    fn ingest_file_rejects_truncated_artifact() {
        // the partial write an interrupted (pre-atomic-rename) bench run
        // could have left behind must never hash into the index
        let dir = tmpdir("truncated");
        let artifact = dir.join("BENCH_partial.json");
        std::fs::write(&artifact, "{\"bench\":\"fleet_churn\",\"version\":1,\"results\":[{\"sc")
            .unwrap();
        let log = BenchLog::open(dir.join("index.jsonl"));
        assert!(log.ingest_file(&artifact).is_err());
        assert!(log.entries().unwrap().is_empty(), "nothing may be appended on rejection");
    }

    #[test]
    fn diff_identical_runs_is_clean_and_regression_is_caught() {
        let dir = tmpdir("diff");
        let path = dir.join("index.jsonl");
        let base_path = dir.join("baseline.jsonl");
        for p in [&path, &base_path] {
            let _ = std::fs::remove_file(p);
        }
        let doc = bench_doc(
            "fleet_churn",
            &[
                ("burst-storm", "online-proposed", 1.0, 20.0),
                ("burst-storm", "static-proposed", 4.0, 220.0),
            ],
        );
        let baseline = BenchLog::open(&base_path);
        baseline.ingest("fleet_churn", "bench", &doc).unwrap();
        let log = BenchLog::open(&path);
        log.ingest("fleet_churn", "bench", &doc).unwrap();
        assert!(diff(&log, &baseline, &DiffOptions::default()).unwrap().is_empty());

        // inject a p99 regression on the online policy: value check
        // fires, and once it climbs past static the ordering check too
        let bad = bench_doc(
            "fleet_churn",
            &[
                ("burst-storm", "online-proposed", 1.0, 500.0),
                ("burst-storm", "static-proposed", 4.0, 220.0),
            ],
        );
        log.ingest("fleet_churn", "bench", &bad).unwrap();
        let findings = diff(&log, &baseline, &DiffOptions::default()).unwrap();
        assert!(findings.iter().any(|f| f.kind == "regression"), "{findings:?}");
        assert!(findings.iter().any(|f| f.kind == "ordering"), "{findings:?}");
        // orderings-only mode still catches the inversion but not values
        let oo = DiffOptions { orderings_only: true, ..DiffOptions::default() };
        let findings = diff(&log, &baseline, &oo).unwrap();
        assert!(findings.iter().all(|f| f.kind == "ordering"), "{findings:?}");
        assert!(!findings.is_empty());
        // and the in-index previous-vs-latest mode sees the same break
        assert!(!diff_latest_pair(&log, &DiffOptions::default()).unwrap().is_empty());
    }

    #[test]
    fn diff_flags_missing_coverage() {
        let dir = tmpdir("coverage");
        let base_path = dir.join("baseline.jsonl");
        let path = dir.join("index.jsonl");
        for p in [&path, &base_path] {
            let _ = std::fs::remove_file(p);
        }
        let baseline = BenchLog::open(&base_path);
        baseline
            .ingest(
                "fleet_scale",
                "bench",
                &bench_doc("fleet_scale", &[("scale-4", "proposed", 1.0, 2.0)]),
            )
            .unwrap();
        let log = BenchLog::open(&path);
        // empty index: the whole bench is missing
        let findings = diff(&log, &baseline, &DiffOptions::default()).unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(findings[0].kind, "coverage");
        // bench present but the row vanished
        log.ingest("fleet_scale", "bench", &bench_doc("fleet_scale", &[])).unwrap();
        let findings = diff(&log, &baseline, &DiffOptions::default()).unwrap();
        assert!(findings.iter().any(|f| f.kind == "coverage" && f.message.contains("scale-4")));
    }

    #[test]
    fn property_random_payloads_roundtrip_and_reject_mutation() {
        // SNIPPETS-style manifest stability: ingest → read → re-serialize
        // must be byte-identical, and any payload byte flip must be
        // rejected by the digest check
        fn gen_payload(r: &mut Rng, depth: usize) -> Json {
            match if depth == 0 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.f64() < 0.5),
                2 => Json::Num((r.normal() * 50.0 * 4.0).round() / 4.0),
                3 => Json::Str(
                    (0..r.below(6)).map(|_| char::from(b'a' + r.below(26) as u8)).collect(),
                ),
                4 => Json::Arr((0..r.below(3)).map(|_| gen_payload(r, depth - 1)).collect()),
                _ => Json::Obj(
                    (0..r.below(3))
                        .map(|i| (format!("k{i}"), gen_payload(r, depth - 1)))
                        .collect(),
                ),
            }
        }
        forall(
            "benchlog entry roundtrip",
            120,
            |r| gen_payload(r, 3),
            |payload| {
                let entry = Entry {
                    seq: 3,
                    bench: "prop".into(),
                    kind: "bench".into(),
                    digest: digest_of(payload),
                    payload: payload.clone(),
                };
                let line = entry.to_line();
                let back = Entry::from_line(&line).map_err(|e| format!("verify failed: {e}"))?;
                if back.to_line() != line {
                    return Err(format!("re-serialization drifted: {}", back.to_line()));
                }
                if back.payload != *payload {
                    return Err("payload drifted through the index".into());
                }
                // an entry whose digest was computed against the original
                // payload but whose stored payload was mutated must fail
                let forged = Json::obj()
                    .set("schema", BENCHLOG_SCHEMA)
                    .set("version", BENCHLOG_VERSION as usize)
                    .set("seq", 4.0)
                    .set("bench", "prop")
                    .set("kind", "bench")
                    .set("digest", back.digest.as_str())
                    .set("payload", Json::Arr(vec![payload.clone(), Json::Bool(true)]))
                    .to_string_compact();
                match Entry::from_line(&forged) {
                    Err(_) => Ok(()),
                    Ok(_) => Err("mutated payload accepted".into()),
                }
            },
        );
    }
}
