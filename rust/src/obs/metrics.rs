//! Lightweight in-process metrics: counters, gauges and f64 histograms
//! in a thread-local ambient registry, plus RAII spans built on
//! [`crate::util::timer::Stopwatch`].
//!
//! The hot paths (`opt/fleet`, `system/queue`, `fleet/events`) record
//! through the free functions ([`counter_add`], [`observe`],
//! [`gauge_set`]) without any signature changes, so instrumentation
//! cannot perturb the numerics the tests pin. The registry is
//! thread-local: parallel test threads and parallel fleet runs never
//! contend or cross-contaminate.
//!
//! Naming convention: dotted lowercase paths, grouped by subsystem —
//! `solver.*` (allocator counters), `queue.*` (edge-queue counters +
//! `queue.depth`/`queue.wait_s` histograms), `events.*` (replay
//! counters + per-slot `events.queue_depth` histogram) and `span.<name>.s`
//! (wall-clock span histograms, recorded when a [`Span`] guard drops).
//!
//! Snapshots export as schema-versioned JSON (`qaci.metrics` v1, see
//! [`Metrics::to_json`]); the CLI writes one via
//! `qaci fleet ... --metrics-out <path>` and the event replay embeds its
//! own capture in every [`crate::fleet::EventReport`].

use super::stats::Summary;
use crate::util::json::Json;
use crate::util::timer::{Samples, Stopwatch};
use std::cell::RefCell;
use std::collections::BTreeMap;

/// Schema identifier stamped on every exported snapshot.
pub const METRICS_SCHEMA: &str = "qaci.metrics";
/// Snapshot layout version this build writes.
pub const METRICS_VERSION: u32 = 1;

/// A metrics registry: monotone counters, last-write gauges and f64
/// histograms (summarized as the same p50/p95/p99 set the fleet reports
/// use). Usually accessed through the thread-local ambient registry via
/// the free functions; held directly when captured by [`scoped`].
#[derive(Debug, Clone, Default)]
pub struct Metrics {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Samples>,
}

impl Metrics {
    /// Fresh empty registry.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Add `by` to a counter (created at zero on first touch).
    pub fn counter_add(&mut self, name: &str, by: u64) {
        if let Some(c) = self.counters.get_mut(name) {
            *c += by;
        } else {
            self.counters.insert(name.to_string(), by);
        }
    }

    /// Set a gauge to its latest value.
    pub fn gauge_set(&mut self, name: &str, value: f64) {
        if let Some(g) = self.gauges.get_mut(name) {
            *g = value;
        } else {
            self.gauges.insert(name.to_string(), value);
        }
    }

    /// Record one sample into a histogram.
    pub fn observe(&mut self, name: &str, value: f64) {
        if let Some(h) = self.histograms.get_mut(name) {
            h.push(value);
        } else {
            let mut s = Samples::new();
            s.push(value);
            self.histograms.insert(name.to_string(), s);
        }
    }

    /// Current counter value (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Latest gauge value, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Histogram samples, if any were recorded.
    pub fn histogram(&self, name: &str) -> Option<&Samples> {
        self.histograms.get(name)
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.gauges.is_empty() && self.histograms.is_empty()
    }

    /// Fold `other` into `self`: counters add, gauges last-write-wins,
    /// histogram samples concatenate.
    pub fn merge(&mut self, other: &Metrics) {
        for (k, v) in &other.counters {
            self.counter_add(k, *v);
        }
        for (k, v) in &other.gauges {
            self.gauge_set(k, *v);
        }
        for (k, s) in &other.histograms {
            if let Some(h) = self.histograms.get_mut(k) {
                h.merge(s);
            } else {
                self.histograms.insert(k.clone(), s.clone());
            }
        }
    }

    /// Schema-versioned JSON snapshot (the `--metrics-out` payload):
    /// `{schema, version, counters, gauges, histograms}` with every
    /// histogram reduced to its [`Summary`].
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
        let histograms = Json::Obj(
            self.histograms.iter().map(|(k, s)| (k.clone(), Summary::of(s).to_json())).collect(),
        );
        Json::obj()
            .set("schema", METRICS_SCHEMA)
            .set("version", METRICS_VERSION as usize)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", histograms)
    }
}

thread_local! {
    static AMBIENT: RefCell<Metrics> = RefCell::new(Metrics::new());
}

/// Bump a counter in the ambient (thread-local) registry.
pub fn counter_add(name: &str, by: u64) {
    AMBIENT.with(|m| m.borrow_mut().counter_add(name, by));
}

/// Set a gauge in the ambient registry.
pub fn gauge_set(name: &str, value: f64) {
    AMBIENT.with(|m| m.borrow_mut().gauge_set(name, value));
}

/// Record a histogram sample in the ambient registry.
pub fn observe(name: &str, value: f64) {
    AMBIENT.with(|m| m.borrow_mut().observe(name, value));
}

/// Clone the ambient registry's current contents.
pub fn snapshot() -> Metrics {
    AMBIENT.with(|m| m.borrow().clone())
}

/// Take the ambient contents, leaving a fresh registry behind (the CLI
/// calls this at command start so a snapshot covers one run only).
pub fn reset() -> Metrics {
    AMBIENT.with(|m| m.replace(Metrics::new()))
}

/// Run `f` against a fresh ambient registry and return its result
/// together with everything it recorded. The capture is also folded back
/// into the surrounding registry, so outer snapshots (e.g. the CLI's
/// `--metrics-out`) still see the full run.
pub fn scoped<R>(f: impl FnOnce() -> R) -> (R, Metrics) {
    let outer = AMBIENT.with(|m| m.replace(Metrics::new()));
    let result = f();
    let captured = AMBIENT.with(|m| m.replace(outer));
    AMBIENT.with(|m| m.borrow_mut().merge(&captured));
    (result, captured)
}

/// RAII span: measures wall-clock from construction to drop and lands it
/// in the ambient histogram `span.<name>.s`.
pub struct Span {
    name: &'static str,
    watch: Stopwatch,
}

/// Open a span; the elapsed time records when the guard drops.
pub fn span(name: &'static str) -> Span {
    Span { name, watch: Stopwatch::start() }
}

impl Drop for Span {
    fn drop(&mut self) {
        observe(&format!("span.{}.s", self.name), self.watch.elapsed_s());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_gauges_histograms_accumulate() {
        let mut m = Metrics::new();
        m.counter_add("a.b", 2);
        m.counter_add("a.b", 3);
        m.gauge_set("g", 1.0);
        m.gauge_set("g", 4.0);
        m.observe("h", 1.0);
        m.observe("h", 3.0);
        assert_eq!(m.counter("a.b"), 5);
        assert_eq!(m.counter("never"), 0);
        assert_eq!(m.gauge("g"), Some(4.0));
        let h = m.histogram("h").unwrap();
        assert_eq!(h.len(), 2);
        assert_eq!(h.mean(), 2.0);
    }

    #[test]
    fn merge_adds_counters_and_concatenates_histograms() {
        let mut a = Metrics::new();
        a.counter_add("c", 1);
        a.observe("h", 1.0);
        a.gauge_set("g", 1.0);
        let mut b = Metrics::new();
        b.counter_add("c", 2);
        b.observe("h", 5.0);
        b.gauge_set("g", 9.0);
        a.merge(&b);
        assert_eq!(a.counter("c"), 3);
        assert_eq!(a.histogram("h").unwrap().len(), 2);
        assert_eq!(a.gauge("g"), Some(9.0), "gauge merge is last-write-wins");
    }

    #[test]
    fn scoped_captures_and_folds_back() {
        let before = reset(); // isolate from other tests on this thread
        counter_add("outer", 1);
        let ((), captured) = scoped(|| {
            counter_add("inner", 7);
            observe("inner.h", 2.0);
        });
        assert_eq!(captured.counter("inner"), 7);
        assert_eq!(captured.counter("outer"), 0, "capture excludes outer state");
        let ambient = snapshot();
        assert_eq!(ambient.counter("outer"), 1);
        assert_eq!(ambient.counter("inner"), 7, "capture folds back into ambient");
        assert_eq!(ambient.histogram("inner.h").unwrap().len(), 1);
        reset();
        AMBIENT.with(|m| *m.borrow_mut() = before);
    }

    #[test]
    fn span_records_elapsed_on_drop() {
        let ((), captured) = scoped(|| {
            let _guard = span("unit");
        });
        let h = captured.histogram("span.unit.s").expect("span histogram");
        assert_eq!(h.len(), 1);
        assert!(h.min() >= 0.0);
    }

    #[test]
    fn snapshot_json_is_schema_versioned() {
        let mut m = Metrics::new();
        m.counter_add("solver.warm_start.hit", 3);
        m.observe("queue.wait_s", 0.25);
        let j = m.to_json();
        assert_eq!(j.get("schema").and_then(Json::as_str), Some(METRICS_SCHEMA));
        assert_eq!(j.get("version").and_then(Json::as_usize), Some(1));
        assert_eq!(
            j.at(&["counters", "solver.warm_start.hit"]).and_then(Json::as_f64),
            Some(3.0)
        );
        assert_eq!(j.at(&["histograms", "queue.wait_s", "n"]).and_then(Json::as_usize), Some(1));
        // round-trips through the crate's own JSON
        let back = crate::util::json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back, j);
    }
}
