//! Observability layer: in-process metrics + spans and the persistent
//! bench-trajectory store.
//!
//! Three pieces:
//!
//! * [`stats`] — the crate's one percentile/summary implementation;
//!   [`crate::util::timer::Samples`] delegates here, so every rollup
//!   (coordinator telemetry, fleet sim, event replay, metrics
//!   histograms) shares one pinned interpolation convention;
//! * [`metrics`] — thread-local counters/gauges/histograms plus RAII
//!   spans, threaded through the allocator (`solver.*`), the shared
//!   edge queue (`queue.*`) and the event replay (`events.*`);
//!   exported as a schema-versioned `qaci.metrics` snapshot
//!   (`qaci fleet ... --metrics-out`), embedded per run in
//!   [`crate::fleet::EventReport`];
//! * [`benchlog`] — the append-only, content-hashed run index behind
//!   `qaci bench-log ingest|query|diff`: every `BENCH_*.json` artifact
//!   or metrics snapshot is stored with a schema version and an FNV-1a
//!   digest over its canonical JSON bytes, queryable across runs and
//!   diffable against a stored baseline (ordering-invariant checks for
//!   CI, value-regression checks for same-machine runs).

pub mod benchlog;
pub mod metrics;
pub mod stats;

pub use benchlog::{BenchLog, DiffOptions, Entry, Finding, Query};
pub use metrics::Metrics;
pub use stats::Summary;
