//! Shared summary statistics: the one percentile implementation every
//! rollup in the crate uses.
//!
//! `coordinator/telemetry`, `fleet/sim` and `fleet/events` all summarize
//! their sample collections through [`crate::util::timer::Samples`],
//! which delegates its percentile math here — so the interpolation
//! convention lives in exactly one place and is pinned by one unit test
//! on a known vector.

use crate::util::json::Json;
use crate::util::timer::Samples;

/// Linear-interpolated percentile over unsorted samples, `p` in [0, 100].
///
/// Convention (the one [`Samples`] has always used): rank = (p/100)·(n−1);
/// value = sorted[⌊rank⌋]·(1−frac) + sorted[⌈rank⌉]·frac. Empty input
/// yields NaN.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_of_sorted(&sorted, p)
}

/// The same percentile on already-sorted samples (callers that summarize
/// one collection at several `p` values sort once and reuse it).
pub fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let rank = (p / 100.0) * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// One collection's fixed summary — the n/mean/min/max + p50/p95/p99 set
/// the fleet reports and the metrics snapshot share.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    /// sample count
    pub n: usize,
    /// arithmetic mean (NaN when empty)
    pub mean: f64,
    /// smallest sample (+inf when empty)
    pub min: f64,
    /// largest sample (−inf when empty)
    pub max: f64,
    /// median
    pub p50: f64,
    /// 95th percentile
    pub p95: f64,
    /// 99th percentile
    pub p99: f64,
}

impl Summary {
    /// Summarize a sample collection.
    pub fn of(s: &Samples) -> Summary {
        Summary {
            n: s.len(),
            mean: s.mean(),
            min: s.min(),
            max: s.max(),
            p50: s.p50(),
            p95: s.p95(),
            p99: s.p99(),
        }
    }

    /// JSON form used by the metrics snapshot; non-finite values map to
    /// `null` so the empty histogram serializes cleanly.
    pub fn to_json(&self) -> Json {
        let num = |v: f64| if v.is_finite() { Json::Num(v) } else { Json::Null };
        Json::obj()
            .set("n", self.n)
            .set("mean", num(self.mean))
            .set("min", num(self.min))
            .set("max", num(self.max))
            .set("p50", num(self.p50))
            .set("p95", num(self.p95))
            .set("p99", num(self.p99))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn interpolation_convention_pinned_on_known_vector() {
        // rank = (p/100)·(n−1), linear interpolation between the two
        // bracketing order statistics — pinned here once for every
        // rollup in the crate (telemetry, fleet sim, event replay)
        let xs = [40.0, 10.0, 30.0, 20.0]; // unsorted on purpose
        for (p, want) in [
            (0.0, 10.0),   // rank 0.00
            (25.0, 17.5),  // rank 0.75
            (50.0, 25.0),  // rank 1.50
            (95.0, 38.5),  // rank 2.85
            (99.0, 39.7),  // rank 2.97
            (100.0, 40.0), // rank 3.00
        ] {
            let got = percentile(&xs, p);
            assert!((got - want).abs() < 1e-9, "p{p}: got {got}, want {want}");
        }
    }

    #[test]
    fn samples_delegate_to_the_shared_implementation() {
        let mut s = Samples::new();
        for x in [3.0, 1.0, 4.0, 1.0, 5.0, 9.0, 2.0, 6.0] {
            s.push(x);
        }
        for p in [0.0, 10.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
            assert_eq!(s.percentile(p), percentile(s.values(), p));
        }
    }

    #[test]
    fn empty_and_singleton_edges() {
        assert!(percentile(&[], 50.0).is_nan());
        assert_eq!(percentile(&[7.0], 0.0), 7.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn summary_json_shape() {
        let mut s = Samples::new();
        s.push(1.0);
        s.push(2.0);
        let j = Summary::of(&s).to_json();
        assert_eq!(j.get("n").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("mean").and_then(Json::as_f64), Some(1.5));
        assert_eq!(j.get("p50").and_then(Json::as_f64), Some(1.5));
        // empty histogram: every non-finite stat becomes null
        let e = Summary::of(&Samples::new()).to_json();
        assert_eq!(e.get("mean"), Some(&Json::Null));
        assert_eq!(e.get("p99"), Some(&Json::Null));
    }
}
