//! Exact reference solver for (P1).
//!
//! Both D^U(b̂-1) and the gap D^U - D^L are strictly decreasing in b̂
//! (verified by theory tests), and the feasible set shrinks monotonically
//! as b̂ grows (more agent cycles). Hence the optimum of (P1) is simply
//! **the largest feasible bit-width**, where per-b̂ feasibility is the
//! analytic 2-D convex frequency problem solved by
//! [`Problem::plan_frequencies`]. Bisection over the continuous relaxation
//! gives the fractional optimum b̃*; the returned integer design rounds
//! down to the largest feasible b̂ ∈ B.
//!
//! This solver exists to *validate* the paper's SCA Algorithm 1 (which
//! generalizes to objectives without this monotone structure): the
//! integration tests assert SCA matches it.

use super::problem::{Design, Problem};

#[derive(Debug, Clone, Copy)]
pub struct BisectionResult {
    pub design: Design,
    /// fractional optimum of the relaxation (P2)
    pub b_tilde_star: f64,
    pub objective: f64,
}

/// Solve (P1) exactly. Returns None when even b̂ = 1 is infeasible.
pub fn solve(problem: &Problem) -> Option<BisectionResult> {
    let b_max = problem.platform.b_max as f64;
    if problem.plan_frequencies(1.0).is_none() {
        return None;
    }
    let b_tilde_star = if problem.plan_frequencies(b_max).is_some() {
        b_max
    } else {
        // invariant: lo feasible, hi infeasible
        let (mut lo, mut hi) = (1.0, b_max);
        for _ in 0..60 {
            let mid = 0.5 * (lo + hi);
            if problem.plan_frequencies(mid).is_some() {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        lo
    };
    // round down to the largest feasible integer (rounding up would
    // violate a budget by construction)
    let mut b_hat = b_tilde_star.floor() as u32;
    while b_hat >= 1 {
        if let Some(d) = problem.plan_design(b_hat) {
            return Some(BisectionResult {
                design: d,
                b_tilde_star,
                objective: problem.objective(b_hat as f64),
            });
        }
        b_hat -= 1;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::Platform;
    use crate::util::prop::forall;

    #[test]
    fn matches_exhaustive_integer_search() {
        forall(
            "bisection == max feasible integer",
            120,
            |r| (r.range(0.3, 6.0), r.range(0.1, 8.0)),
            |&(t0, e0)| {
                let prob = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
                let exhaustive = (1..=prob.platform.b_max)
                    .rev()
                    .find(|&b| prob.plan_design(b).is_some());
                match (solve(&prob), exhaustive) {
                    (None, None) => Ok(()),
                    (Some(r), Some(b)) if r.design.b_hat == b => Ok(()),
                    (got, want) => Err(format!("{got:?} vs want b̂={want:?}")),
                }
            },
        );
    }

    #[test]
    fn solution_is_feasible_and_budget_tight_or_capped() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0);
        let r = solve(&prob).expect("feasible");
        assert!(prob.is_feasible(&r.design));
        // either we hit B_max or one of the budgets is nearly binding at b̂+1
        if r.design.b_hat < prob.platform.b_max {
            assert!(prob.plan_design(r.design.b_hat + 1).is_none());
        }
    }

    #[test]
    fn looser_budgets_never_reduce_bitwidth() {
        forall(
            "b̂*(T0,E0) monotone in budgets",
            80,
            |r| (r.range(0.3, 4.0), r.range(0.1, 4.0)),
            |&(t0, e0)| {
                let tight = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
                let loose = Problem::new(Platform::paper_blip2(), 15.0, t0 * 1.5, e0 * 1.5);
                match (solve(&tight), solve(&loose)) {
                    (Some(a), Some(b)) if b.design.b_hat >= a.design.b_hat => Ok(()),
                    (None, _) => Ok(()),
                    (a, b) => Err(format!("tight {a:?} loose {b:?}")),
                }
            },
        );
    }

    #[test]
    fn infeasible_instance_returns_none() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 1e-9, 1e-12);
        assert!(solve(&prob).is_none());
    }
}
