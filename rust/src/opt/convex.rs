//! Log-barrier interior-point solver for the small convex subproblems
//! (P4.k) — the CVX stand-in (DESIGN.md §2).
//!
//! Scope: smooth convex objective + inequality constraints g_i(x) <= 0 in
//! a handful of variables. Gradients are central finite differences (the
//! problems are 4-dimensional; analytic gradients buy nothing), descent is
//! gradient + Armijo backtracking, and the barrier weight follows the
//! standard outer path t <- mu * t.

pub type Func = Box<dyn Fn(&[f64]) -> f64>;

pub struct ConvexProgram {
    pub objective: Func,
    /// constraints g_i(x) <= 0
    pub constraints: Vec<Func>,
    /// per-variable scale used for finite-difference steps (roughly the
    /// magnitude of each variable; crucial when mixing bits ~1e0 with
    /// frequencies ~1e9)
    pub scales: Vec<f64>,
}

#[derive(Debug, Clone)]
pub struct Solution {
    pub x: Vec<f64>,
    pub objective: f64,
    pub iterations: usize,
}

impl ConvexProgram {
    fn barrier(&self, x: &[f64], t: f64) -> f64 {
        let mut v = (self.objective)(x) * t;
        for g in &self.constraints {
            let gi = g(x);
            if gi >= 0.0 {
                return f64::INFINITY;
            }
            v -= (-gi).ln();
        }
        v
    }

    fn grad_barrier(&self, x: &[f64], t: f64) -> Vec<f64> {
        let n = x.len();
        let mut g = vec![0.0; n];
        let mut xp = x.to_vec();
        for i in 0..n {
            let h = 1e-6 * self.scales[i].max(1e-12);
            xp[i] = x[i] + h;
            let fp = self.barrier(&xp, t);
            xp[i] = x[i] - h;
            let fm = self.barrier(&xp, t);
            xp[i] = x[i];
            g[i] = (fp - fm) / (2.0 * h);
        }
        g
    }

    /// Minimize from a strictly feasible start. `x0` must satisfy
    /// g_i(x0) < 0 for all i (checked).
    pub fn solve(&self, x0: &[f64]) -> anyhow::Result<Solution> {
        for (i, g) in self.constraints.iter().enumerate() {
            let gi = g(x0);
            anyhow::ensure!(
                gi < 0.0,
                "x0 not strictly feasible: constraint {i} = {gi}"
            );
        }
        let mut x = x0.to_vec();
        let mut t = 1.0;
        // perf (§Perf): mu 12 -> 25 and gap 1e-9 -> 1e-8 cut SCA cold
        // planning 64.9 -> 42.8 ms with the exact-solver agreement tests
        // still green. Cutting the inner iteration cap (400 -> 200) was
        // also tried: another -40%, but it broke knife-edge optimality
        // (b-hat 5 -> 3 at T0=2.0) -> reverted.
        let mu = 25.0;
        let m = self.constraints.len() as f64;
        let mut total_iters = 0;
        // outer barrier path: stop when the duality-gap proxy m/t is tiny
        while m / t > 1e-8 {
            // inner: projected gradient descent with backtracking
            for _ in 0..400 {
                total_iters += 1;
                let g = self.grad_barrier(&x, t);
                // scaled step direction
                let dir: Vec<f64> = g
                    .iter()
                    .zip(&self.scales)
                    .map(|(gi, s)| -gi * s * s)
                    .collect();
                let gnorm: f64 = g
                    .iter()
                    .zip(&self.scales)
                    .map(|(gi, s)| (gi * s).powi(2))
                    .sum::<f64>()
                    .sqrt();
                if gnorm < 1e-10 * t.max(1.0) {
                    break;
                }
                let f0 = self.barrier(&x, t);
                let mut alpha = 1.0;
                let slope: f64 = g.iter().zip(&dir).map(|(gi, di)| gi * di).sum();
                let mut advanced = false;
                for _ in 0..60 {
                    let xn: Vec<f64> = x.iter().zip(&dir).map(|(xi, di)| xi + alpha * di).collect();
                    let fn_ = self.barrier(&xn, t);
                    if fn_.is_finite() && fn_ <= f0 + 1e-4 * alpha * slope {
                        x = xn;
                        advanced = true;
                        break;
                    }
                    alpha *= 0.5;
                }
                if !advanced {
                    break; // at numerical resolution for this t
                }
            }
            t *= mu;
        }
        Ok(Solution { objective: (self.objective)(&x), x, iterations: total_iters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn boxed(f: impl Fn(&[f64]) -> f64 + 'static) -> Func {
        Box::new(f)
    }

    #[test]
    fn quadratic_with_box_constraints() {
        // min (x-3)^2 + (y+1)^2 s.t. 0<=x<=2, -0.5<=y<=2 -> opt (2, -0.5)
        let prog = ConvexProgram {
            objective: boxed(|x| (x[0] - 3.0).powi(2) + (x[1] + 1.0).powi(2)),
            constraints: vec![
                boxed(|x| -x[0]),
                boxed(|x| x[0] - 2.0),
                boxed(|x| -x[1] - 0.5),
                boxed(|x| x[1] - 2.0),
            ],
            scales: vec![1.0, 1.0],
        };
        let sol = prog.solve(&[1.0, 0.0]).unwrap();
        assert!((sol.x[0] - 2.0).abs() < 1e-4, "{:?}", sol.x);
        assert!((sol.x[1] + 0.5).abs() < 1e-4, "{:?}", sol.x);
    }

    #[test]
    fn linear_objective_on_simplex_like_region() {
        // min -x-y s.t. x+y<=1, x,y>=0 -> boundary x+y=1
        let prog = ConvexProgram {
            objective: boxed(|x| -x[0] - x[1]),
            constraints: vec![boxed(|x| x[0] + x[1] - 1.0), boxed(|x| -x[0]), boxed(|x| -x[1])],
            scales: vec![1.0, 1.0],
        };
        let sol = prog.solve(&[0.2, 0.2]).unwrap();
        assert!((sol.x[0] + sol.x[1] - 1.0).abs() < 1e-4, "{:?}", sol.x);
    }

    #[test]
    fn badly_scaled_variables() {
        // same geometry but y lives at 1e9 scale (like frequencies)
        let prog = ConvexProgram {
            objective: boxed(|x| (x[0] - 3.0).powi(2) + (x[1] / 1e9 - 1.0).powi(2)),
            constraints: vec![
                boxed(|x| -x[0]),
                boxed(|x| x[0] - 10.0),
                boxed(|x| -x[1]),
                boxed(|x| x[1] - 5e9),
            ],
            scales: vec![1.0, 1e9],
        };
        let sol = prog.solve(&[1.0, 2e9]).unwrap();
        assert!((sol.x[0] - 3.0).abs() < 1e-3, "{:?}", sol.x);
        assert!((sol.x[1] / 1e9 - 1.0).abs() < 1e-3, "{:?}", sol.x);
    }

    #[test]
    fn rejects_infeasible_start() {
        let prog = ConvexProgram {
            objective: boxed(|x| x[0]),
            constraints: vec![boxed(|x| x[0] - 1.0)],
            scales: vec![1.0],
        };
        assert!(prog.solve(&[2.0]).is_err());
    }
}
