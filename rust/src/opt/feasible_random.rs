//! Benchmark scheme 3 (paper §VI-C): feasible random design. Bit-widths
//! are sampled uniformly; each sample is kept only if the remaining
//! frequency variables can be optimized to feasibility. The paper runs 400
//! trials and reports over the feasible ones.

use super::problem::{Design, Problem};
use crate::util::rng::Rng;

pub const PAPER_TRIALS: usize = 400;

/// All feasible designs among `trials` uniformly sampled bit-widths
/// (frequencies chosen by the energy-min oracle, as "optimizing the
/// remaining computation frequency variables").
pub fn sample_feasible(problem: &Problem, trials: usize, seed: u64) -> Vec<Design> {
    let mut rng = Rng::new(seed);
    (0..trials)
        .filter_map(|_| {
            let b_hat = 1 + rng.below(problem.platform.b_max as usize) as u32;
            problem.plan_design(b_hat)
        })
        .collect()
}

/// One representative random-feasible design (first of a fresh sample).
pub fn solve(problem: &Problem, seed: u64) -> Option<Design> {
    sample_feasible(problem, PAPER_TRIALS, seed).first().copied()
}

/// Mean objective over the feasible trials — the quantity the paper's
/// figures report for this baseline.
pub fn mean_objective(problem: &Problem, trials: usize, seed: u64) -> Option<f64> {
    let designs = sample_feasible(problem, trials, seed);
    if designs.is_empty() {
        return None;
    }
    Some(
        designs
            .iter()
            .map(|d| problem.objective(d.b_hat as f64))
            .sum::<f64>()
            / designs.len() as f64,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::bisection;
    use crate::system::Platform;

    fn problem() -> Problem {
        Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0)
    }

    #[test]
    fn all_samples_are_feasible() {
        let prob = problem();
        for d in sample_feasible(&prob, 200, 1) {
            assert!(prob.is_feasible(&d), "{d:?}");
        }
    }

    #[test]
    fn mean_objective_never_beats_optimum() {
        let prob = problem();
        let opt = bisection::solve(&prob).unwrap().objective;
        let mean = mean_objective(&prob, PAPER_TRIALS, 2).unwrap();
        assert!(mean >= opt - 1e-12, "mean {mean} < opt {opt}");
    }

    #[test]
    fn infeasible_problem_yields_no_samples() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 1e-9, 1e-12);
        assert!(sample_feasible(&prob, 100, 3).is_empty());
        assert!(mean_objective(&prob, 100, 3).is_none());
    }

    #[test]
    fn deterministic_in_seed() {
        let prob = problem();
        assert_eq!(sample_feasible(&prob, 50, 7), sample_feasible(&prob, 50, 7));
    }
}
