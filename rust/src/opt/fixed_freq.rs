//! Benchmark scheme 2 (paper §VI-C): fixed-frequency design. Device and
//! server run at predetermined fixed frequencies; only the bit-width is
//! optimized to satisfy the QoS constraints.
//!
//! Note on the "predetermined" values: pinning *both* processors to their
//! maximum frequencies is degenerate under the paper's own energy
//! constants — the server at f̃^max alone costs η̃ψ̃C̃f̃² ≈ 58 J, ~29x the
//! largest E0 the paper sweeps — which would erase this baseline from
//! every figure. We therefore pin the device at its maximum (affordable:
//! ≈0.2 J) and the server at a power-calibrated operating point
//! (`SERVER_FRACTION` of max, chosen so the pinned server roughly fits
//! the paper's central 2 J budget), and document the substitution
//! (DESIGN.md §5). The literal max/max pin stays available for ablations.

use super::problem::{Design, Problem};

/// Server pin: 18% of f̃^max ⇒ pinned server energy ≈ 1.9 J on the paper
/// BLIP-2 platform (just inside the central E0 band).
pub const SERVER_FRACTION: f64 = 0.18;

/// Largest feasible bit-width with frequencies pinned at the given
/// fractions of max; None if none is.
pub fn solve_at_fractions(problem: &Problem, dev_frac: f64, srv_frac: f64) -> Option<Design> {
    let f = problem.platform.device.f_max * dev_frac;
    let f_tilde = problem.platform.server.f_max * srv_frac;
    (1..=problem.platform.b_max)
        .rev()
        .map(|b_hat| Design { b_hat, f, f_tilde })
        .find(|d| problem.is_feasible(d))
}

/// The baseline as run in the benches (device max, server calibrated).
pub fn solve(problem: &Problem) -> Option<Design> {
    solve_at_fractions(problem, 1.0, SERVER_FRACTION)
}

/// The literal max/max-pinned variant (ablation).
pub fn solve_at_max(problem: &Problem) -> Option<Design> {
    solve_at_fractions(problem, 1.0, 1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::bisection;
    use crate::system::Platform;
    use crate::util::prop::forall;

    #[test]
    fn never_beats_joint_design() {
        // running flat-out wastes energy: the joint design's bit-width is
        // always >= the fixed-frequency one
        forall(
            "fixed-freq b̂ <= joint b̂",
            100,
            |r| (r.range(0.5, 6.0), r.range(0.2, 6.0)),
            |&(t0, e0)| {
                let prob = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
                match (solve(&prob), bisection::solve(&prob)) {
                    (Some(ff), Some(joint)) if ff.b_hat <= joint.design.b_hat => Ok(()),
                    (None, _) => Ok(()),
                    (Some(_), None) => Err("fixed feasible but joint not?!".into()),
                    (a, b) => Err(format!("{a:?} vs {b:?}")),
                }
            },
        );
    }

    #[test]
    fn energy_tight_regime_hurts_fixed_freq() {
        // a budget where max-frequency energy is prohibitive but the joint
        // design thrives at lower frequency
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 5.0, 0.6);
        let ff = solve(&prob);
        let joint = bisection::solve(&prob).unwrap();
        match ff {
            None => {} // fixed freq completely infeasible: starkest case
            Some(d) => assert!(d.b_hat < joint.design.b_hat),
        }
    }

    #[test]
    fn design_runs_at_pinned_frequencies() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 4.0, 80.0);
        let d = solve_at_max(&prob).unwrap();
        assert_eq!(d.f, prob.platform.device.f_max);
        assert_eq!(d.f_tilde, prob.platform.server.f_max);
        let d = solve(&prob).unwrap();
        assert_eq!(d.f, prob.platform.device.f_max);
        assert_eq!(d.f_tilde, prob.platform.server.f_max * SERVER_FRACTION);
    }

    #[test]
    fn present_in_the_paper_budget_band() {
        // the whole point of the calibrated pin: the baseline must exist
        // at the paper's central (T0=3.5, E0=2.0) point
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0);
        let d = solve(&prob).expect("fixed-freq feasible at central budgets");
        assert!(d.b_hat >= 2);
    }

    #[test]
    fn max_pinned_is_energy_degenerate_under_paper_constants() {
        // the documented reason for the 60% default: f̃^max alone busts
        // every paper-band energy budget
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 4.0, 4.0);
        assert!(solve_at_max(&prob).is_none());
        assert!(solve(&prob).is_some());
    }
}
