//! Joint multi-agent resource allocation: N embodied agents contending
//! for one edge server and one wireless medium (fleet generalization of
//! the paper's single-pair (P1); cf. "The Larger the Merrier?" and "LLMs
//! over Networks" in PAPERS.md).
//!
//! ## Model
//!
//! Each agent i brings its own device (the paper's agent processor) and a
//! QoS contract (T0_i, E0_i, weight w_i, payload). Two resources are
//! shared:
//!
//! * **server frequency**: the edge server's f̃^max is partitioned into
//!   shares μ_i (Σ μ ≤ 1); agent i's decoder stage may run at
//!   f̃ ≤ μ_i f̃^max — exactly the paper's platform with a scaled server,
//!   so every per-agent subproblem *is* a [`Problem`] instance;
//! * **airtime**: the uplink medium's goodput R is split into shares α_i
//!   (Σ α ≤ 1, [`MultiAccessChannel`]); unlike the single-pair setting —
//!   where the paper excludes the (fast, dedicated) link from the QoS
//!   math — a congested shared medium is first-order, so the fleet
//!   allocator budgets the nominal uplink time against T0_i: the compute
//!   stages get T0_i − t_link(α_i).
//!
//! ## Objective and algorithm
//!
//! Minimize Σ_i w_i · ζ_i where ζ_i is the paper's (P1) objective
//! D^U(b̂_i−1) − D^L(b̂_i−1) for served agents and a rejection penalty
//! for agents the allocator cannot fit. Under the default
//! [`AdmissionPricing::Uniform`] the penalty is 2/λ_i — 4× the worst
//! feasible gap, so serving at b̂ = 1 always beats rejecting; under
//! [`AdmissionPricing::Tiered`] it is scaled by the agent's silicon
//! capability, making it *deliberately* cheaper to turn weak tiers away
//! (the phone-coverage-vs-orin-throughput operator trade). Since both
//! the gap and D^U alone are strictly decreasing in b̂, the same
//! allocation minimizes the fleet-weighted distortion upper bound
//! ([`FleetAllocation::weighted_d_upper`]) under uniform pricing.
//!
//! The proposed solver alternates **per-agent exact bisection**
//! ([`super::bisection`], the inner (P1) solve at fixed shares) with a
//! **water-filling-style outer exchange** on each shared resource: move a
//! share quantum from the agent whose objective suffers least to the
//! agent whose objective gains most, while any such move improves the
//! weighted sum. Two starting points are improved and the better result
//! kept: the equal split (which guarantees the proposed design never
//! loses to the equal-share baseline) and a greedy **admission** init
//! that seats agents by weight at their minimal feasible shares — the
//! path that serves part of the fleet when the equal split is entirely
//! infeasible.
//!
//! ## Heterogeneous silicon and channels
//!
//! Each [`AgentSpec`] carries its own [`DeviceProfile`] (Orin-, Xavier-
//! or phone-class silicon — per-device f^max, κ, power curve) and a
//! channel gain g_i scaling its slice of the shared medium's goodput
//! (α_i·g_i·R). Every per-agent subproblem is built on the agent's own
//! tier ([`FleetProblem::agent_platform`]), which is where the joint
//! design earns its keep over the equal split: a weak device needs a
//! fatter server slice (and more airtime) to meet the same QoS, and
//! only the exchange can move that mass. The benches assert the margin
//! over equal-share widens as the tier spread grows, and that the
//! uniform-Orin ladder reproduces the homogeneous fleet bit for bit.
//!
//! ## Queueing feedback and online re-allocation
//!
//! With [`FleetProblem::with_queue`], burst interference at the shared
//! edge server enters each agent's delay constraint: the compute stages
//! get T0_i − t_link(α_i) − W_i, where W_i is the analytic
//! [`QueueModel`] wait at agent i's slice-capacity service rate (an
//! effective-service-rate term: a bigger μ_i drains the queue faster).
//! The water-filling exchange probes W_i with a **mean-field** rival
//! estimate (uniform split — separable, so coordinate descent stays
//! exact); the allocation that comes out is then **scored** by a damped
//! fixed-point pass over the actual shares
//! ([`FleetProblem::interference_waits`]): rival service times at their
//! real slices, rejected agents' traffic dropped at admission, and a
//! clean fall-back to the mean-field estimate when no binary active-set
//! equilibrium exists. An overloaded queue makes W_i infinite and the
//! agent cleanly unservable at those shares. For churning fleets,
//! [`solve_proposed_warm`] re-runs the water-filling exchange online from
//! the previous allocation instead of from scratch — the entry point the
//! event-driven loop in [`crate::fleet::churn`] drives.
//!
//! ## Multi-server fleets: placement × allocation
//!
//! [`FleetSpec::servers`] generalizes the single edge box to S servers.
//! A [`ServerSpec`] carries a per-server frequency budget (a scale of
//! the base server's f̃^max), an optional explicit slice of the shared
//! medium's airtime, and an optional per-server queue discipline. The
//! joint problem becomes an agent→server [`Placement`] (outer loop)
//! plus the existing exact per-server share allocation (inner loop):
//! each server's sub-fleet is solved as its own single-server problem
//! on the frequency-scaled base and its airtime slice of the medium
//! (shares reported back in fleet-global coordinates), and the fleet
//! objective is the sum over servers. [`PlacementStrategy::LocalSearch`]
//! alternates best-improving single-agent moves (each counted as
//! `placement.moves`) with inner re-solves of the affected servers;
//! [`PlacementStrategy::EqualSpread`] and
//! [`PlacementStrategy::NearestServer`] are the baselines. A fleet
//! whose `servers` is the single default server takes the legacy path
//! and reproduces the pre-placement solver bit for bit (pinned by the
//! S = 1 identity property test below).
//!
//! ## Equivalence classes: the million-agent path
//!
//! Real fleets are population-structured: N = 10⁴–10⁶ agents drawn from
//! a handful of (tier × QoS-class × channel-gain) combinations. The
//! per-agent solver prices every bisection and every exchange probe per
//! *agent*; [`Classing::Exact`] (a [`SolveRequest`] field) collapses
//! content-identical agents into equivalence classes and evaluates one
//! representative subproblem per class, memoized by (class, μ-bits,
//! α-bits). The classed path runs the *same* algorithm over the same
//! per-agent share vector — identical floats are simply computed once —
//! so it is **bit-identical** to [`Classing::PerAgent`] whenever class
//! members really are identical, and trivially so when every class is a
//! singleton (property-tested below on duplicated and all-singleton
//! fleets). Two refinements keep exactness under queue feedback: the
//! damped fixed-point pass computes one wait per (class, weight) — row
//! `i` of [`QueueModel::waits_given`] depends on the observer only
//! through its priority weight — and the *mean-field* probe, whose
//! accumulation order depends on the observer's index, falls back to
//! per-agent memoization when a queue is attached. Per-class admission
//! floors (two bisections per class) run in parallel through
//! [`crate::util::pool::ThreadPool::map`]. [`Classing::Bucketed`]
//! additionally rounds channel gains when forming classes — a
//! deliberately **approximate** mode for continuous gain distributions,
//! where every member is priced at its class representative's gain.
//! `benches/fleet_scale.rs` publishes the solve-time-vs-N ladder
//! (`solve-scale-*` records in `BENCH_fleet_scale.json`: per-agent and
//! classed wall-clock, class counts, and bit-equality of the two costs)
//! and CI gates the classed path at ≥ 10× the per-agent solver at
//! N = 10⁴ on the tier-mix scenario.
//!
//! ## One solver entry point
//!
//! [`FleetProblem::solve`] with a [`SolveRequest`] (algorithm, options,
//! placement strategy, optional warm start, seed) is the solve path;
//! the historical free functions ([`solve`], [`solve_equal_share`],
//! [`solve_proposed`], [`solve_proposed_with`], [`solve_proposed_warm`],
//! [`solve_feasible_random`]) survive as thin wrappers that build the
//! equivalent request, kept only for source compatibility — new code
//! should construct a [`FleetSpec`], validate it once through
//! [`FleetProblem::from_spec`], and call [`FleetProblem::solve`].
//! Malformed *runtime* inputs (a placement that does not cover the
//! fleet, names an unknown server, or mismatched warm-start/dirty/reuse
//! lengths) surface as structured [`FleetError`]s through the
//! [`FleetProblem::try_solve`] family; the infallible entry points are
//! thin wrappers that panic with the same diagnostics.

use super::bisection;
use super::feasible_random;
use super::problem::{Design, Problem};
use crate::obs::metrics as obs_metrics;
use crate::system::channel::MultiAccessChannel;
use crate::system::platform::DeviceProfile;
use crate::quant::mixed::QuantPolicy;
use crate::system::queue::{QueueDiscipline, QueueModel};
use crate::system::Platform;
use crate::theory::rate_distortion as rd;
use crate::util::cli::ParseError;
use crate::util::pool::{self, ThreadPool};
use crate::util::rng::Rng;
use std::cell::RefCell;
use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::fmt;
use std::hash::{Hash, Hasher};
use std::ops::{Deref, DerefMut};
use std::sync::Arc;

/// One agent's QoS contract in the fleet, plus the silicon it runs on.
#[derive(Debug, Clone, Copy)]
pub struct AgentSpec {
    /// QoS class label (matches the coordinator's class names)
    pub class: &'static str,
    /// fitted exponential parameter of this agent's model magnitudes
    pub lambda: f64,
    /// delay budget T0_i [s]
    pub t0: f64,
    /// energy budget E0_i [J]
    pub e0: f64,
    /// fleet weight w_i (relative importance in the objective)
    pub weight: f64,
    /// uplink payload per request [bytes]
    pub payload_bytes: usize,
    /// this agent's silicon tier: its [`DeviceProfile::spec`] replaces
    /// the base platform's device in every per-agent subproblem
    pub device: DeviceProfile,
    /// uplink channel gain g_i ∈ (0, 1]: effective goodput is α_i·g_i·R
    pub channel_gain: f64,
    /// per-agent quantization policy: `QuantPolicy::Static(None)` (the
    /// default) keeps the solver's static bisection pick bit for bit;
    /// pinned/mixed/adaptive policies re-route
    /// [`FleetProblem::agent_design_at_wait`] and the objective
    pub quant: QuantPolicy,
}

impl AgentSpec {
    /// BLIP-2-2.7b-scale embedding upload: 32 query tokens × d = 2560 f32.
    pub const PAYLOAD_BLIP2: usize = 32 * 2560 * 4;

    /// The canonical QoS bands (fleet SLA bands in the paper's Fig. 5
    /// budget range, interactive slightly tightened) with weights
    /// expressing their relative priority.
    const CLASSES: [(&'static str, f64, f64, f64); 3] = [
        ("interactive", 2.40, 2.50, 2.0),
        ("standard", 3.50, 2.00, 1.0),
        ("background", 5.00, 1.00, 0.5),
    ];

    /// The spec a (joining) agent with ordinal `idx` gets: classes cycle
    /// — also how churn assigns contracts to newcomers, so a joined
    /// agent is indistinguishable from one seeded at t = 0. Silicon is
    /// the uniform Orin tier at nominal channel gain (the homogeneous
    /// pre-tier fleet, reproduced bit for bit).
    pub fn class_spec(idx: usize) -> AgentSpec {
        let (class, t0, e0, weight) = Self::CLASSES[idx % Self::CLASSES.len()];
        AgentSpec {
            class,
            lambda: 15.0,
            t0,
            e0,
            weight,
            payload_bytes: Self::PAYLOAD_BLIP2,
            device: DeviceProfile::orin(),
            channel_gain: 1.0,
            quant: QuantPolicy::Static(None),
        }
    }

    /// [`Self::class_spec`] on a heterogeneous silicon ladder: agents
    /// cycle through the QoS classes as always, and every full class
    /// cycle (3 agents) steps to the next tier in `tiers` — so each
    /// tier hosts a complete interactive/standard/background block and
    /// churn newcomers (keyed by ordinal) land on a reproducible tier.
    /// The tier's nominal radio sets the agent's channel gain.
    pub fn tiered_spec(idx: usize, tiers: &[DeviceProfile]) -> AgentSpec {
        assert!(!tiers.is_empty());
        let profile = tiers[(idx / Self::CLASSES.len()) % tiers.len()];
        AgentSpec { device: profile, channel_gain: profile.link_gain, ..Self::class_spec(idx) }
    }

    /// Heterogeneous fleet used by benches and the CLI: cycles the
    /// coordinator's three QoS classes on uniform Orin silicon.
    pub fn mixed_fleet(n: usize) -> Vec<AgentSpec> {
        (0..n).map(Self::class_spec).collect()
    }

    /// A fleet cycling both QoS classes and silicon tiers
    /// ([`Self::tiered_spec`]). `tiered_fleet(n, &[DeviceProfile::orin()])`
    /// is exactly [`Self::mixed_fleet`].
    pub fn tiered_fleet(n: usize, tiers: &[DeviceProfile]) -> Vec<AgentSpec> {
        (0..n).map(|i| Self::tiered_spec(i, tiers)).collect()
    }

    /// The canonical tier ladder by spread level: 0 = uniform Orin,
    /// 1 = Orin + Xavier, 2 = Orin + Xavier + phone. The fleet benches
    /// sweep this to show the proposed allocator's margin over the
    /// equal split widening with silicon disparity.
    pub fn tier_mix(spread: usize) -> Vec<DeviceProfile> {
        let ladder = [DeviceProfile::orin(), DeviceProfile::xavier(), DeviceProfile::phone()];
        ladder[..=spread.min(2)].to_vec()
    }

    /// The platform this agent sees at server-frequency share μ: its own
    /// silicon tier in front of the share-scaled shared server of `base`
    /// (the fleet-wide substitution [`FleetProblem::agent_platform`]
    /// delegates to; the event-level serving loop prices stage times with
    /// it directly, without a [`FleetProblem`] in hand).
    pub fn platform_at(&self, base: Platform, mu: f64) -> Platform {
        let mut p = base;
        p.device = self.device.spec;
        p.server.f_max *= mu.clamp(0.0, 1.0);
        p
    }

    /// Nominal (jitter-free) uplink time at airtime share α on a medium
    /// with the given total rate and base latency, through this agent's
    /// channel gain. A non-finite α is treated as "no airtime" so a
    /// poisoned share degrades to a clean +inf instead of NaN.
    pub fn link_time_at(&self, rate_bps: f64, base_latency_s: f64, alpha: f64) -> f64 {
        let share = if alpha.is_finite() { alpha.clamp(0.0, 1.0) } else { 0.0 };
        MultiAccessChannel::nominal_transmit_s(
            rate_bps * self.channel_gain,
            base_latency_s,
            share,
            self.payload_bytes,
        )
    }
}

/// How admission control prices turning an agent away.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum AdmissionPricing {
    /// The silicon-blind penalty w_i · 2/λ_i (4× the worst feasible
    /// bound gap, so serving any agent at b̂ = 1 always beats rejecting
    /// it) — the pre-tier behavior, bit for bit.
    #[default]
    Uniform,
    /// The uniform penalty scaled by the agent's
    /// [`DeviceProfile::capability`] (Orin 1.0, Xavier 0.35, phone
    /// 0.125): rejecting a weak device forfeits proportionally less
    /// fleet capability. Deliberately breaks the always-serve guarantee
    /// for weak tiers — a phone-class agent whose feasible bit-width is
    /// low (gap above 0.25/λ, i.e. b̂ ≤ 2) is now *better* rejected, and
    /// its shares flow to the Orin/Xavier blocks. That is the operator
    /// trade: phone coverage vs. orin throughput, visible directly in
    /// the event-level tail traces.
    Tiered,
    /// The uniform penalty scaled by **measured** violation pressure
    /// ([`FleetSpec::pressure`], per agent in [0, 1]): an agent whose
    /// observed tail keeps violating its deadline becomes progressively
    /// cheaper to turn away (down to the phone-class capability floor at
    /// pressure 1), so the re-solve sheds the agents the telemetry says
    /// it cannot serve instead of the agents a static capability ladder
    /// guesses at. With an empty/zero pressure vector this is
    /// [`AdmissionPricing::Uniform`] bit for bit — the closed-loop
    /// serving daemon ([`crate::fleet::daemon`]) is what feeds real
    /// pressure in, epoch by epoch.
    Measured,
}

/// Penalty multiplier at full measured pressure — the same floor as the
/// phone-class [`DeviceProfile::capability`], so a maximally-violating
/// agent is never priced below the weakest silicon tier.
pub(crate) const MEASURED_PRESSURE_FLOOR: f64 = 0.125;

impl AdmissionPricing {
    pub fn name(self) -> &'static str {
        match self {
            AdmissionPricing::Uniform => "uniform",
            AdmissionPricing::Tiered => "tiered",
            AdmissionPricing::Measured => "measured",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<AdmissionPricing, ParseError> {
        match s {
            "uniform" => Ok(AdmissionPricing::Uniform),
            "tiered" | "tier" | "capability" => Ok(AdmissionPricing::Tiered),
            "measured" | "p99" => Ok(AdmissionPricing::Measured),
            _ => Err(ParseError::new("admission pricing", s, &["uniform", "tiered", "measured"])),
        }
    }
}

/// One edge server in a multi-server fleet: its frequency budget as a
/// scale of the base server, an optional explicit slice of the shared
/// medium's airtime, and an optional per-server queue discipline. The
/// `Default` server (scale 1, no explicit airtime, no override) is the
/// legacy single-box fleet — a [`FleetSpec`] whose `servers` is exactly
/// `vec![ServerSpec::default()]` solves through the pre-placement code
/// path bit for bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ServerSpec {
    /// this server's f̃^max as a fraction of `base.server.f_max`,
    /// in (0, 1] (the base box is the strongest deployable unit)
    pub freq_scale: f64,
    /// explicit airtime fraction of the shared medium reserved for this
    /// server's agents, in (0, 1]; `None` = split the leftover medium
    /// across unspecified servers proportionally to their head-count
    pub airtime_fraction: Option<f64>,
    /// per-server queue discipline override (`None` = the fleet-wide
    /// [`FleetSpec::queue`] discipline)
    pub queue: Option<QueueDiscipline>,
}

impl Default for ServerSpec {
    fn default() -> Self {
        ServerSpec { freq_scale: 1.0, airtime_fraction: None, queue: None }
    }
}

impl ServerSpec {
    /// A server at a fraction of the base box's frequency budget.
    pub fn scaled(freq_scale: f64) -> ServerSpec {
        ServerSpec { freq_scale, ..ServerSpec::default() }
    }

    /// `s` identical full-budget servers (at least one).
    pub fn identical(s: usize) -> Vec<ServerSpec> {
        vec![ServerSpec::default(); s.max(1)]
    }
}

/// Fleet instance as one plain config struct: shared silicon + servers +
/// shared medium + per-agent contracts, optionally with the edge queue's
/// analytic feedback. Construct it literally (or via [`FleetSpec::new`]
/// for the defaults), then validate once through
/// [`FleetProblem::from_spec`] — this replaces the old
/// `FleetProblem::new(..).with_link(..).with_queue(..).with_pricing(..)`
/// mutation chain, and gives churn's fleet fingerprint a single struct
/// to hash ([`FleetSpec`] implements [`Hash`] over every field, floats
/// by bit pattern).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// shared-infrastructure profile: `base.server` is the reference
    /// edge box every [`ServerSpec::freq_scale`] is relative to (and
    /// `base` carries the workload constants); each agent's processor
    /// comes from its own [`AgentSpec::device`] tier, substituted per
    /// subproblem by [`FleetProblem::agent_platform`]
    pub base: Platform,
    pub agents: Vec<AgentSpec>,
    /// the edge servers agents are placed across;
    /// `vec![ServerSpec::default()]` is the legacy single-server fleet
    pub servers: Vec<ServerSpec>,
    /// shared uplink goodput R [bits/s]
    pub link_rate_bps: f64,
    /// per-message MAC latency [s]
    pub link_base_latency_s: f64,
    /// shared edge-queue model; `None` = PR 1's fluid sharing (no
    /// queueing term in the delay constraint)
    pub queue: Option<QueueModel>,
    /// how rejections are priced ([`AdmissionPricing::Uniform`] keeps the
    /// silicon-blind 2/λ behavior bit for bit)
    pub pricing: AdmissionPricing,
    /// measured per-agent violation pressure in [0, 1] (one entry per
    /// agent, or empty = no telemetry). Only
    /// [`AdmissionPricing::Measured`] reads it; the serving daemon
    /// quantizes observed violation rates into this vector so that a
    /// pressure change re-fingerprints the fleet like any other spec
    /// change. Empty is bit-identical to all-zeros.
    pub pressure: Vec<f64>,
}

impl FleetSpec {
    /// Shared testbed WLAN defaults: one full-budget server, 400 Mbps /
    /// 2 ms medium, no queue feedback, uniform admission pricing.
    pub fn new(base: Platform, agents: Vec<AgentSpec>) -> FleetSpec {
        FleetSpec {
            base,
            agents,
            servers: vec![ServerSpec::default()],
            link_rate_bps: 400e6,
            link_base_latency_s: 2e-3,
            queue: None,
            pricing: AdmissionPricing::default(),
            pressure: Vec::new(),
        }
    }

    /// The one validation gate ([`FleetProblem::from_spec`] and every
    /// legacy builder funnel through it). Panics on a malformed spec —
    /// construction-time failure, never NaN-poisoned allocations later.
    fn validate(&self) {
        assert!(!self.agents.is_empty());
        assert!(
            self.agents.iter().all(|a| a.channel_gain > 0.0 && a.channel_gain <= 1.0),
            "channel gains must lie in (0, 1]"
        );
        // mirrors EdgeQueue::push's NaN-priority guard: a NaN weight
        // would silently mis-order the admission seating and poison the
        // weight-proportional leftover split
        assert!(
            self.agents.iter().all(|a| a.weight.is_finite()),
            "agent weights must be finite"
        );
        for (i, a) in self.agents.iter().enumerate() {
            if let Err(e) = a.quant.validate(self.base.b_max) {
                panic!("agent {i}: invalid quant policy: {e}");
            }
        }
        assert!(!self.servers.is_empty(), "at least one server");
        let mut airtime_reserved = 0.0;
        for s in &self.servers {
            assert!(
                s.freq_scale.is_finite() && s.freq_scale > 0.0 && s.freq_scale <= 1.0,
                "server freq_scale must lie in (0, 1]: {}",
                s.freq_scale
            );
            if let Some(f) = s.airtime_fraction {
                assert!(
                    f.is_finite() && f > 0.0 && f <= 1.0,
                    "server airtime_fraction must lie in (0, 1]: {f}"
                );
                airtime_reserved += f;
            }
        }
        assert!(
            airtime_reserved <= 1.0 + 1e-9,
            "explicit server airtime fractions overcommit the medium: {airtime_reserved}"
        );
        if let Some(q) = &self.queue {
            assert_eq!(q.arrival_rps.len(), self.agents.len(), "one rate per agent");
        }
        if !self.pressure.is_empty() {
            assert_eq!(self.pressure.len(), self.agents.len(), "one pressure per agent");
            assert!(
                self.pressure.iter().all(|p| p.is_finite() && (0.0..=1.0).contains(p)),
                "violation pressure must lie in [0, 1]"
            );
        }
    }
}

fn hash_f64<H: Hasher>(x: f64, state: &mut H) {
    state.write_u64(x.to_bits());
}

/// Content hash over the whole spec (floats by bit pattern) — the
/// churn/event replays fingerprint a fleet by hashing this one struct to
/// gate warm re-solves.
impl Hash for FleetSpec {
    fn hash<H: Hasher>(&self, state: &mut H) {
        for x in [
            self.base.device.f_max,
            self.base.device.flops_per_cycle,
            self.base.device.pue,
            self.base.device.psi,
            self.base.server.f_max,
            self.base.server.flops_per_cycle,
            self.base.server.pue,
            self.base.server.psi,
        ] {
            hash_f64(x, state);
        }
        hash_f64(self.base.n_flop_agent, state);
        hash_f64(self.base.n_flop_server, state);
        hash_f64(self.base.full_bits, state);
        self.base.b_max.hash(state);
        self.agents.len().hash(state);
        for a in &self.agents {
            a.class.hash(state);
            hash_f64(a.lambda, state);
            hash_f64(a.t0, state);
            hash_f64(a.e0, state);
            hash_f64(a.weight, state);
            a.payload_bytes.hash(state);
            a.device.tier.hash(state);
            hash_f64(a.device.spec.f_max, state);
            hash_f64(a.device.spec.flops_per_cycle, state);
            hash_f64(a.device.spec.pue, state);
            hash_f64(a.device.spec.psi, state);
            hash_f64(a.device.link_gain, state);
            hash_f64(a.channel_gain, state);
            a.quant.hash_content(state);
        }
        self.servers.len().hash(state);
        for s in &self.servers {
            hash_f64(s.freq_scale, state);
            s.airtime_fraction.is_some().hash(state);
            hash_f64(s.airtime_fraction.unwrap_or(0.0), state);
            s.queue.hash(state);
        }
        hash_f64(self.link_rate_bps, state);
        hash_f64(self.link_base_latency_s, state);
        match &self.queue {
            None => false.hash(state),
            Some(q) => {
                true.hash(state);
                q.discipline.hash(state);
                for &r in &q.arrival_rps {
                    hash_f64(r, state);
                }
            }
        }
        self.pricing.hash(state);
        self.pressure.len().hash(state);
        for &p in &self.pressure {
            hash_f64(p, state);
        }
    }
}

/// A validated fleet instance — a [`FleetSpec`] that passed
/// [`FleetProblem::from_spec`]. Derefs to the spec, so `fp.agents`,
/// `fp.queue`, `fp.link_rate_bps`, ... read straight through.
#[derive(Debug, Clone)]
pub struct FleetProblem {
    /// the validated spec (mutating it directly bypasses validation,
    /// matching the old public-field behavior)
    pub spec: FleetSpec,
}

impl Deref for FleetProblem {
    type Target = FleetSpec;
    fn deref(&self) -> &FleetSpec {
        &self.spec
    }
}

impl DerefMut for FleetProblem {
    fn deref_mut(&mut self) -> &mut FleetSpec {
        &mut self.spec
    }
}

impl FleetProblem {
    /// The one construction path: validate the spec once, then solve
    /// against it. Panics on a malformed spec (empty fleet, channel
    /// gains outside (0, 1], degenerate servers, overcommitted explicit
    /// airtime, queue-rate/agent mismatch).
    pub fn from_spec(spec: FleetSpec) -> FleetProblem {
        spec.validate();
        FleetProblem { spec }
    }

    /// [`FleetSpec::new`] + [`Self::from_spec`]: the defaults
    /// (single full-budget server, testbed WLAN, no queue feedback).
    pub fn new(base: Platform, agents: Vec<AgentSpec>) -> FleetProblem {
        Self::from_spec(FleetSpec::new(base, agents))
    }

    /// Deprecated builder (source compatibility): prefer setting
    /// [`FleetSpec::link_rate_bps`] / [`FleetSpec::link_base_latency_s`]
    /// and calling [`Self::from_spec`].
    pub fn with_link(mut self, rate_bps: f64, base_latency_s: f64) -> FleetProblem {
        self.spec.link_rate_bps = rate_bps;
        self.spec.link_base_latency_s = base_latency_s;
        self.spec.validate();
        self
    }

    /// Deprecated builder (source compatibility): prefer setting
    /// [`FleetSpec::queue`] and calling [`Self::from_spec`]. Enables the
    /// shared edge queue: its expected wait is carved out of every
    /// agent's delay budget (effective-service-rate feedback).
    pub fn with_queue(mut self, queue: QueueModel) -> FleetProblem {
        self.spec.queue = Some(queue);
        self.spec.validate();
        self
    }

    /// Deprecated builder (source compatibility): prefer setting
    /// [`FleetSpec::pricing`] and calling [`Self::from_spec`].
    pub fn with_pricing(mut self, pricing: AdmissionPricing) -> FleetProblem {
        self.spec.pricing = pricing;
        self.spec.validate();
        self
    }

    /// Deprecated builder (source compatibility): prefer setting
    /// [`FleetSpec::servers`] and calling [`Self::from_spec`].
    pub fn with_servers(mut self, servers: Vec<ServerSpec>) -> FleetProblem {
        self.spec.servers = servers;
        self.spec.validate();
        self
    }

    /// Builder for the measured-telemetry pressure vector (see
    /// [`FleetSpec::pressure`]); pairs with
    /// [`AdmissionPricing::Measured`].
    pub fn with_pressure(mut self, pressure: Vec<f64>) -> FleetProblem {
        self.spec.pressure = pressure;
        self.spec.validate();
        self
    }

    /// Infinite-rate medium: isolates the shared-server dimension (and
    /// makes the N = 1 fleet reduce *exactly* to the single-agent (P1)).
    pub fn ideal_link(self) -> FleetProblem {
        self.with_link(f64::INFINITY, 0.0)
    }

    pub fn n(&self) -> usize {
        self.agents.len()
    }

    /// The platform agent i sees under server-frequency share μ: its own
    /// silicon tier ([`AgentSpec::device`]) in front of the share-scaled
    /// shared server. The uniform Orin tier reproduces the base device
    /// exactly (same constants), so homogeneous fleets are unchanged.
    pub fn agent_platform(&self, i: usize, mu: f64) -> Platform {
        self.agents[i].platform_at(self.base, mu)
    }

    /// Nominal (jitter-free) uplink time at airtime share α — what the
    /// allocator budgets against; the agent's channel gain scales its
    /// effective goodput (α·g_i·R). A non-finite α is treated as "no
    /// airtime" so a poisoned share vector degrades to a clean +inf
    /// (→ rejection) instead of propagating NaN into costs.
    pub fn link_time(&self, i: usize, alpha: f64) -> f64 {
        self.agents[i].link_time_at(self.link_rate_bps, self.link_base_latency_s, alpha)
    }

    /// Slice-capacity drain time of one server-stage job at share μ
    /// (infinite for a degenerate share — the agent can never drain).
    pub fn own_service(&self, mu: f64) -> f64 {
        if !(mu > 0.0) || !mu.is_finite() {
            return f64::INFINITY;
        }
        self.base.server_cycles() / (self.base.server.f_max * mu.clamp(0.0, 1.0))
    }

    /// Mean-field expected shared-queue wait for agent i at server share
    /// μ (0 when no queue model is attached): the agent drains at its
    /// slice capacity μ f̃^max, rivals are estimated at the uniform
    /// split. This is the **separable probe** the water-filling exchange
    /// evaluates (cost must depend on the owner's share alone); the
    /// final allocation is scored by the sharper fixed-point pass
    /// ([`Self::interference_waits`]) over the actual share vector.
    pub fn queue_wait(&self, i: usize, mu: f64) -> f64 {
        let Some(queue) = &self.queue else { return 0.0 };
        if !(mu > 0.0) || !mu.is_finite() {
            return f64::INFINITY;
        }
        let c2 = self.base.server_cycles();
        let own = c2 / (self.base.server.f_max * mu.clamp(0.0, 1.0));
        let reference = c2 * self.n() as f64 / self.base.server.f_max;
        queue.expected_wait_s(i, own, reference, |j| self.agents[j].weight)
    }

    /// Per-agent waits for explicit service/activity vectors (0 when no
    /// queue is attached) — the churn replay scores frozen allocations
    /// with this so static and online policies face the same
    /// actual-share interference model.
    pub fn queue_waits_given(&self, services: &[f64], activity: &[f64]) -> Vec<f64> {
        match &self.queue {
            None => vec![0.0; self.n()],
            Some(q) => q.waits_given(services, activity, |j| self.agents[j].weight),
        }
    }

    /// The delay budget left for the compute stages at shares (μ, α)
    /// under the mean-field queue estimate.
    pub fn effective_t0(&self, i: usize, mu: f64, alpha: f64) -> f64 {
        self.agents[i].t0 - self.link_time(i, alpha) - self.queue_wait(i, mu)
    }

    /// Agent i's effective single-agent (P1) instance under shares
    /// (μ, α) with an explicitly supplied queue wait: the paper's
    /// problem on the agent's tier silicon and share-scaled server, with
    /// the uplink time and the wait carved out of the delay budget.
    /// `None` when nothing is left — including every degenerate input
    /// (share ~0, infinite wait, non-finite shares), so callers always
    /// see a clean rejection, never inf/NaN.
    pub fn agent_problem_at_wait(
        &self,
        i: usize,
        mu: f64,
        alpha: f64,
        wait: f64,
    ) -> Option<Problem> {
        if !(mu > 0.0) || !mu.is_finite() || !alpha.is_finite() {
            return None;
        }
        let t0 = self.agents[i].t0 - self.link_time(i, alpha) - wait;
        if !(t0 > 0.0) {
            return None; // also catches the +inf link/queue times
        }
        Some(Problem::new(self.agent_platform(i, mu), self.agents[i].lambda, t0, self.agents[i].e0))
    }

    /// [`Self::agent_problem_at_wait`] at the mean-field queue wait —
    /// the separable form the exchange and admission probes use.
    pub fn agent_problem(&self, i: usize, mu: f64, alpha: f64) -> Option<Problem> {
        self.agent_problem_at_wait(i, mu, alpha, self.queue_wait(i, mu))
    }

    /// Agent i's design at an already-built (P1) instance, routed
    /// through its [`QuantPolicy`]:
    ///
    /// - `Static(None)` — the legacy exact-bisection pick, bit for bit.
    /// - `Static(Some(b))` / `Mixed` — the delay/energy plan at the
    ///   pinned (average) bit-width; infeasible ⇒ rejection.
    /// - `Adaptive` — the bisection pick clamped into
    ///   `[min_bits, effective_max(pressure)]`; a pick below `min_bits`
    ///   clamps up and (being above the max feasible width) rejects.
    ///   The default window (1, 16, no backoff) reproduces the solver
    ///   pick exactly.
    fn design_for_policy(&self, i: usize, problem: &Problem) -> Option<Design> {
        match self.agents[i].quant {
            QuantPolicy::Static(None) => bisection::solve(problem).map(|r| r.design),
            QuantPolicy::Static(Some(b)) => problem.plan_design(b),
            QuantPolicy::Mixed(alloc) => problem.plan_design(alloc.pinned_bits()),
            QuantPolicy::Adaptive(cfg) => {
                let picked = bisection::solve(problem)?.design;
                let pressure = self.spec.pressure.get(i).copied().unwrap_or(0.0);
                let b = picked.b_hat.clamp(cfg.min_bits, cfg.effective_max(pressure));
                if b == picked.b_hat {
                    Some(picked)
                } else {
                    problem.plan_design(b)
                }
            }
        }
    }

    /// Best per-agent design under shares and an explicit wait, or
    /// `None` when the agent is unservable there (policy-routed via
    /// [`Self::design_for_policy`]).
    pub fn agent_design_at_wait(&self, i: usize, mu: f64, alpha: f64, wait: f64) -> Option<Design> {
        let problem = self.agent_problem_at_wait(i, mu, alpha, wait)?;
        self.design_for_policy(i, &problem)
    }

    /// Best per-agent design under the mean-field queue estimate.
    pub fn agent_design(&self, i: usize, mu: f64, alpha: f64) -> Option<Design> {
        let problem = self.agent_problem(i, mu, alpha)?;
        self.design_for_policy(i, &problem)
    }

    /// Rejection penalty. Uniform pricing: 4× the worst feasible bound
    /// gap, so serving an agent (at any bit-width) always improves the
    /// objective. Tiered pricing scales that by the agent's silicon
    /// capability (see [`AdmissionPricing::Tiered`] for the deliberate
    /// consequences); measured pricing interpolates from the uniform
    /// penalty down to the same capability floor as the observed
    /// violation pressure rises — zero pressure is Uniform bit for bit.
    pub fn rejection_cost(&self, i: usize) -> f64 {
        // a mixed allocation misses group-decomposed mass Σ w_g/λ_g
        // instead of the single-λ mean 1/λ; the 2× margin (serving at
        // any width beats rejection) is preserved either way
        let miss = match self.agents[i].quant {
            QuantPolicy::Mixed(alloc) => alloc.miss_distortion(),
            _ => 1.0 / self.agents[i].lambda,
        };
        let base = self.agents[i].weight * 2.0 * miss;
        match self.pricing {
            AdmissionPricing::Uniform => base,
            AdmissionPricing::Tiered => base * self.agents[i].device.capability(),
            AdmissionPricing::Measured => {
                let p = self.spec.pressure.get(i).copied().unwrap_or(0.0);
                base * (1.0 - (1.0 - MEASURED_PRESSURE_FLOOR) * p)
            }
        }
    }

    /// The single source of truth for the fleet objective: an agent's
    /// weighted contribution given whatever design it was (not) assigned.
    /// Always finite — a degenerate design scores as a rejection so the
    /// water-filling exchange can never be poisoned by inf/NaN costs.
    pub fn design_cost(&self, i: usize, design: &Option<Design>) -> f64 {
        let cost = match design {
            Some(d) => match self.agents[i].quant {
                // group-decomposed (P1) objective: the allocation's own
                // per-group bit vector prices the distortion, the design
                // only certifies delay/energy feasibility at the pinned
                // average width
                QuantPolicy::Mixed(alloc) => {
                    self.agents[i].weight * alloc.bound_gap_total()
                }
                _ => {
                    self.agents[i].weight
                        * rd::bound_gap(d.b_hat as f64, self.agents[i].lambda)
                }
            },
            None => self.rejection_cost(i),
        };
        if cost.is_finite() {
            cost
        } else {
            self.rejection_cost(i)
        }
    }

    /// Weighted per-agent objective contribution at shares (μ, α) under
    /// the mean-field queue estimate — the exchange's probe cost, a
    /// function of the owner's shares alone (separability keeps the
    /// water-filling exact coordinate descent).
    pub fn agent_cost(&self, i: usize, mu: f64, alpha: f64) -> f64 {
        self.design_cost(i, &self.agent_design(i, mu, alpha))
    }

    /// Can agent i be served at all at these shares and this queue
    /// wait? Probed at the policy's minimum servable width
    /// ([`QuantPolicy::probe_bits`]): b̂ = 1 for the legacy default
    /// (bit-identical), the pinned width for pinning policies — an
    /// agent whose pinned width is infeasible cannot be served at all,
    /// so admission floors must not seat it.
    fn servable_at_wait(&self, i: usize, mu: f64, alpha: f64, wait: f64) -> bool {
        let probe = self.agents[i].quant.probe_bits();
        self.agent_problem_at_wait(i, mu, alpha, wait)
            .is_some_and(|p| p.plan_frequencies(probe).is_some())
    }

    /// Damped fixed-point interference pass over the **actual** share
    /// vector — the refinement that replaces the mean-field rival
    /// estimate when an allocation is scored ([`evaluate`]).
    ///
    /// Each agent's service time is its slice-capacity drain time at its
    /// actual μ_i; an agent that cannot be served at the resulting waits
    /// is rejected at admission, so its traffic drops out of every
    /// rival's load. Servability depends on the waits and the waits on
    /// who is served — a fixed point on the active set, iterated with
    /// damped activity levels a_i ∈ [0, 1] (θ = ½) until they settle,
    /// then validated: the thresholded active set must reproduce itself
    /// under the exact servability map. When no such equilibrium exists
    /// (marginal agents flip-flop — e.g. a symmetric overload where
    /// everyone is unservable together and servable alone), the pass
    /// **falls back to the mean-field estimate** unchanged, so callers
    /// never act on an unconverged guess.
    ///
    /// Returned waits are the converged actual-share waits (rejected
    /// agents keep the wait that rejected them) or the mean-field vector
    /// on fallback; `converged` distinguishes the two.
    pub fn interference_waits(&self, mu: &[f64], alpha: &[f64]) -> Interference {
        interference_waits_with(self, &CostOracle::direct(self), mu, alpha)
    }
}

/// [`FleetProblem::interference_waits`] parameterized by the cost
/// oracle: the direct oracle reproduces the historical pass bit for bit;
/// the classed oracle computes one wait per (class, weight) row — row
/// `i` of [`QueueModel::waits_given`] depends on the observer only
/// through its priority weight and its own-service finiteness guard, so
/// the broadcast is exact even when class members hold different shares.
fn interference_waits_with(
    fp: &FleetProblem,
    oracle: &CostOracle<'_>,
    mu: &[f64],
    alpha: &[f64],
) -> Interference {
    let n = fp.n();
    assert_eq!(mu.len(), n);
    assert_eq!(alpha.len(), n);
    if fp.queue.is_none() {
        return Interference { waits: vec![0.0; n], converged: true, active: vec![true; n] };
    }
    let services: Vec<f64> = mu.iter().map(|&m| fp.own_service(m)).collect();
    let want_at = |waits: &[f64]| -> Vec<f64> {
        (0..n)
            .map(|i| {
                let ok = services[i].is_finite()
                    && oracle.servable_at_wait(i, mu[i], alpha[i], waits[i]);
                if ok { 1.0 } else { 0.0 }
            })
            .collect()
    };
    let mut act: Vec<f64> =
        services.iter().map(|s| if s.is_finite() { 1.0 } else { 0.0 }).collect();
    for _ in 0..48 {
        let waits = oracle.waits_given(&services, &act);
        let want = want_at(&waits);
        let mut delta = 0.0f64;
        for (a, w) in act.iter_mut().zip(&want) {
            let next = 0.5 * *a + 0.5 * w;
            delta = delta.max((next - *a).abs());
            *a = next;
        }
        if delta < 1e-9 {
            break;
        }
    }
    let fixed: Vec<f64> = act.iter().map(|&a| if a >= 0.5 { 1.0 } else { 0.0 }).collect();
    let waits = oracle.waits_given(&services, &fixed);
    if want_at(&waits) == fixed {
        obs_metrics::counter_add("solver.fixed_point.converged", 1);
        let active = fixed.iter().map(|&a| a >= 0.5).collect();
        return Interference { waits, converged: true, active };
    }
    // no binary equilibrium: clean mean-field fallback
    obs_metrics::counter_add("solver.fixed_point.fallback", 1);
    let waits = (0..n).map(|i| oracle.queue_wait(i, mu[i])).collect();
    Interference { waits, converged: false, active: vec![true; n] }
}

/// Result of [`FleetProblem::interference_waits`].
#[derive(Debug, Clone)]
pub struct Interference {
    /// per-agent expected shared-queue wait [s]
    pub waits: Vec<f64>,
    /// `true` = the active-set fixed point settled; `false` = mean-field
    /// fallback (waits are exactly the [`FleetProblem::queue_wait`] vector)
    pub converged: bool,
    /// who the converged pass considers admitted-and-loading the queue
    /// (all `true` on fallback: mean-field counts everyone)
    pub active: Vec<bool>,
}

/// One agent's slice of a fleet allocation.
#[derive(Debug, Clone, Copy)]
pub struct AgentAllocation {
    /// `None` = rejected by admission control
    pub design: Option<Design>,
    /// server-frequency share μ_i
    pub server_share: f64,
    /// airtime share α_i
    pub airtime_share: f64,
    /// nominal uplink time at α_i [s]
    pub link_s: f64,
    /// the analytic shared-queue wait this agent was scored at [s]
    /// (fixed-point when converged, mean-field on fallback, 0 without a
    /// queue) — the budget the serving loop carves out of T0
    pub queue_wait_s: f64,
    /// w_i-weighted objective contribution (penalty when rejected)
    pub cost: f64,
}

/// A complete fleet operating point.
#[derive(Debug, Clone)]
pub struct FleetAllocation {
    pub agents: Vec<AgentAllocation>,
    /// Σ_i cost_i — the fleet-weighted (P1) objective
    pub objective: f64,
    pub admitted: usize,
    /// the agent→server map this allocation was solved at
    /// ([`Placement::single`] on the legacy single-server path)
    pub placement: Placement,
}

impl FleetAllocation {
    /// Fleet-weighted distortion upper bound Σ w_i D^U(b̂_i−1); rejected
    /// agents contribute the zero-rate distortion D^U(0) = 1/λ. Agents
    /// on a [`QuantPolicy::Mixed`] allocation contribute the
    /// group-decomposed bound Σ_g w_g D^U(b_g−1, λ_g) when served and
    /// its zero-rate mass Σ_g w_g/λ_g when rejected.
    pub fn weighted_d_upper(&self, fp: &FleetProblem) -> f64 {
        self.agents
            .iter()
            .zip(&fp.agents)
            .map(|(a, spec)| {
                if let QuantPolicy::Mixed(alloc) = spec.quant {
                    let du = match &a.design {
                        Some(_) => alloc.d_upper_total(),
                        None => alloc.miss_distortion(),
                    };
                    return spec.weight * du;
                }
                let rate = match &a.design {
                    Some(d) => d.b_hat as f64 - 1.0,
                    None => 0.0,
                };
                spec.weight * rd::d_upper(rate, spec.lambda)
            })
            .sum()
    }

    pub fn server_shares(&self) -> Vec<f64> {
        self.agents.iter().map(|a| a.server_share).collect()
    }

    pub fn airtime_shares(&self) -> Vec<f64> {
        self.agents.iter().map(|a| a.airtime_share).collect()
    }
}

/// Assemble an allocation from per-agent designs produced by `design_of`
/// — shared by the bisection-based [`evaluate`] and the random baseline,
/// so every algorithm scores against the same objective. `waits[i]` is
/// the analytic queue wait each design was scored at.
fn assemble(
    fp: &FleetProblem,
    mu: &[f64],
    alpha: &[f64],
    waits: &[f64],
    mut design_of: impl FnMut(usize) -> Option<Design>,
) -> FleetAllocation {
    assert_eq!(mu.len(), fp.n());
    assert_eq!(alpha.len(), fp.n());
    let agents: Vec<AgentAllocation> = (0..fp.n())
        .map(|i| {
            let design = design_of(i);
            AgentAllocation {
                cost: fp.design_cost(i, &design),
                design,
                server_share: mu[i],
                airtime_share: alpha[i],
                link_s: fp.link_time(i, alpha[i]),
                queue_wait_s: waits[i],
            }
        })
        .collect();
    FleetAllocation {
        objective: agents.iter().map(|a| a.cost).sum(),
        admitted: agents.iter().filter(|a| a.design.is_some()).count(),
        agents,
        placement: Placement::single(fp.n()),
    }
}

/// Evaluate a share assignment: fixed-point interference waits over the
/// actual shares (mean-field fallback), then per-agent exact bisection +
/// costs at those waits. Without a queue the waits are zero and this is
/// the plain (P1)-per-agent scoring, bit for bit.
pub fn evaluate(fp: &FleetProblem, mu: &[f64], alpha: &[f64]) -> FleetAllocation {
    evaluate_with(fp, &CostOracle::direct(fp), mu, alpha)
}

/// [`evaluate`] parameterized by the cost oracle. The per-agent design
/// probe `agent_design_at_wait` depends only on the agent's *content*
/// (spec, device, gain) and the probe arguments, never on its position
/// in the fleet, so the classed oracle may answer it from the class
/// representative; `design_cost` is still priced per member.
fn evaluate_with(
    fp: &FleetProblem,
    oracle: &CostOracle<'_>,
    mu: &[f64],
    alpha: &[f64],
) -> FleetAllocation {
    let interference = interference_waits_with(fp, oracle, mu, alpha);
    let waits = interference.waits;
    let alloc =
        assemble(fp, mu, alpha, &waits, |i| oracle.design_at_wait(i, mu[i], alpha[i], waits[i]));
    obs_metrics::counter_add("solver.admission.rejected", (fp.n() - alloc.admitted) as u64);
    alloc
}

/// Predicted-gain probe for re-solve hysteresis: the fleet objective of
/// **frozen** shares re-scored under a (possibly changed) problem —
/// without running the exchange. Agents with no previous slot (`None`)
/// get zero shares, i.e. they are priced as rejections. The serving
/// daemon compares this against the counterfactual warm re-solve's
/// objective to decide whether a fingerprint change is worth *applying*
/// at all; the probe itself costs one [`evaluate`] (fixed-point waits +
/// per-agent bisection), not a full exchange.
pub fn probe_frozen(fp: &FleetProblem, shares: &[Option<(f64, f64)>]) -> f64 {
    assert_eq!(shares.len(), fp.n(), "one previous share pair per agent");
    let mu: Vec<f64> = shares.iter().map(|s| s.map_or(0.0, |(m, _)| m)).collect();
    let alpha: Vec<f64> = shares.iter().map(|s| s.map_or(0.0, |(_, a)| a)).collect();
    obs_metrics::counter_add("solver.probe.frozen", 1);
    evaluate(fp, &mu, &alpha).objective
}

/// Which fleet allocator drives a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum FleetAlgorithm {
    /// alternating per-agent bisection + water-filling share exchange
    #[default]
    Proposed,
    /// μ_i = α_i = 1/N, per-agent bisection (the natural baseline)
    EqualShare,
    /// random shares + random feasible per-agent bit-widths
    FeasibleRandom,
}

impl FleetAlgorithm {
    pub const ALL: [FleetAlgorithm; 3] = [
        FleetAlgorithm::Proposed,
        FleetAlgorithm::EqualShare,
        FleetAlgorithm::FeasibleRandom,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FleetAlgorithm::Proposed => "proposed",
            FleetAlgorithm::EqualShare => "equal-share",
            FleetAlgorithm::FeasibleRandom => "feasible-random",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<FleetAlgorithm, ParseError> {
        match s {
            "proposed" | "waterfill" => Ok(FleetAlgorithm::Proposed),
            "equal" | "equal-share" => Ok(FleetAlgorithm::EqualShare),
            "random" | "feasible-random" => Ok(FleetAlgorithm::FeasibleRandom),
            _ => Err(ParseError::new("fleet algorithm", s, &["proposed", "equal", "random"])),
        }
    }
}

/// Outer-loop knobs for [`solve_proposed_with`].
#[derive(Debug, Clone, Copy)]
pub struct ProposedOptions {
    /// alternating (server, airtime) improvement rounds
    pub rounds: usize,
    /// share quantum = 1 / (divisor · N), coarse-to-fine
    pub step_divisors: [f64; 2],
    /// exchange moves allowed per agent per quantum level
    pub moves_per_agent: usize,
}

impl Default for ProposedOptions {
    fn default() -> Self {
        ProposedOptions { rounds: 3, step_divisors: [2.0, 8.0], moves_per_agent: 3 }
    }
}

/// Structured solve-time failure: malformed runtime inputs (placements,
/// warm starts, reuse vectors) surface as errors through the
/// [`FleetProblem::try_solve`] family instead of panicking mid-solve —
/// the serving loops can refuse a bad request and keep serving. Spec
/// malformation is still a construction-time panic
/// ([`FleetProblem::from_spec`]): a validated spec never NaN-poisons an
/// allocation later, but a *placement* arrives per solve call and may
/// come from a remote controller.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetError {
    /// `placement.assignment.len()` != fleet size
    PlacementLength { expected: usize, got: usize },
    /// `assignment[agent]` names a server outside the spec's list
    UnknownServer { agent: usize, server: usize, servers: usize },
    /// per-server stitching left agents without a slot (unreachable
    /// through a validated placement; kept structured so callers see a
    /// diagnosis, never a mid-solve panic)
    UncoveredAgents { missing: usize },
    /// `warm_start.len()` != fleet size
    WarmStartLength { expected: usize, got: usize },
    /// `dirty.len()` != server count
    DirtyLength { expected: usize, got: usize },
    /// `reuse.len()` != fleet size
    ReuseLength { expected: usize, got: usize },
}

impl fmt::Display for FleetError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            FleetError::PlacementLength { expected, got } => {
                write!(f, "one server per agent: placement has {got} slots for {expected} agents")
            }
            FleetError::UnknownServer { agent, server, servers } => {
                write!(
                    f,
                    "placement names an unknown server: agent {agent} on server {server} of {servers}"
                )
            }
            FleetError::UncoveredAgents { missing } => {
                write!(f, "placement covers every agent: {missing} agents left without a slot")
            }
            FleetError::WarmStartLength { expected, got } => {
                write!(f, "one warm-start slot per agent: got {got}, fleet has {expected}")
            }
            FleetError::DirtyLength { expected, got } => {
                write!(f, "one dirty flag per server: got {got}, spec has {expected}")
            }
            FleetError::ReuseLength { expected, got } => {
                write!(f, "one reuse slot per agent: got {got}, fleet has {expected}")
            }
        }
    }
}

impl std::error::Error for FleetError {}

/// Agent→server map for a multi-server fleet: `assignment[i]` is the
/// index into [`FleetSpec::servers`] agent i's decoder stage runs on.
#[derive(Debug, Clone, PartialEq, Eq, Hash, Default)]
pub struct Placement {
    pub assignment: Vec<usize>,
}

impl Placement {
    /// Everyone on server 0 — the legacy single-server fleet.
    pub fn single(n: usize) -> Placement {
        Placement { assignment: vec![0; n] }
    }

    /// Round-robin across the `s` servers (the equal-spread baseline).
    pub fn equal_spread(n: usize, s: usize) -> Placement {
        Placement { assignment: (0..n).map(|i| i % s.max(1)).collect() }
    }

    /// Everyone on one named server.
    pub fn all_on(n: usize, server: usize) -> Placement {
        Placement { assignment: vec![server; n] }
    }

    /// The agents placed on `server`, in agent order.
    pub fn members(&self, server: usize) -> Vec<usize> {
        self.assignment
            .iter()
            .enumerate()
            .filter(|&(_, &k)| k == server)
            .map(|(i, _)| i)
            .collect()
    }

    /// Coverage validation against a fleet of `agents` agents on
    /// `servers` servers: exactly one slot per agent, every named server
    /// known. The [`FleetProblem::try_solve`] family runs this before
    /// touching any solver state, so a partial or dangling placement is
    /// a clean [`FleetError`], never a mid-solve panic.
    pub fn validate(&self, agents: usize, servers: usize) -> Result<(), FleetError> {
        if self.assignment.len() != agents {
            return Err(FleetError::PlacementLength { expected: agents, got: self.assignment.len() });
        }
        for (agent, &server) in self.assignment.iter().enumerate() {
            if server >= servers {
                return Err(FleetError::UnknownServer { agent, server, servers });
            }
        }
        Ok(())
    }
}

/// Outer-loop placement strategy for multi-server fleets (ignored at
/// S = 1, where the placement is trivially [`Placement::single`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum PlacementStrategy {
    /// start from the better of equal-spread and
    /// all-on-the-strongest-server, then accept best-improving
    /// single-agent moves (each counted as `placement.moves`) until no
    /// move improves the fleet objective
    #[default]
    LocalSearch,
    /// round-robin agents across servers (the natural baseline)
    EqualSpread,
    /// every agent on the strongest server (largest frequency budget) —
    /// the "walk to the big box" baseline
    NearestServer,
}

impl PlacementStrategy {
    pub const ALL: [PlacementStrategy; 3] = [
        PlacementStrategy::LocalSearch,
        PlacementStrategy::EqualSpread,
        PlacementStrategy::NearestServer,
    ];

    pub fn name(self) -> &'static str {
        match self {
            PlacementStrategy::LocalSearch => "local-search",
            PlacementStrategy::EqualSpread => "equal-spread",
            PlacementStrategy::NearestServer => "nearest-server",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<PlacementStrategy, ParseError> {
        match s {
            "local-search" | "local" => Ok(PlacementStrategy::LocalSearch),
            "equal-spread" | "spread" => Ok(PlacementStrategy::EqualSpread),
            "nearest-server" | "nearest" => Ok(PlacementStrategy::NearestServer),
            _ => Err(ParseError::new(
                "placement strategy",
                s,
                &["local-search", "equal-spread", "nearest-server"],
            )),
        }
    }
}

/// How the solver treats content-identical agents (the
/// tier × QoS-class × gain equivalence structure of large fleets) — see
/// the "Equivalence classes" section of the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum Classing {
    /// every agent is its own subproblem — the legacy path, bit for bit
    #[default]
    PerAgent,
    /// collapse bit-identical agents into equivalence classes and
    /// memoize one representative evaluation per (class, μ, α) point.
    /// **Exact**: the algorithm and its float trajectory are unchanged —
    /// identical values are computed once instead of N times — so the
    /// allocation is bit-identical to [`Classing::PerAgent`]
    /// (property-tested on duplicated and all-singleton fleets)
    Exact,
    /// like [`Classing::Exact`], but channel gains are rounded to
    /// `gain_decimals` decimal digits when forming classes and every
    /// member is priced at its class representative's gain.
    /// **Approximate** — for fleets with continuous gain distributions
    /// where exact classes would all be singletons; reported shares are
    /// still per-agent and the share simplex is still respected
    Bucketed {
        /// decimal digits of channel gain kept when keying classes
        gain_decimals: u32,
    },
}

impl Classing {
    pub fn name(self) -> &'static str {
        match self {
            Classing::PerAgent => "per-agent",
            Classing::Exact => "exact",
            Classing::Bucketed { .. } => "bucketed",
        }
    }

    /// CLI-facing parser; `bucketed` keys gains at 3 decimal digits.
    pub fn parse(s: &str) -> Result<Classing, ParseError> {
        match s {
            "per-agent" | "agent" => Ok(Classing::PerAgent),
            "exact" | "classed" => Ok(Classing::Exact),
            "bucketed" => Ok(Classing::Bucketed { gain_decimals: 3 }),
            _ => Err(ParseError::new("classing mode", s, &["per-agent", "exact", "bucketed"])),
        }
    }
}

/// The unified solver request: everything [`FleetProblem::solve`] needs
/// to produce a [`FleetAllocation`]. `Default` is the proposed algorithm
/// with default options, local-search placement, no warm start, seed 0,
/// per-agent classing — exactly the historical `solve_proposed`.
#[derive(Debug, Clone, Default)]
pub struct SolveRequest {
    pub algorithm: FleetAlgorithm,
    /// outer-loop knobs for the proposed algorithm (ignored by baselines)
    pub options: ProposedOptions,
    /// agent→server placement strategy (S > 1 fleets only)
    pub placement: PlacementStrategy,
    /// previous shares to warm-start the proposed exchange from:
    /// `Some(prev)` with `prev[i] = Some((μ, α))` for surviving agents
    /// and `None` slots for newcomers (see [`solve_proposed_warm`])
    pub warm_start: Option<Vec<Option<(f64, f64)>>>,
    /// RNG seed (feasible-random baseline only)
    pub seed: u64,
    /// equivalence-class collapsing for large structured fleets
    /// ([`Classing::PerAgent`] = the legacy per-agent path, bit for bit)
    pub classing: Classing,
}

impl FleetProblem {
    /// The one solver entry point: dispatch on `req.algorithm` (and
    /// `req.warm_start`), through the placement layer when the spec has
    /// real multi-server structure. A fleet whose `servers` is the
    /// single default server takes the legacy single-server path bit for
    /// bit — the historical `solve_*` free functions are all thin
    /// wrappers over this method.
    pub fn solve(&self, req: &SolveRequest) -> FleetAllocation {
        self.try_solve(req).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Self::solve`] with structured failure: malformed runtime inputs
    /// (warm-start length, placement coverage) come back as
    /// [`FleetError`]s instead of panics, so a serving loop can refuse a
    /// bad request and keep its current allocation.
    pub fn try_solve(&self, req: &SolveRequest) -> Result<FleetAllocation, FleetError> {
        if let Some(w) = &req.warm_start {
            if w.len() != self.n() {
                return Err(FleetError::WarmStartLength { expected: self.n(), got: w.len() });
            }
        }
        if self.servers.len() == 1 && self.servers[0] == ServerSpec::default() {
            return Ok(solve_single(self, req));
        }
        let placement = self.place(req);
        self.try_solve_with_placement(&placement, req)
    }

    /// Pick an agent→server [`Placement`] per `req.placement` (the outer
    /// loop of the joint placement × allocation problem).
    pub fn place(&self, req: &SolveRequest) -> Placement {
        let (n, s) = (self.n(), self.servers.len());
        match req.placement {
            PlacementStrategy::EqualSpread => Placement::equal_spread(n, s),
            PlacementStrategy::NearestServer => Placement::all_on(n, strongest_server(self)),
            PlacementStrategy::LocalSearch => local_search_placement(self, req),
        }
    }

    /// Solve at a **fixed** placement: each populated server's sub-fleet
    /// is solved as its own single-server problem (frequency-scaled
    /// base, its airtime slice of the medium) and the results are
    /// reported in fleet-global coordinates. The churn replay pins
    /// sticky placements with this.
    pub fn solve_with_placement(
        &self,
        placement: &Placement,
        req: &SolveRequest,
    ) -> FleetAllocation {
        self.try_solve_with_placement(placement, req).unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Self::solve_with_placement`] with structured failure: a partial
    /// placement or one naming an unknown server is a [`FleetError`],
    /// never a mid-solve panic.
    pub fn try_solve_with_placement(
        &self,
        placement: &Placement,
        req: &SolveRequest,
    ) -> Result<FleetAllocation, FleetError> {
        placement.validate(self.n(), self.servers.len())?;
        let mut cache = SubCache::new();
        placed_allocation(self, placement, req, &mut cache)
    }

    /// Content fingerprint of one server's sub-problem under a placement
    /// (member count + the sub-[`FleetSpec`] they would be solved
    /// against, floats by bit pattern) — the per-server gate churn uses
    /// to skip re-solving servers a fleet change did not touch. It is
    /// deliberately free of fleet-global agent *indices* (only content):
    /// a join or leave elsewhere shifts everyone's index but must not
    /// dirty a server whose own sub-problem is unchanged.
    pub fn server_fingerprint(&self, placement: &Placement, server: usize) -> u64 {
        let members = placement.members(server);
        let mut h = DefaultHasher::new();
        members.len().hash(&mut h);
        if !members.is_empty() {
            let phi = airtime_fractions(self, placement);
            sub_problem(self, &members, self.servers[server], phi[server]).spec.hash(&mut h);
        }
        h.finish()
    }

    /// [`solve_with_placement`](Self::solve_with_placement), but re-solve
    /// only the servers marked `dirty`; every member of a clean server
    /// takes its slot from `reuse` (fleet-global coordinates, by agent
    /// index). A clean server with any missing slot is re-solved
    /// defensively. Counts `placement.server.resolved` /
    /// `placement.server.reused` — the churn replay drives this with its
    /// per-server [`server_fingerprint`](Self::server_fingerprint) gate.
    pub fn solve_with_placement_reusing(
        &self,
        placement: &Placement,
        req: &SolveRequest,
        dirty: &[bool],
        reuse: &[Option<AgentAllocation>],
    ) -> FleetAllocation {
        self.try_solve_with_placement_reusing(placement, req, dirty, reuse)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// [`Self::solve_with_placement_reusing`] with structured failure —
    /// every runtime-input malformation (placement coverage, dirty/reuse
    /// lengths) is a [`FleetError`] instead of a panic.
    pub fn try_solve_with_placement_reusing(
        &self,
        placement: &Placement,
        req: &SolveRequest,
        dirty: &[bool],
        reuse: &[Option<AgentAllocation>],
    ) -> Result<FleetAllocation, FleetError> {
        placement.validate(self.n(), self.servers.len())?;
        if dirty.len() != self.servers.len() {
            return Err(FleetError::DirtyLength { expected: self.servers.len(), got: dirty.len() });
        }
        if reuse.len() != self.n() {
            return Err(FleetError::ReuseLength { expected: self.n(), got: reuse.len() });
        }
        let phi = airtime_fractions(self, placement);
        let mut cache = SubCache::new();
        let mut slots: Vec<Option<AgentAllocation>> = vec![None; self.n()];
        for k in 0..self.servers.len() {
            let members = placement.members(k);
            if members.is_empty() {
                continue;
            }
            if !dirty[k] && members.iter().all(|&i| reuse[i].is_some()) {
                obs_metrics::counter_add("placement.server.reused", 1);
                for &i in &members {
                    slots[i] = reuse[i];
                }
            } else {
                obs_metrics::counter_add("placement.server.resolved", 1);
                let sub = sub_allocation(self, k, &members, phi[k], req, &mut cache);
                for (&i, a) in members.iter().zip(&sub) {
                    slots[i] = Some(*a);
                }
            }
        }
        stitch(slots, placement)
    }
}

/// Collect per-agent slots into one fleet allocation; any uncovered slot
/// is the structured [`FleetError::UncoveredAgents`] (unreachable through
/// a validated placement, but never a panic).
fn stitch(
    slots: Vec<Option<AgentAllocation>>,
    placement: &Placement,
) -> Result<FleetAllocation, FleetError> {
    let mut agents = Vec::with_capacity(slots.len());
    let mut missing = 0usize;
    for slot in slots {
        match slot {
            Some(a) => agents.push(a),
            None => missing += 1,
        }
    }
    if missing > 0 {
        return Err(FleetError::UncoveredAgents { missing });
    }
    Ok(FleetAllocation {
        objective: agents.iter().map(|a| a.cost).sum(),
        admitted: agents.iter().filter(|a| a.design.is_some()).count(),
        agents,
        placement: placement.clone(),
    })
}

/// Dispatch on algorithm (legacy free function). `seed` only matters for
/// the random baseline. Deprecated wrapper: build a [`SolveRequest`] and
/// call [`FleetProblem::solve`] instead.
pub fn solve(fp: &FleetProblem, algorithm: FleetAlgorithm, seed: u64) -> FleetAllocation {
    fp.solve(&SolveRequest { algorithm, seed, ..SolveRequest::default() })
}

/// The equal-share baseline. Deprecated wrapper over
/// [`FleetProblem::solve`] with [`FleetAlgorithm::EqualShare`].
pub fn solve_equal_share(fp: &FleetProblem) -> FleetAllocation {
    fp.solve(&SolveRequest { algorithm: FleetAlgorithm::EqualShare, ..SolveRequest::default() })
}

/// The proposed joint multi-agent design (default options). Deprecated
/// wrapper over [`FleetProblem::solve`] with the default request.
pub fn solve_proposed(fp: &FleetProblem) -> FleetAllocation {
    fp.solve(&SolveRequest::default())
}

/// The proposed design with explicit outer-loop options. Deprecated
/// wrapper over [`FleetProblem::solve`].
pub fn solve_proposed_with(fp: &FleetProblem, opts: ProposedOptions) -> FleetAllocation {
    fp.solve(&SolveRequest { options: opts, ..SolveRequest::default() })
}

/// Warm-started online re-solve for a churning fleet (see
/// [`SolveRequest::warm_start`] for the slot convention). Deprecated
/// wrapper over [`FleetProblem::solve`].
pub fn solve_proposed_warm(
    fp: &FleetProblem,
    prev: &[Option<(f64, f64)>],
    opts: ProposedOptions,
) -> FleetAllocation {
    fp.solve(&SolveRequest {
        options: opts,
        warm_start: Some(prev.to_vec()),
        ..SolveRequest::default()
    })
}

/// The feasible-random baseline. Deprecated wrapper over
/// [`FleetProblem::solve`] with [`FleetAlgorithm::FeasibleRandom`].
pub fn solve_feasible_random(fp: &FleetProblem, seed: u64) -> FleetAllocation {
    fp.solve(&SolveRequest {
        algorithm: FleetAlgorithm::FeasibleRandom,
        seed,
        ..SolveRequest::default()
    })
}

/// Mean objective of the random baseline over `trials` draws (the
/// figure-style aggregate).
pub fn feasible_random_mean(fp: &FleetProblem, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    (0..trials.max(1))
        .map(|_| solve_feasible_random(fp, rng.next_u64()).objective)
        .sum::<f64>()
        / trials.max(1) as f64
}

// ---------------------------------------------------------------------------
// single-server solver bodies (the legacy path, bit for bit)
// ---------------------------------------------------------------------------

/// Single-server dispatch — the pre-placement solver, reached directly
/// for default-single-server fleets and per sub-fleet by the placement
/// layer.
fn solve_single(fp: &FleetProblem, req: &SolveRequest) -> FleetAllocation {
    let oracle = CostOracle::new(fp, req.classing);
    match req.algorithm {
        FleetAlgorithm::Proposed => match &req.warm_start {
            Some(prev) => proposed_warm_single(fp, &oracle, prev, req.options),
            None => proposed_single(fp, &oracle, req.options),
        },
        FleetAlgorithm::EqualShare => equal_share_single(fp, &oracle),
        // the random baseline draws per-agent shares anyway; classing
        // would buy nothing, so it always runs the direct path
        FleetAlgorithm::FeasibleRandom => feasible_random_single(fp, req.seed),
    }
}

fn equal_share_single(fp: &FleetProblem, oracle: &CostOracle<'_>) -> FleetAllocation {
    let shares = MultiAccessChannel::equal_shares(fp.n());
    evaluate_with(fp, oracle, &shares, &shares)
}

fn proposed_single(
    fp: &FleetProblem,
    oracle: &CostOracle<'_>,
    opts: ProposedOptions,
) -> FleetAllocation {
    let _span = obs_metrics::span("solver.proposed");
    let equal = MultiAccessChannel::equal_shares(fp.n());
    let mut inits = vec![(equal.clone(), equal)];
    if fp.n() > 1 {
        if let Some((mu0, alpha0)) = admission_init(fp, oracle) {
            inits.push((mu0, alpha0));
        }
    }
    // the untouched equal split is always a candidate: the structural
    // "never worse than equal-share" guarantee must survive the final
    // fixed-point scoring even when the exchange (which probes the
    // separable mean-field costs) wanders off under queue feedback
    let mut best = equal_share_single(fp, oracle);
    for (mut mu, mut alpha) in inits {
        improve(fp, oracle, &mut mu, &mut alpha, opts);
        let alloc = evaluate_with(fp, oracle, &mu, &alpha);
        if alloc.objective < best.objective {
            best = alloc;
        }
    }
    best
}

/// Warm-started online re-solve for a churning fleet: seed the
/// water-filling exchange from a previous allocation's shares instead of
/// the cold inits. Newcomers (`None` slots) are seated at a
/// weight-proportional slice of the pie (carved from the departed
/// agents' freed mass first, then from incumbents), and the exchange
/// refines from there. With an unchanged population this starts at the
/// previous optimum, so the improvement loop terminates immediately and
/// the result can only match or improve it.
fn proposed_warm_single(
    fp: &FleetProblem,
    oracle: &CostOracle<'_>,
    prev: &[Option<(f64, f64)>],
    opts: ProposedOptions,
) -> FleetAllocation {
    assert_eq!(prev.len(), fp.n());
    let _span = obs_metrics::span("solver.warm");
    let n = fp.n();
    let weight_all: f64 = fp.agents.iter().map(|a| a.weight).sum();
    let mut mu: Vec<f64> = prev.iter().map(|p| p.map_or(0.0, |(m, _)| m.max(0.0))).collect();
    let mut alpha: Vec<f64> = prev.iter().map(|p| p.map_or(0.0, |(_, a)| a.max(0.0))).collect();
    for shares in [&mut mu, &mut alpha] {
        let used: f64 = shares.iter().sum();
        if used > 1.0 {
            // defensive renormalization; previous allocations are valid
            for s in shares.iter_mut() {
                *s /= used;
            }
        }
    }
    // the previous operating point itself is a candidate: with an
    // unchanged population the warm solve then can only match or improve
    // it under the final fixed-point scoring, even though reseating
    // treats zero-share survivors like newcomers and the exchange probes
    // the mean-field surrogate
    let raw = evaluate_with(fp, oracle, &mu, &alpha);
    for shares in [&mut mu, &mut alpha] {
        let used: f64 = shares.iter().sum::<f64>().min(1.0);
        let newcomers: Vec<usize> = (0..n).filter(|&i| shares[i] <= 0.0).collect();
        if newcomers.is_empty() {
            // departed agents' mass goes back to everyone, by weight
            let free = 1.0 - used;
            for (i, s) in shares.iter_mut().enumerate() {
                *s += free * fp.agents[i].weight / weight_all;
            }
            continue;
        }
        let weight_new: f64 = newcomers.iter().map(|&i| fp.agents[i].weight).sum();
        let target = weight_new / weight_all; // newcomers' fair slice
        let mut free = 1.0 - used;
        if free < target && used > 0.0 {
            // shrink incumbents proportionally to make room
            let scale = (1.0 - target) / used;
            for s in shares.iter_mut() {
                *s *= scale;
            }
            free = target;
        }
        for &i in &newcomers {
            shares[i] = free * fp.agents[i].weight / weight_new;
        }
    }
    let seeded = evaluate_with(fp, oracle, &mu, &alpha);
    improve(fp, oracle, &mut mu, &mut alpha, opts);
    let mut best = evaluate_with(fp, oracle, &mu, &alpha);
    // the current population's equal split rides along too, so the
    // online path keeps the same structural never-worse-than-equal
    // guarantee as the cold solve
    for cand in [seeded, raw, equal_share_single(fp, oracle)] {
        if cand.objective < best.objective {
            best = cand;
        }
    }
    best
}

/// The feasible-random baseline: Dirichlet(1) shares on both resources
/// and a random feasible bit-width per agent (frequencies by the
/// energy-min oracle, as in [`feasible_random`]).
fn feasible_random_single(fp: &FleetProblem, seed: u64) -> FleetAllocation {
    let mut rng = Rng::new(seed);
    let mut draw_shares = |n: usize| -> Vec<f64> {
        let gammas: Vec<f64> = (0..n).map(|_| rng.exponential(1.0)).collect();
        let total: f64 = gammas.iter().sum();
        gammas.iter().map(|g| g / total.max(1e-300)).collect()
    };
    let mu = draw_shares(fp.n());
    let alpha = draw_shares(fp.n());
    let waits = fp.interference_waits(&mu, &alpha).waits;
    assemble(fp, &mu, &alpha, &waits, |i| {
        fp.agent_problem_at_wait(i, mu[i], alpha[i], waits[i])
            .and_then(|p| feasible_random::solve(&p, rng.next_u64()))
    })
}

// ---------------------------------------------------------------------------
// equivalence-class internals (the classed fast path)
// ---------------------------------------------------------------------------

/// Content-keyed partition of the fleet: agents whose subproblems are
/// float-for-float identical (same QoS contract, silicon tier, channel
/// gain bits, arrival rate and pressure) share a class. Classes are
/// numbered in first-appearance order, so the partition itself is
/// deterministic across runs.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ClassIndex {
    /// class id per agent
    pub class_of: Vec<usize>,
    /// the representative (first member) of each class
    pub rep: Vec<usize>,
    /// multiplicity of each class
    pub count: Vec<usize>,
}

impl ClassIndex {
    pub fn classes(&self) -> usize {
        self.rep.len()
    }

    /// True when classing cannot help: every agent is alone in its class.
    pub fn all_singletons(&self) -> bool {
        self.count.iter().all(|&c| c == 1)
    }
}

impl FleetProblem {
    /// Everything a per-agent subproblem reads about agent `i`, as exact
    /// bit patterns (gains optionally rounded to `gain_decimals` digits
    /// for [`Classing::Bucketed`]). Two agents with equal keys produce
    /// identical floats from every probe the solver makes about them.
    fn class_key(&self, i: usize, gain_decimals: Option<u32>) -> (&'static str, &'static str, Vec<u64>) {
        let a = &self.agents[i];
        let gain = match gain_decimals {
            None => a.channel_gain.to_bits(),
            Some(d) => {
                let scale = 10f64.powi(d.min(12) as i32);
                (a.channel_gain * scale).round().to_bits()
            }
        };
        let mut bits = vec![
            a.lambda.to_bits(),
            a.t0.to_bits(),
            a.e0.to_bits(),
            a.weight.to_bits(),
            a.payload_bytes as u64,
            a.device.spec.f_max.to_bits(),
            a.device.spec.flops_per_cycle.to_bits(),
            a.device.spec.pue.to_bits(),
            a.device.spec.psi.to_bits(),
            a.device.link_gain.to_bits(),
            gain,
        ];
        if let Some(q) = &self.queue {
            bits.push(1);
            bits.push(q.arrival_rps[i].to_bits());
        }
        if !self.pressure.is_empty() {
            bits.push(2);
            bits.push(self.pressure[i].to_bits());
        }
        // non-default quant policies re-route the design dispatch, so
        // they are part of the class identity; the default contributes
        // nothing, keeping legacy keys byte-identical
        if !a.quant.is_default() {
            bits.push(3);
            let mut h = DefaultHasher::new();
            a.quant.hash_content(&mut h);
            bits.push(h.finish());
        }
        (a.class, a.device.tier, bits)
    }

    /// Partition the fleet under a classing mode.
    /// [`Classing::PerAgent`] yields all singletons.
    pub fn class_index(&self, classing: Classing) -> ClassIndex {
        let n = self.n();
        match classing {
            Classing::PerAgent => ClassIndex {
                class_of: (0..n).collect(),
                rep: (0..n).collect(),
                count: vec![1; n],
            },
            Classing::Exact | Classing::Bucketed { .. } => {
                let decimals = match classing {
                    Classing::Bucketed { gain_decimals } => Some(gain_decimals),
                    _ => None,
                };
                let mut ids: HashMap<(&'static str, &'static str, Vec<u64>), usize> =
                    HashMap::new();
                let mut class_of = Vec::with_capacity(n);
                let mut rep = Vec::new();
                let mut count = Vec::new();
                for i in 0..n {
                    let key = self.class_key(i, decimals);
                    let next = rep.len();
                    let c = *ids.entry(key).or_insert(next);
                    if c == next {
                        rep.push(i);
                        count.push(1);
                    } else {
                        count[c] += 1;
                    }
                    class_of.push(c);
                }
                ClassIndex { class_of, rep, count }
            }
        }
    }

    /// One stable content hash per agent over its exact class key — the
    /// class-level fingerprint the churn/daemon layer diffs to decide
    /// which classes a population event actually touched.
    pub fn agent_class_hashes(&self) -> Vec<u64> {
        (0..self.n())
            .map(|i| {
                let mut h = DefaultHasher::new();
                self.class_key(i, None).hash(&mut h);
                h.finish()
            })
            .collect()
    }
}

/// Memoized per-class evaluation state for [`CostOracle::Classed`].
///
/// `collapse_mean` governs the *mean-field* probes (`agent_cost`,
/// `queue_wait` and the admission floors): they collapse to one memo
/// slot per class only when the probe's float path is
/// observer-position-independent — i.e. no queue attached (waits are
/// zero) or [`Classing::Bucketed`] (documented approximate). With a
/// queue, [`QueueModel::expected_wait_s`] accumulates the observer's
/// own term *in place*, so two members of one class can round
/// differently; those probes then memoize per agent (still saving
/// repeat probes at the same share point). The wait-*explicit* probes
/// (`design_at_wait`, `servable_at_wait`) and the fixed-point rows
/// (`waits_given`) are position-independent and always collapse.
struct ClassedOracle<'a> {
    fp: &'a FleetProblem,
    idx: ClassIndex,
    collapse_mean: bool,
    cost: RefCell<HashMap<(usize, u64, u64), f64>>,
    design_at: RefCell<HashMap<(usize, u64, u64, u64), Option<Design>>>,
    servable_at: RefCell<HashMap<(usize, u64, u64, u64), bool>>,
    wait_mean: RefCell<HashMap<(usize, u64), f64>>,
}

impl ClassedOracle<'_> {
    /// (memo slot, evaluation index) for a mean-field probe about `i`.
    fn mean_slot(&self, i: usize) -> (usize, usize) {
        if self.collapse_mean {
            let c = self.idx.class_of[i];
            (c, self.idx.rep[c])
        } else {
            (i, i)
        }
    }
}

/// How the solver bodies ask per-agent questions: `Direct` delegates
/// straight to [`FleetProblem`] (the legacy path, zero overhead),
/// `Classed` memoizes per equivalence class. Every memoized value is
/// the very float the direct path would have computed for some fleet
/// member, which is what makes [`Classing::Exact`] bit-identical.
enum CostOracle<'a> {
    Direct(&'a FleetProblem),
    Classed(Box<ClassedOracle<'a>>),
}

impl<'a> CostOracle<'a> {
    fn direct(fp: &'a FleetProblem) -> CostOracle<'a> {
        CostOracle::Direct(fp)
    }

    fn new(fp: &'a FleetProblem, classing: Classing) -> CostOracle<'a> {
        match classing {
            Classing::PerAgent => CostOracle::Direct(fp),
            _ => {
                let idx = fp.class_index(classing);
                obs_metrics::counter_add("solver.classed.solves", 1);
                obs_metrics::counter_add("solver.classed.classes", idx.classes() as u64);
                let collapse_mean =
                    fp.queue.is_none() || matches!(classing, Classing::Bucketed { .. });
                CostOracle::Classed(Box::new(ClassedOracle {
                    fp,
                    idx,
                    collapse_mean,
                    cost: RefCell::new(HashMap::new()),
                    design_at: RefCell::new(HashMap::new()),
                    servable_at: RefCell::new(HashMap::new()),
                    wait_mean: RefCell::new(HashMap::new()),
                }))
            }
        }
    }

    /// Mean-field cost of agent `i` at shares (μ, α) — the exchange
    /// loop's probe.
    fn agent_cost(&self, i: usize, mu: f64, alpha: f64) -> f64 {
        match self {
            CostOracle::Direct(fp) => fp.agent_cost(i, mu, alpha),
            CostOracle::Classed(cx) => {
                let (slot, at) = cx.mean_slot(i);
                let key = (slot, mu.to_bits(), alpha.to_bits());
                if let Some(&v) = cx.cost.borrow().get(&key) {
                    return v;
                }
                let v = cx.fp.agent_cost(at, mu, alpha);
                cx.cost.borrow_mut().insert(key, v);
                v
            }
        }
    }

    /// Exact per-agent design at an explicit wait — position-independent,
    /// so always answered from the class representative.
    fn design_at_wait(&self, i: usize, mu: f64, alpha: f64, wait: f64) -> Option<Design> {
        match self {
            CostOracle::Direct(fp) => fp.agent_design_at_wait(i, mu, alpha, wait),
            CostOracle::Classed(cx) => {
                let c = cx.idx.class_of[i];
                let key = (c, mu.to_bits(), alpha.to_bits(), wait.to_bits());
                if let Some(v) = cx.design_at.borrow().get(&key) {
                    return *v;
                }
                let v = cx.fp.agent_design_at_wait(cx.idx.rep[c], mu, alpha, wait);
                cx.design_at.borrow_mut().insert(key, v);
                v
            }
        }
    }

    /// Feasibility at an explicit wait — the fixed-point pass's probe.
    fn servable_at_wait(&self, i: usize, mu: f64, alpha: f64, wait: f64) -> bool {
        match self {
            CostOracle::Direct(fp) => fp.servable_at_wait(i, mu, alpha, wait),
            CostOracle::Classed(cx) => {
                let c = cx.idx.class_of[i];
                let key = (c, mu.to_bits(), alpha.to_bits(), wait.to_bits());
                if let Some(&v) = cx.servable_at.borrow().get(&key) {
                    return v;
                }
                let v = cx.fp.servable_at_wait(cx.idx.rep[c], mu, alpha, wait);
                cx.servable_at.borrow_mut().insert(key, v);
                v
            }
        }
    }

    /// Mean-field queue wait (the fallback scoring path).
    fn queue_wait(&self, i: usize, mu: f64) -> f64 {
        match self {
            CostOracle::Direct(fp) => fp.queue_wait(i, mu),
            CostOracle::Classed(cx) => {
                let (slot, at) = cx.mean_slot(i);
                let key = (slot, mu.to_bits());
                if let Some(&v) = cx.wait_mean.borrow().get(&key) {
                    return v;
                }
                let v = cx.fp.queue_wait(at, mu);
                cx.wait_mean.borrow_mut().insert(key, v);
                v
            }
        }
    }

    /// One fixed-point iteration's wait vector. Row `i` of
    /// [`QueueModel::waits_given`] depends on the observer only through
    /// its priority weight (class-keyed) and its own-service finiteness
    /// guard (checked per agent below), so the classed path computes one
    /// row per class and broadcasts it — exact even when members hold
    /// different shares mid-exchange.
    fn waits_given(&self, services: &[f64], activity: &[f64]) -> Vec<f64> {
        match self {
            CostOracle::Direct(fp) => fp.queue_waits_given(services, activity),
            CostOracle::Classed(cx) => {
                let Some(q) = &cx.fp.queue else {
                    return vec![0.0; cx.fp.n()];
                };
                let weight_of = |j: usize| cx.fp.agents[j].weight;
                let mut per_class: Vec<Option<f64>> = vec![None; cx.idx.classes()];
                (0..cx.fp.n())
                    .map(|i| {
                        let s_i = services[i];
                        if !(s_i.is_finite() && s_i >= 0.0) {
                            return f64::INFINITY;
                        }
                        let c = cx.idx.class_of[i];
                        if let Some(w) = per_class[c] {
                            return w;
                        }
                        let w = q.wait_given_one(i, services, activity, weight_of);
                        per_class[c] = Some(w);
                        w
                    })
                    .collect()
            }
        }
    }

    /// The admission loop's two bisected floors (min server share, min
    /// airtime) per agent, in index order. The direct path runs them
    /// inline; the classed path bisects one probe per class (or per
    /// agent when the mean-field probe cannot collapse — still memoized
    /// work worth parallelizing) across
    /// [`crate::util::pool::ThreadPool::map`] workers and broadcasts.
    fn admission_floors(&self) -> Vec<(Option<f64>, Option<f64>)> {
        match self {
            CostOracle::Direct(fp) => (0..fp.n())
                .map(|i| {
                    let probe = fp.agents[i].quant.probe_bits();
                    let servable = |m: f64, a: f64| {
                        fp.agent_problem(i, m, a)
                            .is_some_and(|p| p.plan_frequencies(probe).is_some())
                    };
                    (min_share(|m| servable(m, 1.0)), min_share(|a| servable(1.0, a)))
                })
                .collect(),
            CostOracle::Classed(cx) => {
                let probes: Vec<usize> = if cx.collapse_mean {
                    cx.idx.rep.clone()
                } else {
                    (0..cx.fp.n()).collect()
                };
                let shared = Arc::new(cx.fp.clone());
                let workers = pool::default_parallelism().min(probes.len()).max(1);
                let floors = ThreadPool::new(workers).map(probes, move |i| {
                    let probe = shared.agents[i].quant.probe_bits();
                    let servable = |m: f64, a: f64| {
                        shared
                            .agent_problem(i, m, a)
                            .is_some_and(|p| p.plan_frequencies(probe).is_some())
                    };
                    (min_share(|m| servable(m, 1.0)), min_share(|a| servable(1.0, a)))
                });
                // worker threads drop their thread-local metrics; account
                // for the bisections on the solver thread instead
                obs_metrics::counter_add("solver.class.bisections", 2 * floors.len() as u64);
                if cx.collapse_mean {
                    cx.idx.class_of.iter().map(|&c| floors[c]).collect()
                } else {
                    floors
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// proposed-solver internals
// ---------------------------------------------------------------------------

/// Smallest share s ∈ (0, 1] making `feasible(s)` true (monotone), by
/// bisection; `None` if even s = 1 fails.
fn min_share(feasible: impl Fn(f64) -> bool) -> Option<f64> {
    obs_metrics::counter_add("solver.bisection.calls", 1);
    if !feasible(1.0) {
        return None;
    }
    let (mut lo, mut hi) = (0.0, 1.0);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    obs_metrics::counter_add("solver.bisection.iters", 40);
    Some(hi)
}

/// Greedy admission: seat agents in weight order at their minimal
/// feasible shares (server share probed with the full medium, airtime
/// probed with the full server — each resource's true floor), then hand
/// the leftovers out weight-proportionally. Returns `None` when nobody
/// can be seated (the equal init is then the only candidate).
///
/// The two bisected floors per agent come from
/// [`CostOracle::admission_floors`] — index order, independent of the
/// seating order, so the sorted loop below consumes the exact values the
/// historical in-loop bisections produced. The weight sort uses
/// `total_cmp`: agent weights are validated finite, and unlike the old
/// `partial_cmp(..).unwrap_or(Equal)` it cannot silently mis-order if a
/// NaN ever slipped past validation (mirrors the `EdgeQueue::push`
/// NaN-priority fix).
fn admission_init(fp: &FleetProblem, oracle: &CostOracle<'_>) -> Option<(Vec<f64>, Vec<f64>)> {
    let n = fp.n();
    let floors = oracle.admission_floors();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| fp.agents[b].weight.total_cmp(&fp.agents[a].weight).then(a.cmp(&b)));
    let mut mu = vec![0.0; n];
    let mut alpha = vec![0.0; n];
    let (mut mu_used, mut alpha_used) = (0.0f64, 0.0f64);
    let mut admitted: Vec<usize> = Vec::new();
    for i in order {
        if let (Some(m), Some(a)) = floors[i] {
            if mu_used + m <= 1.0 && alpha_used + a <= 1.0 {
                mu[i] = m;
                alpha[i] = a;
                mu_used += m;
                alpha_used += a;
                admitted.push(i);
            }
        }
    }
    if admitted.is_empty() {
        return None;
    }
    let weight_sum: f64 = admitted.iter().map(|&i| fp.agents[i].weight).sum();
    for &i in &admitted {
        let frac = fp.agents[i].weight / weight_sum;
        mu[i] += (1.0 - mu_used) * frac;
        alpha[i] += (1.0 - alpha_used) * frac;
    }
    Some((mu, alpha))
}

/// Alternating water-filling: improve the server-share vector at fixed
/// airtime, then the airtime vector at fixed server shares, until a full
/// round yields nothing.
fn improve(
    fp: &FleetProblem,
    oracle: &CostOracle<'_>,
    mu: &mut [f64],
    alpha: &mut [f64],
    opts: ProposedOptions,
) {
    let n = fp.n();
    if n < 2 {
        return;
    }
    let max_moves = opts.moves_per_agent * n;
    for _ in 0..opts.rounds {
        obs_metrics::counter_add("solver.exchange.rounds", 1);
        let mut gained = 0.0;
        for divisor in opts.step_divisors {
            let step = 1.0 / (divisor * n as f64);
            gained += exchange(mu, step, max_moves, |i, s| oracle.agent_cost(i, s, alpha[i]));
            gained += exchange(alpha, step, max_moves, |i, s| oracle.agent_cost(i, mu[i], s));
        }
        if gained <= 1e-15 {
            break;
        }
    }
}

/// One resource's greedy pairwise exchange: repeatedly move `step` from
/// the agent whose cost rises least to the agent whose cost falls most,
/// while the net change improves the weighted sum. Cost depends only on
/// the owner's share, so this is exact coordinate descent on a separable
/// objective; per-agent costs are monotone non-increasing in share, which
/// keeps every accepted move a strict improvement.
fn exchange(
    shares: &mut [f64],
    step: f64,
    max_moves: usize,
    cost_at: impl Fn(usize, f64) -> f64,
) -> f64 {
    let n = shares.len();
    if n < 2 {
        return 0.0;
    }
    // cached (current, donate-loss, receive-gain) per agent
    let triple = |i: usize, s: f64| -> (f64, f64, f64) {
        let cur = cost_at(i, s);
        let loss = if s + 1e-12 >= step {
            cost_at(i, (s - step).max(0.0)) - cur
        } else {
            f64::INFINITY // too little left to donate a full quantum
        };
        let gain = cur - cost_at(i, s + step);
        (cur, loss, gain)
    };
    let mut cached: Vec<(f64, f64, f64)> = (0..n).map(|i| triple(i, shares[i])).collect();
    let mut total_gain = 0.0;
    let mut moves = 0u64;
    for _ in 0..max_moves {
        let Some((d, r, net)) = select_move(&cached) else { break };
        shares[d] = (shares[d] - step).max(0.0);
        shares[r] += step;
        cached[d] = triple(d, shares[d]);
        cached[r] = triple(r, shares[r]);
        total_gain += net;
        moves += 1;
    }
    if moves > 0 {
        obs_metrics::counter_add("solver.exchange.moves", moves);
    }
    total_gain
}

/// Pick the donor/receiver pair of the next exchange move in O(n),
/// bit-identical to the historical O(n²) scan (kept as
/// [`select_move_reference`] and property-tested against this).
///
/// Why the shortcut is exact: for a fixed donor `d`, IEEE subtraction is
/// monotone in its first operand, so the row maximum of
/// `fl(gain[r] - loss[d])` over `r ≠ d` is attained at the largest
/// eligible gain — the global top gain, or the runner-up when `d` itself
/// uniquely holds the top. The historical scan kept the *first* strict
/// improvement row-major, i.e. the first donor row attaining the global
/// maximum net and, within it, the first receiver attaining that row's
/// maximum — which is exactly what the strict `>` donor loop and the
/// first-match receiver scan below reproduce.
fn select_move(cached: &[(f64, f64, f64)]) -> Option<(usize, usize, f64)> {
    let n = cached.len();
    // pass 0: top gain (value, first holder, multiplicity) + runner-up
    let mut g1 = f64::NEG_INFINITY;
    let mut r1 = 0usize;
    let mut cnt1 = 0usize;
    let mut g2 = f64::NEG_INFINITY;
    let mut has2 = false;
    for (i, c) in cached.iter().enumerate() {
        let g = c.2;
        if g.is_nan() {
            continue; // NaN nets never beat the threshold in the old scan
        }
        if cnt1 == 0 || g > g1 {
            if cnt1 > 0 {
                g2 = g1;
                has2 = true;
            }
            g1 = g;
            r1 = i;
            cnt1 = 1;
        } else {
            if g == g1 {
                cnt1 += 1;
            }
            if !has2 || g > g2 {
                g2 = g;
                has2 = true;
            }
        }
    }
    if cnt1 == 0 {
        return None;
    }
    // pass 1: best donor under the strict-improvement threshold
    let mut best: Option<(usize, f64)> = None;
    for (d, c) in cached.iter().enumerate() {
        let loss = c.1;
        if !loss.is_finite() {
            continue;
        }
        let top = if d == r1 && cnt1 == 1 {
            if !has2 {
                continue; // no receiver other than the donor itself
            }
            g2
        } else {
            g1
        };
        let net = top - loss;
        if net > best.map_or(1e-15, |(_, b)| b) {
            best = Some((d, net));
        }
    }
    let (d, net) = best?;
    // pass 2: first receiver attaining the winning row's maximum net
    let loss = cached[d].1;
    let r = (0..n).find(|&r| r != d && cached[r].2 - loss == net)?;
    Some((d, r, net))
}

/// The historical O(n²) row-major selection scan, kept verbatim as the
/// property-test reference for [`select_move`].
#[cfg(test)]
fn select_move_reference(cached: &[(f64, f64, f64)]) -> Option<(usize, usize, f64)> {
    let n = cached.len();
    let mut best: Option<(usize, usize, f64)> = None;
    for d in 0..n {
        let loss = cached[d].1;
        if !loss.is_finite() {
            continue;
        }
        for r in 0..n {
            if r == d {
                continue;
            }
            let net = cached[r].2 - loss;
            if net > best.map_or(1e-15, |(_, _, b)| b) {
                best = Some((d, r, net));
            }
        }
    }
    best
}

// ---------------------------------------------------------------------------
// multi-server placement internals
// ---------------------------------------------------------------------------

/// Sub-solve memo for one placement search: (server, members, airtime
/// bits) → the server's globalized per-member allocations. Local search
/// revisits mostly-unchanged placements, so per-server results are
/// shared across candidate scores.
type SubCache = HashMap<(usize, Vec<usize>, u64), Vec<AgentAllocation>>;

/// The strongest server (largest frequency budget, ties to the lowest
/// index) — where the nearest-server baseline concentrates the fleet.
fn strongest_server(fp: &FleetProblem) -> usize {
    let mut best = 0;
    for (k, s) in fp.servers.iter().enumerate().skip(1) {
        if s.freq_scale > fp.servers[best].freq_scale {
            best = k;
        }
    }
    best
}

/// Per-server airtime fraction of the shared medium under a placement:
/// explicit [`ServerSpec::airtime_fraction`] is honored verbatim; the
/// leftover medium is split across the *unspecified, populated* servers
/// proportionally to head-count; an empty server gets 0. At S = 1 with
/// the default server this is exactly 1.0 (n/n — IEEE-exact), so the
/// sub-fleet's medium is the whole medium bit for bit.
fn airtime_fractions(fp: &FleetProblem, placement: &Placement) -> Vec<f64> {
    let mut counts = vec![0usize; fp.servers.len()];
    for &k in &placement.assignment {
        counts[k] += 1;
    }
    let mut explicit_sum = 0.0;
    let mut unspecified = 0usize;
    for (k, srv) in fp.servers.iter().enumerate() {
        if counts[k] == 0 {
            continue;
        }
        match srv.airtime_fraction {
            Some(f) => explicit_sum += f,
            None => unspecified += counts[k],
        }
    }
    let leftover = (1.0 - explicit_sum).max(0.0);
    fp.servers
        .iter()
        .enumerate()
        .map(|(k, srv)| {
            if counts[k] == 0 {
                return 0.0;
            }
            match srv.airtime_fraction {
                Some(f) => f,
                None => leftover * counts[k] as f64 / unspecified as f64,
            }
        })
        .collect()
}

/// One server's sub-fleet as its own single-server [`FleetProblem`]:
/// the member agents on the frequency-scaled base, the server's airtime
/// slice of the medium, the member slice of the queue's arrival rates
/// (under the server's discipline override, if any). Shares solved
/// against this are in sub-fleet coordinates; [`sub_allocation`] scales
/// them back to fleet-global ones.
fn sub_problem(
    fp: &FleetProblem,
    members: &[usize],
    server: ServerSpec,
    phi_air: f64,
) -> FleetProblem {
    let mut base = fp.base;
    base.server.f_max *= server.freq_scale;
    FleetProblem {
        spec: FleetSpec {
            base,
            agents: members.iter().map(|&i| fp.agents[i]).collect(),
            servers: vec![ServerSpec::default()],
            link_rate_bps: fp.link_rate_bps * phi_air,
            link_base_latency_s: fp.link_base_latency_s,
            queue: fp.queue.as_ref().map(|q| {
                QueueModel::new(
                    server.queue.unwrap_or(q.discipline),
                    members.iter().map(|&i| q.arrival_rps[i]).collect(),
                )
            }),
            pricing: fp.pricing,
            pressure: if fp.pressure.is_empty() {
                Vec::new()
            } else {
                members.iter().map(|&i| fp.pressure[i]).collect()
            },
        },
    }
}

/// Solve one populated server's sub-fleet (memoized) and report the
/// allocations in fleet-global coordinates: μ as a fraction of the
/// *base* server's budget, α of the *whole* medium.
fn sub_allocation(
    fp: &FleetProblem,
    k: usize,
    members: &[usize],
    phi_air: f64,
    req: &SolveRequest,
    cache: &mut SubCache,
) -> Vec<AgentAllocation> {
    let key = (k, members.to_vec(), phi_air.to_bits());
    if let Some(hit) = cache.get(&key) {
        return hit.clone();
    }
    let server = fp.servers[k];
    let sub_fp = sub_problem(fp, members, server, phi_air);
    let sub_req = SolveRequest {
        algorithm: req.algorithm,
        options: req.options,
        placement: PlacementStrategy::default(),
        // warm shares arrive in fleet-global coordinates; un-scale them
        // into this server's sub-fleet coordinates
        warm_start: req.warm_start.as_ref().map(|w| {
            members
                .iter()
                .map(|&i| {
                    w[i].map(|(m, a)| {
                        (m / server.freq_scale, if phi_air > 0.0 { a / phi_air } else { 0.0 })
                    })
                })
                .collect()
        }),
        seed: req.seed.wrapping_add(k as u64),
        classing: req.classing,
    };
    let alloc = solve_single(&sub_fp, &sub_req);
    let globalized: Vec<AgentAllocation> = alloc
        .agents
        .iter()
        .map(|a| {
            let mut g = *a;
            g.server_share *= server.freq_scale;
            g.airtime_share *= phi_air;
            g
        })
        .collect();
    cache.insert(key, globalized.clone());
    globalized
}

/// Score a full placement: per-server sub-solves stitched into one fleet
/// allocation (every agent gets a slot, shares fleet-global, objective
/// summed over servers).
fn placed_allocation(
    fp: &FleetProblem,
    placement: &Placement,
    req: &SolveRequest,
    cache: &mut SubCache,
) -> Result<FleetAllocation, FleetError> {
    let phi = airtime_fractions(fp, placement);
    let mut slots: Vec<Option<AgentAllocation>> = vec![None; fp.n()];
    for k in 0..fp.servers.len() {
        let members = placement.members(k);
        if members.is_empty() {
            continue;
        }
        let sub = sub_allocation(fp, k, &members, phi[k], req, cache);
        for (&i, a) in members.iter().zip(&sub) {
            slots[i] = Some(*a);
        }
    }
    stitch(slots, placement)
}

/// A candidate placement's objective for the local search: an
/// unstitchable candidate scores +inf (never chosen) instead of aborting
/// the search — the search only constructs complete placements, so this
/// is purely defensive.
fn placed_objective(
    fp: &FleetProblem,
    placement: &Placement,
    req: &SolveRequest,
    cache: &mut SubCache,
) -> f64 {
    placed_allocation(fp, placement, req, cache).map_or(f64::INFINITY, |a| a.objective)
}

/// Local-search placement: start from the better of equal-spread and
/// all-on-the-strongest-server, then repeatedly apply the best
/// single-agent move that improves the fleet objective (each accepted
/// move counted as `placement.moves`), until no move improves or the
/// move budget (2N) is spent. Sub-solves are memoized across candidate
/// scores, so unchanged servers are never re-solved.
fn local_search_placement(fp: &FleetProblem, req: &SolveRequest) -> Placement {
    let (n, s) = (fp.n(), fp.servers.len());
    let mut cache = SubCache::new();
    let mut best = Placement::equal_spread(n, s);
    let mut best_obj = placed_objective(fp, &best, req, &mut cache);
    let concentrated = Placement::all_on(n, strongest_server(fp));
    let conc_obj = placed_objective(fp, &concentrated, req, &mut cache);
    if conc_obj < best_obj {
        best = concentrated;
        best_obj = conc_obj;
    }
    for _ in 0..2 * n {
        let mut cand: Option<(Placement, f64)> = None;
        for i in 0..n {
            let cur = best.assignment[i];
            for t in 0..s {
                if t == cur {
                    continue;
                }
                let mut p = best.clone();
                p.assignment[i] = t;
                let obj = placed_objective(fp, &p, req, &mut cache);
                if obj < cand.as_ref().map_or(best_obj - 1e-15, |(_, b)| *b) {
                    cand = Some((p, obj));
                }
            }
        }
        let Some((p, obj)) = cand else { break };
        best = p;
        best_obj = obj;
        obs_metrics::counter_add("placement.moves", 1);
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::system::queue::QueueDiscipline;
    use crate::util::prop::forall;

    fn fleet(n: usize) -> FleetProblem {
        FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
    }

    #[test]
    fn n1_fleet_reduces_to_single_agent_bisection() {
        // ideal link + sole agent owning both resources == the paper (P1)
        let fp = fleet(1).ideal_link();
        let spec = fp.agents[0];
        let single = bisection::solve(&Problem::new(
            Platform::fleet_edge(),
            spec.lambda,
            spec.t0,
            spec.e0,
        ))
        .expect("single-agent feasible");
        for algorithm in [FleetAlgorithm::Proposed, FleetAlgorithm::EqualShare] {
            let alloc = solve(&fp, algorithm, 0);
            let d = alloc.agents[0].design.expect("fleet of one admitted");
            assert_eq!(d.b_hat, single.design.b_hat, "{algorithm:?}");
            assert!((d.f - single.design.f).abs() / single.design.f < 1e-9);
            assert!((d.f_tilde - single.design.f_tilde).abs() / single.design.f_tilde < 1e-9);
            assert_eq!(alloc.admitted, 1);
        }
    }

    #[test]
    fn proposed_never_worse_than_equal_share() {
        // structural guarantee (improvement starts at the equal split), so
        // it must hold on any base platform, contended or not
        for n in [2usize, 3, 4, 8] {
            for fp in [
                fleet(n),
                fleet(n).ideal_link(),
                FleetProblem::new(Platform::paper_blip2(), AgentSpec::mixed_fleet(n)),
            ] {
                let equal = solve_equal_share(&fp);
                let proposed = solve_proposed(&fp);
                assert!(
                    proposed.objective <= equal.objective + 1e-12,
                    "n={n}: proposed {} > equal {}",
                    proposed.objective,
                    equal.objective
                );
            }
        }
    }

    #[test]
    fn proposed_strictly_beats_equal_share_on_contended_fleet() {
        // at N >= 4 the shared 10 GHz server binds: interactive agents are
        // starved under the equal split while background agents sit on
        // slack — the exchange must exploit it
        for n in [4usize, 8] {
            let fp = fleet(n);
            let equal = solve_equal_share(&fp);
            let proposed = solve_proposed(&fp);
            assert!(
                proposed.objective < equal.objective * 0.99,
                "n={n}: proposed {} not clearly below equal {}",
                proposed.objective,
                equal.objective
            );
            let wu_p = proposed.weighted_d_upper(&fp);
            let wu_e = equal.weighted_d_upper(&fp);
            assert!(
                wu_p <= wu_e + 1e-12,
                "n={n}: weighted D^U {wu_p} > equal {wu_e}"
            );
        }
    }

    #[test]
    fn admission_control_serves_part_of_an_infeasible_fleet() {
        // 32 agents on one paper server: the equal split gives everyone
        // f̃ = 0.3125 GHz, far below any budget — the proposed allocator
        // must concentrate shares and admit a subset instead
        let n = 32;
        let fp = fleet(n);
        let equal = solve_equal_share(&fp);
        assert_eq!(equal.admitted, 0, "equal split should be fully infeasible");
        let proposed = solve_proposed(&fp);
        assert!(proposed.admitted >= 1, "admission control seated nobody");
        assert!(proposed.objective < equal.objective - 1e-9);
    }

    #[test]
    fn allocations_keep_shares_valid() {
        for n in [1usize, 4, 9] {
            let fp = fleet(n);
            for algorithm in FleetAlgorithm::ALL {
                let alloc = solve(&fp, algorithm, 7);
                for res in [alloc.server_shares(), alloc.airtime_shares()] {
                    assert!(res.iter().all(|&s| (0.0..=1.0 + 1e-9).contains(&s)));
                    let total: f64 = res.iter().sum();
                    assert!(total <= 1.0 + 1e-9, "{algorithm:?} n={n}: {total}");
                }
            }
        }
    }

    #[test]
    fn admitted_designs_are_feasible_for_their_subproblem() {
        // every admitted design satisfies (P1) at the wait it was scored
        // at — with and without the queue model attached
        for fp in [
            fleet(6),
            fleet(6).with_queue(QueueModel::uniform(QueueDiscipline::Fifo, 6, 0.02)),
        ] {
            let alloc = solve_proposed(&fp);
            for (i, a) in alloc.agents.iter().enumerate() {
                if let Some(d) = &a.design {
                    let p = fp
                        .agent_problem_at_wait(i, a.server_share, a.airtime_share, a.queue_wait_s)
                        .expect("admitted agent has a subproblem");
                    assert!(p.is_feasible(d), "agent {i}: {d:?}");
                }
            }
        }
    }

    #[test]
    fn random_baseline_never_beats_proposed() {
        let fp = fleet(6);
        let proposed = solve_proposed(&fp).objective;
        let mean = feasible_random_mean(&fp, 20, 11);
        assert!(mean >= proposed - 1e-12, "random mean {mean} < proposed {proposed}");
    }

    #[test]
    fn deterministic_given_inputs() {
        let fp = fleet(5);
        let a = solve_proposed(&fp);
        let b = solve_proposed(&fp);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.objective, b.objective);
        for (x, y) in a.agents.iter().zip(&b.agents) {
            assert_eq!(x.design.map(|d| d.b_hat), y.design.map(|d| d.b_hat));
        }
        let r1 = solve_feasible_random(&fp, 3).objective;
        let r2 = solve_feasible_random(&fp, 3).objective;
        assert_eq!(r1, r2);
    }

    #[test]
    fn queue_feedback_tightens_but_never_relaxes_designs() {
        // same shares, same agents: adding the queue term shrinks every
        // delay budget, so per-agent bit-widths can only stay or drop and
        // the equal-share objective can only stay or rise
        let n = 4;
        let plain = fleet(n);
        let queued = fleet(n)
            .with_queue(QueueModel::uniform(QueueDiscipline::Fifo, n, 0.05));
        let a = solve_equal_share(&plain);
        let b = solve_equal_share(&queued);
        assert!(b.objective >= a.objective - 1e-12);
        for (x, y) in a.agents.iter().zip(&b.agents) {
            let (bx, by) = (x.design.map_or(0, |d| d.b_hat), y.design.map_or(0, |d| d.b_hat));
            assert!(by <= bx, "queue feedback raised a bit-width: {by} > {bx}");
        }
        // and the wait itself is visible and monotone in the share
        assert!(queued.queue_wait(0, 0.25) > 0.0);
        assert!(queued.queue_wait(0, 0.5) < queued.queue_wait(0, 0.25));
        assert_eq!(plain.queue_wait(0, 0.25), 0.0);
    }

    #[test]
    fn overloaded_queue_rejects_cleanly_and_proposed_recovers() {
        // load heavy enough that the equal split's queue diverges: every
        // agent must be *cleanly* rejected (finite penalty costs), and the
        // proposed allocator must recover a served subset by concentrating
        // server shares (a bigger slice drains the queue faster)
        let n = 4;
        let fp = fleet(n)
            .with_queue(QueueModel::uniform(QueueDiscipline::Fifo, n, 0.2));
        let equal = solve_equal_share(&fp);
        assert_eq!(equal.admitted, 0, "equal split should be queue-overloaded");
        assert!(equal.objective.is_finite());
        let proposed = solve_proposed(&fp);
        assert!(proposed.admitted >= 1, "concentration should recover service");
        assert!(proposed.objective < equal.objective - 1e-9);
        assert!(proposed.objective.is_finite());
    }

    #[test]
    fn degenerate_shares_reject_cleanly_not_nan() {
        // regression: an airtime share driven to ~0 by the exchange (or a
        // poisoned share vector) must surface as a rejection with finite
        // cost, never as inf/NaN designs that poison the water-filling
        let fp = fleet(3);
        assert!(fp.agent_problem(0, f64::NAN, 0.5).is_none());
        assert!(fp.agent_problem(0, 0.5, f64::NAN).is_none());
        assert!(fp.agent_design(0, 1e-300, 0.5).is_none(), "μ ~ 0 is unservable");
        assert!(fp.agent_problem(0, 0.5, 0.0).is_none());
        assert!(fp.agent_problem(0, 0.5, 1e-12).is_none(), "α ~ 0 is unservable");
        let alloc = evaluate(&fp, &[0.5, 0.3, 0.2], &[1.0, 0.0, 1e-300]);
        assert!(alloc.objective.is_finite());
        assert_eq!(alloc.admitted, 1);
        for a in &alloc.agents {
            assert!(a.cost.is_finite());
        }
        // agent_cost (the exchange's probe) is finite on the whole domain
        for mu in [0.0, 1e-300, 0.1, f64::NAN] {
            for alpha in [0.0, 1e-300, 0.1, f64::NAN] {
                assert!(fp.agent_cost(0, mu, alpha).is_finite(), "({mu},{alpha})");
            }
        }
    }

    #[test]
    fn warm_start_matches_or_improves_cold_solve() {
        for fp in [fleet(4), fleet(7), fleet(4).ideal_link()] {
            let cold = solve_proposed(&fp);
            let prev: Vec<Option<(f64, f64)>> = cold
                .agents
                .iter()
                .map(|a| Some((a.server_share, a.airtime_share)))
                .collect();
            let warm = solve_proposed_warm(&fp, &prev, ProposedOptions::default());
            assert!(
                warm.objective <= cold.objective + 1e-12,
                "warm {} regressed past cold {}",
                warm.objective,
                cold.objective
            );
        }
    }

    #[test]
    fn warm_start_seats_newcomers() {
        // grow a solved 3-fleet to 5: the two newcomers start with no
        // shares and must still end up served
        let small = fleet(3);
        let cold = solve_proposed(&small);
        let grown = fleet(5);
        let mut prev: Vec<Option<(f64, f64)>> = cold
            .agents
            .iter()
            .map(|a| Some((a.server_share, a.airtime_share)))
            .collect();
        prev.extend([None, None]);
        let warm = solve_proposed_warm(&grown, &prev, ProposedOptions::default());
        assert!(warm.admitted >= 4, "newcomers not seated: {}", warm.admitted);
        let shares: f64 = warm.server_shares().iter().sum();
        assert!(shares <= 1.0 + 1e-9);
        assert!(warm.agents[3].server_share > 0.0);
        assert!(warm.agents[4].server_share > 0.0);
    }

    #[test]
    fn uniform_orin_tier_reproduces_the_homogeneous_fleet_exactly() {
        // acceptance regression: a tiered fleet on the uniform Orin
        // ladder is field-for-field the pre-tier homogeneous fleet —
        // same specs, same allocations, bit for bit
        for n in [1usize, 4, 8, 16] {
            let uniform = FleetProblem::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(0)),
            );
            let mixed = fleet(n);
            for (a, b) in uniform.agents.iter().zip(&mixed.agents) {
                assert_eq!(a.device.spec, b.device.spec);
                assert_eq!(a.channel_gain, 1.0);
                assert_eq!(a.device.spec, Platform::fleet_edge().device);
            }
            let x = solve_proposed(&uniform);
            let y = solve_proposed(&mixed);
            assert_eq!(x.objective, y.objective, "n={n}");
            assert_eq!(x.admitted, y.admitted);
            for (a, b) in x.agents.iter().zip(&y.agents) {
                assert_eq!(a.design.map(|d| d.b_hat), b.design.map(|d| d.b_hat));
                assert_eq!(a.server_share, b.server_share);
                assert_eq!(a.airtime_share, b.airtime_share);
            }
        }
    }

    #[test]
    fn tiered_n1_fleets_reduce_to_their_single_agent_design() {
        // the N = 1 reduction holds on every silicon tier: the fleet
        // path's per-agent platform substitution is exactly the paper's
        // single-pair platform with that device
        for profile in AgentSpec::tier_mix(2) {
            let spec = AgentSpec::tiered_spec(0, &[profile]);
            let fp = FleetProblem::new(Platform::fleet_edge(), vec![spec]).ideal_link();
            let mut single_platform = Platform::fleet_edge();
            single_platform.device = profile.spec;
            let single =
                bisection::solve(&Problem::new(single_platform, spec.lambda, spec.t0, spec.e0))
                    .expect("single-agent feasible on every tier");
            let alloc = solve_proposed(&fp);
            let d = alloc.agents[0].design.expect("admitted");
            assert_eq!(d.b_hat, single.design.b_hat, "{}", profile.tier);
        }
    }

    #[test]
    fn hetero_margin_over_equal_share_widens_with_tier_spread() {
        // acceptance: at a fully-admitted fleet size the proposed
        // allocator's absolute margin over the equal split is
        // non-decreasing in silicon spread and strictly widens once all
        // three tiers are present (N = 7 seats a phone-class agent)
        let margin = |n: usize, spread: usize| -> (f64, FleetAllocation) {
            let fp = FleetProblem::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(spread)),
            );
            let eq = solve_equal_share(&fp);
            let pr = solve_proposed(&fp);
            assert!(
                pr.objective <= eq.objective + 1e-12,
                "n={n} spread={spread}: proposed above equal"
            );
            (eq.objective - pr.objective, pr)
        };
        for n in [4usize, 6, 7] {
            let (m0, _) = margin(n, 0);
            let (m1, _) = margin(n, 1);
            let (m2, _) = margin(n, 2);
            assert!(m0 <= m1 + 1e-12 && m1 <= m2 + 1e-12, "n={n}: {m0} {m1} {m2}");
        }
        let (m1, _) = margin(7, 1);
        let (m2, alloc) = margin(7, 2);
        assert!(m2 > m1 * 1.5, "3-tier margin {m2} does not widen past 2-tier {m1}");
        assert_eq!(alloc.admitted, 7, "proposed must seat the whole mixed-tier fleet");
    }

    #[test]
    fn prop_interference_pass_converges_or_falls_back_cleanly() {
        // satellite property (seeded sweep): the fixed-point pass either
        // settles on a self-consistent active set — waits bracketed by
        // the mean-field estimates at the fastest and slowest active
        // service — or returns the mean-field vector bit for bit
        forall(
            "fixed-point interference converges or falls back to mean-field",
            120,
            |r| {
                let n = 2 + r.below(6);
                let rps = r.range(0.005, 0.12);
                let fifo = r.f64() < 0.5;
                let raw: Vec<f64> = (0..n).map(|_| r.range(0.02, 1.0)).collect();
                let total: f64 = raw.iter().sum();
                let scale = r.range(0.5, 1.0) / total;
                let mu: Vec<f64> = raw.iter().map(|x| x * scale).collect();
                (n, rps, fifo, mu)
            },
            |(n, rps, fifo, mu)| {
                let discipline = if *fifo {
                    QueueDiscipline::Fifo
                } else {
                    QueueDiscipline::WeightedPriority
                };
                let fp = fleet(*n).with_queue(QueueModel::uniform(discipline, *n, *rps));
                let alpha = MultiAccessChannel::equal_shares(*n);
                let result = fp.interference_waits(mu, &alpha);
                if !result.converged {
                    let mf: Vec<f64> = (0..*n).map(|i| fp.queue_wait(i, mu[i])).collect();
                    return if result.waits == mf {
                        Ok(())
                    } else {
                        Err(format!("unclean fallback: {:?} vs {mf:?}", result.waits))
                    };
                }
                let services: Vec<f64> = mu.iter().map(|&m| fp.own_service(m)).collect();
                let act: Vec<f64> =
                    result.active.iter().map(|&a| if a { 1.0 } else { 0.0 }).collect();
                let active_s: Vec<f64> = services
                    .iter()
                    .zip(&result.active)
                    .filter(|(s, &a)| a && s.is_finite())
                    .map(|(s, _)| *s)
                    .collect();
                let Some((&s_min, &s_max)) = active_s
                    .iter()
                    .min_by(|a, b| a.total_cmp(b))
                    .zip(active_s.iter().max_by(|a, b| a.total_cmp(b)))
                else {
                    return Ok(()); // empty active set: nothing to bracket
                };
                let queue = fp.queue.as_ref().unwrap();
                for i in 0..*n {
                    if !result.active[i] || !services[i].is_finite() {
                        continue;
                    }
                    let mut lo_vec = vec![s_min; *n];
                    lo_vec[i] = services[i];
                    let mut hi_vec = vec![s_max; *n];
                    hi_vec[i] = services[i];
                    let lo = queue.waits_given(&lo_vec, &act, |j| fp.agents[j].weight)[i];
                    let hi = queue.waits_given(&hi_vec, &act, |j| fp.agents[j].weight)[i];
                    if result.waits[i] < lo - 1e-12 {
                        return Err(format!("agent {i}: wait {} below {lo}", result.waits[i]));
                    }
                    if result.waits[i] > hi + 1e-12 && hi.is_finite() {
                        return Err(format!("agent {i}: wait {} above {hi}", result.waits[i]));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn uniform_pricing_is_the_default_and_matches_the_old_penalty() {
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(9, &AgentSpec::tier_mix(2)),
        );
        assert_eq!(fp.pricing, AdmissionPricing::Uniform);
        for (i, spec) in fp.agents.iter().enumerate() {
            // the pre-tier silicon-blind formula, regardless of tier
            assert_eq!(fp.rejection_cost(i), spec.weight * 2.0 / spec.lambda);
        }
        // and an explicit Uniform is bit-identical to the default
        let explicit = fp.clone().with_pricing(AdmissionPricing::Uniform);
        let a = solve_proposed(&fp);
        let b = solve_proposed(&explicit);
        assert_eq!(a.objective, b.objective);
        for (x, y) in a.agents.iter().zip(&b.agents) {
            assert_eq!(x.design.map(|d| d.b_hat), y.design.map(|d| d.b_hat));
        }
    }

    #[test]
    fn tiered_pricing_orders_penalties_by_capability() {
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(9, &AgentSpec::tier_mix(2)),
        )
        .with_pricing(AdmissionPricing::Tiered);
        // agents 0..3 orin, 3..6 xavier, 6..9 phone; same class cycle per
        // tier, so same-class penalties order strictly by capability
        for class_ix in 0..3 {
            let orin = fp.rejection_cost(class_ix);
            let xavier = fp.rejection_cost(3 + class_ix);
            let phone = fp.rejection_cost(6 + class_ix);
            assert!(phone < xavier && xavier < orin, "{phone} {xavier} {orin}");
            // orin pays exactly the uniform penalty (capability 1)
            let spec = &fp.agents[class_ix];
            assert_eq!(orin, spec.weight * 2.0 / spec.lambda);
            // and the ratios are the capability ladder itself
            assert!((xavier / orin - 0.35).abs() < 1e-12);
            assert!((phone / orin - 0.125).abs() < 1e-12);
        }
    }

    #[test]
    fn tiered_pricing_trades_phone_coverage_for_orin_throughput() {
        // the operator trade, end to end: on a contended 9-agent 3-tier
        // fleet, uniform pricing squeezes everyone in (phones land at
        // b̂ = 1), while tiered pricing turns the whole phone block away
        // and spends the freed shares on the orin/xavier blocks — every
        // surviving agent's bit-width can only rise, most strictly
        let specs = AgentSpec::tiered_fleet(9, &AgentSpec::tier_mix(2));
        let uniform = solve_proposed(&FleetProblem::new(Platform::fleet_edge(), specs.clone()));
        let tiered = solve_proposed(
            &FleetProblem::new(Platform::fleet_edge(), specs.clone())
                .with_pricing(AdmissionPricing::Tiered),
        );
        assert_eq!(uniform.admitted, 9, "uniform pricing should seat the full fleet");
        for (slot, spec) in uniform.agents.iter().zip(&specs) {
            if spec.device.tier == "phone" {
                assert_eq!(slot.design.map(|d| d.b_hat), Some(1), "phones at the floor");
            }
        }
        assert!(tiered.admitted < uniform.admitted);
        for (slot, spec) in tiered.agents.iter().zip(&specs) {
            if spec.device.tier == "phone" {
                assert!(slot.design.is_none(), "tiered pricing must reject the phone block");
            }
        }
        let mut strictly_up = 0;
        for (u, t) in uniform.agents.iter().zip(&tiered.agents).take(6) {
            let (bu, bt) = (u.design.map_or(0, |d| d.b_hat), t.design.map_or(0, |d| d.b_hat));
            assert!(bt >= bu, "freed shares must not shrink a surviving design: {bt} < {bu}");
            if bt > bu {
                strictly_up += 1;
            }
        }
        assert!(strictly_up >= 4, "only {strictly_up} designs improved");
    }

    #[test]
    fn tiered_pricing_never_worse_than_equal_share_under_same_pricing() {
        // the structural guarantee is pricing-agnostic: proposed and
        // equal-share are scored with the same rejection costs
        for spread in 0..=2 {
            let fp = FleetProblem::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(8, &AgentSpec::tier_mix(spread)),
            )
            .with_pricing(AdmissionPricing::Tiered);
            let equal = solve_equal_share(&fp);
            let proposed = solve_proposed(&fp);
            assert!(
                proposed.objective <= equal.objective + 1e-12,
                "spread={spread}: {} > {}",
                proposed.objective,
                equal.objective
            );
        }
    }

    #[test]
    fn admission_pricing_parse_roundtrip() {
        for p in
            [AdmissionPricing::Uniform, AdmissionPricing::Tiered, AdmissionPricing::Measured]
        {
            assert_eq!(AdmissionPricing::parse(p.name()), Ok(p));
        }
        assert_eq!(AdmissionPricing::parse("capability"), Ok(AdmissionPricing::Tiered));
        assert_eq!(AdmissionPricing::parse("p99"), Ok(AdmissionPricing::Measured));
        let err = AdmissionPricing::parse("free").unwrap_err();
        assert_eq!(err.token, "free");
        assert!(err.choices.contains(&"tiered"));
        assert!(err.to_string().contains("uniform | tiered | measured"));
    }

    #[test]
    fn measured_pricing_without_pressure_is_uniform_bit_for_bit() {
        // no telemetry yet = no opinion: the measured penalty must fall
        // back to the silicon-blind uniform penalty exactly, so flipping
        // a fleet to Measured before its first epoch changes nothing
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(9, &AgentSpec::tier_mix(2)),
        );
        let measured = fp.clone().with_pricing(AdmissionPricing::Measured);
        for i in 0..fp.n() {
            assert_eq!(measured.rejection_cost(i), fp.rejection_cost(i));
        }
        let a = solve_proposed(&fp);
        let b = solve_proposed(&measured);
        assert_eq!(a.objective, b.objective);
        // all-zero explicit pressure is the same thing
        let zeroed = measured.clone().with_pressure(vec![0.0; 9]);
        for i in 0..fp.n() {
            assert_eq!(zeroed.rejection_cost(i), fp.rejection_cost(i));
        }
    }

    #[test]
    fn measured_pricing_interpolates_penalty_down_to_the_floor() {
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(3, &AgentSpec::tier_mix(0)),
        )
        .with_pricing(AdmissionPricing::Measured)
        .with_pressure(vec![0.0, 0.5, 1.0]);
        let base = |i: usize| fp.agents[i].weight * 2.0 / fp.agents[i].lambda;
        assert_eq!(fp.rejection_cost(0), base(0));
        let mid = base(1) * (1.0 - (1.0 - MEASURED_PRESSURE_FLOOR) * 0.5);
        assert!((fp.rejection_cost(1) - mid).abs() < 1e-15);
        assert!((fp.rejection_cost(2) - base(2) * MEASURED_PRESSURE_FLOOR).abs() < 1e-15);
        // monotone: more pressure, cheaper to shed
        assert!(fp.rejection_cost(2) < fp.rejection_cost(1));
        assert!(fp.rejection_cost(1) < fp.rejection_cost(0));
        // pressure on an agent the solver wants to reject lowers the
        // objective relative to the uniform fallback (never raises it)
        let uniform = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(3, &AgentSpec::tier_mix(0)),
        );
        let none: Vec<Option<(f64, f64)>> = vec![None; 3];
        assert!(probe_frozen(&fp, &none) < probe_frozen(&uniform, &none));
    }

    #[test]
    fn probe_frozen_prices_missing_slots_as_rejections() {
        let fp = fleet(5);
        // no previous allocation at all: everyone is priced as rejected
        let none: Vec<Option<(f64, f64)>> = vec![None; 5];
        let all_rejected: f64 = (0..5).map(|i| fp.rejection_cost(i)).sum();
        assert!((probe_frozen(&fp, &none) - all_rejected).abs() < 1e-12);
        // frozen solved shares score no worse than rejecting the fleet,
        // and a warm re-solve from those shares can only improve on the
        // probe (the frozen point itself is a warm-solve candidate)
        let alloc = solve_proposed(&fp);
        let shares: Vec<Option<(f64, f64)>> =
            alloc.agents.iter().map(|a| Some((a.server_share, a.airtime_share))).collect();
        let frozen = probe_frozen(&fp, &shares);
        assert!(frozen <= all_rejected + 1e-12);
        let warm = solve_proposed_warm(&fp, &shares, ProposedOptions::default());
        assert!(warm.objective <= frozen + 1e-12, "{} > {}", warm.objective, frozen);
    }

    #[test]
    fn algorithm_and_placement_parse_roundtrip() {
        for a in FleetAlgorithm::ALL {
            assert_eq!(FleetAlgorithm::parse(a.name()), Ok(a));
        }
        assert_eq!(FleetAlgorithm::parse("waterfill"), Ok(FleetAlgorithm::Proposed));
        let err = FleetAlgorithm::parse("magic").unwrap_err();
        assert_eq!(err.token, "magic");
        assert!(err.choices.contains(&"proposed"));
        for p in PlacementStrategy::ALL {
            assert_eq!(PlacementStrategy::parse(p.name()), Ok(p));
        }
        assert_eq!(PlacementStrategy::parse("nearest"), Ok(PlacementStrategy::NearestServer));
        assert!(PlacementStrategy::parse("teleport").is_err());
    }

    #[test]
    fn invalid_channel_gain_rejected_at_construction() {
        // the analytic path multiplies the shared rate by the gain, so a
        // degenerate gain must fail fast at construction (mirroring the
        // medium's with_gains validation), not warp delay budgets
        for bad in [0.0, -0.5, 1.5, f64::NAN] {
            let mut specs = AgentSpec::mixed_fleet(2);
            specs[1].channel_gain = bad;
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                FleetProblem::new(Platform::fleet_edge(), specs.clone());
            }));
            assert!(res.is_err(), "gain {bad} must be rejected");
        }
    }

    #[test]
    fn warm_start_sound_under_queue_feedback() {
        // the warm re-solve's raw-previous candidate keeps it no worse
        // than the cold solve even when fixed-point scoring disagrees
        // with the exchange's mean-field probes
        for n in [4usize, 6, 7] {
            let fp =
                fleet(n).with_queue(QueueModel::uniform(QueueDiscipline::Fifo, n, 0.05));
            let cold = solve_proposed(&fp);
            let prev: Vec<Option<(f64, f64)>> = cold
                .agents
                .iter()
                .map(|a| Some((a.server_share, a.airtime_share)))
                .collect();
            let warm = solve_proposed_warm(&fp, &prev, ProposedOptions::default());
            assert!(
                warm.objective <= cold.objective + 1e-12,
                "n={n}: warm {} regressed past cold {}",
                warm.objective,
                cold.objective
            );
        }
    }

    #[test]
    fn fleet_of_one_never_beats_single_agent_optimum() {
        // property (satellite): the N = 1 fleet's weighted D^U is bounded
        // below by the unshared single-agent bisection optimum — the
        // shared-medium carve-out can only cost bits, never mint them
        forall(
            "N=1 weighted D^U >= single-agent optimum",
            60,
            |r| (r.range(0.5, 6.0), r.range(0.3, 6.0), r.range(50.0, 1000.0)),
            |&(t0, e0, rate_mbps)| {
                let mut spec = AgentSpec::class_spec(0);
                spec.t0 = t0;
                spec.e0 = e0;
                let single = bisection::solve(&Problem::new(
                    Platform::fleet_edge(),
                    spec.lambda,
                    t0,
                    e0,
                ));
                let single_du = spec.weight
                    * rd::d_upper(single.map_or(0.0, |s| s.design.b_hat as f64 - 1.0), spec.lambda);
                let fp = FleetProblem::new(Platform::fleet_edge(), vec![spec])
                    .with_link(rate_mbps * 1e6, 2e-3);
                let fleet_du = solve_proposed(&fp).weighted_d_upper(&fp);
                if fleet_du >= single_du - 1e-12 {
                    Ok(())
                } else {
                    Err(format!("fleet {fleet_du} < single {single_du}"))
                }
            },
        );
    }

    /// Field-for-field bitwise equality of two allocations (shares,
    /// designs, waits, costs, objective).
    fn assert_bit_identical(a: &FleetAllocation, b: &FleetAllocation) {
        assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "objective");
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.agents.len(), b.agents.len());
        for (i, (x, y)) in a.agents.iter().zip(&b.agents).enumerate() {
            match (x.design, y.design) {
                (Some(dx), Some(dy)) => {
                    assert_eq!(dx.b_hat, dy.b_hat, "agent {i} b_hat");
                    assert_eq!(dx.f.to_bits(), dy.f.to_bits(), "agent {i} f");
                    assert_eq!(dx.f_tilde.to_bits(), dy.f_tilde.to_bits(), "agent {i} f_tilde");
                }
                (None, None) => {}
                (dx, dy) => panic!("agent {i} admission differs: {dx:?} vs {dy:?}"),
            }
            assert_eq!(x.server_share.to_bits(), y.server_share.to_bits(), "agent {i} mu");
            assert_eq!(x.airtime_share.to_bits(), y.airtime_share.to_bits(), "agent {i} alpha");
            assert_eq!(x.link_s.to_bits(), y.link_s.to_bits(), "agent {i} link");
            assert_eq!(x.queue_wait_s.to_bits(), y.queue_wait_s.to_bits(), "agent {i} wait");
            assert_eq!(x.cost.to_bits(), y.cost.to_bits(), "agent {i} cost");
        }
    }

    #[test]
    fn legacy_wrappers_are_bit_identical_to_solve_requests() {
        // satellite regression: every historical free function is a thin
        // wrapper — its output must be bit-identical to the equivalent
        // SolveRequest through FleetProblem::solve
        let fp = fleet(6).with_queue(QueueModel::uniform(QueueDiscipline::Fifo, 6, 0.02));
        assert_bit_identical(
            &solve_equal_share(&fp),
            &fp.solve(&SolveRequest {
                algorithm: FleetAlgorithm::EqualShare,
                ..SolveRequest::default()
            }),
        );
        assert_bit_identical(&solve_proposed(&fp), &fp.solve(&SolveRequest::default()));
        let opts = ProposedOptions { rounds: 2, ..ProposedOptions::default() };
        assert_bit_identical(
            &solve_proposed_with(&fp, opts),
            &fp.solve(&SolveRequest { options: opts, ..SolveRequest::default() }),
        );
        assert_bit_identical(
            &solve(&fp, FleetAlgorithm::FeasibleRandom, 9),
            &fp.solve(&SolveRequest {
                algorithm: FleetAlgorithm::FeasibleRandom,
                seed: 9,
                ..SolveRequest::default()
            }),
        );
        let cold = solve_proposed(&fp);
        let prev: Vec<Option<(f64, f64)>> = cold
            .agents
            .iter()
            .map(|a| Some((a.server_share, a.airtime_share)))
            .collect();
        assert_bit_identical(
            &solve_proposed_warm(&fp, &prev, ProposedOptions::default()),
            &fp.solve(&SolveRequest { warm_start: Some(prev), ..SolveRequest::default() }),
        );
    }

    #[test]
    fn prop_s1_placement_path_matches_single_server_solver_exactly() {
        // satellite property: at S = 1 (default server) the generic
        // placement machinery — sub-problem construction, airtime
        // splitting, share globalization — is the identity, so solving
        // through an explicit Placement::single must be bit-identical to
        // the legacy single-server path for every algorithm
        for n in [1usize, 4, 8] {
            for fp in [
                fleet(n),
                fleet(n).with_queue(QueueModel::uniform(QueueDiscipline::Fifo, n, 0.02)),
            ] {
                for algorithm in FleetAlgorithm::ALL {
                    let req = SolveRequest { algorithm, seed: 5, ..SolveRequest::default() };
                    let direct = fp.solve(&req);
                    let placed = fp.solve_with_placement(&Placement::single(n), &req);
                    assert_bit_identical(&direct, &placed);
                    assert_eq!(direct.placement, placed.placement);
                }
                let cold = solve_proposed(&fp);
                let prev: Vec<Option<(f64, f64)>> = cold
                    .agents
                    .iter()
                    .map(|a| Some((a.server_share, a.airtime_share)))
                    .collect();
                let req = SolveRequest { warm_start: Some(prev), ..SolveRequest::default() };
                assert_bit_identical(
                    &fp.solve(&req),
                    &fp.solve_with_placement(&Placement::single(n), &req),
                );
            }
        }
    }

    #[test]
    fn splitting_across_identical_servers_never_beats_the_pooled_server() {
        // pooling bound (satellite property): s servers at 1/s of the
        // budget with equal-spread placement vs the single pooled box
        // with the same total budget. The split's fleet-global shares
        // are injected into the pooled solve as a warm start, whose raw
        // candidate scores exactly those shares on the pooled fleet, so
        // the pooled objective is structurally ≤ the split one.
        for n in [2usize, 4, 6, 9] {
            for s in [2usize, 3] {
                let pooled = fleet(n);
                let split =
                    fleet(n).with_servers(vec![ServerSpec::scaled(1.0 / s as f64); s]);
                let split_alloc = split.solve(&SolveRequest {
                    placement: PlacementStrategy::EqualSpread,
                    ..SolveRequest::default()
                });
                let prev: Vec<Option<(f64, f64)>> = split_alloc
                    .agents
                    .iter()
                    .map(|a| Some((a.server_share, a.airtime_share)))
                    .collect();
                let pooled_alloc = pooled
                    .solve(&SolveRequest { warm_start: Some(prev), ..SolveRequest::default() });
                assert!(
                    pooled_alloc.objective <= split_alloc.objective + 1e-9,
                    "n={n} s={s}: pooled {} > split {}",
                    pooled_alloc.objective,
                    split_alloc.objective
                );
            }
        }
    }

    #[test]
    fn local_search_strictly_beats_equal_spread_on_a_hot_server_fleet() {
        // two full-budget boxes plus one badly underpowered one (1.2 GHz
        // against a 2.918 Gcycle server stage): round-robin strands the
        // whole background block on the weak box, where even the full
        // budget can't seat all three — local search moves them off it
        let servers =
            vec![ServerSpec::default(), ServerSpec::default(), ServerSpec::scaled(0.12)];
        let fp = fleet(9).with_servers(servers);
        let spread = fp.solve(&SolveRequest {
            placement: PlacementStrategy::EqualSpread,
            ..SolveRequest::default()
        });
        let local = fp.solve(&SolveRequest {
            placement: PlacementStrategy::LocalSearch,
            ..SolveRequest::default()
        });
        assert!(
            local.objective < spread.objective - 1e-9,
            "local-search {} not strictly below equal-spread {}",
            local.objective,
            spread.objective
        );
        assert!(local.admitted >= spread.admitted);
        // the winning placement must not be the round-robin start
        assert_ne!(local.placement, spread.placement);
    }

    #[test]
    fn fleet_spec_hash_is_stable_and_field_sensitive() {
        let h = |fp: &FleetProblem| {
            let mut s = DefaultHasher::new();
            fp.spec.hash(&mut s);
            s.finish()
        };
        let fp = fleet(4);
        assert_eq!(h(&fp), h(&fp.clone()), "hash must be deterministic");
        let mut faded = fp.clone();
        faded.agents[1].channel_gain = 0.7;
        assert_ne!(h(&fp), h(&faded));
        assert_ne!(h(&fp), h(&fp.clone().with_servers(vec![ServerSpec::scaled(0.5)])));
        assert_ne!(h(&fp), h(&fp.clone().with_pricing(AdmissionPricing::Tiered)));
        // measured pressure is spec state too: the daemon's epoch-to-epoch
        // pressure updates must re-fingerprint the fleet
        assert_ne!(h(&fp), h(&fp.clone().with_pressure(vec![0.25, 0.0, 0.0, 0.0])));
        assert_ne!(
            h(&fp.clone().with_pressure(vec![0.25, 0.0, 0.0, 0.0])),
            h(&fp.clone().with_pressure(vec![0.5, 0.0, 0.0, 0.0]))
        );
        assert_ne!(h(&fp), h(&fp.clone().with_link(200e6, 2e-3)));
        assert_ne!(
            h(&fp),
            h(&fp.clone().with_queue(QueueModel::uniform(QueueDiscipline::Fifo, 4, 0.02)))
        );
    }

    #[test]
    fn server_fingerprint_gates_only_affected_servers() {
        // churn's per-server warm-solve gate: touching an agent on one
        // server must change that server's fingerprint and leave the
        // other server's fingerprint alone
        let fp = fleet(6).with_servers(ServerSpec::identical(2));
        let p = Placement::equal_spread(6, 2);
        let before: Vec<u64> = (0..2).map(|k| fp.server_fingerprint(&p, k)).collect();
        let mut changed = fp.clone();
        changed.agents[0].t0 *= 0.9; // agent 0 lives on server 0
        assert_ne!(changed.server_fingerprint(&p, 0), before[0]);
        assert_eq!(changed.server_fingerprint(&p, 1), before[1]);
        // a placement change alone re-fingerprints the servers it touches
        let moved = Placement { assignment: vec![0, 1, 0, 1, 0, 0] };
        assert_ne!(fp.server_fingerprint(&moved, 0), before[0]);
    }

    #[test]
    fn validation_rejects_malformed_server_specs() {
        for bad in [0.0, -1.0, 1.5, f64::NAN] {
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                fleet(3).with_servers(vec![ServerSpec::scaled(bad)]);
            }));
            assert!(res.is_err(), "freq_scale {bad} must be rejected");
        }
        let overcommit = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fleet(3).with_servers(vec![
                ServerSpec { airtime_fraction: Some(0.7), ..ServerSpec::default() },
                ServerSpec { airtime_fraction: Some(0.6), ..ServerSpec::default() },
            ]);
        }));
        assert!(overcommit.is_err(), "overcommitted airtime must be rejected");
        let empty = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            fleet(3).with_servers(Vec::new());
        }));
        assert!(empty.is_err(), "empty server list must be rejected");
    }
    // -- PR 9: structured errors, total-order admission, classed solver --

    #[test]
    fn malformed_placements_are_structured_errors_not_panics() {
        // regression: a partial placement used to reach the "placement
        // covers every agent" expect deep in per-server stitching and
        // take the serving loop down; now every runtime-input
        // malformation surfaces as a FleetError before any solving
        let fp = fleet(4).with_servers(ServerSpec::identical(2));
        let req = SolveRequest::default();
        let short = Placement { assignment: vec![0, 1] };
        assert_eq!(
            fp.try_solve_with_placement(&short, &req).unwrap_err(),
            FleetError::PlacementLength { expected: 4, got: 2 }
        );
        let rogue = Placement { assignment: vec![0, 1, 0, 5] };
        assert_eq!(
            fp.try_solve_with_placement(&rogue, &req).unwrap_err(),
            FleetError::UnknownServer { agent: 3, server: 5, servers: 2 }
        );
        let good = Placement::equal_spread(4, 2);
        assert_eq!(
            fp.try_solve_with_placement_reusing(&good, &req, &[true], &vec![None; 4])
                .unwrap_err(),
            FleetError::DirtyLength { expected: 2, got: 1 }
        );
        assert_eq!(
            fp.try_solve_with_placement_reusing(&good, &req, &[true, true], &[]).unwrap_err(),
            FleetError::ReuseLength { expected: 4, got: 0 }
        );
        let msg = FleetError::PlacementLength { expected: 4, got: 2 }.to_string();
        assert!(msg.contains('4') && msg.contains('2'), "{msg}");
        // a warm start of the wrong length is an error through try_solve
        let warm = SolveRequest { warm_start: Some(vec![None; 3]), ..SolveRequest::default() };
        assert_eq!(
            fp.try_solve(&warm).unwrap_err(),
            FleetError::WarmStartLength { expected: 4, got: 3 }
        );
        // and a valid placement still solves
        assert!(fp.try_solve_with_placement(&good, &req).is_ok());
    }

    #[test]
    fn non_finite_agent_weights_rejected_at_validation() {
        // regression: a NaN weight used to sail through validation and
        // silently scramble admission's partial_cmp sort (NaN compares
        // Equal under unwrap_or, so ordering depended on input order);
        // the sort is now a total order and non-finite weights fail
        // fast at construction
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let mut specs = AgentSpec::mixed_fleet(3);
            specs[1].weight = bad;
            let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                FleetProblem::new(Platform::fleet_edge(), specs.clone());
            }));
            assert!(res.is_err(), "weight {bad} must be rejected");
        }
    }

    /// Tie-heavy triple entry for the selection property: infinities,
    /// NaNs, exact zeros, repeats of earlier draws, and magnitudes down
    /// to the 1e-15 improvement threshold.
    fn tie_heavy(r: &mut crate::util::rng::Rng, pool: &mut Vec<f64>) -> f64 {
        let k = r.f64();
        if k < 0.25 && !pool.is_empty() {
            pool[r.below(pool.len())]
        } else if k < 0.30 {
            f64::INFINITY
        } else if k < 0.35 {
            f64::NEG_INFINITY
        } else if k < 0.40 {
            f64::NAN
        } else if k < 0.45 {
            0.0
        } else {
            let v = r.range(-2.0, 2.0) * 10f64.powi(r.below(19) as i32 - 16);
            pool.push(v);
            v
        }
    }

    #[test]
    fn fast_move_selection_matches_reference_scan() {
        // the O(n) selection must reproduce the historical O(n^2)
        // row-major scan exactly: same donor, same receiver, and the
        // same net down to the bit
        forall(
            "select_move == reference scan",
            4000,
            |r| {
                let n = 2 + r.below(11);
                let mut pool: Vec<f64> = Vec::new();
                (0..n)
                    .map(|_| (0.0, tie_heavy(r, &mut pool), tie_heavy(r, &mut pool)))
                    .collect::<Vec<(f64, f64, f64)>>()
            },
            |cached| {
                let fast = select_move(cached);
                let slow = select_move_reference(cached);
                let same = match (fast, slow) {
                    (None, None) => true,
                    (Some((d1, r1, n1)), Some((d2, r2, n2))) => {
                        d1 == d2 && r1 == r2 && n1.to_bits() == n2.to_bits()
                    }
                    _ => false,
                };
                if same {
                    Ok(())
                } else {
                    Err(format!("fast {fast:?} != reference {slow:?}"))
                }
            },
        );
    }

    #[test]
    fn class_index_groups_identical_agents() {
        // 18 agents cycling 3 QoS classes x 3 tiers: 9 exact classes of
        // multiplicity 2, partition covering the fleet
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(18, &AgentSpec::tier_mix(2)),
        );
        let idx = fp.class_index(Classing::Exact);
        assert_eq!(idx.classes(), 9);
        assert_eq!(idx.count.iter().sum::<usize>(), 18);
        assert!(idx.count.iter().all(|&c| c == 2));
        for (i, &c) in idx.class_of.iter().enumerate() {
            let rep = &fp.agents[idx.rep[c]];
            assert_eq!(rep.device.tier, fp.agents[i].device.tier);
            assert_eq!(rep.class, fp.agents[i].class);
        }
        assert!(fp.class_index(Classing::PerAgent).all_singletons());
        // class hashes agree with the partition: equal hash <=> equal class
        let hashes = fp.agent_class_hashes();
        for i in 0..18 {
            for j in 0..18 {
                assert_eq!(
                    hashes[i] == hashes[j],
                    idx.class_of[i] == idx.class_of[j],
                    "hash/class disagreement at ({i}, {j})"
                );
            }
        }
    }

    #[test]
    fn classed_solver_bit_identical_on_duplicated_fleet() {
        // the tiered fleet repeats 9 distinct (tier, QoS) profiles, so
        // Exact classing collapses hard — and must still reproduce the
        // per-agent solver bit for bit, for both algorithms
        for n in [9usize, 18, 36] {
            let fp = FleetProblem::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(2)),
            );
            let idx = fp.class_index(Classing::Exact);
            assert!(idx.classes() < n, "n={n}: expected collapse, got {} classes", idx.classes());
            for algorithm in [FleetAlgorithm::Proposed, FleetAlgorithm::EqualShare] {
                let direct = fp.solve(&SolveRequest { algorithm, ..SolveRequest::default() });
                let classed = fp.solve(&SolveRequest {
                    algorithm,
                    classing: Classing::Exact,
                    ..SolveRequest::default()
                });
                assert_bit_identical(&direct, &classed);
            }
        }
    }

    #[test]
    fn classed_solver_bit_identical_on_random_duplicated_fleets() {
        // property (tentpole): duplicated-agent fleets across seeds —
        // k distinct jittered contracts, each repeated m times, shuffled
        forall(
            "classed == per-agent on duplicated fleets",
            12,
            |r| {
                let k = 1 + r.below(4);
                let m = 2 + r.below(3);
                let mut specs = Vec::new();
                for c in 0..k {
                    let mut spec = AgentSpec::class_spec(c);
                    spec.t0 *= r.range(0.8, 1.2);
                    spec.e0 *= r.range(0.8, 1.2);
                    spec.weight *= r.range(0.5, 2.0);
                    for _ in 0..m {
                        specs.push(spec);
                    }
                }
                r.shuffle(&mut specs);
                specs
            },
            |specs| {
                let fp = FleetProblem::new(Platform::fleet_edge(), specs.clone());
                assert!(!fp.class_index(Classing::Exact).all_singletons());
                let direct = fp.solve(&SolveRequest::default());
                let classed = fp
                    .solve(&SolveRequest { classing: Classing::Exact, ..SolveRequest::default() });
                assert_bit_identical(&direct, &classed);
                Ok(())
            },
        );
    }

    #[test]
    fn classed_solver_on_all_singleton_fleet_reduces_bit_for_bit() {
        // property (tentpole): when every class is a singleton the
        // classed path must reduce to the per-agent path exactly
        forall(
            "classed == per-agent on singleton fleets",
            10,
            |r| {
                let n = 3 + r.below(5);
                (0..n)
                    .map(|i| {
                        let mut spec = AgentSpec::class_spec(i);
                        spec.t0 *= r.range(0.7, 1.3);
                        spec.weight *= r.range(0.5, 2.0);
                        spec
                    })
                    .collect::<Vec<AgentSpec>>()
            },
            |specs| {
                let fp = FleetProblem::new(Platform::fleet_edge(), specs.clone());
                if !fp.class_index(Classing::Exact).all_singletons() {
                    return Err("jitter failed to separate classes".into());
                }
                let direct = fp.solve(&SolveRequest::default());
                let classed = fp
                    .solve(&SolveRequest { classing: Classing::Exact, ..SolveRequest::default() });
                assert_bit_identical(&direct, &classed);
                Ok(())
            },
        );
    }

    #[test]
    fn classed_solver_bit_identical_under_queue_feedback() {
        // with a queue attached, Exact classing keeps the mean-field
        // probes per-agent (the M/G/1 accumulation is observer-position-
        // dependent) but still collapses the wait-explicit rows — the
        // allocation must stay bit-identical to the per-agent path
        for (n, discipline) in
            [(4usize, QueueDiscipline::Fifo), (6, QueueDiscipline::Fifo), (9, QueueDiscipline::WeightedPriority)]
        {
            let fp = FleetProblem::new(
                Platform::fleet_edge(),
                AgentSpec::tiered_fleet(n, &AgentSpec::tier_mix(2)),
            )
            .with_queue(QueueModel::uniform(discipline, n, 0.05));
            let direct = fp.solve(&SolveRequest::default());
            let classed =
                fp.solve(&SolveRequest { classing: Classing::Exact, ..SolveRequest::default() });
            assert_bit_identical(&direct, &classed);
        }
    }

    #[test]
    fn classed_warm_solve_bit_identical() {
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(12, &AgentSpec::tier_mix(2)),
        );
        let cold = fp.solve(&SolveRequest::default());
        let prev: Vec<Option<(f64, f64)>> =
            cold.agents.iter().map(|a| Some((a.server_share, a.airtime_share))).collect();
        let direct =
            fp.solve(&SolveRequest { warm_start: Some(prev.clone()), ..SolveRequest::default() });
        let classed = fp.solve(&SolveRequest {
            warm_start: Some(prev),
            classing: Classing::Exact,
            ..SolveRequest::default()
        });
        assert_bit_identical(&direct, &classed);
    }

    #[test]
    fn classed_multi_server_pass_through_bit_identical() {
        // the placement search forwards classing into every per-server
        // sub-solve; the outer search is untouched, so the full
        // multi-server allocation stays bit-identical too
        let fp = FleetProblem::new(
            Platform::fleet_edge(),
            AgentSpec::tiered_fleet(12, &AgentSpec::tier_mix(2)),
        )
        .with_servers(ServerSpec::identical(2));
        for placement in [PlacementStrategy::EqualSpread, PlacementStrategy::LocalSearch] {
            let direct = fp.solve(&SolveRequest { placement, ..SolveRequest::default() });
            let classed = fp.solve(&SolveRequest {
                placement,
                classing: Classing::Exact,
                ..SolveRequest::default()
            });
            assert_bit_identical(&direct, &classed);
        }
    }

    #[test]
    fn bucketed_classing_collapses_jittered_gains() {
        // gains differing in the 5th decimal are distinct to Exact but
        // collapse at 3 bucket decimals; the bucketed solve is the
        // documented approximation — finite and admitting agents
        let mut specs = AgentSpec::mixed_fleet(9);
        for (i, spec) in specs.iter_mut().enumerate() {
            spec.channel_gain = 0.9 + (i as f64) * 1e-5;
        }
        let fp = FleetProblem::new(Platform::fleet_edge(), specs);
        assert_eq!(fp.class_index(Classing::Exact).classes(), 9);
        assert_eq!(fp.class_index(Classing::Bucketed { gain_decimals: 3 }).classes(), 3);
        let alloc = fp.solve(&SolveRequest {
            classing: Classing::Bucketed { gain_decimals: 3 },
            ..SolveRequest::default()
        });
        assert!(alloc.objective.is_finite());
        assert!(alloc.admitted > 0);
    }

    #[test]
    fn classing_parse_round_trips() {
        assert_eq!(Classing::parse("per-agent").unwrap(), Classing::PerAgent);
        assert_eq!(Classing::parse("agent").unwrap(), Classing::PerAgent);
        assert_eq!(Classing::parse("exact").unwrap(), Classing::Exact);
        assert_eq!(Classing::parse("classed").unwrap(), Classing::Exact);
        assert_eq!(Classing::parse("bucketed").unwrap(), Classing::Bucketed { gain_decimals: 3 });
        assert!(Classing::parse("fancy").is_err());
        assert_eq!(Classing::default(), Classing::PerAgent);
    }

    // ---- quantization-policy dispatch --------------------------------

    #[test]
    fn default_adaptive_window_is_bit_identical_to_legacy_solve() {
        // Adaptive with the full (1, b_max, no-backoff) window clamps
        // nothing, so the policy-routed solve must reproduce the legacy
        // Static(None) allocation bit for bit — the static-path
        // acceptance gate of the mixed-precision redesign
        use crate::quant::mixed::AdaptConfig;
        for n in [1usize, 4, 6] {
            let legacy = solve_proposed(&fleet(n));
            let mut specs = AgentSpec::mixed_fleet(n);
            for s in &mut specs {
                s.quant = QuantPolicy::Adaptive(AdaptConfig::default());
            }
            let adaptive = solve_proposed(&FleetProblem::new(Platform::fleet_edge(), specs));
            assert_bit_identical(&legacy, &adaptive);
        }
    }

    #[test]
    fn pinned_static_policy_serves_at_its_width_or_not_at_all() {
        // every admitted agent carries exactly the pinned width; a pin
        // above the max feasible width rejects instead of downgrading
        let mut specs = AgentSpec::mixed_fleet(4);
        for s in &mut specs {
            s.quant = QuantPolicy::Static(Some(3));
        }
        let fp = FleetProblem::new(Platform::fleet_edge(), specs);
        let alloc = solve_proposed(&fp);
        assert!(alloc.admitted >= 1, "pinned fleet seated nobody");
        for (i, a) in alloc.agents.iter().enumerate() {
            if let Some(d) = a.design {
                assert_eq!(d.b_hat, 3, "agent {i} served off its pinned width");
            }
        }
        let b_star = fleet(4)
            .agent_design(0, 0.25, 0.25)
            .expect("legacy pick feasible at equal shares")
            .b_hat;
        assert!(b_star < 16, "premise: legacy pick leaves headroom");
        let mut specs = AgentSpec::mixed_fleet(4);
        specs[0].quant = QuantPolicy::Static(Some(b_star + 1));
        let over = FleetProblem::new(Platform::fleet_edge(), specs);
        assert!(
            over.agent_design(0, 0.25, 0.25).is_none(),
            "width above the max feasible must reject, not degrade"
        );
    }

    #[test]
    fn mixed_policy_prices_the_group_decomposed_objective() {
        use crate::quant::mixed::allocate_bits;
        use crate::theory::rate_distortion::RateBoundModel;
        let ba = allocate_bits(&[4.0, 15.0, 60.0], &[1.0, 1.0, 1.0], 6.0, 16, &RateBoundModel)
            .expect("allocator feasible");
        let mut specs = AgentSpec::mixed_fleet(3);
        specs[0].quant = QuantPolicy::Mixed(ba);
        let fp = FleetProblem::new(Platform::fleet_edge(), specs);
        // rejection prices the group-decomposed miss mass Σ w_g / λ_g
        assert_eq!(fp.rejection_cost(0), specs[0].weight * 2.0 * ba.miss_distortion());
        // served: the design certifies feasibility at the pinned average
        // width, the cost is the allocation's own bound-gap total
        let d = fp.agent_design(0, 0.4, 0.4).expect("pinned width feasible");
        assert_eq!(d.b_hat, ba.pinned_bits());
        assert_eq!(fp.design_cost(0, &Some(d)), specs[0].weight * ba.bound_gap_total());
        // mixed pricing at the same average rate never exceeds uniform:
        // the solved objective must reflect that (agent 0's contribution
        // can only shrink vs. its uniform-width twin)
        let sol = solve_proposed(&fp);
        assert!(sol.objective.is_finite());
        assert!(sol.weighted_d_upper(&fp).is_finite());
    }

    #[test]
    fn adaptive_policy_backs_off_under_measured_pressure() {
        use crate::quant::mixed::AdaptConfig;
        let mut specs = AgentSpec::mixed_fleet(1);
        specs[0].quant = QuantPolicy::Adaptive(AdaptConfig {
            min_bits: 1,
            max_bits: 16,
            pressure_backoff: 14.0,
        });
        let calm = FleetProblem::new(Platform::fleet_edge(), specs.clone()).ideal_link();
        let b_calm = calm.agent_design(0, 1.0, 1.0).expect("sole agent feasible").b_hat;
        assert!(b_calm > 2, "premise: unpressured pick has headroom, got {b_calm}");
        let hot = FleetProblem::new(Platform::fleet_edge(), specs)
            .ideal_link()
            .with_pressure(vec![1.0]);
        let b_hot = hot.agent_design(0, 1.0, 1.0).expect("clamped width stays feasible").b_hat;
        assert_eq!(b_hot, 2, "full pressure must clamp to max_bits - backoff");
    }

    #[test]
    #[should_panic(expected = "invalid quant policy")]
    fn fleet_validation_rejects_overwide_pinned_policy() {
        let mut specs = AgentSpec::mixed_fleet(2);
        specs[0].quant = QuantPolicy::Static(Some(17)); // fleet_edge b_max = 16
        FleetProblem::new(Platform::fleet_edge(), specs);
    }
}

