//! Joint multi-agent resource allocation: N embodied agents contending
//! for one edge server and one wireless medium (fleet generalization of
//! the paper's single-pair (P1); cf. "The Larger the Merrier?" and "LLMs
//! over Networks" in PAPERS.md).
//!
//! ## Model
//!
//! Each agent i brings its own device (the paper's agent processor) and a
//! QoS contract (T0_i, E0_i, weight w_i, payload). Two resources are
//! shared:
//!
//! * **server frequency**: the edge server's f̃^max is partitioned into
//!   shares μ_i (Σ μ ≤ 1); agent i's decoder stage may run at
//!   f̃ ≤ μ_i f̃^max — exactly the paper's platform with a scaled server,
//!   so every per-agent subproblem *is* a [`Problem`] instance;
//! * **airtime**: the uplink medium's goodput R is split into shares α_i
//!   (Σ α ≤ 1, [`MultiAccessChannel`]); unlike the single-pair setting —
//!   where the paper excludes the (fast, dedicated) link from the QoS
//!   math — a congested shared medium is first-order, so the fleet
//!   allocator budgets the nominal uplink time against T0_i: the compute
//!   stages get T0_i − t_link(α_i).
//!
//! ## Objective and algorithm
//!
//! Minimize Σ_i w_i · ζ_i where ζ_i is the paper's (P1) objective
//! D^U(b̂_i−1) − D^L(b̂_i−1) for served agents and a rejection penalty
//! 2/λ_i (4× the worst feasible gap, so serving at b̂ = 1 always beats
//! rejecting) for agents the allocator cannot fit. Since both the gap and
//! D^U alone are strictly decreasing in b̂, the same allocation minimizes
//! the fleet-weighted distortion upper bound
//! ([`FleetAllocation::weighted_d_upper`]).
//!
//! The proposed solver alternates **per-agent exact bisection**
//! ([`super::bisection`], the inner (P1) solve at fixed shares) with a
//! **water-filling-style outer exchange** on each shared resource: move a
//! share quantum from the agent whose objective suffers least to the
//! agent whose objective gains most, while any such move improves the
//! weighted sum. Two starting points are improved and the better result
//! kept: the equal split (which guarantees the proposed design never
//! loses to the equal-share baseline) and a greedy **admission** init
//! that seats agents by weight at their minimal feasible shares — the
//! path that serves part of the fleet when the equal split is entirely
//! infeasible.

use super::bisection;
use super::feasible_random;
use super::problem::{Design, Problem};
use crate::system::channel::MultiAccessChannel;
use crate::system::Platform;
use crate::theory::rate_distortion as rd;
use crate::util::rng::Rng;

/// One agent's QoS contract in the fleet.
#[derive(Debug, Clone, Copy)]
pub struct AgentSpec {
    /// QoS class label (matches the coordinator's class names)
    pub class: &'static str,
    /// fitted exponential parameter of this agent's model magnitudes
    pub lambda: f64,
    /// delay budget T0_i [s]
    pub t0: f64,
    /// energy budget E0_i [J]
    pub e0: f64,
    /// fleet weight w_i (relative importance in the objective)
    pub weight: f64,
    /// uplink payload per request [bytes]
    pub payload_bytes: usize,
}

impl AgentSpec {
    /// BLIP-2-2.7b-scale embedding upload: 32 query tokens × d = 2560 f32.
    pub const PAYLOAD_BLIP2: usize = 32 * 2560 * 4;

    /// Heterogeneous fleet used by benches and the CLI: cycles the
    /// coordinator's three QoS classes (fleet SLA bands in the paper's
    /// Fig. 5 budget range, interactive slightly tightened) with weights
    /// expressing their relative priority.
    pub fn mixed_fleet(n: usize) -> Vec<AgentSpec> {
        const CLASSES: [(&str, f64, f64, f64); 3] = [
            ("interactive", 2.40, 2.50, 2.0),
            ("standard", 3.50, 2.00, 1.0),
            ("background", 5.00, 1.00, 0.5),
        ];
        (0..n)
            .map(|i| {
                let (class, t0, e0, weight) = CLASSES[i % CLASSES.len()];
                AgentSpec {
                    class,
                    lambda: 15.0,
                    t0,
                    e0,
                    weight,
                    payload_bytes: Self::PAYLOAD_BLIP2,
                }
            })
            .collect()
    }
}

/// Fleet instance: shared silicon + shared medium + per-agent contracts.
#[derive(Debug, Clone)]
pub struct FleetProblem {
    /// silicon profile: `base.device` is each agent's own processor,
    /// `base.server` is the one shared edge server
    pub base: Platform,
    pub agents: Vec<AgentSpec>,
    /// shared uplink goodput R [bits/s]
    pub link_rate_bps: f64,
    /// per-message MAC latency [s]
    pub link_base_latency_s: f64,
}

impl FleetProblem {
    /// Shared testbed WLAN defaults (400 Mbps, 2 ms).
    pub fn new(base: Platform, agents: Vec<AgentSpec>) -> FleetProblem {
        assert!(!agents.is_empty());
        FleetProblem { base, agents, link_rate_bps: 400e6, link_base_latency_s: 2e-3 }
    }

    pub fn with_link(mut self, rate_bps: f64, base_latency_s: f64) -> FleetProblem {
        self.link_rate_bps = rate_bps;
        self.link_base_latency_s = base_latency_s;
        self
    }

    /// Infinite-rate medium: isolates the shared-server dimension (and
    /// makes the N = 1 fleet reduce *exactly* to the single-agent (P1)).
    pub fn ideal_link(self) -> FleetProblem {
        self.with_link(f64::INFINITY, 0.0)
    }

    pub fn n(&self) -> usize {
        self.agents.len()
    }

    /// The platform agent i sees under server-frequency share μ.
    pub fn agent_platform(&self, mu: f64) -> Platform {
        let mut p = self.base;
        p.server.f_max *= mu.clamp(0.0, 1.0);
        p
    }

    /// Nominal (jitter-free) uplink time at airtime share α — what the
    /// allocator budgets against.
    pub fn link_time(&self, i: usize, alpha: f64) -> f64 {
        MultiAccessChannel::nominal_transmit_s(
            self.link_rate_bps,
            self.link_base_latency_s,
            alpha.clamp(0.0, 1.0),
            self.agents[i].payload_bytes,
        )
    }

    /// Agent i's effective single-agent (P1) instance under shares
    /// (μ, α): the paper's problem on the share-scaled platform with the
    /// uplink time carved out of the delay budget. `None` when the shares
    /// leave no compute budget at all.
    pub fn agent_problem(&self, i: usize, mu: f64, alpha: f64) -> Option<Problem> {
        if mu <= 0.0 {
            return None;
        }
        let spec = &self.agents[i];
        let t0 = spec.t0 - self.link_time(i, alpha);
        if !(t0 > 0.0) {
            return None; // also catches the +inf link time of α = 0
        }
        Some(Problem::new(self.agent_platform(mu), spec.lambda, t0, spec.e0))
    }

    /// Best per-agent design (exact bisection) under shares, or `None`
    /// when the agent is unservable there.
    pub fn agent_design(&self, i: usize, mu: f64, alpha: f64) -> Option<Design> {
        let problem = self.agent_problem(i, mu, alpha)?;
        bisection::solve(&problem).map(|r| r.design)
    }

    /// Rejection penalty: 4× the worst feasible bound gap, so serving an
    /// agent (at any bit-width) always improves the objective.
    pub fn rejection_cost(&self, i: usize) -> f64 {
        self.agents[i].weight * 2.0 / self.agents[i].lambda
    }

    /// The single source of truth for the fleet objective: an agent's
    /// weighted contribution given whatever design it was (not) assigned.
    pub fn design_cost(&self, i: usize, design: &Option<Design>) -> f64 {
        match design {
            Some(d) => {
                self.agents[i].weight
                    * rd::bound_gap(d.b_hat as f64, self.agents[i].lambda)
            }
            None => self.rejection_cost(i),
        }
    }

    /// Weighted per-agent objective contribution at shares (μ, α).
    pub fn agent_cost(&self, i: usize, mu: f64, alpha: f64) -> f64 {
        self.design_cost(i, &self.agent_design(i, mu, alpha))
    }
}

/// One agent's slice of a fleet allocation.
#[derive(Debug, Clone, Copy)]
pub struct AgentAllocation {
    /// `None` = rejected by admission control
    pub design: Option<Design>,
    /// server-frequency share μ_i
    pub server_share: f64,
    /// airtime share α_i
    pub airtime_share: f64,
    /// nominal uplink time at α_i [s]
    pub link_s: f64,
    /// w_i-weighted objective contribution (penalty when rejected)
    pub cost: f64,
}

/// A complete fleet operating point.
#[derive(Debug, Clone)]
pub struct FleetAllocation {
    pub agents: Vec<AgentAllocation>,
    /// Σ_i cost_i — the fleet-weighted (P1) objective
    pub objective: f64,
    pub admitted: usize,
}

impl FleetAllocation {
    /// Fleet-weighted distortion upper bound Σ w_i D^U(b̂_i−1); rejected
    /// agents contribute the zero-rate distortion D^U(0) = 1/λ.
    pub fn weighted_d_upper(&self, fp: &FleetProblem) -> f64 {
        self.agents
            .iter()
            .zip(&fp.agents)
            .map(|(a, spec)| {
                let rate = match &a.design {
                    Some(d) => d.b_hat as f64 - 1.0,
                    None => 0.0,
                };
                spec.weight * rd::d_upper(rate, spec.lambda)
            })
            .sum()
    }

    pub fn server_shares(&self) -> Vec<f64> {
        self.agents.iter().map(|a| a.server_share).collect()
    }

    pub fn airtime_shares(&self) -> Vec<f64> {
        self.agents.iter().map(|a| a.airtime_share).collect()
    }
}

/// Assemble an allocation from per-agent designs produced by `design_of`
/// — shared by the bisection-based [`evaluate`] and the random baseline,
/// so every algorithm scores against the same objective.
fn assemble(
    fp: &FleetProblem,
    mu: &[f64],
    alpha: &[f64],
    mut design_of: impl FnMut(usize) -> Option<Design>,
) -> FleetAllocation {
    assert_eq!(mu.len(), fp.n());
    assert_eq!(alpha.len(), fp.n());
    let agents: Vec<AgentAllocation> = (0..fp.n())
        .map(|i| {
            let design = design_of(i);
            AgentAllocation {
                cost: fp.design_cost(i, &design),
                design,
                server_share: mu[i],
                airtime_share: alpha[i],
                link_s: fp.link_time(i, alpha[i]),
            }
        })
        .collect();
    FleetAllocation {
        objective: agents.iter().map(|a| a.cost).sum(),
        admitted: agents.iter().filter(|a| a.design.is_some()).count(),
        agents,
    }
}

/// Evaluate a share assignment: per-agent exact bisection + costs.
pub fn evaluate(fp: &FleetProblem, mu: &[f64], alpha: &[f64]) -> FleetAllocation {
    assemble(fp, mu, alpha, |i| fp.agent_design(i, mu[i], alpha[i]))
}

/// Which fleet allocator drives a run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FleetAlgorithm {
    /// alternating per-agent bisection + water-filling share exchange
    Proposed,
    /// μ_i = α_i = 1/N, per-agent bisection (the natural baseline)
    EqualShare,
    /// random shares + random feasible per-agent bit-widths
    FeasibleRandom,
}

impl FleetAlgorithm {
    pub const ALL: [FleetAlgorithm; 3] = [
        FleetAlgorithm::Proposed,
        FleetAlgorithm::EqualShare,
        FleetAlgorithm::FeasibleRandom,
    ];

    pub fn name(self) -> &'static str {
        match self {
            FleetAlgorithm::Proposed => "proposed",
            FleetAlgorithm::EqualShare => "equal-share",
            FleetAlgorithm::FeasibleRandom => "feasible-random",
        }
    }

    pub fn parse(s: &str) -> Option<FleetAlgorithm> {
        match s {
            "proposed" | "waterfill" => Some(FleetAlgorithm::Proposed),
            "equal" | "equal-share" => Some(FleetAlgorithm::EqualShare),
            "random" | "feasible-random" => Some(FleetAlgorithm::FeasibleRandom),
            _ => None,
        }
    }
}

/// Outer-loop knobs for [`solve_proposed_with`].
#[derive(Debug, Clone, Copy)]
pub struct ProposedOptions {
    /// alternating (server, airtime) improvement rounds
    pub rounds: usize,
    /// share quantum = 1 / (divisor · N), coarse-to-fine
    pub step_divisors: [f64; 2],
    /// exchange moves allowed per agent per quantum level
    pub moves_per_agent: usize,
}

impl Default for ProposedOptions {
    fn default() -> Self {
        ProposedOptions { rounds: 3, step_divisors: [2.0, 8.0], moves_per_agent: 3 }
    }
}

/// Dispatch on algorithm. `seed` only matters for the random baseline.
pub fn solve(fp: &FleetProblem, algorithm: FleetAlgorithm, seed: u64) -> FleetAllocation {
    match algorithm {
        FleetAlgorithm::Proposed => solve_proposed(fp),
        FleetAlgorithm::EqualShare => solve_equal_share(fp),
        FleetAlgorithm::FeasibleRandom => solve_feasible_random(fp, seed),
    }
}

/// The equal-share baseline.
pub fn solve_equal_share(fp: &FleetProblem) -> FleetAllocation {
    let shares = MultiAccessChannel::equal_shares(fp.n());
    evaluate(fp, &shares, &shares)
}

/// The proposed joint multi-agent design (default options).
pub fn solve_proposed(fp: &FleetProblem) -> FleetAllocation {
    solve_proposed_with(fp, ProposedOptions::default())
}

pub fn solve_proposed_with(fp: &FleetProblem, opts: ProposedOptions) -> FleetAllocation {
    let equal = MultiAccessChannel::equal_shares(fp.n());
    let mut inits = vec![(equal.clone(), equal)];
    if fp.n() > 1 {
        if let Some((mu0, alpha0)) = admission_init(fp) {
            inits.push((mu0, alpha0));
        }
    }
    let mut best: Option<FleetAllocation> = None;
    for (mut mu, mut alpha) in inits {
        improve(fp, &mut mu, &mut alpha, opts);
        let alloc = evaluate(fp, &mu, &alpha);
        if best.as_ref().map_or(true, |b| alloc.objective < b.objective) {
            best = Some(alloc);
        }
    }
    best.expect("at least the equal init was evaluated")
}

/// The feasible-random baseline: Dirichlet(1) shares on both resources
/// and a random feasible bit-width per agent (frequencies by the
/// energy-min oracle, as in [`feasible_random`]).
pub fn solve_feasible_random(fp: &FleetProblem, seed: u64) -> FleetAllocation {
    let mut rng = Rng::new(seed);
    let mut draw_shares = |n: usize| -> Vec<f64> {
        let gammas: Vec<f64> = (0..n).map(|_| rng.exponential(1.0)).collect();
        let total: f64 = gammas.iter().sum();
        gammas.iter().map(|g| g / total.max(1e-300)).collect()
    };
    let mu = draw_shares(fp.n());
    let alpha = draw_shares(fp.n());
    assemble(fp, &mu, &alpha, |i| {
        fp.agent_problem(i, mu[i], alpha[i])
            .and_then(|p| feasible_random::solve(&p, rng.next_u64()))
    })
}

/// Mean objective of the random baseline over `trials` draws (the
/// figure-style aggregate).
pub fn feasible_random_mean(fp: &FleetProblem, trials: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    (0..trials.max(1))
        .map(|_| solve_feasible_random(fp, rng.next_u64()).objective)
        .sum::<f64>()
        / trials.max(1) as f64
}

// ---------------------------------------------------------------------------
// proposed-solver internals
// ---------------------------------------------------------------------------

/// Smallest share s ∈ (0, 1] making `feasible(s)` true (monotone), by
/// bisection; `None` if even s = 1 fails.
fn min_share(feasible: impl Fn(f64) -> bool) -> Option<f64> {
    if !feasible(1.0) {
        return None;
    }
    let (mut lo, mut hi) = (0.0, 1.0);
    for _ in 0..40 {
        let mid = 0.5 * (lo + hi);
        if feasible(mid) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    Some(hi)
}

/// Greedy admission: seat agents in weight order at their minimal
/// feasible shares (server share probed with the full medium, airtime
/// probed with the full server — each resource's true floor), then hand
/// the leftovers out weight-proportionally. Returns `None` when nobody
/// can be seated (the equal init is then the only candidate).
fn admission_init(fp: &FleetProblem) -> Option<(Vec<f64>, Vec<f64>)> {
    let n = fp.n();
    let servable = |i: usize, mu: f64, alpha: f64| -> bool {
        fp.agent_problem(i, mu, alpha)
            .map_or(false, |p| p.plan_frequencies(1.0).is_some())
    };
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        fp.agents[b]
            .weight
            .partial_cmp(&fp.agents[a].weight)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    let mut mu = vec![0.0; n];
    let mut alpha = vec![0.0; n];
    let (mut mu_used, mut alpha_used) = (0.0f64, 0.0f64);
    let mut admitted: Vec<usize> = Vec::new();
    for i in order {
        let need_mu = min_share(|m| servable(i, m, 1.0));
        let need_alpha = min_share(|a| servable(i, 1.0, a));
        if let (Some(m), Some(a)) = (need_mu, need_alpha) {
            if mu_used + m <= 1.0 && alpha_used + a <= 1.0 {
                mu[i] = m;
                alpha[i] = a;
                mu_used += m;
                alpha_used += a;
                admitted.push(i);
            }
        }
    }
    if admitted.is_empty() {
        return None;
    }
    let weight_sum: f64 = admitted.iter().map(|&i| fp.agents[i].weight).sum();
    for &i in &admitted {
        let frac = fp.agents[i].weight / weight_sum;
        mu[i] += (1.0 - mu_used) * frac;
        alpha[i] += (1.0 - alpha_used) * frac;
    }
    Some((mu, alpha))
}

/// Alternating water-filling: improve the server-share vector at fixed
/// airtime, then the airtime vector at fixed server shares, until a full
/// round yields nothing.
fn improve(fp: &FleetProblem, mu: &mut [f64], alpha: &mut [f64], opts: ProposedOptions) {
    let n = fp.n();
    if n < 2 {
        return;
    }
    let max_moves = opts.moves_per_agent * n;
    for _ in 0..opts.rounds {
        let mut gained = 0.0;
        for divisor in opts.step_divisors {
            let step = 1.0 / (divisor * n as f64);
            gained += exchange(mu, step, max_moves, |i, s| fp.agent_cost(i, s, alpha[i]));
            gained += exchange(alpha, step, max_moves, |i, s| fp.agent_cost(i, mu[i], s));
        }
        if gained <= 1e-15 {
            break;
        }
    }
}

/// One resource's greedy pairwise exchange: repeatedly move `step` from
/// the agent whose cost rises least to the agent whose cost falls most,
/// while the net change improves the weighted sum. Cost depends only on
/// the owner's share, so this is exact coordinate descent on a separable
/// objective; per-agent costs are monotone non-increasing in share, which
/// keeps every accepted move a strict improvement.
fn exchange(
    shares: &mut [f64],
    step: f64,
    max_moves: usize,
    cost_at: impl Fn(usize, f64) -> f64,
) -> f64 {
    let n = shares.len();
    if n < 2 {
        return 0.0;
    }
    // cached (current, donate-loss, receive-gain) per agent
    let triple = |i: usize, s: f64| -> (f64, f64, f64) {
        let cur = cost_at(i, s);
        let loss = if s + 1e-12 >= step {
            cost_at(i, (s - step).max(0.0)) - cur
        } else {
            f64::INFINITY // too little left to donate a full quantum
        };
        let gain = cur - cost_at(i, s + step);
        (cur, loss, gain)
    };
    let mut cached: Vec<(f64, f64, f64)> =
        (0..n).map(|i| triple(i, shares[i])).collect();
    let mut total_gain = 0.0;
    for _ in 0..max_moves {
        let mut best: Option<(usize, usize, f64)> = None;
        for d in 0..n {
            let loss = cached[d].1;
            if !loss.is_finite() {
                continue;
            }
            for r in 0..n {
                if r == d {
                    continue;
                }
                let net = cached[r].2 - loss;
                if net > best.map_or(1e-15, |(_, _, b)| b) {
                    best = Some((d, r, net));
                }
            }
        }
        let Some((d, r, net)) = best else { break };
        shares[d] = (shares[d] - step).max(0.0);
        shares[r] += step;
        cached[d] = triple(d, shares[d]);
        cached[r] = triple(r, shares[r]);
        total_gain += net;
    }
    total_gain
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> FleetProblem {
        FleetProblem::new(Platform::fleet_edge(), AgentSpec::mixed_fleet(n))
    }

    #[test]
    fn n1_fleet_reduces_to_single_agent_bisection() {
        // ideal link + sole agent owning both resources == the paper (P1)
        let fp = fleet(1).ideal_link();
        let spec = fp.agents[0];
        let single = bisection::solve(&Problem::new(
            Platform::fleet_edge(),
            spec.lambda,
            spec.t0,
            spec.e0,
        ))
        .expect("single-agent feasible");
        for algorithm in [FleetAlgorithm::Proposed, FleetAlgorithm::EqualShare] {
            let alloc = solve(&fp, algorithm, 0);
            let d = alloc.agents[0].design.expect("fleet of one admitted");
            assert_eq!(d.b_hat, single.design.b_hat, "{algorithm:?}");
            assert!((d.f - single.design.f).abs() / single.design.f < 1e-9);
            assert!(
                (d.f_tilde - single.design.f_tilde).abs() / single.design.f_tilde
                    < 1e-9
            );
            assert_eq!(alloc.admitted, 1);
        }
    }

    #[test]
    fn proposed_never_worse_than_equal_share() {
        // structural guarantee (improvement starts at the equal split), so
        // it must hold on any base platform, contended or not
        for n in [2usize, 3, 4, 8] {
            for fp in [
                fleet(n),
                fleet(n).ideal_link(),
                FleetProblem::new(Platform::paper_blip2(), AgentSpec::mixed_fleet(n)),
            ] {
                let equal = solve_equal_share(&fp);
                let proposed = solve_proposed(&fp);
                assert!(
                    proposed.objective <= equal.objective + 1e-12,
                    "n={n}: proposed {} > equal {}",
                    proposed.objective,
                    equal.objective
                );
            }
        }
    }

    #[test]
    fn proposed_strictly_beats_equal_share_on_contended_fleet() {
        // at N >= 4 the shared 10 GHz server binds: interactive agents are
        // starved under the equal split while background agents sit on
        // slack — the exchange must exploit it
        for n in [4usize, 8] {
            let fp = fleet(n);
            let equal = solve_equal_share(&fp);
            let proposed = solve_proposed(&fp);
            assert!(
                proposed.objective < equal.objective * 0.99,
                "n={n}: proposed {} not clearly below equal {}",
                proposed.objective,
                equal.objective
            );
            let wu_p = proposed.weighted_d_upper(&fp);
            let wu_e = equal.weighted_d_upper(&fp);
            assert!(
                wu_p <= wu_e + 1e-12,
                "n={n}: weighted D^U {wu_p} > equal {wu_e}"
            );
        }
    }

    #[test]
    fn admission_control_serves_part_of_an_infeasible_fleet() {
        // 32 agents on one paper server: the equal split gives everyone
        // f̃ = 0.3125 GHz, far below any budget — the proposed allocator
        // must concentrate shares and admit a subset instead
        let n = 32;
        let fp = fleet(n);
        let equal = solve_equal_share(&fp);
        assert_eq!(equal.admitted, 0, "equal split should be fully infeasible");
        let proposed = solve_proposed(&fp);
        assert!(proposed.admitted >= 1, "admission control seated nobody");
        assert!(proposed.objective < equal.objective - 1e-9);
    }

    #[test]
    fn allocations_keep_shares_valid() {
        for n in [1usize, 4, 9] {
            let fp = fleet(n);
            for algorithm in FleetAlgorithm::ALL {
                let alloc = solve(&fp, algorithm, 7);
                for res in [alloc.server_shares(), alloc.airtime_shares()] {
                    assert!(res.iter().all(|&s| (0.0..=1.0 + 1e-9).contains(&s)));
                    let total: f64 = res.iter().sum();
                    assert!(total <= 1.0 + 1e-9, "{algorithm:?} n={n}: {total}");
                }
            }
        }
    }

    #[test]
    fn admitted_designs_are_feasible_for_their_subproblem() {
        let fp = fleet(6);
        let alloc = solve_proposed(&fp);
        for (i, a) in alloc.agents.iter().enumerate() {
            if let Some(d) = &a.design {
                let p = fp
                    .agent_problem(i, a.server_share, a.airtime_share)
                    .expect("admitted agent has a subproblem");
                assert!(p.is_feasible(d), "agent {i}: {d:?}");
            }
        }
    }

    #[test]
    fn random_baseline_never_beats_proposed() {
        let fp = fleet(6);
        let proposed = solve_proposed(&fp).objective;
        let mean = feasible_random_mean(&fp, 20, 11);
        assert!(mean >= proposed - 1e-12, "random mean {mean} < proposed {proposed}");
    }

    #[test]
    fn deterministic_given_inputs() {
        let fp = fleet(5);
        let a = solve_proposed(&fp);
        let b = solve_proposed(&fp);
        assert_eq!(a.admitted, b.admitted);
        assert_eq!(a.objective, b.objective);
        for (x, y) in a.agents.iter().zip(&b.agents) {
            assert_eq!(
                x.design.map(|d| d.b_hat),
                y.design.map(|d| d.b_hat)
            );
        }
        let r1 = solve_feasible_random(&fp, 3).objective;
        let r2 = solve_feasible_random(&fp, 3).objective;
        assert_eq!(r1, r2);
    }
}
