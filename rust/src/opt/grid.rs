//! Exhaustive grid search over (b̂, f, f̃) — the brute-force oracle used to
//! validate the analytic planners and SCA on small instances, and as an
//! ablation point in the benches (how much do we lose to gridding?).

use super::problem::{Design, Problem};

/// Best feasible design on a `freq_points`² × B grid: minimal objective,
/// energy as tie-break.
pub fn solve(problem: &Problem, freq_points: usize) -> Option<Design> {
    let p = &problem.platform;
    let mut best: Option<(f64, f64, Design)> = None;
    for b_hat in 1..=p.b_max {
        let obj = problem.objective(b_hat as f64);
        if let Some((bo, be, _)) = best {
            if obj > bo || (obj == bo && be == 0.0) {
                // objective only improves with b̂; still scan for energy
                // tie-breaks at equal objective (can't happen: strictly
                // monotone) — so once worse, done with pruning
            }
        }
        for i in 1..=freq_points {
            let f = p.device.f_max * i as f64 / freq_points as f64;
            for j in 1..=freq_points {
                let f_tilde = p.server.f_max * j as f64 / freq_points as f64;
                let d = Design { b_hat, f, f_tilde };
                if problem.total_delay(&d) <= problem.t0
                    && problem.total_energy(&d) <= problem.e0
                {
                    let e = problem.total_energy(&d);
                    let better = match &best {
                        None => true,
                        Some((bo, be, _)) => obj < *bo || (obj == *bo && e < *be),
                    };
                    if better {
                        best = Some((obj, e, d));
                    }
                }
            }
        }
    }
    best.map(|(_, _, d)| d)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::bisection;
    use crate::system::Platform;
    use crate::util::prop::forall;

    #[test]
    fn grid_agrees_with_analytic_solver() {
        forall(
            "grid b̂ == bisection b̂",
            30,
            |r| (r.range(0.8, 5.0), r.range(0.4, 5.0)),
            |&(t0, e0)| {
                let prob = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
                let g = solve(&prob, 96);
                let a = bisection::solve(&prob);
                match (g, a) {
                    (None, None) => Ok(()),
                    // the grid is a restriction of the feasible set: it can
                    // never beat the exact solver, and finite frequency
                    // resolution can cost a few bits when the feasible
                    // frequency sliver is narrow
                    (Some(gd), Some(ad))
                        if gd.b_hat <= ad.design.b_hat
                            && ad.design.b_hat - gd.b_hat <= 3 =>
                    {
                        Ok(())
                    }
                    // knife-edge budgets: a coarse grid can miss a feasible
                    // sliver the analytic oracle finds — acceptable, but the
                    // reverse (grid feasible, exact not) is a real bug
                    (None, Some(_)) => Ok(()),
                    (Some(gd), None) => {
                        Err(format!("grid found {gd:?} but exact says infeasible"))
                    }
                    (got, want) => Err(format!("grid {got:?} vs exact {want:?}")),
                }
            },
        );
    }

    #[test]
    fn grid_solution_is_feasible() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0);
        let d = solve(&prob, 48).unwrap();
        assert!(prob.is_feasible(&d));
    }
}
