//! Joint quantization bit-width + computation frequency design (paper §V).
//!
//! * [`problem`] — Problem (P1) and the analytic per-bitwidth feasibility
//!   oracle (minimum-energy frequency split under a delay budget).
//! * [`sca`] — the paper's Algorithm 1: continuous relaxation + successive
//!   convex approximation over subproblems (P4.k), then rounding.
//! * [`convex`] — log-barrier solver for the (P4.k) subproblems (the CVX
//!   stand-in).
//! * [`bisection`] — exact reference solver: the objective is monotone
//!   decreasing in b̂, so the optimum is the largest feasible bit-width;
//!   feasibility per b̂ is an analytic 2-D convex problem.
//! * [`fixed_freq`], [`feasible_random`] — the paper's benchmark schemes 2
//!   and 3; [`grid`] — exhaustive oracle for tests.
//! * [`fleet`] — the multi-agent generalization: N agents, each on its
//!   own silicon tier ([`crate::system::DeviceProfile`]) with its own
//!   channel gain, contending for one edge server (server-frequency
//!   shares) and one wireless medium (airtime shares), solved by
//!   alternating per-agent bisection with a water-filling outer loop
//!   plus admission control — priced uniformly or by silicon capability
//!   ([`fleet::AdmissionPricing`]). Optionally queue-aware (the shared
//!   edge queue's expected wait tightens each delay budget — mean-field
//!   probes, fixed-point scoring) and re-runnable online via
//!   [`fleet::solve_proposed_warm`] when the population churns.

pub mod bisection;
pub mod convex;
pub mod feasible_random;
pub mod fixed_freq;
pub mod fleet;
pub mod grid;
pub mod problem;
pub mod sca;

pub use fleet::{FleetAllocation, FleetAlgorithm, FleetProblem};
pub use problem::{Design, Problem};
