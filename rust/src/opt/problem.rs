//! Problem (P1): minimize the rate–distortion bound gap
//! D^U(b̂-1) - D^L(b̂-1) subject to delay, energy, bit-width and frequency
//! constraints (paper §V-A).

use crate::system::{delay, energy, Platform};
use crate::theory::rate_distortion as rd;

/// A complete operating point: the decision variables of (P1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Design {
    pub b_hat: u32,
    /// device frequency [Hz]
    pub f: f64,
    /// server frequency [Hz]
    pub f_tilde: f64,
}

/// Instance of (P1).
#[derive(Debug, Clone, Copy)]
pub struct Problem {
    pub platform: Platform,
    /// fitted exponential parameter of the agent model's magnitudes
    pub lambda: f64,
    /// delay budget T0 [s]  (constraint 30a)
    pub t0: f64,
    /// energy budget E0 [J]  (constraint 30b)
    pub e0: f64,
}

/// Result of the per-bitwidth feasibility oracle.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FreqPlan {
    pub f: f64,
    pub f_tilde: f64,
    pub delay: f64,
    pub energy: f64,
}

impl Problem {
    pub fn new(platform: Platform, lambda: f64, t0: f64, e0: f64) -> Problem {
        assert!(lambda > 0.0 && t0 > 0.0 && e0 > 0.0);
        Problem { platform, lambda, t0, e0 }
    }

    /// The (P1) objective at bit-width b̂.
    pub fn objective(&self, b_hat: f64) -> f64 {
        rd::bound_gap(b_hat, self.lambda)
    }

    pub fn total_delay(&self, d: &Design) -> f64 {
        delay::total_delay(&self.platform, d.b_hat as f64, d.f, d.f_tilde)
    }

    pub fn total_energy(&self, d: &Design) -> f64 {
        energy::total_energy(&self.platform, d.b_hat as f64, d.f, d.f_tilde)
    }

    /// All (P1) constraints, with a small relative tolerance for designs
    /// produced by numerical solvers.
    pub fn is_feasible(&self, d: &Design) -> bool {
        const TOL: f64 = 1.0 + 1e-6;
        d.b_hat >= 1
            && d.b_hat <= self.platform.b_max
            && d.f > 0.0
            && d.f <= self.platform.device.f_max * TOL
            && d.f_tilde > 0.0
            && d.f_tilde <= self.platform.server.f_max * TOL
            && self.total_delay(d) <= self.t0 * TOL
            && self.total_energy(d) <= self.e0 * TOL
    }

    /// Analytic feasibility oracle for a (possibly fractional) bit-width:
    /// find the **minimum-energy** frequency pair meeting the delay budget.
    ///
    /// With stage delays t1 + t2 = T0 and e_i = k_i / t_i², the
    /// unconstrained optimum splits t1/t2 = (k1/k2)^(1/3); the split is
    /// then clamped to the box [C_i/f_i^max, ·]. Returns `None` when even
    /// max frequencies miss T0 or the min energy exceeds E0.
    pub fn plan_frequencies(&self, b_tilde: f64) -> Option<FreqPlan> {
        let p = &self.platform;
        let c1 = p.agent_cycles(b_tilde);
        let c2 = p.server_cycles();
        let t1_min = c1 / p.device.f_max;
        let t2_min = c2 / p.server.f_max;
        if t1_min + t2_min > self.t0 {
            return None; // delay-infeasible even at max frequencies
        }
        let k1 = p.device.pue * p.device.psi * c1 * c1 * c1;
        let k2 = p.server.pue * p.server.psi * c2 * c2 * c2;
        // unconstrained energy-optimal split of the delay budget
        let ratio = (k1 / k2).powf(1.0 / 3.0); // = t1/t2 at optimum
        let mut t1 = self.t0 * ratio / (1.0 + ratio);
        // clamp to the feasible interval [t1_min, T0 - t2_min]; the bounds
        // can cross by an ulp when the budget is exactly tight
        let t1_hi = (self.t0 - t2_min).max(t1_min);
        t1 = t1.max(t1_min).min(t1_hi);
        let t2 = self.t0 - t1;
        let f = c1 / t1;
        let f_tilde = c2 / t2;
        let e = energy::total_energy(p, b_tilde, f, f_tilde);
        if e > self.e0 * (1.0 + 1e-9) {
            return None; // energy-infeasible at the energy-min point
        }
        Some(FreqPlan { f, f_tilde, delay: t1 + t2, energy: e })
    }

    /// Testbed-mode planner: the device frequency is **pinned** to a DVFS
    /// profile (it cannot be lowered below the profile point, unlike the
    /// continuous case), so device delay/energy are fixed per b̂ and only
    /// the server frequency is optimized. Returns the largest feasible
    /// bit-width's design. This is what makes the Table-I phenomenon
    /// appear: at a pinned high profile the device energy ηψC1f² grows
    /// with b̂ and bites the energy budget.
    pub fn plan_pinned_device(&self, f_dev: f64) -> Option<Design> {
        let p = &self.platform;
        let c2 = p.server_cycles();
        let t2_min = c2 / p.server.f_max;
        let k2 = p.server.pue * p.server.psi * c2 * c2 * c2;
        for b_hat in (1..=p.b_max).rev() {
            let c1 = p.agent_cycles(b_hat as f64);
            let t1 = c1 / f_dev;
            let e1 = p.device.pue * p.device.psi * c1 * f_dev * f_dev;
            if t1 > self.t0 || e1 > self.e0 {
                continue;
            }
            let t2_max = self.t0 - t1;
            if t2_min > t2_max {
                continue;
            }
            // server runs as slow as the remaining delay budget allows
            // (minimum energy); cap the resulting stretch at a sane floor
            let e2 = k2 / (t2_max * t2_max);
            if e1 + e2 > self.e0 {
                continue;
            }
            return Some(Design { b_hat, f: f_dev, f_tilde: c2 / t2_max });
        }
        None
    }

    /// Integer-bitwidth convenience wrapper producing a full Design.
    pub fn plan_design(&self, b_hat: u32) -> Option<Design> {
        if b_hat < 1 || b_hat > self.platform.b_max {
            return None;
        }
        self.plan_frequencies(b_hat as f64).map(|plan| Design {
            b_hat,
            f: plan.f,
            f_tilde: plan.f_tilde,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn problem() -> Problem {
        Problem::new(Platform::paper_blip2(), 15.0, 3.5, 2.0)
    }

    #[test]
    fn objective_decreases_in_bits() {
        let p = problem();
        for b in 2..=15 {
            assert!(p.objective(b as f64 + 1.0) < p.objective(b as f64));
        }
    }

    #[test]
    fn planned_designs_are_feasible() {
        forall(
            "plan_frequencies output satisfies (P1)",
            200,
            |r| (r.range(1.0, 16.0), r.range(0.5, 6.0), r.range(0.2, 8.0)),
            |&(b, t0, e0)| {
                let prob = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
                match prob.plan_design(b as u32) {
                    None => Ok(()), // infeasible is a valid answer
                    Some(d) => {
                        if prob.is_feasible(&d) {
                            Ok(())
                        } else {
                            Err(format!(
                                "plan violated: T={} (T0={t0}) E={} (E0={e0}) d={d:?}",
                                prob.total_delay(&d),
                                prob.total_energy(&d)
                            ))
                        }
                    }
                }
            },
        );
    }

    #[test]
    fn feasibility_is_monotone_in_bits() {
        // higher b̂ can only shrink the feasible set (Remark 4.1 coupling)
        forall(
            "feasible(b̂+1) => feasible(b̂)",
            150,
            |r| (1 + r.below(15) as u32, r.range(0.5, 5.0), r.range(0.2, 6.0)),
            |&(b, t0, e0)| {
                let prob = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
                if prob.plan_frequencies((b + 1) as f64).is_some()
                    && prob.plan_frequencies(b as f64).is_none()
                {
                    Err("monotonicity violated".into())
                } else {
                    Ok(())
                }
            },
        );
    }

    #[test]
    fn plan_is_energy_minimal_among_delay_feasible() {
        // sample random feasible frequency pairs at the same b̂; none may
        // beat the oracle's energy while meeting the delay budget
        let prob = problem();
        let b = 6u32;
        let plan = prob.plan_frequencies(b as f64).unwrap();
        let mut rng = Rng::new(3);
        for _ in 0..2000 {
            let f = rng.range(1e8, prob.platform.device.f_max);
            let ft = rng.range(1e8, prob.platform.server.f_max);
            let d = Design { b_hat: b, f, f_tilde: ft };
            if prob.total_delay(&d) <= prob.t0 {
                assert!(
                    prob.total_energy(&d) >= plan.energy * (1.0 - 1e-9),
                    "found cheaper feasible point: {d:?}"
                );
            }
        }
    }

    #[test]
    fn loose_budgets_make_everything_feasible() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 100.0, 100.0);
        for b in 1..=16 {
            assert!(prob.plan_design(b).is_some(), "b̂={b}");
        }
    }

    #[test]
    fn impossible_budgets_are_infeasible() {
        let prob = Problem::new(Platform::paper_blip2(), 15.0, 1e-6, 1e-9);
        assert!(prob.plan_design(1).is_none());
    }
}
