//! Algorithm 1: SCA-based solution of (P1) (paper §V-B).
//!
//! Faithful implementation of the paper's pipeline:
//! 1. relax the integer bit-width b̂ to b̃ ∈ (1, B_max];
//! 2. introduce the auxiliary b̃' (≈ 1/b̃) to convexify (31a)/(31b) into
//!    (32a)/(32b);
//! 3. iteratively solve the convex subproblem (P4.k) built from the
//!    first-order surrogates (33)–(35) around the previous iterate;
//! 4. stop when the objective decrease falls below a threshold, and round
//!    b̃* to the nearest feasible value in B (re-planning frequencies).

use super::convex::{ConvexProgram, Func};
use super::problem::{Design, Problem};
use crate::theory::rate_distortion as rd;

#[derive(Debug, Clone)]
pub struct ScaResult {
    pub design: Design,
    pub b_tilde_star: f64,
    pub objective: f64,
    /// objective trace across SCA iterations (monotone non-increasing)
    pub trace: Vec<f64>,
}

#[derive(Debug, Clone, Copy)]
pub struct ScaOptions {
    pub max_iters: usize,
    pub tol: f64,
}

impl Default for ScaOptions {
    fn default() -> Self {
        ScaOptions { max_iters: 25, tol: 1e-7 }
    }
}

/// Find a strictly feasible start for the relaxation: plan frequencies
/// against shrunk budgets so every constraint has slack. The shrink factor
/// backs off when budgets are knife-edge tight (where shrinking by 10%
/// would make the inner problem infeasible even though (P1) is not).
fn initial_point(problem: &Problem) -> Option<[f64; 4]> {
    for shrink in [0.90, 0.97, 0.995, 0.9995] {
        let inner = Problem::new(
            problem.platform,
            problem.lambda,
            problem.t0 * shrink,
            problem.e0 * shrink,
        );
        // largest b̃ feasible under the shrunk budgets, then start strictly
        // inside (1, b̃*]
        let Some(r) = super::bisection::solve(&inner) else { continue };
        let b0 = (1.0 + 0.9 * (r.b_tilde_star - 1.0)).max(1.0 + 1e-4);
        if let Some(plan) = inner.plan_frequencies(b0) {
            let f = plan.f.min(problem.platform.device.f_max * 0.999);
            let ft = plan.f_tilde.min(problem.platform.server.f_max * 0.999);
            // b̃' strictly below 1/b̃ keeps surrogate (35) strictly feasible
            return Some([b0, (1.0 / b0) * 0.999, f, ft]);
        }
    }
    None
}

/// Build and solve the convex subproblem (P4.k) around (b_k, bp_k).
fn solve_subproblem(
    problem: &Problem,
    b_k: f64,
    bp_k: f64,
    x0: &[f64; 4],
) -> anyhow::Result<Vec<f64>> {
    let p = problem.platform;
    let lambda = problem.lambda;
    let a1 = p.agent_cycles(1.0); // N/(b c): agent cycles per unit b̂
    let c2 = p.server_cycles();
    let (t0, e0) = (problem.t0, problem.e0);
    let (eta_psi, eta_psi_s) = (p.device.pue * p.device.psi, p.server.pue * p.server.psi);
    let (f_max, fs_max) = (p.device.f_max, p.server.f_max);
    let b_max = p.b_max as f64;

    // x = [b̃, b̃', f, f̃]
    let objective: Func = Box::new(move |x| rd::zeta_bar(x[0], b_k, lambda));
    let constraints: Vec<Func> = vec![
        // (32a) delay with 1/b̃' substitution
        Box::new(move |x| a1 / (x[1] * x[2]) + c2 / x[3] - t0),
        // (32b) energy
        Box::new(move |x| {
            eta_psi * a1 * x[2] * x[2] / x[1] + eta_psi_s * c2 * x[3] * x[3] - e0
        }),
        // (35) linearized coupling b̃ <= 1/b̃'
        Box::new(move |x| {
            x[0] - 1.0 / bp_k + (x[1] - bp_k) / (bp_k * bp_k)
        }),
        // (31c) 1 < b̃ <= B_max
        Box::new(move |x| 1.0 - x[0]),
        Box::new(move |x| x[0] - b_max),
        // (30d)/(30e) frequency boxes, (32d) b̃' > 0
        Box::new(move |x| -x[2]),
        Box::new(move |x| x[2] - f_max),
        Box::new(move |x| -x[3]),
        Box::new(move |x| x[3] - fs_max),
        Box::new(move |x| -x[1]),
    ];
    let prog = ConvexProgram {
        objective,
        constraints,
        scales: vec![1.0, 0.2, f_max, fs_max],
    };
    Ok(prog.solve(x0)?.x)
}

/// Algorithm 1 with multi-start: SCA is a local method and can stall a
/// couple of bits short when the feasible region is knife-edge; restarting
/// from a few spread-out initial bit-widths and keeping the best final
/// objective recovers the global optimum in practice (validated against
/// the exact solver in tests).
pub fn solve(problem: &Problem, opts: ScaOptions) -> Option<ScaResult> {
    let base = initial_point(problem)?;
    let mut candidates = vec![base];
    // extra starts: nudge the initial relaxed bit-width up/down, keeping
    // the (strictly feasible) frequency plan of the base start when the
    // nudged b̃ still fits it
    for factor in [0.5, 1.5] {
        let b0 = (1.0 + (base[0] - 1.0) * factor).clamp(1.0 + 1e-4, problem.platform.b_max as f64);
        let inner =
            Problem::new(problem.platform, problem.lambda, problem.t0 * 0.97, problem.e0 * 0.97);
        if let Some(plan) = inner.plan_frequencies(b0) {
            let f = plan.f.min(problem.platform.device.f_max * 0.999);
            let ft = plan.f_tilde.min(problem.platform.server.f_max * 0.999);
            candidates.push([b0, (1.0 / b0) * 0.999, f, ft]);
        }
    }
    let mut best: Option<ScaResult> = None;
    for x0 in candidates {
        if let Some(r) = solve_from(problem, x0, opts) {
            let better = match &best {
                None => true,
                Some(b) => r.objective < b.objective,
            };
            if better {
                best = Some(r);
            }
        }
    }
    best
}

fn solve_from(problem: &Problem, x0: [f64; 4], opts: ScaOptions) -> Option<ScaResult> {
    let mut x = x0;
    let mut trace = vec![problem.objective(x[0])];
    for _ in 0..opts.max_iters {
        let (b_k, bp_k) = (x[0], x[1]);
        let sol = match solve_subproblem(problem, b_k, bp_k, &x) {
            Ok(s) => s,
            Err(_) => break, // numerical feasibility exhausted: keep x
        };
        // step 6: update the local point. Pull the iterate strictly inside
        // the surrogate region for the next linearization.
        x = [sol[0], sol[1].min((1.0 / sol[0]) * 0.9999), sol[2], sol[3]];
        let obj = problem.objective(x[0]);
        let decrease = trace.last().unwrap() - obj;
        trace.push(obj);
        if decrease.abs() < opts.tol {
            break;
        }
    }
    let b_tilde_star = x[0];
    // step 9: round to the nearest feasible value in B
    let mut b_hat = (b_tilde_star.round() as u32)
        .clamp(1, problem.platform.b_max);
    loop {
        if let Some(design) = problem.plan_design(b_hat) {
            return Some(ScaResult {
                objective: problem.objective(b_hat as f64),
                design,
                b_tilde_star,
                trace,
            });
        }
        if b_hat == 1 {
            return None;
        }
        b_hat -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::bisection;
    use crate::system::Platform;
    use crate::util::prop::forall;

    fn problem(t0: f64, e0: f64) -> Problem {
        Problem::new(Platform::paper_blip2(), 15.0, t0, e0)
    }

    #[test]
    fn objective_trace_is_monotone_nonincreasing() {
        let r = solve(&problem(3.5, 2.0), ScaOptions::default()).unwrap();
        for w in r.trace.windows(2) {
            assert!(w[1] <= w[0] + 1e-9, "trace not monotone: {:?}", r.trace);
        }
    }

    #[test]
    fn sca_exact_at_knife_edge_budgets() {
        // regression guard: these points once lost 2 bits to premature
        // inner-loop truncation in the barrier solver
        for (t0, e0) in [(2.0, 2.0), (2.1, 2.0), (3.5, 0.65)] {
            let prob = problem(t0, e0);
            let exact = bisection::solve(&prob).unwrap();
            let sca = solve(&prob, ScaOptions::default()).unwrap();
            assert!(
                (exact.design.b_hat as i64 - sca.design.b_hat as i64).abs() <= 1,
                "({t0},{e0}): exact {} vs sca {}",
                exact.design.b_hat,
                sca.design.b_hat
            );
        }
    }

    #[test]
    fn sca_matches_exact_solver() {
        forall(
            "SCA == bisection optimum (±1 bit rounding)",
            25,
            |r| (r.range(0.8, 5.0), r.range(0.3, 5.0)),
            |&(t0, e0)| {
                let prob = problem(t0, e0);
                let exact = bisection::solve(&prob);
                let sca = solve(&prob, ScaOptions::default());
                match (exact, sca) {
                    (None, None) => Ok(()),
                    (Some(e), Some(s)) => {
                        // SCA is a local method + rounding: allow 1 bit slack
                        let diff = (e.design.b_hat as i64 - s.design.b_hat as i64).abs();
                        if diff <= 1 {
                            Ok(())
                        } else {
                            Err(format!(
                                "exact b̂={} sca b̂={} (b̃*={:.3})",
                                e.design.b_hat, s.design.b_hat, s.b_tilde_star
                            ))
                        }
                    }
                    (e, s) => Err(format!("feasibility mismatch: {e:?} vs {s:?}")),
                }
            },
        );
    }

    #[test]
    fn returned_design_is_feasible() {
        forall(
            "SCA design feasible",
            25,
            |r| (r.range(0.5, 5.0), r.range(0.2, 5.0)),
            |&(t0, e0)| {
                let prob = problem(t0, e0);
                match solve(&prob, ScaOptions::default()) {
                    None => Ok(()),
                    Some(r) => {
                        if prob.is_feasible(&r.design) {
                            Ok(())
                        } else {
                            Err(format!("infeasible design {:?}", r.design))
                        }
                    }
                }
            },
        );
    }

    #[test]
    fn infeasible_problem_returns_none() {
        assert!(solve(&problem(1e-9, 1e-12), ScaOptions::default()).is_none());
    }
}
