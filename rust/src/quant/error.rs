//! Quantization distortion measurement (the paper's eq. 15 metric) plus
//! closed-ish-form expected distortions used to sanity-check the
//! rate–distortion bounds against *actual* quantizer behavior.

use crate::metrics::stats;

/// Total L1 parameter distortion Σ_i |w_i - ŵ_i| (eq. 15).
pub fn total_l1_distortion(orig: &[f32], quant: &[f32]) -> f64 {
    stats::l1_dist(orig, quant)
}

/// Per-parameter mean |w - ŵ| — the "D" that the rate–distortion bounds
/// of §IV speak about (they are per-sample quantities).
pub fn mean_abs_distortion(orig: &[f32], quant: &[f32]) -> f64 {
    assert!(!orig.is_empty());
    total_l1_distortion(orig, quant) / orig.len() as f64
}

/// Expected |Θ - Q(Θ)| for Θ ~ Exp(λ) under uniform quantization with the
/// given step, computed by numerical integration. Used in tests to confirm
/// the analytic bounds sandwich a *real* quantizer (not just the BA
/// optimum).
pub fn expected_uniform_distortion(lambda: f64, step: f64, theta_max: f64) -> f64 {
    if step <= 0.0 {
        return 0.0;
    }
    let n = 200_000;
    let dx = theta_max / n as f64;
    let mut acc = 0.0;
    for i in 0..n {
        let x = (i as f64 + 0.5) * dx;
        let q = ((x / step).round() * step).min(theta_max);
        acc += (x - q).abs() * lambda * (-lambda * x).exp() * dx;
    }
    // tail above theta_max maps to theta_max
    let tail_mass = (-lambda * theta_max).exp();
    acc + tail_mass * (1.0 / lambda) // E[X - θmax | X > θmax] = 1/λ
}

/// [`DistortionModel`](crate::theory::distortion::DistortionModel) over
/// the *measured* uniform quantizer: per group, the numerically
/// integrated E|Θ - Q(Θ)| for Θ ~ Exp(λ_g) on the grid
/// `uniform_step(θ_max_g, b_g)`, weighted by the allocation's w_g. The
/// empirical cross-check of the analytic `RateBoundModel`.
#[derive(Debug, Clone)]
pub struct EmpiricalUniformModel {
    theta_max: Vec<f64>,
}

impl EmpiricalUniformModel {
    /// One θ_max (magnitude clip) per allocation group.
    pub fn new(theta_max: Vec<f64>) -> EmpiricalUniformModel {
        assert!(!theta_max.is_empty() && theta_max.iter().all(|t| *t > 0.0));
        EmpiricalUniformModel { theta_max }
    }
}

impl crate::theory::distortion::DistortionModel for EmpiricalUniformModel {
    fn predict(&self, alloc: &crate::quant::mixed::BitAllocation) -> f64 {
        assert_eq!(alloc.len(), self.theta_max.len(), "allocation/theta_max count mismatch");
        alloc
            .groups()
            .zip(&self.theta_max)
            .map(|((bits, lambda, weight), &tmax)| {
                let step = crate::quant::uniform::uniform_step(tmax as f32, bits) as f64;
                weight * expected_uniform_distortion(lambda, step, tmax)
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::mixed::BitAllocation;
    use crate::quant::{quantize_magnitudes, Scheme};
    use crate::theory::distortion::DistortionModel;
    use crate::theory::rate_distortion::{d_lower, d_upper};
    use crate::util::rng::Rng;

    #[test]
    fn zero_distortion_for_identical() {
        let w = vec![1.0f32, -2.0, 3.0];
        assert_eq!(total_l1_distortion(&w, &w), 0.0);
    }

    /// A real uniform quantizer on exponential data must land within
    /// [D^L, ~scaled D^U]: above the information-theoretic floor always;
    /// near-or-below the test-channel bound at moderate rates.
    #[test]
    fn real_quantizer_respects_shannon_floor() {
        let mut rng = Rng::new(21);
        let lambda = 15.0;
        let w: Vec<f32> = (0..200_000)
            .map(|_| {
                let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                (sign * rng.exponential(lambda)) as f32
            })
            .collect();
        for bits in 3..=8u32 {
            let q = quantize_magnitudes(&w, bits, Scheme::Uniform);
            let d = mean_abs_distortion(&w, &q);
            let rate = (bits - 1) as f64;
            let lo = d_lower(rate, lambda);
            assert!(
                d >= lo * 0.95,
                "bits={bits}: measured {d} below Shannon floor {lo}"
            );
            // a scalar round-to-nearest quantizer is within ~4x of D(R);
            // D^U is itself above D(R), so a loose factor guards the shape
            let hi = d_upper(rate, lambda);
            assert!(
                d <= hi * 4.0,
                "bits={bits}: measured {d} far above upper bound {hi}"
            );
        }
    }

    #[test]
    fn empirical_model_tracks_analytic_bounds_per_group() {
        // the measured-quantizer model stays within the §IV sandwich on
        // every group, so allocating against it agrees with the analytic
        // model to within the bound gap
        let lambdas = [4.0, 15.0, 60.0];
        let theta_max: Vec<f64> = lambdas.iter().map(|l| 8.0 / l).collect();
        let model = EmpiricalUniformModel::new(theta_max);
        for bits in 4..=8u32 {
            let alloc = BitAllocation::new(
                &[bits; 3],
                &lambdas,
                &[1.0, 1.0, 1.0],
            )
            .unwrap();
            let measured = model.predict(&alloc);
            let rate = (bits - 1) as f64;
            let lo: f64 =
                lambdas.iter().map(|l| d_lower(rate, *l) / 3.0).sum();
            let hi: f64 =
                lambdas.iter().map(|l| d_upper(rate, *l) / 3.0).sum();
            assert!(
                measured >= lo * 0.95 && measured <= hi * 4.0,
                "bits {bits}: {measured} outside [{lo}, {hi}]-ish"
            );
        }
    }

    #[test]
    fn numeric_expected_distortion_matches_monte_carlo() {
        let mut rng = Rng::new(5);
        let (lambda, step, theta_max) = (10.0, 0.02, 1.2);
        let n = 400_000;
        let mc: f64 = (0..n)
            .map(|_| {
                let x = rng.exponential(lambda);
                let q = ((x / step).round() * step).min(theta_max);
                (x - q).abs()
            })
            .sum::<f64>()
            / n as f64;
        let ni = expected_uniform_distortion(lambda, step, theta_max);
        assert!((mc - ni).abs() / ni < 0.05, "mc {mc} vs ni {ni}");
    }
}
