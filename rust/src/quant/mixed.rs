//! Mixed-precision bit allocation (ROADMAP item 3; QVLA / DyQ-VLA).
//!
//! The paper's joint design (§IV) picks one static bit-width per agent,
//! but the §III distortion machinery is group-decomposable: channels are
//! not equally sensitive (QVLA), so spending more bits on heavy-tailed
//! channel groups and fewer on sharply-peaked ones strictly lowers the
//! distortion upper bound at the *same average rate*. This module owns
//! that machinery:
//!
//! - [`BitAllocation`] — a per-group bit vector over contiguous channel
//!   groups of a layer stack, each group carrying its fitted Exp(λ_g)
//!   magnitude model and its parameter-mass weight w_g (Σ w_g = 1). The
//!   group-decomposed §IV bounds are exact sums:
//!   D^U(alloc) = Σ_g w_g D^U(b_g - 1, λ_g).
//! - [`allocate_bits`] — greedy marginal-gain water-filling over integer
//!   bits minimizing any [`DistortionModel`] subject to the average-rate
//!   budget Σ w_g b_g <= R̄. The uniform-b̂ allocation is kept as an
//!   explicit candidate, so mixed <= best-static is structural, not
//!   empirical.
//! - [`QuantPolicy`] — the per-agent knob the fleet layer threads through
//!   [`crate::opt::fleet::AgentSpec`]: keep the solver's static pick,
//!   pin a bit-width, pin a mixed allocation, or adapt online
//!   ([`AdaptConfig`]) by re-picking the max-feasible bit-width at every
//!   warm re-solve boundary — the DyQ-VLA move, landing exactly where
//!   `AdmissionPricing::Measured` already re-prices admission from epoch
//!   telemetry.
//!
//! Distortion prediction is behind [`DistortionModel`]
//! (`theory::distortion`), so the allocator runs identically against the
//! analytic rate bound, the empirical uniform-quantizer integral, the
//! eq. 15 surrogate, or the Prop. 3.1 output bound.

use crate::theory::distortion::DistortionModel;
use crate::theory::expdist::ExponentialModel;
use crate::theory::rate_distortion as rd;
use crate::util::cli::ParseError;

/// Fixed capacity of a [`BitAllocation`] (keeps the type `Copy`, like
/// every other spec type the fleet hashes and replays).
pub const MAX_GROUPS: usize = 16;

/// A per-group bit vector over contiguous channel groups, plus each
/// group's fitted exponential magnitude model λ_g and parameter-mass
/// weight w_g. Weights are normalized to sum to 1 at construction, so
/// `avg_bits` is the average rate in bits/parameter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BitAllocation {
    len: usize,
    bits: [u8; MAX_GROUPS],
    lambda: [f64; MAX_GROUPS],
    weight: [f64; MAX_GROUPS],
}

impl BitAllocation {
    /// Build and validate an allocation. Group count must be in
    /// `1..=MAX_GROUPS`, slices equal-length, bits in `1..=32`, every
    /// λ_g finite and positive, every weight finite and positive
    /// (weights are normalized to sum to 1).
    pub fn new(bits: &[u32], lambdas: &[f64], weights: &[f64]) -> Result<BitAllocation, String> {
        let n = bits.len();
        if n == 0 || n > MAX_GROUPS {
            return Err(format!("group count {n} outside 1..={MAX_GROUPS}"));
        }
        if lambdas.len() != n || weights.len() != n {
            return Err(format!(
                "mismatched group slices: {} bits, {} lambdas, {} weights",
                n,
                lambdas.len(),
                weights.len()
            ));
        }
        let mut alloc = BitAllocation {
            len: n,
            bits: [0; MAX_GROUPS],
            lambda: [0.0; MAX_GROUPS],
            weight: [0.0; MAX_GROUPS],
        };
        let mut wsum = 0.0;
        for g in 0..n {
            if !(1..=32).contains(&bits[g]) {
                return Err(format!("group {g}: bit-width {} outside 1..=32", bits[g]));
            }
            if !(lambdas[g].is_finite() && lambdas[g] > 0.0) {
                return Err(format!("group {g}: lambda {} not finite positive", lambdas[g]));
            }
            if !(weights[g].is_finite() && weights[g] > 0.0) {
                return Err(format!("group {g}: weight {} not finite positive", weights[g]));
            }
            alloc.bits[g] = bits[g] as u8;
            alloc.lambda[g] = lambdas[g];
            alloc.weight[g] = weights[g];
            wsum += weights[g];
        }
        for g in 0..n {
            alloc.weight[g] /= wsum;
        }
        Ok(alloc)
    }

    /// The uniform allocation at bit-width `bits` over the same groups —
    /// the static baseline mixed precision must match or beat.
    pub fn uniform_like(&self, bits: u32) -> BitAllocation {
        let mut u = *self;
        for g in 0..u.len {
            u.bits[g] = bits.clamp(1, 32) as u8;
        }
        u
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// `(bits, lambda, weight)` per group, in channel order.
    pub fn groups(&self) -> impl Iterator<Item = (u32, f64, f64)> + '_ {
        (0..self.len).map(move |g| (self.bits[g] as u32, self.lambda[g], self.weight[g]))
    }

    pub fn bits(&self) -> Vec<u32> {
        (0..self.len).map(|g| self.bits[g] as u32).collect()
    }

    /// Average rate Σ w_g b_g in bits/parameter (the budget quantity).
    pub fn avg_bits(&self) -> f64 {
        self.groups().map(|(b, _, w)| w * b as f64).sum()
    }

    /// Integer bit-width the fleet's delay/energy design is planned at:
    /// compute cycles scale with the *average* rate (§II-D), so the
    /// pinned design bit-width is round(Σ w_g b_g), at least 1.
    pub fn pinned_bits(&self) -> u32 {
        (self.avg_bits().round() as u32).max(1)
    }

    /// Group-decomposed Prop. 4.2 bound: Σ w_g D^U(b_g - 1, λ_g).
    pub fn d_upper_total(&self) -> f64 {
        self.groups().map(|(b, l, w)| w * rd::d_upper(b as f64 - 1.0, l)).sum()
    }

    /// Group-decomposed (P1) objective: Σ w_g (D^U - D^L)(b_g - 1, λ_g).
    pub fn bound_gap_total(&self) -> f64 {
        self.groups().map(|(b, l, w)| w * rd::bound_gap(b as f64, l)).sum()
    }

    /// Distortion of not serving at all (every group reconstructed as 0):
    /// Σ w_g E[Θ_g] = Σ w_g / λ_g — the mixed-precision analog of the
    /// single-λ rejection distortion 1/λ.
    pub fn miss_distortion(&self) -> f64 {
        self.groups().map(|(_, l, w)| w / l).sum()
    }

    /// Content hash (order-sensitive, f64s by bit pattern) — feeds
    /// `FleetSpec`'s hash so warm caches and churn fingerprints see
    /// allocation changes like any other re-solve input.
    pub fn hash_content<H: std::hash::Hasher>(&self, state: &mut H) {
        state.write_usize(self.len);
        for (b, l, w) in self.groups() {
            state.write_u32(b);
            state.write_u64(l.to_bits());
            state.write_u64(w.to_bits());
        }
    }
}

/// Split a flat weight blob into `n_groups` contiguous channel groups and
/// MLE-fit each group's Exp(λ_g) magnitude model; returns per-group
/// (λ_g, w_g) with w_g the group's fraction of parameters. This is the
/// calibration front half of [`allocate_bits`].
pub fn fit_groups(weights: &[f32], n_groups: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n_groups >= 1 && n_groups <= MAX_GROUPS, "n_groups {n_groups}");
    assert!(weights.len() >= n_groups, "fewer weights than groups");
    let models = ExponentialModel::fit_channel_groups(weights, n_groups);
    let n = weights.len();
    let lambdas = models.iter().map(|m| m.lambda).collect();
    let fracs = (0..n_groups)
        .map(|g| {
            let lo = g * n / n_groups;
            let hi = (g + 1) * n / n_groups;
            (hi - lo) as f64 / n as f64
        })
        .collect();
    (lambdas, fracs)
}

/// Greedy marginal-gain water-filling: starting from 1 bit everywhere,
/// repeatedly grant +1 bit to the group with the largest distortion
/// decrease per unit of average-rate spend (Δ`model.predict` / w_g),
/// subject to Σ w_g b_g <= `avg_rate` and b_g <= `b_max`. The uniform
/// allocation at b̂ = ⌊R̄⌋ is evaluated as an explicit candidate and
/// returned instead whenever it predicts strictly lower distortion, so
/// the result is never worse than the best uniform static at the same
/// average rate — by construction, for *any* monotone distortion model.
pub fn allocate_bits(
    lambdas: &[f64],
    weights: &[f64],
    avg_rate: f64,
    b_max: u32,
    model: &dyn DistortionModel,
) -> Result<BitAllocation, String> {
    if !(avg_rate.is_finite() && avg_rate >= 1.0) {
        return Err(format!("average rate {avg_rate} must be finite and >= 1"));
    }
    if !(1..=32).contains(&b_max) {
        return Err(format!("b_max {b_max} outside 1..=32"));
    }
    let ones = vec![1u32; lambdas.len()];
    let mut cur = BitAllocation::new(&ones, lambdas, weights)?;
    let mut cur_pred = model.predict(&cur);
    loop {
        let avg = cur.avg_bits();
        let mut best: Option<(usize, f64, f64)> = None; // (group, gain/w, pred)
        for g in 0..cur.len {
            if cur.bits[g] as u32 >= b_max {
                continue;
            }
            if avg + cur.weight[g] > avg_rate + 1e-12 {
                continue;
            }
            let mut cand = cur;
            cand.bits[g] += 1;
            let pred = model.predict(&cand);
            let rate = (cur_pred - pred) / cur.weight[g];
            let better = match best {
                None => true,
                Some((_, r, _)) => rate > r,
            };
            if better {
                best = Some((g, rate, pred));
            }
        }
        match best {
            Some((g, rate, pred)) if rate > 0.0 => {
                cur.bits[g] += 1;
                cur_pred = pred;
            }
            _ => break,
        }
    }
    let uniform = cur.uniform_like((avg_rate.floor() as u32).clamp(1, b_max));
    if model.predict(&uniform) < cur_pred {
        Ok(uniform)
    } else {
        Ok(cur)
    }
}

/// Online adaptation bounds for [`QuantPolicy::Adaptive`]: at every
/// (re-)solve the agent takes the solver's max-feasible bit-width,
/// clamped into `[min_bits, max_bits - round(pressure * pressure_backoff)]`
/// where `pressure` is the agent's measured deadline-violation pressure
/// from the previous telemetry epoch (`FleetSpec::pressure`, the same
/// signal `AdmissionPricing::Measured` prices admission with). The
/// default (1, 16, 0.0) reproduces the unconstrained solver pick
/// bit-for-bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdaptConfig {
    /// Never serve below this bit-width (clamping up may turn an
    /// otherwise-servable agent into a rejection — that is the point).
    pub min_bits: u32,
    /// Never serve above this bit-width.
    pub max_bits: u32,
    /// Bits of headroom shed per unit of measured violation pressure
    /// (pressure in [0, 1]; backoff bits = round(pressure * this)).
    pub pressure_backoff: f64,
}

impl Default for AdaptConfig {
    fn default() -> AdaptConfig {
        AdaptConfig { min_bits: 1, max_bits: 16, pressure_backoff: 0.0 }
    }
}

impl AdaptConfig {
    /// Upper clamp after shedding `round(pressure * pressure_backoff)`
    /// bits, never below `min_bits`.
    pub fn effective_max(&self, pressure: f64) -> u32 {
        let shed = (pressure.clamp(0.0, 1.0) * self.pressure_backoff).round() as u32;
        self.max_bits.saturating_sub(shed).max(self.min_bits)
    }

    pub fn validate(&self, b_max: u32) -> Result<(), String> {
        if self.min_bits < 1 || self.min_bits > self.max_bits {
            return Err(format!(
                "adaptive bit range [{}, {}] invalid",
                self.min_bits, self.max_bits
            ));
        }
        if self.max_bits > b_max {
            return Err(format!("adaptive max_bits {} above b_max {b_max}", self.max_bits));
        }
        if !(self.pressure_backoff.is_finite() && self.pressure_backoff >= 0.0) {
            return Err(format!(
                "pressure_backoff {} not finite non-negative",
                self.pressure_backoff
            ));
        }
        Ok(())
    }
}

/// Per-agent quantization policy, threaded through
/// [`crate::opt::fleet::AgentSpec`] (and from there through churn,
/// events, and the daemon). The default, `Static(None)`, is the
/// pre-mixed-precision behavior bit-for-bit: the solver's bisection
/// picks the max-feasible bit-width and the objective prices it with the
/// single-λ bound gap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum QuantPolicy {
    /// `None`: solver picks (legacy). `Some(b)`: pin bit-width b — the
    /// agent serves at exactly b or is rejected.
    Static(Option<u32>),
    /// Pin a per-group mixed-precision allocation; the delay/energy
    /// design is planned at `BitAllocation::pinned_bits()` and the
    /// objective prices the group-decomposed bounds.
    Mixed(BitAllocation),
    /// Re-pick the max-feasible bit-width at every (warm) re-solve,
    /// clamped by [`AdaptConfig`] and backed off under measured
    /// pressure.
    Adaptive(AdaptConfig),
}

impl Default for QuantPolicy {
    fn default() -> QuantPolicy {
        QuantPolicy::Static(None)
    }
}

impl QuantPolicy {
    /// True for the legacy solver-picks default (used to keep hashes and
    /// class keys byte-identical for pre-existing specs).
    pub fn is_default(&self) -> bool {
        matches!(self, QuantPolicy::Static(None))
    }

    /// Report/CLI label.
    pub fn label(&self) -> String {
        match self {
            QuantPolicy::Static(None) => "static".into(),
            QuantPolicy::Static(Some(b)) => format!("static:{b}"),
            QuantPolicy::Mixed(a) => format!("mixed:{}g@{:.2}", a.len(), a.avg_bits()),
            QuantPolicy::Adaptive(c) => {
                if c.pressure_backoff > 0.0 {
                    format!("adaptive:{}-{}:{}", c.min_bits, c.max_bits, c.pressure_backoff)
                } else {
                    format!("adaptive:{}-{}", c.min_bits, c.max_bits)
                }
            }
        }
    }

    /// True when the policy *reads* measured violation pressure (an
    /// adaptive window with a non-zero backoff): telemetry must then
    /// participate in the fleet fingerprint so epoch boundaries can
    /// re-pick bit-widths, exactly like
    /// [`AdmissionPricing::Measured`](crate::opt::fleet::AdmissionPricing)
    /// re-prices admission.
    pub fn pressure_sensitive(&self) -> bool {
        matches!(self, QuantPolicy::Adaptive(c) if c.pressure_backoff > 0.0)
    }

    /// Pinned design bit-width, if this policy pins one.
    pub fn pinned_bits(&self) -> Option<u32> {
        match self {
            QuantPolicy::Static(Some(b)) => Some(*b),
            QuantPolicy::Mixed(a) => Some(a.pinned_bits()),
            _ => None,
        }
    }

    /// Bit-width at which servability/admission floors probe feasibility:
    /// the pinned width for pinning policies (serving below it is not an
    /// option), `min_bits` for adaptive, 1 for the legacy default.
    pub fn probe_bits(&self) -> f64 {
        match self {
            QuantPolicy::Static(None) => 1.0,
            QuantPolicy::Static(Some(b)) => *b as f64,
            QuantPolicy::Mixed(a) => a.pinned_bits() as f64,
            QuantPolicy::Adaptive(c) => c.min_bits as f64,
        }
    }

    pub fn validate(&self, b_max: u32) -> Result<(), String> {
        match self {
            QuantPolicy::Static(None) => Ok(()),
            QuantPolicy::Static(Some(b)) => {
                if *b < 1 || *b > b_max {
                    Err(format!("static bit-width {b} outside 1..={b_max}"))
                } else {
                    Ok(())
                }
            }
            QuantPolicy::Mixed(a) => {
                if a.len() == 0 {
                    return Err("mixed allocation has no groups".into());
                }
                for (g, (b, l, w)) in a.groups().enumerate() {
                    if b < 1 || b > b_max {
                        return Err(format!("mixed group {g}: bit-width {b} outside 1..={b_max}"));
                    }
                    if !(l.is_finite() && l > 0.0) || !(w.is_finite() && w > 0.0) {
                        return Err(format!("mixed group {g}: invalid (lambda, weight)"));
                    }
                }
                Ok(())
            }
            QuantPolicy::Adaptive(c) => c.validate(b_max),
        }
    }

    /// Content hash; the default policy hashes to the same single `0`
    /// tag on every spec, and non-default policies mix in their full
    /// payload (f64s by bit pattern).
    pub fn hash_content<H: std::hash::Hasher>(&self, state: &mut H) {
        match self {
            QuantPolicy::Static(None) => state.write_u8(0),
            QuantPolicy::Static(Some(b)) => {
                state.write_u8(1);
                state.write_u32(*b);
            }
            QuantPolicy::Mixed(a) => {
                state.write_u8(2);
                a.hash_content(state);
            }
            QuantPolicy::Adaptive(c) => {
                state.write_u8(3);
                state.write_u32(c.min_bits);
                state.write_u32(c.max_bits);
                state.write_u64(c.pressure_backoff.to_bits());
            }
        }
    }

    /// CLI-facing parser. Accepted spellings:
    /// `static` | `static:<bits>` | `adaptive` |
    /// `adaptive:<min>-<max>` | `adaptive:<min>-<max>:<backoff>`.
    /// (`Mixed` carries a fitted allocation and is constructed
    /// programmatically, not from a CLI token.)
    pub fn parse(s: &str) -> Result<QuantPolicy, ParseError> {
        const CHOICES: &[&str] =
            &["static", "static:<bits>", "adaptive", "adaptive:<min>-<max>[:<backoff>]"];
        let err = || ParseError::new("quant policy", s, CHOICES);
        match s {
            "static" => return Ok(QuantPolicy::Static(None)),
            "adaptive" => return Ok(QuantPolicy::Adaptive(AdaptConfig::default())),
            _ => {}
        }
        if let Some(bits) = s.strip_prefix("static:") {
            let b: u32 = bits.parse().map_err(|_| err())?;
            if b < 1 {
                return Err(err());
            }
            return Ok(QuantPolicy::Static(Some(b)));
        }
        if let Some(body) = s.strip_prefix("adaptive:") {
            let (range, backoff) = match body.split_once(':') {
                Some((r, b)) => (r, Some(b)),
                None => (body, None),
            };
            let (lo, hi) = range.split_once('-').ok_or_else(err)?;
            let min_bits: u32 = lo.parse().map_err(|_| err())?;
            let max_bits: u32 = hi.parse().map_err(|_| err())?;
            let pressure_backoff: f64 = match backoff {
                Some(b) => b.parse().map_err(|_| err())?,
                None => 0.0,
            };
            if min_bits < 1 || max_bits < min_bits || !pressure_backoff.is_finite() {
                return Err(err());
            }
            return Ok(QuantPolicy::Adaptive(AdaptConfig { min_bits, max_bits, pressure_backoff }));
        }
        Err(err())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::theory::rate_distortion::RateBoundModel;

    const GOLDEN_LAMBDAS: [f64; 3] = [4.0, 15.0, 60.0];

    fn golden_alloc(avg_rate: f64) -> BitAllocation {
        allocate_bits(&GOLDEN_LAMBDAS, &[1.0, 1.0, 1.0], avg_rate, 16, &RateBoundModel).unwrap()
    }

    /// Golden pin of the greedy allocator on the fixed 3-group λ-spread
    /// stack (λ = [4, 15, 60], equal weights, R̄ = 6): the heavy-tailed
    /// group earns two extra bits, the sharp group gives two up.
    #[test]
    fn golden_three_group_allocation() {
        let a = golden_alloc(6.0);
        assert_eq!(a.bits(), vec![8, 6, 4]);
        assert!(a.avg_bits() <= 6.0 + 1e-12, "{}", a.avg_bits());
        // and it strictly beats the uniform 6-bit allocation
        let u = a.uniform_like(6);
        assert!(a.d_upper_total() < u.d_upper_total());
    }

    #[test]
    fn mixed_never_worse_than_uniform_at_equal_rate() {
        let spreads: [&[f64]; 4] = [
            &[4.0, 15.0, 60.0],
            &[15.0, 15.0, 15.0],
            &[1.0, 10.0, 100.0, 1000.0],
            &[8.0, 9.0, 10.0, 11.0, 12.0],
        ];
        for lambdas in spreads {
            let w = vec![1.0; lambdas.len()];
            for rbar in 2..=8u32 {
                let a = allocate_bits(lambdas, &w, rbar as f64, 16, &RateBoundModel).unwrap();
                let u = a.uniform_like(rbar);
                assert!(
                    a.d_upper_total() <= u.d_upper_total() + 1e-15,
                    "lambdas {lambdas:?} rbar {rbar}: {} > {}",
                    a.d_upper_total(),
                    u.d_upper_total()
                );
                assert!(a.avg_bits() <= rbar as f64 + 1e-12);
            }
        }
    }

    #[test]
    fn zero_spread_reduces_to_uniform() {
        let a =
            allocate_bits(&[20.0, 20.0, 20.0], &[1.0, 1.0, 1.0], 5.0, 16, &RateBoundModel).unwrap();
        assert_eq!(a.bits(), vec![5, 5, 5]);
    }

    #[test]
    fn budget_monotone() {
        let mut prev = f64::INFINITY;
        for rbar in 1..=10 {
            let a = golden_alloc(rbar as f64);
            let d = a.d_upper_total();
            assert!(d <= prev + 1e-18, "rbar {rbar}: {d} > {prev}");
            prev = d;
        }
    }

    #[test]
    fn allocation_validation_rejects_bad_groups() {
        assert!(BitAllocation::new(&[], &[], &[]).is_err());
        assert!(BitAllocation::new(&[0], &[1.0], &[1.0]).is_err());
        assert!(BitAllocation::new(&[33], &[1.0], &[1.0]).is_err());
        assert!(BitAllocation::new(&[4], &[0.0], &[1.0]).is_err());
        assert!(BitAllocation::new(&[4], &[1.0], &[-1.0]).is_err());
        assert!(BitAllocation::new(&[4, 4], &[1.0], &[1.0, 1.0]).is_err());
        let a = BitAllocation::new(&[4, 8], &[10.0, 2.0], &[3.0, 1.0]).unwrap();
        assert!((a.avg_bits() - 5.0).abs() < 1e-12); // weights normalized: 0.75/0.25
        assert_eq!(a.pinned_bits(), 5);
    }

    #[test]
    fn fit_groups_recovers_spread() {
        let mut rng = crate::util::rng::Rng::new(77);
        let mut blob = Vec::new();
        for lam in GOLDEN_LAMBDAS {
            for _ in 0..30_000 {
                let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                blob.push((sign * rng.exponential(lam)) as f32);
            }
        }
        let (lambdas, fracs) = fit_groups(&blob, 3);
        for (fit, truth) in lambdas.iter().zip(GOLDEN_LAMBDAS) {
            assert!((fit - truth).abs() / truth < 0.05, "{fit} vs {truth}");
        }
        assert!(fracs.iter().all(|f| (f - 1.0 / 3.0).abs() < 1e-12));
    }

    #[test]
    fn policy_parse_roundtrip_and_rejection() {
        assert_eq!(QuantPolicy::parse("static"), Ok(QuantPolicy::Static(None)));
        assert_eq!(QuantPolicy::parse("static:6"), Ok(QuantPolicy::Static(Some(6))));
        assert_eq!(QuantPolicy::parse("adaptive"), Ok(QuantPolicy::Adaptive(AdaptConfig::default())));
        assert_eq!(
            QuantPolicy::parse("adaptive:2-8"),
            Ok(QuantPolicy::Adaptive(AdaptConfig { min_bits: 2, max_bits: 8, pressure_backoff: 0.0 }))
        );
        assert_eq!(
            QuantPolicy::parse("adaptive:2-8:3.5"),
            Ok(QuantPolicy::Adaptive(AdaptConfig { min_bits: 2, max_bits: 8, pressure_backoff: 3.5 }))
        );
        for bad in ["", "dynamic", "static:", "static:0", "static:x", "adaptive:8-2", "adaptive:0-4", "adaptive:1..4", "mixed"] {
            let err = QuantPolicy::parse(bad).unwrap_err();
            assert_eq!(err.token, bad);
            assert_eq!(err.what, "quant policy");
            assert!(err.choices.contains(&"static"), "{:?}", err.choices);
        }
    }

    #[test]
    fn policy_validation_against_b_max() {
        assert!(QuantPolicy::Static(None).validate(16).is_ok());
        assert!(QuantPolicy::Static(Some(16)).validate(16).is_ok());
        assert!(QuantPolicy::Static(Some(17)).validate(16).is_err());
        assert!(QuantPolicy::Adaptive(AdaptConfig::default()).validate(16).is_ok());
        assert!(QuantPolicy::Adaptive(AdaptConfig { max_bits: 17, ..Default::default() })
            .validate(16)
            .is_err());
        let a = BitAllocation::new(&[4, 8], &[10.0, 2.0], &[1.0, 1.0]).unwrap();
        assert!(QuantPolicy::Mixed(a).validate(16).is_ok());
        assert!(QuantPolicy::Mixed(a).validate(6).is_err());
    }

    #[test]
    fn adaptive_effective_max_backs_off_under_pressure() {
        let c = AdaptConfig { min_bits: 2, max_bits: 10, pressure_backoff: 4.0 };
        assert_eq!(c.effective_max(0.0), 10);
        assert_eq!(c.effective_max(0.5), 8);
        assert_eq!(c.effective_max(1.0), 6);
        assert_eq!(c.effective_max(5.0), 6); // pressure clamps to 1
        let deep = AdaptConfig { min_bits: 4, max_bits: 5, pressure_backoff: 8.0 };
        assert_eq!(deep.effective_max(1.0), 4); // never below min_bits
    }

    #[test]
    fn default_policy_hash_is_stable_tag() {
        use std::collections::hash_map::DefaultHasher;
        use std::hash::Hasher;
        let mut h1 = DefaultHasher::new();
        QuantPolicy::default().hash_content(&mut h1);
        let mut h2 = DefaultHasher::new();
        QuantPolicy::Static(None).hash_content(&mut h2);
        assert_eq!(h1.finish(), h2.finish());
        let mut h3 = DefaultHasher::new();
        QuantPolicy::Static(Some(6)).hash_content(&mut h3);
        assert_ne!(h1.finish(), h3.finish());
    }
}
