//! Sign-preserving magnitude quantizers (paper §II-A/§II-C).
//!
//! A total bit-width b̂ spends 1 bit on the sign and m = b̂ - 1 bits on the
//! magnitude; the magnitude grid is either uniform [31] or power-of-two
//! logarithmic [32]. These are the native Rust twins of the Pallas
//! `fake_quant_*` kernels — the runtime hot path quantizes weight blobs
//! here (no python), and integration tests cross-check the two
//! implementations through PJRT on golden buffers.
//!
//! The one entry point is [`Quantizer`]: a [`QuantConfig`] (scheme +
//! bit depth, uniform or per-group mixed precision) validated at
//! construction, then applied to blobs via [`Quantizer::quantize`] /
//! [`Quantizer::quantize_into`]. The scheme-specific free functions
//! remain as thin wrappers for existing call sites; regression tests pin
//! them bit-identical to their `Quantizer` forms.

pub mod error;
pub mod mixed;
pub mod pot;
pub mod uniform;

pub use error::{mean_abs_distortion, total_l1_distortion};
pub use mixed::{allocate_bits, AdaptConfig, BitAllocation, QuantPolicy};
pub use pot::{pot_params, quantize_pot, quantize_pot_into};
pub use uniform::{quantize_uniform, quantize_uniform_into, uniform_step};

use crate::util::cli::ParseError;

/// Quantization scheme selector, used across the optimizer and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    Uniform,
    Pot,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Uniform => "uniform",
            Scheme::Pot => "pot",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<Scheme, ParseError> {
        match s {
            "uniform" => Ok(Scheme::Uniform),
            "pot" | "nonuniform" | "pot-log" => Ok(Scheme::Pot),
            _ => Err(ParseError::new("quantization scheme", s, &["uniform", "pot"])),
        }
    }
}

/// Bit-depth half of a [`QuantConfig`]: one width for the whole blob, or
/// a per-group [`BitAllocation`] over contiguous channel groups (each
/// group gets its own grid scaled to the group's θ_max).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BitDepth {
    Uniform(u32),
    PerGroup(BitAllocation),
}

/// Scheme + bit depth, validated once by [`Quantizer::new`] (the
/// `FleetSpec` construction pattern: invalid configs are unrepresentable
/// past the constructor, so the hot path carries no checks).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantConfig {
    pub scheme: Scheme,
    pub bits: BitDepth,
}

/// The unified quantization entry point. Construction validates the
/// config; [`Quantizer::quantize`]/[`Quantizer::quantize_into`] then
/// dispatch scheme × depth without further checks. The uniform-depth
/// paths are bit-identical to the legacy free functions
/// ([`quantize_magnitudes`], [`quantize_uniform`], [`quantize_pot`] and
/// their `_into` variants), which regression tests pin.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Quantizer {
    cfg: QuantConfig,
}

impl Quantizer {
    pub fn new(cfg: QuantConfig) -> Result<Quantizer, String> {
        match cfg.bits {
            BitDepth::Uniform(b) => {
                if !(1..=32).contains(&b) {
                    return Err(format!("bit-width {b} outside 1..=32"));
                }
            }
            // a BitAllocation is validated at its own construction; its
            // invariants (1..=32 bits, positive weights) are exactly
            // what the per-group path needs
            BitDepth::PerGroup(_) => {}
        }
        Ok(Quantizer { cfg })
    }

    pub fn config(&self) -> QuantConfig {
        self.cfg
    }

    /// Contiguous index spans of the per-group path: group g covers the
    /// slice between the cumulative-weight boundaries rounded to indices
    /// (the same contiguous-channel-group convention as
    /// [`mixed::fit_groups`]).
    fn group_spans(alloc: &BitAllocation, n: usize) -> Vec<(usize, usize)> {
        let count = alloc.len();
        let mut spans = Vec::with_capacity(count);
        let mut cum = 0.0;
        let mut lo = 0usize;
        for (g, (_, _, w)) in alloc.groups().enumerate() {
            cum += w;
            let hi = if g + 1 == count { n } else { ((cum * n as f64).round() as usize).clamp(lo, n) };
            spans.push((lo, hi));
            lo = hi;
        }
        spans
    }

    fn quantize_span(scheme: Scheme, bits: u32, span: &[f32], out: &mut [f32]) {
        let theta_max = span.iter().fold(0.0f32, |m, w| m.max(w.abs()));
        match scheme {
            Scheme::Uniform => {
                quantize_uniform_into(span, uniform_step(theta_max, bits), out)
            }
            Scheme::Pot => {
                let (emin, emax) = pot_params(theta_max, bits);
                quantize_pot_into(span, emin, emax, out)
            }
        }
    }

    /// In-place variant for the runtime hot path (no allocation).
    pub fn quantize_into(&self, weights: &[f32], out: &mut [f32]) {
        assert_eq!(weights.len(), out.len());
        match self.cfg.bits {
            BitDepth::Uniform(b) => Self::quantize_span(self.cfg.scheme, b, weights, out),
            BitDepth::PerGroup(alloc) => {
                for ((lo, hi), (bits, _, _)) in
                    Self::group_spans(&alloc, weights.len()).into_iter().zip(alloc.groups())
                {
                    if lo < hi {
                        Self::quantize_span(
                            self.cfg.scheme,
                            bits,
                            &weights[lo..hi],
                            &mut out[lo..hi],
                        );
                    }
                }
            }
        }
    }

    pub fn quantize(&self, weights: &[f32]) -> Vec<f32> {
        let mut out = vec![0.0; weights.len()];
        self.quantize_into(weights, &mut out);
        out
    }
}

/// Quantize a weight blob at total bit-width `bits` with the given scheme.
/// `bits == 0` is rejected; `bits == 1` keeps only signs (all magnitudes
/// collapse); `bits >= 23`-ish is effectively lossless for f32.
///
/// Deprecated entry point: prefer
/// `Quantizer::new(QuantConfig { scheme, bits: BitDepth::Uniform(bits) })`,
/// which validates once and also covers per-group mixed precision. Kept
/// as a bit-identical wrapper for existing call sites (pinned by the
/// `quantizer_matches_*` regression tests).
pub fn quantize_magnitudes(weights: &[f32], bits: u32, scheme: Scheme) -> Vec<f32> {
    assert!(bits >= 1, "need at least the sign bit");
    let theta_max = weights.iter().fold(0.0f32, |m, w| m.max(w.abs()));
    match scheme {
        Scheme::Uniform => {
            let step = uniform_step(theta_max, bits);
            quantize_uniform(weights, step)
        }
        Scheme::Pot => {
            let (emin, emax) = pot_params(theta_max, bits);
            quantize_pot(weights, emin, emax)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn blob(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0.1 * rng.normal()) as f32).collect()
    }

    #[test]
    fn idempotent_for_both_schemes() {
        forall(
            "quantize twice == once",
            40,
            |r| {
                (
                    r.next_u64(),
                    2 + r.below(7) as u32,
                    if r.f64() < 0.5 { Scheme::Uniform } else { Scheme::Pot },
                )
            },
            |&(seed, bits, scheme)| {
                let w = blob(seed, 512);
                let q1 = quantize_magnitudes(&w, bits, scheme);
                // re-quantize with the SAME grid (theta_max of q1 <= of w,
                // so derive grid from the original): apply raw quantizers
                let theta_max = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let q2 = match scheme {
                    Scheme::Uniform => {
                        quantize_uniform(&q1, uniform_step(theta_max, bits))
                    }
                    Scheme::Pot => {
                        let (lo, hi) = pot_params(theta_max, bits);
                        quantize_pot(&q1, lo, hi)
                    }
                };
                if q1 == q2 {
                    Ok(())
                } else {
                    Err("not idempotent".into())
                }
            },
        );
    }

    #[test]
    fn distortion_monotone_in_bits() {
        let w = blob(3, 4096);
        for scheme in [Scheme::Uniform, Scheme::Pot] {
            let dists: Vec<f64> = (1..=10)
                .map(|b| {
                    let q = quantize_magnitudes(&w, b, scheme);
                    total_l1_distortion(&w, &q)
                })
                .collect();
            for win in dists.windows(2) {
                assert!(
                    win[1] <= win[0] * 1.0001 + 1e-9,
                    "{scheme:?}: {dists:?}"
                );
            }
            // uniform refines the grid with every bit; PoT only extends the
            // exponent range downward, so it saturates at the log-rounding
            // floor (|w - 2^round(log2 w)| stays, up to ~17% relative)
            let floor = match scheme {
                Scheme::Uniform => 0.05,
                Scheme::Pot => 0.25,
            };
            assert!(dists[9] < dists[0] * floor, "{scheme:?}: {dists:?}");
        }
    }

    #[test]
    fn signs_always_preserved() {
        forall(
            "sign preservation",
            30,
            |r| {
                (
                    r.next_u64(),
                    1 + r.below(8) as u32,
                    if r.f64() < 0.5 { Scheme::Uniform } else { Scheme::Pot },
                )
            },
            |&(seed, bits, scheme)| {
                let w = blob(seed, 256);
                let q = quantize_magnitudes(&w, bits, scheme);
                for (a, b) in w.iter().zip(&q) {
                    if *b != 0.0 && a.signum() != b.signum() {
                        return Err(format!("sign flip {a} -> {b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn high_bits_uniform_is_near_lossless_pot_hits_log_floor() {
        let w = blob(7, 2048);
        let scale = w.iter().map(|v| v.abs() as f64).sum::<f64>() / w.len() as f64;
        // uniform: grid refines -> error vanishes
        let qu = quantize_magnitudes(&w, 16, Scheme::Uniform);
        assert!(mean_abs_distortion(&w, &qu) < scale * 0.01);
        // PoT: levels stay powers of two -> saturates at the log-rounding
        // floor (E|w - 2^round(log2|w|)| ≈ 0.11 |w| for smooth inputs)
        let qp = quantize_magnitudes(&w, 16, Scheme::Pot);
        let err_p = mean_abs_distortion(&w, &qp);
        assert!(err_p > scale * 0.05 && err_p < scale * 0.25, "{err_p} vs {scale}");
        // and 20 bits doesn't improve PoT further (saturation)
        let qp20 = quantize_magnitudes(&w, 20, Scheme::Pot);
        assert!((mean_abs_distortion(&w, &qp20) - err_p).abs() < scale * 1e-3);
    }

    #[test]
    fn one_bit_uniform_zeroes_magnitudes() {
        let w = blob(9, 128);
        let q = quantize_magnitudes(&w, 1, Scheme::Uniform);
        assert!(q.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn quantizer_matches_free_fns_bit_for_bit() {
        // the deprecated-doc'd free fns must stay bit-identical to their
        // Quantizer forms, for every scheme and bit width
        let w = blob(13, 2048);
        for scheme in [Scheme::Uniform, Scheme::Pot] {
            for bits in 1..=12u32 {
                let q = Quantizer::new(QuantConfig { scheme, bits: BitDepth::Uniform(bits) })
                    .unwrap();
                let via_quantizer = q.quantize(&w);
                let via_free = quantize_magnitudes(&w, bits, scheme);
                assert_eq!(via_quantizer, via_free, "{scheme:?} bits={bits}");
                // and the raw scheme fns through precomputed grids
                let theta_max = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let via_raw = match scheme {
                    Scheme::Uniform => quantize_uniform(&w, uniform_step(theta_max, bits)),
                    Scheme::Pot => {
                        let (lo, hi) = pot_params(theta_max, bits);
                        quantize_pot(&w, lo, hi)
                    }
                };
                assert_eq!(via_quantizer, via_raw, "{scheme:?} bits={bits} (raw)");
                // _into variants agree too
                let mut buf = vec![0.0f32; w.len()];
                q.quantize_into(&w, &mut buf);
                assert_eq!(buf, via_free, "{scheme:?} bits={bits} (into)");
            }
        }
    }

    #[test]
    fn quantizer_validates_at_construction() {
        assert!(Quantizer::new(QuantConfig {
            scheme: Scheme::Uniform,
            bits: BitDepth::Uniform(0)
        })
        .is_err());
        assert!(Quantizer::new(QuantConfig {
            scheme: Scheme::Uniform,
            bits: BitDepth::Uniform(33)
        })
        .is_err());
        assert!(Quantizer::new(QuantConfig {
            scheme: Scheme::Pot,
            bits: BitDepth::Uniform(8)
        })
        .is_ok());
    }

    #[test]
    fn per_group_depth_scales_each_group_grid() {
        // two groups with very different magnitude scales: a shared
        // uniform grid wastes levels on the small group; per-group grids
        // (same average rate) cut its distortion
        let mut rng = Rng::new(55);
        let n = 4096;
        let mut w: Vec<f32> = Vec::with_capacity(2 * n);
        for _ in 0..n {
            w.push((2.0 * rng.normal()) as f32); // heavy group
        }
        for _ in 0..n {
            w.push((0.02 * rng.normal()) as f32); // sharp group
        }
        let alloc = BitAllocation::new(&[6, 6], &[0.5, 50.0], &[1.0, 1.0]).unwrap();
        let grouped = Quantizer::new(QuantConfig {
            scheme: Scheme::Uniform,
            bits: BitDepth::PerGroup(alloc),
        })
        .unwrap()
        .quantize(&w);
        let shared = quantize_magnitudes(&w, 6, Scheme::Uniform);
        let sharp = n..2 * n;
        let d_grouped = total_l1_distortion(&w[sharp.clone()], &grouped[sharp.clone()]);
        let d_shared = total_l1_distortion(&w[sharp.clone()], &shared[sharp]);
        assert!(
            d_grouped < d_shared * 0.25,
            "per-group {d_grouped} vs shared {d_shared}"
        );
        // group spans tile the blob exactly
        let spans = Quantizer::group_spans(&alloc, 2 * n);
        assert_eq!(spans, vec![(0, n), (n, 2 * n)]);
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("uniform"), Ok(Scheme::Uniform));
        assert_eq!(Scheme::parse("pot"), Ok(Scheme::Pot));
        assert_eq!(Scheme::parse("nonuniform"), Ok(Scheme::Pot));
        let err = Scheme::parse("x").unwrap_err();
        assert_eq!(err.token, "x");
        assert_eq!(err.choices, &["uniform", "pot"]);
    }
}
