//! Sign-preserving magnitude quantizers (paper §II-A/§II-C).
//!
//! A total bit-width b̂ spends 1 bit on the sign and m = b̂ - 1 bits on the
//! magnitude; the magnitude grid is either uniform [31] or power-of-two
//! logarithmic [32]. These are the native Rust twins of the Pallas
//! `fake_quant_*` kernels — the runtime hot path quantizes weight blobs
//! here (no python), and integration tests cross-check the two
//! implementations through PJRT on golden buffers.

pub mod error;
pub mod pot;
pub mod uniform;

pub use error::{mean_abs_distortion, total_l1_distortion};
pub use pot::{pot_params, quantize_pot, quantize_pot_into};
pub use uniform::{quantize_uniform, quantize_uniform_into, uniform_step};

use crate::util::cli::ParseError;

/// Quantization scheme selector, used across the optimizer and benches.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    Uniform,
    Pot,
}

impl Scheme {
    pub fn name(self) -> &'static str {
        match self {
            Scheme::Uniform => "uniform",
            Scheme::Pot => "pot",
        }
    }

    /// CLI-facing parser; the error names the token and valid choices.
    pub fn parse(s: &str) -> Result<Scheme, ParseError> {
        match s {
            "uniform" => Ok(Scheme::Uniform),
            "pot" | "nonuniform" | "pot-log" => Ok(Scheme::Pot),
            _ => Err(ParseError::new("quantization scheme", s, &["uniform", "pot"])),
        }
    }
}

/// Quantize a weight blob at total bit-width `bits` with the given scheme.
/// `bits == 0` is rejected; `bits == 1` keeps only signs (all magnitudes
/// collapse); `bits >= 23`-ish is effectively lossless for f32.
pub fn quantize_magnitudes(weights: &[f32], bits: u32, scheme: Scheme) -> Vec<f32> {
    assert!(bits >= 1, "need at least the sign bit");
    let theta_max = weights.iter().fold(0.0f32, |m, w| m.max(w.abs()));
    match scheme {
        Scheme::Uniform => {
            let step = uniform_step(theta_max, bits);
            quantize_uniform(weights, step)
        }
        Scheme::Pot => {
            let (emin, emax) = pot_params(theta_max, bits);
            quantize_pot(weights, emin, emax)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    fn blob(seed: u64, n: usize) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| (0.1 * rng.normal()) as f32).collect()
    }

    #[test]
    fn idempotent_for_both_schemes() {
        forall(
            "quantize twice == once",
            40,
            |r| {
                (
                    r.next_u64(),
                    2 + r.below(7) as u32,
                    if r.f64() < 0.5 { Scheme::Uniform } else { Scheme::Pot },
                )
            },
            |&(seed, bits, scheme)| {
                let w = blob(seed, 512);
                let q1 = quantize_magnitudes(&w, bits, scheme);
                // re-quantize with the SAME grid (theta_max of q1 <= of w,
                // so derive grid from the original): apply raw quantizers
                let theta_max = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
                let q2 = match scheme {
                    Scheme::Uniform => {
                        quantize_uniform(&q1, uniform_step(theta_max, bits))
                    }
                    Scheme::Pot => {
                        let (lo, hi) = pot_params(theta_max, bits);
                        quantize_pot(&q1, lo, hi)
                    }
                };
                if q1 == q2 {
                    Ok(())
                } else {
                    Err("not idempotent".into())
                }
            },
        );
    }

    #[test]
    fn distortion_monotone_in_bits() {
        let w = blob(3, 4096);
        for scheme in [Scheme::Uniform, Scheme::Pot] {
            let dists: Vec<f64> = (1..=10)
                .map(|b| {
                    let q = quantize_magnitudes(&w, b, scheme);
                    total_l1_distortion(&w, &q)
                })
                .collect();
            for win in dists.windows(2) {
                assert!(
                    win[1] <= win[0] * 1.0001 + 1e-9,
                    "{scheme:?}: {dists:?}"
                );
            }
            // uniform refines the grid with every bit; PoT only extends the
            // exponent range downward, so it saturates at the log-rounding
            // floor (|w - 2^round(log2 w)| stays, up to ~17% relative)
            let floor = match scheme {
                Scheme::Uniform => 0.05,
                Scheme::Pot => 0.25,
            };
            assert!(dists[9] < dists[0] * floor, "{scheme:?}: {dists:?}");
        }
    }

    #[test]
    fn signs_always_preserved() {
        forall(
            "sign preservation",
            30,
            |r| {
                (
                    r.next_u64(),
                    1 + r.below(8) as u32,
                    if r.f64() < 0.5 { Scheme::Uniform } else { Scheme::Pot },
                )
            },
            |&(seed, bits, scheme)| {
                let w = blob(seed, 256);
                let q = quantize_magnitudes(&w, bits, scheme);
                for (a, b) in w.iter().zip(&q) {
                    if *b != 0.0 && a.signum() != b.signum() {
                        return Err(format!("sign flip {a} -> {b}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn high_bits_uniform_is_near_lossless_pot_hits_log_floor() {
        let w = blob(7, 2048);
        let scale = w.iter().map(|v| v.abs() as f64).sum::<f64>() / w.len() as f64;
        // uniform: grid refines -> error vanishes
        let qu = quantize_magnitudes(&w, 16, Scheme::Uniform);
        assert!(mean_abs_distortion(&w, &qu) < scale * 0.01);
        // PoT: levels stay powers of two -> saturates at the log-rounding
        // floor (E|w - 2^round(log2|w|)| ≈ 0.11 |w| for smooth inputs)
        let qp = quantize_magnitudes(&w, 16, Scheme::Pot);
        let err_p = mean_abs_distortion(&w, &qp);
        assert!(err_p > scale * 0.05 && err_p < scale * 0.25, "{err_p} vs {scale}");
        // and 20 bits doesn't improve PoT further (saturation)
        let qp20 = quantize_magnitudes(&w, 20, Scheme::Pot);
        assert!((mean_abs_distortion(&w, &qp20) - err_p).abs() < scale * 1e-3);
    }

    #[test]
    fn one_bit_uniform_zeroes_magnitudes() {
        let w = blob(9, 128);
        let q = quantize_magnitudes(&w, 1, Scheme::Uniform);
        assert!(q.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(Scheme::parse("uniform"), Ok(Scheme::Uniform));
        assert_eq!(Scheme::parse("pot"), Ok(Scheme::Pot));
        assert_eq!(Scheme::parse("nonuniform"), Ok(Scheme::Pot));
        let err = Scheme::parse("x").unwrap_err();
        assert_eq!(err.token, "x");
        assert_eq!(err.choices, &["uniform", "pot"]);
    }
}
