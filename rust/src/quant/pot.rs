//! Power-of-two logarithmic quantization [32] ("PoT-log", the paper's
//! nonuniform scheme): magnitude levels {0} ∪ {2^k : emin <= k <= emax}.
//!
//! Semantics match the Pallas `fake_quant_pot` kernel: nearest level in
//! the log2 domain, flush-to-zero when log2|w| < emin - 0.5.

/// Exponent range for total bit-width `bits`: emax anchors at the largest
/// power of two <= θ_max, and m = bits-1 magnitude bits give 2^m - 1
/// nonzero levels => emin = emax - (2^m - 2).
pub fn pot_params(theta_max: f32, bits: u32) -> (f32, f32) {
    assert!(bits >= 1);
    let m = bits - 1;
    if m == 0 || theta_max <= 0.0 {
        // no nonzero levels: encode as an empty range below any magnitude
        return (-126.0, -126.0 - 1.0); // emin > emax => all flushed
    }
    let emax = theta_max.log2().floor();
    let levels = (1u64 << m) - 1;
    let emin = emax - (levels as f32 - 1.0);
    (emin, emax)
}

/// Apply PoT fake-quantization with precomputed exponent bounds.
pub fn quantize_pot(weights: &[f32], emin: f32, emax: f32) -> Vec<f32> {
    weights.iter().map(|&w| quantize_one(w, emin, emax)).collect()
}

pub fn quantize_pot_into(weights: &[f32], emin: f32, emax: f32, out: &mut [f32]) {
    assert_eq!(weights.len(), out.len());
    for (o, &w) in out.iter_mut().zip(weights) {
        *o = quantize_one(w, emin, emax);
    }
}

#[inline]
pub fn quantize_one(w: f32, emin: f32, emax: f32) -> f32 {
    let mag = w.abs();
    if mag == 0.0 {
        return 0.0;
    }
    if emin > emax {
        return 0.0; // empty level set (bits == 1)
    }
    let lg = mag.log2();
    if lg < emin - 0.5 {
        return 0.0; // flush-to-zero region
    }
    let e = super::uniform::round_half_even(lg).clamp(emin, emax);
    w.signum() * e.exp2()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_levels() {
        // bits=3 -> 3 nonzero levels; theta_max=1.0 -> emax=0, emin=-2
        let (emin, emax) = pot_params(1.0, 3);
        assert_eq!((emin, emax), (-2.0, 0.0));
        // levels: 0.25, 0.5, 1.0 (+0); the flush boundary is
        // 2^(emin-0.5) = 2^-2.5 ≈ 0.177, so 0.15 flushes to zero
        let q = quantize_pot(&[1.0, 0.6, 0.3, 0.15, 0.05, -0.8], emin, emax);
        assert_eq!(q, vec![1.0, 0.5, 0.25, 0.0, 0.0, -1.0]);
    }

    #[test]
    fn log_domain_rounding_boundary() {
        let (emin, emax) = (-4.0f32, 0.0f32);
        // 2^-0.5 ≈ 0.7071: log2 = -0.5 exactly -> half-even rounds to 0
        let q = quantize_one(0.70710678f32, emin, emax);
        assert_eq!(q, 1.0);
        // just below the midpoint rounds down
        let q = quantize_one(0.70f32, emin, emax);
        assert_eq!(q, 0.5);
    }

    #[test]
    fn flush_to_zero_region() {
        let (emin, emax) = (-3.0f32, 0.0f32);
        // 2^(-3.5) ≈ 0.0884 is the boundary; below -> 0
        assert_eq!(quantize_one(0.08, emin, emax), 0.0);
        assert_eq!(quantize_one(0.09, emin, emax), 0.125);
    }

    #[test]
    fn one_bit_flushes_everything() {
        let (emin, emax) = pot_params(2.0, 1);
        let q = quantize_pot(&[1.0, -0.5, 2.0], emin, emax);
        assert!(q.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn powers_of_two_are_fixed_points() {
        let (emin, emax) = pot_params(4.0, 6);
        for e in [-8i32, -4, -1, 0, 1, 2] {
            let v = (e as f32).exp2();
            if e as f32 >= emin && e as f32 <= emax {
                assert_eq!(quantize_one(v, emin, emax), v);
            }
        }
    }

    #[test]
    fn into_variant_matches() {
        let w: Vec<f32> = (1..64).map(|i| i as f32 * 0.017 - 0.5).collect();
        let (emin, emax) = pot_params(0.6, 4);
        let a = quantize_pot(&w, emin, emax);
        let mut b = vec![0.0; w.len()];
        quantize_pot_into(&w, emin, emax, &mut b);
        assert_eq!(a, b);
    }
}
