//! Uniform magnitude quantization [31]: fixed-step grid over [0, θ_max].
//!
//! Semantics are bit-identical to the Pallas `fake_quant_uniform` kernel
//! (same f32 ops in the same order): q = sign(w) * round(|w|/step) * step,
//! with step <= 0 meaning "identity" (the full-precision limit).

/// Step size for total bit-width `bits` (1 sign bit + m = bits-1 magnitude
/// bits => 2^m - 1 nonzero levels). m = 0 collapses all magnitudes to 0,
/// encoded as step = +inf -> handled by the grid formula below via a
/// sentinel 0-level count.
pub fn uniform_step(theta_max: f32, bits: u32) -> f32 {
    assert!(bits >= 1);
    let m = bits - 1;
    if m == 0 {
        // only the zero level exists; any step larger than 2*theta_max
        // rounds every magnitude to 0
        return f32::MAX;
    }
    let levels = (1u64 << m) - 1; // nonzero levels
    if theta_max <= 0.0 {
        0.0
    } else {
        theta_max / levels as f32
    }
}

/// Apply uniform fake-quantization with a precomputed step.
pub fn quantize_uniform(weights: &[f32], step: f32) -> Vec<f32> {
    weights.iter().map(|&w| quantize_one(w, step)).collect()
}

/// In-place variant for the runtime hot path (no allocation).
pub fn quantize_uniform_into(weights: &[f32], step: f32, out: &mut [f32]) {
    assert_eq!(weights.len(), out.len());
    for (o, &w) in out.iter_mut().zip(weights) {
        *o = quantize_one(w, step);
    }
}

#[inline]
pub fn quantize_one(w: f32, step: f32) -> f32 {
    if step <= 0.0 {
        return w;
    }
    if step == f32::MAX {
        return 0.0 * w.signum(); // keep signed zero semantics trivially
    }
    let mag = w.abs();
    let q = round_half_even(mag / step) * step;
    w.signum() * q
}

/// jnp.round rounds half-to-even; f32::round rounds half-away. Match the
/// Pallas kernel exactly so Rust- and XLA-quantized blobs agree bitwise.
#[inline]
pub fn round_half_even(x: f32) -> f32 {
    let r = x.round();
    if (x - x.trunc()).abs() == 0.5 {
        // exactly halfway: pick the even neighbor
        let down = x.trunc();
        if (down as i64) % 2 == 0 {
            down
        } else {
            down + x.signum()
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_grid() {
        // bits=3 -> m=2 -> 3 nonzero levels; theta_max = 3 -> step 1
        let step = uniform_step(3.0, 3);
        assert_eq!(step, 1.0);
        let q = quantize_uniform(&[0.4, -0.6, 1.4, -2.9, 3.0], step);
        assert_eq!(q, vec![0.0, -1.0, 1.0, -3.0, 3.0]);
    }

    #[test]
    fn round_half_even_matches_numpy_semantics() {
        assert_eq!(round_half_even(0.5), 0.0);
        assert_eq!(round_half_even(1.5), 2.0);
        assert_eq!(round_half_even(2.5), 2.0);
        assert_eq!(round_half_even(-0.5), 0.0);
        assert_eq!(round_half_even(-1.5), -2.0);
        assert_eq!(round_half_even(0.49), 0.0);
        assert_eq!(round_half_even(0.51), 1.0);
    }

    #[test]
    fn theta_max_is_representable() {
        for bits in 2..=8 {
            let step = uniform_step(1.7, bits);
            let q = quantize_one(1.7, step);
            assert!((q - 1.7).abs() < 1e-6, "bits={bits} q={q}");
        }
    }

    #[test]
    fn sign_bit_only_zeroes() {
        let step = uniform_step(5.0, 1);
        let q = quantize_uniform(&[1.0, -2.0, 5.0], step);
        assert!(q.iter().all(|v| *v == 0.0));
    }

    #[test]
    fn into_variant_matches_alloc_variant() {
        let w: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) * 0.03).collect();
        let step = uniform_step(1.0, 4);
        let a = quantize_uniform(&w, step);
        let mut b = vec![0.0; w.len()];
        quantize_uniform_into(&w, step, &mut b);
        assert_eq!(a, b);
    }
}
