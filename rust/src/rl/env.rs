//! The joint-design decision environment.

use crate::opt::problem::{Design, Problem};
use crate::system::Platform;
use crate::util::rng::Rng;

/// Ranges the QoS budgets are drawn from during training — the same bands
//  the paper sweeps in Figs. 5-8.
#[derive(Debug, Clone, Copy)]
pub struct BudgetRanges {
    pub t0: (f64, f64),
    pub e0: (f64, f64),
}

impl Default for BudgetRanges {
    fn default() -> Self {
        BudgetRanges { t0: (1.0, 5.0), e0: (0.5, 4.0) }
    }
}

#[derive(Debug, Clone)]
pub struct DesignEnv {
    pub platform: Platform,
    pub lambda: f64,
    pub ranges: BudgetRanges,
    /// constraint-violation penalty weight (the paper's "penalty-driven
    /// constraint handling")
    pub penalty: f64,
}

pub const STATE_DIM: usize = 5;
pub const ACTION_DIM: usize = 3;

impl DesignEnv {
    pub fn new(platform: Platform, lambda: f64, ranges: BudgetRanges) -> DesignEnv {
        DesignEnv { platform, lambda, ranges, penalty: 4.0 }
    }

    /// Sample a QoS context (one episode's state).
    pub fn sample_context(&self, rng: &mut Rng) -> Problem {
        Problem::new(
            self.platform,
            self.lambda,
            rng.range(self.ranges.t0.0, self.ranges.t0.1),
            rng.range(self.ranges.e0.0, self.ranges.e0.1),
        )
    }

    /// Normalized state features for a context.
    pub fn state(&self, p: &Problem) -> Vec<f64> {
        vec![
            p.t0 / self.ranges.t0.1,
            p.e0 / self.ranges.e0.1,
            // how hard is the delay budget? (min-delay at 1 bit vs T0)
            (self.platform.min_delay(1.0) / p.t0).min(4.0),
            (self.platform.min_delay(self.platform.b_max as f64) / p.t0).min(4.0),
            (self.lambda.ln() / 10.0).clamp(-1.0, 1.0),
        ]
    }

    /// Map a raw action in R³ (squashed here) to a concrete design.
    pub fn action_to_design(&self, a: &[f64]) -> Design {
        let sq = |x: f64| 0.5 * (x.tanh() + 1.0); // -> (0,1)
        let b_hat = (1.0 + sq(a[0]) * (self.platform.b_max as f64 - 1.0)).round() as u32;
        Design {
            b_hat: b_hat.clamp(1, self.platform.b_max),
            f: (0.02 + 0.98 * sq(a[1])) * self.platform.device.f_max,
            f_tilde: (0.02 + 0.98 * sq(a[2])) * self.platform.server.f_max,
        }
    }

    /// Reward: the (monotone) log of the bound gap for feasible designs —
    /// the gap decays ~2^-b̂, so -log2 gives a learning signal that is
    /// roughly linear in the bit-width instead of vanishing at high b̂;
    /// constraint violations are penalized proportionally (the paper's
    /// penalty-driven handling).
    pub fn reward(&self, p: &Problem, d: &Design) -> f64 {
        let gap = p.objective(d.b_hat as f64) * self.lambda;
        let t = p.total_delay(d);
        let e = p.total_energy(d);
        let viol = ((t - p.t0) / p.t0).max(0.0) + ((e - p.e0) / p.e0).max(0.0);
        0.15 * (-(gap + 1e-12).log2()) - self.penalty * viol.min(10.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::opt::bisection;

    fn env() -> DesignEnv {
        DesignEnv::new(Platform::paper_blip2(), 15.0, BudgetRanges::default())
    }

    #[test]
    fn actions_map_into_valid_designs() {
        let e = env();
        for a in [[-5.0, -5.0, -5.0], [0.0, 0.0, 0.0], [5.0, 5.0, 5.0]] {
            let d = e.action_to_design(&a);
            assert!(d.b_hat >= 1 && d.b_hat <= e.platform.b_max);
            assert!(d.f > 0.0 && d.f <= e.platform.device.f_max);
            assert!(d.f_tilde > 0.0 && d.f_tilde <= e.platform.server.f_max);
        }
    }

    #[test]
    fn optimal_design_maximizes_reward_among_feasible() {
        let e = env();
        let mut rng = Rng::new(0);
        let p = e.sample_context(&mut rng);
        let opt = bisection::solve(&p).unwrap().design;
        let r_opt = e.reward(&p, &opt);
        // any feasible design with fewer bits scores worse
        for b in 1..opt.b_hat {
            if let Some(d) = p.plan_design(b) {
                assert!(e.reward(&p, &d) <= r_opt + 1e-12);
            }
        }
    }

    #[test]
    fn violations_are_penalized() {
        let e = env();
        let p = Problem::new(e.platform, e.lambda, 2.5, 1.5);
        let feasible = bisection::solve(&p).unwrap().design;
        let violating = Design {
            b_hat: e.platform.b_max,
            f: e.platform.device.f_max,
            f_tilde: e.platform.server.f_max,
        };
        assert!(e.reward(&p, &violating) < e.reward(&p, &feasible));
    }

    #[test]
    fn state_features_are_bounded() {
        let e = env();
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let p = e.sample_context(&mut rng);
            let s = e.state(&p);
            assert_eq!(s.len(), STATE_DIM);
            assert!(s.iter().all(|v| v.is_finite() && v.abs() <= 4.0), "{s:?}");
        }
    }
}
