//! PPO-based design baseline (paper §VI-C, benchmark scheme 1, after [12]).
//!
//! The joint quantization/frequency decision is modeled as a one-step MDP
//! (contextual bandit): the state encodes the QoS budgets and platform
//! statistics, the continuous action maps to (b̂, f, f̃), and the reward is
//! the negative bound gap with penalty-driven constraint handling — the
//! exact structure whose initialization/exploration sensitivity the paper
//! credits for the proposed design's advantage.

pub mod env;
pub mod policy;
pub mod ppo;

pub use env::DesignEnv;
pub use policy::GaussianPolicy;
pub use ppo::{Ppo, PpoConfig};
