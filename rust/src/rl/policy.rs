//! Diagonal-Gaussian policy and value networks for PPO.

use crate::nn::{Activation, Mlp};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct GaussianPolicy {
    /// state -> action mean
    pub net: Mlp,
    /// state-independent log standard deviations
    pub log_std: Vec<f64>,
}

impl GaussianPolicy {
    pub fn new(state_dim: usize, action_dim: usize, hidden: usize, rng: &mut Rng) -> Self {
        GaussianPolicy {
            net: Mlp::new(&[state_dim, hidden, hidden, action_dim], Activation::Tanh, rng),
            log_std: vec![-0.3; action_dim],
        }
    }

    pub fn mean(&self, state: &[f64]) -> Vec<f64> {
        self.net.forward(state)
    }

    pub fn sample(&self, state: &[f64], rng: &mut Rng) -> Vec<f64> {
        self.mean(state)
            .into_iter()
            .zip(&self.log_std)
            .map(|(m, ls)| m + ls.exp() * rng.normal())
            .collect()
    }

    /// log π(a|s) for a diagonal Gaussian.
    pub fn log_prob(&self, state: &[f64], action: &[f64]) -> f64 {
        let mean = self.mean(state);
        Self::log_prob_given_mean(&mean, &self.log_std, action)
    }

    pub fn log_prob_given_mean(mean: &[f64], log_std: &[f64], action: &[f64]) -> f64 {
        const HALF_LN_2PI: f64 = 0.918_938_533_204_672_7;
        mean.iter()
            .zip(log_std)
            .zip(action)
            .map(|((m, ls), a)| {
                let z = (a - m) / ls.exp();
                -0.5 * z * z - ls - HALF_LN_2PI
            })
            .sum()
    }

    /// Gaussian entropy (bits of exploration left).
    pub fn entropy(&self) -> f64 {
        const HALF_LN_2PIE: f64 = 1.418_938_533_204_672_7;
        self.log_std.iter().map(|ls| ls + HALF_LN_2PIE).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_prob_peaks_at_mean() {
        let mut rng = Rng::new(0);
        let pi = GaussianPolicy::new(3, 2, 16, &mut rng);
        let s = [0.1, 0.2, 0.3];
        let mean = pi.mean(&s);
        let at_mean = pi.log_prob(&s, &mean);
        let off: Vec<f64> = mean.iter().map(|m| m + 0.5).collect();
        assert!(at_mean > pi.log_prob(&s, &off));
    }

    #[test]
    fn log_prob_matches_univariate_formula() {
        let mean = [1.0];
        let log_std = [0.2f64];
        let a = [1.7];
        let sigma = log_std[0].exp();
        let expected = -0.5 * ((a[0] - mean[0]) / sigma).powi(2)
            - sigma.ln()
            - 0.5 * (2.0 * std::f64::consts::PI).ln();
        let got = GaussianPolicy::log_prob_given_mean(&mean, &log_std, &a);
        assert!((got - expected).abs() < 1e-12);
    }

    #[test]
    fn samples_concentrate_as_std_shrinks() {
        let mut rng = Rng::new(1);
        let mut pi = GaussianPolicy::new(2, 1, 8, &mut rng);
        let s = [0.5, -0.5];
        pi.log_std[0] = -4.0;
        let m = pi.mean(&s)[0];
        for _ in 0..50 {
            let a = pi.sample(&s, &mut rng)[0];
            assert!((a - m).abs() < 0.2);
        }
    }

    #[test]
    fn entropy_increases_with_std() {
        let mut rng = Rng::new(2);
        let mut pi = GaussianPolicy::new(2, 2, 8, &mut rng);
        let e1 = pi.entropy();
        pi.log_std = vec![1.0, 1.0];
        assert!(pi.entropy() > e1);
    }
}
