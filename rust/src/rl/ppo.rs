//! Clipped-surrogate PPO on the one-step design environment.
//!
//! Episodes are single decisions, so the advantage reduces to
//! A = r - V(s) (no bootstrapping/GAE horizon). Policy and value networks
//! are the hand-backprop MLPs from [`crate::nn`]; gradients of the clipped
//! surrogate flow through the Gaussian mean analytically:
//! ∂logπ/∂μ_i = (a_i - μ_i)/σ_i², ∂logπ/∂logσ_i = z_i² - 1.

use super::env::{DesignEnv, ACTION_DIM, STATE_DIM};
use super::policy::GaussianPolicy;
use crate::nn::{Activation, Adam, Mlp};
use crate::opt::problem::{Design, Problem};
use crate::util::rng::Rng;

#[derive(Debug, Clone, Copy)]
pub struct PpoConfig {
    pub iterations: usize,
    pub batch: usize,
    pub epochs: usize,
    pub clip: f64,
    pub lr_policy: f64,
    pub lr_value: f64,
    pub entropy_coef: f64,
    pub hidden: usize,
}

impl Default for PpoConfig {
    fn default() -> Self {
        PpoConfig {
            iterations: 80,
            batch: 256,
            epochs: 4,
            clip: 0.2,
            lr_policy: 3e-3,
            lr_value: 1e-2,
            entropy_coef: 1e-3,
            hidden: 32,
        }
    }
}

pub struct Ppo {
    pub env: DesignEnv,
    pub policy: GaussianPolicy,
    pub value: Mlp,
    cfg: PpoConfig,
    opt_policy: Adam,
    opt_value: Adam,
    /// mean reward per training iteration (learning curve)
    pub reward_trace: Vec<f64>,
}

struct Transition {
    state: Vec<f64>,
    action: Vec<f64>,
    reward: f64,
    log_prob_old: f64,
}

impl Ppo {
    pub fn new(env: DesignEnv, cfg: PpoConfig, rng: &mut Rng) -> Ppo {
        let policy = GaussianPolicy::new(STATE_DIM, ACTION_DIM, cfg.hidden, rng);
        let value = Mlp::new(&[STATE_DIM, cfg.hidden, 1], Activation::Tanh, rng);
        let n_pol = policy.net.n_params() + ACTION_DIM;
        let n_val = value.n_params();
        Ppo {
            env,
            opt_policy: Adam::new(n_pol, cfg.lr_policy),
            opt_value: Adam::new(n_val, cfg.lr_value),
            policy,
            value,
            cfg,
            reward_trace: Vec::new(),
        }
    }

    fn collect(&self, rng: &mut Rng) -> Vec<Transition> {
        (0..self.cfg.batch)
            .map(|_| {
                let problem = self.env.sample_context(rng);
                let state = self.env.state(&problem);
                let action = self.policy.sample(&state, rng);
                let design = self.env.action_to_design(&action);
                let reward = self.env.reward(&problem, &design);
                let log_prob_old = self.policy.log_prob(&state, &action);
                Transition { state, action, reward, log_prob_old }
            })
            .collect()
    }

    /// One PPO iteration: collect a batch, update policy (clipped
    /// surrogate) and value (MSE) for `epochs` passes.
    pub fn train_iteration(&mut self, rng: &mut Rng) -> f64 {
        let batch = self.collect(rng);
        let mean_reward = batch.iter().map(|t| t.reward).sum::<f64>() / batch.len() as f64;

        // advantages, normalized
        let mut adv: Vec<f64> = batch
            .iter()
            .map(|t| t.reward - self.value.forward(&t.state)[0])
            .collect();
        let m = adv.iter().sum::<f64>() / adv.len() as f64;
        let sd = (adv.iter().map(|a| (a - m) * (a - m)).sum::<f64>()
            / adv.len() as f64)
            .sqrt()
            .max(1e-6);
        for a in &mut adv {
            *a = (*a - m) / sd;
        }

        for _ in 0..self.cfg.epochs {
            // ---- policy update ----
            let mut grads = self.policy.net.zero_grads();
            let mut grad_log_std = vec![0.0; ACTION_DIM];
            for (t, &a_hat) in batch.iter().zip(&adv) {
                let (mean, cache) = self.policy.net.forward_cached(&t.state);
                let log_prob =
                    GaussianPolicy::log_prob_given_mean(&mean, &self.policy.log_std, &t.action);
                let ratio = (log_prob - t.log_prob_old).exp();
                // clipped surrogate: dL/dratio (we *minimize* -L)
                let clipped = ratio
                    .clamp(1.0 - self.cfg.clip, 1.0 + self.cfg.clip);
                let use_unclipped = (ratio * a_hat) <= (clipped * a_hat);
                // gradient flows only through the unclipped branch
                if use_unclipped {
                    let coef = -a_hat * ratio / batch.len() as f64;
                    // d log_prob / d mean_i = (a_i - mu_i) / sigma_i^2
                    let mut dmean = vec![0.0; ACTION_DIM];
                    for i in 0..ACTION_DIM {
                        let sigma2 = (2.0 * self.policy.log_std[i]).exp();
                        dmean[i] = coef * (t.action[i] - mean[i]) / sigma2;
                        // d log_prob / d log_std_i = z^2 - 1
                        let z = (t.action[i] - mean[i])
                            / self.policy.log_std[i].exp();
                        grad_log_std[i] += coef * (z * z - 1.0);
                    }
                    self.policy.net.backward(&cache, &dmean, &mut grads);
                }
            }
            // entropy bonus: d(-c·H)/d log_std = -c
            for g in grad_log_std.iter_mut() {
                *g -= self.cfg.entropy_coef;
            }
            let mut flat = self.policy.net.flat_params();
            flat.extend_from_slice(&self.policy.log_std);
            let mut gflat = Mlp::flat_grads(&grads);
            gflat.extend_from_slice(&grad_log_std);
            self.opt_policy.step(&mut flat, &gflat, Some(5.0));
            let (net_flat, ls_flat) = flat.split_at(flat.len() - ACTION_DIM);
            self.policy.net.set_flat_params(net_flat);
            self.policy.log_std.copy_from_slice(ls_flat);
            for ls in &mut self.policy.log_std {
                *ls = ls.clamp(-3.5, 1.0);
            }

            // ---- value update ----
            let mut vgrads = self.value.zero_grads();
            for t in &batch {
                let (v, cache) = self.value.forward_cached(&t.state);
                let dout = [2.0 * (v[0] - t.reward) / batch.len() as f64];
                self.value.backward(&cache, &dout, &mut vgrads);
            }
            let mut vflat = self.value.flat_params();
            let vg = Mlp::flat_grads(&vgrads);
            self.opt_value.step(&mut vflat, &vg, Some(5.0));
            self.value.set_flat_params(&vflat);
        }
        self.reward_trace.push(mean_reward);
        mean_reward
    }

    pub fn train(&mut self, rng: &mut Rng) {
        for _ in 0..self.cfg.iterations {
            self.train_iteration(rng);
        }
    }

    /// Deterministic (mean-action) design for a QoS context.
    pub fn solve(&self, problem: &Problem) -> Design {
        let state = self.env.state(problem);
        self.env.action_to_design(&self.policy.mean(&state))
    }

    /// Deployment-guarded variant: if the raw PPO design violates the
    /// budgets, degrade the bit-width (re-planning frequencies) until
    /// feasible — an infeasible design cannot be deployed. Returns None if
    /// no bit-width is feasible.
    pub fn solve_projected(&self, problem: &Problem) -> Option<Design> {
        let raw = self.solve(problem);
        if problem.is_feasible(&raw) {
            return Some(raw);
        }
        (1..=raw.b_hat)
            .rev()
            .find_map(|b| problem.plan_design(b))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rl::env::BudgetRanges;
    use crate::system::Platform;

    fn small_cfg() -> PpoConfig {
        PpoConfig { iterations: 30, batch: 128, ..PpoConfig::default() }
    }

    #[test]
    fn learns_to_improve_reward() {
        let env = DesignEnv::new(Platform::paper_blip2(), 15.0, BudgetRanges::default());
        let mut rng = Rng::new(0);
        let mut ppo = Ppo::new(env, small_cfg(), &mut rng);
        ppo.train(&mut rng);
        let early: f64 = ppo.reward_trace[..5].iter().sum::<f64>() / 5.0;
        let n = ppo.reward_trace.len();
        let late: f64 = ppo.reward_trace[n - 5..].iter().sum::<f64>() / 5.0;
        assert!(
            late > early + 0.05,
            "no learning: early {early:.3} late {late:.3}"
        );
    }

    #[test]
    fn projected_solution_is_feasible() {
        let env = DesignEnv::new(Platform::paper_blip2(), 15.0, BudgetRanges::default());
        let mut rng = Rng::new(1);
        let mut ppo = Ppo::new(env, PpoConfig { iterations: 10, ..small_cfg() }, &mut rng);
        ppo.train(&mut rng);
        for (t0, e0) in [(3.5, 2.0), (1.5, 1.0), (2.5, 0.8)] {
            let p = Problem::new(Platform::paper_blip2(), 15.0, t0, e0);
            if let Some(d) = ppo.solve_projected(&p) {
                assert!(p.is_feasible(&d), "{d:?} at ({t0},{e0})");
            }
        }
    }
}
