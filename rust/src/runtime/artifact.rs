//! Artifact registry: the manifest-driven view of everything `make
//! artifacts` produced, with compile-once executable caching.

use crate::runtime::client::{Executable, Runtime};
use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

pub struct Registry {
    pub dir: PathBuf,
    pub manifest: Json,
    pub runtime: Runtime,
    exe_cache: RefCell<HashMap<String, Rc<Executable>>>,
}

impl Registry {
    /// Open the artifacts directory (validates the manifest exists).
    pub fn open(dir: &Path) -> Result<Registry> {
        let manifest = json::parse_file(&dir.join("manifest.json"))
            .context("artifacts not built? run `make artifacts`")?;
        Ok(Registry {
            dir: dir.to_path_buf(),
            manifest,
            runtime: Runtime::cpu()?,
            exe_cache: RefCell::new(HashMap::new()),
        })
    }

    /// Open the default location (`QACI_ARTIFACTS` or ./artifacts).
    pub fn open_default() -> Result<Registry> {
        Registry::open(&crate::artifacts_dir())
    }

    /// Compile (or fetch the cached) executable for an artifact file name.
    pub fn executable(&self, file: &str) -> Result<Rc<Executable>> {
        if let Some(e) = self.exe_cache.borrow().get(file) {
            return Ok(e.clone());
        }
        let exe = Rc::new(self.runtime.compile_file(&self.dir.join(file))?);
        self.exe_cache.borrow_mut().insert(file.to_string(), exe.clone());
        Ok(exe)
    }

    /// Manifest entry for a model.
    pub fn model(&self, name: &str) -> Result<&Json> {
        self.manifest
            .at(&["models", name])
            .with_context(|| format!("model {name} not in manifest"))
    }

    /// Names of all shipped models.
    pub fn model_names(&self) -> Vec<&str> {
        self.manifest
            .get("models")
            .map(|m| m.keys())
            .unwrap_or_default()
    }

    /// Golden vectors (written by aot.py for integration tests).
    pub fn golden(&self) -> Result<Json> {
        json::parse_file(&self.dir.join("golden.json"))
    }

    pub fn compiled_count(&self) -> usize {
        self.exe_cache.borrow().len()
    }
}
