//! Thin PJRT wrapper: compile HLO text modules once, execute many times.
//!
//! Interchange is HLO *text* (see /opt/xla-example/README.md): jax >= 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids.

use anyhow::{Context, Result};
use std::path::Path;

/// Process-wide PJRT client (CPU).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO text file into an executable.
    pub fn compile_file(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe, name: path.display().to_string() })
    }
}

/// A compiled module. All our AOT modules are lowered with
/// `return_tuple=True`, so outputs arrive as a 1-tuple.
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Executable {
    /// Execute with borrowed input literals; returns the untupled result.
    pub fn run(&self, args: &[&xla::Literal]) -> Result<xla::Literal> {
        let out = self
            .exe
            .execute::<&xla::Literal>(args)
            .with_context(|| format!("executing {}", self.name))?;
        let lit = out[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        lit.to_tuple1()
            .with_context(|| format!("untupling result of {}", self.name))
    }

    /// Execute and read back an f32 tensor.
    pub fn run_f32(&self, args: &[&xla::Literal]) -> Result<Vec<f32>> {
        Ok(self.run(args)?.to_vec::<f32>()?)
    }

    /// Execute and read back an i32 tensor (token ids).
    pub fn run_i32(&self, args: &[&xla::Literal]) -> Result<Vec<i32>> {
        Ok(self.run(args)?.to_vec::<i32>()?)
    }
}

/// Build an f32 literal of the given shape from a host slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(n == data.len(), "shape {dims:?} != data len {}", data.len());
    let bytes: &[u8] = unsafe {
        std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
    };
    Ok(xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, dims, bytes)?)
}

/// Scalar f32 literal.
pub fn literal_scalar(v: f32) -> Result<xla::Literal> {
    literal_f32(&[v], &[])
}
